#!/usr/bin/env bash
# CI gate: build, test, doc-lint (broken intra-doc links fail), format check.
# Usage: ./ci.sh   (from the repository root; fully offline)
set -euo pipefail
cd "$(dirname "$0")"

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

echo "==> cargo doc --no-deps (rustdoc warnings are errors)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

echo "==> cargo fmt --check"
cargo fmt --check

echo "CI gate passed."
