#!/usr/bin/env bash
# CI gate: build, test, quickstart + LOO end-to-end smokes, doc-lint (broken
# intra-doc links fail), format and clippy checks (both guarded: skipped
# when the component is not installed), and the kernel-bench smoke that
# emits the BENCH_kernels.json perf trajectory.
#
# Usage:
#   ./ci.sh                 full gate (from the repository root; fully offline)
#   ./ci.sh --bench-smoke   only the kernel bench at tiny sizes + JSON validation
set -euo pipefail
cd "$(dirname "$0")"

bench_smoke() {
  # smoke runs validate the harness + JSON shape into an UNTRACKED scratch
  # file: tiny-size reps=1 numbers must never land in the tracked
  # BENCH_kernels.json perf trajectory, which only the manual full-size run
  # (cargo bench --bench bench_kernels) writes
  local out="target/BENCH_kernels.smoke.json"
  mkdir -p target
  echo "==> bench_kernels smoke (tiny sizes, JSON validity) -> $out"
  cargo bench --bench bench_kernels -- --smoke --out "$out"
  test -s "$out"
  grep -q '"kernel"' "$out"
  grep -q '"packed_secs"' "$out"
  # the factor-update subsystem stages and the LOO structural phase counts
  grep -q '"chud_r1"' "$out"
  grep -q '"chud_rk"' "$out"
  grep -q '"loo_sweep"' "$out"
  grep -q '"loo_phases"' "$out"
  grep -q '"per_row_chol": 0' "$out"
  echo "bench smoke passed: $out present and well-formed."
}

if [[ "${1:-}" == "--bench-smoke" ]]; then
  bench_smoke
  exit 0
fi

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

echo "==> cargo run --release --example quickstart (end-to-end smoke gate)"
cargo run --release --example quickstart

echo "==> cargo run --release --example loo (LOO downdate-engine smoke gate)"
cargo run --release --example loo

echo "==> cargo doc --no-deps (rustdoc warnings are errors)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

if cargo fmt --version >/dev/null 2>&1; then
  echo "==> cargo fmt --check"
  cargo fmt --check
else
  echo "==> rustfmt not installed; skipping format check"
fi

if cargo clippy --version >/dev/null 2>&1; then
  echo "==> cargo clippy --all-targets -- -D warnings"
  cargo clippy --all-targets -- -D warnings
else
  echo "==> cargo clippy not installed; skipping lint step"
fi

# keep the bench harness honest: every full gate compiles and runs it at
# smoke sizes and validates the emitted JSON (into target/, untracked —
# the tracked BENCH_kernels.json trajectory is refreshed only by the
# manual full-size run: cargo bench --bench bench_kernels)
bench_smoke

echo "CI gate passed."
