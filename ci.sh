#!/usr/bin/env bash
# CI gate: build, test, quickstart + LOO + factor-level-k-fold (fig2)
# end-to-end smokes, the cross-mode conformance suite, the chaos
# (fault-injection) suite run twice for seeded determinism, the
# accuracy/cost-ladder certification suite (aloocv vs exact loo), the
# observability gate (obs no-perturbation + ledger/trace artifact
# validation), doc-lint (broken intra-doc links fail), format and clippy
# checks (both guarded: skipped when the component is not installed), and
# the kernel-bench smoke that emits the BENCH_kernels.json perf trajectory.
#
# Usage:
#   ./ci.sh                 full gate (from the repository root; fully offline)
#   ./ci.sh --bench-smoke   only the kernel bench at tiny sizes + JSON validation
#   ./ci.sh --conformance   only the cross-mode conformance suite
#                           (fold_strategy refactor|downdate × --mode loo,
#                           bitwise at workers 1/2/4)
#   ./ci.sh --backends      only the per-backend kernel conformance suite,
#                           once per micro-kernel backend the host supports
#                           (scalar always; avx2/neon when detected)
#   ./ci.sh --chaos         only the deterministic fault-injection suite
#                           (NaN ingest, Gram spikes, drift-budget
#                           exhaustion, worker panics, garbage bench file),
#                           run twice to pin seeded determinism
#   ./ci.sh --tiers         only the accuracy/cost-ladder certification
#                           suite (aloocv vs exact loo: λ* within a decade,
#                           bitwise worker invariance at 1/2/4, leverage
#                           escalation through the recovery ladder)
#   ./ci.sh --obs           only the observability gate: tests/obs.rs
#                           (no-perturbation + worker-invariant event
#                           content) plus an end-to-end sweep that writes
#                           --ledger-out / --trace-out artifacts and
#                           validates both with python3
#   ./ci.sh --service       only the streaming-service gate: tests/service.rs
#                           (bitwise worker/batch invariance of the traffic
#                           replay, window refold round-trip, non-blocking
#                           queries) plus an end-to-end `pichol serve` replay
#                           that writes a --ledger-out artifact, validated
#                           with python3 including a full-precision float
#                           parse-back of every numeric field
set -euo pipefail
cd "$(dirname "$0")"

conformance() {
  # the cross-mode conformance suite: fold_strategy=refactor vs =downdate vs
  # --mode loo on the seeded problem generators, λ*/curve agreement ≤1e-9
  # RMS, bitwise worker invariance at {1,2,4}, and the fold-granular
  # breakdown-fallback injection — tests/conformance.rs end to end
  echo "==> cross-mode conformance suite (refactor | downdate | loo, workers 1/2/4)"
  cargo test -q --test conformance
}

backends() {
  # the scalar-vs-vector bitwise conformance suite (tests/kernel_backends.rs),
  # once per micro-kernel backend this host can run: the env var pins the
  # dispatch default so even the tests that never call force_backend run
  # their library code on the backend under test
  local list="scalar" arch
  arch="$(uname -m)"
  if [[ "$arch" == "x86_64" ]] \
     && grep -q avx2 /proc/cpuinfo 2>/dev/null \
     && grep -q fma /proc/cpuinfo 2>/dev/null; then
    list="$list avx2"
  fi
  if [[ "$arch" == "aarch64" || "$arch" == "arm64" ]]; then
    list="$list neon"
  fi
  echo "==> per-backend kernel conformance (backends: $list)"
  local b
  for b in $list; do
    echo "==> cargo test --test kernel_backends [PICHOL_KERNEL_BACKEND=$b]"
    PICHOL_KERNEL_BACKEND="$b" cargo test -q --test kernel_backends
  done
}

chaos() {
  # the deterministic fault-injection suite (tests/chaos.rs): every injector
  # is seeded/addressed, so two runs of the whole suite must both pass with
  # identical outcomes — the second run is the seeded-determinism gate (a
  # flaky injector, a leaked armed panic, or scheduling-dependent
  # degradation records would break it). The suite also pins the obs
  # no-perturbation contract under faults: arming the observability layer
  # on a run with injected spikes + worker panics must leave every numeric
  # output bitwise identical to the obs-off run.
  echo "==> chaos suite (fault injection: ingest / spike / drift / panic / bench-file)"
  cargo test -q --test chaos
  echo "==> chaos suite, second seeded run (determinism gate)"
  cargo test -q --test chaos
}

tiers() {
  # the accuracy/cost-ladder certification suite (tests/tiers.rs): the
  # hat-diagonal ALOOCV tier must land λ* within one decade of exact LOO on
  # every seeded generator, stay bitwise identical at workers {1,2,4}, and
  # route high-leverage rows (h_i ≥ 1−ε) through the recovery ladder as
  # recorded degradations instead of Inf/NaN scores
  echo "==> accuracy/cost-ladder certification suite (aloocv vs loo, workers 1/2/4)"
  cargo test -q --test tiers
}

obs() {
  # the observability gate. tests/obs.rs pins the three contracts (off by
  # default / bitwise non-perturbing when armed / event *content* invariant
  # across worker counts); the end-to-end run below exercises the artifact
  # writers: a small k-fold sweep with both --ledger-out and --trace-out,
  # --batch pinned so task granularity (and thus the event log) does not
  # depend on the worker count, and a sub-epsilon trust budget so the
  # recovery ladder climbs deterministically and the ledger carries
  # degradation records, not just the clean-path ones.
  echo "==> observability suite (no-perturbation, worker invariance, ledger/trace)"
  cargo test -q --test obs
  local led="target/obs_run.jsonl" trc="target/obs_trace.json"
  mkdir -p target
  echo "==> end-to-end obs artifacts (k-fold sweep) -> $led + $trc"
  cargo run --release --bin pichol -- cv \
    --dataset mnist --solver chol --n 48 --h 12 --folds 3 --grid 8 --g 4 \
    --threads 2 --batch 4 --trust-budget 1e-300 \
    --ledger-out "$led" --trace-out "$trc"
  test -s "$led"
  test -s "$trc"
  # every ledger line must parse as one self-contained JSON object, open
  # with provenance, close with the summary, and carry span quantiles
  python3 - "$led" <<'EOF'
import json, sys
recs = [json.loads(line) for line in open(sys.argv[1]) if line.strip()]
kinds = [r["record"] for r in recs]
assert kinds[0] == "provenance", kinds[:1]
assert kinds[-1] == "summary", kinds[-1:]
assert "degradation" in kinds, "sub-epsilon trust budget must degrade"
assert "phase" in kinds and "task_kind" in kinds, sorted(set(kinds))
for r in recs:
    if r["record"] in ("phase", "task_kind"):
        assert "p50_us" in r and "p90_us" in r and "p99_us" in r, r
print("ledger OK: %d records, kinds=%s" % (len(recs), sorted(set(kinds))))
EOF
  # the Chrome trace must be one valid JSON document of complete spans
  python3 -m json.tool "$trc" >/dev/null
  grep -q '"record":"provenance"' "$led"
  grep -q '"record":"degradation"' "$led"
  grep -q '"p50_us"' "$led"
  grep -q '"p99_us"' "$led"
  grep -q '"ph":"X"' "$trc"
  echo "obs gate passed: $led + $trc present and well-formed."
}

service() {
  # the streaming-service gate. tests/service.rs pins the tentpole
  # acceptance bar (same seeded replay → bitwise-identical snapshots and
  # identical degradation ledgers at eval workers {1,2,4} × admission
  # batches {1,3,64}; refold round-trips bitwise against a from-scratch
  # Gram; queries never block and epochs are monotone). The end-to-end run
  # below drives `pichol serve` — the bounded admission queue, sliding
  # window with segment retirement, and epoch-swapped serving — with a
  # ledger artifact, validated including a full-precision parse-back of
  # every float field (the `{v:e}` ledger fix: round-tripping a ledger
  # must reproduce the run's numbers bit for bit).
  echo "==> streaming-service suite (worker/batch invariance, refold, non-blocking queries)"
  cargo test -q --test service
  local led="target/service_run.jsonl"
  mkdir -p target
  echo "==> end-to-end service replay (pichol serve) -> $led"
  cargo run --release --bin pichol -- serve \
    --dataset mnist --n 600 --h 8 --batch 8 --queries 2 \
    --window 512 --refresh-every 48 --queue-depth 8 --tier aloocv \
    --grid 9 --g 4 --threads 2 \
    --trust-max-hops 40 --ledger-out "$led"
  test -s "$led"
  python3 - "$led" <<'EOF'
import json, sys
recs = [json.loads(line) for line in open(sys.argv[1]) if line.strip()]
kinds = [r["record"] for r in recs]
assert kinds[0] == "provenance", kinds[:1]
assert kinds[-1] == "summary", kinds[-1:]
prov = recs[0]
assert prov["mode"] == "service", prov["mode"]
assert "degradation" in kinds, "the hop budget must have tripped re-anchors"
assert "phase" in kinds and "task_kind" in kinds, sorted(set(kinds))
# full-precision parse-back: every float field must round-trip exactly
# (the ledger writes {v:e}, not a truncated {v:.6e})
def floats(obj, path=""):
    if isinstance(obj, dict):
        for k, v in obj.items():
            yield from floats(v, path + "/" + k)
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            yield from floats(v, "%s[%d]" % (path, i))
    elif isinstance(obj, float):
        yield path, obj
n = 0
for r in recs:
    for path, v in floats(r):
        s = json.dumps(v)
        assert json.loads(s) == v or (v != v and json.loads(s) != json.loads(s)), (path, v)
        n += 1
print("service ledger OK: %d records, %d floats round-tripped, kinds=%s"
      % (len(recs), n, sorted(set(kinds))))
EOF
  grep -q '"record":"provenance"' "$led"
  grep -q '"mode":"service"' "$led"
  grep -q '"record":"degradation"' "$led"
  grep -q '"p50_us"' "$led"
  echo "service gate passed: $led present and well-formed."
}

bench_smoke() {
  # smoke runs validate the harness + JSON shape into an UNTRACKED scratch
  # file: tiny-size reps=1 numbers must never land in the tracked
  # BENCH_kernels.json perf trajectory, which only the manual full-size run
  # (cargo bench --bench bench_kernels) writes
  local out="target/BENCH_kernels.smoke.json"
  mkdir -p target
  echo "==> bench_kernels smoke (tiny sizes, JSON validity) -> $out"
  cargo bench --bench bench_kernels -- --smoke --out "$out"
  test -s "$out"
  grep -q '"kernel"' "$out"
  grep -q '"kernel_backend"' "$out"
  grep -q '"packed_secs"' "$out"
  # the factor-update subsystem stages and the LOO structural phase counts
  grep -q '"chud_r1"' "$out"
  grep -q '"chud_rk"' "$out"
  grep -q '"kfold_downdate"' "$out"
  grep -q '"loo_sweep"' "$out"
  grep -q '"loo_phases"' "$out"
  grep -q '"per_row_chol": 0' "$out"
  # the ALOOCV tier rides the same harness: its sweep row and the
  # structural proof that the fast path did zero per-row factor work
  grep -q '"aloocv_sweep"' "$out"
  grep -q '"aloocv_phases"' "$out"
  grep -q '"per_row_downdate": 0' "$out"
  # the streaming service's replay rides the harness too: admission and
  # snapshot-serve latency quantiles from the deterministic replay
  grep -q '"service_replay"' "$out"
  grep -q '"service_query"' "$out"
  # per-stage latency quantiles ride next to the wall-clock means
  grep -q '"p50_us"' "$out"
  grep -q '"p99_us"' "$out"
  echo "bench smoke passed: $out present and well-formed."
}

if [[ "${1:-}" == "--bench-smoke" ]]; then
  bench_smoke
  exit 0
fi

if [[ "${1:-}" == "--conformance" ]]; then
  conformance
  exit 0
fi

if [[ "${1:-}" == "--backends" ]]; then
  backends
  exit 0
fi

if [[ "${1:-}" == "--chaos" ]]; then
  chaos
  exit 0
fi

if [[ "${1:-}" == "--tiers" ]]; then
  tiers
  exit 0
fi

if [[ "${1:-}" == "--obs" ]]; then
  obs
  exit 0
fi

if [[ "${1:-}" == "--service" ]]; then
  service
  exit 0
fi

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

# the conformance stage re-runs the cross-mode suite as its own named gate
# (guarded like clippy/fmt in spirit: it only needs cargo, so it always runs)
conformance

# scalar-vs-vector bitwise conformance, once per backend the host supports
backends

# deterministic fault injection, twice — the second run pins seeded
# determinism of every injected degradation
chaos

# the accuracy/cost ladder: aloocv certification against exact loo
tiers

# the observability gate: tests/obs.rs + end-to-end ledger/trace artifacts
obs

# the streaming-service gate: tests/service.rs + end-to-end `pichol serve`
# replay with a parse-back-validated ledger artifact
service

echo "==> cargo run --release --example quickstart (end-to-end smoke gate)"
cargo run --release --example quickstart

echo "==> cargo run --release --example loo (LOO downdate-engine smoke gate)"
cargo run --release --example loo

echo "==> cargo run --release --example fig2 (fold_strategy=downdate smoke gate)"
cargo run --release --example fig2

echo "==> cargo doc --no-deps (rustdoc warnings are errors)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

if cargo fmt --version >/dev/null 2>&1; then
  echo "==> cargo fmt --check"
  cargo fmt --check
else
  echo "==> rustfmt not installed; skipping format check"
fi

if cargo clippy --version >/dev/null 2>&1; then
  echo "==> cargo clippy --all-targets -- -D warnings"
  cargo clippy --all-targets -- -D warnings
else
  echo "==> cargo clippy not installed; skipping lint step"
fi

# keep the bench harness honest: every full gate compiles and runs it at
# smoke sizes and validates the emitted JSON (into target/, untracked —
# the tracked BENCH_kernels.json trajectory is refreshed only by the
# manual full-size run: cargo bench --bench bench_kernels)
bench_smoke

echo "CI gate passed."
