#!/usr/bin/env bash
# CI gate: build, test, quickstart end-to-end smoke, doc-lint (broken
# intra-doc links fail), format and clippy checks.
#
# Usage:
#   ./ci.sh                 full gate (from the repository root; fully offline)
#   ./ci.sh --bench-smoke   compile + run the kernel bench at tiny sizes and
#                           validate the emitted BENCH_kernels.json
set -euo pipefail
cd "$(dirname "$0")"

if [[ "${1:-}" == "--bench-smoke" ]]; then
  echo "==> bench_kernels smoke (tiny sizes, JSON validity)"
  cargo bench --bench bench_kernels -- --smoke
  test -s BENCH_kernels.json
  grep -q '"kernel"' BENCH_kernels.json
  grep -q '"packed_secs"' BENCH_kernels.json
  echo "bench smoke passed: BENCH_kernels.json present and well-formed."
  exit 0
fi

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

echo "==> cargo run --release --example quickstart (end-to-end smoke gate)"
cargo run --release --example quickstart

echo "==> cargo doc --no-deps (rustdoc warnings are errors)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

echo "==> cargo fmt --check"
cargo fmt --check

if cargo clippy --version >/dev/null 2>&1; then
  echo "==> cargo clippy --all-targets -- -D warnings"
  cargo clippy --all-targets -- -D warnings
else
  echo "==> cargo clippy not installed; skipping lint step"
fi

echo "CI gate passed."
