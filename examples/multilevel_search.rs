//! Multi-level Cholesky (§6.2): watch the binary search narrow the λ range,
//! and compare its cost/trajectory against piCholesky — the paper's Figure 9
//! story on one fold.
//!
//! ```bash
//! cargo run --release --example multilevel_search
//! ```

use picholesky::cv::{holdout_error, CvConfig, FoldData, Metric};
use picholesky::data::folds::kfold;
use picholesky::data::gram::GramCache;
use picholesky::data::synthetic::{DatasetKind, SyntheticDataset};
use picholesky::linalg::cholesky::{cholesky_shifted, CholeskyError};
use picholesky::linalg::triangular::solve_cholesky;
use picholesky::pichol::mchol::{multilevel_search, MCholParams};
use picholesky::util::{fmt_secs, logspace, PhaseTimer};

fn main() -> picholesky::Result<()> {
    let ds = SyntheticDataset::generate(DatasetKind::CoilLike, 600, 128, 3);
    let folds = kfold(ds.n(), 5, 1);
    // shared-Gram data pipeline: G = XᵀX once, fold Hessian by downdate
    let gram = GramCache::assemble(&ds.x, &ds.y);
    let (xv, yv) = folds[0].materialize_val(&ds.x, &ds.y);
    let mut timer = PhaseTimer::new();
    let data = FoldData::from_gram(&gram, xv, yv, None, &mut timer);

    // the paper's setting: s = 1.5, s0 = 0.0025, centred on the range middle
    let params = MCholParams { s: 1.5, s0: 0.0025 };
    println!("multi-level search: s = {}, s0 = {}", params.s, params.s0);

    let result = multilevel_search(-1.5, params, |lam| -> Result<f64, CholeskyError> {
        let l = cholesky_shifted(&data.h_mat, lam)?;
        let theta = solve_cholesky(&l, &data.g_vec);
        Ok(holdout_error(&data.xv, &data.yv, &theta, Metric::Rmse))
    })?;

    println!("\nprobe trajectory ({} probes, {} factorizations):", result.probes.len(), result.factorizations);
    for (i, p) in result.probes.iter().enumerate() {
        if i % 3 == 0 {
            println!("  level {}", i / 3);
        }
        println!(
            "    λ = {:>10.4e}  err = {:.5}  t = {}",
            p.lambda,
            p.error,
            fmt_secs(p.elapsed)
        );
    }
    println!(
        "\nMChol selected λ = {:.4e} (err {:.5}), final range [{:.4e}, {:.4e}]",
        result.best_lambda, result.best_error, result.final_range.0, result.final_range.1
    );

    // contrast: piCholesky gets a *dense* curve from 4 factorizations
    let cfg = CvConfig::default();
    let grid = logspace(1e-3, 1.0, cfg.q_grid);
    let mut t2 = PhaseTimer::new();
    let mut scratch = picholesky::linalg::Scratch::new();
    let sweep = picholesky::cv::solvers::sweep(
        picholesky::cv::solvers::SolverKind::PiChol,
        &data,
        &grid,
        &cfg,
        &mut scratch,
        &mut t2,
    )?;
    println!(
        "\npiCholesky on the same fold: λ = {:.4e} (err {:.5}) with {} exact factorizations in {}",
        sweep.best_lambda,
        sweep.best_error,
        cfg.g_samples,
        fmt_secs(t2.total())
    );
    println!(
        "MChol needed {} factorizations — this is the Figure 9 gap.",
        result.factorizations
    );
    Ok(())
}
