//! End-to-end driver: the full three-layer system on a real workload.
//!
//! This is the repo's capstone validation: a k-fold cross-validation run
//! where every numeric step on the request path executes inside compiled
//! HLO artifacts (Pallas kernels lowered by `make artifacts`) through the
//! rust PJRT runtime — python is not running. Per fold:
//!
//!   `gram` → `cholvec` → `polyfit` → fused `sweep`   (piCholesky)
//!   `gram` → `exact_sweep`                           (Chol baseline)
//!
//! and at the end the native f64 path re-validates the selected λ.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end [-- h]
//! ```

use picholesky::coordinator::{HloFold, HloPipeline, Metrics};
use picholesky::data::folds::kfold;
use picholesky::data::synthetic::{DatasetKind, SyntheticDataset};
use picholesky::runtime::Engine;
use picholesky::util::fmt_secs;

fn main() -> picholesky::Result<()> {
    let h: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(128);
    let k_folds = 4;

    let engine = Engine::new("artifacts")?;
    let cfg = engine.config(h, None, None)?;
    println!(
        "engine: {} | config {} (n={}, n_val={}, g={}, r={}, m={}, D={})",
        engine.platform(),
        cfg.tag,
        cfg.n,
        cfg.n_val,
        cfg.g,
        cfg.r,
        cfg.m,
        cfg.d_tri
    );

    // dataset sized so every fold's train split has ≥ n rows and its val
    // split ≥ n_val rows (the AOT shapes are static; extras are trimmed)
    let total = ((cfg.n * k_folds).div_ceil(k_folds - 1)).max(cfg.n_val * k_folds) + k_folds;
    let ds = SyntheticDataset::generate(DatasetKind::MnistLike, total, cfg.h, 2024);
    let folds = kfold(total, k_folds, 77);

    let metrics = Metrics::new();
    let pipe = HloPipeline::new(&engine, cfg, &metrics);
    let t0 = std::time::Instant::now();
    pipe.warmup()?;
    println!("compiled 5 artifacts in {}\n", fmt_secs(t0.elapsed().as_secs_f64()));

    let (lo, hi) = ds.kind.lambda_range();
    let mut pi_secs = 0.0;
    let mut exact_secs = 0.0;
    let mut pi_errs = vec![0.0f64; cfg.m];
    let mut exact_errs = vec![0.0f64; cfg.m];
    let mut agreements = 0usize;

    for (fi, fold) in folds.iter().enumerate() {
        // materialize at exactly the lowered shapes: n train rows, n_val val rows
        let (xt, yt, xv, yv) = fold.materialize(&ds.x, &ds.y);
        let hf = HloFold {
            xt: xt.slice(0, cfg.n, 0, cfg.h),
            yt: yt[..cfg.n].to_vec(),
            xv: xv.slice(0, cfg.n_val, 0, cfg.h),
            yv: yv[..cfg.n_val].to_vec(),
        };

        let t = std::time::Instant::now();
        let pi = pipe.run_fold(&hf, lo, hi)?;
        pi_secs += t.elapsed().as_secs_f64();

        let t = std::time::Instant::now();
        let exact = pipe.run_fold_exact(&hf, lo, hi)?;
        exact_secs += t.elapsed().as_secs_f64();

        for i in 0..cfg.m {
            pi_errs[i] += pi.rmse[i] / k_folds as f64;
            exact_errs[i] += exact.rmse[i] / k_folds as f64;
        }
        let agree = (pi.best_idx as i64 - exact.best_idx as i64).abs() <= 1;
        agreements += agree as usize;
        println!(
            "fold {fi}: piCholesky λ*={:.3e} rmse={:.4} | exact λ*={:.3e} rmse={:.4} | λ agree(±1): {}",
            pi.best_lambda(),
            pi.best_rmse(),
            exact.best_lambda(),
            exact.best_rmse(),
            agree
        );
    }

    // aggregate curve + selection
    let best = |errs: &[f64]| {
        errs.iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| a.partial_cmp(b).unwrap())
            .map(|(i, e)| (i, *e))
            .unwrap()
    };
    let grid = pipe.grid(lo, hi);
    let (pi_i, pi_e) = best(&pi_errs);
    let (ex_i, ex_e) = best(&exact_errs);
    println!("\n===== aggregate over {k_folds} folds =====");
    println!(
        "piCholesky: λ* = {:.4e}  mean holdout = {:.4}  sweep time = {}",
        grid[pi_i],
        pi_e,
        fmt_secs(pi_secs)
    );
    println!(
        "exact Chol: λ* = {:.4e}  mean holdout = {:.4}  sweep time = {}",
        grid[ex_i],
        ex_e,
        fmt_secs(exact_secs)
    );
    println!(
        "selected-λ agreement (±1 grid step): {agreements}/{k_folds} folds; \
         curve max gap = {:.3}%",
        100.0
            * pi_errs
                .iter()
                .zip(&exact_errs)
                .map(|(a, b)| (a - b).abs() / b)
                .fold(0.0f64, f64::max)
    );

    // native f64 re-validation of the selected λ (belt and braces)
    let (xt, yt, xv, yv) = folds[0].materialize(&ds.x, &ds.y);
    let xt = xt.slice(0, cfg.n, 0, cfg.h);
    let hm = picholesky::linalg::gemm::syrk_lower(&xt);
    let gv = picholesky::linalg::gemm::gemv_t(&xt, &yt[..cfg.n]);
    let l = picholesky::linalg::cholesky::cholesky_shifted(&hm, grid[pi_i])?;
    let theta = picholesky::linalg::triangular::solve_cholesky(&l, &gv);
    let native_err = picholesky::cv::holdout_error(
        &xv.slice(0, cfg.n_val, 0, cfg.h),
        &yv[..cfg.n_val],
        &theta,
        picholesky::cv::Metric::Rmse,
    );
    println!("native f64 re-validation at λ* (fold 0): rmse = {native_err:.4}");

    println!("\n===== runtime metrics =====");
    print!("{}", metrics.snapshot());
    Ok(())
}
