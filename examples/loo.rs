//! Leave-one-out CV quickstart: the factor-update subsystem at work.
//!
//! One exact `chol(G + λI)` per anchor λ, then every one of the n held-out
//! factors by a rank-1 hyperbolic downdate (`O(d²)` each) — the LOO error
//! curve costs `O(n·d²)` per λ instead of the `O(n·d³)` of per-row
//! refactorization.
//!
//! ```bash
//! cargo run --release --example loo
//! ```

use picholesky::cv::loo::run_loo;
use picholesky::cv::CvConfig;
use picholesky::data::synthetic::{DatasetKind, SyntheticDataset};
use picholesky::util::fmt_secs;

fn main() -> picholesky::Result<()> {
    // 1. a synthetic dataset (same generator as the k-fold quickstart)
    let ds = SyntheticDataset::generate(DatasetKind::MnistLike, 400, 48, 42);
    println!("dataset: {} — n = {}, h = {}", ds.kind.name(), ds.n(), ds.h());

    // 2. exact LOO at g = 4 anchor λ's, PINRMSE-interpolated over the
    //    31-point grid
    let cfg = CvConfig::default();
    let rep = run_loo(&ds, &cfg)?;

    println!(
        "\nselected λ* = {:.4}   LOO-RMSE = {:.4}   ({} held-out solves, wall {})",
        rep.best_lambda,
        rep.best_error,
        rep.n * rep.anchor_lambdas.len(),
        fmt_secs(rep.wall_secs),
    );
    for (lam, rmse) in rep.anchor_lambdas.iter().zip(&rep.anchor_rmse) {
        println!("  anchor λ = {lam:.4}   exact LOO-RMSE = {rmse:.4}");
    }
    println!("phase breakdown:");
    for (phase, secs) in rep.timer.entries() {
        println!("  {phase:<10} {}", fmt_secs(*secs));
    }

    // 3. smoke-gate sanity (ci.sh runs this example): the structural
    //    invariant of the subsystem — one O(d³) factorization per anchor,
    //    one O(d²) downdate per (row, anchor), zero per-row factorizations
    let anchors = rep.anchor_lambdas.len() as u64;
    assert_eq!(rep.timer.count("factor"), anchors, "factor != anchors");
    assert_eq!(
        rep.timer.count("downdate"),
        rep.n as u64 * anchors,
        "downdate != n per anchor"
    );
    assert_eq!(rep.timer.count("chol"), 0, "a per-row O(d³) path crept in");
    assert!(rep.best_error.is_finite() && rep.best_lambda > 0.0);
    assert!(rep.skipped.is_empty(), "unexpected downdate breakdowns");
    println!("\nphase counts OK: factor == {anchors} anchors, downdate == n × anchors");
    Ok(())
}
