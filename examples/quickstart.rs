//! Quickstart: fit a ridge-regression model with piCholesky-accelerated
//! cross-validation in ~30 lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use picholesky::cv::solvers::SolverKind;
use picholesky::cv::{run_cv, CvConfig};
use picholesky::data::synthetic::{DatasetKind, SyntheticDataset};
use picholesky::util::fmt_secs;

fn main() -> picholesky::Result<()> {
    // 1. a dataset: MNIST-like images → Kar–Karnick random polynomial
    //    features (h−1 dims) + intercept, balanced ±1 labels
    let ds = SyntheticDataset::generate(DatasetKind::MnistLike, 1024, 128, 42);
    println!("dataset: {} — n = {}, h = {}", ds.kind.name(), ds.n(), ds.h());

    // 2. cross-validate the regularization parameter with piCholesky:
    //    only g = 4 exact factorizations per fold serve the whole
    //    31-point λ grid (Algorithm 1)
    let cfg = CvConfig::default();
    let report = run_cv(&ds, SolverKind::PiChol, &cfg)?;

    println!(
        "\nselected λ = {:.4}   hold-out RMSE = {:.4}",
        report.best_lambda, report.best_error
    );
    println!("phase breakdown over {} folds:", cfg.k_folds);
    for (phase, secs) in report.timer.entries() {
        println!("  {phase:<10} {}", fmt_secs(*secs));
    }

    // 3. sanity: compare against the exact-Cholesky sweep. With the default
    //    auto thread count the sweep runs in parallel, so compare wall-clock
    //    (total_secs() is the CPU-time-like sum over workers).
    let exact = run_cv(&ds, SolverKind::Chol, &cfg)?;
    println!(
        "\nexact sweep: λ = {:.4}, RMSE = {:.4}, wall {} (piCholesky: {} → {:.2}× faster; \
         cpu {} vs {})",
        exact.best_lambda,
        exact.best_error,
        fmt_secs(exact.wall_secs),
        fmt_secs(report.wall_secs),
        exact.wall_secs / report.wall_secs,
        fmt_secs(exact.total_secs()),
        fmt_secs(report.total_secs()),
    );
    Ok(())
}
