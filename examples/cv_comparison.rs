//! The §6 comparison: all six algorithms (Chol, PIChol, MChol, SVD, t-SVD,
//! r-SVD) on one dataset — a one-machine rendition of Figure 6 + Table 4's
//! columns, fanned across the coordinator's worker pool.
//!
//! ```bash
//! cargo run --release --example cv_comparison
//! ```

use std::sync::Arc;

use picholesky::coordinator::Coordinator;
use picholesky::cv::solvers::SolverKind;
use picholesky::cv::CvConfig;
use picholesky::data::synthetic::{DatasetKind, SyntheticDataset};
use picholesky::util::fmt_secs;

fn main() -> picholesky::Result<()> {
    let (n, h) = (768, 160);
    let coord = Coordinator::default();
    let cfg = CvConfig::default();
    let ds = Arc::new(SyntheticDataset::generate(DatasetKind::CoilLike, n, h, 7));
    println!(
        "dataset {} (n={n}, h={h}), {} folds × {} λ grid, {} workers\n",
        ds.kind.name(),
        cfg.k_folds,
        cfg.q_grid,
        coord.workers()
    );

    let reports = coord.run_matrix(ds, &SolverKind::paper_six(), &cfg);

    println!(
        "{:<8} {:>12} {:>10} {:>10} {:>12}",
        "algo", "λ*", "holdout", "total", "vs Chol"
    );
    let mut chol_secs = None;
    for rep in reports {
        let rep = rep?;
        let total = rep.total_secs();
        if rep.kind == SolverKind::Chol {
            chol_secs = Some(total);
        }
        let speed = chol_secs
            .map(|c| format!("{:.2}×", c / total))
            .unwrap_or_else(|| "—".into());
        println!(
            "{:<8} {:>12.4e} {:>10.4} {:>10} {:>12}",
            rep.kind.name(),
            rep.best_lambda,
            rep.best_error,
            fmt_secs(total),
            speed
        );
    }
    println!(
        "\nexpected shape (paper Table 3/4): PIChol ≈ Chol's error at a fraction of the \
         time; r-SVD fastest but with a distorted error curve."
    );
    Ok(())
}
