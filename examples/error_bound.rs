//! Theorem 4.4 / 4.7 in action: compute the Fréchet-derivative Taylor
//! expansion of the Cholesky map, the R_[a,b] remainder scale, and verify
//! that the measured piCholesky interpolation error sits under the bound.
//!
//! ```bash
//! cargo run --release --example error_bound
//! ```

use picholesky::pichol::bound::{v_pseudoinverse_norm, BoundCalculator};
use picholesky::pichol::{fit, FitOptions};
use picholesky::testutil::random_spd;
use picholesky::util::PhaseTimer;
use picholesky::vectorize::RowWise;

fn main() -> picholesky::Result<()> {
    let h = 24;
    let a = random_spd(h, 1e3, 7);
    let calc = BoundCalculator::new(a.clone());
    println!("A: random SPD, h = {h}, cond = 1e3, D = {}", calc.d_tri());

    // Taylor expansion around λc (Theorem 4.4)
    let lambda_c = 0.5;
    let taylor = calc.taylor_poly(lambda_c);
    println!("\nTheorem 4.4 — second-order Taylor expansion around λc = {lambda_c}:");
    println!("{:<8} {:>14} {:>14}", "γ", "measured", "bound");
    for gamma in [0.05, 0.1, 0.2, 0.3] {
        let lam = lambda_c + gamma;
        let measured = calc.measured_rms_error(lam, &taylor.eval(lam));
        let bound = calc.thm44_rhs(lam, lambda_c, 7);
        println!("{gamma:<8.2} {measured:>14.4e} {bound:>14.4e}");
    }
    println!("(cubic growth in γ on both columns — the O(γ³) remainder)");

    // piCholesky bound (Theorem 4.7)
    let w = 0.2;
    let gamma = 0.3;
    let lams: Vec<f64> = (0..4)
        .map(|i| lambda_c - w + 2.0 * w * i as f64 / 3.0)
        .collect();
    println!(
        "\nTheorem 4.7 — piCholesky fit from g = 4 samples in [{:.2}, {:.2}]:",
        lams[0],
        lams[3]
    );
    println!("‖V†‖₂ = {:.4} (V well-conditioned)", v_pseudoinverse_norm(&lams, 2));

    let mut timer = PhaseTimer::new();
    let interp = fit(
        &a,
        &lams,
        &FitOptions {
            degree: 2,
            strategy: &RowWise,
        },
        &mut timer,
    )?;
    let bound = calc.thm47_rhs(gamma, w, lambda_c, &lams, 2, 7);
    println!("uniform bound over [λc−γ, λc+γ] = {bound:.4e}");
    println!("{:<10} {:>14} {:>10}", "λ", "measured", "ok");
    let mut all_ok = true;
    for i in 0..9 {
        let lam = lambda_c - gamma + 2.0 * gamma * i as f64 / 8.0;
        let measured =
            calc.measured_rms_error(lam, &interp.eval_factor(lam, &RowWise));
        let ok = measured <= bound;
        all_ok &= ok;
        println!(
            "{lam:<10.4} {measured:>14.4e} {:>10}",
            if ok { "ok" } else { "VIOLATED" }
        );
    }
    println!(
        "\nbound {} on all probes (the theory holds; slack is expected — R_[a,b] is \
         a worst-case third-derivative scale).",
        if all_ok { "holds" } else { "VIOLATED" }
    );
    Ok(())
}
