//! Figure-2 smoke, factor-level k-fold edition: where the pipeline time
//! goes under the two fold strategies.
//!
//! The paper's Figure 2 shows the `k·q` Cholesky sweep swallowing the
//! pipeline once `n < k·q·d`. The factor-level engine
//! (`fold_strategy = downdate`, the default) attacks exactly that term:
//! per grid λ it factors `chol(G + λI)` **once** and derives every fold's
//! factor by a chained rank-`n_v` hyperbolic downdate — so the `O(d³)`
//! column of the cost split shrinks from `k·q` factorizations to `q`.
//!
//! ```bash
//! cargo run --release --example fig2
//! ```
//!
//! ci.sh runs this example as the fold-downdate smoke gate: it asserts the
//! structural phase counts (per anchor: `factor == 1`,
//! `fold_downdate == k`, `chol == 0`) and that both strategies produce the
//! same curve.

use picholesky::cv::solvers::SolverKind;
use picholesky::cv::{run_cv, CvConfig, CvReport, FoldStrategy};
use picholesky::data::synthetic::{DatasetKind, SyntheticDataset};
use picholesky::util::fmt_secs;

fn main() -> picholesky::Result<()> {
    // many small folds: the regime the downdate chain exists for
    let (n, h, k, q) = (256usize, 64usize, 8usize, 15usize);
    let ds = SyntheticDataset::generate(DatasetKind::MnistLike, n, h, 42);
    println!(
        "dataset: {} — n = {n}, h = {h}, k = {k} folds, q = {q} grid λ's",
        ds.kind.name()
    );

    let base = CvConfig {
        k_folds: k,
        q_grid: q,
        lambda_range: Some((1e-2, 1.0)),
        ..CvConfig::default()
    };
    let run = |strategy: FoldStrategy| -> picholesky::Result<CvReport> {
        run_cv(
            &ds,
            SolverKind::Chol,
            &CvConfig {
                fold_strategy: strategy,
                ..base.clone()
            },
        )
    };
    let down = run(FoldStrategy::Downdate)?;
    let refr = run(FoldStrategy::Refactor)?;

    // the Figure-2 style split: O(d³) factorizations vs everything else
    println!("\nphase                 downdate     refactor");
    for phase in ["gram", "downdate", "factor", "fold_downdate", "chol", "solve", "holdout"] {
        println!(
            "  {phase:<16} {:>10} {:>12}",
            fmt_secs(down.timer.get(phase)),
            fmt_secs(refr.timer.get(phase)),
        );
    }
    println!(
        "\nλ* = {:.4e} (downdate) vs {:.4e} (refactor)   holdout {:.4} vs {:.4}",
        down.best_lambda, refr.best_lambda, down.best_error, refr.best_error
    );
    println!(
        "O(d³) factorizations: {} (downdate: one per anchor λ) vs {} (refactor: k per λ)",
        down.timer.count("factor"),
        refr.timer.count("chol"),
    );

    // smoke-gate asserts: the structural invariant of the factor-level path
    assert_eq!(down.timer.count("factor"), q as u64, "factor == 1 per anchor");
    assert_eq!(
        down.timer.count("fold_downdate"),
        (q * k) as u64,
        "fold_downdate == k per anchor"
    );
    assert_eq!(down.timer.count("chol"), 0, "no per-cell refactorization");
    assert!(
        down.degradations.is_empty(),
        "unexpected recovery-ladder escalations: {:?}",
        down.degradations
    );
    assert_eq!(refr.timer.count("chol"), (q * k) as u64);

    // and the two strategies tell the same story
    let rms = {
        let s: f64 = down
            .mean_errors
            .iter()
            .zip(&refr.mean_errors)
            .map(|(a, b)| (a - b) * (a - b))
            .sum();
        (s / refr.mean_errors.len() as f64).sqrt()
    };
    assert!(rms <= 1e-9, "strategy curves drifted: RMS {rms:.2e}");
    assert!(down.best_error.is_finite() && down.best_lambda > 0.0);
    println!("\nconformance OK: curves agree to {rms:.1e} RMS");
    Ok(())
}
