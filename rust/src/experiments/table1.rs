//! Table 1: cost of vectorizing, fitting and interpolating under the three
//! vectorization strategies (row-wise / full-matrix / recursive).
//!
//! Paper's finding (MNIST, dims 1024…16384): full-matrix has the cheapest
//! *vec* but ~2× *fit*+*interp* (D = h² instead of h(h+1)/2); row-wise has
//! the cheapest fit/interp but pays h small copies in *vec*; recursive gets
//! both — ~2× total win over row-wise at scale, ~2.3× over full-matrix.

use crate::linalg::matrix::Matrix;
use crate::prng::Xoshiro256;
use crate::util::{fmt_secs, markdown_table, timed};
use crate::vectorize::{all_strategies, VecStrategy};

use super::{csv_of, Report};

/// One strategy's measured phases at one dimension.
#[derive(Clone, Debug)]
pub struct Row {
    pub h: usize,
    pub strategy: String,
    pub vec_s: f64,
    pub fit_s: f64,
    pub interp_s: f64,
}

impl Row {
    pub fn total(&self) -> f64 {
        self.vec_s + self.fit_s + self.interp_s
    }
}

/// Synthesize g plausible lower-triangular factors (entries don't matter for
/// timing; triangular structure does).
fn fake_factors(h: usize, g: usize, seed: u64) -> Vec<Matrix> {
    let mut rng = Xoshiro256::seed_from(seed);
    (0..g)
        .map(|_| {
            Matrix::from_fn(h, h, |i, j| {
                if j < i {
                    rng.uniform() - 0.5
                } else if j == i {
                    1.0 + rng.uniform()
                } else {
                    0.0
                }
            })
        })
        .collect()
}

/// Time vec/fit/interp for one strategy at one dimension.
///
/// - *vec*: flatten the g factors into T **and** unvec one factor back
///   (Table 1's "transformation between a factor and its vectorized form");
/// - *fit*: Θ = (VᵀV)⁻¹VᵀT over this strategy's D;
/// - *interp*: evaluate the D polynomials at `m_interp` dense λ's.
pub fn measure_strategy(
    strategy: &dyn VecStrategy,
    h: usize,
    g: usize,
    m_interp: usize,
    seed: u64,
) -> Row {
    let factors = fake_factors(h, g, seed);
    let lams: Vec<f64> = (0..g).map(|i| 0.1 + 0.9 * i as f64 / (g - 1) as f64).collect();

    // vec: build T from the factors, then unvec one row back
    let (t, vec_s) = timed(|| {
        let t = crate::vectorize::build_target_matrix(strategy, &factors);
        let back = strategy.unvec(t.row(0), h);
        std::hint::black_box(back[(h - 1, 0)]);
        t
    });

    // fit: Θ = A·T with A the (r+1)×g projector
    let v = crate::pichol::vandermonde(&lams, 2);
    let gem = crate::linalg::gemm::Gemm::default();
    let (theta, fit_s) = timed(|| {
        let h_lam = gem.at_b(&v, &v);
        let l = crate::linalg::cholesky::cholesky_blocked(&h_lam).unwrap();
        let vt = v.transpose();
        let w = crate::linalg::triangular::trsm_left_lower(&l, &vt);
        let a = crate::linalg::triangular::trsm_left_lower_t(&l, &w);
        gem.mul(&a, &t)
    });

    // interp: evaluate at m dense λ's (axpy over D per λ)
    let d = strategy.dim(h);
    let (_, interp_s) = timed(|| {
        let mut out = vec![0.0f64; d];
        for k in 0..m_interp {
            let lam = 0.1 + 0.9 * k as f64 / (m_interp.max(2) - 1) as f64;
            out.copy_from_slice(theta.row(0));
            let mut pw = 1.0;
            for p in 1..=2usize {
                pw *= lam;
                let row = theta.row(p);
                for (o, &c) in out.iter_mut().zip(row) {
                    *o += pw * c;
                }
            }
            std::hint::black_box(out[d - 1]);
        }
    });

    Row {
        h,
        strategy: strategy.name().to_string(),
        vec_s,
        fit_s,
        interp_s,
    }
}

/// Run the full Table 1 sweep.
pub fn run(dims: &[usize], g: usize, m_interp: usize, seed: u64) -> Report {
    let mut report = Report::new("table1");
    report.push_md("# Table 1 — triangular vectorization strategies\n");
    report.push_md(&format!(
        "g = {g} sample factors, r = 2, {m_interp} interpolation points per dim.\n"
    ));

    let mut md_rows = Vec::new();
    let mut csv_rows = Vec::new();
    let mut all_rows = Vec::new();
    for &h in dims {
        for strategy in all_strategies() {
            let row = measure_strategy(strategy.as_ref(), h, g, m_interp, seed);
            md_rows.push(vec![
                row.h.to_string(),
                row.strategy.clone(),
                fmt_secs(row.vec_s),
                fmt_secs(row.fit_s),
                fmt_secs(row.interp_s),
                fmt_secs(row.total()),
            ]);
            csv_rows.push(vec![
                row.h as f64,
                match row.strategy.as_str() {
                    "row-wise" => 0.0,
                    "full-matrix" => 1.0,
                    _ => 2.0,
                },
                row.vec_s,
                row.fit_s,
                row.interp_s,
            ]);
            all_rows.push(row);
        }
    }
    report.push_md(&markdown_table(
        &["h", "strategy", "vec", "fit", "interp", "total"],
        &md_rows,
    ));

    // headline ratios at the largest dim
    if let Some(&hmax) = dims.iter().max() {
        let get = |name: &str| {
            all_rows
                .iter()
                .find(|r| r.h == hmax && r.strategy == name)
                .map(Row::total)
                .unwrap_or(f64::NAN)
        };
        let (rw, fm, rec) = (get("row-wise"), get("full-matrix"), get("recursive"));
        report.push_md(&format!(
            "\nAt h = {hmax}: recursive is {:.2}× faster than row-wise, {:.2}× than full-matrix \
             (paper at h=16384: 1.9×, 2.3×).\n",
            rw / rec,
            fm / rec
        ));
    }
    report.push_series(
        "timings",
        csv_of(&["h", "strategy", "vec_s", "fit_s", "interp_s"], &csv_rows),
    );
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phases_positive_and_structurally_sane() {
        let r = run(&[96, 128], 4, 8, 1);
        assert!(r.markdown.contains("row-wise"));
        assert!(r.markdown.contains("recursive"));
        assert_eq!(r.series.len(), 1);
    }

    #[test]
    fn fullmatrix_fit_costs_about_double() {
        // D doubles, so the fit phase should be ~2× row-wise (loose bounds:
        // timing noise on a busy box)
        let rw = measure_strategy(&crate::vectorize::RowWise, 512, 4, 4, 2);
        let fm = measure_strategy(&crate::vectorize::FullMatrix, 512, 4, 4, 2);
        let ratio = fm.fit_s / rw.fit_s;
        assert!(
            ratio > 1.2 && ratio < 4.5,
            "fit ratio full/rowwise = {ratio:.2}"
        );
    }
}
