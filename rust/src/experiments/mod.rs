//! Experiment drivers: one submodule per table/figure of the paper's
//! evaluation (§6), each parameterized by problem size so the same code runs
//! as a fast smoke test or as the full bench (see DESIGN.md §4 for the
//! experiment index).
//!
//! | module | reproduces |
//! |---|---|
//! | [`fig2`] | Figure 2 — % time of pipeline steps vs (n, h) |
//! | [`table1`] | Table 1 — vec/fit/interp cost of the 3 vectorizations |
//! | [`fig4`] | Figure 4 — exact vs interpolated factor entries over λ |
//! | [`fig6_table3`] | Figure 6 + Table 3 — timing of the 6 algorithms |
//! | [`fig7_table4`] | Figures 7-8 + Table 4 — hold-out curves and selections |
//! | [`fig9`] | Figure 9 — selected-λ error vs wall-time trajectories |
//! | [`fig10`] | Figure 10 — PINRMSE vs PIChol interpolation quality |
//! | [`fig11`] | Figure 11 — NRMSE of the factor interpolation vs λ |
//! | [`ablations`] | design-choice sweeps (g, r, block sizes, h₀) |

pub mod ablations;
pub mod fig10;
pub mod fig11;
pub mod fig2;
pub mod fig4;
pub mod fig6_table3;
pub mod fig7_table4;
pub mod fig9;
pub mod table1;

use std::io::Write;
use std::path::Path;

/// A rendered experiment report: markdown text plus optional CSV series.
pub struct Report {
    /// Experiment id, e.g. "table1".
    pub id: String,
    /// Human-readable markdown (tables, headers, notes).
    pub markdown: String,
    /// (name, csv-text) data series for plotting.
    pub series: Vec<(String, String)>,
}

impl Report {
    pub fn new(id: &str) -> Self {
        Self {
            id: id.to_string(),
            markdown: String::new(),
            series: Vec::new(),
        }
    }

    pub fn push_md(&mut self, text: &str) {
        self.markdown.push_str(text);
        if !text.ends_with('\n') {
            self.markdown.push('\n');
        }
    }

    pub fn push_series(&mut self, name: &str, csv: String) {
        self.series.push((name.to_string(), csv));
    }

    /// Print to stdout (bench harness behaviour).
    pub fn print(&self) {
        println!("\n===== {} =====", self.id);
        println!("{}", self.markdown);
    }

    /// Write `<dir>/<id>.md` and `<dir>/<id>_<series>.csv`.
    pub fn write_to(&self, dir: impl AsRef<Path>) -> std::io::Result<()> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)?;
        let mut f = std::fs::File::create(dir.join(format!("{}.md", self.id)))?;
        f.write_all(self.markdown.as_bytes())?;
        for (name, csv) in &self.series {
            let mut f = std::fs::File::create(dir.join(format!("{}_{}.csv", self.id, name)))?;
            f.write_all(csv.as_bytes())?;
        }
        Ok(())
    }
}

/// Render a CSV from a header and rows of f64.
pub fn csv_of(header: &[&str], rows: &[Vec<f64>]) -> String {
    let mut s = header.join(",");
    s.push('\n');
    for row in rows {
        let cells: Vec<String> = row.iter().map(|v| format!("{v:.6e}")).collect();
        s.push_str(&cells.join(","));
        s.push('\n');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_roundtrip(){
        let mut r = Report::new("t");
        r.push_md("# hello");
        r.push_series("curve", csv_of(&["x", "y"], &[vec![1.0, 2.0]]));
        let dir = std::env::temp_dir().join("pichol_report_test");
        r.write_to(&dir).unwrap();
        assert!(dir.join("t.md").exists());
        assert!(dir.join("t_curve.csv").exists());
        let csv = std::fs::read_to_string(dir.join("t_curve.csv")).unwrap();
        assert!(csv.starts_with("x,y"));
    }
}
