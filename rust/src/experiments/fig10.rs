//! Figure 10: PINRMSE (interpolating the hold-out-error curve) vs PIChol
//! (interpolating the factors), against the exact curve, per dataset.
//!
//! Paper shape: PIChol's reconstructed error curve hugs the exact one, while
//! PINRMSE's quadratic fit of the error curve can pick λ's decades away from
//! the optimum (MNIST, Caltech-101).

use std::sync::Arc;

use crate::coordinator::Coordinator;
use crate::cv::solvers::SolverKind;
use crate::cv::CvConfig;
use crate::data::synthetic::{DatasetKind, SyntheticDataset};
use crate::util::markdown_table;

use super::{csv_of, Report};

/// Run Figure 10 across datasets.
pub fn run(
    coord: &Coordinator,
    datasets: &[DatasetKind],
    n: usize,
    h: usize,
    cfg: &CvConfig,
) -> Report {
    let mut report = Report::new("fig10");
    report.push_md(&format!(
        "# Figure 10 — PIChol vs PINRMSE interpolation quality (h = {h}, n = {n}, g = {}, r = {})\n",
        cfg.g_samples, cfg.degree
    ));

    let kinds = [SolverKind::Chol, SolverKind::PiChol, SolverKind::Pinrmse];
    let mut md_rows = Vec::new();
    for &dkind in datasets {
        let ds = Arc::new(SyntheticDataset::generate(dkind, n, h, cfg.seed));
        let reports: Vec<_> = coord
            .run_matrix(ds, &kinds, cfg)
            .into_iter()
            .map(|r| r.expect("cv"))
            .collect();
        let (chol, pi, pin) = (&reports[0], &reports[1], &reports[2]);

        let ratio = |sel: f64| (sel.log10() - chol.best_lambda.log10()).abs();
        md_rows.push(vec![
            dkind.name().to_string(),
            format!("{:.3e}", chol.best_lambda),
            format!("{:.3e} (Δlog {:.2})", pi.best_lambda, ratio(pi.best_lambda)),
            format!("{:.3e} (Δlog {:.2})", pin.best_lambda, ratio(pin.best_lambda)),
        ]);

        let mut rows = Vec::new();
        for (i, &lam) in chol.grid.iter().enumerate() {
            rows.push(vec![
                lam,
                chol.mean_errors[i],
                pi.mean_errors[i],
                pin.mean_errors[i],
            ]);
        }
        report.push_series(
            &format!("curves_{}", dkind.name()),
            csv_of(&["lambda", "exact", "pichol", "pinrmse"], &rows),
        );
    }
    report.push_md(&markdown_table(
        &["dataset", "Chol λ*", "PIChol λ (Δlog₁₀)", "PINRMSE λ (Δlog₁₀)"],
        &md_rows,
    ));
    report.push_md(
        "\nExpected shape (paper Fig. 10): PIChol's Δlog ≈ 0 everywhere; PINRMSE lands far \
         from λ* on at least one dataset.\n",
    );
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pichol_beats_pinrmse_on_curve_fidelity() {
        // Figure 10's claim is statistical: PINRMSE *often* misfits badly
        // while PIChol is consistently faithful — on any single tiny problem
        // PINRMSE can get lucky, so average curve gaps over several seeds.
        let coord = Coordinator::new(1);
        let cfg = CvConfig {
            k_folds: 2,
            q_grid: 15,
            ..CvConfig::default()
        };
        let rms = |a: &[f64], b: &[f64]| -> f64 {
            let s: f64 = a
                .iter()
                .zip(b)
                .map(|(x, y)| (x - y) * (x - y))
                .sum::<f64>()
                / a.len() as f64;
            s.sqrt()
        };
        let (mut pi_total, mut pin_total) = (0.0, 0.0);
        for seed in [9u64, 10, 11, 12] {
            let ds = Arc::new(SyntheticDataset::generate(
                DatasetKind::MnistLike,
                200,
                33,
                seed,
            ));
            let reports: Vec<_> = coord
                .run_matrix(
                    ds,
                    &[SolverKind::Chol, SolverKind::PiChol, SolverKind::Pinrmse],
                    &cfg,
                )
                .into_iter()
                .map(|r| r.unwrap())
                .collect();
            let pi_gap = rms(&reports[0].mean_errors, &reports[1].mean_errors);
            let pin_gap = rms(&reports[0].mean_errors, &reports[2].mean_errors);
            // PIChol individually must always stay faithful to the curve
            assert!(pi_gap < 0.05, "PIChol curve gap {pi_gap:.4} (seed {seed})");
            pi_total += pi_gap;
            pin_total += pin_gap;
        }
        assert!(
            pi_total < pin_total,
            "mean PIChol gap {:.4} should beat mean PINRMSE gap {:.4}",
            pi_total / 4.0,
            pin_total / 4.0
        );
    }
}
