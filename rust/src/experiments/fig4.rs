//! Figure 4: individual entries of L(λ) — exact (dense sweep) vs the
//! piCholesky interpolation from g sparse samples. The paper plots a handful
//! of entries to make the "factors lie on smooth curves" point visually;
//! we emit the same curves as CSV plus summary agreement numbers.

use crate::linalg::cholesky::cholesky_shifted;
use crate::pichol::{fit, FitOptions};
use crate::testutil::random_spd;
use crate::util::PhaseTimer;
use crate::vectorize::RowWise;

use super::{csv_of, Report};

/// Exact and interpolated curves for selected factor entries.
pub struct Curves {
    pub lambdas: Vec<f64>,
    /// (i, j) of each tracked entry.
    pub entries: Vec<(usize, usize)>,
    /// exact[e][t] — entry e at dense λ index t.
    pub exact: Vec<Vec<f64>>,
    /// interp[e][t].
    pub interp: Vec<Vec<f64>>,
}

impl Curves {
    /// Max relative deviation between the curves, per entry.
    pub fn max_rel_dev(&self) -> Vec<f64> {
        self.entries
            .iter()
            .enumerate()
            .map(|(e, _)| {
                self.exact[e]
                    .iter()
                    .zip(&self.interp[e])
                    .map(|(x, y)| (x - y).abs() / x.abs().max(1e-12))
                    .fold(0.0, f64::max)
            })
            .collect()
    }
}

/// Trace `n_entries` spread-out factor entries over `m_dense` λ's.
pub fn trace(h: usize, g: usize, r: usize, m_dense: usize, seed: u64) -> Curves {
    let a = random_spd(h, 1e4, seed);
    let lo = 0.05;
    let hi = 1.0;
    let lambdas: Vec<f64> = (0..m_dense)
        .map(|i| lo + (hi - lo) * i as f64 / (m_dense - 1) as f64)
        .collect();
    let sample: Vec<f64> = (0..g)
        .map(|i| lo + (hi - lo) * i as f64 / (g - 1) as f64)
        .collect();

    let mut timer = PhaseTimer::new();
    let interp = fit(
        &a,
        &sample,
        &FitOptions {
            degree: r,
            strategy: &RowWise,
        },
        &mut timer,
    )
    .expect("fit");

    // a spread of entries: diagonal head/tail, off-diagonals near and far
    let entries = vec![
        (0, 0),
        (h / 2, h / 2),
        (h - 1, h - 1),
        (h / 2, 0),
        (h - 1, h / 2),
        (h / 3, h / 4),
    ];

    let mut exact = vec![Vec::with_capacity(m_dense); entries.len()];
    let mut interp_vals = vec![Vec::with_capacity(m_dense); entries.len()];
    for &lam in &lambdas {
        let le = cholesky_shifted(&a, lam).expect("PD");
        let li = interp.eval_factor(lam, &RowWise);
        for (e, &(i, j)) in entries.iter().enumerate() {
            exact[e].push(le[(i, j)]);
            interp_vals[e].push(li[(i, j)]);
        }
    }

    Curves {
        lambdas,
        entries,
        exact,
        interp: interp_vals,
    }
}

/// Run the Figure 4 experiment.
pub fn run(h: usize, g: usize, r: usize, m_dense: usize, seed: u64) -> Report {
    let curves = trace(h, g, r, m_dense, seed);
    let mut report = Report::new("fig4");
    report.push_md("# Figure 4 — factor entries over λ: exact vs interpolated\n");
    report.push_md(&format!(
        "h = {h}, g = {g} sample points, degree r = {r}, {m_dense} dense λ's.\n"
    ));
    report.push_md("| entry (i,j) | max rel deviation |\n|---|---|");
    for ((i, j), dev) in curves.entries.iter().zip(curves.max_rel_dev()) {
        report.push_md(&format!("| ({i},{j}) | {dev:.2e} |"));
    }
    report.push_md(
        "\nExpected shape (paper Fig. 4): blue (interpolated) traces red (exact) closely; \
         deviations ≪ 1%.\n",
    );

    let mut rows = Vec::new();
    for (t, &lam) in curves.lambdas.iter().enumerate() {
        let mut row = vec![lam];
        for e in 0..curves.entries.len() {
            row.push(curves.exact[e][t]);
            row.push(curves.interp[e][t]);
        }
        rows.push(row);
    }
    let mut header = vec!["lambda".to_string()];
    for (i, j) in &curves.entries {
        header.push(format!("exact_{i}_{j}"));
        header.push(format!("interp_{i}_{j}"));
    }
    let header_refs: Vec<&str> = header.iter().map(String::as_str).collect();
    report.push_series("curves", csv_of(&header_refs, &rows));
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interpolation_tracks_exact_closely() {
        // the paper's g=6, r=2 setting on a modest matrix
        let curves = trace(24, 6, 2, 25, 3);
        for ((i, j), dev) in curves.entries.iter().zip(curves.max_rel_dev()) {
            assert!(dev < 0.01, "entry ({i},{j}) deviates {dev:.2e}");
        }
    }

    #[test]
    fn entries_are_smooth_monotone_diagonal() {
        // diagonal entries of chol(H+λI) grow with λ
        let curves = trace(16, 5, 2, 15, 4);
        let diag_idx = 0; // entry (0,0)
        let c = &curves.exact[diag_idx];
        for w in c.windows(2) {
            assert!(w[1] >= w[0] - 1e-12, "diagonal entry not monotone in λ");
        }
    }
}
