//! Figure 6 + Table 3: wall-clock time of the six algorithms.
//!
//! Figure 6: total CV seconds vs h on the MNIST-like dataset.
//! Table 3: per-fold seconds at the largest h across all four datasets.
//!
//! Paper shapes to reproduce: PIChol ≈ 3-4× faster than Chol; MChol between
//! them; SVD ~13× slower than Chol; t-SVD slower than Chol; r-SVD fastest
//! of all (but useless for λ selection — Figure 7/Table 4's point).

use std::sync::Arc;

use crate::coordinator::Coordinator;
use crate::cv::solvers::SolverKind;
use crate::cv::CvConfig;
use crate::data::synthetic::{DatasetKind, SyntheticDataset};
use crate::util::{fmt_secs, markdown_table};

use super::{csv_of, Report};

/// Timing of every algorithm at one h on one dataset.
pub fn time_matrix(
    coord: &Coordinator,
    kind: DatasetKind,
    n: usize,
    h: usize,
    cfg: &CvConfig,
) -> Vec<(SolverKind, f64, f64, f64)> {
    let ds = Arc::new(SyntheticDataset::generate(kind, n, h, cfg.seed));
    let kinds = SolverKind::paper_six();
    let reports = coord.run_matrix(ds, &kinds, cfg);
    kinds
        .iter()
        .zip(reports)
        .map(|(&k, rep)| {
            let rep = rep.expect("cv run failed");
            (k, rep.total_secs(), rep.best_lambda, rep.best_error)
        })
        .collect()
}

/// Figure 6: algorithm timing vs h (MNIST-like).
pub fn run_fig6(coord: &Coordinator, hs: &[usize], n_per_h: usize, cfg: &CvConfig) -> Report {
    let mut report = Report::new("fig6");
    report.push_md("# Figure 6 — total CV seconds vs h (MNIST-like)\n");
    report.push_md(&format!(
        "k = {} folds, q = {} grid points, g = {}, r = {}; n = {n_per_h}·1 per h.\n",
        cfg.k_folds, cfg.q_grid, cfg.g_samples, cfg.degree
    ));

    let mut md_rows = Vec::new();
    let mut csv_rows = Vec::new();
    for &h in hs {
        let n = (n_per_h * h).max(4 * h);
        let times = time_matrix(coord, DatasetKind::MnistLike, n, h, cfg);
        let mut row = vec![h.to_string()];
        let mut crow = vec![h as f64];
        for (_, secs, _, _) in &times {
            row.push(fmt_secs(*secs));
            crow.push(*secs);
        }
        md_rows.push(row);
        csv_rows.push(crow);
    }
    let mut headers = vec!["h"];
    headers.extend(SolverKind::paper_six().iter().map(|k| k.name()));
    report.push_md(&markdown_table(&headers, &md_rows));

    if let (Some(first), Some(last)) = (csv_rows.first(), csv_rows.last()) {
        let _ = first;
        // speedup summary at the largest h: Chol/PIChol
        report.push_md(&format!(
            "\nAt h = {}: PIChol is {:.2}× faster than Chol (paper at h=16384: ≈3.8×), \
             SVD is {:.1}× slower than Chol (paper: ≈13×).\n",
            last[0] as usize,
            last[1] / last[2],
            last[4] / last[1],
        ));
    }
    report.push_series("times", csv_of(&headers_as_csv(), &csv_rows));
    report
}

fn headers_as_csv() -> Vec<&'static str> {
    let mut v = vec!["h"];
    v.extend(SolverKind::paper_six().iter().map(|k| k.name()));
    v
}

/// Table 3: per-fold seconds at one h across the four datasets.
pub fn run_table3(coord: &Coordinator, n: usize, h: usize, cfg: &CvConfig) -> Report {
    let mut report = Report::new("table3");
    report.push_md(&format!(
        "# Table 3 — per-fold seconds at h = {h} (paper: h = 16384)\n"
    ));

    let mut md_rows = Vec::new();
    let mut csv_rows = Vec::new();
    for kind in DatasetKind::all() {
        let times = time_matrix(coord, kind, n, h, cfg);
        for (i, (k, secs, _, _)) in times.iter().enumerate() {
            let per_fold = secs / cfg.k_folds as f64;
            if md_rows.len() <= i {
                md_rows.push(vec![k.name().to_string()]);
                csv_rows.push(vec![i as f64]);
            }
            md_rows[i].push(fmt_secs(per_fold));
            csv_rows[i].push(per_fold);
        }
        let _ = kind;
    }
    let mut headers = vec!["algorithm"];
    headers.extend(DatasetKind::all().iter().map(|k| k.name()));
    report.push_md(&markdown_table(&headers, &md_rows));
    report.push_md(
        "\nExpected shape (paper Table 3): PIChol ≈ 3-4× under Chol; SVD slowest; \
         r-SVD fastest.\n",
    );
    report.push_series(
        "per_fold_seconds",
        csv_of(
            &["algo_idx", "mnist", "coil", "caltech101", "caltech256"],
            &csv_rows,
        ),
    );
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pichol_beats_chol_at_moderate_h() {
        let coord = Coordinator::new(1);
        let cfg = CvConfig {
            k_folds: 2,
            q_grid: 31,
            ..CvConfig::default()
        };
        let times = time_matrix(&coord, DatasetKind::MnistLike, 256, 96, &cfg);
        let chol = times.iter().find(|(k, ..)| *k == SolverKind::Chol).unwrap().1;
        let pichol = times.iter().find(|(k, ..)| *k == SolverKind::PiChol).unwrap().1;
        assert!(
            pichol < chol,
            "piCholesky should already win at h=96/q=31: chol={chol:.3}s pichol={pichol:.3}s"
        );
    }
}
