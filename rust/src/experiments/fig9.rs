//! Figure 9: |log₁₀(λ_selected / λ_optimal)| as a function of elapsed
//! wall-clock time for Chol, PIChol and MChol.
//!
//! Paper shape: MChol's trajectory steps down slowly (each refinement level
//! costs 3 exact factorizations); Chol's drops as its sequential sweep
//! happens to pass near the optimum; PIChol jumps to (near) zero as soon as
//! its g factorizations + fit complete — much earlier than the others.

use crate::cv::solvers::SolverKind;
use crate::cv::{holdout_error, CvConfig, FoldData};
use crate::data::folds::kfold;
use crate::data::gram::GramCache;
use crate::data::synthetic::{DatasetKind, SyntheticDataset};
use crate::linalg::cholesky::{cholesky_shifted, CholeskyError};
use crate::linalg::triangular::solve_cholesky;
use crate::pichol::{fit, FitOptions};
use crate::util::{logspace, subsample_indices, PhaseTimer};
use crate::vectorize::{Recursive, VecStrategy};

use super::{csv_of, Report};

/// One algorithm's trajectory: (elapsed seconds, |log10 λ_sel/λ_opt|).
pub struct Trajectory {
    pub kind: SolverKind,
    pub points: Vec<(f64, f64)>,
}

/// Reference optimum: exact Chol over the full grid (what Figure 9 measures
/// selection error against).
fn reference_lambda(data: &FoldData, grid: &[f64], cfg: &CvConfig) -> f64 {
    let mut best = (grid[0], f64::INFINITY);
    for &lam in grid {
        let l = cholesky_shifted(&data.h_mat, lam).expect("PD");
        let th = solve_cholesky(&l, &data.g_vec);
        let e = holdout_error(&data.xv, &data.yv, &th, cfg.metric);
        if e < best.1 {
            best = (lam, e);
        }
    }
    best.0
}

fn log_ratio(sel: f64, opt: f64) -> f64 {
    (sel.log10() - opt.log10()).abs()
}

/// Chol trajectory: after each sequential grid evaluation, the current
/// best-so-far λ.
fn chol_trajectory(data: &FoldData, grid: &[f64], opt: f64, cfg: &CvConfig) -> Trajectory {
    let t0 = std::time::Instant::now();
    let mut best = (grid[0], f64::INFINITY);
    let mut points = Vec::new();
    for &lam in grid {
        let l = cholesky_shifted(&data.h_mat, lam).expect("PD");
        let th = solve_cholesky(&l, &data.g_vec);
        let e = holdout_error(&data.xv, &data.yv, &th, cfg.metric);
        if e < best.1 {
            best = (lam, e);
        }
        points.push((t0.elapsed().as_secs_f64(), log_ratio(best.0, opt)));
    }
    Trajectory {
        kind: SolverKind::Chol,
        points,
    }
}

/// PIChol trajectory: one point when the fit completes (selection ready),
/// then refinement as the interpolated sweep walks the grid.
fn pichol_trajectory(data: &FoldData, grid: &[f64], opt: f64, cfg: &CvConfig) -> Trajectory {
    let t0 = std::time::Instant::now();
    let strategy = Recursive::default();
    let sample: Vec<f64> = subsample_indices(grid.len(), cfg.g_samples)
        .into_iter()
        .map(|i| grid[i])
        .collect();
    let mut timer = PhaseTimer::new();
    let interp = fit(
        &data.h_mat,
        &sample,
        &FitOptions {
            degree: cfg.degree,
            strategy: &strategy,
        },
        &mut timer,
    )
    .expect("fit");

    let mut best = (grid[0], f64::INFINITY);
    let mut points = Vec::new();
    let mut vbuf = vec![0.0; interp.theta.cols()];
    for &lam in grid {
        interp.eval_vec_into(lam, &mut vbuf);
        let l = strategy.unvec(&vbuf, interp.h);
        let th = solve_cholesky(&l, &data.g_vec);
        let e = holdout_error(&data.xv, &data.yv, &th, cfg.metric);
        if e < best.1 {
            best = (lam, e);
        }
        points.push((t0.elapsed().as_secs_f64(), log_ratio(best.0, opt)));
    }
    Trajectory {
        kind: SolverKind::PiChol,
        points,
    }
}

/// MChol trajectory straight from its probe log.
fn mchol_trajectory(data: &FoldData, grid: &[f64], opt: f64, cfg: &CvConfig) -> Trajectory {
    let c = 0.5 * (grid[0].log10() + grid[grid.len() - 1].log10());
    let s = 0.5 * (grid[grid.len() - 1].log10() - grid[0].log10());
    let result = crate::pichol::mchol::multilevel_search(
        c,
        crate::pichol::mchol::MCholParams { s, s0: 0.0025 },
        |lam| -> Result<f64, CholeskyError> {
            let l = cholesky_shifted(&data.h_mat, lam)?;
            let th = solve_cholesky(&l, &data.g_vec);
            Ok(holdout_error(&data.xv, &data.yv, &th, cfg.metric))
        },
    )
    .expect("H + λI not PD inside the Figure 9 probe range");
    let mut best = (result.probes[0].lambda, f64::INFINITY);
    let mut points = Vec::new();
    for p in &result.probes {
        if p.error < best.1 {
            best = (p.lambda, p.error);
        }
        points.push((p.elapsed, log_ratio(best.0, opt)));
    }
    Trajectory {
        kind: SolverKind::MChol,
        points,
    }
}

/// Run Figure 9 on one dataset.
pub fn run(kind: DatasetKind, n: usize, h: usize, cfg: &CvConfig, seed: u64) -> Report {
    let ds = SyntheticDataset::generate(kind, n, h, seed);
    let (lo, hi) = cfg.lambda_range.unwrap_or_else(|| kind.lambda_range());
    let grid = logspace(lo, hi, cfg.q_grid);
    let folds = kfold(ds.n(), cfg.k_folds, cfg.seed);
    // the shared-Gram pipeline, single-fold edition: assemble once, downdate
    let gram = GramCache::assemble(&ds.x, &ds.y);
    let (xv, yv) = folds[0].materialize_val(&ds.x, &ds.y);
    let mut timer = PhaseTimer::new();
    let data = FoldData::from_gram(&gram, xv, yv, None, &mut timer);

    let opt = reference_lambda(&data, &grid, cfg);
    let trajectories = vec![
        chol_trajectory(&data, &grid, opt, cfg),
        pichol_trajectory(&data, &grid, opt, cfg),
        mchol_trajectory(&data, &grid, opt, cfg),
    ];

    let mut report = Report::new("fig9");
    report.push_md(&format!(
        "# Figure 9 — |log₁₀(λ_sel/λ_opt)| vs time ({}, h = {h})\n",
        kind.name()
    ));
    report.push_md("| algorithm | time to reach ≤0.2 | final |log ratio| | total time |\n|---|---|---|---|");
    for t in &trajectories {
        let reach = t
            .points
            .iter()
            .find(|(_, r)| *r <= 0.2)
            .map(|(s, _)| format!("{s:.4}s"))
            .unwrap_or_else(|| "never".into());
        let last = t.points.last().unwrap();
        report.push_md(&format!(
            "| {} | {reach} | {:.3} | {:.4}s |",
            t.kind.name(),
            last.1,
            last.0
        ));
    }
    report.push_md(
        "\nExpected shape (paper Fig. 9): PIChol reaches low selection error in a fraction \
         of Chol/MChol's time.\n",
    );

    for t in &trajectories {
        let rows: Vec<Vec<f64>> = t.points.iter().map(|&(s, r)| vec![s, r]).collect();
        report.push_series(
            &format!("traj_{}", t.kind.name()),
            csv_of(&["elapsed_s", "abs_log10_ratio"], &rows),
        );
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pichol_converges_faster_than_chol() {
        let cfg = CvConfig {
            k_folds: 2,
            q_grid: 21,
            ..CvConfig::default()
        };
        let rep = run(DatasetKind::CoilLike, 200, 64, &cfg, 7);
        // parse: pichol total < chol total (structure check via series)
        let chol = rep.series.iter().find(|(n, _)| n == "traj_Chol").unwrap();
        let pi = rep.series.iter().find(|(n, _)| n == "traj_PIChol").unwrap();
        let last_time = |csv: &str| -> f64 {
            csv.lines()
                .last()
                .unwrap()
                .split(',')
                .next()
                .unwrap()
                .parse()
                .unwrap()
        };
        assert!(
            last_time(&pi.1) < last_time(&chol.1),
            "pichol total should be below chol"
        );
        // and its final selection error is small
        let final_ratio: f64 = pi
            .1
            .lines()
            .last()
            .unwrap()
            .split(',')
            .nth(1)
            .unwrap()
            .parse()
            .unwrap();
        assert!(final_ratio < 0.5, "pichol final log-ratio {final_ratio}");
    }
}
