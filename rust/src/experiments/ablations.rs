//! Ablations over the design choices DESIGN.md calls out:
//!
//! - **g** (number of exact factors): accuracy/cost frontier of Algorithm 1;
//! - **r** (polynomial degree): the paper argues r=2 suffices because the
//!   entries are concave; we sweep r = 1..3;
//! - **Cholesky panel width**: the blocked `potrf`'s BLAS-3 fraction;
//! - **recursive-vectorization base case h₀**: Table 1's threshold.

use crate::linalg::cholesky::{cholesky_in_place, cholesky_shifted};
use crate::linalg::norms::nrmse;
use crate::pichol::{fit, FitOptions};
use crate::testutil::random_spd;
use crate::util::{logspace, markdown_table, subsample_indices, timed, PhaseTimer};
use crate::vectorize::{Recursive, RowWise, VecStrategy};

use super::{csv_of, Report};

/// Mean NRMSE of the interpolation over a dense grid, for given (g, r).
pub fn interp_quality(h: usize, g: usize, r: usize, seed: u64) -> f64 {
    let a = random_spd(h, 1e4, seed);
    let grid = logspace(1e-3, 1.0, 25);
    let sample: Vec<f64> = subsample_indices(grid.len(), g)
        .into_iter()
        .map(|i| grid[i])
        .collect();
    let mut timer = PhaseTimer::new();
    let interp = fit(
        &a,
        &sample,
        &FitOptions {
            degree: r,
            strategy: &RowWise,
        },
        &mut timer,
    )
    .expect("fit");
    let mut total = 0.0;
    for &lam in &grid {
        let exact = cholesky_shifted(&a, lam).expect("PD");
        total += nrmse(&interp.eval_factor(lam, &RowWise), &exact);
    }
    total / grid.len() as f64
}

/// Sweep g and r.
pub fn run_gr(h: usize, seed: u64) -> Report {
    let mut report = Report::new("ablation_gr");
    report.push_md(&format!("# Ablation — sample count g and degree r (h = {h})\n"));
    let mut md = Vec::new();
    let mut rows = Vec::new();
    for r in 1..=3usize {
        for g in (r + 1).max(3)..=8 {
            let q = interp_quality(h, g, r, seed);
            md.push(vec![g.to_string(), r.to_string(), format!("{q:.5}")]);
            rows.push(vec![g as f64, r as f64, q]);
        }
    }
    report.push_md(&markdown_table(&["g", "r", "mean NRMSE"], &md));
    report.push_md(
        "\nExpected: r=2 already ≪ r=1 (entries are curved); g beyond ~5 gives \
         diminishing returns — the paper's g=4, r=2 sits at the knee.\n",
    );
    report.push_series("gr", csv_of(&["g", "r", "mean_nrmse"], &rows));
    report
}

/// Sweep the blocked-Cholesky panel width.
pub fn run_chol_block(h: usize, widths: &[usize], reps: usize, seed: u64) -> Report {
    let a = random_spd(h, 1e5, seed);
    let mut report = Report::new("ablation_chol_block");
    report.push_md(&format!("# Ablation — Cholesky panel width (h = {h})\n"));
    let mut md = Vec::new();
    let mut rows = Vec::new();
    for &w in widths {
        let (_, secs) = timed(|| {
            for _ in 0..reps {
                let mut c = a.clone();
                cholesky_in_place(&mut c, w).unwrap();
                std::hint::black_box(c[(h - 1, h - 1)]);
            }
        });
        md.push(vec![w.to_string(), format!("{:.2}ms", secs / reps as f64 * 1e3)]);
        rows.push(vec![w as f64, secs / reps as f64]);
    }
    report.push_md(&markdown_table(&["panel width", "time / factorization"], &md));
    report.push_series("block", csv_of(&["width", "secs"], &rows));
    report
}

/// Sweep the recursive-vectorization base threshold h₀.
pub fn run_recursive_h0(h: usize, h0s: &[usize], reps: usize, seed: u64) -> Report {
    let mut rng = crate::prng::Xoshiro256::seed_from(seed);
    let l = crate::linalg::matrix::Matrix::from_fn(h, h, |i, j| {
        if j <= i {
            rng.normal()
        } else {
            0.0
        }
    });
    let mut report = Report::new("ablation_recursive_h0");
    report.push_md(&format!(
        "# Ablation — recursive vectorization base case h₀ (h = {h})\n"
    ));
    let mut md = Vec::new();
    let mut rows = Vec::new();
    for &h0 in h0s {
        let strat = Recursive::with_base(h0);
        let mut buf = vec![0.0; strat.dim(h)];
        let (_, secs) = timed(|| {
            for _ in 0..reps {
                strat.vec_into(&l, &mut buf);
                std::hint::black_box(buf[0]);
            }
        });
        md.push(vec![h0.to_string(), format!("{:.3}ms", secs / reps as f64 * 1e3)]);
        rows.push(vec![h0 as f64, secs / reps as f64]);
    }
    report.push_md(&markdown_table(&["h₀", "vec time"], &md));
    report.push_series("h0", csv_of(&["h0", "secs"], &rows));
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn degree_two_beats_degree_one() {
        let q1 = interp_quality(24, 4, 1, 5);
        let q2 = interp_quality(24, 4, 2, 5);
        assert!(q2 < q1, "r=2 NRMSE {q2:.5} should beat r=1 {q1:.5}");
    }

    #[test]
    fn reports_render() {
        let r = run_gr(12, 1);
        assert!(r.markdown.contains("mean NRMSE"));
        let r = run_chol_block(64, &[16, 64], 2, 2);
        assert!(r.markdown.contains("panel width"));
        let r = run_recursive_h0(128, &[8, 64], 3, 3);
        assert!(r.markdown.contains("h₀"));
    }
}
