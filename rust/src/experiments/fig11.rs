//! Figure 11: normalized root-mean-squared error of the piCholesky factor
//! interpolation, as a function of λ.
//!
//! NRMSE normalizes against the spread of the exact factor's entries, so 1.0
//! means "no better than predicting the mean entry". The paper's maximum on
//! MNIST is 0.0457 — high interpolation fidelity across the whole sweep.

use crate::linalg::cholesky::cholesky_shifted;
use crate::linalg::norms::nrmse;
use crate::pichol::{fit, FitOptions};
use crate::testutil::random_spd;
use crate::util::{logspace, subsample_indices, PhaseTimer};
use crate::vectorize::RowWise;

use super::{csv_of, Report};

/// NRMSE of the interpolated factor at each grid λ.
pub fn nrmse_curve(h: usize, g: usize, r: usize, grid: &[f64], seed: u64) -> Vec<f64> {
    let a = random_spd(h, 1e4, seed);
    let sample: Vec<f64> = subsample_indices(grid.len(), g)
        .into_iter()
        .map(|i| grid[i])
        .collect();
    let mut timer = PhaseTimer::new();
    let interp = fit(
        &a,
        &sample,
        &FitOptions {
            degree: r,
            strategy: &RowWise,
        },
        &mut timer,
    )
    .expect("fit");

    grid.iter()
        .map(|&lam| {
            let exact = cholesky_shifted(&a, lam).expect("PD");
            let approx = interp.eval_factor(lam, &RowWise);
            nrmse(&approx, &exact)
        })
        .collect()
}

/// Run Figure 11.
pub fn run(h: usize, g: usize, r: usize, q: usize, seed: u64) -> Report {
    let grid = logspace(1e-3, 1.0, q);
    let curve = nrmse_curve(h, g, r, &grid, seed);

    let mut report = Report::new("fig11");
    report.push_md(&format!(
        "# Figure 11 — NRMSE of factor interpolation vs λ (h = {h}, g = {g}, r = {r})\n"
    ));
    let max = curve.iter().cloned().fold(0.0, f64::max);
    let mean = curve.iter().sum::<f64>() / curve.len() as f64;
    report.push_md(&format!(
        "max NRMSE = {max:.4}, mean = {mean:.4} (paper max on MNIST: 0.0457; \
         naive mean-predictor baseline: 1.0)\n"
    ));
    let rows: Vec<Vec<f64>> = grid.iter().zip(&curve).map(|(&l, &e)| vec![l, e]).collect();
    report.push_series("nrmse", csv_of(&["lambda", "nrmse"], &rows));
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nrmse_stays_far_below_one() {
        let grid = logspace(1e-3, 1.0, 15);
        let curve = nrmse_curve(32, 4, 2, &grid, 11);
        let max = curve.iter().cloned().fold(0.0, f64::max);
        assert!(
            max < 0.1,
            "interpolation NRMSE should beat 0.1 everywhere, got max {max:.4}"
        );
    }

    #[test]
    fn nrmse_shrinks_with_more_samples() {
        let grid = logspace(1e-3, 1.0, 15);
        let c4: f64 = nrmse_curve(24, 4, 2, &grid, 12).iter().sum();
        let c8: f64 = nrmse_curve(24, 8, 2, &grid, 12).iter().sum();
        assert!(
            c8 < c4 * 1.5,
            "more sample factors should not hurt: g=4 sum {c4:.4}, g=8 sum {c8:.4}"
        );
    }
}
