//! Figure 2: percent of pipeline time spent in (a) Hessian computation,
//! (b) the cross-validation Cholesky sweep, (c) everything else, as a
//! function of n (training points) and h (feature dimension).
//!
//! The paper's point: once `n < k·q·d`, the k·q factorizations dominate —
//! which is exactly the regime piCholesky attacks.

use crate::data::gram::GramCache;
use crate::data::synthetic::{DatasetKind, SyntheticDataset};
use crate::linalg::cholesky::cholesky_shifted;
use crate::linalg::triangular::solve_cholesky;
use crate::util::{logspace, timed};

use super::{csv_of, Report};

/// Measured split for one (n, h).
#[derive(Clone, Debug)]
pub struct Split {
    pub n: usize,
    pub h: usize,
    pub hessian_s: f64,
    pub chol_sweep_s: f64,
    pub other_s: f64,
}

impl Split {
    pub fn percents(&self) -> (f64, f64, f64) {
        let total = self.hessian_s + self.chol_sweep_s + self.other_s;
        (
            100.0 * self.hessian_s / total,
            100.0 * self.chol_sweep_s / total,
            100.0 * self.other_s / total,
        )
    }
}

/// Time one (n, h) cell: Hessian build + q-point Cholesky sweep + solves.
pub fn measure_cell(n: usize, h: usize, q: usize, seed: u64) -> Split {
    let ds = SyntheticDataset::generate(DatasetKind::MnistLike, n, h, seed);
    let grid = logspace(1e-3, 1.0, q);

    // the production data path: one streamed Gram assembly (bitwise equal
    // to a monolithic syrk_lower + gemv_t — see data::gram)
    let (gram, hessian_s) = timed(|| GramCache::assemble(&ds.x, &ds.y));
    let (h_mat, g_vec) = gram.into_parts();

    let mut chol_sweep_s = 0.0;
    let mut other_s = 0.0;
    for &lam in &grid {
        let (l, cs) = timed(|| cholesky_shifted(&h_mat, lam).expect("PD"));
        chol_sweep_s += cs;
        let (theta, os) = timed(|| solve_cholesky(&l, &g_vec));
        std::hint::black_box(theta[0]);
        other_s += os;
    }

    Split {
        n,
        h,
        hessian_s,
        chol_sweep_s,
        other_s,
    }
}

/// Run the Figure 2 grid.
pub fn run(ns: &[usize], hs: &[usize], q: usize, seed: u64) -> Report {
    let mut report = Report::new("fig2");
    report.push_md("# Figure 2 — pipeline cost split (% of total)\n");
    report.push_md(&format!("q = {q} candidate λ values per sweep.\n"));
    report.push_md("| n | h | hessian % | chol-sweep % | other % |\n|---|---|---|---|---|");

    let mut rows = Vec::new();
    for &n in ns {
        for &h in hs {
            if h > n {
                continue; // keep the Hessian meaningful
            }
            let s = measure_cell(n, h, q, seed);
            let (ph, pc, po) = s.percents();
            report.push_md(&format!(
                "| {n} | {h} | {ph:.1} | {pc:.1} | {po:.1} |"
            ));
            rows.push(vec![n as f64, h as f64, ph, pc, po]);
        }
    }
    report.push_md(
        "\nExpected shape (paper Fig. 2): the chol-sweep share grows with h and shrinks \
         with n; for n ≲ k·q·d the sweep dominates.\n",
    );
    report.push_series(
        "percents",
        csv_of(&["n", "h", "hessian_pct", "chol_pct", "other_pct"], &rows),
    );
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percents_sum_to_100() {
        let s = measure_cell(128, 32, 5, 1);
        let (a, b, c) = s.percents();
        assert!((a + b + c - 100.0).abs() < 1e-9);
    }

    #[test]
    fn chol_share_grows_with_h() {
        // the Figure 2 trend: larger h → factorization sweep dominates more
        let lo = measure_cell(512, 16, 9, 2);
        let hi = measure_cell(512, 96, 9, 2);
        let (_, pc_lo, _) = lo.percents();
        let (_, pc_hi, _) = hi.percents();
        assert!(
            pc_hi > pc_lo,
            "chol% should grow with h: {pc_lo:.1} → {pc_hi:.1}"
        );
    }
}
