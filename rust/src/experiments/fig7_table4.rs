//! Figures 7-8 + Table 4: hold-out error curves over λ for the six
//! algorithms, and the minimum error / selected λ per algorithm × dataset.
//!
//! Paper shapes to reproduce: PIChol's curve traces Chol's closely (best near
//! the optimum); SVD coincides with Chol exactly; t-SVD and r-SVD sit well
//! above with distorted curves, so their selected λ's are unreliable.

use std::sync::Arc;

use crate::coordinator::Coordinator;
use crate::cv::solvers::SolverKind;
use crate::cv::{CvConfig, CvReport};
use crate::data::synthetic::{DatasetKind, SyntheticDataset};
use crate::util::markdown_table;

use super::{csv_of, Report};

/// All six algorithm reports for one dataset.
pub fn curves_for(
    coord: &Coordinator,
    kind: DatasetKind,
    n: usize,
    h: usize,
    cfg: &CvConfig,
) -> Vec<CvReport> {
    let ds = Arc::new(SyntheticDataset::generate(kind, n, h, cfg.seed));
    coord
        .run_matrix(ds, &SolverKind::paper_six(), cfg)
        .into_iter()
        .map(|r| r.expect("cv run"))
        .collect()
}

/// Figures 7-8: hold-out error curves per dataset.
pub fn run_fig7_8(
    coord: &Coordinator,
    datasets: &[DatasetKind],
    n: usize,
    h: usize,
    cfg: &CvConfig,
) -> Report {
    let mut report = Report::new("fig7_8");
    report.push_md(&format!(
        "# Figures 7-8 — hold-out error vs λ at h = {h}, n = {n}\n"
    ));

    for &kind in datasets {
        let reports = curves_for(coord, kind, n, h, cfg);
        report.push_md(&format!("\n## {}\n", kind.name()));

        // agreement summary: PIChol vs Chol mean relative curve gap
        let chol = &reports[0];
        let pi = &reports[1];
        let mut gap = 0.0;
        let mut cnt = 0;
        for (a, b) in chol.mean_errors.iter().zip(&pi.mean_errors) {
            if a.is_finite() && b.is_finite() {
                gap += (a - b).abs() / a;
                cnt += 1;
            }
        }
        report.push_md(&format!(
            "PIChol vs Chol mean curve gap: {:.2}% over {cnt} grid points.\n",
            100.0 * gap / cnt.max(1) as f64
        ));

        let mut rows = Vec::new();
        for (i, &lam) in chol.grid.iter().enumerate() {
            let mut row = vec![lam];
            for rep in &reports {
                row.push(rep.mean_errors[i]);
            }
            rows.push(row);
        }
        let mut header = vec!["lambda"];
        header.extend(SolverKind::paper_six().iter().map(|k| k.name()));
        report.push_series(&format!("curve_{}", kind.name()), csv_of(&header, &rows));
    }
    report.push_md(
        "\nExpected shape (paper Figs. 7-8): PIChol ≈ Chol ≈ SVD; t-SVD/r-SVD curves sit \
         higher and flatten the valley.\n",
    );
    report
}

/// Table 4: minimum hold-out error and selected λ per algorithm × dataset.
pub fn run_table4(
    coord: &Coordinator,
    n: usize,
    h: usize,
    cfg: &CvConfig,
) -> Report {
    let mut report = Report::new("table4");
    report.push_md(&format!(
        "# Table 4 — min hold-out error and selected λ (h = {h}, n = {n})\n"
    ));

    let mut md_rows: Vec<Vec<String>> = SolverKind::paper_six()
        .iter()
        .map(|k| vec![k.name().to_string()])
        .collect();
    let mut csv_rows: Vec<Vec<f64>> = (0..6).map(|i| vec![i as f64]).collect();

    let mut pi_within_one_step = true;
    for kind in DatasetKind::all() {
        let reports = curves_for(coord, kind, n, h, cfg);
        let chol_lam = reports[0].best_lambda;
        for (i, rep) in reports.iter().enumerate() {
            md_rows[i].push(format!("{:.4}", rep.best_error));
            md_rows[i].push(format!("{:.3e}", rep.best_lambda));
            csv_rows[i].push(rep.best_error);
            csv_rows[i].push(rep.best_lambda);
        }
        // the Table 4 claim: PIChol's λ within ~one grid step of Chol's
        let pi_lam = reports[1].best_lambda;
        let step = (reports[0].grid[1] / reports[0].grid[0]).ln();
        if (pi_lam.ln() - chol_lam.ln()).abs() > 1.6 * step {
            pi_within_one_step = false;
        }
    }

    let mut headers = vec!["algorithm".to_string()];
    for kind in DatasetKind::all() {
        headers.push(format!("{} err", kind.name()));
        headers.push(format!("{} λ", kind.name()));
    }
    let header_refs: Vec<&str> = headers.iter().map(String::as_str).collect();
    report.push_md(&markdown_table(&header_refs, &md_rows));
    report.push_md(&format!(
        "\nPIChol selected λ within ≈ one grid step of Chol on all datasets: {}.\n",
        if pi_within_one_step { "YES" } else { "NO" }
    ));
    report.push_series(
        "table4",
        csv_of(
            &[
                "algo_idx", "mnist_err", "mnist_lam", "coil_err", "coil_lam", "c101_err",
                "c101_lam", "c256_err", "c256_lam",
            ],
            &csv_rows,
        ),
    );
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pichol_curve_gap_small_and_svd_exact() {
        let coord = Coordinator::new(1);
        let cfg = CvConfig {
            k_folds: 2,
            q_grid: 11,
            ..CvConfig::default()
        };
        let reports = curves_for(&coord, DatasetKind::MnistLike, 200, 33, &cfg);
        let chol = &reports[0];
        let pi = &reports[1];
        let svd = &reports[3];
        for ((a, b), c) in chol
            .mean_errors
            .iter()
            .zip(&pi.mean_errors)
            .zip(&svd.mean_errors)
        {
            assert!((a - b).abs() / a < 0.1, "pichol gap too big: {a} vs {b}");
            assert!((a - c).abs() < 1e-6, "svd must equal chol: {a} vs {c}");
        }
    }
}
