//! Command-line interface for the `pichol` launcher.
//!
//! No `clap` in the offline crate set — a small hand-rolled parser covers
//! the subcommand + `--flag value` grammar:
//!
//! ```text
//! pichol cv        --dataset mnist --h 128 --n 1024 --solver pichol [...]
//! pichol serve     --n 2048 --h 16 --window 512 [...]  # streaming service replay
//! pichol compare   --dataset mnist --h 96  --n 512     # all six algorithms
//! pichol experiments --out results [--fast]            # every table/figure
//! pichol bound     --h 16 --lambda-c 0.5               # Theorem 4.7 demo
//! pichol info      [--artifacts artifacts]             # manifest + platform
//! ```

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// Parsed invocation: subcommand + flags.
#[derive(Debug, Clone)]
pub struct Args {
    pub command: String,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`.
    pub fn parse(argv: &[String]) -> Result<Args> {
        let command = argv
            .first()
            .cloned()
            .ok_or_else(|| anyhow!("missing subcommand\n{}", USAGE))?;
        let mut flags = BTreeMap::new();
        let mut switches = Vec::new();
        let mut i = 1;
        while i < argv.len() {
            let a = &argv[i];
            let Some(name) = a.strip_prefix("--") else {
                bail!("unexpected positional argument '{a}'\n{USAGE}");
            };
            if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                flags.insert(name.to_string(), argv[i + 1].clone());
                i += 2;
            } else {
                switches.push(name.to_string());
                i += 1;
            }
        }
        Ok(Args {
            command,
            flags,
            switches,
        })
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    pub fn usize_flag(&self, name: &str, default: usize) -> Result<usize> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn f64_flag(&self, name: &str, default: f64) -> Result<f64> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects a number, got '{v}'")),
        }
    }

    pub fn switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }
}

/// Top-level usage text.
pub const USAGE: &str = "\
pichol — piCholesky cross-validation coordinator

USAGE:
  pichol <command> [--flag value]...

COMMANDS:
  cv           run one algorithm's k-fold CV through the parallel sweep engine
               --dataset mnist|coil|caltech101|caltech256  --solver chol|pichol|mchol|svd|tsvd|rsvd|pinrmse
               --mode kfold|loo|aloocv   (loo = exact leave-one-out via rank-1
               factor downdates: one exact factor per λ anchor, n downdates
               each; aloocv = approximate LOO from hat diagonals — one exact
               factor per λ anchor, then batched multi-RHS triangular solves
               through the packed kernel, O(n·d²) per anchor; add --certify
               to re-run exact LOO and stamp the λ* agreement verdict)
               --fold-strategy downdate|refactor|auto   (downdate = default:
               one chol(G+λI) per λ anchor, fold factors by rank-(n/k)
               downdate chains; refactor = per-(fold,λ) chol(H_f+λI);
               auto = pick from the measured chud_rk crossover in the last
               BENCH_kernels.json, defaulting to downdate without one)
               (micro-kernel backend: PICHOL_KERNEL_BACKEND=scalar|avx2|neon
               env var; detected at startup otherwise — all backends are
               bit-identical)
               --h <dim> --n <samples> --folds <k> --grid <q> --g <samples> --degree <r>
               --threads <n|0=auto> --batch <λ per task; LOO: rows per task|0=auto>
               --chunk-rows <Gram stream block|0=auto>
               --trust-budget <relative drift before forced refactorization|inf>
               --trust-max-hops <update hops before forced refactorization|0=off>
               --trust-shift-retries <growing-shift retries on breakdown>
               --trust-shift-growth <per-retry shift factor, > 1>
               --trust-task-retries <panicking-task resubmissions before quarantine>
               --obs        (arm the observability layer: per-task span events,
               per-phase latency histograms, p50/p90/p99 in the report; off by
               default — zero-allocation hot path and bitwise-identical numeric
               output either way)
               --trace-out <file.json>   (write a Chrome trace-event file of
               the merged span log — open in chrome://tracing or Perfetto;
               implies --obs)
               --ledger-out <file.jsonl> (append-style run ledger: one JSONL
               record each for config provenance, every degradation, the
               certification verdict, and per-phase/per-kind latency
               quantiles; implies --obs)
               --seed <u64> --config <file.toml>
  serve        run the streaming CV service over the deterministic traffic
               replay: seeded rows admitted through a bounded queue into a
               sliding-window Gram, λ*/θ(λ*) + the LOO/ALOOCV curve served
               from epoch-swapped immutable snapshots (queries never block
               on a window update); bitwise identical at any thread count
               or admission batch size
               --n <total rows streamed> --h <dim> --dataset <as cv> --seed <u64>
               --batch <rows per admitted batch> --queries <point queries per batch>
               --window <max retained rows> --refresh-every <rows between refreshes>
               --queue-depth <admission backpressure, in batches>
               --eval-batch <window rows per eval task|0=auto>
               --tier loo|aloocv   (which tier scores the window at each anchor)
               --threads <eval workers|0=auto> --grid <q> --g <anchors> --degree <r>
               --trust-* as for `cv` (budget trips re-anchor λ* and are
               recorded as degradations) --obs --trace-out --ledger-out
               --config <file.toml>   ([service] section: window,
               refresh_every, queue_depth, workers, eval_batch, tier)
  compare      run all six algorithms on one dataset (Figure 6 row)
               flags as for `cv`
  hlo          run one fold through the AOT HLO pipeline (requires `make artifacts`)
               --h 64|128|256|512 --dataset mnist --seed <u64> --artifacts <dir> --exact
  experiments  regenerate every paper table/figure into --out <dir> (--fast shrinks sizes)
  bound        evaluate the Theorem 4.4/4.7 error bound --h <dim> --lambda-c <f64>
  info         show PJRT platform + artifact manifest --artifacts <dir>
  help         this text
";

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_switches() {
        let a = Args::parse(&argv(&["cv", "--h", "128", "--fast", "--seed", "7"])).unwrap();
        assert_eq!(a.command, "cv");
        assert_eq!(a.usize_flag("h", 0).unwrap(), 128);
        assert_eq!(a.usize_flag("seed", 0).unwrap(), 7);
        assert!(a.switch("fast"));
        assert!(!a.switch("slow"));
        assert_eq!(a.usize_flag("missing", 42).unwrap(), 42);
    }

    #[test]
    fn rejects_positional() {
        assert!(Args::parse(&argv(&["cv", "oops"])).is_err());
    }

    #[test]
    fn rejects_empty() {
        assert!(Args::parse(&[]).is_err());
    }

    #[test]
    fn bad_int_flag() {
        let a = Args::parse(&argv(&["cv", "--h", "many"])).unwrap();
        assert!(a.usize_flag("h", 1).is_err());
    }
}
