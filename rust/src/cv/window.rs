//! The sliding-window CV state machine behind the streaming service.
//!
//! ## The workload
//!
//! ROADMAP item 1 — "the millions-of-users workload" — is a λ-sweep that
//! never stops: rows arrive continuously, old rows age out, and the served
//! model `θ(λ*)` plus its LOO/ALOOCV error curve must stay fresh without
//! ever re-running the offline pipeline. Every hard primitive already
//! exists: [`GramCache::append_rows`]/[`GramCache::retire_rows`] keep
//! `(G, g)` incremental at `O(m·d²)` per block,
//! [`AnchorFactors`] keeps the `g` anchor factors `chol(G + λ_s I)` fresh
//! by rank-k rotation, the [`FactorTrust`] tags bound the accumulated
//! rotation error, and the recovery ladder ([`crate::cv::recovery`]) turns
//! every numerical surprise into a recorded degradation instead of a
//! panic. This module is the composition: a window of recent rows, the
//! incremental caches that shadow it, and a deterministic **refresh** that
//! re-anchors λ* and rebuilds the served snapshot.
//!
//! ## Determinism contract — why the service is bitwise replayable
//!
//! The acceptance bar (ISSUE 10) is that the same admitted row sequence
//! yields bitwise-identical snapshots at any worker count and any
//! admission batch size. Three design rules deliver it:
//!
//! 1. **Per-row numerics.** [`WindowCv::push_row`] is the only mutation
//!    entry point; the service splits every admitted batch into single
//!    rows before touching numerics, so batch size affects queueing only —
//!    the rank-1 update sequence is a pure function of the row sequence.
//! 2. **Segment-aligned refolds.** Rows live in sealed
//!    [`SEGMENT_ROWS`]-aligned segments (plus one short tail), each sealed
//!    segment caching the partial `(XᵀX, Xᵀy)` computed by the *same* code
//!    path [`GramCache::assemble`] uses. Retirement drops whole segments
//!    only, so survivors always start on a segment boundary — and at every
//!    refresh the Gram is **refolded** from the cached partials
//!    ([`crate::data::gram::fold_partials`]), which is bitwise identical
//!    to a from-scratch assembly of the surviving rows by construction.
//!    The incremental Gram (kept between refreshes for transactional
//!    validation) is replaced by the refold, so per-row rounding drift can
//!    never accumulate across refreshes.
//! 3. **Fixed eval partition, ordered merge.** The curve evaluation fans
//!    (anchor × row-block) tasks over the pool in blocks of
//!    [`ServiceConfig::eval_batch`] rows — a pure function of the window
//!    size — and merges results in input order
//!    ([`WorkerPool::map_scratch`]'s contract), so the worker count can
//!    never reorder a floating-point reduction.
//!
//! ## Re-anchor policy
//!
//! A refresh fires when either trigger trips ([`WindowCv::needs_refresh`]):
//! **staleness** (`refresh_every` rows admitted since the last refresh) or
//! the **drift budget** (any anchor's [`FactorTrust`] exceeds the
//! [`RecoveryPolicy`] budget). A staleness refresh keeps within-budget
//! anchor factors incremental (the cheap path); a budget trip refactors
//! the over-budget anchors from the refolded Gram through
//! [`recovery::refactor_ladder`], recorded as `cause: "drift-budget"`
//! degradations with `surface: "service"`. A retirement downdate that
//! breaks down ([`AnchorFactors::retire_rows`] is transactional) triggers
//! an immediate full refactor from the refolded Gram, recorded as
//! `cause: "breakdown"`. Both are the exact policy faces PR 7 introduced,
//! pointed at the streaming surface.

use std::collections::VecDeque;
use std::sync::Arc;

use crate::coordinator::pool::WorkerPool;
use crate::data::gram::{self, GramCache, IngestError, SEGMENT_ROWS};
use crate::linalg::cholesky::CholeskyError;
use crate::linalg::matrix::Matrix;
use crate::linalg::scratch::Scratch;
use crate::linalg::triangular::solve_cholesky_into;
use crate::linalg::trust::FactorTrust;
use crate::pichol::pinrmse::fit_error_curve;
use crate::util::{logspace, subsample_indices, PhaseTimer};

use super::loo::{eval_heldout_point, AnchorFactors};
use super::recovery::{self, DegradeInfo, Degradation, RecoveryPolicy, Rung};
use super::{CvConfig, CvMode};

/// The `[service]` knob set: window shape, refresh cadence, admission
/// queue depth, and the evaluation tier/fan-out. TOML `[service]`, CLI
/// `pichol serve` flags.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ServiceConfig {
    /// Maximum retained window rows. Retirement happens at whole-segment
    /// granularity ([`SEGMENT_ROWS`]), so the effective capacity is
    /// rounded up to the segment grid; values below one segment are
    /// clamped up.
    pub window: usize,
    /// Rows admitted between snapshot refreshes (the staleness trigger).
    pub refresh_every: usize,
    /// Bounded admission-queue depth: producers block (backpressure) when
    /// this many batches are in flight.
    pub queue_depth: usize,
    /// Curve-evaluation worker threads (0 = auto, like `sweep_threads`).
    pub workers: usize,
    /// Window rows per curve-evaluation task (0 = auto). A pure function
    /// of the config — never of the worker count — so the eval partition
    /// cannot perturb a result bit.
    pub eval_batch: usize,
    /// Which accuracy tier scores the window rows at each anchor:
    /// [`CvMode::Aloocv`] (batched hat-diagonal solves, the `O(n·d)`
    /// tier) or [`CvMode::Loo`] (exact rank-1 downdates). `KFold` is not
    /// a streaming tier and is rejected by config validation.
    pub tier: CvMode,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            window: 512,
            refresh_every: 64,
            queue_depth: 32,
            workers: 0,
            eval_batch: 0,
            tier: CvMode::Aloocv,
        }
    }
}

impl ServiceConfig {
    /// The effective eval-task row count (auto = one segment).
    pub fn effective_eval_batch(&self) -> usize {
        if self.eval_batch == 0 {
            SEGMENT_ROWS
        } else {
            self.eval_batch
        }
    }
}

/// One immutable served snapshot: everything a query needs, stamped with
/// its epoch and the trust state it was built under. Published behind an
/// `Arc` and swapped atomically — readers holding an old epoch keep a
/// fully consistent view forever.
#[derive(Clone, Debug)]
pub struct Snapshot {
    /// Monotone refresh counter; 0 is the empty pre-data snapshot.
    pub epoch: u64,
    /// Window rows the snapshot was computed over.
    pub rows: usize,
    /// Total rows ever admitted when the snapshot was built.
    pub rows_admitted: u64,
    /// The candidate λ grid (`q` points, fixed for the service lifetime).
    pub grid: Vec<f64>,
    /// Interpolated error curve over the grid (NaN when too few anchors
    /// survived to fit — same degradation semantics as the batch tiers).
    pub curve: Vec<f64>,
    /// The anchor λ's (`g` of them, fixed for the service lifetime).
    pub anchor_lambdas: Vec<f64>,
    /// Exact tier RMSE at each anchor over the window rows.
    pub anchor_rmse: Vec<f64>,
    /// Grid minimizer of the curve (anchor argmin when the fit degraded;
    /// NaN before any data).
    pub best_lambda: f64,
    /// Curve (or anchor) value at `best_lambda`.
    pub best_error: f64,
    /// The served model `θ(λ*)` — an exact `chol(G + λ*I)` solve over the
    /// refolded window Gram, never an interpolated factor. Empty before
    /// any data or after a full ladder exhaustion.
    pub theta: Vec<f64>,
    /// Largest anchor relative drift at build time (the trust stamp).
    pub max_relative_drift: f64,
    /// Largest anchor hop count at build time.
    pub max_hops: u64,
    /// Cumulative degradations recorded by the window so far.
    pub degradations: usize,
    /// The accuracy tier that scored the curve.
    pub tier: CvMode,
}

impl Snapshot {
    /// The pre-data snapshot (epoch 0): served before the first refresh so
    /// queries never observe an uninitialized state.
    pub fn empty(grid: Vec<f64>, anchor_lambdas: Vec<f64>, tier: CvMode) -> Snapshot {
        let q = grid.len();
        let g = anchor_lambdas.len();
        Snapshot {
            epoch: 0,
            rows: 0,
            rows_admitted: 0,
            grid,
            curve: vec![f64::NAN; q],
            anchor_lambdas,
            anchor_rmse: vec![f64::NAN; g],
            best_lambda: f64::NAN,
            best_error: f64::NAN,
            theta: Vec::new(),
            max_relative_drift: 0.0,
            max_hops: 0,
            degradations: 0,
            tier,
        }
    }

    /// Predict `xᵀθ(λ*)` for one feature row (NaN before the first
    /// refresh — the served-model face of a point query).
    pub fn predict(&self, x: &[f64]) -> f64 {
        if self.theta.len() != x.len() {
            return f64::NAN;
        }
        x.iter().zip(&self.theta).map(|(a, b)| a * b).sum()
    }
}

/// One sealed window segment: exactly [`SEGMENT_ROWS`] rows plus their
/// cached Gram partial, computed by the same code path
/// [`GramCache::assemble`] uses (the refold-bitwise keystone).
struct Segment {
    x: Matrix,
    y: Vec<f64>,
    ph: Matrix,
    pg: Vec<f64>,
}

/// Per-cell evaluation result, matching the batch tiers' task bodies.
type CellRes = Result<(f64, Option<(Rung, DegradeInfo)>), CholeskyError>;

/// The sliding-window CV state: sealed segments + tail, the incremental
/// `(G, g)` and anchor-factor caches that shadow them, and the refresh
/// that turns the current window into a served [`Snapshot`]. Single-owner
/// (the service worker thread); all parallelism lives inside
/// [`WindowCv::refresh`]'s eval fan-out.
pub struct WindowCv {
    svc: ServiceConfig,
    cv: CvConfig,
    grid: Vec<f64>,
    anchor_lambdas: Vec<f64>,
    dim: Option<usize>,
    segments: VecDeque<Segment>,
    tail_x: Vec<f64>,
    tail_y: Vec<f64>,
    gram: Option<GramCache>,
    anchors: Option<AnchorFactors>,
    trans: Matrix,
    epoch: u64,
    rows_admitted: u64,
    rows_since_refresh: usize,
    /// Every recorded escalation, in admission order — the service
    /// report's degradation ledger.
    pub degradations: Vec<Degradation>,
}

impl WindowCv {
    /// Build an empty window. The λ grid and anchor schedule are fixed
    /// here for the service lifetime (the same `logspace` +
    /// `subsample_indices` plan the batch tiers use); `cv.lambda_range`
    /// falls back to `[1e-2, 1e2]` when unset — a service has no dataset
    /// kind to inherit a paper range from.
    pub fn new(svc: ServiceConfig, cv: CvConfig) -> WindowCv {
        let (lo, hi) = cv.lambda_range.unwrap_or((1e-2, 1e2));
        let grid = logspace(lo, hi, cv.q_grid.max(2));
        let g = cv.g_samples.clamp(2, grid.len());
        let anchor_lambdas: Vec<f64> = subsample_indices(grid.len(), g)
            .into_iter()
            .map(|i| grid[i])
            .collect();
        let svc = ServiceConfig {
            window: svc.window.max(SEGMENT_ROWS),
            ..svc
        };
        WindowCv {
            svc,
            cv,
            grid,
            anchor_lambdas,
            dim: None,
            segments: VecDeque::new(),
            tail_x: Vec::new(),
            tail_y: Vec::new(),
            gram: None,
            anchors: None,
            trans: Matrix::zeros(0, 0),
            epoch: 0,
            rows_admitted: 0,
            rows_since_refresh: 0,
            degradations: Vec::new(),
        }
    }

    /// The pre-data snapshot this window serves at epoch 0.
    pub fn empty_snapshot(&self) -> Snapshot {
        Snapshot::empty(self.grid.clone(), self.anchor_lambdas.clone(), self.svc.tier)
    }

    /// Rows currently retained in the window.
    pub fn rows(&self) -> usize {
        self.segments.len() * SEGMENT_ROWS + self.tail_y.len()
    }

    /// Total rows ever admitted.
    pub fn rows_admitted(&self) -> u64 {
        self.rows_admitted
    }

    /// The current snapshot epoch (number of completed refreshes).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Admit one row: validate, fold into the incremental `(G, g)` and
    /// anchor caches (rank-1 each — `O(d²)` + `O(g·d²)`), seal a segment
    /// when the tail fills, and retire the oldest segment(s) once the
    /// window overflows. The **only** numeric mutation path — the service
    /// splits every admitted batch to single rows before calling this, so
    /// admission batch size can never perturb a result bit.
    pub fn push_row(&mut self, xi: &[f64], yi: f64) -> Result<(), IngestError> {
        let d = *self.dim.get_or_insert(xi.len());
        if xi.len() != d {
            return Err(IngestError::DimMismatch {
                expected: d,
                got: xi.len(),
            });
        }
        let mut xrow = Matrix::zeros(1, d);
        xrow.row_mut(0).copy_from_slice(xi);
        match self.gram.as_mut() {
            None => {
                gram::validate_rows(&xrow, &[yi])?;
                let cache = GramCache::assemble(&xrow, &[yi]);
                // chol(G + λI) with λ > 0 succeeds on any PSD Gram — a
                // failure here means a λ grid at/below rounding noise,
                // which config validation rejects
                self.anchors = AnchorFactors::factor(&cache, &self.anchor_lambdas).ok();
                self.gram = Some(cache);
            }
            Some(cache) => {
                cache.append_rows(&xrow, &[yi])?;
                if let Some(anchors) = self.anchors.as_mut() {
                    anchors.append_rows(&xrow, &mut self.trans);
                }
            }
        }
        self.tail_x.extend_from_slice(xi);
        self.tail_y.push(yi);
        if self.tail_y.len() == SEGMENT_ROWS {
            self.seal_tail(d);
        }
        while self.rows() > self.svc.window && !self.segments.is_empty() {
            self.retire_oldest_segment();
        }
        self.rows_admitted += 1;
        self.rows_since_refresh += 1;
        Ok(())
    }

    fn seal_tail(&mut self, d: usize) {
        let rows = self.tail_y.len();
        let mut x = Matrix::zeros(rows, d);
        for r in 0..rows {
            x.row_mut(r).copy_from_slice(&self.tail_x[r * d..(r + 1) * d]);
        }
        let y = std::mem::take(&mut self.tail_y);
        self.tail_x.clear();
        let (ph, pg) = gram::segment_partial(&x, &y, 0, rows);
        self.segments.push_back(Segment { x, y, ph, pg });
    }

    /// Retire the oldest sealed segment: incremental Gram downdate plus a
    /// transactional rank-[`SEGMENT_ROWS`] anchor downdate. A downdate
    /// breakdown (the retired rows carried the factor's whole mass at
    /// some pivot) leaves the anchor cache untouched; recovery refolds
    /// the Gram from the surviving partials — drift-free by construction
    /// — and refactors every anchor through the ladder, recorded as a
    /// `"breakdown"` degradation.
    fn retire_oldest_segment(&mut self) {
        let Some(seg) = self.segments.pop_front() else {
            return;
        };
        let Some(cache) = self.gram.as_mut() else {
            return;
        };
        cache.retire_rows(&seg.x, &seg.y);
        if let Some(anchors) = self.anchors.as_mut() {
            let max_drift = anchors
                .trusts
                .iter()
                .map(FactorTrust::relative_drift)
                .fold(0.0, f64::max);
            if let Err(e) = anchors.retire_rows(&seg.x, &mut self.trans) {
                self.degradations.push(Degradation {
                    surface: "service",
                    fold: self.rows_admitted as usize,
                    lambda: f64::NAN,
                    cause: "breakdown",
                    rung: Rung::Refactor,
                    trust: max_drift,
                    detail: format!(
                        "window retirement downdate broke down ({e}); all anchors refactored from refolded Gram"
                    ),
                });
                let refolded = self.refold();
                let policy = self.cv.recovery;
                if let Some(anchors) = self.anchors.as_mut() {
                    refactor_all(anchors, &refolded, &policy);
                }
                self.gram = Some(refolded);
            }
        }
    }

    /// Rebuild a [`GramCache`] from the cached segment partials plus the
    /// tail — **bitwise identical** to `GramCache::assemble` over the
    /// surviving rows (see the module docs). Public so the determinism
    /// suite can pin the round-trip directly.
    pub fn refold(&self) -> GramCache {
        let d = self.dim.unwrap_or(0);
        let tail_partial = if self.tail_y.is_empty() {
            None
        } else {
            let rows = self.tail_y.len();
            let mut x = Matrix::zeros(rows, d);
            for r in 0..rows {
                x.row_mut(r).copy_from_slice(&self.tail_x[r * d..(r + 1) * d]);
            }
            Some(gram::segment_partial(&x, &self.tail_y, 0, rows))
        };
        let partials = self
            .segments
            .iter()
            .map(|s| (&s.ph, s.pg.as_slice()))
            .chain(tail_partial.iter().map(|(ph, pg)| (ph, pg.as_slice())));
        gram::fold_partials(partials, d, self.rows())
    }

    /// Gather the current window rows in window order (oldest first) — the
    /// evaluation set of a refresh, and the determinism suite's oracle
    /// input.
    pub fn window_rows(&self) -> (Matrix, Vec<f64>) {
        let d = self.dim.unwrap_or(0);
        let n = self.rows();
        let mut x = Matrix::zeros(n, d);
        let mut y = Vec::with_capacity(n);
        let mut r = 0;
        for seg in &self.segments {
            for i in 0..seg.x.rows() {
                x.row_mut(r).copy_from_slice(seg.x.row(i));
                r += 1;
            }
            y.extend_from_slice(&seg.y);
        }
        for i in 0..self.tail_y.len() {
            x.row_mut(r).copy_from_slice(&self.tail_x[i * d..(i + 1) * d]);
            r += 1;
        }
        y.extend_from_slice(&self.tail_y);
        (x, y)
    }

    /// Whether a refresh is due: the staleness trigger
    /// (`refresh_every` rows since the last one) or the drift-budget
    /// trigger (any anchor's trust tag over the [`RecoveryPolicy`]
    /// budget). Both are pure functions of the admitted row sequence.
    pub fn needs_refresh(&self) -> bool {
        if self.gram.is_none() {
            return false;
        }
        if self.rows_since_refresh >= self.svc.refresh_every.max(1) {
            return true;
        }
        self.anchors
            .as_ref()
            .is_some_and(|a| a.trusts.iter().any(|t| t.exceeds(&self.cv.recovery.budget)))
    }

    /// Re-anchor λ* and rebuild the served snapshot:
    ///
    /// 1. **refold** — replace the incremental Gram with the segment-partial
    ///    refold (bitwise the from-scratch assembly; repairs per-row drift);
    /// 2. **anchor_refresh** — refactor over-budget anchors through the
    ///    ladder (recorded), keep the rest incremental;
    /// 3. **solve** — `θ_s` per anchor for the ALOOCV residual scoring;
    /// 4. **eval** — score every window row at every anchor over the pool
    ///    (fixed row-block partition, input-order merge — worker-invariant);
    /// 5. **fit** — PINRMSE polynomial through the anchor RMSEs, swept over
    ///    the grid (anchor argmin fallback when too few anchors survive);
    /// 6. **theta** — exact `chol(G + λ*I)` solve for the served model.
    pub fn refresh(&mut self, pool: &WorkerPool, timer: &mut PhaseTimer) -> Snapshot {
        let Some(_) = self.gram.as_ref() else {
            return self.empty_snapshot();
        };
        let refolded = timer.time("refold", || self.refold());
        let policy = self.cv.recovery;

        // stage 2: drift-budget refactorizations, from the refolded Gram
        if let Some(anchors) = self.anchors.as_mut() {
            for s in 0..anchors.lambdas.len() {
                if !anchors.trusts[s].exceeds(&policy.budget) {
                    continue;
                }
                let lam = anchors.lambdas[s];
                let drift = anchors.trusts[s].relative_drift();
                let hops = anchors.trusts[s].hops();
                let res = timer.time("anchor_refresh", || {
                    let mut out = Matrix::zeros(0, 0);
                    recovery::refactor_ladder(refolded.hessian(), lam, &mut out, &policy)
                        .map(|ok| (out, ok))
                });
                let (rung, detail) = match res {
                    Ok((out, (rung, extra))) => {
                        anchors.trusts[s] = FactorTrust::fresh(&out);
                        anchors.factors[s] = out;
                        let mut detail = format!(
                            "relative drift {drift:.3e} over budget after {hops} hops; refactored"
                        );
                        if extra > 0.0 {
                            detail.push_str(&format!(" with extra shift {extra:.3e}"));
                        }
                        (rung, detail)
                    }
                    Err(e) => (
                        Rung::Skip,
                        format!("drift over budget and refactor ladder exhausted: {e}"),
                    ),
                };
                self.degradations.push(Degradation {
                    surface: "service",
                    fold: self.rows_admitted as usize,
                    lambda: lam,
                    cause: "drift-budget",
                    rung,
                    trust: drift,
                    detail,
                });
            }
        }

        let shared = Arc::new(refolded);
        let (sums, counts) = self.eval_anchors(&shared, pool, timer);

        // stage 5: fit + sweep, the same fallback ladder as the batch tiers
        let anchor_rmse: Vec<f64> = sums
            .iter()
            .zip(&counts)
            .map(|(&s, &c)| if c > 0 { (s / c as f64).sqrt() } else { f64::NAN })
            .collect();
        let (usable_l, usable_e): (Vec<f64>, Vec<f64>) = self
            .anchor_lambdas
            .iter()
            .zip(&anchor_rmse)
            .filter(|(_, e)| e.is_finite())
            .map(|(&l, &e)| (l, e))
            .unzip();
        let (best_lambda, best_error, curve) = timer.time("fit", || {
            if usable_l.len() > self.cv.degree {
                let poly = fit_error_curve(&usable_l, &usable_e, self.cv.degree);
                poly.sweep(&self.grid)
            } else if let Some((i, _)) = usable_e
                .iter()
                .enumerate()
                .min_by(|(_, a), (_, b)| a.partial_cmp(b).unwrap())
            {
                (usable_l[i], usable_e[i], vec![f64::NAN; self.grid.len()])
            } else {
                (f64::NAN, f64::NAN, vec![f64::NAN; self.grid.len()])
            }
        });

        // stage 6: the served model — exact factor at λ*, never interpolated
        let theta = timer.time("theta", || {
            if !best_lambda.is_finite() {
                return Vec::new();
            }
            let mut out = Matrix::zeros(0, 0);
            match recovery::refactor_ladder(shared.hessian(), best_lambda, &mut out, &policy) {
                Ok((rung, extra)) => {
                    if rung > Rung::Refactor {
                        self.degradations.push(Degradation {
                            surface: "service",
                            fold: self.rows_admitted as usize,
                            lambda: best_lambda,
                            cause: "breakdown",
                            rung,
                            trust: 0.0,
                            detail: format!("θ(λ*) factor needed extra shift {extra:.3e}"),
                        });
                    }
                    let mut work = Vec::new();
                    let mut theta = Vec::new();
                    solve_cholesky_into(&out, shared.gradient(), &mut work, &mut theta);
                    theta
                }
                Err(e) => {
                    self.degradations.push(Degradation {
                        surface: "service",
                        fold: self.rows_admitted as usize,
                        lambda: best_lambda,
                        cause: "breakdown",
                        rung: Rung::Skip,
                        trust: 0.0,
                        detail: format!("θ(λ*) ladder exhausted: {e}"),
                    });
                    Vec::new()
                }
            }
        });

        // the incremental cache is replaced by the refold: drift repaired,
        // and the next refresh starts from the bitwise-exact state
        let (max_drift, max_hops) = self.anchors.as_ref().map_or((0.0, 0), |a| {
            a.trusts.iter().fold((0.0f64, 0u64), |(d, h), t| {
                (d.max(t.relative_drift()), h.max(t.hops()))
            })
        });
        self.gram = Some(
            Arc::try_unwrap(shared).unwrap_or_else(|_| unreachable!("eval jobs have quiesced")),
        );
        self.epoch += 1;
        self.rows_since_refresh = 0;
        Snapshot {
            epoch: self.epoch,
            rows: self.rows(),
            rows_admitted: self.rows_admitted,
            grid: self.grid.clone(),
            curve,
            anchor_lambdas: self.anchor_lambdas.clone(),
            anchor_rmse,
            best_lambda,
            best_error,
            theta,
            max_relative_drift: max_drift,
            max_hops,
            degradations: self.degradations.len(),
            tier: self.svc.tier,
        }
    }

    /// Stage 3 + 4: per-anchor `θ_s` solves, then the (anchor × row-block)
    /// eval fan-out. Returns per-anchor (squared-error sum, served-cell
    /// count), merged in ascending (anchor, block, row) order.
    fn eval_anchors(
        &mut self,
        shared: &Arc<GramCache>,
        pool: &WorkerPool,
        timer: &mut PhaseTimer,
    ) -> (Vec<f64>, Vec<usize>) {
        let g = self.anchor_lambdas.len();
        let (mut sums, mut counts) = (vec![0.0; g], vec![0usize; g]);
        let Some(anchors) = self.anchors.as_ref() else {
            return (sums, counts);
        };
        let n = self.rows();
        if n == 0 {
            return (sums, counts);
        }
        let (wx, wy) = self.window_rows();
        let d = wx.cols();
        let factors: Vec<Arc<Matrix>> =
            anchors.factors.iter().map(|f| Arc::new(f.clone())).collect();
        let trusts = anchors.trusts.clone();
        let thetas: Vec<Arc<Vec<f64>>> = timer.time("solve", || {
            factors
                .iter()
                .map(|f| {
                    let mut work = Vec::new();
                    let mut th = Vec::new();
                    solve_cholesky_into(f, shared.gradient(), &mut work, &mut th);
                    Arc::new(th)
                })
                .collect()
        });

        let eb = self.svc.effective_eval_batch();
        let blocks: Vec<(usize, usize)> = (0..n).step_by(eb).map(|lo| (lo, (lo + eb).min(n))).collect();
        let hists_on = timer.hists_armed();
        type JobOut = (Vec<CellRes>, PhaseTimer);
        let mut jobs: Vec<Box<dyn FnOnce(&mut Scratch) -> JobOut + Send>> = Vec::new();
        let mut meta = Vec::new();
        for s in 0..g {
            let lam = self.anchor_lambdas[s];
            for &(lo, hi) in &blocks {
                let factor = Arc::clone(&factors[s]);
                let trust = trusts[s];
                let gramc = Arc::clone(shared);
                let theta = Arc::clone(&thetas[s]);
                let xblock = wx.slice(lo, hi, 0, d);
                let yblock = wy[lo..hi].to_vec();
                let policy = self.cv.recovery;
                let tier = self.svc.tier;
                meta.push((s, lo));
                jobs.push(Box::new(move |scratch| {
                    let mut t = if hists_on {
                        PhaseTimer::with_hists()
                    } else {
                        PhaseTimer::new()
                    };
                    let cells = match tier {
                        CvMode::Loo => (0..xblock.rows())
                            .map(|i| {
                                eval_heldout_point(
                                    &factor,
                                    trust,
                                    &gramc,
                                    xblock.row(i),
                                    yblock[i],
                                    lam,
                                    &policy,
                                    scratch,
                                    &mut t,
                                )
                            })
                            .collect(),
                        // KFold is rejected by config validation; serve it
                        // as ALOOCV rather than poisoning the run
                        CvMode::Aloocv | CvMode::KFold => super::aloocv::eval_hat_block(
                            &factor, trust, &gramc, &theta, &xblock, &yblock, lam, &policy,
                            scratch, &mut t,
                        ),
                    };
                    (cells, t)
                }));
            }
        }
        let results = timer.time("eval", || pool.map_scratch(jobs));
        for ((s, lo), (cells, t)) in meta.into_iter().zip(results) {
            timer.merge(&t);
            let lam = self.anchor_lambdas[s];
            for (local, cell) in cells.into_iter().enumerate() {
                match cell {
                    Ok((sqerr, climb)) => {
                        sums[s] += sqerr;
                        counts[s] += 1;
                        if let Some((rung, info)) = climb {
                            self.degradations.push(info.into_degradation(
                                "service",
                                lo + local,
                                lam,
                                rung,
                            ));
                        }
                    }
                    Err(e) => {
                        self.degradations.push(Degradation {
                            surface: "service",
                            fold: lo + local,
                            lambda: lam,
                            cause: "breakdown",
                            rung: Rung::Skip,
                            trust: 0.0,
                            detail: format!("ladder exhausted: {e}"),
                        });
                    }
                }
            }
        }
        (sums, counts)
    }
}

/// Refactor every anchor from `gram` through the ladder (the retirement
/// breakdown recovery). Ladder exhaustion keeps the old factor — the next
/// budget trip retries.
fn refactor_all(anchors: &mut AnchorFactors, gram: &GramCache, policy: &RecoveryPolicy) {
    for s in 0..anchors.lambdas.len() {
        let mut out = Matrix::zeros(0, 0);
        if recovery::refactor_ladder(gram.hessian(), anchors.lambdas[s], &mut out, policy).is_ok() {
            anchors.trusts[s] = FactorTrust::fresh(&out);
            anchors.factors[s] = out;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{DatasetKind, SyntheticDataset};

    fn service_cfg() -> ServiceConfig {
        ServiceConfig {
            window: 2 * SEGMENT_ROWS,
            refresh_every: 24,
            ..ServiceConfig::default()
        }
    }

    fn cv_cfg() -> CvConfig {
        CvConfig {
            q_grid: 9,
            g_samples: 4,
            lambda_range: Some((0.1, 10.0)),
            ..CvConfig::default()
        }
    }

    fn feed(win: &mut WindowCv, ds: &SyntheticDataset, lo: usize, hi: usize) {
        for i in lo..hi {
            win.push_row(ds.x.row(i), ds.y[i]).unwrap();
        }
    }

    /// The keystone: after growth past capacity (appends + whole-segment
    /// retirements), the refold is bitwise a from-scratch `GramCache`
    /// over exactly the surviving rows.
    #[test]
    fn window_refold_is_bitwise_from_scratch_on_survivors() {
        let n = 5 * SEGMENT_ROWS + 7;
        let ds = SyntheticDataset::generate(DatasetKind::MnistLike, n, 11, 42);
        let mut win = WindowCv::new(service_cfg(), cv_cfg());
        feed(&mut win, &ds, 0, n);
        assert!(win.rows() <= 2 * SEGMENT_ROWS + SEGMENT_ROWS, "retention must bound the window");
        let (wx, wy) = win.window_rows();
        let refold = win.refold();
        let fresh = GramCache::assemble(&wx, &wy);
        assert_eq!(refold.hessian().as_slice(), fresh.hessian().as_slice());
        assert_eq!(refold.gradient(), fresh.gradient());
        assert_eq!(refold.n_rows(), win.rows());
        // and the surviving rows are the most recent ones, oldest first
        let expect_first = n - win.rows();
        assert_eq!(wx.row(0), ds.x.row(expect_first));
        assert_eq!(wy[0], ds.y[expect_first]);
    }

    /// A refresh produces a usable snapshot: finite curve and λ*, a served
    /// θ, a monotone epoch, and a trust stamp.
    #[test]
    fn refresh_serves_a_finite_snapshot() {
        let ds = SyntheticDataset::generate(DatasetKind::MnistLike, 90, 9, 7);
        let pool = WorkerPool::new(2);
        let mut timer = PhaseTimer::new();
        let mut win = WindowCv::new(service_cfg(), cv_cfg());
        feed(&mut win, &ds, 0, 90);
        assert!(win.needs_refresh(), "staleness trigger must have tripped");
        let snap = win.refresh(&pool, &mut timer);
        assert_eq!(snap.epoch, 1);
        assert_eq!(snap.rows, win.rows());
        assert!(snap.best_lambda.is_finite() && snap.best_error.is_finite());
        assert!(snap.curve.iter().all(|v| v.is_finite()));
        assert_eq!(snap.theta.len(), 9);
        assert!(snap.predict(ds.x.row(0)).is_finite());
        assert!(!win.needs_refresh(), "refresh must reset the staleness trigger");
        // structural: one refold, one θ(λ*) factor, g anchor solves
        assert_eq!(timer.count("refold"), 1);
        assert_eq!(timer.count("theta"), 1);
    }

    /// Worker-count invariance of a single refresh: the eval fan-out
    /// merges in input order, so curve bits cannot depend on the pool.
    #[test]
    fn refresh_is_bitwise_worker_invariant() {
        let ds = SyntheticDataset::generate(DatasetKind::MnistLike, 80, 8, 3);
        let run = |workers: usize| {
            let pool = WorkerPool::new(workers);
            let mut timer = PhaseTimer::new();
            let mut win = WindowCv::new(service_cfg(), cv_cfg());
            feed(&mut win, &ds, 0, 80);
            win.refresh(&pool, &mut timer)
        };
        let base = run(1);
        for workers in [2usize, 4] {
            let par = run(workers);
            assert_eq!(base.curve, par.curve, "workers={workers}");
            assert_eq!(base.anchor_rmse, par.anchor_rmse, "workers={workers}");
            assert_eq!(base.theta, par.theta, "workers={workers}");
            assert_eq!(base.best_lambda.to_bits(), par.best_lambda.to_bits());
        }
    }

    /// The drift-budget trigger: a budget no finite drift satisfies trips
    /// after the first incremental append, forces recorded anchor
    /// refactorizations at the next refresh, and the refactored anchors
    /// carry fresh trust tags.
    #[test]
    fn tight_budget_forces_recorded_anchor_refactorizations() {
        let ds = SyntheticDataset::generate(DatasetKind::MnistLike, 40, 7, 5);
        let pool = WorkerPool::new(1);
        let mut timer = PhaseTimer::new();
        let mut cv = cv_cfg();
        cv.recovery.budget = crate::linalg::trust::TrustBudget {
            max_relative_drift: 1e-300,
            max_hops: 0,
        };
        let mut win = WindowCv::new(service_cfg(), cv);
        feed(&mut win, &ds, 0, 2);
        assert!(win.needs_refresh(), "budget trigger must trip after one rank-1 hop");
        let snap = win.refresh(&pool, &mut timer);
        let budget_degs: Vec<_> = win
            .degradations
            .iter()
            .filter(|d| d.cause == "drift-budget")
            .collect();
        assert_eq!(budget_degs.len(), 4, "every anchor must be refactored");
        for d in budget_degs {
            assert_eq!(d.surface, "service");
            assert_eq!(d.rung, Rung::Refactor);
            assert!(d.trust > 0.0);
        }
        assert_eq!(snap.max_relative_drift, 0.0, "refactored anchors are fresh");
        assert_eq!(snap.max_hops, 0);
    }

    /// Bad rows are rejected at the door without mutating the window —
    /// the same ingest gate as the batch pipeline.
    #[test]
    fn bad_rows_are_rejected_without_mutation() {
        let ds = SyntheticDataset::generate(DatasetKind::MnistLike, 10, 6, 1);
        let mut win = WindowCv::new(service_cfg(), cv_cfg());
        feed(&mut win, &ds, 0, 10);
        let before = win.rows();
        let bad = vec![1.0, f64::NAN, 0.0, 0.0, 0.0, 0.0];
        assert!(win.push_row(&bad, 1.0).is_err());
        assert!(win.push_row(&[1.0, 2.0], 1.0).is_err(), "dim mismatch");
        assert_eq!(win.rows(), before);
        assert_eq!(win.rows_admitted(), 10);
    }
}
