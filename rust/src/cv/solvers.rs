//! The six comparative algorithms of §6.2, behind one dispatch point.
//!
//! | kind      | paper name | strategy |
//! |-----------|------------|----------|
//! | `Chol`    | Exact Cholesky | factorize `H+λI` at every grid point |
//! | `PiChol`  | piCholesky | Algorithm 1: g exact factors + interpolation |
//! | `MChol`   | Multi-level Cholesky | binary-search narrowing (§6.2.3) |
//! | `Svd`     | Exact SVD | one SVD of X, closed-form θ per λ (eq. 11) |
//! | `TSvd`    | Truncated SVD | Lanczos top-k, then eq. 11 on the truncation |
//! | `RSvd`    | Randomized SVD | Halko sketch, then eq. 11 |
//! | `Pinrmse` | PINRMSE | interpolate the error curve itself (Figure 10) |

use super::recovery::{self, DegradeInfo, RecoveryPolicy, Rung};
use super::{holdout_error_with, CvConfig, FoldData, Metric, SweepResult};
use crate::linalg::cholesky::{cholesky_shifted_into, CholeskyError};
use crate::linalg::trust::FactorTrust;
use crate::pichol::Interpolant;
use crate::linalg::lanczos::lanczos_svd;
use crate::linalg::matrix::Matrix;
use crate::linalg::randomized::randomized_svd;
use crate::linalg::scratch::Scratch;
use crate::linalg::svd::{jacobi_svd, Svd};
use crate::linalg::triangular::solve_cholesky_into;
use crate::pichol::{self, FitOptions};
use crate::util::{subsample_indices, PhaseTimer};
use crate::vectorize::{Recursive, VecStrategy};

/// Algorithm selector (paper §6.2 numbering).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SolverKind {
    Chol,
    PiChol,
    MChol,
    Svd,
    TSvd,
    RSvd,
    Pinrmse,
}

impl SolverKind {
    pub fn name(&self) -> &'static str {
        match self {
            SolverKind::Chol => "Chol",
            SolverKind::PiChol => "PIChol",
            SolverKind::MChol => "MChol",
            SolverKind::Svd => "SVD",
            SolverKind::TSvd => "t-SVD",
            SolverKind::RSvd => "r-SVD",
            SolverKind::Pinrmse => "PINRMSE",
        }
    }

    /// The paper's six (Table 3 / Figure 6 row order).
    pub fn paper_six() -> [SolverKind; 6] {
        [
            SolverKind::Chol,
            SolverKind::PiChol,
            SolverKind::MChol,
            SolverKind::Svd,
            SolverKind::TSvd,
            SolverKind::RSvd,
        ]
    }

    pub fn parse(s: &str) -> Option<SolverKind> {
        match s.to_ascii_lowercase().as_str() {
            "chol" => Some(SolverKind::Chol),
            "pichol" | "pi" => Some(SolverKind::PiChol),
            "mchol" => Some(SolverKind::MChol),
            "svd" => Some(SolverKind::Svd),
            "tsvd" | "t-svd" => Some(SolverKind::TSvd),
            "rsvd" | "r-svd" => Some(SolverKind::RSvd),
            "pinrmse" => Some(SolverKind::Pinrmse),
            _ => None,
        }
    }
}

/// Dispatch one fold's λ sweep to the chosen algorithm. `scratch` is the
/// caller's arena (the executing worker's, on the engine's fold-level path)
/// — every solver draws its factor/solve/prediction buffers from it, so no
/// solver allocates per grid point.
pub fn sweep(
    kind: SolverKind,
    data: &FoldData,
    grid: &[f64],
    cfg: &CvConfig,
    scratch: &mut Scratch,
    timer: &mut PhaseTimer,
) -> crate::Result<SweepResult> {
    match kind {
        SolverKind::Chol => sweep_chol(data, grid, cfg, scratch, timer),
        SolverKind::PiChol => sweep_pichol(data, grid, cfg, scratch, timer),
        SolverKind::MChol => sweep_mchol(data, grid, cfg, scratch, timer),
        SolverKind::Svd => sweep_svd_like(data, grid, cfg, scratch, timer, SvdFlavor::Full),
        SolverKind::TSvd => sweep_svd_like(data, grid, cfg, scratch, timer, SvdFlavor::Truncated),
        SolverKind::RSvd => {
            sweep_svd_like(data, grid, cfg, scratch, timer, SvdFlavor::Randomized)
        }
        SolverKind::Pinrmse => sweep_pinrmse(data, grid, cfg, scratch, timer),
    }
}

/// The vectorization strategy every PiChol sweep site shares. A factor
/// fitted through one strategy must be `unvec`'d through the same one
/// (the layout is a bijection), so the serial path and the engine's
/// anchor-fit + grid-task sites all construct it through this single
/// function — never inline a strategy at a PiChol call site.
pub(crate) fn pichol_strategy() -> Recursive {
    Recursive::default()
}

/// One exact-Cholesky grid-point evaluation — the shared task body of the
/// serial [`sweep`] path and the sweep engine's parallel grid tasks (both
/// must run *this* code so parallel results are bit-identical to serial).
/// Factor, solve and prediction buffers come from the caller's [`Scratch`]
/// arena (the executing worker's, on the parallel path) — zero heap
/// allocation once the arena is warm.
///
/// A [`CholeskyError`] means `H + λI` was indefinite at this λ; the sweep
/// propagates it (recovery is shift-and-retry with a larger λ — see
/// [`CholeskyError`]'s docs).
pub(crate) fn eval_exact_point(
    data: &FoldData,
    lam: f64,
    metric: Metric,
    scratch: &mut Scratch,
    timer: &mut PhaseTimer,
) -> Result<f64, CholeskyError> {
    timer.time("chol", || {
        cholesky_shifted_into(&data.h_mat, lam, &mut scratch.factor)
    })?;
    timer.time("solve", || {
        solve_cholesky_into(
            &scratch.factor,
            &data.g_vec,
            &mut scratch.work,
            &mut scratch.theta,
        )
    });
    Ok(timer.time("holdout", || {
        holdout_error_with(&data.xv, &data.yv, &scratch.theta, metric, &mut scratch.pred)
    }))
}

/// The per-cell escalation outcome of a recovering grid evaluation: `None`
/// on a baseline-rung cell, `Some((rung, info))` when the ladder climbed —
/// including [`Rung::Skip`], where the cell's error is NaN.
pub(crate) type CellDegrade = Option<(Rung, DegradeInfo)>;

/// One **factor-level** grid-point evaluation — the task body of the
/// [`crate::cv::FoldStrategy::Downdate`] sweep (shared by the engine's
/// parallel grid tasks; there is no other call site, so parallel results
/// are a pure function of the inputs). The fold factor comes from
/// [`FoldData::factor_from_anchor`] — the shared `chol(G + λI)` anchor
/// downdated by the fold's validation rows, escalating through the unified
/// recovery ladder on breakdown or drift-budget exhaustion — then the
/// identical solve + hold-out scoring as [`eval_exact_point`]. Never
/// fails: an exhausted ladder returns a NaN cell with a [`Rung::Skip`]
/// record, so one hopeless cell degrades one report entry.
#[allow(clippy::too_many_arguments)]
pub(crate) fn eval_anchored_point(
    data: &FoldData,
    anchor: &Matrix,
    anchor_trust: FactorTrust,
    lam: f64,
    metric: Metric,
    policy: &RecoveryPolicy,
    scratch: &mut Scratch,
    timer: &mut PhaseTimer,
) -> (f64, CellDegrade) {
    let fold_factor =
        match data.factor_from_anchor(anchor, anchor_trust, lam, policy, scratch, timer) {
            Ok(ff) => ff,
            Err(err) => return (f64::NAN, skip_cell(anchor_trust, err)),
        };
    finish_anchored_cell(data, fold_factor, metric, scratch, timer)
}

/// [`eval_anchored_point`] with the fold's update block gathered once by
/// the caller — the **λ-warm-start** task body: a grid task covering a
/// batch of λ cells of one fold gathers `X_vᵀ` once and replays it per
/// cell ([`FoldData::factor_from_anchor_pregathered`], a contiguous memcpy
/// instead of a strided re-gather). Bitwise identical to
/// [`eval_anchored_point`] on the same inputs.
#[allow(clippy::too_many_arguments)]
pub(crate) fn eval_anchored_point_pregathered(
    data: &FoldData,
    anchor: &Matrix,
    anchor_trust: FactorTrust,
    gathered: &Matrix,
    lam: f64,
    metric: Metric,
    policy: &RecoveryPolicy,
    scratch: &mut Scratch,
    timer: &mut PhaseTimer,
) -> (f64, CellDegrade) {
    let fold_factor = match data
        .factor_from_anchor_pregathered(anchor, anchor_trust, gathered, lam, policy, scratch, timer)
    {
        Ok(ff) => ff,
        Err(err) => return (f64::NAN, skip_cell(anchor_trust, err)),
    };
    finish_anchored_cell(data, fold_factor, metric, scratch, timer)
}

/// Rung 4 in cell form: a NaN error plus the skip record.
fn skip_cell(anchor_trust: FactorTrust, err: CholeskyError) -> CellDegrade {
    Some((
        Rung::Skip,
        DegradeInfo {
            cause: "breakdown",
            trust_at_failure: anchor_trust.relative_drift(),
            detail: format!("ladder exhausted: {err}"),
        },
    ))
}

/// The shared solve + hold-out tail of both anchored task bodies.
fn finish_anchored_cell(
    data: &FoldData,
    fold_factor: crate::cv::FoldFactor,
    metric: Metric,
    scratch: &mut Scratch,
    timer: &mut PhaseTimer,
) -> (f64, CellDegrade) {
    timer.time("solve", || {
        solve_cholesky_into(
            &scratch.factor,
            &data.g_vec,
            &mut scratch.work,
            &mut scratch.theta,
        )
    });
    let err = timer.time("holdout", || {
        holdout_error_with(&data.xv, &data.yv, &scratch.theta, metric, &mut scratch.pred)
    });
    let rung = fold_factor.rung;
    (err, fold_factor.degraded.map(|info| (rung, info)))
}

/// [`eval_exact_point`] under the unified recovery ladder — the
/// [`crate::cv::FoldStrategy::Refactor`] grid-task body. The baseline rung
/// here is [`Rung::Refactor`] (the first attempt is bitwise
/// [`cholesky_shifted_into`], so happy-path cells are untouched); on
/// breakdown the cell escalates to bounded growing-shift retries and
/// finally to a NaN skip — it never fails the task.
pub(crate) fn eval_exact_point_recovering(
    data: &FoldData,
    lam: f64,
    metric: Metric,
    policy: &RecoveryPolicy,
    scratch: &mut Scratch,
    timer: &mut PhaseTimer,
) -> (f64, CellDegrade) {
    let ladder = timer.time("chol", || {
        recovery::refactor_ladder(&data.h_mat, lam, &mut scratch.factor, policy)
    });
    let (rung, extra_shift) = match ladder {
        Ok(v) => v,
        Err(err) => {
            return (
                f64::NAN,
                Some((
                    Rung::Skip,
                    DegradeInfo {
                        cause: "breakdown",
                        trust_at_failure: 0.0,
                        detail: format!("ladder exhausted: {err}"),
                    },
                )),
            )
        }
    };
    timer.time("solve", || {
        solve_cholesky_into(
            &scratch.factor,
            &data.g_vec,
            &mut scratch.work,
            &mut scratch.theta,
        )
    });
    let err = timer.time("holdout", || {
        holdout_error_with(&data.xv, &data.yv, &scratch.theta, metric, &mut scratch.pred)
    });
    let degrade = (rung > Rung::Refactor).then(|| {
        (
            rung,
            DegradeInfo {
                cause: "breakdown",
                trust_at_failure: 0.0,
                detail: format!("served with extra shift {extra_shift:.3e}"),
            },
        )
    });
    (err, degrade)
}

/// One interpolated grid-point evaluation (piCholesky's payoff step) —
/// shared by the serial path and the engine's grid tasks. `strategy` must be
/// the strategy the interpolant was fitted with; all buffers (the D-length
/// eval vector, the reconstructed factor, the solve and prediction vectors)
/// come from the caller's [`Scratch`] arena — zero heap allocation once
/// warm.
pub(crate) fn eval_interp_point(
    data: &FoldData,
    interp: &Interpolant,
    strategy: &dyn VecStrategy,
    lam: f64,
    metric: Metric,
    scratch: &mut Scratch,
    timer: &mut PhaseTimer,
) -> f64 {
    timer.time("interp", || {
        interp.eval_factor_into(lam, strategy, &mut scratch.vbuf, &mut scratch.factor)
    });
    timer.time("solve", || {
        solve_cholesky_into(
            &scratch.factor,
            &data.g_vec,
            &mut scratch.work,
            &mut scratch.theta,
        )
    });
    timer.time("holdout", || {
        holdout_error_with(&data.xv, &data.yv, &scratch.theta, metric, &mut scratch.pred)
    })
}

pub(crate) fn best_of(grid: &[f64], errors: &[f64]) -> (f64, f64) {
    let (mut bl, mut be) = (grid[0], f64::INFINITY);
    for (&l, &e) in grid.iter().zip(errors) {
        if e.is_finite() && e < be {
            be = e;
            bl = l;
        }
    }
    (bl, be)
}

/// Exact Cholesky at every grid point — the paper's reference algorithm.
fn sweep_chol(
    data: &FoldData,
    grid: &[f64],
    cfg: &CvConfig,
    scratch: &mut Scratch,
    timer: &mut PhaseTimer,
) -> crate::Result<SweepResult> {
    let mut errors = Vec::with_capacity(grid.len());
    for &lam in grid {
        errors.push(eval_exact_point(data, lam, cfg.metric, scratch, timer)?);
    }
    let (bl, be) = best_of(grid, &errors);
    Ok(SweepResult {
        errors,
        best_lambda: bl,
        best_error: be,
        probes: Vec::new(),
    })
}

/// piCholesky: g exact factors, then O(r·d²) interpolation per grid point.
fn sweep_pichol(
    data: &FoldData,
    grid: &[f64],
    cfg: &CvConfig,
    scratch: &mut Scratch,
    timer: &mut PhaseTimer,
) -> crate::Result<SweepResult> {
    let strategy = pichol_strategy();
    let sample_lams: Vec<f64> = subsample_indices(grid.len(), cfg.g_samples)
        .into_iter()
        .map(|i| grid[i])
        .collect();
    let interp = pichol::fit(
        &data.h_mat,
        &sample_lams,
        &FitOptions {
            degree: cfg.degree,
            strategy: &strategy,
        },
        timer,
    )?;

    let mut errors = Vec::with_capacity(grid.len());
    for &lam in grid {
        errors.push(eval_interp_point(
            data,
            &interp,
            &strategy,
            lam,
            cfg.metric,
            scratch,
            timer,
        ));
    }
    let (bl, be) = best_of(grid, &errors);
    Ok(SweepResult {
        errors,
        best_lambda: bl,
        best_error: be,
        probes: Vec::new(),
    })
}

/// Multi-level Cholesky: §6.2's binary search. Grid errors are reported at
/// the grid points nearest to each probe (NaN elsewhere).
fn sweep_mchol(
    data: &FoldData,
    grid: &[f64],
    cfg: &CvConfig,
    scratch: &mut Scratch,
    timer: &mut PhaseTimer,
) -> crate::Result<SweepResult> {
    // centre the search on the middle of the grid range (log scale); the
    // paper seeds MChol the same way it seeds everyone's ranges
    let c = 0.5 * (grid[0].log10() + grid[grid.len() - 1].log10());
    let s = 0.5 * (grid[grid.len() - 1].log10() - grid[0].log10());
    let params = crate::pichol::mchol::MCholParams { s, s0: 0.0025 };

    let t0 = std::time::Instant::now();
    // an indefinite probe propagates as CholeskyError and fails the sweep
    // cleanly (shift-and-retry happens at the configuration level — see the
    // CholeskyError docs); probe buffers come from the worker's arena, so
    // the search allocates nothing per probe
    let result = crate::pichol::mchol::multilevel_search(
        c,
        params,
        |lam| -> Result<f64, CholeskyError> {
            cholesky_shifted_into(&data.h_mat, lam, &mut scratch.factor)?;
            solve_cholesky_into(
                &scratch.factor,
                &data.g_vec,
                &mut scratch.work,
                &mut scratch.theta,
            );
            Ok(holdout_error_with(
                &data.xv,
                &data.yv,
                &scratch.theta,
                cfg.metric,
                &mut scratch.pred,
            ))
        },
    )?;
    timer.add("chol", t0.elapsed().as_secs_f64());

    // scatter probes onto the grid for the mean-curve plots
    let mut errors = vec![f64::NAN; grid.len()];
    for p in &result.probes {
        let idx = grid
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| {
                let da = (a.ln() - p.lambda.ln()).abs();
                let db = (b.ln() - p.lambda.ln()).abs();
                da.partial_cmp(&db).unwrap()
            })
            .map(|(i, _)| i)
            .unwrap();
        if errors[idx].is_nan() || p.error < errors[idx] {
            errors[idx] = p.error;
        }
    }

    Ok(SweepResult {
        errors,
        best_lambda: result.best_lambda,
        best_error: result.best_error,
        probes: result.probes,
    })
}

enum SvdFlavor {
    Full,
    Truncated,
    Randomized,
}

/// The three SVD baselines share the eq. 11 sweep; they differ only in how
/// the factorization is obtained (and how much of the spectrum it carries).
/// These are the only solvers that touch `X` itself, so they require the
/// fold's materialized [`super::TrainSplit`].
fn sweep_svd_like(
    data: &FoldData,
    grid: &[f64],
    cfg: &CvConfig,
    scratch: &mut Scratch,
    timer: &mut PhaseTimer,
    flavor: SvdFlavor,
) -> crate::Result<SweepResult> {
    let split = data.train_split();
    let h = split.xt.cols();
    let k = ((h as f64 * cfg.tsvd_rank_frac).round() as usize).clamp(1, h);
    let svd: Svd = match flavor {
        SvdFlavor::Full => timer.time("svd", || jacobi_svd(&split.xt)),
        SvdFlavor::Truncated => timer.time("svd", || lanczos_svd(&split.xt, k, 10, cfg.seed)),
        SvdFlavor::Randomized => {
            let (p, q) = cfg.rsvd_params;
            timer.time("svd", || randomized_svd(&split.xt, k, p, q, cfg.seed))
        }
    };
    let uty = timer.time("svd", || svd.project_y(&split.yt));

    let mut errors = Vec::with_capacity(grid.len());
    for &lam in grid {
        timer.time("solve", || {
            svd.ridge_solve_into(&uty, lam, &mut scratch.work, &mut scratch.theta)
        });
        let e = timer.time("holdout", || {
            holdout_error_with(&data.xv, &data.yv, &scratch.theta, cfg.metric, &mut scratch.pred)
        });
        errors.push(e);
    }
    let (bl, be) = best_of(grid, &errors);
    Ok(SweepResult {
        errors,
        best_lambda: bl,
        best_error: be,
        probes: Vec::new(),
    })
}

/// PINRMSE: exact solves at the g sparse λ's only, then interpolate the
/// *error curve* (Figure 10's strawman).
fn sweep_pinrmse(
    data: &FoldData,
    grid: &[f64],
    cfg: &CvConfig,
    scratch: &mut Scratch,
    timer: &mut PhaseTimer,
) -> crate::Result<SweepResult> {
    let sample_idx = subsample_indices(grid.len(), cfg.g_samples);
    let sample_lams: Vec<f64> = sample_idx.iter().map(|&i| grid[i]).collect();
    let mut sample_errs = Vec::with_capacity(sample_lams.len());
    for &lam in &sample_lams {
        timer.time("chol", || {
            cholesky_shifted_into(&data.h_mat, lam, &mut scratch.factor)
        })?;
        timer.time("solve", || {
            solve_cholesky_into(
                &scratch.factor,
                &data.g_vec,
                &mut scratch.work,
                &mut scratch.theta,
            )
        });
        let e = timer.time("holdout", || {
            holdout_error_with(&data.xv, &data.yv, &scratch.theta, cfg.metric, &mut scratch.pred)
        });
        sample_errs.push(e);
    }
    let (errors, best_lambda, best_error) = {
        let poly = timer.time("fit", || {
            crate::pichol::pinrmse::fit_error_curve(&sample_lams, &sample_errs, cfg.degree)
        });
        let (bl, be, curve) = timer.time("interp", || poly.sweep(grid));
        (curve, bl, be)
    };
    Ok(SweepResult {
        errors,
        best_lambda,
        best_error,
        probes: Vec::new(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cv::{run_cv, CvConfig};
    use crate::data::synthetic::{DatasetKind, SyntheticDataset};

    fn tiny_cfg() -> CvConfig {
        CvConfig {
            k_folds: 2,
            q_grid: 11,
            ..CvConfig::default()
        }
    }

    fn tiny_ds() -> SyntheticDataset {
        SyntheticDataset::generate(DatasetKind::MnistLike, 160, 21, 5)
    }

    #[test]
    fn all_solvers_run_and_agree_on_scale() {
        let ds = tiny_ds();
        let cfg = tiny_cfg();
        let chol = run_cv(&ds, SolverKind::Chol, &cfg).unwrap();
        for kind in [
            SolverKind::PiChol,
            SolverKind::MChol,
            SolverKind::Svd,
            SolverKind::TSvd,
            SolverKind::RSvd,
            SolverKind::Pinrmse,
        ] {
            let rep = run_cv(&ds, kind, &cfg).unwrap();
            assert!(
                rep.best_error.is_finite() && rep.best_error > 0.0,
                "{} best error {}",
                kind.name(),
                rep.best_error
            );
            // every algorithm's best error is within 3× of exact Cholesky's
            // (even the bad ones aren't *that* bad on an easy tiny problem)
            assert!(
                rep.best_error < 3.0 * chol.best_error + 0.5,
                "{}: {} vs chol {}",
                kind.name(),
                rep.best_error,
                chol.best_error
            );
        }
    }

    #[test]
    fn pichol_tracks_chol_curve() {
        let ds = tiny_ds();
        let cfg = tiny_cfg();
        let chol = run_cv(&ds, SolverKind::Chol, &cfg).unwrap();
        let pi = run_cv(&ds, SolverKind::PiChol, &cfg).unwrap();
        // curves agree pointwise within a few percent (Figures 7-8)
        for (i, (&a, &b)) in chol.mean_errors.iter().zip(&pi.mean_errors).enumerate() {
            let rel = (a - b).abs() / a;
            assert!(rel < 0.08, "grid[{i}]: chol={a:.4} pichol={b:.4} rel={rel:.3}");
        }
        // selected λ within one grid step (Table 4)
        let li = chol
            .grid
            .iter()
            .position(|&l| (l - chol.best_lambda).abs() / l < 0.5)
            .unwrap_or(0);
        let pi_idx = pi
            .grid
            .iter()
            .position(|&l| (l - pi.best_lambda).abs() / l < 0.5)
            .unwrap_or(pi.grid.len());
        assert!(
            (li as i64 - pi_idx as i64).abs() <= 2,
            "selected λ far apart: chol={} pichol={}",
            chol.best_lambda,
            pi.best_lambda
        );
    }

    #[test]
    fn svd_matches_chol_exactly() {
        // eq. 11 and the normal equations are algebraically identical
        let ds = tiny_ds();
        let cfg = tiny_cfg();
        let chol = run_cv(&ds, SolverKind::Chol, &cfg).unwrap();
        let svd = run_cv(&ds, SolverKind::Svd, &cfg).unwrap();
        for (&a, &b) in chol.mean_errors.iter().zip(&svd.mean_errors) {
            assert!((a - b).abs() < 1e-6, "chol={a} svd={b}");
        }
    }

    #[test]
    fn mchol_reaches_grid_optimum() {
        let ds = tiny_ds();
        let cfg = tiny_cfg();
        let chol = run_cv(&ds, SolverKind::Chol, &cfg).unwrap();
        let mchol = run_cv(&ds, SolverKind::MChol, &cfg).unwrap();
        // MChol refines continuously, so its best error is ≤ grid best + slack
        assert!(mchol.best_error <= chol.best_error + 0.02);
        // the selected λ may wander when the curve is flat near its optimum
        // (λ is then weakly identified — Table 4's agreement holds on the
        // paper-scale datasets, checked in the fig7/table4 bench); here we
        // only require the same decade-and-a-half
        let ratio = (mchol.best_lambda.log10() - chol.best_lambda.log10()).abs();
        assert!(ratio < 2.0, "log10 ratio {ratio}");
        // probes recorded for Figure 9
        assert!(!mchol.probes[0].is_empty());
    }

    #[test]
    fn solver_kind_parse() {
        assert_eq!(SolverKind::parse("pichol"), Some(SolverKind::PiChol));
        assert_eq!(SolverKind::parse("T-SVD"), Some(SolverKind::TSvd));
        assert_eq!(SolverKind::parse("nope"), None);
    }
}
