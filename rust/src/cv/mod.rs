//! Cross-validation framework: the paper's §6 experimental machinery.
//!
//! The data path is the **shared-Gram pipeline**: `G = XᵀX` and `g = Xᵀy`
//! are assembled exactly once per dataset ([`crate::data::gram::GramCache`],
//! streamed in row blocks), and each fold's Hessian/gradient come from the
//! hold-out downdate `H_f = G − X_vᵀX_v`, `g_f = g − X_vᵀy_v`
//! ([`FoldData::from_gram`]) — `O(n·d²/k)` per fold instead of the
//! `O(n·d²)` per-fold SYRK of the literal Figure-1 pipeline. Each fold then
//! runs one of the six comparative algorithms ([`solvers`]) over the
//! candidate-λ grid, scoring θ on the held-out split. [`run_cv`] plans the
//! fold×λ grid as a [`SweepPlan`] and executes it on the parallel
//! [`crate::coordinator::sweep_engine`], then aggregates the per-fold
//! results with per-phase wall-clock timings — the raw material for
//! Figures 2, 6, 7-9 and Tables 3-4. Results are bit-identical for every
//! thread count (see the engine's determinism contract).
//!
//! The **factor level** goes one step further ([`FoldStrategy::Downdate`],
//! the default): the hold-out downdate commutes with the λ shift
//! (`H_f + λI = (G + λI) − X_vᵀX_v`), so per λ anchor the engine factors
//! `chol(G + λI)` exactly **once** and derives every fold's factor by a
//! chained rank-`n_v` hyperbolic downdate
//! ([`crate::linalg::chud::downdate_rank_k`]) — `k` downdates at
//! `O(n_v·d²)` each instead of `k` refactorizations at `O(d³)`. A fold
//! whose downdate goes numerically indefinite — or whose factor's drift
//! budget is exhausted — climbs the unified escalation ladder ([`recovery`])
//! for that (fold, λ) only, recorded in [`CvReport::degradations`]
//! ([`FoldData::factor_from_anchor`]).
//!
//! Besides k-fold, the crate runs leave-one-out CV on a three-tier
//! **accuracy/cost ladder**: approximate LOO via batched hat-diagonal
//! solves ([`aloocv`], `O(n·d)` per grid λ — select with
//! [`CvMode::Aloocv`]), **exact leave-one-out CV** ([`loo`]) on the
//! factor-update subsystem (one anchor factor per λ, every held-out factor
//! by rank-1 downdate — [`CvMode::Loo`]), and the brute-force per-row
//! refactorization oracle ([`loo::brute_force_loo_rmse`]). The cheap tier
//! escalates individual high-leverage rows to the exact tier through the
//! shared recovery ladder, and [`aloocv::run_certified`] checks the two
//! tiers select the same λ* to within a decade.

pub mod aloocv;
pub mod loo;
pub mod recovery;
pub mod solvers;
pub mod strategy;
pub mod window;

use crate::coordinator::sweep_engine::{SweepEngine, SweepPlan, SweepReport};
use crate::data::gram::{self, GramCache};
use crate::data::synthetic::SyntheticDataset;
use crate::linalg::cholesky::CholeskyError;
use crate::linalg::chud;
use crate::linalg::gemm::{gemv_into, gemv_t, gram_downdate, syrk_lower};
use crate::linalg::matrix::Matrix;
use crate::linalg::scratch::Scratch;
use crate::linalg::trust::FactorTrust;
use crate::pichol::mchol::Probe;
use crate::util::PhaseTimer;
use recovery::{DegradeInfo, Degradation, RecoveryPolicy, Rung};
use solvers::SolverKind;

/// Which cross-validation scheme a run executes. The LOO family is an
/// accuracy/cost ladder: [`CvMode::Aloocv`] is the cheap tier,
/// [`CvMode::Loo`] the exact tier it escalates onto per high-leverage row,
/// and the brute-force per-row refactorization
/// ([`loo::brute_force_loo_rmse`]) the oracle above both.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CvMode {
    /// k-fold CV — the paper's §6 scheme (folds, solvers, the fold×λ grid).
    KFold,
    /// Exact leave-one-out CV on the factor-update subsystem ([`loo`]):
    /// anchor factors once per λ, every held-out factor by rank-1 downdate.
    Loo,
    /// Approximate LOO via batched hat-diagonal solves ([`aloocv`]):
    /// `h_i = xᵢᵀ(G+λI)⁻¹xᵢ` for all n rows as one blocked multi-RHS TRSM
    /// per anchor — `O(n·d)` per additional grid λ.
    Aloocv,
}

impl CvMode {
    /// Parse a mode name (TOML `cv.mode`, CLI `--mode`).
    pub fn parse(s: &str) -> Option<CvMode> {
        match s.to_ascii_lowercase().as_str() {
            "kfold" | "k-fold" => Some(CvMode::KFold),
            "loo" | "leave-one-out" => Some(CvMode::Loo),
            "aloocv" | "aloo" | "approximate-loo" => Some(CvMode::Aloocv),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            CvMode::KFold => "kfold",
            CvMode::Loo => "loo",
            CvMode::Aloocv => "aloocv",
        }
    }
}

/// How the k-fold sweep obtains each fold's per-λ Cholesky factor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FoldStrategy {
    /// Factorize `chol(H_f + λI)` from the downdated fold Hessian at every
    /// (fold, λ) grid point — the literal paper pipeline. Kept alive as the
    /// per-fold breakdown fallback and as the conformance-suite oracle.
    Refactor,
    /// Factor-level downdate chains (the **default**): factor
    /// `chol(G + λI)` once per λ anchor, then derive each fold's factor by
    /// a chained rank-`n_v` hyperbolic downdate with the fold's validation
    /// rows ([`crate::linalg::chud::downdate_rank_k`]) — fold prep per
    /// anchor drops from `k` refactorizations at `O(d³)` to `k` downdates
    /// at `O(n_v·d²)`. Wins when folds are small (`n_v ≪ d`); a
    /// numerically indefinite fold climbs the escalation ladder
    /// ([`recovery`]) for that (fold, λ) only, recorded in
    /// [`CvReport::degradations`].
    Downdate,
    /// Measured-crossover auto-selection ([`strategy`]): read the last
    /// `BENCH_kernels.json` trajectory and pick [`FoldStrategy::Downdate`]
    /// vs [`FoldStrategy::Refactor`] from the measured `chud_rk` crossover
    /// at this run's `(n_v, d)`; with no trajectory file at all, a ~10 ms
    /// in-process probe measures the crossover instead, and only an
    /// unusable (present-but-malformed) file or a failed probe lands on
    /// the static default (downdate). Resolved to a concrete
    /// strategy in [`SweepPlan::new`] — the engine never sees `Auto`, and
    /// the resolved choice plus its provenance are recorded in
    /// [`CvReport::fold_strategy`]/[`CvReport::strategy_source`].
    Auto,
}

impl FoldStrategy {
    /// Parse a strategy name (TOML `cv.fold_strategy`, CLI
    /// `--fold-strategy`).
    pub fn parse(s: &str) -> Option<FoldStrategy> {
        match s.to_ascii_lowercase().as_str() {
            "refactor" | "refactorize" => Some(FoldStrategy::Refactor),
            "downdate" => Some(FoldStrategy::Downdate),
            "auto" => Some(FoldStrategy::Auto),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            FoldStrategy::Refactor => "refactor",
            FoldStrategy::Downdate => "downdate",
            FoldStrategy::Auto => "auto",
        }
    }
}

/// Hold-out error metric.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Metric {
    /// Root-mean-square error of predictions vs ±1 labels (the paper's
    /// hold-out error scale: MNIST ≈ 0.36, Caltech-256 ≈ 0.94).
    Rmse,
    /// Sign-misclassification rate.
    Misclass,
}

/// Score one coefficient vector on the validation split.
pub fn holdout_error(xv: &Matrix, yv: &[f64], theta: &[f64], metric: Metric) -> f64 {
    let mut pred = Vec::new();
    holdout_error_with(xv, yv, theta, metric, &mut pred)
}

/// [`holdout_error`] with a caller-provided prediction buffer (the
/// per-worker [`crate::linalg::scratch::Scratch`] on the sweep hot path —
/// no allocation once warm).
pub fn holdout_error_with(
    xv: &Matrix,
    yv: &[f64],
    theta: &[f64],
    metric: Metric,
    pred: &mut Vec<f64>,
) -> f64 {
    gemv_into(xv, theta, pred);
    match metric {
        Metric::Rmse => {
            let mse: f64 = pred
                .iter()
                .zip(yv)
                .map(|(p, y)| (p - y) * (p - y))
                .sum::<f64>()
                / yv.len() as f64;
            mse.sqrt()
        }
        Metric::Misclass => {
            let wrong = pred
                .iter()
                .zip(yv)
                .filter(|(p, y)| p.signum() != y.signum())
                .count();
            wrong as f64 / yv.len() as f64
        }
    }
}

/// A materialized training split — only carried by folds whose solver needs
/// the design matrix `X` itself (the SVD family); every Hessian-based solver
/// works from the downdated `(H_f, g_f)` pair alone.
pub struct TrainSplit {
    pub xt: Matrix,
    pub yt: Vec<f64>,
}

/// Everything a solver needs for one fold: the gathered validation block,
/// the fold Hessian/gradient (owned, downdated from the shared Gram on the
/// fast path), and — only when the solver genuinely needs `X` itself — the
/// materialized training split.
pub struct FoldData {
    /// Gathered validation block.
    pub xv: Matrix,
    pub yv: Vec<f64>,
    /// `H_f = X_tᵀX_t` over the training split (downdated:
    /// `G − X_vᵀX_v`).
    pub h_mat: Matrix,
    /// `g_f = X_tᵀy_t` over the training split (downdated:
    /// `g − X_vᵀy_v`).
    pub g_vec: Vec<f64>,
    /// Training split, materialized only for the SVD-family solvers; `None`
    /// on the Gram-downdate fast path (no near-full dataset copy per fold).
    pub train: Option<TrainSplit>,
}

impl FoldData {
    /// The fast path: derive `(H_f, g_f)` from the shared [`GramCache`] by
    /// hold-out downdate, timed under the `downdate` phase — `O(n_v·d²)`,
    /// touching only the validation block. `train` is whatever the solver
    /// requires (`None` for every Hessian-based algorithm).
    pub fn from_gram(
        gram: &GramCache,
        xv: Matrix,
        yv: Vec<f64>,
        train: Option<TrainSplit>,
        timer: &mut PhaseTimer,
    ) -> Self {
        let mut h_mat = Matrix::zeros(0, 0);
        let mut g_vec = Vec::new();
        timer.time("downdate", || {
            gram_downdate(
                gram.hessian(),
                gram.gradient(),
                &xv,
                &yv,
                &mut h_mat,
                &mut g_vec,
            )
        });
        Self {
            xv,
            yv,
            h_mat,
            g_vec,
            train,
        }
    }

    /// The direct path: build `(H, g)` straight from a materialized split
    /// with a per-fold SYRK, timed under the `hessian` phase. Kept for
    /// single-fold drivers (Figure 9, the HLO comparison tests); the sweep
    /// engine always goes through [`FoldData::from_gram`].
    pub fn build(
        xt: Matrix,
        yt: Vec<f64>,
        xv: Matrix,
        yv: Vec<f64>,
        timer: &mut PhaseTimer,
    ) -> Self {
        let h_mat = timer.time("hessian", || syrk_lower(&xt));
        let g_vec = timer.time("hessian", || gemv_t(&xt, &yt));
        Self {
            xv,
            yv,
            h_mat,
            g_vec,
            train: Some(TrainSplit { xt, yt }),
        }
    }

    /// The materialized training split; panics if this fold was prepared on
    /// the fast path without one (only the SVD family asks).
    pub fn train_split(&self) -> &TrainSplit {
        self.train
            .as_ref()
            .expect("solver needs the materialized training split, but this fold was prepared on the Gram-downdate fast path")
    }

    /// The **factor-level** fold view ([`FoldStrategy::Downdate`]'s task
    /// kernel): derive this fold's `chol(H_f + λI)` into `scratch.factor`
    /// from the shared per-λ anchor factor `anchor = chol(G + λI)` by a
    /// chained rank-`n_v` **tracked** hyperbolic downdate with the
    /// validation rows — the downdated `L` replaces any look at `H_f`,
    /// `O(n_v·d²)` against the `O(d³)` refactorization (timed under
    /// `fold_downdate`). The rotation work is charged to `anchor_trust`'s
    /// drift bound ([`crate::linalg::trust`]).
    ///
    /// **Escalation:** a numerically indefinite pivot, or a downdated
    /// factor whose drift bound exceeds `policy.budget`, climbs the
    /// unified ladder ([`recovery`]): full refactorization from the
    /// SYRK-downdated Gram pair this fold already carries, then bounded
    /// growing-shift retries (both timed under `chol`, like every
    /// refactor-strategy evaluation) — so one bad cell degrades instead of
    /// failing the sweep, with the climb carried in
    /// [`FoldFactor::degraded`] for the engine to record. `Err` means the
    /// whole ladder is exhausted; the caller's rung 4 is skip-and-record.
    pub fn factor_from_anchor(
        &self,
        anchor: &Matrix,
        anchor_trust: FactorTrust,
        lam: f64,
        policy: &RecoveryPolicy,
        scratch: &mut Scratch,
        timer: &mut PhaseTimer,
    ) -> Result<FoldFactor, CholeskyError> {
        let mut trust = anchor_trust;
        let down = timer.time("fold_downdate", || {
            chud::downdate_rank_k_tracked(
                anchor,
                &self.xv,
                &mut scratch.factor,
                &mut scratch.update,
                &mut scratch.trans,
                &mut trust,
            )
        });
        self.escalate(down, trust, lam, policy, scratch, timer)
    }

    /// [`FoldData::factor_from_anchor`] with the update block gathered once
    /// up front — the **λ-warm-start** variant. A sweep task covering
    /// several λ cells of one fold gathers `X_vᵀ` into `scratch.gather`
    /// once ([`chud::gather_update_block`], timed under `gather`) and
    /// replays the block per cell through
    /// [`chud::downdate_rank_k_pregathered_tracked`] (a contiguous memcpy
    /// instead of the strided per-cell row gather). Bitwise identical to
    /// the ungathered path — same values flow into the same transform
    /// chain — so curves, degradations, and the partition-independence
    /// contract are untouched; only the `fold_downdate` phase gets cheaper
    /// per cell.
    pub fn factor_from_anchor_pregathered(
        &self,
        anchor: &Matrix,
        anchor_trust: FactorTrust,
        gathered: &Matrix,
        lam: f64,
        policy: &RecoveryPolicy,
        scratch: &mut Scratch,
        timer: &mut PhaseTimer,
    ) -> Result<FoldFactor, CholeskyError> {
        let mut trust = anchor_trust;
        let down = timer.time("fold_downdate", || {
            chud::downdate_rank_k_pregathered_tracked(
                anchor,
                gathered,
                &mut scratch.factor,
                &mut scratch.update,
                &mut scratch.trans,
                &mut trust,
            )
        });
        self.escalate(down, trust, lam, policy, scratch, timer)
    }

    /// Shared rungs 2–3 of both anchor-derived paths: decide whether the
    /// tracked downdate's outcome can be served as-is (success within
    /// budget → rung 1), and otherwise rebuild through the refactor ladder
    /// from this fold's own Gram pair, capturing the cause for the report.
    fn escalate(
        &self,
        down: Result<(), CholeskyError>,
        trust: FactorTrust,
        lam: f64,
        policy: &RecoveryPolicy,
        scratch: &mut Scratch,
        timer: &mut PhaseTimer,
    ) -> Result<FoldFactor, CholeskyError> {
        let (cause, detail) = match &down {
            Ok(()) => {
                if !trust.exceeds(&policy.budget) {
                    return Ok(FoldFactor {
                        rung: Rung::Downdate,
                        extra_shift: 0.0,
                        trust,
                        degraded: None,
                    });
                }
                (
                    "drift-budget",
                    format!(
                        "relative drift {:.3e} over budget after {} hops",
                        trust.relative_drift(),
                        trust.hops()
                    ),
                )
            }
            Err(e) => ("breakdown", e.to_string()),
        };
        let info = DegradeInfo {
            cause,
            trust_at_failure: trust.relative_drift(),
            detail,
        };
        // the downdate poisoned (or out-drifted) only the scratch copy —
        // rebuild it from the downdated Gram, the strategy-independent
        // oracle, escalating through bounded growing-shift retries
        let (rung, extra_shift) = timer.time("chol", || {
            recovery::refactor_ladder(&self.h_mat, lam, &mut scratch.factor, policy)
        })?;
        Ok(FoldFactor {
            rung,
            extra_shift,
            trust: FactorTrust::fresh(&scratch.factor),
            degraded: Some(info),
        })
    }
}

/// What [`FoldData::factor_from_anchor`] produced: the fold factor itself
/// lands in the caller's `scratch.factor` (it lives in the worker arena so
/// the follow-up solve can borrow the other scratch buffers); this carries
/// the provenance.
pub struct FoldFactor {
    /// The ladder rung that served the factor ([`Rung::Downdate`] on the
    /// happy path).
    pub rung: Rung,
    /// Extra diagonal shift of a [`Rung::ShiftedRefactor`] factor (0.0
    /// below that rung).
    pub extra_shift: f64,
    /// The served factor's trust tag: the charged downdate trust on rung
    /// 1, a fresh tag after any refactorization.
    pub trust: FactorTrust,
    /// `Some` when the ladder climbed above [`Rung::Downdate`] — why, and
    /// the drift bound at the moment of failure — for the engine to turn
    /// into a [`Degradation`] record.
    pub degraded: Option<DegradeInfo>,
}

/// Per-fold sweep output.
pub struct SweepResult {
    /// Hold-out error at each grid λ; NaN where the algorithm never
    /// evaluated (MChol probes off-grid).
    pub errors: Vec<f64>,
    /// Best λ according to this algorithm (may be off-grid for MChol).
    pub best_lambda: f64,
    /// Error at `best_lambda`.
    pub best_error: f64,
    /// Time-stamped probe trajectory (Figure 9); empty for grid algorithms.
    pub probes: Vec<Probe>,
}

/// Cross-validation configuration (paper §6.3 defaults).
#[derive(Clone, Debug)]
pub struct CvConfig {
    /// Number of folds k.
    pub k_folds: usize,
    /// Candidate grid size q (31 exponentially spaced values).
    pub q_grid: usize,
    /// piCholesky sample count g.
    pub g_samples: usize,
    /// Polynomial degree r.
    pub degree: usize,
    /// λ search range; `None` = use the dataset's paper range.
    pub lambda_range: Option<(f64, f64)>,
    /// Master seed (folds, sketches).
    pub seed: u64,
    /// Truncated-SVD rank as a fraction of h.
    pub tsvd_rank_frac: f64,
    /// Randomized-SVD (oversample, power iterations).
    pub rsvd_params: (usize, usize),
    /// Hold-out metric.
    pub metric: Metric,
    /// Sweep-engine worker threads (0 = auto: `PICHOL_WORKERS` env var or
    /// the hardware's available parallelism). Results are bit-identical for
    /// every value.
    pub sweep_threads: usize,
    /// λ grid points per sweep task — the batch shape of the parallel grid
    /// wave (0 = auto: ~4 batches per worker per fold).
    pub sweep_batch: usize,
    /// Row-block size of the streaming Gram assembly (0 = auto). Snapped up
    /// to the fixed accumulation grid
    /// ([`crate::data::gram::SEGMENT_ROWS`]-aligned segments), so any value
    /// yields bit-identical results — the knob trades scheduling granularity
    /// against per-task block footprint only. TOML: `[data] chunk_rows`;
    /// CLI: `--chunk-rows`.
    pub chunk_rows: usize,
    /// Cross-validation scheme: k-fold (default) or leave-one-out on the
    /// factor-update subsystem. TOML: `[cv] mode = "loo"`; CLI:
    /// `--mode loo`. In LOO mode `g_samples` picks the anchor count and
    /// `sweep_batch` the held-out rows per task (0 = auto).
    pub mode: CvMode,
    /// How k-fold per-(fold, λ) factors are produced:
    /// [`FoldStrategy::Downdate`] (default — factor-level downdate chains
    /// off one `chol(G + λI)` anchor per λ) or [`FoldStrategy::Refactor`]
    /// (the literal per-cell `chol(H_f + λI)`, kept as fallback and test
    /// oracle). TOML: `[cv] fold_strategy = "refactor" | "downdate"`; CLI:
    /// `--fold-strategy`. Curves agree within rounding; the strategies are
    /// pinned against each other by the cross-mode conformance suite.
    pub fold_strategy: FoldStrategy,
    /// The numerical-trust knobs: factor drift/hop budget, bounded
    /// growing-shift retries, per-task panic retries — one
    /// [`RecoveryPolicy`] drives every escalation decision of the run.
    /// TOML: `[trust]`; CLI: `--trust-budget` and friends.
    pub recovery: RecoveryPolicy,
    /// Arm per-run observability ([`crate::obs`]): lock-free per-worker
    /// event rings, per-phase latency histograms, and the merged event log
    /// in the report. **Off by default** — disarmed runs take zero
    /// per-event work and are bitwise identical to armed ones (pinned by
    /// the chaos suite). TOML: `[obs] enabled = true`; implied by the CLI
    /// `--trace-out` / `--ledger-out` flags.
    pub obs: bool,
}

impl Default for CvConfig {
    fn default() -> Self {
        Self {
            k_folds: 5,
            q_grid: 31,
            g_samples: 4,
            degree: 2,
            lambda_range: None,
            seed: 0x9C0_1E5C,
            tsvd_rank_frac: 0.15,
            rsvd_params: (8, 1),
            metric: Metric::Rmse,
            sweep_threads: 0,
            sweep_batch: 0,
            chunk_rows: 0,
            mode: CvMode::KFold,
            fold_strategy: FoldStrategy::Downdate,
            recovery: RecoveryPolicy::default(),
            obs: false,
        }
    }
}

/// Aggregated result of a k-fold run of one algorithm.
pub struct CvReport {
    pub kind: SolverKind,
    /// The candidate λ grid.
    pub grid: Vec<f64>,
    /// Mean hold-out error per grid point (NaN-aware mean over folds).
    pub mean_errors: Vec<f64>,
    /// Mean best λ across folds (geometric mean — λ lives on a log scale).
    pub best_lambda: f64,
    /// Mean of per-fold best errors.
    pub best_error: f64,
    /// Cumulative phase timings over all folds.
    pub timer: PhaseTimer,
    /// Elapsed wall-clock seconds of the sweep (what shrinks with threads).
    pub wall_secs: f64,
    /// Per-fold (best λ, best error).
    pub fold_bests: Vec<(f64, f64)>,
    /// Probe trajectories per fold (Figure 9; empty for grid algorithms).
    pub probes: Vec<Vec<Probe>>,
    /// Recorded escalations of the unified recovery ladder — breakdowns,
    /// drift-budget refactorizations, shifted retries, skips, and panic
    /// quarantines — in ascending (fold, grid-index) order; empty on the
    /// happy path.
    pub degradations: Vec<Degradation>,
    /// The micro-kernel backend every GEMM of this run dispatched to
    /// ([`crate::linalg::kernel::active_backend`]): `"scalar"`, `"avx2"`, or
    /// `"neon"`. All backends are bit-identical; this records which one ran.
    pub kernel_backend: &'static str,
    /// The concrete fold strategy the sweep executed — never
    /// [`FoldStrategy::Auto`] (resolution happens in `SweepPlan::new`).
    pub fold_strategy: FoldStrategy,
    /// Where [`CvReport::fold_strategy`] came from: `"config"` (explicit
    /// setting), `"bench-file"` / `"bench-file-mismatch"` (auto mode,
    /// measured crossover — the latter when every usable row was recorded
    /// on a different kernel backend), `"probe"` (auto mode, no trajectory
    /// file — in-process micro-calibration), or `"default"` (auto mode,
    /// file present but unusable, or the probe failed) — see
    /// [`strategy`].
    pub strategy_source: &'static str,
    /// Worker threads the sweep used.
    pub threads: usize,
    /// Total tasks executed (Gram chunks + fold prep + anchors + sweeps).
    pub tasks: usize,
    /// Observability payload — merged event log + latency histograms —
    /// present only when the run was armed ([`CvConfig::obs`]). See
    /// [`crate::obs`] for the event schema and ordering contract.
    pub obs: Option<crate::obs::ObsReport>,
}

impl CvReport {
    /// Seconds summed across folds and phases — CPU-time-like when the sweep
    /// ran with threads > 1 (use [`CvReport::wall_secs`] for elapsed time).
    /// With one thread the two coincide, so single-threaded timing
    /// comparisons (Figure 6 / Table 3) are unaffected.
    pub fn total_secs(&self) -> f64 {
        self.timer.total()
    }
}

/// Run k-fold cross-validation of one algorithm over a dataset.
///
/// Routing: builds a [`SweepPlan`] (grid + thread/batch shape from
/// [`CvConfig`]), executes it on a [`SweepEngine`], and folds the resulting
/// [`SweepReport`] into a [`CvReport`] via [`aggregate_sweep`]. Thread count
/// comes from `cfg.sweep_threads` (0 = auto); any value yields bit-identical
/// numbers.
pub fn run_cv(
    ds: &SyntheticDataset,
    kind: SolverKind,
    cfg: &CvConfig,
) -> crate::Result<CvReport> {
    match cfg.mode {
        // a k-fold report cannot masquerade as a LOO-family run — route
        // explicitly to the tier's own entry point
        CvMode::Loo => anyhow::bail!(
            "cfg.mode is 'loo' but run_cv executes k-fold sweeps; \
             call cv::loo::run_loo (or Coordinator::run_loo) instead"
        ),
        CvMode::Aloocv => anyhow::bail!(
            "cfg.mode is 'aloocv' but run_cv executes k-fold sweeps; \
             call cv::aloocv::run_aloocv (or Coordinator::run_aloocv) instead"
        ),
        CvMode::KFold => {}
    }
    // ingest validation: non-finite rows/labels or shape mismatches are
    // structured errors here, never NaNs inside a factor
    gram::validate_rows(&ds.x, &ds.y)?;
    let plan = SweepPlan::new(ds, kind, cfg);
    let engine = SweepEngine::new(plan.threads);
    Ok(aggregate_sweep(engine.run(ds, &plan)?))
}

/// Fold a [`SweepReport`] into the aggregate [`CvReport`]: NaN-aware mean
/// error curve, geometric-mean best λ, mean best error. Aggregation iterates
/// folds in order on the calling thread, so it is deterministic regardless
/// of how the sweep was scheduled.
pub fn aggregate_sweep(report: SweepReport) -> CvReport {
    let SweepReport {
        kind,
        grid,
        fold_results,
        timer,
        wall_secs,
        degradations,
        kernel_backend,
        fold_strategy,
        strategy_source,
        threads,
        tasks,
        obs,
        ..
    } = report;

    let mut sum_errors = vec![0.0f64; grid.len()];
    let mut cnt_errors = vec![0usize; grid.len()];
    let mut fold_bests = Vec::with_capacity(fold_results.len());
    let mut probes = Vec::new();
    let mut log_lambda_sum = 0.0;
    let mut best_err_sum = 0.0;

    // folds whose every cell was skipped (quarantined task, ladder
    // exhausted everywhere) carry a non-finite best — leave them out of the
    // means instead of poisoning the aggregate; on the happy path this is
    // bit-for-bit the old k-fold mean
    let mut finite_folds = 0usize;
    for result in fold_results {
        for (i, &e) in result.errors.iter().enumerate() {
            if e.is_finite() {
                sum_errors[i] += e;
                cnt_errors[i] += 1;
            }
        }
        if result.best_error.is_finite() {
            log_lambda_sum += result.best_lambda.ln();
            best_err_sum += result.best_error;
            finite_folds += 1;
        }
        fold_bests.push((result.best_lambda, result.best_error));
        probes.push(result.probes);
    }

    let mean_errors: Vec<f64> = sum_errors
        .iter()
        .zip(&cnt_errors)
        .map(|(&s, &c)| if c > 0 { s / c as f64 } else { f64::NAN })
        .collect();

    let k = finite_folds as f64;
    CvReport {
        kind,
        grid,
        mean_errors,
        best_lambda: if finite_folds > 0 {
            (log_lambda_sum / k).exp()
        } else {
            f64::NAN
        },
        best_error: if finite_folds > 0 {
            best_err_sum / k
        } else {
            f64::NAN
        },
        timer,
        wall_secs,
        fold_bests,
        probes,
        degradations,
        kernel_backend,
        fold_strategy,
        strategy_source,
        threads,
        tasks,
        obs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::DatasetKind;

    #[test]
    fn holdout_metrics() {
        let xv = Matrix::eye(4);
        let yv = vec![1.0, 1.0, -1.0, -1.0];
        let theta = vec![1.0, 1.0, -1.0, 1.0]; // last one wrong
        assert!((holdout_error(&xv, &yv, &theta, Metric::Misclass) - 0.25).abs() < 1e-12);
        let rmse = holdout_error(&xv, &yv, &theta, Metric::Rmse);
        assert!((rmse - 1.0).abs() < 1e-12); // one coord off by 2 → √(4/4)=1
    }

    #[test]
    fn run_cv_chol_small() {
        let ds = SyntheticDataset::generate(DatasetKind::MnistLike, 120, 17, 3);
        let cfg = CvConfig {
            k_folds: 3,
            q_grid: 9,
            ..CvConfig::default()
        };
        let rep = run_cv(&ds, SolverKind::Chol, &cfg).unwrap();
        assert_eq!(rep.mean_errors.len(), 9);
        assert!(rep.mean_errors.iter().all(|e| e.is_finite()));
        assert!(rep.best_error > 0.0 && rep.best_error < 2.0);
        // factor-level default: the O(d³) work is the per-anchor `factor`
        // phase; per-(fold, λ) factors come from `fold_downdate`
        assert!(rep.timer.get("factor") > 0.0);
        assert!(rep.timer.get("fold_downdate") > 0.0);
        assert!(rep.degradations.is_empty());
        // shared-Gram pipeline: one assembly per run, one downdate per fold,
        // and no per-fold `hessian` SYRK anywhere
        assert_eq!(rep.timer.count("gram"), 1);
        assert_eq!(rep.timer.count("downdate"), 3);
        assert_eq!(rep.timer.count("hessian"), 0);
    }

    #[test]
    fn run_cv_chol_refactor_strategy_keeps_legacy_accounting() {
        let ds = SyntheticDataset::generate(DatasetKind::MnistLike, 120, 17, 3);
        let cfg = CvConfig {
            k_folds: 3,
            q_grid: 9,
            fold_strategy: FoldStrategy::Refactor,
            ..CvConfig::default()
        };
        let rep = run_cv(&ds, SolverKind::Chol, &cfg).unwrap();
        assert!(rep.timer.get("chol") > 0.0);
        assert_eq!(rep.timer.count("chol"), 3 * 9, "one chol per (fold, λ)");
        assert_eq!(rep.timer.count("factor"), 0);
        assert_eq!(rep.timer.count("fold_downdate"), 0);
        assert!(rep.degradations.is_empty());
    }

    #[test]
    fn fold_strategy_parse() {
        assert_eq!(FoldStrategy::parse("downdate"), Some(FoldStrategy::Downdate));
        assert_eq!(FoldStrategy::parse("Refactor"), Some(FoldStrategy::Refactor));
        assert_eq!(FoldStrategy::parse("auto"), Some(FoldStrategy::Auto));
        assert_eq!(FoldStrategy::parse("nope"), None);
        assert_eq!(FoldStrategy::Downdate.name(), "downdate");
        assert_eq!(FoldStrategy::Auto.name(), "auto");
    }

    /// Auto resolves before the engine runs: the report carries a concrete
    /// strategy, its provenance, and the dispatched kernel backend.
    #[test]
    fn run_cv_auto_strategy_resolves_and_reports() {
        let ds = SyntheticDataset::generate(DatasetKind::MnistLike, 120, 17, 3);
        let cfg = CvConfig {
            k_folds: 3,
            q_grid: 9,
            fold_strategy: FoldStrategy::Auto,
            ..CvConfig::default()
        };
        let rep = run_cv(&ds, SolverKind::Chol, &cfg).unwrap();
        assert_ne!(rep.fold_strategy, FoldStrategy::Auto, "must resolve");
        assert!(
            matches!(
                rep.strategy_source,
                "bench-file" | "bench-file-mismatch" | "probe" | "default"
            ),
            "auto provenance, got '{}'",
            rep.strategy_source
        );
        assert!(!rep.kernel_backend.is_empty());
        assert!(rep.mean_errors.iter().all(|e| e.is_finite()));
    }

    /// An explicit strategy is passed through untouched with source
    /// `"config"`.
    #[test]
    fn run_cv_explicit_strategy_reports_config_source() {
        let ds = SyntheticDataset::generate(DatasetKind::MnistLike, 120, 17, 3);
        let cfg = CvConfig {
            k_folds: 3,
            q_grid: 9,
            ..CvConfig::default()
        };
        let rep = run_cv(&ds, SolverKind::Chol, &cfg).unwrap();
        assert_eq!(rep.fold_strategy, FoldStrategy::Downdate);
        assert_eq!(rep.strategy_source, "config");
    }

    /// `factor_from_anchor` is numerically the refactorize oracle: same
    /// factor within rounding, happy path never falls back, and the factor
    /// lands in `scratch.factor`.
    #[test]
    fn factor_from_anchor_matches_refactorization() {
        use crate::data::kfold;
        use crate::linalg::cholesky::cholesky_shifted;
        let ds = SyntheticDataset::generate(DatasetKind::MnistLike, 103, 9, 4);
        let gram = GramCache::assemble(&ds.x, &ds.y);
        let mut t = PhaseTimer::new();
        let mut scratch = Scratch::new();
        let policy = RecoveryPolicy::default();
        for lam in [1e-2, 0.3] {
            let anchor = cholesky_shifted(gram.hessian(), lam).unwrap();
            let trust = FactorTrust::fresh(&anchor);
            for fold in kfold(ds.n(), 5, 1) {
                let (xv, yv) = fold.materialize_val(&ds.x, &ds.y);
                let fd = FoldData::from_gram(&gram, xv, yv, None, &mut t);
                let ff = fd
                    .factor_from_anchor(&anchor, trust, lam, &policy, &mut scratch, &mut t)
                    .unwrap();
                assert!(ff.degraded.is_none());
                assert_eq!(ff.rung, Rung::Downdate);
                assert!(ff.trust.hops() == trust.hops() + 1, "one charged hop");
                let oracle = cholesky_shifted(&fd.h_mat, lam).unwrap();
                assert!(
                    scratch.factor.max_abs_diff(&oracle) < 1e-9,
                    "λ={lam}: {:.2e}",
                    scratch.factor.max_abs_diff(&oracle)
                );
            }
        }
        assert_eq!(t.count("fold_downdate"), 10);
        assert_eq!(t.count("chol"), 0, "happy path never refactorizes");
    }

    /// The drift budget bites: an impossibly tight budget forces every
    /// fold factor through the refactor rung, bitwise equal to the direct
    /// `chol(H_f + λI)` oracle, with the climb recorded as a
    /// `"drift-budget"` degradation.
    #[test]
    fn tight_drift_budget_forces_refactorization() {
        use crate::data::kfold;
        use crate::linalg::cholesky::{cholesky_shifted, cholesky_shifted_into};
        use crate::linalg::trust::TrustBudget;
        let ds = SyntheticDataset::generate(DatasetKind::MnistLike, 103, 9, 4);
        let gram = GramCache::assemble(&ds.x, &ds.y);
        let mut t = PhaseTimer::new();
        let mut scratch = Scratch::new();
        let policy = RecoveryPolicy {
            budget: TrustBudget {
                max_relative_drift: 1e-300,
                max_hops: 0,
            },
            ..RecoveryPolicy::default()
        };
        let lam = 0.3;
        let anchor = cholesky_shifted(gram.hessian(), lam).unwrap();
        let trust = FactorTrust::fresh(&anchor);
        for fold in kfold(ds.n(), 5, 1) {
            let (xv, yv) = fold.materialize_val(&ds.x, &ds.y);
            let fd = FoldData::from_gram(&gram, xv, yv, None, &mut t);
            let ff = fd
                .factor_from_anchor(&anchor, trust, lam, &policy, &mut scratch, &mut t)
                .unwrap();
            assert_eq!(ff.rung, Rung::Refactor);
            assert_eq!(ff.extra_shift, 0.0);
            let info = ff.degraded.expect("budget climb must be recorded");
            assert_eq!(info.cause, "drift-budget");
            assert!(info.trust_at_failure > 0.0);
            let mut oracle = Matrix::zeros(0, 0);
            cholesky_shifted_into(&fd.h_mat, lam, &mut oracle).unwrap();
            assert_eq!(
                scratch.factor.as_slice(),
                oracle.as_slice(),
                "forced refactorization must be bitwise the refactor oracle"
            );
        }
        // every cell attempted the downdate AND paid the refactorization
        assert_eq!(t.count("fold_downdate"), 5);
        assert_eq!(t.count("chol"), 5);
    }

    #[test]
    fn run_cv_rejects_loo_mode() {
        // LOO must be routed explicitly — a k-fold report must never come
        // back silently labeled as a LOO run
        let ds = SyntheticDataset::generate(DatasetKind::MnistLike, 60, 9, 1);
        let cfg = CvConfig {
            mode: CvMode::Loo,
            ..CvConfig::default()
        };
        let err = run_cv(&ds, SolverKind::Chol, &cfg).unwrap_err();
        assert!(err.to_string().contains("run_loo"), "{err}");
    }

    #[test]
    fn fold_data_from_gram_matches_direct_build() {
        use crate::data::kfold;
        let ds = SyntheticDataset::generate(DatasetKind::MnistLike, 103, 9, 4);
        let gram = crate::data::gram::GramCache::assemble(&ds.x, &ds.y);
        let mut t = PhaseTimer::new();
        for fold in kfold(ds.n(), 5, 1) {
            let (xt, yt, xv, yv) = fold.materialize(&ds.x, &ds.y);
            let direct = FoldData::build(xt, yt, xv.clone(), yv.clone(), &mut t);
            let fast = FoldData::from_gram(&gram, xv, yv, None, &mut t);
            assert!(fast.h_mat.max_abs_diff(&direct.h_mat) < 1e-10);
            for (a, b) in fast.g_vec.iter().zip(&direct.g_vec) {
                assert!((a - b).abs() < 1e-10);
            }
            assert!(fast.train.is_none());
            assert!(direct.train.is_some());
        }
        assert_eq!(t.count("downdate"), 5);
        assert_eq!(t.count("hessian"), 10); // build times H and g separately
    }
}
