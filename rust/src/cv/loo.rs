//! Exact leave-one-out cross-validation on the factor-update subsystem.
//!
//! ## The workload
//!
//! Leave-one-out CV evaluates the ridge solution with each single sample
//! held out: `θ_i = (H_i + λI)⁻¹ g_i` with `H_i = G − x_i x_iᵀ` and
//! `g_i = g − y_i x_i`, scored by the prediction `x_iᵀθ_i` against `y_i`.
//! The naive engine refactorizes per held-out row — `O(n·d³)` per λ. The
//! key identity is that the hold-out downdate **commutes with the λ
//! shift**:
//!
//! ```text
//!   H_i + λI = (G + λI) − x_i x_iᵀ
//! ```
//!
//! so one **anchor factor** `L_λ = chol(G + λI)` per λ serves every
//! held-out row by a rank-1 hyperbolic downdate
//! ([`crate::linalg::chud::chol_downdate_rank1`], `O(d²)`): the LOO sweep
//! at one λ costs `O(n·d²)` instead of `O(n·d³)` — the same amortization
//! move the paper makes for the λ axis, applied to the sample axis.
//!
//! ## The λ axis — feeding the interpolation machinery
//!
//! Like piCholesky, the engine factors only `g ≪ q` anchor λ's (the same
//! `subsample_indices` schedule Algorithm 1 uses), computes the **exact**
//! LOO-RMSE at each anchor, and interpolates the error curve over the full
//! q-point grid with the existing PINRMSE polynomial machinery
//! ([`crate::pichol::pinrmse::fit_error_curve`]). PINRMSE is a poor
//! stand-in for *hold-out* curves interpolated from 4 points of a single
//! split (Figure 10), but the LOO curve is an *average over n splits* —
//! much smoother, so the same machinery serves it well; crank
//! `g_samples` up to `q_grid` for a fully exact curve.
//!
//! ## Breakdown semantics
//!
//! A held-out row whose removal makes `G − x_i x_iᵀ + λI` numerically
//! indefinite (λ at or below the Gram's rounding noise) surfaces as a
//! [`CholeskyError`] from the downdate, carrying the failing column index.
//! The sweep **skips that (row, λ) cell and records it** in
//! [`LooReport::skipped`] — one bad row never poisons the other `n−1`
//! contributions, and the anchor's RMSE is the mean over the rows that
//! factored. The engine copies the anchor factor into worker scratch
//! before each downdate, so a breakdown poisons only the scratch copy.
//!
//! Scheduling (per-i batches over the worker pool, bitwise independent of
//! the worker count) lives in
//! [`crate::coordinator::sweep_engine::SweepEngine::run_loo`]; this module
//! owns the task body (`eval_heldout_point`), the report shape, the
//! brute-force oracle the tests compare against, and the
//! [`AnchorFactors`] cache that keeps anchor factors fresh under
//! streaming-row arrivals by rank-k update instead of refactorization.

use crate::coordinator::sweep_engine::{LooPlan, SweepEngine};
use crate::data::gram::GramCache;
use crate::data::synthetic::SyntheticDataset;
use crate::linalg::cholesky::{cholesky_shifted, CholeskyError};
use crate::linalg::chud::{chol_downdate, chol_downdate_rank1, chol_update};
use crate::linalg::matrix::Matrix;
use crate::linalg::scratch::Scratch;
use crate::linalg::triangular::solve_cholesky_into;
use crate::util::PhaseTimer;

use super::CvConfig;

/// One skipped (held-out row, anchor λ) cell: the downdate hit a
/// numerically indefinite `G − x_i x_iᵀ + λI`. The error carries the
/// failing column index ([`CholeskyError::pivot`]).
#[derive(Debug, Clone)]
pub struct LooSkip {
    /// The held-out row index.
    pub row: usize,
    /// The anchor λ at which the downdate broke down.
    pub lambda: f64,
    /// The breakdown, with the failing column index in `pivot`.
    pub error: CholeskyError,
}

/// What a leave-one-out run produced.
pub struct LooReport {
    /// The candidate λ grid (`q` points).
    pub grid: Vec<f64>,
    /// Interpolated LOO-RMSE over the grid (NaN when too few anchors
    /// survived to fit the curve).
    pub curve: Vec<f64>,
    /// The anchor λ's that were factored exactly (`g` of them).
    pub anchor_lambdas: Vec<f64>,
    /// Exact LOO-RMSE at each anchor (mean over the rows that factored;
    /// NaN if every row broke down at that anchor).
    pub anchor_rmse: Vec<f64>,
    /// Grid minimizer of the interpolated curve. When too few anchors
    /// survive to fit the degree-r curve, degrades to the argmin over the
    /// surviving anchors' exact RMSEs (`curve` stays NaN); NaN only when
    /// every anchor lost all its rows.
    pub best_lambda: f64,
    /// Curve (or, degraded, exact anchor) value at `best_lambda`.
    pub best_error: f64,
    /// Skipped (row, λ) cells — breakdowns recorded, not fatal.
    pub skipped: Vec<LooSkip>,
    /// Phase timings summed over all tasks (`gram` / `factor` / `downdate`
    /// / `solve` / `holdout` / `fit` / `interp`). The structural
    /// invariants — `factor` counted once per anchor, `downdate` once per
    /// (row, anchor), zero per-row `chol` — are what the acceptance tests
    /// and `bench_kernels` assert.
    pub timer: PhaseTimer,
    /// Elapsed wall-clock seconds of the run.
    pub wall_secs: f64,
    /// Worker threads the run used.
    pub threads: usize,
    /// Total tasks executed (Gram chunks + anchor factors + per-i batches).
    pub tasks: usize,
    /// Rows of the dataset (the number of held-out evaluations per anchor).
    pub n: usize,
}

/// Run leave-one-out CV over a dataset: plans the anchors/grid from `cfg`
/// (`q_grid`, `g_samples`, `lambda_range`, threads/batch knobs), executes
/// on a [`SweepEngine`] — Gram assembly, anchor factorizations, per-i
/// downdate batches — and fits the LOO error curve. Results are
/// bit-identical for every thread count.
pub fn run_loo(ds: &SyntheticDataset, cfg: &CvConfig) -> crate::Result<LooReport> {
    let plan = LooPlan::new(ds, cfg);
    let engine = SweepEngine::new(plan.threads);
    engine.run_loo(ds, &plan)
}

/// One held-out evaluation at one anchor — the body of the sweep engine's
/// per-i tasks (and of the serial path: both run *this* code, which is why
/// parallel results are bit-identical to serial). Copies the anchor factor
/// into `scratch.factor`, downdates by `x_i`, solves, and returns the
/// squared prediction error; a downdate breakdown comes back as
/// `Err(CholeskyError)` for the caller to record. Every buffer is worker
/// scratch — zero heap allocation once warm.
pub(crate) fn eval_heldout_point(
    anchor: &Matrix,
    gram_g: &[f64],
    xi: &[f64],
    yi: f64,
    scratch: &mut Scratch,
    timer: &mut PhaseTimer,
) -> Result<f64, CholeskyError> {
    timer.time("downdate", || {
        scratch.factor.copy_from(anchor);
        scratch.vbuf.clear();
        scratch.vbuf.extend_from_slice(xi);
        chol_downdate_rank1(&mut scratch.factor, &mut scratch.vbuf, &mut scratch.trans)
    })?;
    timer.time("solve", || {
        scratch.gvec.clear();
        scratch.gvec.extend_from_slice(gram_g);
        for (gj, &xj) in scratch.gvec.iter_mut().zip(xi) {
            *gj -= yi * xj;
        }
        solve_cholesky_into(
            &scratch.factor,
            &scratch.gvec,
            &mut scratch.work,
            &mut scratch.theta,
        );
    });
    Ok(timer.time("holdout", || {
        let pred: f64 = xi.iter().zip(&scratch.theta).map(|(x, t)| x * t).sum();
        let r = pred - yi;
        r * r
    }))
}

/// The brute-force oracle: LOO-RMSE at each λ by per-row refactorization
/// (`n` exact `chol(H_i + λI)` per λ — the `O(n·d³)` path the downdate
/// engine replaces). Used by tests and `bench_kernels` as the correctness
/// and timing baseline; rows whose factorization fails are skipped, like
/// the engine skips downdate breakdowns.
pub fn brute_force_loo_rmse(ds: &SyntheticDataset, lambdas: &[f64]) -> Vec<f64> {
    let (n, h) = (ds.n(), ds.h());
    let mut out = Vec::with_capacity(lambdas.len());
    for &lam in lambdas {
        let mut sum = 0.0;
        let mut cnt = 0usize;
        for i in 0..n {
            // gather every row but i
            let mut xt = Matrix::zeros(n - 1, h);
            let mut yt = Vec::with_capacity(n - 1);
            let mut r = 0;
            for j in 0..n {
                if j == i {
                    continue;
                }
                xt.row_mut(r).copy_from_slice(ds.x.row(j));
                yt.push(ds.y[j]);
                r += 1;
            }
            let hmat = crate::linalg::gemm::syrk_lower(&xt);
            let gvec = crate::linalg::gemm::gemv_t(&xt, &yt);
            let Ok(l) = cholesky_shifted(&hmat, lam) else {
                continue;
            };
            let theta = crate::linalg::triangular::solve_cholesky(&l, &gvec);
            let pred: f64 = ds.x.row(i).iter().zip(&theta).map(|(x, t)| x * t).sum();
            sum += (pred - ds.y[i]) * (pred - ds.y[i]);
            cnt += 1;
        }
        out.push(if cnt > 0 {
            (sum / cnt as f64).sqrt()
        } else {
            f64::NAN
        });
    }
    out
}

/// A cache of anchor factors `chol(G + λ_s I)` that stays fresh under
/// dataset growth/shrinkage **by rank-k update/downdate instead of
/// refactorization**: the λ shift commutes with the row-block perturbation
/// (`(G ± XᵀX) + λI = (G + λI) ± XᵀX`), so appending `m` rows costs
/// `O(g·m·d²)` against the `O(g·d³)` of refactoring every anchor. Pairs
/// with [`GramCache::append_rows`] / [`GramCache::retire_rows`], which keep
/// `(G, g)` themselves incremental.
pub struct AnchorFactors {
    /// The anchor λ's, in the order the factors are stored.
    pub lambdas: Vec<f64>,
    /// `factors[s] = chol(G + lambdas[s]·I)`.
    pub factors: Vec<Matrix>,
}

impl AnchorFactors {
    /// Factor every anchor from scratch (the cold start).
    pub fn factor(gram: &GramCache, lambdas: &[f64]) -> Result<Self, CholeskyError> {
        let factors = lambdas
            .iter()
            .map(|&lam| cholesky_shifted(gram.hessian(), lam))
            .collect::<Result<Vec<_>, _>>()?;
        Ok(Self {
            lambdas: lambdas.to_vec(),
            factors,
        })
    }

    /// Fold `m` appended rows into every anchor factor by rank-m update
    /// (`O(g·m·d²)`). Call alongside [`GramCache::append_rows`] with the
    /// same block. `trans` is the rotation-transform buffer
    /// (`Scratch::trans` on worker paths).
    pub fn append_rows(&mut self, x_new: &Matrix, trans: &mut Matrix) {
        for f in &mut self.factors {
            let mut u = x_new.transpose(); // d×m: one update vector per column
            chol_update(f, &mut u, trans);
        }
    }

    /// Remove `m` retired rows from every anchor factor by rank-m
    /// downdate. **Transactional**: downdates land on copies and are
    /// committed only when every anchor succeeds, so on
    /// [`CholeskyError`] (some factor numerically indefinite — retire
    /// fewer rows at a time, or refactor from the downdated Gram) the
    /// cache is left exactly as it was; a half-downdated cache would
    /// silently corrupt every later solve.
    pub fn retire_rows(&mut self, x_old: &Matrix, trans: &mut Matrix) -> Result<(), CholeskyError> {
        let mut fresh = Vec::with_capacity(self.factors.len());
        for f in &self.factors {
            let mut l = f.clone();
            let mut u = x_old.transpose();
            chol_downdate(&mut l, &mut u, trans)?;
            fresh.push(l);
        }
        self.factors = fresh;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::DatasetKind;

    fn cfg(threads: usize) -> CvConfig {
        CvConfig {
            q_grid: 21,
            g_samples: 4,
            lambda_range: Some((0.1, 1.0)),
            sweep_threads: threads,
            ..CvConfig::default()
        }
    }

    /// The tentpole acceptance bar: the downdate engine's exact per-anchor
    /// LOO-RMSE matches brute-force per-row refactorization to ≤ 1e-9 RMS.
    #[test]
    fn loo_matches_brute_force_refactorization() {
        let ds = SyntheticDataset::generate(DatasetKind::MnistLike, 60, 9, 11);
        let rep = run_loo(&ds, &cfg(1)).unwrap();
        assert!(rep.skipped.is_empty(), "no breakdowns expected: {:?}", rep.skipped);
        let brute = brute_force_loo_rmse(&ds, &rep.anchor_lambdas);
        let rms = (rep
            .anchor_rmse
            .iter()
            .zip(&brute)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            / brute.len() as f64)
            .sqrt();
        assert!(rms <= 1e-9, "LOO vs brute-force RMS {rms:.2e}");
        // and the interpolated curve is finite everywhere
        assert!(rep.curve.iter().all(|e| e.is_finite()));
        assert!(rep.best_error.is_finite() && rep.best_lambda > 0.0);
    }

    /// Per-i downdate tasks are scheduled across the pool but results are
    /// bitwise independent of the worker count, like every other engine
    /// path.
    #[test]
    fn loo_bitwise_identical_across_worker_counts() {
        let ds = SyntheticDataset::generate(DatasetKind::MnistLike, 90, 13, 7);
        let serial = run_loo(&ds, &cfg(1)).unwrap();
        for threads in [2usize, 4] {
            let par = run_loo(&ds, &cfg(threads)).unwrap();
            assert_eq!(par.threads, threads);
            assert_eq!(serial.anchor_rmse, par.anchor_rmse, "threads={threads}");
            assert_eq!(serial.curve, par.curve, "threads={threads}");
            assert_eq!(serial.best_lambda, par.best_lambda);
            assert_eq!(serial.best_error, par.best_error);
            assert_eq!(serial.skipped.len(), par.skipped.len());
        }
    }

    /// The structural invariant behind the whole subsystem: exactly one
    /// O(d³) factorization per anchor, one downdate per (row, anchor), and
    /// zero per-row factorizations anywhere.
    #[test]
    fn loo_phase_counts_prove_no_per_row_refactorization() {
        let ds = SyntheticDataset::generate(DatasetKind::MnistLike, 50, 8, 3);
        for threads in [1usize, 3] {
            let rep = run_loo(&ds, &cfg(threads)).unwrap();
            let anchors = rep.anchor_lambdas.len() as u64;
            assert_eq!(rep.timer.count("gram"), 1);
            assert_eq!(rep.timer.count("factor"), anchors, "factor == anchors");
            assert_eq!(
                rep.timer.count("downdate"),
                ds.n() as u64 * anchors,
                "downdate == n per anchor"
            );
            assert_eq!(rep.timer.count("chol"), 0, "no per-row factorization");
            assert_eq!(rep.n, ds.n());
        }
    }

    /// A held-out row that makes `G − x_i x_iᵀ + λI` numerically indefinite
    /// is skipped and recorded — never fatal. Runs on the shared
    /// [`crate::testutil::conformance::spiked_dataset`] fixture (see its
    /// docs for the exactness argument): holding out the spiked row 0 makes
    /// the first downdate pivot exactly `1e18 − 1e18 = 0` — deterministic
    /// breakdown at column 0, at every anchor, while the other 39 rows
    /// sweep fine.
    #[test]
    fn loo_breakdown_is_skipped_and_recorded() {
        let ds = crate::testutil::conformance::spiked_dataset(40, 8, 5);
        let rep = run_loo(&ds, &cfg(2)).unwrap();
        let anchors = rep.anchor_lambdas.len();
        assert_eq!(
            rep.skipped.len(),
            anchors,
            "row 0 must break down at every anchor"
        );
        for skip in &rep.skipped {
            assert_eq!(skip.row, 0);
            assert_eq!(skip.error.pivot, 0, "failing column index must be carried");
            assert!(skip.error.value <= 0.0);
        }
        // the other 39 rows still produce a usable report
        assert!(rep.anchor_rmse.iter().all(|e| e.is_finite()));
        assert!(rep.curve.iter().all(|e| e.is_finite()));
    }

    /// Streaming growth: GramCache::append_rows + AnchorFactors::append_rows
    /// track a fresh assemble+factor of the grown dataset; retiring the same
    /// rows returns to the original factors.
    #[test]
    fn anchor_factors_follow_appended_and_retired_rows() {
        let ds = SyntheticDataset::generate(DatasetKind::MnistLike, 80, 11, 9);
        let (split, h) = (64usize, ds.h());
        let x0 = ds.x.slice(0, split, 0, h);
        let y0 = ds.y[..split].to_vec();
        let x_new = ds.x.slice(split, ds.n(), 0, h);
        let y_new = ds.y[split..].to_vec();
        let lambdas = [0.2, 0.8];

        let mut gram = GramCache::assemble(&x0, &y0);
        let mut anchors = AnchorFactors::factor(&gram, &lambdas).unwrap();
        let originals: Vec<Matrix> = anchors.factors.clone();
        let mut trans = Matrix::zeros(0, 0);

        // grow: incremental must track the fresh build of the full dataset
        gram.append_rows(&x_new, &y_new);
        anchors.append_rows(&x_new, &mut trans);
        let full = GramCache::assemble(&ds.x, &ds.y);
        assert_eq!(gram.n_rows(), ds.n());
        assert!(gram.hessian().max_abs_diff(full.hessian()) < 1e-8);
        let fresh = AnchorFactors::factor(&full, &lambdas).unwrap();
        for (inc, fr) in anchors.factors.iter().zip(&fresh.factors) {
            assert!(inc.max_abs_diff(fr) < 1e-7, "{:.2e}", inc.max_abs_diff(fr));
        }

        // shrink back: retire the same rows, return to the original factors
        gram.retire_rows(&x_new, &y_new);
        anchors.retire_rows(&x_new, &mut trans).unwrap();
        assert_eq!(gram.n_rows(), split);
        let base = GramCache::assemble(&x0, &y0);
        assert!(gram.hessian().max_abs_diff(base.hessian()) < 1e-8);
        for (inc, orig) in anchors.factors.iter().zip(&originals) {
            assert!(
                inc.max_abs_diff(orig) < 1e-7,
                "retire drift {:.2e}",
                inc.max_abs_diff(orig)
            );
        }

        // failed retire must be transactional: downdating rows that are not
        // in the Gram breaks down, and the cache must come back untouched
        let before: Vec<Matrix> = anchors.factors.clone();
        let mut huge = Matrix::zeros(2, h);
        for v in huge.as_mut_slice() {
            *v = 1e6;
        }
        let err = anchors.retire_rows(&huge, &mut trans);
        assert!(err.is_err(), "retiring foreign huge rows must break down");
        for (now, b) in anchors.factors.iter().zip(&before) {
            assert_eq!(
                now.as_slice(),
                b.as_slice(),
                "failed retire must leave every anchor factor untouched"
            );
        }
    }
}
