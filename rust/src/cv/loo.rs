//! Exact leave-one-out cross-validation on the factor-update subsystem.
//!
//! ## The workload
//!
//! Leave-one-out CV evaluates the ridge solution with each single sample
//! held out: `θ_i = (H_i + λI)⁻¹ g_i` with `H_i = G − x_i x_iᵀ` and
//! `g_i = g − y_i x_i`, scored by the prediction `x_iᵀθ_i` against `y_i`.
//! The naive engine refactorizes per held-out row — `O(n·d³)` per λ. The
//! key identity is that the hold-out downdate **commutes with the λ
//! shift**:
//!
//! ```text
//!   H_i + λI = (G + λI) − x_i x_iᵀ
//! ```
//!
//! so one **anchor factor** `L_λ = chol(G + λI)` per λ serves every
//! held-out row by a rank-1 hyperbolic downdate
//! ([`crate::linalg::chud::chol_downdate_rank1`], `O(d²)`): the LOO sweep
//! at one λ costs `O(n·d²)` instead of `O(n·d³)` — the same amortization
//! move the paper makes for the λ axis, applied to the sample axis.
//!
//! ## The λ axis — feeding the interpolation machinery
//!
//! Like piCholesky, the engine factors only `g ≪ q` anchor λ's (the same
//! `subsample_indices` schedule Algorithm 1 uses), computes the **exact**
//! LOO-RMSE at each anchor, and interpolates the error curve over the full
//! q-point grid with the existing PINRMSE polynomial machinery
//! ([`crate::pichol::pinrmse::fit_error_curve`]). PINRMSE is a poor
//! stand-in for *hold-out* curves interpolated from 4 points of a single
//! split (Figure 10), but the LOO curve is an *average over n splits* —
//! much smoother, so the same machinery serves it well; crank
//! `g_samples` up to `q_grid` for a fully exact curve.
//!
//! ## Breakdown semantics
//!
//! A held-out row whose removal makes `G − x_i x_iᵀ + λI` numerically
//! indefinite (λ at or below the Gram's rounding noise) surfaces as a
//! [`CholeskyError`] from the downdate, carrying the failing column index.
//! The cell then climbs the unified recovery ladder
//! ([`crate::cv::recovery`]): rung 2 rebuilds `H_i = G − x_i x_iᵀ` from the
//! cached Gram and refactors it directly — which routinely *rescues* rows
//! the rank-1 downdate cannot serve (the downdate fails on an exactly-zero
//! pivot; the direct `chol(H_i + λI)` sails through it at `√λ`) — rung 3
//! adds bounded growing shifts, and only full exhaustion **skips the
//! (row, λ) cell and records it** in [`LooReport::skipped`]. Every climb
//! above the downdate rung lands in [`LooReport::degradations`]. A drift
//! budget exhausted by the tracked rank-1 chain escalates through the same
//! ladder with `cause: "drift-budget"`. One bad row never poisons the
//! other `n−1` contributions; the engine copies the anchor factor into
//! worker scratch before each downdate, so a breakdown poisons only the
//! scratch copy.
//!
//! Scheduling (per-i batches over the worker pool, bitwise independent of
//! the worker count) lives in
//! [`crate::coordinator::sweep_engine::SweepEngine::run_loo`]; this module
//! owns the task body (`eval_heldout_point`), the report shape, the
//! brute-force oracle the tests compare against, and the
//! [`AnchorFactors`] cache that keeps anchor factors fresh under
//! streaming-row arrivals by rank-k update instead of refactorization.

use crate::coordinator::sweep_engine::{LooPlan, SweepEngine};
use crate::data::gram::GramCache;
use crate::data::synthetic::SyntheticDataset;
use crate::linalg::cholesky::{cholesky_shifted, CholeskyError};
use crate::linalg::chud::{chol_downdate_rank1_tracked, chol_downdate_tracked, chol_update_tracked};
use crate::linalg::matrix::Matrix;
use crate::linalg::scratch::Scratch;
use crate::linalg::triangular::solve_cholesky_into;
use crate::linalg::trust::{FactorTrust, TrustBudget};
use crate::util::PhaseTimer;

use super::recovery::{self, DegradeInfo, Degradation, RecoveryPolicy, Rung};
use super::CvConfig;

/// One skipped (held-out row, anchor λ) cell: the downdate hit a
/// numerically indefinite `G − x_i x_iᵀ + λI`. The error carries the
/// failing column index ([`CholeskyError::pivot`]).
#[derive(Debug, Clone)]
pub struct LooSkip {
    /// The held-out row index.
    pub row: usize,
    /// The anchor λ at which the downdate broke down.
    pub lambda: f64,
    /// The breakdown, with the failing column index in `pivot`.
    pub error: CholeskyError,
}

/// What a leave-one-out run produced.
pub struct LooReport {
    /// The candidate λ grid (`q` points).
    pub grid: Vec<f64>,
    /// Interpolated LOO-RMSE over the grid (NaN when too few anchors
    /// survived to fit the curve).
    pub curve: Vec<f64>,
    /// The anchor λ's that were factored exactly (`g` of them).
    pub anchor_lambdas: Vec<f64>,
    /// Exact LOO-RMSE at each anchor (mean over the rows that factored;
    /// NaN if every row broke down at that anchor).
    pub anchor_rmse: Vec<f64>,
    /// Grid minimizer of the interpolated curve. When too few anchors
    /// survive to fit the degree-r curve, degrades to the argmin over the
    /// surviving anchors' exact RMSEs (`curve` stays NaN); NaN only when
    /// every anchor lost all its rows.
    pub best_lambda: f64,
    /// Curve (or, degraded, exact anchor) value at `best_lambda`.
    pub best_error: f64,
    /// Skipped (row, λ) cells — full-ladder exhaustion recorded, not fatal.
    pub skipped: Vec<LooSkip>,
    /// Every cell that climbed above the downdate rung — rescued
    /// breakdowns, drift-budget refactorizations, skips — in ascending
    /// (row, anchor) order ([`crate::cv::recovery`]).
    pub degradations: Vec<Degradation>,
    /// Phase timings summed over all tasks (`gram` / `factor` / `downdate`
    /// / `solve` / `holdout` / `fit` / `interp`). The structural
    /// invariants — `factor` counted once per anchor, `downdate` once per
    /// (row, anchor), zero per-row `chol` — are what the acceptance tests
    /// and `bench_kernels` assert.
    pub timer: PhaseTimer,
    /// Elapsed wall-clock seconds of the run.
    pub wall_secs: f64,
    /// Worker threads the run used.
    pub threads: usize,
    /// Total tasks executed (Gram chunks + anchor factors + per-i batches).
    pub tasks: usize,
    /// Rows of the dataset (the number of held-out evaluations per anchor).
    pub n: usize,
    /// Observability payload — merged event log + latency histograms —
    /// present only when the run was armed ([`CvConfig::obs`]). See
    /// [`crate::obs`] for the event schema and ordering contract.
    pub obs: Option<crate::obs::ObsReport>,
}

/// Run leave-one-out CV over a dataset: plans the anchors/grid from `cfg`
/// (`q_grid`, `g_samples`, `lambda_range`, threads/batch knobs), executes
/// on a [`SweepEngine`] — Gram assembly, anchor factorizations, per-i
/// downdate batches — and fits the LOO error curve. Results are
/// bit-identical for every thread count.
pub fn run_loo(ds: &SyntheticDataset, cfg: &CvConfig) -> crate::Result<LooReport> {
    let plan = LooPlan::new(ds, cfg);
    let engine = SweepEngine::new(plan.threads);
    engine.run_loo(ds, &plan)
}

/// One held-out evaluation at one anchor — the body of the sweep engine's
/// per-i tasks (and of the serial path: both run *this* code, which is why
/// parallel results are bit-identical to serial). Copies the anchor factor
/// into `scratch.factor`, downdates by `x_i` (tracked against the anchor's
/// [`FactorTrust`] tag), solves, and returns the squared prediction error.
/// On a downdate breakdown — or a drift budget exhausted by the chain —
/// the cell climbs the recovery ladder: `H_i = G − x_i x_iᵀ` is rebuilt
/// from the cached Gram and refactored directly
/// ([`recovery::refactor_ladder`], "chol" phase), with the climb returned
/// as a `Some((rung, info))` record; only full ladder exhaustion comes
/// back as `Err(CholeskyError)` for the caller to skip-and-record. Every
/// buffer is worker scratch — zero heap allocation once warm.
#[allow(clippy::too_many_arguments)]
pub(crate) fn eval_heldout_point(
    anchor: &Matrix,
    anchor_trust: FactorTrust,
    gram: &GramCache,
    xi: &[f64],
    yi: f64,
    lam: f64,
    policy: &RecoveryPolicy,
    scratch: &mut Scratch,
    timer: &mut PhaseTimer,
) -> Result<(f64, Option<(Rung, DegradeInfo)>), CholeskyError> {
    let mut trust = anchor_trust;
    let down = timer.time("downdate", || {
        scratch.factor.copy_from(anchor);
        scratch.vbuf.clear();
        scratch.vbuf.extend_from_slice(xi);
        chol_downdate_rank1_tracked(
            &mut scratch.factor,
            &mut scratch.vbuf,
            &mut scratch.trans,
            &mut trust,
        )
    });
    let degrade = if down.is_ok() && !trust.exceeds(&policy.budget) {
        None
    } else {
        let (cause, detail) = match &down {
            Err(e) => ("breakdown", e.to_string()),
            Ok(()) => (
                "drift-budget",
                format!(
                    "relative drift {:.3e} over budget after {} hops",
                    trust.relative_drift(),
                    trust.hops()
                ),
            ),
        };
        let trust_at_failure = trust.relative_drift();
        // rung ≥ 2: rebuild H_i = G − x_i x_iᵀ from the cached Gram
        // (lower triangle only — that is all the factorization reads) and
        // send it up the ladder
        let (rung, extra) = timer.time("chol", || {
            let h_i = &mut scratch.update;
            h_i.copy_from(gram.hessian());
            for r in 0..h_i.rows() {
                for c in 0..=r {
                    h_i[(r, c)] -= xi[r] * xi[c];
                }
            }
            recovery::refactor_ladder(&scratch.update, lam, &mut scratch.factor, policy)
        })?;
        let mut info = DegradeInfo {
            cause,
            trust_at_failure,
            detail,
        };
        if extra > 0.0 {
            info.detail
                .push_str(&format!("; served with extra shift {extra:.3e}"));
        }
        Some((rung, info))
    };
    timer.time("solve", || {
        scratch.gvec.clear();
        scratch.gvec.extend_from_slice(gram.gradient());
        for (gj, &xj) in scratch.gvec.iter_mut().zip(xi) {
            *gj -= yi * xj;
        }
        solve_cholesky_into(
            &scratch.factor,
            &scratch.gvec,
            &mut scratch.work,
            &mut scratch.theta,
        );
    });
    let sqerr = timer.time("holdout", || {
        let pred: f64 = xi.iter().zip(&scratch.theta).map(|(x, t)| x * t).sum();
        let r = pred - yi;
        r * r
    });
    Ok((sqerr, degrade))
}

/// The brute-force oracle: LOO-RMSE at each λ by per-row refactorization
/// (`n` exact `chol(H_i + λI)` per λ — the `O(n·d³)` path the downdate
/// engine replaces). Used by tests and `bench_kernels` as the correctness
/// and timing baseline; rows whose factorization fails are skipped, like
/// the engine skips downdate breakdowns.
pub fn brute_force_loo_rmse(ds: &SyntheticDataset, lambdas: &[f64]) -> Vec<f64> {
    let (n, h) = (ds.n(), ds.h());
    let mut out = Vec::with_capacity(lambdas.len());
    for &lam in lambdas {
        let mut sum = 0.0;
        let mut cnt = 0usize;
        for i in 0..n {
            // gather every row but i
            let mut xt = Matrix::zeros(n - 1, h);
            let mut yt = Vec::with_capacity(n - 1);
            let mut r = 0;
            for j in 0..n {
                if j == i {
                    continue;
                }
                xt.row_mut(r).copy_from_slice(ds.x.row(j));
                yt.push(ds.y[j]);
                r += 1;
            }
            let hmat = crate::linalg::gemm::syrk_lower(&xt);
            let gvec = crate::linalg::gemm::gemv_t(&xt, &yt);
            let Ok(l) = cholesky_shifted(&hmat, lam) else {
                continue;
            };
            let theta = crate::linalg::triangular::solve_cholesky(&l, &gvec);
            let pred: f64 = ds.x.row(i).iter().zip(&theta).map(|(x, t)| x * t).sum();
            sum += (pred - ds.y[i]) * (pred - ds.y[i]);
            cnt += 1;
        }
        out.push(if cnt > 0 {
            (sum / cnt as f64).sqrt()
        } else {
            f64::NAN
        });
    }
    out
}

/// A cache of anchor factors `chol(G + λ_s I)` that stays fresh under
/// dataset growth/shrinkage **by rank-k update/downdate instead of
/// refactorization**: the λ shift commutes with the row-block perturbation
/// (`(G ± XᵀX) + λI = (G + λI) ± XᵀX`), so appending `m` rows costs
/// `O(g·m·d²)` against the `O(g·d³)` of refactoring every anchor. Pairs
/// with [`GramCache::append_rows`] / [`GramCache::retire_rows`], which keep
/// `(G, g)` themselves incremental.
pub struct AnchorFactors {
    /// The anchor λ's, in the order the factors are stored.
    pub lambdas: Vec<f64>,
    /// `factors[s] = chol(G + lambdas[s]·I)`.
    pub factors: Vec<Matrix>,
    /// One [`FactorTrust`] drift tag per factor, charged by every
    /// append/retire rotation pass; [`Self::refresh_stale`] refactors the
    /// ones whose budget is exhausted.
    pub trusts: Vec<FactorTrust>,
}

impl AnchorFactors {
    /// Factor every anchor from scratch (the cold start). Each factor
    /// starts with a fresh zero-drift trust tag.
    pub fn factor(gram: &GramCache, lambdas: &[f64]) -> Result<Self, CholeskyError> {
        let factors = lambdas
            .iter()
            .map(|&lam| cholesky_shifted(gram.hessian(), lam))
            .collect::<Result<Vec<_>, _>>()?;
        let trusts = factors.iter().map(FactorTrust::fresh).collect();
        Ok(Self {
            lambdas: lambdas.to_vec(),
            factors,
            trusts,
        })
    }

    /// Fold `m` appended rows into every anchor factor by rank-m update
    /// (`O(g·m·d²)`), charging each factor's drift tag. Call alongside
    /// [`GramCache::append_rows`] with the same block. `trans` is the
    /// rotation-transform buffer (`Scratch::trans` on worker paths).
    pub fn append_rows(&mut self, x_new: &Matrix, trans: &mut Matrix) {
        for (f, trust) in self.factors.iter_mut().zip(&mut self.trusts) {
            let mut u = x_new.transpose(); // d×m: one update vector per column
            chol_update_tracked(f, &mut u, trans, trust);
        }
    }

    /// Remove `m` retired rows from every anchor factor by rank-m
    /// downdate, charging each factor's drift tag. **Transactional**:
    /// downdates (and trust charges) land on copies and are committed only
    /// when every anchor succeeds, so on [`CholeskyError`] (some factor
    /// numerically indefinite — retire fewer rows at a time, or refactor
    /// from the downdated Gram) the cache is left exactly as it was; a
    /// half-downdated cache would silently corrupt every later solve.
    pub fn retire_rows(&mut self, x_old: &Matrix, trans: &mut Matrix) -> Result<(), CholeskyError> {
        let mut fresh = Vec::with_capacity(self.factors.len());
        let mut fresh_trusts = self.trusts.clone();
        for (f, trust) in self.factors.iter().zip(&mut fresh_trusts) {
            let mut l = f.clone();
            let mut u = x_old.transpose();
            chol_downdate_tracked(&mut l, &mut u, trans, trust)?;
            fresh.push(l);
        }
        self.factors = fresh;
        self.trusts = fresh_trusts;
        Ok(())
    }

    /// Refactor every anchor whose drift tag exceeds `budget` from the
    /// current Gram (resetting its tag to fresh); factors within budget
    /// are untouched. Returns how many were refreshed. This is the
    /// streaming-cache face of the drift-budget policy: call it after a
    /// burst of appends/retires to bound the accumulated rotation error
    /// without refactoring the anchors that do not need it.
    pub fn refresh_stale(
        &mut self,
        gram: &GramCache,
        budget: &TrustBudget,
    ) -> Result<usize, CholeskyError> {
        let mut refreshed = 0usize;
        for ((f, trust), &lam) in self
            .factors
            .iter_mut()
            .zip(self.trusts.iter_mut())
            .zip(self.lambdas.iter())
        {
            if trust.exceeds(budget) {
                *f = cholesky_shifted(gram.hessian(), lam)?;
                *trust = FactorTrust::fresh(f);
                refreshed += 1;
            }
        }
        Ok(refreshed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::DatasetKind;

    fn cfg(threads: usize) -> CvConfig {
        CvConfig {
            q_grid: 21,
            g_samples: 4,
            lambda_range: Some((0.1, 1.0)),
            sweep_threads: threads,
            ..CvConfig::default()
        }
    }

    /// The tentpole acceptance bar: the downdate engine's exact per-anchor
    /// LOO-RMSE matches brute-force per-row refactorization to ≤ 1e-9 RMS.
    #[test]
    fn loo_matches_brute_force_refactorization() {
        let ds = SyntheticDataset::generate(DatasetKind::MnistLike, 60, 9, 11);
        let rep = run_loo(&ds, &cfg(1)).unwrap();
        assert!(rep.skipped.is_empty(), "no breakdowns expected: {:?}", rep.skipped);
        assert!(rep.degradations.is_empty(), "no escalations expected");
        let brute = brute_force_loo_rmse(&ds, &rep.anchor_lambdas);
        let rms = (rep
            .anchor_rmse
            .iter()
            .zip(&brute)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            / brute.len() as f64)
            .sqrt();
        assert!(rms <= 1e-9, "LOO vs brute-force RMS {rms:.2e}");
        // and the interpolated curve is finite everywhere
        assert!(rep.curve.iter().all(|e| e.is_finite()));
        assert!(rep.best_error.is_finite() && rep.best_lambda > 0.0);
    }

    /// Per-i downdate tasks are scheduled across the pool but results are
    /// bitwise independent of the worker count, like every other engine
    /// path.
    #[test]
    fn loo_bitwise_identical_across_worker_counts() {
        let ds = SyntheticDataset::generate(DatasetKind::MnistLike, 90, 13, 7);
        let serial = run_loo(&ds, &cfg(1)).unwrap();
        for threads in [2usize, 4] {
            let par = run_loo(&ds, &cfg(threads)).unwrap();
            assert_eq!(par.threads, threads);
            assert_eq!(serial.anchor_rmse, par.anchor_rmse, "threads={threads}");
            assert_eq!(serial.curve, par.curve, "threads={threads}");
            assert_eq!(serial.best_lambda, par.best_lambda);
            assert_eq!(serial.best_error, par.best_error);
            assert_eq!(serial.skipped.len(), par.skipped.len());
            assert_eq!(serial.degradations.len(), par.degradations.len());
        }
    }

    /// The structural invariant behind the whole subsystem: exactly one
    /// O(d³) factorization per anchor, one downdate per (row, anchor), and
    /// zero per-row factorizations anywhere.
    #[test]
    fn loo_phase_counts_prove_no_per_row_refactorization() {
        let ds = SyntheticDataset::generate(DatasetKind::MnistLike, 50, 8, 3);
        for threads in [1usize, 3] {
            let rep = run_loo(&ds, &cfg(threads)).unwrap();
            let anchors = rep.anchor_lambdas.len() as u64;
            assert_eq!(rep.timer.count("gram"), 1);
            assert_eq!(rep.timer.count("factor"), anchors, "factor == anchors");
            assert_eq!(
                rep.timer.count("downdate"),
                ds.n() as u64 * anchors,
                "downdate == n per anchor"
            );
            assert_eq!(rep.timer.count("chol"), 0, "no per-row factorization");
            assert_eq!(rep.n, ds.n());
        }
    }

    /// A held-out row that makes the rank-1 downdate numerically indefinite
    /// is **rescued by the recovery ladder**, not skipped: on the shared
    /// [`crate::testutil::conformance::spiked_dataset`] fixture (see its
    /// docs for the exactness argument), holding out the spiked row 0 makes
    /// the downdate pivot exactly `1e18 − 1e18 = 0` — deterministic
    /// breakdown at column 0, at every anchor — but rung 2's direct
    /// `chol(H_0 + λI)` sails through the exactly-zero column at pivot
    /// `√λ`, so the cell is served (prediction 0, squared error exactly 1)
    /// and recorded as a rung-2 degradation. Nothing is skipped and every
    /// row contributes.
    #[test]
    fn loo_breakdown_is_rescued_by_refactor_rung() {
        let ds = crate::testutil::conformance::spiked_dataset(40, 8, 5);
        let rep = run_loo(&ds, &cfg(2)).unwrap();
        let anchors = rep.anchor_lambdas.len();
        assert!(
            rep.skipped.is_empty(),
            "rung 2 must rescue the spiked row: {:?}",
            rep.skipped
        );
        assert_eq!(
            rep.degradations.len(),
            anchors,
            "row 0 must escalate at every anchor"
        );
        for (d, &lam) in rep.degradations.iter().zip(&rep.anchor_lambdas) {
            assert_eq!(d.surface, "loo");
            assert_eq!(d.fold, 0, "only the spiked row escalates");
            assert_eq!(d.lambda, lam);
            assert_eq!(d.cause, "breakdown");
            assert_eq!(d.rung, Rung::Refactor, "no extra shift needed");
        }
        // one ladder refactorization per escalated cell — and only those
        assert_eq!(rep.timer.count("chol"), anchors as u64);
        // all 40 rows contribute now, and the report is fully usable
        assert!(rep.anchor_rmse.iter().all(|e| e.is_finite()));
        assert!(rep.curve.iter().all(|e| e.is_finite()));
    }

    /// Streaming growth: GramCache::append_rows + AnchorFactors::append_rows
    /// track a fresh assemble+factor of the grown dataset; retiring the same
    /// rows returns to the original factors.
    #[test]
    fn anchor_factors_follow_appended_and_retired_rows() {
        let ds = SyntheticDataset::generate(DatasetKind::MnistLike, 80, 11, 9);
        let (split, h) = (64usize, ds.h());
        let x0 = ds.x.slice(0, split, 0, h);
        let y0 = ds.y[..split].to_vec();
        let x_new = ds.x.slice(split, ds.n(), 0, h);
        let y_new = ds.y[split..].to_vec();
        let lambdas = [0.2, 0.8];

        let mut gram = GramCache::assemble(&x0, &y0);
        let mut anchors = AnchorFactors::factor(&gram, &lambdas).unwrap();
        let originals: Vec<Matrix> = anchors.factors.clone();
        let mut trans = Matrix::zeros(0, 0);

        // grow: incremental must track the fresh build of the full dataset
        gram.append_rows(&x_new, &y_new).unwrap();
        anchors.append_rows(&x_new, &mut trans);
        let full = GramCache::assemble(&ds.x, &ds.y);
        assert_eq!(gram.n_rows(), ds.n());
        assert!(gram.hessian().max_abs_diff(full.hessian()) < 1e-8);
        let fresh = AnchorFactors::factor(&full, &lambdas).unwrap();
        for (inc, fr) in anchors.factors.iter().zip(&fresh.factors) {
            assert!(inc.max_abs_diff(fr) < 1e-7, "{:.2e}", inc.max_abs_diff(fr));
        }

        // shrink back: retire the same rows, return to the original factors
        gram.retire_rows(&x_new, &y_new);
        anchors.retire_rows(&x_new, &mut trans).unwrap();
        assert_eq!(gram.n_rows(), split);
        let base = GramCache::assemble(&x0, &y0);
        assert!(gram.hessian().max_abs_diff(base.hessian()) < 1e-8);
        for (inc, orig) in anchors.factors.iter().zip(&originals) {
            assert!(
                inc.max_abs_diff(orig) < 1e-7,
                "retire drift {:.2e}",
                inc.max_abs_diff(orig)
            );
        }

        // failed retire must be transactional: downdating rows that are not
        // in the Gram breaks down, and the cache must come back untouched
        let before: Vec<Matrix> = anchors.factors.clone();
        let mut huge = Matrix::zeros(2, h);
        for v in huge.as_mut_slice() {
            *v = 1e6;
        }
        let err = anchors.retire_rows(&huge, &mut trans);
        assert!(err.is_err(), "retiring foreign huge rows must break down");
        for (now, b) in anchors.factors.iter().zip(&before) {
            assert_eq!(
                now.as_slice(),
                b.as_slice(),
                "failed retire must leave every anchor factor untouched"
            );
        }
    }

    /// The streaming face of the drift budget: every append/retire charges
    /// each anchor's trust tag, and `refresh_stale` refactors exactly the
    /// anchors whose budget is exhausted — bitwise the cold factorization —
    /// resetting their tags, while fresh factors are never touched.
    #[test]
    fn anchor_factors_refresh_stale_under_tight_budget() {
        let ds = SyntheticDataset::generate(DatasetKind::MnistLike, 70, 9, 13);
        let (split, h) = (60usize, ds.h());
        let x0 = ds.x.slice(0, split, 0, h);
        let y0 = ds.y[..split].to_vec();
        let x_new = ds.x.slice(split, ds.n(), 0, h);
        let y_new = ds.y[split..].to_vec();
        let lambdas = [0.3, 0.9];
        let tight = TrustBudget {
            max_relative_drift: 1e-300,
            max_hops: 0,
        };

        let mut gram = GramCache::assemble(&x0, &y0);
        let mut anchors = AnchorFactors::factor(&gram, &lambdas).unwrap();
        assert!(anchors.trusts.iter().all(|t| t.hops() == 0 && t.drift() == 0.0));
        // fresh factors carry zero drift — nothing is stale even under a
        // budget this tight
        assert_eq!(anchors.refresh_stale(&gram, &tight).unwrap(), 0);

        gram.append_rows(&x_new, &y_new).unwrap();
        let mut trans = Matrix::zeros(0, 0);
        anchors.append_rows(&x_new, &mut trans);
        assert!(anchors.trusts.iter().all(|t| t.hops() == 1 && t.drift() > 0.0));
        // the default budget tolerates a single hop by ~6 orders of
        // magnitude…
        assert_eq!(
            anchors.refresh_stale(&gram, &TrustBudget::default()).unwrap(),
            0
        );
        // …the tight one refreshes every factor, bitwise the cold build
        assert_eq!(anchors.refresh_stale(&gram, &tight).unwrap(), 2);
        let cold = AnchorFactors::factor(&gram, &lambdas).unwrap();
        for (a, b) in anchors.factors.iter().zip(&cold.factors) {
            assert_eq!(a.as_slice(), b.as_slice());
        }
        assert!(anchors.trusts.iter().all(|t| t.hops() == 0 && t.drift() == 0.0));
    }
}
