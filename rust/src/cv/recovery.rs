//! The unified breakdown-escalation ladder and degradation reporting.
//!
//! Every factor-producing path in the engine — k-fold downdate chains, LOO
//! rank-1 chains, anchored-grid tasks — used to carry its own ad-hoc
//! breakdown policy (skip-and-record in LOO, per-cell refactor fallback in
//! k-fold, shift-and-retry in `cholesky`). This module replaces them with
//! **one ladder**, applied uniformly and driven by one [`RecoveryPolicy`]:
//!
//! ```text
//!   rung 1  Downdate         the fast path: reuse the anchor factor via a
//!                            (tracked) hyperbolic downdate
//!      │ breakdown, or drift budget exceeded
//!      ▼
//!   rung 2  Refactor         full chol(H_f + λI) from the fold's own
//!                            downdated Gram pair — the strategy-independent
//!                            oracle, bitwise the refactor strategy's cell
//!      │ indefinite at λ
//!      ▼
//!   rung 3  ShiftedRefactor  chol(H_f + (λ+extra)·I), extra growing by
//!                            `shift_growth` for at most `max_shift_retries`
//!                            attempts ([`cholesky_shifted_retry_into`])
//!      │ still indefinite
//!      ▼
//!   rung 4  Skip             the cell's error becomes NaN; aggregation is
//!                            NaN-aware, the sweep completes
//! ```
//!
//! Climbing above a path's **baseline rung** (rung 1 for the downdate
//! strategy, rung 2 for the refactor strategy) is recorded as a
//! [`Degradation`] — which cell, why ([`Degradation::cause`]), how far the
//! ladder climbed, and the factor's relative drift at the moment of failure
//! — surfaced in `SweepReport::degradations` / `CvReport::degradations` in
//! deterministic ascending (fold, grid-index) order. Worker panics ride the
//! same reporting: a task that keeps panicking after `task_retries`
//! resubmissions is quarantined, its cells skip to NaN, and the report gains
//! a `cause: "panic"` entry naming the task.

use crate::linalg::cholesky::{cholesky_shifted_retry_into, CholeskyError, ShiftOutcome};
use crate::linalg::matrix::Matrix;
use crate::linalg::trust::TrustBudget;
use std::fmt;

/// How far up the escalation ladder a cell's factor had to climb.
///
/// Ordered: `Downdate < Refactor < ShiftedRefactor < Skip`, so "did this
/// cell degrade" is `rung > baseline`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rung {
    /// Rung 1 — the anchor-reuse fast path (tracked hyperbolic downdate).
    Downdate,
    /// Rung 2 — full refactorization `chol(H + λI)` from the cell's own
    /// Gram pair.
    Refactor,
    /// Rung 3 — refactorization with a recorded extra diagonal shift
    /// (the factor solves the *shifted* problem).
    ShiftedRefactor,
    /// Rung 4 — the cell was skipped; its error is NaN and aggregation
    /// ignores it.
    Skip,
}

impl Rung {
    pub fn name(&self) -> &'static str {
        match self {
            Rung::Downdate => "downdate",
            Rung::Refactor => "refactor",
            Rung::ShiftedRefactor => "shifted-refactor",
            Rung::Skip => "skip",
        }
    }
}

impl fmt::Display for Rung {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// One recorded escalation: a cell that had to climb above its path's
/// baseline rung, carried in `SweepReport::degradations` /
/// `CvReport::degradations` (ascending (fold, grid-index) order — the
/// deterministic-merge contract covers degradations too).
#[derive(Debug, Clone)]
pub struct Degradation {
    /// Which engine surface degraded: `"kfold"`, `"loo"`, `"grid"`, or
    /// `"task"` (worker-panic quarantine).
    pub surface: &'static str,
    /// Fold index (k-fold), held-out row (LOO), or task index (`"task"`).
    pub fold: usize,
    /// The grid λ of the affected cell (NaN for whole-task entries).
    pub lambda: f64,
    /// Why the ladder was climbed: `"breakdown"` (indefinite pivot),
    /// `"drift-budget"` (trust budget exceeded), or `"panic"` (worker
    /// panic quarantine).
    pub cause: &'static str,
    /// The rung that finally served (or skipped) the cell.
    pub rung: Rung,
    /// The factor's relative drift bound at the moment of failure
    /// ([`crate::linalg::trust::FactorTrust::relative_drift`]); 0.0 when no
    /// tracked factor was involved (e.g. panics).
    pub trust: f64,
    /// Human-readable specifics (failing pivot, extra shift, panic payload).
    pub detail: String,
}

impl fmt::Display for Degradation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "[{}] fold {} λ={:.3e}: {} → {} (trust {:.2e}) {}",
            self.surface, self.fold, self.lambda, self.cause, self.rung, self.trust, self.detail
        )
    }
}

/// The cause and context captured at the moment a ladder climb started —
/// everything a [`Degradation`] needs except the cell coordinates (and the
/// final rung), which only the caller knows.
#[derive(Debug, Clone)]
pub struct DegradeInfo {
    /// `"breakdown"` or `"drift-budget"` (see [`Degradation::cause`]).
    pub cause: &'static str,
    /// The factor's relative drift bound when the climb started.
    pub trust_at_failure: f64,
    /// Human-readable specifics (failing pivot, drift vs budget, …).
    pub detail: String,
}

impl DegradeInfo {
    /// Attach the cell coordinates and final rung to produce the report
    /// entry.
    pub fn into_degradation(
        self,
        surface: &'static str,
        fold: usize,
        lambda: f64,
        rung: Rung,
    ) -> Degradation {
        Degradation {
            surface,
            fold,
            lambda,
            cause: self.cause,
            rung,
            trust: self.trust_at_failure,
            detail: self.detail,
        }
    }
}

/// The one knob set that drives every recovery decision in the engine —
/// TOML `[trust]`, CLI `--trust-*` flags.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RecoveryPolicy {
    /// Drift/hop budget on reused factors; exceeding it forces a full
    /// refactorization (cause `"drift-budget"`).
    pub budget: TrustBudget,
    /// Bounded growing-shift retries of ladder rung 3 (0 disables the
    /// rung — breakdown at rung 2 skips straight to rung 4).
    pub max_shift_retries: u32,
    /// Per-attempt growth factor of the rung-3 extra shift (values ≤ 1
    /// are coerced to 10).
    pub shift_growth: f64,
    /// Resubmissions of a panicking sweep task before it is quarantined
    /// and its cells skip to NaN.
    pub task_retries: u32,
}

impl Default for RecoveryPolicy {
    fn default() -> Self {
        Self {
            budget: TrustBudget::default(),
            max_shift_retries: 4,
            shift_growth: 10.0,
            task_retries: 1,
        }
    }
}

impl RecoveryPolicy {
    /// A policy whose drift budget never bites — rungs 2–4 still apply on
    /// genuine breakdowns (the pre-trust engine behavior).
    pub fn unlimited() -> Self {
        Self {
            budget: TrustBudget::unlimited(),
            ..Self::default()
        }
    }
}

/// Rungs 2–3 in one call: full refactorization `chol(h + λI)` into `out`,
/// escalating to bounded growing-shift retries on breakdown. Returns the
/// rung that served the factor and the extra shift it needed (0.0 at rung
/// 2). `Err` means rung 3 is exhausted too — the caller's only move left is
/// rung 4 (skip-and-record).
pub fn refactor_ladder(
    h: &Matrix,
    lam: f64,
    out: &mut Matrix,
    policy: &RecoveryPolicy,
) -> Result<(Rung, f64), CholeskyError> {
    let ShiftOutcome {
        extra_shift,
        attempts,
    } = cholesky_shifted_retry_into(h, lam, out, policy.max_shift_retries, policy.shift_growth)?;
    if attempts == 0 {
        Ok((Rung::Refactor, 0.0))
    } else {
        Ok((Rung::ShiftedRefactor, extra_shift))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::Gemm;
    use crate::testutil::random_matrix;

    #[test]
    fn rungs_are_ordered_and_named() {
        assert!(Rung::Downdate < Rung::Refactor);
        assert!(Rung::Refactor < Rung::ShiftedRefactor);
        assert!(Rung::ShiftedRefactor < Rung::Skip);
        assert_eq!(Rung::ShiftedRefactor.name(), "shifted-refactor");
        assert_eq!(Rung::Skip.to_string(), "skip");
    }

    #[test]
    fn default_policy_matches_documented_knobs() {
        let p = RecoveryPolicy::default();
        assert_eq!(p.max_shift_retries, 4);
        assert_eq!(p.shift_growth, 10.0);
        assert_eq!(p.task_retries, 1);
        assert_eq!(p.budget, crate::linalg::trust::TrustBudget::default());
        assert!(!RecoveryPolicy::unlimited()
            .budget
            .max_relative_drift
            .is_finite());
    }

    #[test]
    fn ladder_serves_spd_at_rung_two_with_no_extra() {
        let x = random_matrix(50, 20, 11);
        let h = crate::linalg::gemm::syrk_lower(&x);
        let mut out = Matrix::zeros(0, 0);
        let (rung, extra) = refactor_ladder(&h, 0.2, &mut out, &RecoveryPolicy::default()).unwrap();
        assert_eq!(rung, Rung::Refactor);
        assert_eq!(extra, 0.0);
    }

    #[test]
    fn ladder_escalates_to_shifted_refactor_on_rank_deficiency() {
        let xt = random_matrix(12, 5, 7);
        let g = Gemm::default().a_bt(&xt, &xt); // 12×12, rank ≤ 5
        let mut out = Matrix::zeros(0, 0);
        let policy = RecoveryPolicy {
            max_shift_retries: 8,
            ..RecoveryPolicy::default()
        };
        let (rung, extra) = refactor_ladder(&g, 0.0, &mut out, &policy).unwrap();
        assert_eq!(rung, Rung::ShiftedRefactor);
        assert!(extra > 0.0);
    }

    #[test]
    fn exhausted_ladder_reports_the_breakdown() {
        let mut bad = Matrix::eye(5);
        bad[(2, 2)] = -1e12;
        let mut out = Matrix::zeros(0, 0);
        let err = refactor_ladder(&bad, 1e-3, &mut out, &RecoveryPolicy::default()).unwrap_err();
        assert_eq!(err.pivot, 2);
    }

    #[test]
    fn degradation_display_names_the_cell() {
        let d = Degradation {
            surface: "kfold",
            fold: 3,
            lambda: 1e-2,
            cause: "breakdown",
            rung: Rung::Refactor,
            trust: 2.5e-13,
            detail: "pivot 0".into(),
        };
        let s = d.to_string();
        assert!(s.contains("kfold") && s.contains("fold 3") && s.contains("refactor"), "{s}");
    }
}
