//! Measured-crossover auto-selection of the k-fold factor strategy.
//!
//! `fold_strategy = "auto"` turns the downdate-vs-refactor choice from a
//! static default into a **cost-model decision driven by this machine's own
//! measurements**: the perf harness (`benches/bench_kernels.rs`) records,
//! per dimension `d`, the wall-clock of a rank-`CHUD_RANK_CHUNK` packed
//! downdate (`chud_rk.packed_secs`) and of the full refactorization it
//! replaces (`chud_rk.reference_secs`). From the best `chud_rk` row — rows
//! whose recorded `kernel_backend` matches the backend *this* run dispatches
//! to are preferred as a class, then nearest dimension within the class —
//! the picker extrapolates both costs to the run's actual `(n_v, d)`:
//!
//! - downdate: `packed · (d/d_row)² · ceil(n_v / CHUD_RANK_CHUNK)` — the
//!   chained rank-`n_v` downdate is `O(n_v·d²)`, executed in
//!   rank-`CHUD_RANK_CHUNK` chain links;
//! - refactor: `reference · (d/d_row)³` — one `chol(H_f + λI)` is `O(d³)`.
//!
//! Downdate wins when its predicted cost is ≤ the refactor prediction —
//! the asymptotic `n_v ≪ d` regime, which the measurement grounds at real
//! constants instead of big-O faith.
//!
//! The trajectory file is best-effort input, and the provenance string
//! records exactly which way every decision was made so reports never hide
//! a fallback:
//!
//! - `"config"` — the strategy was explicit, no measurement consulted;
//! - `"bench-file"` — the measured crossover decided, from a row recorded
//!   on the same micro-kernel backend this run uses;
//! - `"bench-file-mismatch"` — the crossover decided, but every usable row
//!   was recorded on a *different* backend (timings are transferable only
//!   to first order — the note flags the weaker evidence);
//! - `"probe"` — no trajectory file existed, so a ~10 ms in-process
//!   micro-calibration measured the `chud_rk`-vs-refactor crossover right
//!   here (cached per kernel backend: a later `force_backend` /
//!   `PICHOL_KERNEL_BACKEND` flip re-probes instead of reusing a
//!   measurement taken under different dispatch) instead of silently
//!   using the static default;
//! - `"default"` — the file was present but malformed/unusable (kept
//!   distinct from *absent* so a corrupt file degrades loudly rather than
//!   triggering hidden re-measurement), or the probe itself failed.
//!
//! Resolution happens once per run in
//! [`SweepPlan::new`](crate::coordinator::sweep_engine::SweepPlan::new);
//! the sweep engine itself never sees [`FoldStrategy::Auto`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::cv::FoldStrategy;
use crate::linalg::chud::{chol_downdate_tracked, CHUD_RANK_CHUNK};
use crate::linalg::trust::FactorTrust;
use crate::runtime::json::{self, Json};

/// A resolved strategy plus its provenance.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Resolved {
    /// The concrete strategy (never [`FoldStrategy::Auto`]).
    pub strategy: FoldStrategy,
    /// `"config"`, `"bench-file"`, `"bench-file-mismatch"`, `"probe"` or
    /// `"default"` — see the module docs for the exact semantics.
    pub source: &'static str,
}

/// The static default auto falls back to when no usable measurement exists.
pub const AUTO_DEFAULT: FoldStrategy = FoldStrategy::Downdate;

/// Env var naming the bench trajectory file to read (tests; deployments
/// with a relocated trajectory). Unset → the workspace-root
/// `BENCH_kernels.json` the perf harness writes.
pub const BENCH_FILE_ENV: &str = "PICHOL_BENCH_FILE";

/// Resolve a configured strategy for a run with `k_folds` over an `n×d`
/// dataset. Explicit strategies pass through with source `"config"`; auto
/// reads the bench trajectory file (see [`BENCH_FILE_ENV`]) and, when no
/// file exists at all, falls back to the in-process micro-calibration
/// probe before surrendering to the static default.
pub fn resolve(cfg_strategy: FoldStrategy, n: usize, d: usize, k_folds: usize) -> Resolved {
    let n_v = if k_folds > 0 { n.div_ceil(k_folds) } else { n };
    if cfg_strategy != FoldStrategy::Auto {
        return resolve_with(cfg_strategy, n_v, d, None, "scalar");
    }
    let active = crate::linalg::kernel::active_backend().name();
    match read_bench_file() {
        Some(text) => resolve_with(FoldStrategy::Auto, n_v, d, Some(&text), active),
        None => match probe_for(active) {
            // a probe measures on the active backend by construction —
            // the cache is keyed by it, so a later backend flip re-probes
            Some((d_row, packed, reference)) => Resolved {
                strategy: decide(n_v, d, d_row, packed, reference),
                source: "probe",
            },
            None => Resolved {
                strategy: AUTO_DEFAULT,
                source: "default",
            },
        },
    }
}

/// Pure core of [`resolve`]: decide from the configured strategy, the fold
/// validation-block size `n_v`, the factor dimension `d`, the bench
/// trajectory text (`None` = file absent/unreadable) and the active
/// micro-kernel backend name. Separated from the filesystem (and from the
/// probe — `None` text falls straight to the default here) so unit tests
/// drive both sides of the crossover directly.
pub fn resolve_with(
    cfg_strategy: FoldStrategy,
    n_v: usize,
    d: usize,
    bench_text: Option<&str>,
    active_backend: &str,
) -> Resolved {
    if cfg_strategy != FoldStrategy::Auto {
        return Resolved {
            strategy: cfg_strategy,
            source: "config",
        };
    }
    match bench_text.and_then(|t| pick_from_json(t, n_v, d, active_backend)) {
        Some((strategy, mismatch)) => Resolved {
            strategy,
            source: if mismatch {
                "bench-file-mismatch"
            } else {
                "bench-file"
            },
        },
        None => Resolved {
            strategy: AUTO_DEFAULT,
            source: "default",
        },
    }
}

/// Parse a `BENCH_kernels.json` document and pick a strategy for `(n_v, d)`
/// from its `chud_rk` rows. Rows recorded on `active_backend` (per-row
/// `kernel_backend`, falling back to the document-level field) are
/// preferred as a class over rows from other backends; within a class the
/// nearest-dimension row wins. Returns the decision plus a mismatch flag
/// (`true` when the winning row's backend differs from the active one).
/// `None` when the text is malformed or carries no usable row
/// (non-positive timings, zero dimension).
pub fn pick_from_json(
    text: &str,
    n_v: usize,
    d: usize,
    active_backend: &str,
) -> Option<(FoldStrategy, bool)> {
    let doc = json::parse(text).ok()?;
    // "results" is the key the perf harness emits; "rows" tolerated for
    // hand-written fixtures.
    let rows = doc
        .get("results")
        .or_else(|| doc.get("rows"))?
        .as_arr()?;
    let doc_backend = doc.get("kernel_backend").and_then(Json::as_str);
    // (backend matches, d_row, packed, reference)
    let mut nearest: Option<(bool, usize, f64, f64)> = None;
    for row in rows {
        if row.get("kernel").and_then(Json::as_str) != Some("chud_rk") {
            continue;
        }
        let d_row = row.get("d").and_then(Json::as_usize).unwrap_or(0);
        let packed = row.get("packed_secs").and_then(Json::as_f64).unwrap_or(0.0);
        let reference = row
            .get("reference_secs")
            .and_then(Json::as_f64)
            .unwrap_or(0.0);
        let usable = |t: f64| t.is_finite() && t > 0.0;
        if d_row == 0 || !usable(packed) || !usable(reference) {
            continue;
        }
        let row_backend = row
            .get("kernel_backend")
            .and_then(Json::as_str)
            .or(doc_backend);
        let matches = row_backend == Some(active_backend);
        let better = match nearest {
            None => true,
            Some((best_matches, best_d, _, _)) => {
                (matches && !best_matches)
                    || (matches == best_matches && d.abs_diff(d_row) < d.abs_diff(best_d))
            }
        };
        if better {
            nearest = Some((matches, d_row, packed, reference));
        }
    }
    let (matches, d_row, packed, reference) = nearest?;
    Some((decide(n_v, d, d_row, packed, reference), !matches))
}

/// The shared cost model: extrapolate a `chud_rk` measurement at `d_row`
/// to this run's `(n_v, d)` and pick the cheaper side (ties → downdate).
/// Used identically by the bench-file path and the probe path, so the two
/// provenances can never disagree on the same numbers.
fn decide(n_v: usize, d: usize, d_row: usize, packed: f64, reference: f64) -> FoldStrategy {
    let scale = d as f64 / d_row as f64;
    let chain_links = n_v.div_ceil(CHUD_RANK_CHUNK).max(1);
    let predicted_downdate = packed * scale * scale * chain_links as f64;
    let predicted_refactor = reference * scale * scale * scale;
    if predicted_downdate <= predicted_refactor {
        FoldStrategy::Downdate
    } else {
        FoldStrategy::Refactor
    }
}

/// Probe dimension: small enough that three downdate + three refactor reps
/// stay well under ~10 ms even on the scalar backend, large enough that the
/// packed kernel's blocking is actually exercised.
const PROBE_DIM: usize = 64;

/// The startup micro-calibration: when no trajectory file exists, measure
/// the `chud_rk`-vs-refactor crossover in-process — one seeded `2d×d`
/// dataset, one anchor factor, then min-of-3 reps of (a) the tracked
/// rank-`CHUD_RANK_CHUNK` packed downdate of a factor copy (exactly what
/// the downdate strategy runs per fold cell) and (b) the `chol(H + λI)`
/// refactorization it replaces (Hessian downdated once, outside the timed
/// region). Returns `(d_row, packed_secs, reference_secs)` shaped like a
/// `chud_rk` bench row, or `None` if the probe breaks down or the clock
/// resolution swallows a timing. Cached **per kernel backend**, not per
/// process: the packed downdate dispatches through the active micro-kernel
/// backend, so a measurement taken under `scalar` says nothing about
/// `avx2`. Flipping back to an already-probed backend returns its original
/// measurement (the map is append-only — entries are never evicted).
pub fn probe_for(active_backend: &'static str) -> Option<(usize, f64, f64)> {
    static PROBES: Mutex<Vec<(&'static str, Option<(usize, f64, f64)>)>> = Mutex::new(Vec::new());
    let mut cache = PROBES.lock().unwrap_or_else(|e| e.into_inner());
    if let Some((_, cached)) = cache.iter().find(|(b, _)| *b == active_backend) {
        return *cached;
    }
    let fresh = run_probe();
    cache.push((active_backend, fresh));
    fresh
}

/// How many times the probe has actually *measured* (cache misses), across
/// all backends. Observability hook for the chaos suite: a backend flip
/// must bump this, a repeat hit must not.
pub fn probe_runs() -> u64 {
    PROBE_RUNS.load(Ordering::Relaxed)
}

static PROBE_RUNS: AtomicU64 = AtomicU64::new(0);

fn run_probe() -> Option<(usize, f64, f64)> {
    PROBE_RUNS.fetch_add(1, Ordering::Relaxed);
    const LAM: f64 = 0.5;
    let d = PROBE_DIM;
    let x = crate::testutil::random_matrix(2 * d, d, 0x9e3779b9);
    let g = crate::linalg::gemm::syrk_lower(&x);
    let l = crate::linalg::cholesky::cholesky_shifted(&g, LAM).ok()?;
    // the held-out block: the first CHUD_RANK_CHUNK data rows, so the
    // downdated matrix is the Gram of the remaining rows — genuinely PSD,
    // like every real fold downdate
    let xv = x.slice(0, CHUD_RANK_CHUNK, 0, d);
    let mut trans = crate::linalg::matrix::Matrix::zeros(0, 0);
    let mut packed = f64::INFINITY;
    for _ in 0..3 {
        let t0 = Instant::now();
        let mut lc = l.clone();
        let mut u = xv.transpose();
        let mut trust = FactorTrust::fresh(&lc);
        chol_downdate_tracked(&mut lc, &mut u, &mut trans, &mut trust).ok()?;
        packed = packed.min(t0.elapsed().as_secs_f64());
    }
    let mut h = crate::linalg::matrix::Matrix::zeros(0, 0);
    crate::linalg::gemm::syrk_lower_downdate_into(&g, &xv, &mut h);
    let mut reference = f64::INFINITY;
    for _ in 0..3 {
        let t0 = Instant::now();
        crate::linalg::cholesky::cholesky_shifted(&h, LAM).ok()?;
        reference = reference.min(t0.elapsed().as_secs_f64());
    }
    let usable = |t: f64| t.is_finite() && t > 0.0;
    if usable(packed) && usable(reference) {
        Some((d, packed, reference))
    } else {
        None
    }
}

/// Read the bench trajectory file: `PICHOL_BENCH_FILE` when set, else the
/// workspace-root `BENCH_kernels.json` the perf harness writes. `None` on
/// any I/O failure — auto never panics over a missing measurement (it
/// probes instead; see [`resolve`]).
fn read_bench_file() -> Option<String> {
    let path = std::env::var(BENCH_FILE_ENV)
        .unwrap_or_else(|_| concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_kernels.json").into());
    std::fs::read_to_string(path).ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A minimal trajectory with one `chud_rk` row at dimension `d`, with
    /// the given measured seconds.
    fn fixture(d: usize, packed: f64, reference: f64) -> String {
        format!(
            r#"{{"bench": "kernels", "kernel_backend": "scalar",
                "results": [
                  {{"kernel": "gemm", "d": {d}, "packed_secs": 1.0, "reference_secs": 2.0}},
                  {{"kernel": "chud_rk", "d": {d}, "packed_secs": {packed}, "reference_secs": {reference}}}
                ]}}"#
        )
    }

    #[test]
    fn explicit_strategy_is_config_sourced() {
        for s in [FoldStrategy::Refactor, FoldStrategy::Downdate] {
            let r = resolve_with(s, 10, 50, Some(&fixture(50, 1.0, 1.0)), "scalar");
            assert_eq!(r.strategy, s);
            assert_eq!(r.source, "config");
        }
    }

    #[test]
    fn auto_picks_downdate_when_chains_are_cheap() {
        // one chain link (n_v ≤ CHUD_RANK_CHUNK), downdate measured 10×
        // cheaper than refactorization at the same d → downdate wins
        let text = fixture(64, 0.1, 1.0);
        let r = resolve_with(FoldStrategy::Auto, CHUD_RANK_CHUNK, 64, Some(&text), "scalar");
        assert_eq!(r.strategy, FoldStrategy::Downdate);
        assert_eq!(r.source, "bench-file");
    }

    #[test]
    fn auto_picks_refactor_when_folds_are_huge() {
        // n_v ≫ d: enough chain links that the extrapolated downdate cost
        // crosses the one-off refactorization → refactor wins
        let text = fixture(64, 0.5, 1.0);
        let nv_huge = 64 * CHUD_RANK_CHUNK;
        let r = resolve_with(FoldStrategy::Auto, nv_huge, 64, Some(&text), "scalar");
        assert_eq!(r.strategy, FoldStrategy::Refactor);
        assert_eq!(r.source, "bench-file");
    }

    #[test]
    fn crossover_flips_with_the_measurement_alone() {
        // same (n_v, d), only the measured ratio changes sides
        let nv = 4 * CHUD_RANK_CHUNK; // 4 chain links
        let cheap = fixture(100, 0.2, 1.0); // 4·0.2 = 0.8 ≤ 1.0 → downdate
        let dear = fixture(100, 0.3, 1.0); // 4·0.3 = 1.2 > 1.0 → refactor
        assert_eq!(
            resolve_with(FoldStrategy::Auto, nv, 100, Some(&cheap), "scalar").strategy,
            FoldStrategy::Downdate
        );
        assert_eq!(
            resolve_with(FoldStrategy::Auto, nv, 100, Some(&dear), "scalar").strategy,
            FoldStrategy::Refactor
        );
    }

    #[test]
    fn nearest_dimension_row_wins() {
        // two chud_rk rows; the d=32 row says refactor, the d=512 row says
        // downdate. A d=64 run must use the d=32 row.
        let text = r#"{"rows": [
            {"kernel": "chud_rk", "d": 32, "packed_secs": 5.0, "reference_secs": 1.0},
            {"kernel": "chud_rk", "d": 512, "packed_secs": 0.001, "reference_secs": 1.0}
        ]}"#;
        let r = resolve_with(FoldStrategy::Auto, 8, 64, Some(text), "scalar");
        assert_eq!(r.strategy, FoldStrategy::Refactor);
        // and a d=400 run must use the d=512 row
        let r = resolve_with(FoldStrategy::Auto, 8, 400, Some(text), "scalar");
        assert_eq!(r.strategy, FoldStrategy::Downdate);
    }

    #[test]
    fn backend_mismatch_is_flagged_in_the_provenance() {
        // every usable row was recorded on a different backend: the
        // crossover still decides, but the provenance carries the note
        let text = fixture(64, 0.1, 1.0); // doc-level backend "scalar"
        let r = resolve_with(FoldStrategy::Auto, CHUD_RANK_CHUNK, 64, Some(&text), "avx2");
        assert_eq!(r.strategy, FoldStrategy::Downdate);
        assert_eq!(r.source, "bench-file-mismatch");
    }

    #[test]
    fn matching_backend_row_beats_nearer_mismatched_row() {
        // the d=64 row (exactly this run's d) was recorded on avx2 and says
        // refactor; the d=512 scalar row says downdate. On a scalar run the
        // scalar row must win despite the worse dimension match — and the
        // provenance stays clean. On an avx2 run the avx2 row wins.
        let text = r#"{"kernel_backend": "scalar", "rows": [
            {"kernel": "chud_rk", "d": 64, "packed_secs": 5.0, "reference_secs": 1.0,
             "kernel_backend": "avx2"},
            {"kernel": "chud_rk", "d": 512, "packed_secs": 0.001, "reference_secs": 1.0}
        ]}"#;
        let r = resolve_with(FoldStrategy::Auto, 8, 64, Some(text), "scalar");
        assert_eq!(r.strategy, FoldStrategy::Downdate);
        assert_eq!(r.source, "bench-file");
        let r = resolve_with(FoldStrategy::Auto, 8, 64, Some(text), "avx2");
        assert_eq!(r.strategy, FoldStrategy::Refactor);
        assert_eq!(r.source, "bench-file");
    }

    #[test]
    fn absent_or_malformed_file_falls_back_without_panic() {
        // `resolve_with` is the probe-free core: None text (absent file)
        // and malformed text both land on the static default here — the
        // probe path is `resolve`'s, exercised by the chaos suite
        for text in [
            None,
            Some("not json at all {{{"),
            Some("{}"),
            Some(r#"{"rows": "wrong type"}"#),
            Some(r#"{"rows": []}"#),
            // chud_rk present but unusable timings
            Some(r#"{"rows": [{"kernel": "chud_rk", "d": 0, "packed_secs": 1.0, "reference_secs": 1.0}]}"#),
            Some(r#"{"rows": [{"kernel": "chud_rk", "d": 64, "packed_secs": 0.0, "reference_secs": 1.0}]}"#),
            Some(r#"{"rows": [{"kernel": "gemm", "d": 64, "packed_secs": 1.0, "reference_secs": 1.0}]}"#),
        ] {
            let r = resolve_with(FoldStrategy::Auto, 10, 64, text, "scalar");
            assert_eq!(r.strategy, AUTO_DEFAULT, "input: {text:?}");
            assert_eq!(r.source, "default", "input: {text:?}");
        }
    }

    #[test]
    fn probe_measurement_is_usable_and_cached_per_backend() {
        // the probe itself: a real in-process measurement on this machine
        // must produce positive timings at the probe dimension, and the
        // per-backend cache must hand back the identical numbers on every
        // later call under the same key (a fake key keeps this test
        // independent of whatever real backends other tests have probed)
        let first =
            probe_for("strategy-test-backend").expect("probe must measure on a healthy host");
        assert_eq!(first.0, PROBE_DIM);
        assert!(first.1 > 0.0 && first.2 > 0.0);
        let second = probe_for("strategy-test-backend").unwrap();
        assert_eq!(first, second, "probe must be cached per backend");
    }

    #[test]
    fn resolve_derives_nv_from_folds() {
        // filesystem-free sanity: explicit strategy ignores the file system
        let r = resolve(FoldStrategy::Refactor, 1000, 64, 5);
        assert_eq!(r.strategy, FoldStrategy::Refactor);
        assert_eq!(r.source, "config");
    }
}
