//! Measured-crossover auto-selection of the k-fold factor strategy.
//!
//! `fold_strategy = "auto"` turns the downdate-vs-refactor choice from a
//! static default into a **cost-model decision driven by this machine's own
//! measurements**: the perf harness (`benches/bench_kernels.rs`) records,
//! per dimension `d`, the wall-clock of a rank-`CHUD_RANK_CHUNK` packed
//! downdate (`chud_rk.packed_secs`) and of the full refactorization it
//! replaces (`chud_rk.reference_secs`). From the row nearest this run's
//! factor dimension the picker extrapolates both costs to the run's actual
//! `(n_v, d)`:
//!
//! - downdate: `packed · (d/d_row)² · ceil(n_v / CHUD_RANK_CHUNK)` — the
//!   chained rank-`n_v` downdate is `O(n_v·d²)`, executed in
//!   rank-`CHUD_RANK_CHUNK` chain links;
//! - refactor: `reference · (d/d_row)³` — one `chol(H_f + λI)` is `O(d³)`.
//!
//! Downdate wins when its predicted cost is ≤ the refactor prediction —
//! the asymptotic `n_v ≪ d` regime, which the measurement grounds at real
//! constants instead of big-O faith. The trajectory file is best-effort
//! input: absent, unreadable, malformed, or missing the `chud_rk` rows all
//! degrade to the **static default (downdate)** without panicking, and the
//! provenance string records which way the decision was made (`"config"` /
//! `"bench-file"` / `"default"`) so reports never hide the fallback.
//!
//! Resolution happens once per run in
//! [`SweepPlan::new`](crate::coordinator::sweep_engine::SweepPlan::new);
//! the sweep engine itself never sees [`FoldStrategy::Auto`].

use crate::cv::FoldStrategy;
use crate::linalg::chud::CHUD_RANK_CHUNK;
use crate::runtime::json::{self, Json};

/// A resolved strategy plus its provenance.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Resolved {
    /// The concrete strategy (never [`FoldStrategy::Auto`]).
    pub strategy: FoldStrategy,
    /// `"config"` when the strategy was explicit, `"bench-file"` when the
    /// measured crossover decided, `"default"` when auto fell back.
    pub source: &'static str,
}

/// The static default auto falls back to when no usable measurement exists.
pub const AUTO_DEFAULT: FoldStrategy = FoldStrategy::Downdate;

/// Env var naming the bench trajectory file to read (tests; deployments
/// with a relocated trajectory). Unset → the workspace-root
/// `BENCH_kernels.json` the perf harness writes.
pub const BENCH_FILE_ENV: &str = "PICHOL_BENCH_FILE";

/// Resolve a configured strategy for a run with `k_folds` over an `n×d`
/// dataset. Explicit strategies pass through with source `"config"`; auto
/// reads the bench trajectory file (see [`BENCH_FILE_ENV`]).
pub fn resolve(cfg_strategy: FoldStrategy, n: usize, d: usize, k_folds: usize) -> Resolved {
    let n_v = if k_folds > 0 { n.div_ceil(k_folds) } else { n };
    let text = match cfg_strategy {
        FoldStrategy::Auto => read_bench_file(),
        _ => None,
    };
    resolve_with(cfg_strategy, n_v, d, text.as_deref())
}

/// Pure core of [`resolve`]: decide from the configured strategy, the fold
/// validation-block size `n_v`, the factor dimension `d`, and the bench
/// trajectory text (`None` = file absent/unreadable). Separated from the
/// filesystem so unit tests drive both sides of the crossover directly.
pub fn resolve_with(
    cfg_strategy: FoldStrategy,
    n_v: usize,
    d: usize,
    bench_text: Option<&str>,
) -> Resolved {
    if cfg_strategy != FoldStrategy::Auto {
        return Resolved {
            strategy: cfg_strategy,
            source: "config",
        };
    }
    match bench_text.and_then(|t| pick_from_json(t, n_v, d)) {
        Some(strategy) => Resolved {
            strategy,
            source: "bench-file",
        },
        None => Resolved {
            strategy: AUTO_DEFAULT,
            source: "default",
        },
    }
}

/// Parse a `BENCH_kernels.json` document and pick a strategy for `(n_v, d)`
/// from its `chud_rk` rows. `None` when the text is malformed or carries no
/// usable row (non-positive timings, zero dimension).
pub fn pick_from_json(text: &str, n_v: usize, d: usize) -> Option<FoldStrategy> {
    let doc = json::parse(text).ok()?;
    // "results" is the key the perf harness emits; "rows" tolerated for
    // hand-written fixtures.
    let rows = doc
        .get("results")
        .or_else(|| doc.get("rows"))?
        .as_arr()?;
    let mut nearest: Option<(usize, f64, f64)> = None;
    for row in rows {
        if row.get("kernel").and_then(Json::as_str) != Some("chud_rk") {
            continue;
        }
        let d_row = row.get("d").and_then(Json::as_usize).unwrap_or(0);
        let packed = row.get("packed_secs").and_then(Json::as_f64).unwrap_or(0.0);
        let reference = row
            .get("reference_secs")
            .and_then(Json::as_f64)
            .unwrap_or(0.0);
        let usable = |t: f64| t.is_finite() && t > 0.0;
        if d_row == 0 || !usable(packed) || !usable(reference) {
            continue;
        }
        let better = match nearest {
            None => true,
            Some((best_d, _, _)) => d.abs_diff(d_row) < d.abs_diff(best_d),
        };
        if better {
            nearest = Some((d_row, packed, reference));
        }
    }
    let (d_row, packed, reference) = nearest?;
    let scale = d as f64 / d_row as f64;
    let chain_links = n_v.div_ceil(CHUD_RANK_CHUNK).max(1);
    let predicted_downdate = packed * scale * scale * chain_links as f64;
    let predicted_refactor = reference * scale * scale * scale;
    Some(if predicted_downdate <= predicted_refactor {
        FoldStrategy::Downdate
    } else {
        FoldStrategy::Refactor
    })
}

/// Read the bench trajectory file: `PICHOL_BENCH_FILE` when set, else the
/// workspace-root `BENCH_kernels.json` the perf harness writes. `None` on
/// any I/O failure — auto never panics over a missing measurement.
fn read_bench_file() -> Option<String> {
    let path = std::env::var(BENCH_FILE_ENV)
        .unwrap_or_else(|_| concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_kernels.json").into());
    std::fs::read_to_string(path).ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A minimal trajectory with one `chud_rk` row at dimension `d`, with
    /// the given measured seconds.
    fn fixture(d: usize, packed: f64, reference: f64) -> String {
        format!(
            r#"{{"bench": "kernels", "kernel_backend": "scalar",
                "results": [
                  {{"kernel": "gemm", "d": {d}, "packed_secs": 1.0, "reference_secs": 2.0}},
                  {{"kernel": "chud_rk", "d": {d}, "packed_secs": {packed}, "reference_secs": {reference}}}
                ]}}"#
        )
    }

    #[test]
    fn explicit_strategy_is_config_sourced() {
        for s in [FoldStrategy::Refactor, FoldStrategy::Downdate] {
            let r = resolve_with(s, 10, 50, Some(&fixture(50, 1.0, 1.0)));
            assert_eq!(r.strategy, s);
            assert_eq!(r.source, "config");
        }
    }

    #[test]
    fn auto_picks_downdate_when_chains_are_cheap() {
        // one chain link (n_v ≤ CHUD_RANK_CHUNK), downdate measured 10×
        // cheaper than refactorization at the same d → downdate wins
        let text = fixture(64, 0.1, 1.0);
        let r = resolve_with(FoldStrategy::Auto, CHUD_RANK_CHUNK, 64, Some(&text));
        assert_eq!(r.strategy, FoldStrategy::Downdate);
        assert_eq!(r.source, "bench-file");
    }

    #[test]
    fn auto_picks_refactor_when_folds_are_huge() {
        // n_v ≫ d: enough chain links that the extrapolated downdate cost
        // crosses the one-off refactorization → refactor wins
        let text = fixture(64, 0.5, 1.0);
        let nv_huge = 64 * CHUD_RANK_CHUNK;
        let r = resolve_with(FoldStrategy::Auto, nv_huge, 64, Some(&text));
        assert_eq!(r.strategy, FoldStrategy::Refactor);
        assert_eq!(r.source, "bench-file");
    }

    #[test]
    fn crossover_flips_with_the_measurement_alone() {
        // same (n_v, d), only the measured ratio changes sides
        let nv = 4 * CHUD_RANK_CHUNK; // 4 chain links
        let cheap = fixture(100, 0.2, 1.0); // 4·0.2 = 0.8 ≤ 1.0 → downdate
        let dear = fixture(100, 0.3, 1.0); // 4·0.3 = 1.2 > 1.0 → refactor
        assert_eq!(
            resolve_with(FoldStrategy::Auto, nv, 100, Some(&cheap)).strategy,
            FoldStrategy::Downdate
        );
        assert_eq!(
            resolve_with(FoldStrategy::Auto, nv, 100, Some(&dear)).strategy,
            FoldStrategy::Refactor
        );
    }

    #[test]
    fn nearest_dimension_row_wins() {
        // two chud_rk rows; the d=32 row says refactor, the d=512 row says
        // downdate. A d=64 run must use the d=32 row.
        let text = r#"{"rows": [
            {"kernel": "chud_rk", "d": 32, "packed_secs": 5.0, "reference_secs": 1.0},
            {"kernel": "chud_rk", "d": 512, "packed_secs": 0.001, "reference_secs": 1.0}
        ]}"#;
        let r = resolve_with(FoldStrategy::Auto, 8, 64, Some(text));
        assert_eq!(r.strategy, FoldStrategy::Refactor);
        // and a d=400 run must use the d=512 row
        let r = resolve_with(FoldStrategy::Auto, 8, 400, Some(text));
        assert_eq!(r.strategy, FoldStrategy::Downdate);
    }

    #[test]
    fn absent_or_malformed_file_falls_back_without_panic() {
        for text in [
            None,
            Some("not json at all {{{"),
            Some("{}"),
            Some(r#"{"rows": "wrong type"}"#),
            Some(r#"{"rows": []}"#),
            // chud_rk present but unusable timings
            Some(r#"{"rows": [{"kernel": "chud_rk", "d": 0, "packed_secs": 1.0, "reference_secs": 1.0}]}"#),
            Some(r#"{"rows": [{"kernel": "chud_rk", "d": 64, "packed_secs": 0.0, "reference_secs": 1.0}]}"#),
            Some(r#"{"rows": [{"kernel": "gemm", "d": 64, "packed_secs": 1.0, "reference_secs": 1.0}]}"#),
        ] {
            let r = resolve_with(FoldStrategy::Auto, 10, 64, text);
            assert_eq!(r.strategy, AUTO_DEFAULT, "input: {text:?}");
            assert_eq!(r.source, "default", "input: {text:?}");
        }
    }

    #[test]
    fn resolve_derives_nv_from_folds() {
        // filesystem-free sanity: explicit strategy ignores the file system
        let r = resolve(FoldStrategy::Refactor, 1000, 64, 5);
        assert_eq!(r.strategy, FoldStrategy::Refactor);
        assert_eq!(r.source, "config");
    }
}
