//! Approximate leave-one-out CV (ALOOCV): the cheapest rung of the
//! accuracy/cost ladder.
//!
//! ## The identity
//!
//! For ridge regression the leave-one-out residual has a closed form in the
//! **hat-matrix diagonals** `h_i = xᵢᵀ (G + λI)⁻¹ xᵢ`:
//!
//! ```text
//!   y_i − x_iᵀθ_{−i}  =  (y_i − x_iᵀθ) / (1 − h_i)
//! ```
//!
//! so one full-data solve θ plus all n hat diagonals reproduce every
//! held-out residual without ever removing a row. This is the workhorse of
//! the approximate-CV family (Stephenson–Udell–Broderick, arXiv 2008.10547)
//! and of the model-assessment/selection guarantees of Wilson–Kasy–Mackey
//! (arXiv 2003.00617); for ridge the identity is exact, so the "approximate"
//! in the name buys a pure cost win over the downdate engine ([`super::loo`])
//! at equal answers — the approximation enters only through the
//! interpolated λ axis, same as everywhere else in the crate.
//!
//! ## The cost structure — why this is the O(n·d) tier
//!
//! With the anchor factor `L = chol(G + λI)` already cached, the diagonals
//! of the whole dataset are one **multi-RHS triangular solve**: gather a row
//! batch as `B = Xᵀ` (d×b), solve `L W = B` with the blocked
//! [`crate::linalg::triangular::trsm_left_lower_into`] (row-panelled through
//! the packed micro-kernel), and read `h_i = ‖W·,ᵢ‖²` off the columns.
//! That is `O(n·d²)` per anchor for the *entire* dataset — the same order
//! one single exact-LOO row costs — and `O(n·d)` marginal per additional
//! grid λ, because non-anchor λ's are served by the PINRMSE interpolation
//! of the anchor curve (the paper's move, applied to the error curve). The
//! exact-LOO tier pays `O(n·d²)` per anchor *per row* batch of downdates;
//! the brute tier `O(n·d³)`. Hence the ladder:
//!
//! | tier | per-anchor cost | mechanism |
//! |---|---|---|
//! | `aloocv` | `O(n·d²)` total, `O(n·d)`/extra λ | batched hat solves |
//! | `loo` | `O(n·d²)` **per row** | rank-1 downdate chains |
//! | brute | `O(n·d³)` | per-row refactorization |
//!
//! ## Leverage guard — the ladder inside the tier
//!
//! A diagonal `h_i ≥ 1 − ε` ([`LEVERAGE_EPS`]) makes `1/(1 − h_i)` blow up:
//! the row essentially determines its own prediction and the closed form is
//! numerically void. Instead of emitting Inf/NaN, the cell **escalates to
//! the exact-LOO tier** — `loo::eval_heldout_point`, the rank-1
//! downdate body, which itself may climb the shared recovery ladder
//! ([`super::recovery`]) — and the climb is recorded as a [`Degradation`]
//! with `cause: "leverage"` on surface `"aloocv"`. Only full ladder
//! exhaustion skips the (row, anchor) cell, recorded in
//! [`AloocvReport::skipped`]; the report never carries a non-finite cell.
//!
//! ## Certification
//!
//! [`run_certified`] reproduces the Wilson et al. selection experiment
//! in-crate: run the cheap tier and the exact tier on the same plan and
//! certify whether the selected λ* agree within a decade
//! ([`Certification`]). The conformance suite (`tests/tiers.rs`,
//! `./ci.sh --tiers`) pins this on the shared problem generators at
//! workers {1, 2, 4}, bitwise.
//!
//! Scheduling (per-batch tasks over the worker pool, bitwise independent of
//! the worker count) lives in
//! [`crate::coordinator::sweep_engine::SweepEngine::run_aloocv`]; this
//! module owns the task body (`eval_hat_block`), the report shape and the
//! certification record.

use crate::coordinator::sweep_engine::{LooPlan, SweepEngine};
use crate::data::gram::GramCache;
use crate::data::synthetic::SyntheticDataset;
use crate::linalg::cholesky::CholeskyError;
use crate::linalg::matrix::Matrix;
use crate::linalg::scratch::Scratch;
use crate::linalg::triangular::trsm_left_lower_into;
use crate::linalg::trust::FactorTrust;
use crate::util::PhaseTimer;

use super::loo::{eval_heldout_point, run_loo, LooSkip};
use super::recovery::{DegradeInfo, Degradation, RecoveryPolicy, Rung};
use super::CvConfig;

/// Leverage guard threshold: a hat diagonal `h_i ≥ 1 − LEVERAGE_EPS` routes
/// the row through the recovery ladder (escalation to exact LOO) instead of
/// evaluating the `1/(1 − h_i)` closed form.
pub const LEVERAGE_EPS: f64 = 1e-8;

/// The cheap-vs-exact selection verdict of [`run_certified`] — the Wilson
/// et al. model-selection experiment reproduced in-crate.
#[derive(Debug, Clone)]
pub struct Certification {
    /// λ* selected by the ALOOCV tier.
    pub aloo_lambda: f64,
    /// λ* selected by the exact-LOO tier on the same plan.
    pub loo_lambda: f64,
    /// `|log10(aloo_lambda) − log10(loo_lambda)|`.
    pub decades: f64,
    /// Whether the tiers agree within one decade (both finite).
    pub certified: bool,
}

/// What an ALOOCV run produced. Identical in shape to
/// [`super::loo::LooReport`] — the tiers are interchangeable consumers of
/// the same plan — plus the optional certification verdict.
pub struct AloocvReport {
    /// The candidate λ grid (`q` points).
    pub grid: Vec<f64>,
    /// Interpolated ALOO-RMSE over the grid (NaN when too few anchors
    /// survived to fit the curve).
    pub curve: Vec<f64>,
    /// The anchor λ's that were factored exactly (`g` of them).
    pub anchor_lambdas: Vec<f64>,
    /// ALOO-RMSE at each anchor (mean over served rows; NaN if every row
    /// was skipped at that anchor).
    pub anchor_rmse: Vec<f64>,
    /// Grid minimizer of the interpolated curve (degrades like
    /// [`super::loo::LooReport::best_lambda`]).
    pub best_lambda: f64,
    /// Curve (or, degraded, exact anchor) value at `best_lambda`.
    pub best_error: f64,
    /// Skipped (row, λ) cells — full-ladder exhaustion on an escalated
    /// leverage row; recorded, not fatal.
    pub skipped: Vec<LooSkip>,
    /// Every leverage escalation and ladder climb, in ascending
    /// (row, anchor) order, on surface `"aloocv"` with `cause: "leverage"`.
    pub degradations: Vec<Degradation>,
    /// Phase timings summed over all tasks. The structural invariants —
    /// `factor` and `solve` counted once per anchor, `hat_solve` once per
    /// (batch, anchor), zero `chol`/`downdate` on a clean run — are what
    /// the tier tests and `bench_kernels` assert.
    pub timer: PhaseTimer,
    /// Elapsed wall-clock seconds of the run.
    pub wall_secs: f64,
    /// Worker threads the run used.
    pub threads: usize,
    /// Total tasks executed (Gram chunks + anchor factors + batch solves).
    pub tasks: usize,
    /// Rows of the dataset.
    pub n: usize,
    /// Tier-agreement verdict — `Some` only from [`run_certified`].
    pub certification: Option<Certification>,
    /// Observability payload — merged event log + latency histograms —
    /// present only when the run was armed ([`CvConfig::obs`]). From
    /// [`run_certified`] this is the *cheap tier's* payload; the exact
    /// tier's run is observable through its own [`super::loo::LooReport`].
    pub obs: Option<crate::obs::ObsReport>,
}

/// Run ALOOCV over a dataset: plans anchors/grid from `cfg` exactly like
/// the exact-LOO tier ([`LooPlan`]), executes on a [`SweepEngine`] — Gram
/// assembly, anchor factorizations, batched hat-diagonal solves — and fits
/// the ALOO error curve. Results are bit-identical for every thread count.
pub fn run_aloocv(ds: &SyntheticDataset, cfg: &CvConfig) -> crate::Result<AloocvReport> {
    let plan = LooPlan::new(ds, cfg);
    let engine = SweepEngine::new(plan.threads);
    engine.run_aloocv(ds, &plan)
}

/// Run the cheap tier and the exact tier on the same plan and stamp the
/// selection-agreement verdict into the report ([`Certification`]): the
/// Wilson et al. experiment as a library call.
pub fn run_certified(ds: &SyntheticDataset, cfg: &CvConfig) -> crate::Result<AloocvReport> {
    let mut rep = run_aloocv(ds, cfg)?;
    let exact = run_loo(ds, cfg)?;
    let decades = (rep.best_lambda.log10() - exact.best_lambda.log10()).abs();
    rep.certification = Some(Certification {
        aloo_lambda: rep.best_lambda,
        loo_lambda: exact.best_lambda,
        decades,
        certified: decades.is_finite() && decades <= 1.0,
    });
    Ok(rep)
}

/// One (batch, anchor) hat-diagonal evaluation — the body of the sweep
/// engine's batch tasks (and of the serial path; parallel results are
/// bit-identical to serial because both run *this* code). Gathers the row
/// batch as `Xᵀ` into `scratch.rhs` ("gather"), runs the blocked multi-RHS
/// TRSM into `scratch.wsol` and accumulates each column's squared norm
/// ("hat_solve"), then scores every row's ALOO residual against the
/// anchor's full-data θ ("aloo_score"). Rows whose diagonal trips the
/// leverage guard escalate to [`eval_heldout_point`]; the per-row cells come
/// back in batch-row order, `Err` only on full ladder exhaustion. Every
/// buffer is worker scratch — zero heap allocation once warm.
#[allow(clippy::too_many_arguments)]
pub(crate) fn eval_hat_block(
    anchor: &Matrix,
    anchor_trust: FactorTrust,
    gram: &GramCache,
    theta: &[f64],
    xblock: &Matrix,
    yblock: &[f64],
    lam: f64,
    policy: &RecoveryPolicy,
    scratch: &mut Scratch,
    timer: &mut PhaseTimer,
) -> Vec<Result<(f64, Option<(Rung, DegradeInfo)>), CholeskyError>> {
    let (b, d) = (xblock.rows(), xblock.cols());
    timer.time("gather", || {
        scratch.rhs.reset_zeroed(d, b);
        let rhs = scratch.rhs.as_mut_slice();
        for c in 0..b {
            for (j, &x) in xblock.row(c).iter().enumerate() {
                rhs[j * b + c] = x;
            }
        }
    });
    timer.time("hat_solve", || {
        trsm_left_lower_into(anchor, &scratch.rhs, &mut scratch.wsol);
        // h_i = ‖W·,ᵢ‖², accumulated row-wise in ascending order — the
        // per-column bits depend only on that column (see the TRSM's
        // bitwise contract), so batch boundaries and worker count can
        // never change a diagonal. Stashed in scratch.pred (unused by
        // this path otherwise).
        scratch.pred.clear();
        scratch.pred.resize(b, 0.0);
        let w = scratch.wsol.as_slice();
        for r in 0..d {
            let row = &w[r * b..(r + 1) * b];
            for (h, &v) in scratch.pred.iter_mut().zip(row) {
                *h += v * v;
            }
        }
    });
    let mut cells = Vec::with_capacity(b);
    for i in 0..b {
        let h = scratch.pred[i];
        let xi = xblock.row(i);
        let yi = yblock[i];
        if h < 1.0 - LEVERAGE_EPS {
            let sqerr = timer.time("aloo_score", || {
                let e: f64 = xi.iter().zip(theta).map(|(x, t)| x * t).sum::<f64>() - yi;
                let r = e / (1.0 - h);
                r * r
            });
            cells.push(Ok((sqerr, None)));
            continue;
        }
        // leverage blow-up: escalate this row to the exact-LOO tier (which
        // may itself climb the recovery ladder), recorded as a degradation
        let cell = match eval_heldout_point(
            anchor,
            anchor_trust,
            gram,
            xi,
            yi,
            lam,
            policy,
            scratch,
            timer,
        ) {
            Ok((sqerr, inner)) => {
                let (rung, info) = match inner {
                    None => (
                        Rung::Downdate,
                        DegradeInfo {
                            cause: "leverage",
                            trust_at_failure: 0.0,
                            detail: format!(
                                "hat diagonal {h:.17} ≥ 1 − {LEVERAGE_EPS:.0e}; served by exact-LOO downdate"
                            ),
                        },
                    ),
                    Some((rung, mut info)) => {
                        info.detail = format!(
                            "hat diagonal {h:.17} ≥ 1 − {LEVERAGE_EPS:.0e}; exact-LOO escalated further: {}",
                            info.detail
                        );
                        info.cause = "leverage";
                        (rung, info)
                    }
                };
                Ok((sqerr, Some((rung, info))))
            }
            Err(e) => Err(e),
        };
        cells.push(cell);
    }
    cells
}
