//! The §4 theoretical machinery: Fréchet derivative of the Cholesky map,
//! its second-order Taylor polynomial, and the Theorem 4.4 / 4.7 error
//! bounds.
//!
//! The paper works with the h²×h² Kronecker operator `M = ⟦C(A)⟧ =
//! C(A)⊗I + I⊗C(A)` and freely identifies `vec(Γ)` with `vec(Γᵀ)`. That
//! identification is exact only on symmetric arguments; the derivative of
//! the Cholesky map is *lower-triangular*, so a faithful implementation uses
//! the same operator **restricted to the lower-triangular/symmetric pair of
//! D-dimensional subspaces** (D = h(h+1)/2):
//!
//! ```text
//!   op(X) : lt-coords(Γ) ↦ sym-coords(Γ Xᵀ + X Γᵀ)          (D×D)
//! ```
//!
//! Theorem 4.1 says exactly that `op(L)` is invertible, and all the paper's
//! quantities carry over verbatim:
//!
//! - `M_s = op(L_s)` with `L_s = C(A + sI)`;
//! - first derivative direction `Γ_s = unvec(M_s⁻¹ v_I)` (Theorem 4.3);
//! - `E_s = op(Γ_s)`; second derivative direction `M_s⁻¹ E_s M_s⁻¹ v_I`
//!   (the sign/factor bookkeeping reproduces `d²L/ds² = −M⁻¹·2 vec(Γ Γᵀ)`);
//! - `R_[a,b] = max_s (‖M_s⁻¹E_s‖₂²·‖M_s⁻¹v_I‖₂ +
//!   ‖M_s⁻¹‖₂·‖M_s⁻¹E_s‖₂·‖M_s⁻¹v_I‖₂²)` — Theorem 4.4's remainder scale.
//!
//! The restricted operator is also D×D instead of h²×h², which makes the
//! bound computable at h=64 instead of h=16. Everything here is exact dense
//! linear algebra — this module exists to *validate* the theory (see
//! `examples/error_bound.rs`), not to run on the request path.

use crate::linalg::cholesky::cholesky_shifted;
use crate::linalg::gemm::{gemm, gemv};
use crate::linalg::lu::lu_decompose;
use crate::linalg::matrix::Matrix;
use crate::linalg::norms::spectral_norm_est;
use crate::linalg::svd::jacobi_svd;

/// Row-wise lower-triangular coordinates: index of entry (i, j), j ≤ i.
#[inline]
fn lt_index(i: usize, j: usize) -> usize {
    i * (i + 1) / 2 + j
}

/// Lower-triangle coordinates of a (lower-triangular or symmetric) matrix.
pub fn lt_vec(x: &Matrix) -> Vec<f64> {
    let h = x.rows();
    let mut v = vec![0.0; h * (h + 1) / 2];
    for i in 0..h {
        for j in 0..=i {
            v[lt_index(i, j)] = x[(i, j)];
        }
    }
    v
}

/// Rebuild a lower-triangular matrix from its lt-coordinates.
pub fn lt_unvec(v: &[f64], h: usize) -> Matrix {
    assert_eq!(v.len(), h * (h + 1) / 2);
    let mut m = Matrix::zeros(h, h);
    for i in 0..h {
        for j in 0..=i {
            m[(i, j)] = v[lt_index(i, j)];
        }
    }
    m
}

/// The restricted symmetrized-Kronecker operator:
/// `op(X)·lt(Γ) = sym-coords(Γ Xᵀ + X Γᵀ)` for lower-triangular Γ.
pub fn op_lt(x: &Matrix) -> Matrix {
    let h = x.rows();
    assert!(x.is_square());
    let d = h * (h + 1) / 2;
    let mut m = Matrix::zeros(d, d);
    // column (p, q): image of the basis matrix E_pq (q ≤ p):
    //   S[i,j] = δ_ip X[j,q] + δ_jp X[i,q]
    for p in 0..h {
        for q in 0..=p {
            let col = lt_index(p, q);
            // rows with i = p: S[p,j] += X[j,q] for j ≤ p
            for j in 0..=p {
                m[(lt_index(p, j), col)] += x[(j, q)];
            }
            // rows with j = p: S[i,p] += X[i,q] for i ≥ p
            for i in p..h {
                m[(lt_index(i, p), col)] += x[(i, q)];
            }
        }
    }
    m
}

/// Everything Theorem 4.4 needs at one shift s.
pub struct ShiftQuantities {
    /// `‖M_s⁻¹‖₂`
    pub minv_norm: f64,
    /// `‖M_s⁻¹ E_s‖₂`
    pub minv_e_norm: f64,
    /// `‖M_s⁻¹ v_I‖₂`
    pub minv_vi_norm: f64,
    /// First derivative direction `dL/ds` in lt-coordinates.
    pub dvec: Vec<f64>,
    /// `−d²L/ds²` in lt-coordinates (`M⁻¹ E M⁻¹ v_I`).
    pub d2vec: Vec<f64>,
}

/// Bound calculator for a fixed positive-definite `A`.
pub struct BoundCalculator {
    a: Matrix,
    h: usize,
}

impl BoundCalculator {
    pub fn new(a: Matrix) -> Self {
        assert!(a.is_square());
        let h = a.rows();
        Self { a, h }
    }

    /// D = h(h+1)/2 — the paper's entry count.
    pub fn d_tri(&self) -> usize {
        self.h * (self.h + 1) / 2
    }

    /// Compute the Theorem 4.4 quantities at shift s (one D×D LU).
    pub fn at_shift(&self, s: f64) -> ShiftQuantities {
        let h = self.h;
        let l = cholesky_shifted(&self.a, s).expect("A + sI not PD");
        let m = op_lt(&l);
        let lu = lu_decompose(&m).expect("Fréchet operator singular (A+sI should be PD)");
        let minv = lu.inverse();

        let vi = lt_vec(&Matrix::eye(h));
        let dvec = gemv(&minv, &vi); // Γ = M⁻¹ v_I  (= dL/ds)
        let e = op_lt(&lt_unvec(&dvec, h)); // E_s = op(Γ)
        let minv_e = gemm(&minv, &e);
        let d2vec = gemv(&minv_e, &dvec); // M⁻¹ E M⁻¹ v_I (= −d²L/ds²)

        let minv_norm = spectral_norm_est(&minv, 150, 17);
        let minv_e_norm = spectral_norm_est(&minv_e, 150, 18);
        let minv_vi_norm = dvec.iter().map(|x| x * x).sum::<f64>().sqrt();

        ShiftQuantities {
            minv_norm,
            minv_e_norm,
            minv_vi_norm,
            dvec,
            d2vec,
        }
    }

    /// `R_[a,b]` estimated by maximizing over `samples` shifts in [a, b].
    pub fn r_interval(&self, a: f64, b: f64, samples: usize) -> f64 {
        let (lo, hi) = (a.min(b), a.max(b));
        let mut r = 0.0f64;
        let n = samples.max(2);
        for i in 0..n {
            let s = lo + (hi - lo) * i as f64 / (n - 1) as f64;
            let q = self.at_shift(s);
            let term = q.minv_e_norm * q.minv_e_norm * q.minv_vi_norm
                + q.minv_norm * q.minv_e_norm * q.minv_vi_norm * q.minv_vi_norm;
            r = r.max(term);
        }
        r
    }

    /// The second-order Taylor polynomial `p_TS(λ; λc)` of Theorem 4.4.
    pub fn taylor_poly(&self, lambda_c: f64) -> TaylorPoly {
        let q = self.at_shift(lambda_c);
        let l_c = cholesky_shifted(&self.a, lambda_c).expect("A + λcI not PD");
        TaylorPoly {
            lambda_c,
            l_c,
            d1: lt_unvec(&q.dvec, self.h),
            d2: lt_unvec(&q.d2vec, self.h),
        }
    }

    /// Theorem 4.4 RHS: `2|λ−λc|³ R_[λc,λ] / (3√D)`.
    pub fn thm44_rhs(&self, lambda: f64, lambda_c: f64, samples: usize) -> f64 {
        let gamma = (lambda - lambda_c).abs();
        let r = self.r_interval(lambda_c.min(lambda), lambda_c.max(lambda), samples);
        2.0 * gamma.powi(3) * r / (3.0 * (self.d_tri() as f64).sqrt())
    }

    /// Theorem 4.7 RHS for a query window γ around λc, samples within w:
    /// `[γ³ + √g w³ (1+γ²)(λc+1)‖V†‖₂] · R_[λc−γ, λc+γ] / √D`.
    pub fn thm47_rhs(
        &self,
        gamma: f64,
        w: f64,
        lambda_c: f64,
        sample_lambdas: &[f64],
        degree: usize,
        r_samples: usize,
    ) -> f64 {
        let g = sample_lambdas.len() as f64;
        let vpinv = v_pseudoinverse_norm(sample_lambdas, degree);
        let lo = (lambda_c - gamma).max(1e-12);
        let r = self.r_interval(lo, lambda_c + gamma, r_samples);
        (gamma.powi(3) + g.sqrt() * w.powi(3) * (1.0 + gamma * gamma) * (lambda_c + 1.0) * vpinv)
            * r
            / (self.d_tri() as f64).sqrt()
    }

    /// Measured `1/√D · ‖C(A+λI) − L̂‖_F` over the lower triangle — the LHS
    /// the bounds control.
    pub fn measured_rms_error(&self, lambda: f64, approx: &Matrix) -> f64 {
        let exact = cholesky_shifted(&self.a, lambda).expect("A + λI not PD");
        let mut sum = 0.0;
        for i in 0..self.h {
            for j in 0..=i {
                let d = exact[(i, j)] - approx[(i, j)];
                sum += d * d;
            }
        }
        (sum / self.d_tri() as f64).sqrt()
    }
}

/// The Theorem 4.4 second-order Taylor expansion of the Cholesky map:
/// `p_TS(λ) = L_c + (λ−λc)·Γ − (λ−λc)²/2 · (M⁻¹EM⁻¹v_I)`.
pub struct TaylorPoly {
    pub lambda_c: f64,
    l_c: Matrix,
    d1: Matrix,
    d2: Matrix,
}

impl TaylorPoly {
    pub fn eval(&self, lambda: f64) -> Matrix {
        let t = lambda - self.lambda_c;
        let mut out = self.l_c.clone();
        for ((o, &a), &b) in out
            .as_mut_slice()
            .iter_mut()
            .zip(self.d1.as_slice())
            .zip(self.d2.as_slice())
        {
            *o += t * a - 0.5 * t * t * b;
        }
        out
    }
}

/// `‖V†‖₂ = 1/σ_min(V)` for the Vandermonde observation matrix (Theorem 4.6's
/// conditioning measure).
pub fn v_pseudoinverse_norm(sample_lambdas: &[f64], degree: usize) -> f64 {
    let v = super::vandermonde(sample_lambdas, degree);
    let svd = jacobi_svd(&v);
    let smin = svd.s.last().copied().unwrap_or(0.0);
    assert!(smin > 0.0, "V rank-deficient: duplicate sample points?");
    1.0 / smin
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{random_lower_factor, random_spd};

    #[test]
    fn lt_vec_roundtrip() {
        let l = random_lower_factor(7, 1);
        assert!(lt_unvec(&lt_vec(&l), 7).max_abs_diff(&l) == 0.0);
    }

    #[test]
    fn op_action_matches_definition() {
        // op(X)·lt(Γ) = sym-coords(ΓXᵀ + XΓᵀ)
        let x = crate::testutil::random_matrix(5, 5, 2);
        let g = random_lower_factor(5, 3);
        let m = op_lt(&x);
        let got = gemv(&m, &lt_vec(&g));
        let gxt = gemm(&g, &x.transpose());
        let xgt = gemm(&x, &g.transpose());
        let expect_mat = Matrix::from_fn(5, 5, |i, j| gxt[(i, j)] + xgt[(i, j)]);
        let expect = lt_vec(&expect_mat);
        for (a, b) in got.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-12, "{a} vs {b}");
        }
    }

    #[test]
    fn frechet_derivative_matches_finite_difference() {
        // Theorem 4.3: dL/ds = unvec(M⁻¹ v_I) — check vs central difference
        let a = random_spd(8, 1e2, 4);
        let calc = BoundCalculator::new(a.clone());
        let s = 0.5;
        let q = calc.at_shift(s);
        let analytic = lt_unvec(&q.dvec, 8);
        let eps = 1e-5;
        let lp = cholesky_shifted(&a, s + eps).unwrap();
        let lm = cholesky_shifted(&a, s - eps).unwrap();
        let fd = Matrix::from_fn(8, 8, |i, j| (lp[(i, j)] - lm[(i, j)]) / (2.0 * eps));
        assert!(
            analytic.max_abs_diff(&fd) < 1e-6,
            "Δ = {}",
            analytic.max_abs_diff(&fd)
        );
    }

    #[test]
    fn second_derivative_matches_finite_difference() {
        let a = random_spd(6, 50.0, 9);
        let calc = BoundCalculator::new(a.clone());
        let s = 0.7;
        let q = calc.at_shift(s);
        // d²L/ds² = −M⁻¹EM⁻¹v_I
        let analytic = lt_unvec(&q.d2vec, 6);
        let eps = 1e-4;
        let lp = cholesky_shifted(&a, s + eps).unwrap();
        let l0 = cholesky_shifted(&a, s).unwrap();
        let lm = cholesky_shifted(&a, s - eps).unwrap();
        let fd = Matrix::from_fn(6, 6, |i, j| {
            -(lp[(i, j)] - 2.0 * l0[(i, j)] + lm[(i, j)]) / (eps * eps)
        });
        assert!(
            analytic.max_abs_diff(&fd) < 1e-4,
            "Δ = {}",
            analytic.max_abs_diff(&fd)
        );
    }

    #[test]
    fn taylor_error_is_cubic_in_gamma() {
        let a = random_spd(8, 1e2, 5);
        let calc = BoundCalculator::new(a.clone());
        let p = calc.taylor_poly(0.5);
        let err = |gamma: f64| calc.measured_rms_error(0.5 + gamma, &p.eval(0.5 + gamma));
        let (e1, e2) = (err(0.05), err(0.1));
        // doubling γ should scale error by ≈ 8 (cubic remainder)
        let ratio = e2 / e1;
        assert!(
            (5.0..13.0).contains(&ratio),
            "remainder not cubic: ratio = {ratio}"
        );
    }

    #[test]
    fn thm44_bound_dominates_measured_error() {
        let a = random_spd(6, 50.0, 6);
        let calc = BoundCalculator::new(a.clone());
        let lambda_c = 0.6;
        let p = calc.taylor_poly(lambda_c);
        for lam in [0.45, 0.55, 0.7, 0.8] {
            let measured = calc.measured_rms_error(lam, &p.eval(lam));
            let bound = calc.thm44_rhs(lam, lambda_c, 7);
            assert!(
                measured <= bound * 1.01 + 1e-14,
                "λ={lam}: measured {measured:.3e} > bound {bound:.3e}"
            );
        }
    }

    #[test]
    fn thm47_bound_dominates_pichol_error() {
        let a = random_spd(6, 50.0, 7);
        let calc = BoundCalculator::new(a.clone());
        let lambda_c = 0.55;
        let w = 0.15;
        let lams: Vec<f64> = (0..4)
            .map(|i| lambda_c - w + 2.0 * w * i as f64 / 3.0)
            .collect();
        let mut timer = crate::util::PhaseTimer::new();
        let interp = crate::pichol::fit(
            &a,
            &lams,
            &crate::pichol::FitOptions {
                degree: 2,
                strategy: &crate::vectorize::RowWise,
            },
            &mut timer,
        )
        .unwrap();
        let gamma = 0.2;
        let bound = calc.thm47_rhs(gamma, w, lambda_c, &lams, 2, 7);
        for lam in [lambda_c - 0.18, lambda_c, lambda_c + 0.18] {
            let approx = interp.eval_factor(lam, &crate::vectorize::RowWise);
            let measured = calc.measured_rms_error(lam, &approx);
            assert!(
                measured <= bound * 1.01 + 1e-14,
                "λ={lam}: measured {measured:.3e} > bound {bound:.3e}"
            );
        }
    }

    #[test]
    fn v_pinv_norm_matches_inverse_min_singular() {
        let lams = [0.1, 0.3, 0.6, 1.0];
        let n = v_pseudoinverse_norm(&lams, 2);
        let v = crate::pichol::vandermonde(&lams, 2);
        let svd = jacobi_svd(&v);
        assert!((n - 1.0 / svd.s[2]).abs() < 1e-10);
    }
}
