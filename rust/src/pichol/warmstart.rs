//! Cross-fold warm-starting — the paper's §7 future-work item, implemented.
//!
//! "Currently, we apply the learned polynomial functions within a particular
//! validation fold. Going forward, we intend to use these functions to
//! *warm-start* the learning process in a different fold. This would reduce
//! the number of exact Cholesky factors required in a fold."
//!
//! The mechanism: fold j's Hessian `H_j` differs from fold i's by a low-rank
//! (n/k-row) resampling, so the fitted coefficient matrix Θ changes little
//! between folds. We therefore fit fold 1 with the full g sample points, and
//! every later fold with only `g_warm < g` *fresh* factorizations:
//!
//! 1. evaluate the previous fold's interpolant at the g_warm fresh λ's;
//! 2. compute the exact factors there (the only O(d³) work in this fold);
//! 3. fit a **correction polynomial of degree r_warm ≤ g_warm − 1** to the
//!    residuals `vec(Lˢ_exact) − vec(L̂ˢ_prev)`;
//! 4. the fold's interpolant is `Θ_prev + Θ_residual` (padded in degree).
//!
//! Because the residual is small and smooth, a low-degree correction
//! suffices — the per-fold exact-factorization count drops from g to g_warm
//! (e.g. 4 → 2), which is exactly the saving the paper projected. The
//! ablation bench measures both the saving and the accuracy cost.

use crate::linalg::cholesky::CholeskyError;
use crate::linalg::gemm::Gemm;
use crate::linalg::matrix::Matrix;
use crate::util::PhaseTimer;
use crate::vectorize::{build_target_matrix, VecStrategy};

use super::{fit, projector_for, vandermonde, FitOptions, Interpolant};

/// Warm-start configuration.
#[derive(Clone, Copy, Debug)]
pub struct WarmStartOptions {
    /// Fresh exact factorizations per warm fold (must exceed `degree_warm`).
    pub g_warm: usize,
    /// Degree of the residual correction polynomial.
    pub degree_warm: usize,
}

impl Default for WarmStartOptions {
    fn default() -> Self {
        // two fresh factors, linear correction: the cheapest honest update
        Self {
            g_warm: 2,
            degree_warm: 1,
        }
    }
}

/// Fit fold j's interpolant from fold i's, using only `g_warm` exact factors.
pub fn warm_fit(
    prev: &Interpolant,
    h_mat: &Matrix,
    fresh_lambdas: &[f64],
    opts: &WarmStartOptions,
    strategy: &dyn VecStrategy,
    timer: &mut PhaseTimer,
) -> Result<Interpolant, CholeskyError> {
    let gw = fresh_lambdas.len();
    assert_eq!(gw, opts.g_warm, "fresh λ count must match g_warm");
    assert!(
        gw > opts.degree_warm,
        "warm fit needs g_warm > degree_warm (got {gw} ≤ {})",
        opts.degree_warm
    );
    let h = h_mat.rows();
    assert_eq!(h, prev.h, "fold dimension changed");

    // 1-2. fresh exact factors at the warm sample points
    let mut factors = Vec::with_capacity(gw);
    for &lam in fresh_lambdas {
        factors.push(timer.time("chol", || {
            crate::linalg::cholesky::cholesky_shifted(h_mat, lam)
        })?);
    }
    let t_exact = timer.time("vec", || build_target_matrix(strategy, &factors));

    // residuals against the previous fold's interpolant
    let d = prev.theta.cols();
    let mut resid = Matrix::zeros(gw, d);
    timer.time("interp", || {
        let mut buf = vec![0.0; d];
        for (s, &lam) in fresh_lambdas.iter().enumerate() {
            prev.eval_vec_into(lam, &mut buf);
            for (o, (&e, &p)) in resid.row_mut(s).iter_mut().zip(t_exact.row(s).iter().zip(&buf))
            {
                *o = e - p;
            }
        }
    });

    // 3. low-degree LS fit of the residual curves
    let theta_resid = timer.time("fit", || {
        let v = vandermonde(fresh_lambdas, opts.degree_warm);
        let a = projector_for(&v);
        Gemm::default().mul(&a, &resid)
    });

    // 4. Θ_new = Θ_prev + Θ_resid (degree-padded)
    let degree = prev.degree.max(opts.degree_warm);
    let mut theta = Matrix::zeros(degree + 1, d);
    for p in 0..=prev.degree {
        theta.row_mut(p).copy_from_slice(prev.theta.row(p));
    }
    for p in 0..=opts.degree_warm {
        let row = theta_resid.row(p).to_vec();
        for (o, r) in theta.row_mut(p).iter_mut().zip(row) {
            *o += r;
        }
    }

    Ok(Interpolant {
        theta,
        h,
        degree,
        sample_lambdas: fresh_lambdas.to_vec(),
    })
}

/// Convenience: run a whole k-fold schedule — full fit on the first Hessian,
/// warm fits on the rest. Returns the interpolants and the total number of
/// exact factorizations performed (the paper's cost metric).
pub fn warm_schedule(
    hessians: &[Matrix],
    full_lambdas: &[f64],
    warm_lambdas: &[f64],
    degree: usize,
    opts: &WarmStartOptions,
    strategy: &dyn VecStrategy,
    timer: &mut PhaseTimer,
) -> Result<(Vec<Interpolant>, usize), CholeskyError> {
    assert!(!hessians.is_empty());
    let mut out = Vec::with_capacity(hessians.len());
    let first = fit(
        &hessians[0],
        full_lambdas,
        &FitOptions { degree, strategy },
        timer,
    )?;
    let mut factorizations = full_lambdas.len();
    out.push(first);
    for h_mat in &hessians[1..] {
        let prev = out.last().unwrap();
        let warm = warm_fit(prev, h_mat, warm_lambdas, opts, strategy, timer)?;
        factorizations += warm_lambdas.len();
        out.push(warm);
    }
    Ok((out, factorizations))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::cholesky::cholesky_shifted;
    use crate::linalg::norms::fro_norm;
    use crate::testutil::{random_matrix, random_spd};
    use crate::vectorize::RowWise;

    /// Two "folds": H and a low-rank resampled perturbation of it.
    fn fold_pair(h: usize, seed: u64) -> (Matrix, Matrix) {
        let a = random_spd(h, 1e3, seed);
        // resample ~1/5 of the mass: A' = A + small symmetric low-rank bump
        let u = random_matrix(h, 3, seed + 1);
        let mut b = a.clone();
        let bump = Gemm::default().a_bt(&u, &u);
        for (x, y) in b.as_mut_slice().iter_mut().zip(bump.as_slice()) {
            *x += 0.02 * y;
        }
        (a, b)
    }

    fn rel_factor_err(interp: &Interpolant, h_mat: &Matrix, lam: f64) -> f64 {
        let exact = cholesky_shifted(h_mat, lam).unwrap();
        let got = interp.eval_factor(lam, &RowWise);
        let mut d = got;
        for (x, y) in d.as_mut_slice().iter_mut().zip(exact.as_slice()) {
            *x -= y;
        }
        fro_norm(&d) / fro_norm(&exact)
    }

    #[test]
    fn warm_fit_tracks_new_fold() {
        let (a, b) = fold_pair(20, 3);
        let lams = [0.1, 0.4, 0.7, 1.0];
        let mut timer = PhaseTimer::new();
        let full = fit(
            &a,
            &lams,
            &FitOptions {
                degree: 2,
                strategy: &RowWise,
            },
            &mut timer,
        )
        .unwrap();

        // stale interpolant on the new fold: measurable error
        let stale = rel_factor_err(&full, &b, 0.55);
        // warm fit with only 2 fresh factors
        let warm = warm_fit(
            &full,
            &b,
            &[0.25, 0.85],
            &WarmStartOptions::default(),
            &RowWise,
            &mut timer,
        )
        .unwrap();
        let corrected = rel_factor_err(&warm, &b, 0.55);
        assert!(
            corrected < stale,
            "warm fit should improve on the stale interpolant: {corrected:.2e} !< {stale:.2e}"
        );
        // and it should approach the full refit's quality
        let refit = fit(
            &b,
            &lams,
            &FitOptions {
                degree: 2,
                strategy: &RowWise,
            },
            &mut timer,
        )
        .unwrap();
        let refit_err = rel_factor_err(&refit, &b, 0.55);
        assert!(
            corrected < refit_err * 25.0 + 1e-9,
            "warm {corrected:.2e} vs refit {refit_err:.2e}"
        );
    }

    #[test]
    fn warm_schedule_counts_factorizations() {
        let (a, b) = fold_pair(16, 7);
        let (c, _) = fold_pair(16, 8);
        let mut timer = PhaseTimer::new();
        let (interps, count) = warm_schedule(
            &[a, b, c],
            &[0.1, 0.4, 0.7, 1.0],
            &[0.25, 0.85],
            2,
            &WarmStartOptions::default(),
            &RowWise,
            &mut timer,
        )
        .unwrap();
        assert_eq!(interps.len(), 3);
        // 4 (full) + 2 + 2 (warm) instead of 3 × 4 = 12
        assert_eq!(count, 8);
    }

    #[test]
    fn identical_fold_warm_fit_is_nearly_exact() {
        // if the "new" fold equals the old one, residuals ≈ 0 and the warm
        // interpolant reproduces the previous one
        let a = random_spd(14, 1e2, 9);
        let lams = [0.1, 0.5, 1.0, 1.5];
        let mut timer = PhaseTimer::new();
        let full = fit(
            &a,
            &lams,
            &FitOptions {
                degree: 2,
                strategy: &RowWise,
            },
            &mut timer,
        )
        .unwrap();
        let warm = warm_fit(
            &full,
            &a,
            &[0.3, 1.2],
            &WarmStartOptions::default(),
            &RowWise,
            &mut timer,
        )
        .unwrap();
        for lam in [0.2, 0.6, 1.4] {
            let e_full = rel_factor_err(&full, &a, lam);
            let e_warm = rel_factor_err(&warm, &a, lam);
            // the correction refits the full fit's own residual at 2 points,
            // so a small perturbation (same order of magnitude) is expected
            assert!(
                e_warm < e_full * 5.0 + 1e-6,
                "λ={lam}: warm {e_warm:.2e} vs full {e_full:.2e}"
            );
        }
    }

    #[test]
    #[should_panic(expected = "g_warm > degree_warm")]
    fn rejects_underdetermined_correction() {
        let a = random_spd(8, 1e2, 1);
        let mut timer = PhaseTimer::new();
        let full = fit(
            &a,
            &[0.1, 0.5, 1.0],
            &FitOptions {
                degree: 2,
                strategy: &RowWise,
            },
            &mut timer,
        )
        .unwrap();
        let _ = warm_fit(
            &full,
            &a,
            &[0.3],
            &WarmStartOptions {
                g_warm: 1,
                degree_warm: 1,
            },
            &RowWise,
            &mut timer,
        );
    }
}
