//! Multi-level Cholesky (MChol) — the paper's §6.2 binary-search baseline,
//! also used to find the initial λ ranges every algorithm searches.
//!
//! Starting from a range `[10^(c−s), 10^(c+s)]`, iterate:
//!   (a) evaluate the hold-out error at λ = 10^(c−s), 10^c, 10^(c+s) with
//!       exact Cholesky factorizations,
//!   (b) recentre c on the best of the three,
//!   (c) halve s,
//! until `s ≤ s₀`. Each level costs 3 exact `O(d³)` factorizations (cached
//! across levels when a grid point repeats).

use std::collections::HashMap;

/// One evaluated probe point.
#[derive(Clone, Debug)]
pub struct Probe {
    pub lambda: f64,
    pub error: f64,
    /// Cumulative wall-clock seconds when this probe finished (for Figure 9).
    pub elapsed: f64,
}

/// Result of a multi-level search.
pub struct MCholResult {
    /// Best λ found.
    pub best_lambda: f64,
    /// Hold-out error at the best λ.
    pub best_error: f64,
    /// Every probe in evaluation order (Figure 9's trajectory).
    pub probes: Vec<Probe>,
    /// Final bracketing range `[10^(c−s₀), 10^(c+s₀)]`.
    pub final_range: (f64, f64),
    /// Number of exact factorizations actually performed (cache misses).
    pub factorizations: usize,
}

/// Search parameters (paper §6.3: s = 1.5, s₀ = 0.0025).
#[derive(Clone, Copy, Debug)]
pub struct MCholParams {
    /// Initial log₁₀ half-width.
    pub s: f64,
    /// Terminal half-width.
    pub s0: f64,
}

impl Default for MCholParams {
    fn default() -> Self {
        Self { s: 1.5, s0: 0.0025 }
    }
}

/// Run the multi-level search. `eval` maps λ to hold-out error (each call is
/// expected to do an exact factorization — the paper's step (a)); results are
/// memoized so re-probed grid points are free.
///
/// `eval` is fallible: a probe that cannot be evaluated (typically a
/// [`crate::linalg::cholesky::CholeskyError`] from an indefinite `H + λI`)
/// aborts the search and the error propagates to the caller — the sweep
/// fails cleanly instead of panicking inside a pool worker.
pub fn multilevel_search<E>(
    center_log10: f64,
    params: MCholParams,
    mut eval: impl FnMut(f64) -> Result<f64, E>,
) -> Result<MCholResult, E> {
    let mut c = center_log10;
    let mut s = params.s;
    let mut probes = Vec::new();
    let mut cache: HashMap<u64, f64> = HashMap::new();
    let mut factorizations = 0usize;
    let t0 = std::time::Instant::now();

    let mut best = (f64::NAN, f64::INFINITY);
    while s > params.s0 {
        for exp in [c - s, c, c + s] {
            let lam = 10f64.powf(exp);
            let key = lam.to_bits();
            let err = match cache.get(&key) {
                Some(&e) => e,
                None => {
                    factorizations += 1;
                    let e = eval(lam)?;
                    cache.insert(key, e);
                    e
                }
            };
            probes.push(Probe {
                lambda: lam,
                error: err,
                elapsed: t0.elapsed().as_secs_f64(),
            });
            if err < best.1 {
                best = (lam, err);
            }
        }
        // recentre on the best of the three and halve the bracket
        c = best.0.log10();
        s /= 2.0;
    }

    Ok(MCholResult {
        best_lambda: best.0,
        best_error: best.1,
        probes,
        final_range: (10f64.powf(c - params.s0), 10f64.powf(c + params.s0)),
        factorizations,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Convex error curve with known minimizer λ* = 10^(-1.3).
    fn synthetic_err(lam: f64) -> Result<f64, ()> {
        let l = lam.log10();
        Ok((l + 1.3) * (l + 1.3) + 0.25)
    }

    #[test]
    fn converges_to_minimum_of_convex_curve() {
        let r = multilevel_search(0.0, MCholParams { s: 1.5, s0: 1e-3 }, synthetic_err).unwrap();
        assert!(
            (r.best_lambda.log10() + 1.3).abs() < 5e-3,
            "found λ = 1e{:.4}",
            r.best_lambda.log10()
        );
        assert!((r.best_error - 0.25).abs() < 1e-4);
    }

    #[test]
    fn halving_schedule_length() {
        // levels = ceil(log2(s/s0)); each level probes 3 points
        let r = multilevel_search(0.0, MCholParams { s: 1.6, s0: 0.05 }, synthetic_err).unwrap();
        let levels = (1.6f64 / 0.05).log2().ceil() as usize;
        assert_eq!(r.probes.len(), 3 * levels);
    }

    #[test]
    fn memoization_avoids_repeat_factorizations() {
        let mut calls = 0usize;
        let r = multilevel_search(0.0, MCholParams { s: 1.5, s0: 0.01 }, |lam| {
            calls += 1;
            synthetic_err(lam)
        })
        .unwrap();
        assert_eq!(calls, r.factorizations);
        // the centre point repeats between levels → strictly fewer evals than probes
        assert!(r.factorizations < r.probes.len());
    }

    #[test]
    fn probes_have_monotone_timestamps() {
        let r = multilevel_search(0.0, MCholParams::default(), synthetic_err).unwrap();
        for w in r.probes.windows(2) {
            assert!(w[1].elapsed >= w[0].elapsed);
        }
    }

    #[test]
    fn final_range_brackets_best() {
        let r = multilevel_search(0.0, MCholParams { s: 1.5, s0: 0.01 }, synthetic_err).unwrap();
        assert!(r.final_range.0 <= r.best_lambda && r.best_lambda <= r.final_range.1);
    }

    #[test]
    fn probe_error_aborts_search_and_propagates() {
        let mut calls = 0usize;
        let out = multilevel_search(0.0, MCholParams { s: 1.5, s0: 0.01 }, |lam| {
            calls += 1;
            if calls == 2 {
                Err("indefinite")
            } else {
                synthetic_err(lam).map_err(|_| "unreachable")
            }
        });
        match out {
            Err(e) => assert_eq!(e, "indefinite"),
            Ok(_) => panic!("search must fail when a probe fails"),
        }
        assert_eq!(calls, 2, "search must stop at the first failing probe");
    }
}
