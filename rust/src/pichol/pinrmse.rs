//! PINRMSE — interpolate the *hold-out error curve* instead of the factors
//! (the paper's Figure 10 ablation).
//!
//! "PINRMSE is equivalent to replacing the g×D matrix T in Algorithm 1 with
//! a g×1 vector t, where the entries in t are the hold-out errors that
//! correspond to the sparsely sampled λ values." The paper shows this is
//! *much* worse than interpolating the factors: the error curve is not
//! as polynomial-friendly as the factor entries, so the selected λ can be
//! dramatically wrong (MNIST, Caltech-101).

use super::vandermonde;
use crate::linalg::gemm::Gemm;

/// A degree-r polynomial fitted to (λ, hold-out-error) samples.
pub struct ErrorCurvePoly {
    /// r+1 coefficients, constant term first.
    pub coeffs: Vec<f64>,
}

/// Fit the error-curve polynomial (Algorithm 1 with D = 1).
pub fn fit_error_curve(sample_lambdas: &[f64], errors: &[f64], degree: usize) -> ErrorCurvePoly {
    assert_eq!(sample_lambdas.len(), errors.len());
    assert!(sample_lambdas.len() > degree, "need g > r samples");
    let v = vandermonde(sample_lambdas, degree);
    let gem = Gemm::default();
    let h = gem.at_b(&v, &v);
    let l = crate::linalg::cholesky::cholesky_blocked(&h).expect("degenerate sample points");
    // g_vec = Vᵀ t
    let g_vec = crate::linalg::gemm::gemv_t(&v, errors);
    let coeffs = crate::linalg::triangular::solve_cholesky(&l, &g_vec);
    ErrorCurvePoly { coeffs }
}

impl ErrorCurvePoly {
    /// Evaluate the fitted error curve at λ (Horner).
    pub fn eval(&self, lam: f64) -> f64 {
        self.coeffs.iter().rev().fold(0.0, |acc, &c| acc * lam + c)
    }

    /// Interpolated errors over a grid; returns (argmin λ, min error, curve).
    pub fn sweep(&self, grid: &[f64]) -> (f64, f64, Vec<f64>) {
        let curve: Vec<f64> = grid.iter().map(|&l| self.eval(l)).collect();
        let (mut bi, mut be) = (0usize, f64::INFINITY);
        for (i, &e) in curve.iter().enumerate() {
            if e < be {
                be = e;
                bi = i;
            }
        }
        (grid[bi], be, curve)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recovers_exact_quadratic() {
        // t(λ) = 2 − 3λ + λ² sampled at 4 points
        let lams = [0.1, 0.4, 0.7, 1.0];
        let errs: Vec<f64> = lams.iter().map(|&l| 2.0 - 3.0 * l + l * l).collect();
        let p = fit_error_curve(&lams, &errs, 2);
        assert!((p.coeffs[0] - 2.0).abs() < 1e-9);
        assert!((p.coeffs[1] + 3.0).abs() < 1e-9);
        assert!((p.coeffs[2] - 1.0).abs() < 1e-9);
        assert!((p.eval(0.55) - (2.0 - 3.0 * 0.55 + 0.55 * 0.55)).abs() < 1e-9);
    }

    #[test]
    fn sweep_finds_quadratic_minimum() {
        // minimum of 2 − 3λ + λ² is at λ = 1.5; clamp grid to [0,1] → edge
        let lams = [0.1, 0.4, 0.7, 1.0];
        let errs: Vec<f64> = lams.iter().map(|&l| 2.0 - 3.0 * l + l * l).collect();
        let p = fit_error_curve(&lams, &errs, 2);
        let grid: Vec<f64> = (0..50).map(|i| 0.02 * (i + 1) as f64).collect();
        let (best, _, curve) = p.sweep(&grid);
        assert_eq!(curve.len(), 50);
        assert!((best - 1.0).abs() < 1e-12, "grid minimum at the boundary");
    }

    #[test]
    fn misfits_nonpolynomial_curves() {
        // the Figure 10 phenomenon: a sharp exponential valley fitted by a
        // quadratic picks a far-off λ
        let truth = |l: f64| ((l.log10() + 2.0) * 3.0).powi(2).min(5.0) + 0.1;
        let lams = [1e-3, 1e-2, 1e-1, 1.0];
        let errs: Vec<f64> = lams.iter().map(|&l| truth(l)).collect();
        let p = fit_error_curve(&lams, &errs, 2);
        let grid: Vec<f64> = (0..100).map(|i| 10f64.powf(-3.0 + 3.0 * i as f64 / 99.0)).collect();
        let (best_fit, _, _) = p.sweep(&grid);
        // true minimizer is 1e-2; the quadratic-in-λ fit lands far away
        let log_ratio = (best_fit.log10() - (-2.0f64)).abs();
        assert!(log_ratio > 0.5, "PINRMSE unexpectedly accurate: λ={best_fit}");
    }
}
