//! The paper's core contribution: Algorithm 1 (*pi*CHOLESKY).
//!
//! Fit: given the Hessian `H` and `g` sample points `{λ_s}`, compute the
//! exact factors `Lˢ = chol(H + λ_s I)`, vectorize them into the g×D target
//! matrix `T` (via any [`crate::vectorize::VecStrategy`]), build the
//! g×(r+1) Vandermonde observation matrix `V`, and solve the one-shot
//! least-squares problem `Θ = (VᵀV)⁻¹VᵀT` — D independent degree-r
//! polynomials, one per factor entry, learned simultaneously (eq. 3-4).
//!
//! Eval: for any new λ_t, `vec(L^t) = [1 λ_t … λ_t^r] Θ` at `O(r·d²)` —
//! versus `O(d³)` for an exact factorization.
//!
//! Submodules: [`mchol`] (the §6.2 multi-level binary search), [`bound`]
//! (the §4 Fréchet/Taylor error-bound calculator), [`pinrmse`] (the
//! hold-out-error-interpolation alternative the paper compares against in
//! Figure 10).

pub mod bound;
pub mod mchol;
pub mod pinrmse;
pub mod warmstart;

use crate::linalg::cholesky::{cholesky_shifted, CholeskyError};
use crate::linalg::gemm::Gemm;
use crate::linalg::matrix::Matrix;
use crate::util::PhaseTimer;
use crate::vectorize::{build_target_matrix, VecStrategy};

/// Build the g×(r+1) observation matrix V: row s is `[1, λ_s, …, λ_s^r]`
/// (Algorithm 1 lines 3-4: the leftmost r+1 columns of the Vandermonde
/// matrix, monomial basis).
pub fn vandermonde(lams: &[f64], r: usize) -> Matrix {
    Matrix::from_fn(lams.len(), r + 1, |s, p| lams[s].powi(p as i32))
}

/// Solve the tiny (r+1)×(r+1) normal-equations system for the projector
/// `A = (VᵀV)⁻¹Vᵀ` ((r+1)×g). The system is symmetric positive-definite for
/// distinct sample points, so Cholesky is exact here too.
pub(crate) fn projector_for(v: &Matrix) -> Matrix {
    let gem = Gemm::default();
    let h_lam = gem.at_b(v, v); // VᵀV, (r+1)×(r+1)
    let l = crate::linalg::cholesky::cholesky_blocked(&h_lam)
        .expect("Vandermonde normal equations not PD: duplicate sample points?");
    // A = H⁻¹Vᵀ: solve against Vᵀ
    let vt = v.transpose();
    let w = crate::linalg::triangular::trsm_left_lower(&l, &vt);
    crate::linalg::triangular::trsm_left_lower_t(&l, &w)
}

/// A fitted piCholesky interpolant: Θ plus everything needed to reconstruct
/// factors at arbitrary λ.
pub struct Interpolant {
    /// (r+1)×D coefficient matrix (Algorithm 1's Θ).
    pub theta: Matrix,
    /// Factor dimension h = d+1.
    pub h: usize,
    /// Polynomial degree r.
    pub degree: usize,
    /// Sample points used for the fit.
    pub sample_lambdas: Vec<f64>,
}

impl Interpolant {
    /// Interpolated vectorized factor at λ: `vec(L) = [1 λ … λ^r] Θ`,
    /// evaluated by **Horner's rule** — `r` fused sweeps of
    /// `out = out·λ + Θ[p]` over the D axis, one multiply-add per
    /// coefficient instead of the monomial form's separate power tracking,
    /// and better conditioned for λ near the grid edges. `O(r·D)` — the
    /// paper's payoff step.
    pub fn eval_vec_into(&self, lam: f64, out: &mut [f64]) {
        let d = self.theta.cols();
        debug_assert_eq!(out.len(), d);
        out.copy_from_slice(self.theta.row(self.degree));
        for p in (0..self.degree).rev() {
            let row = self.theta.row(p);
            for (o, &c) in out.iter_mut().zip(row) {
                *o = *o * lam + c;
            }
        }
    }

    /// Allocating wrapper around [`Interpolant::eval_vec_into`].
    pub fn eval_vec(&self, lam: f64) -> Vec<f64> {
        let mut out = vec![0.0; self.theta.cols()];
        self.eval_vec_into(lam, &mut out);
        out
    }

    /// Interpolated factor as a matrix (unvec through the given strategy —
    /// must be the same strategy the fit used).
    pub fn eval_factor(&self, lam: f64, strategy: &dyn VecStrategy) -> Matrix {
        let mut vbuf = Vec::new();
        let mut out = Matrix::zeros(0, 0);
        self.eval_factor_into(lam, strategy, &mut vbuf, &mut out);
        out
    }

    /// Interpolated factor into caller-provided buffers: `vbuf` is the
    /// D-length evaluation scratch, `out` is reshaped to `h×h` and fully
    /// overwritten. On the sweep hot path both live in the per-worker
    /// [`crate::linalg::scratch::Scratch`], so steady-state grid tasks
    /// reconstruct factors with **zero heap allocation** (this is what
    /// [`Interpolant::eval_factor`] cost per λ before: one `Vec` + one
    /// `Matrix`). Bitwise identical to [`Interpolant::eval_factor`].
    pub fn eval_factor_into(
        &self,
        lam: f64,
        strategy: &dyn VecStrategy,
        vbuf: &mut Vec<f64>,
        out: &mut Matrix,
    ) {
        let d = self.theta.cols();
        if vbuf.len() != d {
            // size fix only; eval_vec_into fully overwrites the contents
            vbuf.clear();
            vbuf.resize(d, 0.0);
        }
        self.eval_vec_into(lam, vbuf);
        strategy.unvec_into(vbuf, self.h, out);
    }
}

/// Fit configuration for Algorithm 1.
pub struct FitOptions<'a> {
    /// Polynomial degree r (paper default 2; requires g > r sample points).
    pub degree: usize,
    /// Vectorization strategy for building T (paper default: recursive).
    pub strategy: &'a dyn VecStrategy,
}

/// Algorithm 1 lines 2-6, from exact factors the caller already holds.
///
/// Line 1 (the `O(g·d³)` anchor factorizations) is the parallelizable part,
/// so the sweep engine computes the factors on its worker pool and hands
/// them here; [`fit`] is the serial convenience wrapper that does line 1
/// itself. Factors must be ordered like `sample_lambdas` — `factors[s]` is
/// `chol(H + λ_s I)`.
///
/// Phase timings land in `timer` under the Table 1 names: `vec` (line 2),
/// `fit` (lines 3-6).
pub fn fit_from_factors(
    sample_lambdas: &[f64],
    factors: &[Matrix],
    opts: &FitOptions,
    timer: &mut PhaseTimer,
) -> Interpolant {
    let g = sample_lambdas.len();
    let r = opts.degree;
    assert!(g > r, "Algorithm 1 requires g > r (got g={g}, r={r})");
    assert_eq!(factors.len(), g, "need exactly one factor per sample λ");
    let h = factors[0].rows();

    // line 2: vectorize into T (g×D)
    let t = timer.time("vec", || build_target_matrix(opts.strategy, factors));

    // lines 3-6: V, G_λ = VᵀT, H_λ = VᵀV, Θ = H_λ⁻¹G_λ — done as Θ = A·T
    let theta = timer.time("fit", || {
        let v = vandermonde(sample_lambdas, r);
        let a = projector_for(&v);
        Gemm::default().mul(&a, &t)
    });

    Interpolant {
        theta,
        h,
        degree: r,
        sample_lambdas: sample_lambdas.to_vec(),
    }
}

/// Algorithm 1: fit the interpolant from `g` exact factorizations.
///
/// Phase timings land in `timer` under the Table 1 names: `chol` (line 1),
/// `vec` (line 2), `fit` (lines 3-6). A [`CholeskyError`] from line 1 means
/// some sample λ left `H + λI` indefinite — recover by resampling with
/// larger λ's (shift-and-retry, see
/// [`crate::linalg::cholesky::CholeskyError`]).
pub fn fit(
    h_mat: &Matrix,
    sample_lambdas: &[f64],
    opts: &FitOptions,
    timer: &mut PhaseTimer,
) -> Result<Interpolant, CholeskyError> {
    let g = sample_lambdas.len();
    let r = opts.degree;
    assert!(g > r, "Algorithm 1 requires g > r (got g={g}, r={r})");

    // line 1: the g exact factors — the O(g d³) dominant cost
    let mut factors = Vec::with_capacity(g);
    for &lam in sample_lambdas {
        let l = timer.time("chol", || cholesky_shifted(h_mat, lam))?;
        factors.push(l);
    }

    Ok(fit_from_factors(sample_lambdas, &factors, opts, timer))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::norms::fro_norm;
    use crate::testutil::{proptest_lite, random_spd};
    use crate::util::PhaseTimer;
    use crate::vectorize::{Recursive, RowWise};

    fn fit_default(h_mat: &Matrix, lams: &[f64]) -> Interpolant {
        let mut t = PhaseTimer::new();
        fit(
            h_mat,
            lams,
            &FitOptions {
                degree: 2,
                strategy: &RowWise,
            },
            &mut t,
        )
        .unwrap()
    }

    fn rel_err(got: &Matrix, exact: &Matrix) -> f64 {
        let mut d = got.clone();
        for (x, y) in d.as_mut_slice().iter_mut().zip(exact.as_slice()) {
            *x -= y;
        }
        fro_norm(&d) / fro_norm(exact)
    }

    #[test]
    fn fit_from_factors_matches_fit() {
        // the engine's split (anchors elsewhere, lines 2-6 here) must
        // reproduce the one-shot fit bit for bit
        let a = random_spd(14, 1e3, 6);
        let lams = [0.1, 0.45, 0.8, 1.2];
        let mut t = PhaseTimer::new();
        let whole = fit(
            &a,
            &lams,
            &FitOptions {
                degree: 2,
                strategy: &RowWise,
            },
            &mut t,
        )
        .unwrap();
        let factors: Vec<Matrix> = lams
            .iter()
            .map(|&lam| cholesky_shifted(&a, lam).unwrap())
            .collect();
        let split = fit_from_factors(
            &lams,
            &factors,
            &FitOptions {
                degree: 2,
                strategy: &RowWise,
            },
            &mut t,
        );
        assert_eq!(whole.theta.as_slice(), split.theta.as_slice());
        assert_eq!(whole.h, split.h);
    }

    #[test]
    fn horner_matches_monomial_eval() {
        let a = random_spd(12, 1e3, 9);
        let lams = [0.1, 0.4, 0.8, 1.1];
        let interp = fit_default(&a, &lams);
        for &lam in &[0.05, 0.3, 0.77, 1.3] {
            let v = interp.eval_vec(lam);
            // monomial reference: Σ_p λ^p · Θ[p]
            for (j, &got) in v.iter().enumerate() {
                let mut expect = 0.0;
                for p in 0..=interp.degree {
                    expect += lam.powi(p as i32) * interp.theta[(p, j)];
                }
                assert!((got - expect).abs() < 1e-10, "λ={lam} entry {j}");
            }
        }
    }

    #[test]
    fn eval_factor_into_bitwise_matches_eval_factor() {
        let a = random_spd(20, 1e3, 10);
        let lams = [0.1, 0.5, 0.9, 1.2];
        let interp = fit_default(&a, &lams);
        let mut vbuf = vec![f64::NAN; 3]; // dirty + wrong-sized on purpose
        let mut out = Matrix::zeros(7, 7);
        for &lam in &[0.2, 0.6, 1.0] {
            let fresh = interp.eval_factor(lam, &RowWise);
            interp.eval_factor_into(lam, &RowWise, &mut vbuf, &mut out);
            // slice equality is NaN-propagating (max_abs_diff is not)
            assert_eq!(out.as_slice(), fresh.as_slice(), "λ={lam}");
        }
    }

    #[test]
    fn vandermonde_shape_and_values() {
        let v = vandermonde(&[0.5, 2.0], 2);
        assert_eq!((v.rows(), v.cols()), (2, 3));
        assert_eq!(v[(0, 0)], 1.0);
        assert_eq!(v[(0, 2)], 0.25);
        assert_eq!(v[(1, 1)], 2.0);
        assert_eq!(v[(1, 2)], 4.0);
    }

    #[test]
    fn interpolant_hits_sample_points_when_g_eq_r_plus_1() {
        // with g = r+1 the LS fit is interpolation: exact at the samples
        let a = random_spd(16, 1e3, 1);
        let lams = [0.1, 0.5, 1.0];
        let interp = fit_default(&a, &lams);
        for &lam in &lams {
            let exact = cholesky_shifted(&a, lam).unwrap();
            let got = interp.eval_factor(lam, &RowWise);
            let rel = rel_err(&got, &exact);
            assert!(rel < 1e-9, "rel error at sample λ={lam}: {rel:.2e}");
        }
    }

    #[test]
    fn interpolation_error_small_between_samples() {
        // the Figure 4 claim: g=6, r=2 tracks the exact factors densely
        let a = random_spd(24, 1e4, 2);
        let lams: Vec<f64> = (0..6).map(|i| 0.05 + 0.19 * i as f64).collect();
        let interp = fit_default(&a, &lams);
        for i in 0..50 {
            let lam = 0.05 + 0.95 * i as f64 / 49.0;
            let exact = cholesky_shifted(&a, lam).unwrap();
            let got = interp.eval_factor(lam, &RowWise);
            let rel = rel_err(&got, &exact);
            assert!(rel < 5e-3, "λ={lam}: rel={rel:.2e}");
        }
    }

    #[test]
    fn extrapolation_degrades_gracefully() {
        // the cubic-in-γ bound (Thm 4.7): error far outside the sampled
        // interval must be much larger than inside
        let a = random_spd(16, 1e3, 7);
        let lams = [0.4, 0.5, 0.6, 0.7];
        let interp = fit_default(&a, &lams);
        let inside = rel_err(
            &interp.eval_factor(0.55, &RowWise),
            &cholesky_shifted(&a, 0.55).unwrap(),
        );
        let outside = rel_err(
            &interp.eval_factor(5.0, &RowWise),
            &cholesky_shifted(&a, 5.0).unwrap(),
        );
        assert!(outside > 10.0 * inside, "inside={inside:.2e} outside={outside:.2e}");
    }

    #[test]
    fn strategy_agnostic_factors() {
        // fit with recursive ordering must reproduce the same factor as
        // row-wise ordering (the polynomials are per-entry, order-independent)
        let a = random_spd(20, 1e3, 3);
        let lams = [0.05, 0.3, 0.7, 1.0];
        let mut t = PhaseTimer::new();
        let rec = Recursive::default();
        let f_rec = fit(
            &a,
            &lams,
            &FitOptions {
                degree: 2,
                strategy: &rec,
            },
            &mut t,
        )
        .unwrap();
        let f_rw = fit_default(&a, &lams);
        let l_rec = f_rec.eval_factor(0.42, &rec);
        let l_rw = f_rw.eval_factor(0.42, &RowWise);
        assert!(l_rec.max_abs_diff(&l_rw) < 1e-10);
    }

    #[test]
    fn timer_records_all_phases() {
        let a = random_spd(12, 1e2, 4);
        let mut t = PhaseTimer::new();
        let _ = fit(
            &a,
            &[0.1, 0.4, 0.8, 1.0],
            &FitOptions {
                degree: 2,
                strategy: &RowWise,
            },
            &mut t,
        )
        .unwrap();
        assert!(t.get("chol") > 0.0);
        assert!(t.get("vec") > 0.0);
        assert!(t.get("fit") > 0.0);
    }

    #[test]
    #[should_panic(expected = "requires g > r")]
    fn rejects_underdetermined() {
        let a = random_spd(8, 1e2, 5);
        let mut t = PhaseTimer::new();
        let _ = fit(
            &a,
            &[0.1, 0.5],
            &FitOptions {
                degree: 2,
                strategy: &RowWise,
            },
            &mut t,
        );
    }

    #[test]
    fn interpolated_factor_solves_ridge_accurately_property() {
        // end use: θ from the interpolated factor ≈ θ from the exact factor
        proptest_lite::check("interp-solve", 8, |c| {
            let h = c.dim(10, 28);
            let a = random_spd(h, 1e3, 0xF17 + c.index as u64);
            let lams = [0.1, 0.4, 0.7, 1.0];
            let interp = fit_default(&a, &lams);
            let lam = c.float(0.12, 0.98);
            let g: Vec<f64> = (0..h).map(|i| (i as f64 * 0.71).sin()).collect();
            let l_exact = cholesky_shifted(&a, lam).unwrap();
            let l_pi = interp.eval_factor(lam, &RowWise);
            let th_exact = crate::linalg::triangular::solve_cholesky(&l_exact, &g);
            let th_pi = crate::linalg::triangular::solve_cholesky(&l_pi, &g);
            let num: f64 = th_exact
                .iter()
                .zip(&th_pi)
                .map(|(x, y)| (x - y).powi(2))
                .sum::<f64>()
                .sqrt();
            let den: f64 = th_exact.iter().map(|x| x * x).sum::<f64>().sqrt();
            assert!(num / den < 0.02, "θ rel err {} at λ={lam}", num / den);
        });
    }
}
