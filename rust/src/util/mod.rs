//! Small shared utilities: wall-clock timing, formatting, log-spaced grids.

use crate::obs::hist::PhaseHists;
use std::time::Instant;

/// Measure the wall-clock seconds of a closure, returning (result, secs).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// A cumulative named timer: the per-phase instrumentation behind Table 1 and
/// Figure 2 (vec / fit / interp / gram / downdate / cholesky / solve /
/// holdout). Each phase also carries an **invocation count** — how many times
/// it was timed — which is what lets tests assert structural properties like
/// "the Gram was assembled exactly once per sweep".
#[derive(Default, Debug, Clone)]
pub struct PhaseTimer {
    entries: Vec<(String, f64)>,
    counts: Vec<(String, u64)>,
    /// Optional latency-histogram sink: `None` (the default) keeps the
    /// timer's behavior and cost exactly as before observability existed;
    /// [`PhaseTimer::with_hists`] arms it so every individual sample also
    /// lands in a per-phase log-bucketed histogram.
    hists: Option<Box<PhaseHists>>,
}

impl PhaseTimer {
    pub fn new() -> Self {
        Self::default()
    }

    /// A timer that additionally records every sample into per-phase
    /// latency histograms (the observability layer's p50/p90/p99 source).
    pub fn with_hists() -> Self {
        PhaseTimer {
            hists: Some(Box::default()),
            ..Self::default()
        }
    }

    /// Run `f`, accumulating its wall time under `phase`.
    pub fn time<T>(&mut self, phase: &str, f: impl FnOnce() -> T) -> T {
        let (out, secs) = timed(f);
        self.add(phase, secs);
        out
    }

    fn bump(&mut self, phase: &str, secs: f64, invocations: u64) {
        if let Some(e) = self.entries.iter_mut().find(|(n, _)| n == phase) {
            e.1 += secs;
        } else {
            self.entries.push((phase.to_string(), secs));
        }
        if invocations > 0 {
            if let Some(c) = self.counts.iter_mut().find(|(n, _)| n == phase) {
                c.1 += invocations;
            } else {
                self.counts.push((phase.to_string(), invocations));
            }
        }
    }

    /// Add seconds to a phase directly (counts as one invocation).
    pub fn add(&mut self, phase: &str, secs: f64) {
        self.bump(phase, secs, 1);
        if let Some(h) = self.hists.as_deref_mut() {
            h.record_secs(phase, secs);
        }
    }

    /// Seconds accumulated under `phase` (0 if never timed).
    pub fn get(&self, phase: &str) -> f64 {
        self.entries
            .iter()
            .find(|(n, _)| n == phase)
            .map(|(_, s)| *s)
            .unwrap_or(0.0)
    }

    /// Times `phase` was timed/added, summed across merges (0 if never).
    pub fn count(&self, phase: &str) -> u64 {
        self.counts
            .iter()
            .find(|(n, _)| n == phase)
            .map(|(_, c)| *c)
            .unwrap_or(0)
    }

    /// Total across phases.
    pub fn total(&self) -> f64 {
        self.entries.iter().map(|(_, s)| s).sum()
    }

    /// (phase, seconds) pairs in insertion order.
    pub fn entries(&self) -> &[(String, f64)] {
        &self.entries
    }

    /// Merge another timer into this one (seconds and invocation counts both
    /// accumulate; merging never counts as a fresh invocation). Armed
    /// histograms merge too — histogram merging is order-independent, so
    /// the coordinator's deterministic merge order is not load-bearing
    /// for the quantiles.
    pub fn merge(&mut self, other: &PhaseTimer) {
        for (name, secs) in &other.entries {
            self.bump(name, *secs, 0);
        }
        for (name, n) in &other.counts {
            self.bump(name, 0.0, *n);
        }
        if let (Some(mine), Some(theirs)) = (self.hists.as_deref_mut(), other.hists.as_deref()) {
            mine.merge(theirs);
        }
    }

    /// Detach the accumulated per-phase histograms (empty when the timer
    /// was never armed).
    pub fn take_hists(&mut self) -> PhaseHists {
        self.hists.take().map(|b| *b).unwrap_or_default()
    }

    /// Whether this timer records per-sample histograms.
    pub fn hists_armed(&self) -> bool {
        self.hists.is_some()
    }

    /// Render a sorted, fixed-format per-phase summary: one line per
    /// phase in lexicographic order, names padded to the longest name,
    /// seconds in a fixed-width column — diffable between runs, like
    /// `Metrics::snapshot`.
    pub fn render(&self) -> String {
        let mut names: Vec<&String> = self.entries.iter().map(|(n, _)| n).collect();
        names.sort();
        let width = names.iter().map(|n| n.len()).max().unwrap_or(0);
        let mut s = String::new();
        for n in names {
            s.push_str(&format!(
                "phase   {n:<width$} = {:>13.4}s  n={}\n",
                self.get(n),
                self.count(n)
            ));
        }
        s
    }
}

/// `q` exponentially spaced values in `[lo, hi]` (the paper's candidate-λ
/// grids, e.g. 31 points on `[10⁻³, 1]`).
pub fn logspace(lo: f64, hi: f64, q: usize) -> Vec<f64> {
    assert!(lo > 0.0 && hi > lo && q >= 2);
    let (a, b) = (lo.log10(), hi.log10());
    (0..q)
        .map(|i| 10f64.powf(a + (b - a) * i as f64 / (q - 1) as f64))
        .collect()
}

/// Evenly pick `g` of the `q` grid values (the paper sparsely samples its g=4
/// interpolation points from the 31 candidates).
pub fn subsample_indices(q: usize, g: usize) -> Vec<usize> {
    assert!(g >= 2 && g <= q);
    (0..g)
        .map(|i| (i as f64 * (q - 1) as f64 / (g - 1) as f64).round() as usize)
        .collect()
}

/// Render a markdown table (used by the experiment reports).
pub fn markdown_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut s = String::new();
    s.push_str("| ");
    s.push_str(&headers.join(" | "));
    s.push_str(" |\n|");
    for _ in headers {
        s.push_str("---|");
    }
    s.push('\n');
    for row in rows {
        s.push_str("| ");
        s.push_str(&row.join(" | "));
        s.push_str(" |\n");
    }
    s
}

/// Format seconds with sensible precision.
pub fn fmt_secs(s: f64) -> String {
    if s >= 100.0 {
        format!("{s:.0}")
    } else if s >= 1.0 {
        format!("{s:.2}")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.1}µs", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logspace_endpoints_and_monotone() {
        let g = logspace(1e-3, 1.0, 31);
        assert_eq!(g.len(), 31);
        assert!((g[0] - 1e-3).abs() < 1e-12);
        assert!((g[30] - 1.0).abs() < 1e-9);
        for w in g.windows(2) {
            assert!(w[1] > w[0]);
        }
    }

    #[test]
    fn subsample_hits_endpoints() {
        let idx = subsample_indices(31, 4);
        assert_eq!(idx[0], 0);
        assert_eq!(*idx.last().unwrap(), 30);
        assert_eq!(idx.len(), 4);
    }

    #[test]
    fn phase_timer_accumulates() {
        let mut t = PhaseTimer::new();
        t.add("vec", 1.0);
        t.add("fit", 2.0);
        t.add("vec", 0.5);
        assert!((t.get("vec") - 1.5).abs() < 1e-12);
        assert!((t.total() - 3.5).abs() < 1e-12);
        assert_eq!(t.count("vec"), 2);
        assert_eq!(t.count("fit"), 1);
        assert_eq!(t.count("nope"), 0);
        let mut u = PhaseTimer::new();
        u.add("vec", 1.0);
        u.merge(&t);
        assert!((u.get("vec") - 2.5).abs() < 1e-12);
        // merge sums invocation counts; it is not itself an invocation
        assert_eq!(u.count("vec"), 3);
        assert_eq!(u.count("fit"), 1);
    }

    #[test]
    fn phase_timer_hists_record_and_merge() {
        let mut t = PhaseTimer::with_hists();
        assert!(t.hists_armed());
        t.add("vec", 0.001);
        t.add("vec", 0.002);
        let mut u = PhaseTimer::with_hists();
        u.add("vec", 0.004);
        u.add("fit", 0.008);
        t.merge(&u);
        let h = t.take_hists();
        assert_eq!(h.get("vec").unwrap().count(), 3);
        assert_eq!(h.get("fit").unwrap().count(), 1);
        // a disarmed timer records nothing and takes an empty collection
        let mut plain = PhaseTimer::new();
        plain.add("vec", 1.0);
        assert!(!plain.hists_armed());
        assert!(plain.take_hists().is_empty());
    }

    #[test]
    fn phase_timer_render_is_sorted_and_fixed_format() {
        let mut t = PhaseTimer::new();
        t.add("solve", 1.25);
        t.add("chol", 0.0625);
        t.add("chol", 0.0625);
        let expected = "\
phase   chol  =        0.1250s  n=2
phase   solve =        1.2500s  n=1
";
        assert_eq!(t.render(), expected);
    }

    #[test]
    fn markdown_table_shape() {
        let s = markdown_table(&["a", "b"], &[vec!["1".into(), "2".into()]]);
        assert!(s.contains("| a | b |"));
        assert!(s.contains("| 1 | 2 |"));
    }
}
