//! Triangular solves: the per-λ request-path operation (paper §3.2).
//!
//! Once a factor L (exact or interpolated) is in hand, solving
//! `L Lᵀ θ = g` is a forward substitution followed by a backward one —
//! `O(d²)` each, which is exactly why interpolating L (instead of the
//! solution θ) preserves the cheap per-λ cost structure.

use super::matrix::Matrix;

/// Forward substitution: solve `L w = b` for lower-triangular L.
pub fn trsv_lower(l: &Matrix, b: &[f64]) -> Vec<f64> {
    let n = l.rows();
    assert!(l.is_square() && b.len() == n);
    let mut w = vec![0.0; n];
    for i in 0..n {
        let row = l.row(i);
        let mut s = b[i];
        // contiguous dot over the already-solved prefix
        for k in 0..i {
            s -= row[k] * w[k];
        }
        w[i] = s / row[i];
    }
    w
}

/// Backward substitution: solve `Lᵀ x = b` given lower-triangular L
/// (reads L column-wise, i.e. Lᵀ row-wise).
pub fn trsv_upper(l: &Matrix, b: &[f64]) -> Vec<f64> {
    let n = l.rows();
    assert!(l.is_square() && b.len() == n);
    let mut x = b.to_vec();
    for i in (0..n).rev() {
        let xi = x[i] / l[(i, i)];
        x[i] = xi;
        // eliminate xi from all earlier equations: x[k] -= L[i][k] * xi
        let row = l.row(i);
        for k in 0..i {
            x[k] -= row[k] * xi;
        }
    }
    x
}

/// Solve `L Lᵀ θ = g` — the complete per-λ ridge solve.
pub fn solve_cholesky(l: &Matrix, g: &[f64]) -> Vec<f64> {
    trsv_upper(l, &trsv_lower(l, g))
}

/// Block TRSM: solve `L X = B` for a multi-column right-hand side
/// (lower-triangular L, B overwritten column-block-wise).
pub fn trsm_left_lower(l: &Matrix, b: &Matrix) -> Matrix {
    let n = l.rows();
    assert!(l.is_square() && b.rows() == n);
    let ncols = b.cols();
    let mut x = b.clone();
    for i in 0..n {
        let lii = l[(i, i)];
        // x[i,:] = (b[i,:] - Σ_{k<i} L[i,k]·x[k,:]) / L[i,i]
        for k in 0..i {
            let lik = l[(i, k)];
            if lik == 0.0 {
                continue;
            }
            let (xk, xi) = x.two_rows_mut(k, i);
            for c in 0..ncols {
                xi[c] -= lik * xk[c];
            }
        }
        for v in x.row_mut(i) {
            *v /= lii;
        }
    }
    x
}

/// Solve `Lᵀ X = B` for a multi-column RHS.
pub fn trsm_left_lower_t(l: &Matrix, b: &Matrix) -> Matrix {
    let n = l.rows();
    assert!(l.is_square() && b.rows() == n);
    let ncols = b.cols();
    let mut x = b.clone();
    for i in (0..n).rev() {
        let lii = l[(i, i)];
        for v in x.row_mut(i) {
            *v /= lii;
        }
        let lrow = l.row(i).to_vec();
        for k in 0..i {
            let lik = lrow[k];
            if lik == 0.0 {
                continue;
            }
            let (xk, xi) = x.two_rows_mut(k, i);
            for c in 0..ncols {
                xk[c] -= lik * xi[c];
            }
        }
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::cholesky::cholesky_blocked;
    use crate::linalg::gemm::{gemm, gemv};
    use crate::testutil::{random_matrix, random_spd};

    #[test]
    fn trsv_lower_solves() {
        let a = random_spd(20, 1e3, 1);
        let l = cholesky_blocked(&a).unwrap();
        let b: Vec<f64> = (0..20).map(|i| (i as f64).cos()).collect();
        let w = trsv_lower(&l, &b);
        let lb = gemv(&l, &w);
        for (x, y) in lb.iter().zip(&b) {
            assert!((x - y).abs() < 1e-10);
        }
    }

    #[test]
    fn trsv_upper_solves() {
        let a = random_spd(20, 1e3, 2);
        let l = cholesky_blocked(&a).unwrap();
        let b: Vec<f64> = (0..20).map(|i| (i as f64).sin()).collect();
        let x = trsv_upper(&l, &b);
        let ltx = gemv(&l.transpose(), &x);
        for (p, q) in ltx.iter().zip(&b) {
            assert!((p - q).abs() < 1e-10);
        }
    }

    #[test]
    fn solve_cholesky_residual() {
        let a = random_spd(50, 1e5, 3);
        let l = cholesky_blocked(&a).unwrap();
        let g: Vec<f64> = (0..50).map(|i| ((i * 7 % 13) as f64) - 6.0).collect();
        let th = solve_cholesky(&l, &g);
        let ath = gemv(&a, &th);
        let res: f64 = ath.iter().zip(&g).map(|(x, y)| (x - y).powi(2)).sum::<f64>().sqrt();
        let gn: f64 = g.iter().map(|x| x * x).sum::<f64>().sqrt();
        assert!(res / gn < 1e-8, "relative residual {}", res / gn);
    }

    #[test]
    fn trsm_matches_columnwise_trsv() {
        let a = random_spd(16, 1e2, 4);
        let l = cholesky_blocked(&a).unwrap();
        let b = random_matrix(16, 5, 5);
        let x = trsm_left_lower(&l, &b);
        for j in 0..5 {
            let bj = b.col(j);
            let xj = trsv_lower(&l, &bj);
            for i in 0..16 {
                assert!((x[(i, j)] - xj[i]).abs() < 1e-10);
            }
        }
        let xt = trsm_left_lower_t(&l, &b);
        for j in 0..5 {
            let bj = b.col(j);
            let xj = trsv_upper(&l, &bj);
            for i in 0..16 {
                assert!((xt[(i, j)] - xj[i]).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn trsm_reconstruction() {
        let a = random_spd(12, 1e2, 6);
        let l = cholesky_blocked(&a).unwrap();
        let b = random_matrix(12, 3, 7);
        let x = trsm_left_lower(&l, &b);
        let lb = gemm(&l, &x);
        assert!(lb.max_abs_diff(&b) < 1e-10);
    }
}
