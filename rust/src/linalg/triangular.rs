//! Triangular solves: the per-λ request-path operation (paper §3.2).
//!
//! Once a factor L (exact or interpolated) is in hand, solving
//! `L Lᵀ θ = g` is a forward substitution followed by a backward one —
//! `O(d²)` each, which is exactly why interpolating L (instead of the
//! solution θ) preserves the cheap per-λ cost structure. The `_into`
//! variants write into caller-provided buffers (the per-worker
//! [`super::scratch::Scratch`] arena on the sweep hot path) so the
//! steady-state grid tasks solve with zero heap allocation.
//!
//! [`trsm_right_lower_t_inplace`] is the factorization-side TRSM: the
//! `L21 = A21·L11⁻ᵀ` panel solve of the blocked Cholesky, column-blocked so
//! the bulk of its work is GEMM-shaped updates routed through the packed
//! micro-kernel engine.

use super::kernel::{self, Acc, Src};
use super::matrix::Matrix;

/// Forward substitution: solve `L w = b` for lower-triangular L.
pub fn trsv_lower(l: &Matrix, b: &[f64]) -> Vec<f64> {
    let mut w = Vec::new();
    trsv_lower_into(l, b, &mut w);
    w
}

/// Forward substitution into a caller-provided buffer (resized to `n`; no
/// allocation once warm).
pub fn trsv_lower_into(l: &Matrix, b: &[f64], w: &mut Vec<f64>) {
    let n = l.rows();
    assert!(l.is_square() && b.len() == n);
    w.clear();
    w.resize(n, 0.0);
    for i in 0..n {
        let row = l.row(i);
        let mut s = b[i];
        // contiguous dot over the already-solved prefix
        for (x, y) in row[..i].iter().zip(&w[..i]) {
            s -= x * y;
        }
        w[i] = s / row[i];
    }
}

/// Backward substitution: solve `Lᵀ x = b` given lower-triangular L
/// (reads L column-wise, i.e. Lᵀ row-wise).
pub fn trsv_upper(l: &Matrix, b: &[f64]) -> Vec<f64> {
    let mut x = Vec::new();
    trsv_upper_into(l, b, &mut x);
    x
}

/// Backward substitution into a caller-provided buffer (no allocation once
/// warm).
pub fn trsv_upper_into(l: &Matrix, b: &[f64], x: &mut Vec<f64>) {
    let n = l.rows();
    assert!(l.is_square() && b.len() == n);
    x.clear();
    x.extend_from_slice(b);
    for i in (0..n).rev() {
        let xi = x[i] / l[(i, i)];
        x[i] = xi;
        // eliminate xi from all earlier equations: x[k] -= L[i][k] * xi
        let row = l.row(i);
        for (xk, &lik) in x[..i].iter_mut().zip(row) {
            *xk -= lik * xi;
        }
    }
}

/// Solve `L Lᵀ θ = g` — the complete per-λ ridge solve.
pub fn solve_cholesky(l: &Matrix, g: &[f64]) -> Vec<f64> {
    let mut w = Vec::new();
    let mut x = Vec::new();
    solve_cholesky_into(l, g, &mut w, &mut x);
    x
}

/// `L Lᵀ θ = g` into caller-provided buffers: `work` receives the forward
/// intermediate, `theta` the solution. Zero allocation once both are warm —
/// this is what the sweep engine's grid tasks call with their worker's
/// [`super::scratch::Scratch`].
pub fn solve_cholesky_into(l: &Matrix, g: &[f64], work: &mut Vec<f64>, theta: &mut Vec<f64>) {
    trsv_lower_into(l, g, work);
    trsv_upper_into(l, work, theta);
}

/// Block TRSM: solve `L X = B` for a multi-column right-hand side.
/// Allocating convenience wrapper over [`trsm_left_lower_into`].
pub fn trsm_left_lower(l: &Matrix, b: &Matrix) -> Matrix {
    let mut x = Matrix::zeros(0, 0);
    trsm_left_lower_into(l, b, &mut x);
    x
}

/// Blocked left-side TRSM: solve `L X = B` for a multi-column RHS into a
/// caller-provided buffer (resized/overwritten; no allocation once warm).
/// This is the ALOOCV hot path: with `B = Xᵀ` a `d×b` gather of data rows,
/// `X = L⁻¹Xᵀ` yields every hat diagonal of the block as a squared column
/// norm — one call replaces `b` separate forward substitutions.
///
/// Row-panelled at `TRSM_TB` (32): for each row panel `rb..re`, the
/// contribution of the already-solved rows is one GEMM-shaped update
/// (`L[rb..re, 0..rb] · W[0..rb, :]`) routed through the packed micro-kernel
/// into the per-thread output panel and subtracted row-wise; only the small
/// diagonal triangle is solved by scalar forward substitution in the exact
/// [`trsv_lower`] recurrence order.
///
/// **Bitwise contract** (mirrors [`trsm_right_lower_t_inplace`]):
///
/// - *Column-partition independent, bitwise*: each output column's
///   arithmetic touches only that column of B (the packed updates accumulate
///   per element in fixed ascending-k order — see [`super::kernel`] — and the
///   substitution triangle is columnwise-independent). Solving any disjoint
///   column blocks of B in separate calls, on any worker, reproduces the
///   whole-call bits exactly; the sweep engine's per-batch hat solves rely on
///   this for worker-count invariance.
/// - *trsv-exact for single-panel problems (`n ≤ 32`)*: there is no GEMM
///   stage and each column is the [`trsv_lower_into`] recurrence verbatim.
///   Beyond one panel the trailing update subtracts a pre-rounded sum where
///   trsv subtracts term-by-term, so cross-panel agreement with the oracle is
///   to rounding (≈1e-13 relative), not bitwise — the property tests pin both
///   halves of this contract.
pub fn trsm_left_lower_into(l: &Matrix, b: &Matrix, out: &mut Matrix) {
    let n = l.rows();
    assert!(l.is_square() && b.rows() == n);
    let ncols = b.cols();
    out.copy_from(b);
    if n == 0 || ncols == 0 {
        return;
    }
    let ld = l.as_slice();
    for rb in (0..n).step_by(TRSM_TB) {
        let re = (rb + TRSM_TB).min(n);
        let m = re - rb;
        if rb > 0 {
            // W[rb..re, :] -= L[rb..re, 0..rb] · W[0..rb, :]
            kernel::with_tmp(m * ncols, |tmp| {
                kernel::gemm_into(
                    m,
                    ncols,
                    rb,
                    Src::N {
                        data: ld,
                        stride: n,
                        r0: rb,
                        c0: 0,
                    },
                    Src::N {
                        data: out.as_slice(),
                        stride: ncols,
                        r0: 0,
                        c0: 0,
                    },
                    tmp,
                    ncols,
                    0,
                    0,
                    Acc::Set,
                );
                let data = out.as_mut_slice();
                for i in 0..m {
                    let dst = &mut data[(rb + i) * ncols..][..ncols];
                    for (d, &u) in dst.iter_mut().zip(&tmp[i * ncols..(i + 1) * ncols]) {
                        *d -= u;
                    }
                }
            });
        }
        // scalar forward substitution on the diagonal triangle: per column,
        // terms are subtracted one by one in ascending k — the trsv_lower
        // association exactly.
        let data = out.as_mut_slice();
        for i in rb..re {
            let lrow = &ld[i * n..i * n + i];
            let (solved, rest) = data.split_at_mut(i * ncols);
            let wi = &mut rest[..ncols];
            for k in rb..i {
                let lik = lrow[k];
                let wk = &solved[k * ncols..(k + 1) * ncols];
                for (d, &u) in wi.iter_mut().zip(wk) {
                    *d -= lik * u;
                }
            }
            let lii = ld[i * n + i];
            for v in wi.iter_mut() {
                *v /= lii;
            }
        }
    }
}

/// Solve `Lᵀ X = B` for a multi-column RHS.
pub fn trsm_left_lower_t(l: &Matrix, b: &Matrix) -> Matrix {
    let n = l.rows();
    assert!(l.is_square() && b.rows() == n);
    let ncols = b.cols();
    let mut x = b.clone();
    for i in (0..n).rev() {
        let lii = l[(i, i)];
        for v in x.row_mut(i) {
            *v /= lii;
        }
        let lrow = l.row(i).to_vec();
        for k in 0..i {
            let lik = lrow[k];
            if lik == 0.0 {
                continue;
            }
            let (xk, xi) = x.two_rows_mut(k, i);
            for c in 0..ncols {
                xk[c] -= lik * xi[c];
            }
        }
    }
    x
}

/// Panel width of the blocked TRSMs (column blocks of the right-TRSM, row
/// panels of the left-TRSM): the substitution triangle stays this small
/// while everything outside it is GEMM-shaped.
const TRSM_TB: usize = 32;

/// Blocked right-side TRSM: solve `X · Lᵀ = B` **in place** over the row
/// block `rows r0..r1` of `a`, where X/B occupy columns `c0..c0+l.rows()`
/// and `l` is the lower-triangular diagonal panel (the Cholesky L11).
///
/// Column-blocked at `TRSM_TB` (32): for each column block, the contribution of
/// the already-solved columns is one GEMM-shaped update
/// (`X[:, solved] · L[block, solved]ᵀ`) routed through the packed
/// micro-kernel into the per-thread output panel and subtracted row-wise;
/// only the small remaining triangle is solved by scalar forward
/// substitution on row slices. This replaces the previous all-scalar
/// bounds-checked triple loop — for a `b`-wide panel, `(TB/b)`-fraction of
/// the flops stay scalar and the rest run at micro-kernel speed.
///
/// **Row-partition independent, bitwise**: each row's arithmetic touches
/// only that row and `l`, the column blocking depends only on `l.rows()`,
/// and the packed updates accumulate per element in fixed ascending-k order
/// (see [`super::kernel`]). Solving `r0..r1` in one call or as any set of
/// disjoint sub-ranges produces identical bits — the pooled Cholesky's TRSM
/// tiles rely on this to match the serial factorization exactly.
pub fn trsm_right_lower_t_inplace(a: &mut Matrix, r0: usize, r1: usize, c0: usize, l: &Matrix) {
    let nb = l.rows();
    debug_assert!(l.is_square());
    assert!(r1 <= a.rows() && c0 + nb <= a.cols() && r0 <= r1);
    if r0 == r1 || nb == 0 {
        return;
    }
    let stride = a.cols();
    let m = r1 - r0;
    for cb in (0..nb).step_by(TRSM_TB) {
        let ce = (cb + TRSM_TB).min(nb);
        let w = ce - cb;
        if cb > 0 {
            // A[r0..r1, c0+cb..c0+ce] -= X[r0..r1, c0..c0+cb] · L[cb..ce, 0..cb]ᵀ
            kernel::with_tmp(m * w, |tmp| {
                kernel::gemm_into(
                    m,
                    w,
                    cb,
                    Src::N {
                        data: a.as_slice(),
                        stride,
                        r0,
                        c0,
                    },
                    Src::T {
                        data: l.as_slice(),
                        stride: nb,
                        r0: cb,
                        c0: 0,
                    },
                    tmp,
                    w,
                    0,
                    0,
                    Acc::Set,
                );
                let data = a.as_mut_slice();
                for i in 0..m {
                    let dst = &mut data[(r0 + i) * stride + c0 + cb..][..w];
                    for (d, &u) in dst.iter_mut().zip(&tmp[i * w..(i + 1) * w]) {
                        *d -= u;
                    }
                }
            });
        }
        // scalar forward substitution on the small triangle, row slices only
        let data = a.as_mut_slice();
        let ld = l.as_slice();
        for i in 0..m {
            let row = &mut data[(r0 + i) * stride + c0..][..ce];
            for j in cb..ce {
                let lrow = &ld[j * nb..j * nb + j];
                let mut s = row[j];
                for (x, y) in row[cb..j].iter().zip(&lrow[cb..]) {
                    s -= x * y;
                }
                row[j] = s / ld[j * nb + j];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::cholesky::cholesky_blocked;
    use crate::linalg::gemm::{gemm, gemv};
    use crate::testutil::{random_matrix, random_spd};

    #[test]
    fn trsv_lower_solves() {
        let a = random_spd(20, 1e3, 1);
        let l = cholesky_blocked(&a).unwrap();
        let b: Vec<f64> = (0..20).map(|i| (i as f64).cos()).collect();
        let w = trsv_lower(&l, &b);
        let lb = gemv(&l, &w);
        for (x, y) in lb.iter().zip(&b) {
            assert!((x - y).abs() < 1e-10);
        }
    }

    #[test]
    fn trsv_upper_solves() {
        let a = random_spd(20, 1e3, 2);
        let l = cholesky_blocked(&a).unwrap();
        let b: Vec<f64> = (0..20).map(|i| (i as f64).sin()).collect();
        let x = trsv_upper(&l, &b);
        let ltx = gemv(&l.transpose(), &x);
        for (p, q) in ltx.iter().zip(&b) {
            assert!((p - q).abs() < 1e-10);
        }
    }

    #[test]
    fn solve_cholesky_residual() {
        let a = random_spd(50, 1e5, 3);
        let l = cholesky_blocked(&a).unwrap();
        let g: Vec<f64> = (0..50).map(|i| ((i * 7 % 13) as f64) - 6.0).collect();
        let th = solve_cholesky(&l, &g);
        let ath = gemv(&a, &th);
        let res: f64 = ath.iter().zip(&g).map(|(x, y)| (x - y).powi(2)).sum::<f64>().sqrt();
        let gn: f64 = g.iter().map(|x| x * x).sum::<f64>().sqrt();
        assert!(res / gn < 1e-8, "relative residual {}", res / gn);
    }

    #[test]
    fn solve_into_matches_allocating_bitwise() {
        let a = random_spd(30, 1e4, 9);
        let l = cholesky_blocked(&a).unwrap();
        let g: Vec<f64> = (0..30).map(|i| (i as f64 * 0.3).sin()).collect();
        let fresh = solve_cholesky(&l, &g);
        // pre-dirtied, wrong-sized buffers must converge to the same bits
        let mut w = vec![f64::NAN; 7];
        let mut th = vec![f64::NAN; 91];
        solve_cholesky_into(&l, &g, &mut w, &mut th);
        assert_eq!(th, fresh);
    }

    #[test]
    fn trsm_matches_columnwise_trsv() {
        let a = random_spd(16, 1e2, 4);
        let l = cholesky_blocked(&a).unwrap();
        let b = random_matrix(16, 5, 5);
        let x = trsm_left_lower(&l, &b);
        for j in 0..5 {
            let bj = b.col(j);
            let xj = trsv_lower(&l, &bj);
            for i in 0..16 {
                assert!((x[(i, j)] - xj[i]).abs() < 1e-10);
            }
        }
        let xt = trsm_left_lower_t(&l, &b);
        for j in 0..5 {
            let bj = b.col(j);
            let xj = trsv_upper(&l, &bj);
            for i in 0..16 {
                assert!((xt[(i, j)] - xj[i]).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn trsm_reconstruction() {
        let a = random_spd(12, 1e2, 6);
        let l = cholesky_blocked(&a).unwrap();
        let b = random_matrix(12, 3, 7);
        let x = trsm_left_lower(&l, &b);
        let lb = gemm(&l, &x);
        assert!(lb.max_abs_diff(&b) < 1e-10);
    }

    /// Single-panel shapes (n ≤ TRSM_TB, plus the MR-degenerate sizes 1 and
    /// MR−1 = 3): the blocked left-TRSM never engages the GEMM stage, so
    /// every column must reproduce the `trsv_lower` oracle **bitwise**.
    #[test]
    fn left_trsm_bitwise_matches_trsv_on_single_panel_shapes() {
        for n in [1, 3, 17, 32] {
            let spd = random_spd(n, 1e3, 90 + n as u64);
            let l = cholesky_blocked(&spd).unwrap();
            for ncols in [1, 3, 9] {
                let b = random_matrix(n, ncols, 91 + (n * ncols) as u64);
                let x = trsm_left_lower(&l, &b);
                for j in 0..ncols {
                    let xj = trsv_lower(&l, &b.col(j));
                    for i in 0..n {
                        assert_eq!(x[(i, j)], xj[i], "n={n} ncols={ncols} ({i},{j})");
                    }
                }
            }
        }
    }

    /// Multi-panel shapes bracketing the kernel's k-chunk (KC ± 1 = 255,
    /// 257): the GEMM trailing update subtracts a pre-rounded sum where trsv
    /// subtracts term-by-term, so the oracle is matched to rounding — but any
    /// **column partition** of B must reproduce the whole-call bits exactly
    /// (the worker-invariance contract of the batched hat solves).
    #[test]
    fn left_trsm_is_column_partition_independent_bitwise() {
        for n in [255usize, 257] {
            let spd = random_spd(n, 1e3, 100 + n as u64);
            let l = cholesky_blocked(&spd).unwrap();
            let ncols = 10;
            let b = random_matrix(n, ncols, 101 + n as u64);
            let whole = trsm_left_lower(&l, &b);

            // L · X must reconstruct B
            let rec = gemm(&l, &whole);
            assert!(rec.max_abs_diff(&b) < 1e-8, "n={n}");

            // oracle agreement to rounding, columnwise
            for j in 0..ncols {
                let xj = trsv_lower(&l, &b.col(j));
                for i in 0..n {
                    assert!((whole[(i, j)] - xj[i]).abs() < 1e-8, "n={n} ({i},{j})");
                }
            }

            // any column partition reproduces the exact bits
            for splits in [vec![0, ncols], vec![0, 1, ncols], vec![0, 3, 7, ncols]] {
                for win in splits.windows(2) {
                    let part = trsm_left_lower(&l, &b.slice(0, n, win[0], win[1]));
                    for i in 0..n {
                        for j in win[0]..win[1] {
                            assert_eq!(
                                part[(i, j - win[0])],
                                whole[(i, j)],
                                "n={n} splits={splits:?} ({i},{j})"
                            );
                        }
                    }
                }
            }
        }
    }

    /// The `_into` form must converge to the same bits from a pre-dirtied,
    /// wrong-sized output buffer, and tolerate degenerate shapes.
    #[test]
    fn left_trsm_into_reuses_dirty_buffer_bitwise() {
        let spd = random_spd(40, 1e3, 110);
        let l = cholesky_blocked(&spd).unwrap();
        let b = random_matrix(40, 6, 111);
        let fresh = trsm_left_lower(&l, &b);
        let mut dirty = Matrix::zeros(3, 17);
        for v in dirty.as_mut_slice() {
            *v = f64::NAN;
        }
        trsm_left_lower_into(&l, &b, &mut dirty);
        assert_eq!(dirty.as_slice(), fresh.as_slice());

        // zero-column RHS: legal, produces a 40×0 result
        let empty = trsm_left_lower(&l, &Matrix::zeros(40, 0));
        assert_eq!(empty.rows(), 40);
        assert_eq!(empty.cols(), 0);
    }

    /// The factorization-side TRSM solves X·L11ᵀ = B: verify against L
    /// applied from the right.
    #[test]
    fn right_trsm_solves_and_is_row_partition_independent() {
        for nb in [1, 7, 32, 51] {
            let spd = random_spd(nb, 1e3, 40 + nb as u64);
            let l = cholesky_blocked(&spd).unwrap();
            let b = random_matrix(60, nb, 41 + nb as u64);

            let mut whole = b.clone();
            trsm_right_lower_t_inplace(&mut whole, 0, 60, 0, &l);

            // X · Lᵀ must reconstruct B
            let rec = gemm(&whole, &l.transpose());
            assert!(rec.max_abs_diff(&b) < 1e-8, "nb={nb}");

            // any row partition reproduces the exact bits
            for splits in [vec![0, 60], vec![0, 1, 60], vec![0, 13, 29, 44, 60]] {
                let mut parts = b.clone();
                for win in splits.windows(2) {
                    trsm_right_lower_t_inplace(&mut parts, win[0], win[1], 0, &l);
                }
                // slice equality is NaN-propagating (max_abs_diff is not)
                assert_eq!(parts.as_slice(), whole.as_slice(), "nb={nb} splits={splits:?}");
            }
        }
    }

    /// The column-offset form (solving inside a wider matrix, as the blocked
    /// Cholesky does) must match the compact form bitwise.
    #[test]
    fn right_trsm_column_offset_matches_compact() {
        let nb = 24;
        let spd = random_spd(nb, 1e3, 77);
        let l = cholesky_blocked(&spd).unwrap();
        let wide = random_matrix(30, 40, 78);

        let mut compact = wide.slice(0, 30, 9, 9 + nb);
        trsm_right_lower_t_inplace(&mut compact, 0, 30, 0, &l);

        let mut inplace = wide.clone();
        trsm_right_lower_t_inplace(&mut inplace, 0, 30, 9, &l);
        for i in 0..30 {
            for j in 0..nb {
                assert_eq!(inplace[(i, 9 + j)], compact[(i, j)]);
            }
        }
        // columns outside the panel untouched
        for i in 0..30 {
            for j in 0..9 {
                assert_eq!(inplace[(i, j)], wide[(i, j)]);
            }
            for j in 9 + nb..40 {
                assert_eq!(inplace[(i, j)], wide[(i, j)]);
            }
        }
    }
}
