//! Triangular solves: the per-λ request-path operation (paper §3.2).
//!
//! Once a factor L (exact or interpolated) is in hand, solving
//! `L Lᵀ θ = g` is a forward substitution followed by a backward one —
//! `O(d²)` each, which is exactly why interpolating L (instead of the
//! solution θ) preserves the cheap per-λ cost structure. The `_into`
//! variants write into caller-provided buffers (the per-worker
//! [`super::scratch::Scratch`] arena on the sweep hot path) so the
//! steady-state grid tasks solve with zero heap allocation.
//!
//! [`trsm_right_lower_t_inplace`] is the factorization-side TRSM: the
//! `L21 = A21·L11⁻ᵀ` panel solve of the blocked Cholesky, column-blocked so
//! the bulk of its work is GEMM-shaped updates routed through the packed
//! micro-kernel engine.

use super::kernel::{self, Acc, Src};
use super::matrix::Matrix;

/// Forward substitution: solve `L w = b` for lower-triangular L.
pub fn trsv_lower(l: &Matrix, b: &[f64]) -> Vec<f64> {
    let mut w = Vec::new();
    trsv_lower_into(l, b, &mut w);
    w
}

/// Forward substitution into a caller-provided buffer (resized to `n`; no
/// allocation once warm).
pub fn trsv_lower_into(l: &Matrix, b: &[f64], w: &mut Vec<f64>) {
    let n = l.rows();
    assert!(l.is_square() && b.len() == n);
    w.clear();
    w.resize(n, 0.0);
    for i in 0..n {
        let row = l.row(i);
        let mut s = b[i];
        // contiguous dot over the already-solved prefix
        for (x, y) in row[..i].iter().zip(&w[..i]) {
            s -= x * y;
        }
        w[i] = s / row[i];
    }
}

/// Backward substitution: solve `Lᵀ x = b` given lower-triangular L
/// (reads L column-wise, i.e. Lᵀ row-wise).
pub fn trsv_upper(l: &Matrix, b: &[f64]) -> Vec<f64> {
    let mut x = Vec::new();
    trsv_upper_into(l, b, &mut x);
    x
}

/// Backward substitution into a caller-provided buffer (no allocation once
/// warm).
pub fn trsv_upper_into(l: &Matrix, b: &[f64], x: &mut Vec<f64>) {
    let n = l.rows();
    assert!(l.is_square() && b.len() == n);
    x.clear();
    x.extend_from_slice(b);
    for i in (0..n).rev() {
        let xi = x[i] / l[(i, i)];
        x[i] = xi;
        // eliminate xi from all earlier equations: x[k] -= L[i][k] * xi
        let row = l.row(i);
        for (xk, &lik) in x[..i].iter_mut().zip(row) {
            *xk -= lik * xi;
        }
    }
}

/// Solve `L Lᵀ θ = g` — the complete per-λ ridge solve.
pub fn solve_cholesky(l: &Matrix, g: &[f64]) -> Vec<f64> {
    let mut w = Vec::new();
    let mut x = Vec::new();
    solve_cholesky_into(l, g, &mut w, &mut x);
    x
}

/// `L Lᵀ θ = g` into caller-provided buffers: `work` receives the forward
/// intermediate, `theta` the solution. Zero allocation once both are warm —
/// this is what the sweep engine's grid tasks call with their worker's
/// [`super::scratch::Scratch`].
pub fn solve_cholesky_into(l: &Matrix, g: &[f64], work: &mut Vec<f64>, theta: &mut Vec<f64>) {
    trsv_lower_into(l, g, work);
    trsv_upper_into(l, work, theta);
}

/// Block TRSM: solve `L X = B` for a multi-column right-hand side
/// (lower-triangular L, B overwritten column-block-wise).
pub fn trsm_left_lower(l: &Matrix, b: &Matrix) -> Matrix {
    let n = l.rows();
    assert!(l.is_square() && b.rows() == n);
    let ncols = b.cols();
    let mut x = b.clone();
    for i in 0..n {
        let lii = l[(i, i)];
        // x[i,:] = (b[i,:] - Σ_{k<i} L[i,k]·x[k,:]) / L[i,i]
        for k in 0..i {
            let lik = l[(i, k)];
            if lik == 0.0 {
                continue;
            }
            let (xk, xi) = x.two_rows_mut(k, i);
            for c in 0..ncols {
                xi[c] -= lik * xk[c];
            }
        }
        for v in x.row_mut(i) {
            *v /= lii;
        }
    }
    x
}

/// Solve `Lᵀ X = B` for a multi-column RHS.
pub fn trsm_left_lower_t(l: &Matrix, b: &Matrix) -> Matrix {
    let n = l.rows();
    assert!(l.is_square() && b.rows() == n);
    let ncols = b.cols();
    let mut x = b.clone();
    for i in (0..n).rev() {
        let lii = l[(i, i)];
        for v in x.row_mut(i) {
            *v /= lii;
        }
        let lrow = l.row(i).to_vec();
        for k in 0..i {
            let lik = lrow[k];
            if lik == 0.0 {
                continue;
            }
            let (xk, xi) = x.two_rows_mut(k, i);
            for c in 0..ncols {
                xk[c] -= lik * xi[c];
            }
        }
    }
    x
}

/// Column block width of the blocked right-TRSM: the substitution triangle
/// stays this small while everything left of it is GEMM-shaped.
const TRSM_TB: usize = 32;

/// Blocked right-side TRSM: solve `X · Lᵀ = B` **in place** over the row
/// block `rows r0..r1` of `a`, where X/B occupy columns `c0..c0+l.rows()`
/// and `l` is the lower-triangular diagonal panel (the Cholesky L11).
///
/// Column-blocked at `TRSM_TB` (32): for each column block, the contribution of
/// the already-solved columns is one GEMM-shaped update
/// (`X[:, solved] · L[block, solved]ᵀ`) routed through the packed
/// micro-kernel into the per-thread output panel and subtracted row-wise;
/// only the small remaining triangle is solved by scalar forward
/// substitution on row slices. This replaces the previous all-scalar
/// bounds-checked triple loop — for a `b`-wide panel, `(TB/b)`-fraction of
/// the flops stay scalar and the rest run at micro-kernel speed.
///
/// **Row-partition independent, bitwise**: each row's arithmetic touches
/// only that row and `l`, the column blocking depends only on `l.rows()`,
/// and the packed updates accumulate per element in fixed ascending-k order
/// (see [`super::kernel`]). Solving `r0..r1` in one call or as any set of
/// disjoint sub-ranges produces identical bits — the pooled Cholesky's TRSM
/// tiles rely on this to match the serial factorization exactly.
pub fn trsm_right_lower_t_inplace(a: &mut Matrix, r0: usize, r1: usize, c0: usize, l: &Matrix) {
    let nb = l.rows();
    debug_assert!(l.is_square());
    assert!(r1 <= a.rows() && c0 + nb <= a.cols() && r0 <= r1);
    if r0 == r1 || nb == 0 {
        return;
    }
    let stride = a.cols();
    let m = r1 - r0;
    for cb in (0..nb).step_by(TRSM_TB) {
        let ce = (cb + TRSM_TB).min(nb);
        let w = ce - cb;
        if cb > 0 {
            // A[r0..r1, c0+cb..c0+ce] -= X[r0..r1, c0..c0+cb] · L[cb..ce, 0..cb]ᵀ
            kernel::with_tmp(m * w, |tmp| {
                kernel::gemm_into(
                    m,
                    w,
                    cb,
                    Src::N {
                        data: a.as_slice(),
                        stride,
                        r0,
                        c0,
                    },
                    Src::T {
                        data: l.as_slice(),
                        stride: nb,
                        r0: cb,
                        c0: 0,
                    },
                    tmp,
                    w,
                    0,
                    0,
                    Acc::Set,
                );
                let data = a.as_mut_slice();
                for i in 0..m {
                    let dst = &mut data[(r0 + i) * stride + c0 + cb..][..w];
                    for (d, &u) in dst.iter_mut().zip(&tmp[i * w..(i + 1) * w]) {
                        *d -= u;
                    }
                }
            });
        }
        // scalar forward substitution on the small triangle, row slices only
        let data = a.as_mut_slice();
        let ld = l.as_slice();
        for i in 0..m {
            let row = &mut data[(r0 + i) * stride + c0..][..ce];
            for j in cb..ce {
                let lrow = &ld[j * nb..j * nb + j];
                let mut s = row[j];
                for (x, y) in row[cb..j].iter().zip(&lrow[cb..]) {
                    s -= x * y;
                }
                row[j] = s / ld[j * nb + j];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::cholesky::cholesky_blocked;
    use crate::linalg::gemm::{gemm, gemv};
    use crate::testutil::{random_matrix, random_spd};

    #[test]
    fn trsv_lower_solves() {
        let a = random_spd(20, 1e3, 1);
        let l = cholesky_blocked(&a).unwrap();
        let b: Vec<f64> = (0..20).map(|i| (i as f64).cos()).collect();
        let w = trsv_lower(&l, &b);
        let lb = gemv(&l, &w);
        for (x, y) in lb.iter().zip(&b) {
            assert!((x - y).abs() < 1e-10);
        }
    }

    #[test]
    fn trsv_upper_solves() {
        let a = random_spd(20, 1e3, 2);
        let l = cholesky_blocked(&a).unwrap();
        let b: Vec<f64> = (0..20).map(|i| (i as f64).sin()).collect();
        let x = trsv_upper(&l, &b);
        let ltx = gemv(&l.transpose(), &x);
        for (p, q) in ltx.iter().zip(&b) {
            assert!((p - q).abs() < 1e-10);
        }
    }

    #[test]
    fn solve_cholesky_residual() {
        let a = random_spd(50, 1e5, 3);
        let l = cholesky_blocked(&a).unwrap();
        let g: Vec<f64> = (0..50).map(|i| ((i * 7 % 13) as f64) - 6.0).collect();
        let th = solve_cholesky(&l, &g);
        let ath = gemv(&a, &th);
        let res: f64 = ath.iter().zip(&g).map(|(x, y)| (x - y).powi(2)).sum::<f64>().sqrt();
        let gn: f64 = g.iter().map(|x| x * x).sum::<f64>().sqrt();
        assert!(res / gn < 1e-8, "relative residual {}", res / gn);
    }

    #[test]
    fn solve_into_matches_allocating_bitwise() {
        let a = random_spd(30, 1e4, 9);
        let l = cholesky_blocked(&a).unwrap();
        let g: Vec<f64> = (0..30).map(|i| (i as f64 * 0.3).sin()).collect();
        let fresh = solve_cholesky(&l, &g);
        // pre-dirtied, wrong-sized buffers must converge to the same bits
        let mut w = vec![f64::NAN; 7];
        let mut th = vec![f64::NAN; 91];
        solve_cholesky_into(&l, &g, &mut w, &mut th);
        assert_eq!(th, fresh);
    }

    #[test]
    fn trsm_matches_columnwise_trsv() {
        let a = random_spd(16, 1e2, 4);
        let l = cholesky_blocked(&a).unwrap();
        let b = random_matrix(16, 5, 5);
        let x = trsm_left_lower(&l, &b);
        for j in 0..5 {
            let bj = b.col(j);
            let xj = trsv_lower(&l, &bj);
            for i in 0..16 {
                assert!((x[(i, j)] - xj[i]).abs() < 1e-10);
            }
        }
        let xt = trsm_left_lower_t(&l, &b);
        for j in 0..5 {
            let bj = b.col(j);
            let xj = trsv_upper(&l, &bj);
            for i in 0..16 {
                assert!((xt[(i, j)] - xj[i]).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn trsm_reconstruction() {
        let a = random_spd(12, 1e2, 6);
        let l = cholesky_blocked(&a).unwrap();
        let b = random_matrix(12, 3, 7);
        let x = trsm_left_lower(&l, &b);
        let lb = gemm(&l, &x);
        assert!(lb.max_abs_diff(&b) < 1e-10);
    }

    /// The factorization-side TRSM solves X·L11ᵀ = B: verify against L
    /// applied from the right.
    #[test]
    fn right_trsm_solves_and_is_row_partition_independent() {
        for nb in [1, 7, 32, 51] {
            let spd = random_spd(nb, 1e3, 40 + nb as u64);
            let l = cholesky_blocked(&spd).unwrap();
            let b = random_matrix(60, nb, 41 + nb as u64);

            let mut whole = b.clone();
            trsm_right_lower_t_inplace(&mut whole, 0, 60, 0, &l);

            // X · Lᵀ must reconstruct B
            let rec = gemm(&whole, &l.transpose());
            assert!(rec.max_abs_diff(&b) < 1e-8, "nb={nb}");

            // any row partition reproduces the exact bits
            for splits in [vec![0, 60], vec![0, 1, 60], vec![0, 13, 29, 44, 60]] {
                let mut parts = b.clone();
                for win in splits.windows(2) {
                    trsm_right_lower_t_inplace(&mut parts, win[0], win[1], 0, &l);
                }
                // slice equality is NaN-propagating (max_abs_diff is not)
                assert_eq!(parts.as_slice(), whole.as_slice(), "nb={nb} splits={splits:?}");
            }
        }
    }

    /// The column-offset form (solving inside a wider matrix, as the blocked
    /// Cholesky does) must match the compact form bitwise.
    #[test]
    fn right_trsm_column_offset_matches_compact() {
        let nb = 24;
        let spd = random_spd(nb, 1e3, 77);
        let l = cholesky_blocked(&spd).unwrap();
        let wide = random_matrix(30, 40, 78);

        let mut compact = wide.slice(0, 30, 9, 9 + nb);
        trsm_right_lower_t_inplace(&mut compact, 0, 30, 0, &l);

        let mut inplace = wide.clone();
        trsm_right_lower_t_inplace(&mut inplace, 0, 30, 9, &l);
        for i in 0..30 {
            for j in 0..nb {
                assert_eq!(inplace[(i, 9 + j)], compact[(i, j)]);
            }
        }
        // columns outside the panel untouched
        for i in 0..30 {
            for j in 0..9 {
                assert_eq!(inplace[(i, j)], wide[(i, j)]);
            }
            for j in 9 + nb..40 {
                assert_eq!(inplace[(i, j)], wide[(i, j)]);
            }
        }
    }
}
