//! One-sided Jacobi SVD — the paper's `SVD` baseline (§6.2, eq. 11).
//!
//! The paper solves ridge regression for all λ at once from one SVD of the
//! design matrix: `θ = V diag(σᵢ/(σᵢ²+λ)) Uᵀ g`. One-sided Jacobi is chosen
//! here because it is simple, numerically excellent (high relative accuracy),
//! and needs no bidiagonalization machinery; its O(n·d²·sweeps) cost is also
//! faithful to the paper's observation that full SVD is ~13× slower than a
//! Cholesky sweep.

use super::matrix::Matrix;

/// Result of a (thin) SVD: `a = U · diag(s) · Vᵀ`.
pub struct Svd {
    /// m×k left singular vectors (columns).
    pub u: Matrix,
    /// Singular values, non-increasing.
    pub s: Vec<f64>,
    /// n×k right singular vectors (columns).
    pub v: Matrix,
}

/// One-sided Jacobi SVD of an m×n matrix (m ≥ n, thin factors, k = n).
///
/// Works on W = A (copy), repeatedly rotating column pairs until all are
/// mutually orthogonal; then `σⱼ = ‖wⱼ‖`, `uⱼ = wⱼ/σⱼ`, and V accumulates
/// the rotations.
pub fn jacobi_svd(a: &Matrix) -> Svd {
    let (m, n) = (a.rows(), a.cols());
    assert!(m >= n, "jacobi_svd expects m >= n (pass the transpose otherwise)");
    // Work in column-major-ish form: w[j] is column j (contiguous for the
    // rotation inner loop).
    let mut w: Vec<Vec<f64>> = (0..n).map(|j| a.col(j)).collect();
    let mut v = Matrix::eye(n);

    let eps = 1e-13;
    let max_sweeps = 60;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                // 2×2 Gram block of columns p,q
                let (mut app, mut aqq, mut apq) = (0.0, 0.0, 0.0);
                for i in 0..m {
                    app += w[p][i] * w[p][i];
                    aqq += w[q][i] * w[q][i];
                    apq += w[p][i] * w[q][i];
                }
                if apq.abs() <= eps * (app * aqq).sqrt() {
                    continue;
                }
                off = off.max(apq.abs() / (app * aqq).sqrt().max(1e-300));
                // Jacobi rotation annihilating apq
                let zeta = (aqq - app) / (2.0 * apq);
                let t = zeta.signum() / (zeta.abs() + (1.0 + zeta * zeta).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                // rotate data columns
                let (wp, wq) = {
                    let (a1, a2) = w.split_at_mut(q);
                    (&mut a1[p], &mut a2[0])
                };
                for i in 0..m {
                    let xp = wp[i];
                    let xq = wq[i];
                    wp[i] = c * xp - s * xq;
                    wq[i] = s * xp + c * xq;
                }
                // rotate V rows correspondingly (V columns p,q)
                for i in 0..n {
                    let vp = v[(i, p)];
                    let vq = v[(i, q)];
                    v[(i, p)] = c * vp - s * vq;
                    v[(i, q)] = s * vp + c * vq;
                }
            }
        }
        if off < 1e-12 {
            break;
        }
    }

    // extract singular values / left vectors
    let mut order: Vec<usize> = (0..n).collect();
    let sigmas: Vec<f64> = w
        .iter()
        .map(|col| col.iter().map(|x| x * x).sum::<f64>().sqrt())
        .collect();
    order.sort_by(|&i, &j| sigmas[j].partial_cmp(&sigmas[i]).unwrap());

    let mut u = Matrix::zeros(m, n);
    let mut vv = Matrix::zeros(n, n);
    let mut s = Vec::with_capacity(n);
    for (dst, &src) in order.iter().enumerate() {
        let sig = sigmas[src];
        s.push(sig);
        let inv = if sig > 0.0 { 1.0 / sig } else { 0.0 };
        for i in 0..m {
            u[(i, dst)] = w[src][i] * inv;
        }
        for i in 0..n {
            vv[(i, dst)] = v[(i, src)];
        }
    }
    Svd { u, s, v: vv }
}

impl Svd {
    /// Ridge solution for one λ: `θ = V diag(σᵢ/(σᵢ²+λ)) Uᵀ y` — the paper's
    /// eq. 11, reusing the factorization across the whole λ sweep.
    pub fn ridge_solve(&self, uty: &[f64], lam: f64) -> Vec<f64> {
        let mut scaled = Vec::new();
        let mut theta = Vec::new();
        self.ridge_solve_into(uty, lam, &mut scaled, &mut theta);
        theta
    }

    /// [`Svd::ridge_solve`] into caller-provided buffers (`scaled` holds the
    /// k-length spectrum reweighting, `theta` the solution) — the sweep hot
    /// path feeds these from the per-worker
    /// [`crate::linalg::scratch::Scratch`], so the eq. 11 λ sweep allocates
    /// nothing per grid point. Bitwise identical to the allocating form.
    pub fn ridge_solve_into(
        &self,
        uty: &[f64],
        lam: f64,
        scaled: &mut Vec<f64>,
        theta: &mut Vec<f64>,
    ) {
        let k = self.s.len();
        assert_eq!(uty.len(), k);
        scaled.clear();
        scaled.extend((0..k).map(|i| {
            let sig = self.s[i];
            uty[i] * sig / (sig * sig + lam)
        }));
        super::gemm::gemv_into(&self.v, scaled, theta);
    }

    /// `Uᵀ y` — computed once per fold, shared across λ's.
    pub fn project_y(&self, y: &[f64]) -> Vec<f64> {
        super::gemm::gemv_t(&self.u, y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{gemm, gemv};
    use crate::testutil::{assert_matrix_close, random_matrix};

    #[test]
    fn reconstructs() {
        let a = random_matrix(20, 8, 1);
        let svd = jacobi_svd(&a);
        let us = Matrix::from_fn(20, 8, |i, j| svd.u[(i, j)] * svd.s[j]);
        let rec = gemm(&us, &svd.v.transpose());
        assert_matrix_close(&rec, &a, 1e-9);
    }

    #[test]
    fn singular_values_sorted_nonincreasing() {
        let a = random_matrix(30, 10, 2);
        let svd = jacobi_svd(&a);
        for i in 1..svd.s.len() {
            assert!(svd.s[i - 1] >= svd.s[i] - 1e-12);
        }
    }

    #[test]
    fn factors_orthonormal() {
        let a = random_matrix(25, 7, 3);
        let svd = jacobi_svd(&a);
        assert_matrix_close(&gemm(&svd.u.transpose(), &svd.u), &Matrix::eye(7), 1e-9);
        assert_matrix_close(&gemm(&svd.v.transpose(), &svd.v), &Matrix::eye(7), 1e-9);
    }

    #[test]
    fn known_diagonal() {
        let a = Matrix::from_fn(4, 4, |i, j| if i == j { (4 - i) as f64 } else { 0.0 });
        let svd = jacobi_svd(&a);
        for (i, &s) in svd.s.iter().enumerate() {
            assert!((s - (4 - i) as f64).abs() < 1e-12);
        }
    }

    #[test]
    fn ridge_solve_matches_direct() {
        // θ_svd must equal (XᵀX + λI)⁻¹ Xᵀy computed via Cholesky
        let x = random_matrix(40, 12, 4);
        let y: Vec<f64> = (0..40).map(|i| (i as f64 * 0.37).sin()).collect();
        let lam = 0.5;
        let svd = jacobi_svd(&x);
        let uty = svd.project_y(&y);
        let theta = svd.ridge_solve(&uty, lam);

        let h = crate::linalg::gemm::syrk_lower(&x);
        let g = crate::linalg::gemm::gemv_t(&x, &y);
        let l = crate::linalg::cholesky::cholesky_shifted(&h, lam).unwrap();
        let theta_chol = crate::linalg::triangular::solve_cholesky(&l, &g);
        for (a, b) in theta.iter().zip(&theta_chol) {
            assert!((a - b).abs() < 1e-8, "{a} vs {b}");
        }
        // sanity: residual of the normal equations
        let hth = gemv(&h.add_diag(lam), &theta);
        for (p, q) in hth.iter().zip(&g) {
            assert!((p - q).abs() < 1e-7);
        }
    }

    #[test]
    fn rank_deficient_ok() {
        // duplicate columns → zero singular values must not NaN
        let base = random_matrix(15, 3, 5);
        let a = Matrix::from_fn(15, 6, |i, j| base[(i, j % 3)]);
        let svd = jacobi_svd(&a);
        assert!(svd.s[3..].iter().all(|&s| s < 1e-8));
        assert!(svd.s.iter().all(|s| s.is_finite()));
    }
}
