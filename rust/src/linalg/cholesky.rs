//! Blocked Cholesky factorization (LAPACK `dpotrf` shape).
//!
//! This is the paper's dominant cost: each fold×λ pair needs one
//! `chol(H + λI)` at `(1/3)d³` flops (§1, Figure 1). The right-looking
//! blocked form does panel factorization + TRSM + SYRK trailing update so
//! ~all flops land in the BLAS-3 kernels of [`super::gemm`].

use super::gemm::Gemm;
use super::matrix::Matrix;
use std::fmt;

/// Factorization failure: the matrix is not (numerically) positive-definite.
#[derive(Debug, Clone, PartialEq)]
pub struct CholeskyError {
    /// Index of the pivot that went non-positive.
    pub pivot: usize,
    /// The offending pivot value.
    pub value: f64,
}

impl fmt::Display for CholeskyError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "matrix not positive-definite: pivot {} = {:.3e}",
            self.pivot, self.value
        )
    }
}

impl std::error::Error for CholeskyError {}

/// Unblocked in-place Cholesky of the leading `n×n` of `a` (lower triangle).
/// Used for panels; the strict upper triangle is left untouched.
fn potrf_unblocked(a: &mut Matrix, off: usize, n: usize) -> Result<(), CholeskyError> {
    for j in 0..n {
        let mut diag = a[(off + j, off + j)];
        for k in 0..j {
            let v = a[(off + j, off + k)];
            diag -= v * v;
        }
        if diag <= 0.0 || !diag.is_finite() {
            return Err(CholeskyError {
                pivot: off + j,
                value: diag,
            });
        }
        let ljj = diag.sqrt();
        a[(off + j, off + j)] = ljj;
        for i in (j + 1)..n {
            let mut s = a[(off + i, off + j)];
            for k in 0..j {
                s -= a[(off + i, off + k)] * a[(off + j, off + k)];
            }
            a[(off + i, off + j)] = s / ljj;
        }
    }
    Ok(())
}

/// In-place blocked Cholesky: on success the lower triangle of `a` holds L
/// (strict upper is zeroed). `block` = panel width.
pub fn cholesky_in_place(a: &mut Matrix, block: usize) -> Result<(), CholeskyError> {
    assert!(a.is_square(), "cholesky needs a square matrix");
    let n = a.rows();
    let gem = Gemm { block };

    let mut j0 = 0;
    while j0 < n {
        let jb = block.min(n - j0);

        // 1. factor the diagonal panel A[j0.., j0..][..jb]
        potrf_unblocked(a, j0, jb)?;

        if j0 + jb < n {
            // 2. TRSM: L21 = A21 · L11⁻ᵀ  (solve x·L11ᵀ = a for each row)
            for i in (j0 + jb)..n {
                for j in 0..jb {
                    let mut s = a[(i, j0 + j)];
                    for k in 0..j {
                        s -= a[(i, j0 + k)] * a[(j0 + j, j0 + k)];
                    }
                    a[(i, j0 + j)] = s / a[(j0 + j, j0 + j)];
                }
            }

            // 3. SYRK trailing update: A22 -= L21 · L21ᵀ (lower triangle only)
            let m = n - j0 - jb;
            let l21 = a.slice(j0 + jb, n, j0, j0 + jb);
            let upd = gem.a_bt(&l21, &l21);
            for i in 0..m {
                let gi = j0 + jb + i;
                for j in 0..=i {
                    a[(gi, j0 + jb + j)] -= upd[(i, j)];
                }
            }
        }
        j0 += jb;
    }
    a.zero_upper();
    Ok(())
}

/// Out-of-place blocked Cholesky with the default panel width (64).
pub fn cholesky_blocked(a: &Matrix) -> Result<Matrix, CholeskyError> {
    let mut l = a.clone();
    cholesky_in_place(&mut l, 64)?;
    Ok(l)
}

/// `chol(H + λI)` — the per-λ operation of the cross-validation sweep.
pub fn cholesky_shifted(h: &Matrix, lam: f64) -> Result<Matrix, CholeskyError> {
    let mut a = h.add_diag(lam);
    cholesky_in_place(&mut a, 64)?;
    Ok(a)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::gemm;
    use crate::testutil::{random_spd, assert_matrix_close};

    #[test]
    fn reconstructs_spd() {
        let a = random_spd(33, 1e4, 1);
        let l = cholesky_blocked(&a).unwrap();
        let rec = gemm(&l, &l.transpose());
        assert_matrix_close(&rec, &a, 1e-8);
    }

    #[test]
    fn matches_known_3x3() {
        // classic textbook example
        let a = Matrix::from_vec(
            3,
            3,
            vec![4.0, 12.0, -16.0, 12.0, 37.0, -43.0, -16.0, -43.0, 98.0],
        );
        let l = cholesky_blocked(&a).unwrap();
        let expect = Matrix::from_vec(3, 3, vec![2.0, 0.0, 0.0, 6.0, 1.0, 0.0, -8.0, 5.0, 3.0]);
        assert!(l.max_abs_diff(&expect) < 1e-12);
    }

    #[test]
    fn block_size_invariance() {
        let a = random_spd(100, 1e5, 2);
        let mut l8 = a.clone();
        cholesky_in_place(&mut l8, 8).unwrap();
        let mut l64 = a.clone();
        cholesky_in_place(&mut l64, 64).unwrap();
        let mut l256 = a.clone();
        cholesky_in_place(&mut l256, 256).unwrap();
        assert!(l8.max_abs_diff(&l64) < 1e-9);
        assert!(l64.max_abs_diff(&l256) < 1e-9);
    }

    #[test]
    fn rejects_indefinite() {
        let mut a = Matrix::eye(4);
        a[(2, 2)] = -1.0;
        let err = cholesky_blocked(&a).unwrap_err();
        assert_eq!(err.pivot, 2);
    }

    #[test]
    fn shift_regularizes() {
        // rank-deficient H: chol fails at λ=0, succeeds for λ>0
        let x = crate::testutil::random_matrix(10, 4, 3);
        let h = crate::linalg::gemm::syrk_lower(&x);
        let mut hfull = Matrix::zeros(10, 10);
        // embed the rank-4 gram of Xᵀ (10×10 of rank ≤ 4)
        let xt = x; // 10×4 → XXᵀ is 10×10 rank 4
        let g = crate::linalg::gemm::Gemm::default().a_bt(&xt, &xt);
        for i in 0..10 {
            for j in 0..10 {
                hfull[(i, j)] = g[(i, j)];
            }
        }
        let _ = h; // silence
        assert!(cholesky_blocked(&hfull).is_err());
        assert!(cholesky_shifted(&hfull, 1e-3).is_ok());
    }

    #[test]
    fn factor_is_lower_triangular() {
        let a = random_spd(17, 100.0, 4);
        let l = cholesky_blocked(&a).unwrap();
        for i in 0..17 {
            for j in (i + 1)..17 {
                assert_eq!(l[(i, j)], 0.0);
            }
        }
    }
}
