//! Blocked Cholesky factorization (LAPACK `dpotrf` shape).
//!
//! This is the paper's dominant cost: each fold×λ pair needs one
//! `chol(H + λI)` at `(1/3)d³` flops (§1, Figure 1). The right-looking
//! blocked form does panel factorization + TRSM + SYRK trailing update so
//! ~all flops land in the BLAS-3 kernels of [`super::gemm`].

use super::gemm::Gemm;
use super::matrix::Matrix;
use crate::coordinator::pool::WorkerPool;
use std::fmt;
use std::sync::Arc;

/// Factorization failure: the matrix is not (numerically) positive-definite.
///
/// # Recovery semantics (shift-and-retry)
///
/// In the cross-validation setting `A = H + λI` with `H = XᵀX ⪰ 0`, so a
/// failure means λ is too small relative to the rank deficiency / rounding
/// noise of `H`. The standard recovery is to **increase the shift and
/// retry**: call [`cholesky_shifted`] again with a larger λ (e.g. the next
/// grid point, or `λ + ε·trace(H)/d`). Every caller in this crate follows
/// one of two policies:
///
/// - *grid sweeps* ([`crate::cv`], the sweep engine) propagate the error
///   and the whole sweep aborts with it (in-flight parallel tasks drain
///   first) — a λ grid whose low end leaves `H + λI` indefinite is a
///   misconfigured search range, and the fix is to rerun with a larger
///   `lambda_range` lower bound (the retry happens at the configuration
///   level, not per grid point);
/// - *fixed-λ call sites* (MChol probes, tests) treat the error as a
///   precondition violation, because their λ ranges are bounded away from
///   zero by construction.
///
/// The struct carries the failing pivot index and value so callers can size
/// a retry shift if they choose to.
#[derive(Debug, Clone, PartialEq)]
pub struct CholeskyError {
    /// Index of the pivot that went non-positive.
    pub pivot: usize,
    /// The offending pivot value.
    pub value: f64,
}

impl fmt::Display for CholeskyError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "matrix not positive-definite: pivot {} = {:.3e}",
            self.pivot, self.value
        )
    }
}

impl std::error::Error for CholeskyError {}

/// Unblocked in-place Cholesky of the leading `n×n` of `a` (lower triangle).
/// Used for panels; the strict upper triangle is left untouched.
fn potrf_unblocked(a: &mut Matrix, off: usize, n: usize) -> Result<(), CholeskyError> {
    for j in 0..n {
        let mut diag = a[(off + j, off + j)];
        for k in 0..j {
            let v = a[(off + j, off + k)];
            diag -= v * v;
        }
        if diag <= 0.0 || !diag.is_finite() {
            return Err(CholeskyError {
                pivot: off + j,
                value: diag,
            });
        }
        let ljj = diag.sqrt();
        a[(off + j, off + j)] = ljj;
        for i in (j + 1)..n {
            let mut s = a[(off + i, off + j)];
            for k in 0..j {
                s -= a[(off + i, off + k)] * a[(off + j, off + k)];
            }
            a[(off + i, off + j)] = s / ljj;
        }
    }
    Ok(())
}

/// In-place blocked Cholesky: on success the lower triangle of `a` holds L
/// (strict upper is zeroed). `block` = panel width.
pub fn cholesky_in_place(a: &mut Matrix, block: usize) -> Result<(), CholeskyError> {
    assert!(a.is_square(), "cholesky needs a square matrix");
    let n = a.rows();
    let gem = Gemm { block };

    let mut j0 = 0;
    while j0 < n {
        let jb = block.min(n - j0);

        // 1. factor the diagonal panel A[j0.., j0..][..jb]
        potrf_unblocked(a, j0, jb)?;

        if j0 + jb < n {
            // 2. TRSM: L21 = A21 · L11⁻ᵀ  (solve x·L11ᵀ = a for each row)
            for i in (j0 + jb)..n {
                for j in 0..jb {
                    let mut s = a[(i, j0 + j)];
                    for k in 0..j {
                        s -= a[(i, j0 + k)] * a[(j0 + j, j0 + k)];
                    }
                    a[(i, j0 + j)] = s / a[(j0 + j, j0 + j)];
                }
            }

            // 3. SYRK trailing update: A22 -= L21 · L21ᵀ (lower triangle only)
            let m = n - j0 - jb;
            let l21 = a.slice(j0 + jb, n, j0, j0 + jb);
            let upd = gem.a_bt(&l21, &l21);
            for i in 0..m {
                let gi = j0 + jb + i;
                for j in 0..=i {
                    a[(gi, j0 + jb + j)] -= upd[(i, j)];
                }
            }
        }
        j0 += jb;
    }
    a.zero_upper();
    Ok(())
}

/// Out-of-place blocked Cholesky with the default panel width (64).
pub fn cholesky_blocked(a: &Matrix) -> Result<Matrix, CholeskyError> {
    let mut l = a.clone();
    cholesky_in_place(&mut l, 64)?;
    Ok(l)
}

/// `chol(H + λI)` — the per-λ operation of the cross-validation sweep.
///
/// On [`CholeskyError`] the factor is unusable; see the error type's docs
/// for the shift-and-retry recovery contract (retry with a larger λ).
pub fn cholesky_shifted(h: &Matrix, lam: f64) -> Result<Matrix, CholeskyError> {
    let mut a = h.add_diag(lam);
    cholesky_in_place(&mut a, 64)?;
    Ok(a)
}

/// Evenly split `lo..hi` into at most `parts` non-empty contiguous ranges.
fn chunk_ranges(lo: usize, hi: usize, parts: usize) -> Vec<(usize, usize)> {
    let n = hi.saturating_sub(lo);
    let parts = parts.max(1).min(n.max(1));
    let base = n / parts;
    let rem = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = lo;
    for p in 0..parts {
        let len = base + usize::from(p < rem);
        if len == 0 {
            continue;
        }
        out.push((start, start + len));
        start += len;
    }
    out
}

/// In-place blocked Cholesky with **intra-factorization parallelism**: the
/// TRSM and SYRK trailing updates of each panel step are tiled into
/// independent row-panel tasks executed on `pool` (§5's "maximally exploit
/// the compute power of modern architectures", applied to a single large
/// factor).
///
/// The result is **bitwise identical** to [`cholesky_in_place`] with the
/// same `block`, for any worker count: each TRSM tile replays the serial
/// per-row substitution order, and each SYRK tile is produced by
/// [`Gemm::a_bt_rows`], whose per-row schedule matches the serial
/// [`Gemm::a_bt`]. Panel factorization (the `O(d·b²)` serial fraction) stays
/// on the calling thread.
///
/// **Deadlock rule:** must be driven from a thread that is *not* itself a
/// worker of `pool` (see the [`crate::coordinator::pool`] module docs).
/// Falls back to the serial kernel when the pool has one worker or the
/// matrix is too small to amortize tiling.
pub fn cholesky_in_place_pooled(
    a: &mut Matrix,
    block: usize,
    pool: &WorkerPool,
) -> Result<(), CholeskyError> {
    assert!(a.is_square(), "cholesky needs a square matrix");
    let n = a.rows();
    if pool.size() <= 1 || n <= 2 * block {
        return cholesky_in_place(a, block);
    }

    let mut j0 = 0;
    while j0 < n {
        let jb = block.min(n - j0);

        // 1. factor the diagonal panel on the calling thread
        potrf_unblocked(a, j0, jb)?;

        if j0 + jb < n {
            // 2. TRSM tiles: L21 = A21 · L11⁻ᵀ, row panels in parallel.
            // Each task owns copies of its operands (jobs must be 'static);
            // the panel is small (jb×jb) and the row chunk is disjoint.
            let l11 = Arc::new(a.slice(j0, j0 + jb, j0, j0 + jb));
            let row_chunks = chunk_ranges(j0 + jb, n, pool.size());
            let trsm_jobs: Vec<Box<dyn FnOnce() -> Matrix + Send + 'static>> = row_chunks
                .iter()
                .map(|&(r0, r1)| {
                    let l11 = Arc::clone(&l11);
                    let chunk = a.slice(r0, r1, j0, j0 + jb);
                    let f: Box<dyn FnOnce() -> Matrix + Send + 'static> = Box::new(move || {
                        let mut x = chunk;
                        for i in 0..x.rows() {
                            for j in 0..l11.rows() {
                                let mut s = x[(i, j)];
                                for k in 0..j {
                                    s -= x[(i, k)] * l11[(j, k)];
                                }
                                x[(i, j)] = s / l11[(j, j)];
                            }
                        }
                        x
                    });
                    f
                })
                .collect();
            for (&(r0, _), solved) in row_chunks.iter().zip(pool.map(trsm_jobs)) {
                a.set_block(r0, j0, &solved);
            }

            // 3. SYRK tiles: A22 -= L21 · L21ᵀ, row panels of the update in
            // parallel, subtraction applied in deterministic order here.
            let m = n - j0 - jb;
            let l21 = Arc::new(a.slice(j0 + jb, n, j0, j0 + jb));
            let upd_chunks = chunk_ranges(0, m, pool.size());
            let gem_block = block;
            let syrk_jobs: Vec<Box<dyn FnOnce() -> Matrix + Send + 'static>> = upd_chunks
                .iter()
                .map(|&(q0, q1)| {
                    let l21 = Arc::clone(&l21);
                    let f: Box<dyn FnOnce() -> Matrix + Send + 'static> = Box::new(move || {
                        Gemm { block: gem_block }.a_bt_rows(&l21, &l21, q0, q1)
                    });
                    f
                })
                .collect();
            for (&(q0, q1), upd) in upd_chunks.iter().zip(pool.map(syrk_jobs)) {
                for i in q0..q1 {
                    let gi = j0 + jb + i;
                    let urow = upd.row(i - q0);
                    for j in 0..=i {
                        a[(gi, j0 + jb + j)] -= urow[j];
                    }
                }
            }
        }
        j0 += jb;
    }
    a.zero_upper();
    Ok(())
}

/// `chol(H + λI)` with the trailing updates tiled across `pool` — the
/// anchor-factorization kernel of the sweep engine when a few large factors
/// must be produced with many idle workers. Bitwise identical to
/// [`cholesky_shifted`]; same shift-and-retry recovery contract.
pub fn cholesky_shifted_pooled(
    h: &Matrix,
    lam: f64,
    pool: &WorkerPool,
) -> Result<Matrix, CholeskyError> {
    let mut a = h.add_diag(lam);
    cholesky_in_place_pooled(&mut a, 64, pool)?;
    Ok(a)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::gemm;
    use crate::testutil::{random_spd, assert_matrix_close};

    #[test]
    fn reconstructs_spd() {
        let a = random_spd(33, 1e4, 1);
        let l = cholesky_blocked(&a).unwrap();
        let rec = gemm(&l, &l.transpose());
        assert_matrix_close(&rec, &a, 1e-8);
    }

    #[test]
    fn matches_known_3x3() {
        // classic textbook example
        let a = Matrix::from_vec(
            3,
            3,
            vec![4.0, 12.0, -16.0, 12.0, 37.0, -43.0, -16.0, -43.0, 98.0],
        );
        let l = cholesky_blocked(&a).unwrap();
        let expect = Matrix::from_vec(3, 3, vec![2.0, 0.0, 0.0, 6.0, 1.0, 0.0, -8.0, 5.0, 3.0]);
        assert!(l.max_abs_diff(&expect) < 1e-12);
    }

    #[test]
    fn block_size_invariance() {
        let a = random_spd(100, 1e5, 2);
        let mut l8 = a.clone();
        cholesky_in_place(&mut l8, 8).unwrap();
        let mut l64 = a.clone();
        cholesky_in_place(&mut l64, 64).unwrap();
        let mut l256 = a.clone();
        cholesky_in_place(&mut l256, 256).unwrap();
        assert!(l8.max_abs_diff(&l64) < 1e-9);
        assert!(l64.max_abs_diff(&l256) < 1e-9);
    }

    #[test]
    fn rejects_indefinite() {
        let mut a = Matrix::eye(4);
        a[(2, 2)] = -1.0;
        let err = cholesky_blocked(&a).unwrap_err();
        assert_eq!(err.pivot, 2);
    }

    #[test]
    fn shift_regularizes() {
        // rank-deficient H: chol fails at λ=0, succeeds for λ>0
        let x = crate::testutil::random_matrix(10, 4, 3);
        let h = crate::linalg::gemm::syrk_lower(&x);
        let mut hfull = Matrix::zeros(10, 10);
        // embed the rank-4 gram of Xᵀ (10×10 of rank ≤ 4)
        let xt = x; // 10×4 → XXᵀ is 10×10 rank 4
        let g = crate::linalg::gemm::Gemm::default().a_bt(&xt, &xt);
        for i in 0..10 {
            for j in 0..10 {
                hfull[(i, j)] = g[(i, j)];
            }
        }
        let _ = h; // silence
        assert!(cholesky_blocked(&hfull).is_err());
        assert!(cholesky_shifted(&hfull, 1e-3).is_ok());
    }

    #[test]
    fn pooled_factorization_bitwise_matches_serial() {
        use crate::coordinator::pool::WorkerPool;
        let a = random_spd(150, 1e4, 11);
        for workers in [1, 2, 4] {
            let pool = WorkerPool::new(workers);
            for block in [16, 32, 64] {
                let mut serial = a.clone();
                cholesky_in_place(&mut serial, block).unwrap();
                let mut pooled = a.clone();
                cholesky_in_place_pooled(&mut pooled, block, &pool).unwrap();
                assert_eq!(
                    serial.max_abs_diff(&pooled),
                    0.0,
                    "pooled factor differs at workers={workers} block={block}"
                );
            }
        }
    }

    #[test]
    fn pooled_shifted_matches_serial_shifted() {
        use crate::coordinator::pool::WorkerPool;
        let x = crate::testutil::random_matrix(220, 130, 21);
        let h = crate::linalg::gemm::syrk_lower(&x);
        let pool = WorkerPool::new(3);
        let serial = cholesky_shifted(&h, 0.37).unwrap();
        let pooled = cholesky_shifted_pooled(&h, 0.37, &pool).unwrap();
        assert_eq!(serial.max_abs_diff(&pooled), 0.0);
    }

    #[test]
    fn pooled_rejects_indefinite_like_serial() {
        use crate::coordinator::pool::WorkerPool;
        let pool = WorkerPool::new(2);
        let mut a = Matrix::eye(200);
        a[(150, 150)] = -1.0;
        let mut p = a.clone();
        let err = cholesky_in_place_pooled(&mut p, 32, &pool).unwrap_err();
        assert_eq!(err.pivot, 150);
    }

    #[test]
    fn factor_is_lower_triangular() {
        let a = random_spd(17, 100.0, 4);
        let l = cholesky_blocked(&a).unwrap();
        for i in 0..17 {
            for j in (i + 1)..17 {
                assert_eq!(l[(i, j)], 0.0);
            }
        }
    }
}
