//! Blocked Cholesky factorization (LAPACK `dpotrf` shape).
//!
//! This is the paper's dominant cost: each fold×λ pair needs one
//! `chol(H + λI)` at `(1/3)d³` flops (§1, Figure 1). The right-looking
//! blocked form does panel factorization + TRSM + SYRK trailing update, and
//! both BLAS-3 steps route through the packed micro-kernel engine
//! ([`super::kernel`]): the TRSM via the column-blocked
//! [`trsm_right_lower_t_inplace`], the SYRK via the row-chunked
//! [`Gemm::a_bt_rows`] schedule — the same schedule the pooled variant fans
//! across workers, so serial and pooled factors are bitwise identical by
//! construction.

use super::gemm::Gemm;
use super::kernel::{self, Acc, Src};
use super::matrix::Matrix;
use super::triangular::trsm_right_lower_t_inplace;
use crate::coordinator::pool::WorkerPool;
use std::fmt;
use std::sync::Arc;

/// Factorization failure: the matrix is not (numerically) positive-definite.
///
/// # Recovery semantics (the escalation ladder)
///
/// In the cross-validation setting `A = H + λI` with `H = XᵀX ⪰ 0`, so a
/// failure means λ is too small relative to the rank deficiency / rounding
/// noise of `H`. Every engine path in this crate now recovers through **one
/// unified ladder** ([`crate::cv::recovery::RecoveryPolicy`]): downdate →
/// refactor → shifted refactor with bounded growing-shift retries
/// ([`cholesky_shifted_retry_into`]) → skip-and-record. A breakdown degrades
/// the one affected cell/row into the report's `degradations` section; it
/// never aborts a sweep and never panics. Fixed-λ call sites outside the
/// engine (MChol probes, tests) still treat the error as a precondition
/// violation, because their λ ranges are bounded away from zero by
/// construction.
///
/// The struct carries the failing pivot index and value so callers can size
/// a retry shift if they choose to.
#[derive(Debug, Clone, PartialEq)]
pub struct CholeskyError {
    /// Index of the pivot that went non-positive.
    pub pivot: usize,
    /// The offending pivot value.
    pub value: f64,
}

impl fmt::Display for CholeskyError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "matrix not positive-definite: pivot {} = {:.3e}",
            self.pivot, self.value
        )
    }
}

impl std::error::Error for CholeskyError {}

/// Unblocked in-place Cholesky of the leading `n×n` of `a` at offset `off`
/// (lower triangle). Used for panels; the strict upper triangle is left
/// untouched. All inner loops run on contiguous row slices (`split_at_mut`
/// around the pivot row) — no bounds-checked `a[(i, j)]` indexing survives
/// in the hot loops.
fn potrf_unblocked(a: &mut Matrix, off: usize, n: usize) -> Result<(), CholeskyError> {
    let stride = a.cols();
    let data = a.as_mut_slice();
    for j in 0..n {
        let jrow = (off + j) * stride + off;
        let mut diag = data[jrow + j];
        for &v in &data[jrow..jrow + j] {
            diag -= v * v;
        }
        if diag <= 0.0 || !diag.is_finite() {
            return Err(CholeskyError {
                pivot: off + j,
                value: diag,
            });
        }
        let ljj = diag.sqrt();
        data[jrow + j] = ljj;
        // rows below the pivot: s = a[i][j] - Σ_k a[i][k]·a[j][k], then /ljj.
        // split keeps row j immutable while rows i > j are written.
        let (head, tail) = data.split_at_mut(jrow + j + 1);
        let lrow_j = &head[jrow..jrow + j];
        for i in (j + 1)..n {
            let t0 = (off + i) * stride + off - (jrow + j + 1);
            let row_i = &mut tail[t0..t0 + j + 1];
            let mut s = row_i[j];
            for (x, y) in row_i[..j].iter().zip(lrow_j) {
                s -= x * y;
            }
            row_i[j] = s / ljj;
        }
    }
    Ok(())
}

/// Row chunk height for the serial trailing update — the SYRK is streamed
/// through the packed kernel one chunk at a time (bounded temp footprint;
/// bitwise identical to any other chunking, see [`Gemm::a_bt_rows`]).
const SYRK_CHUNK: usize = 128;

/// In-place blocked Cholesky: on success the lower triangle of `a` holds L
/// (strict upper is zeroed). `block` = panel width.
pub fn cholesky_in_place(a: &mut Matrix, block: usize) -> Result<(), CholeskyError> {
    assert!(a.is_square(), "cholesky needs a square matrix");
    let n = a.rows();
    let stride = n;

    let mut j0 = 0;
    while j0 < n {
        let jb = block.min(n - j0);

        // 1. factor the diagonal panel A[j0.., j0..][..jb]
        potrf_unblocked(a, j0, jb)?;

        if j0 + jb < n {
            // 2. TRSM: L21 = A21 · L11⁻ᵀ, column-blocked through the packed
            // kernel (the panel copy decouples the borrow; jb×jb is small)
            let l11 = a.slice(j0, j0 + jb, j0, j0 + jb);
            trsm_right_lower_t_inplace(a, j0 + jb, n, j0, &l11);

            // 3. SYRK trailing update: A22 -= L21·L21ᵀ (lower triangle),
            // streamed in row chunks with the a_bt_rows schedule
            let m = n - j0 - jb;
            let l21 = a.slice(j0 + jb, n, j0, j0 + jb);
            for q0 in (0..m).step_by(SYRK_CHUNK) {
                let q1 = (q0 + SYRK_CHUNK).min(m);
                let rows = q1 - q0;
                kernel::with_tmp(rows * m, |tmp| {
                    kernel::gemm_into(
                        rows,
                        m,
                        jb,
                        Src::N {
                            data: l21.as_slice(),
                            stride: jb,
                            r0: q0,
                            c0: 0,
                        },
                        Src::t(l21.as_slice(), jb),
                        tmp,
                        m,
                        0,
                        0,
                        Acc::Set,
                    );
                    let data = a.as_mut_slice();
                    for i in 0..rows {
                        let gi = j0 + jb + q0 + i;
                        let take = q0 + i + 1; // lower triangle only
                        let dst = &mut data[gi * stride + j0 + jb..][..take];
                        for (d, &u) in dst.iter_mut().zip(&tmp[i * m..i * m + take]) {
                            *d -= u;
                        }
                    }
                });
            }
        }
        j0 += jb;
    }
    a.zero_upper();
    Ok(())
}

/// Out-of-place blocked Cholesky with the default panel width (64).
pub fn cholesky_blocked(a: &Matrix) -> Result<Matrix, CholeskyError> {
    let mut l = a.clone();
    cholesky_in_place(&mut l, 64)?;
    Ok(l)
}

/// `chol(H + λI)` — the per-λ operation of the cross-validation sweep.
///
/// On [`CholeskyError`] the factor is unusable; see the error type's docs
/// for the shift-and-retry recovery contract (retry with a larger λ).
pub fn cholesky_shifted(h: &Matrix, lam: f64) -> Result<Matrix, CholeskyError> {
    let mut a = h.add_diag(lam);
    cholesky_in_place(&mut a, 64)?;
    Ok(a)
}

/// `chol(H + λI)` into a caller-provided matrix (the per-worker
/// [`super::scratch::Scratch`] factor buffer on the sweep hot path): `out`
/// is overwritten with `H + λI` reusing its allocation, then factorized in
/// place — the steady-state exact-Cholesky grid task allocates nothing.
/// Bitwise identical to [`cholesky_shifted`]. On error `out` holds an
/// unusable partial factor.
pub fn cholesky_shifted_into(h: &Matrix, lam: f64, out: &mut Matrix) -> Result<(), CholeskyError> {
    out.copy_from(h);
    out.add_diag_in_place(lam);
    cholesky_in_place(out, 64)
}

/// Outcome of a successful [`cholesky_shifted_retry_into`]: how much extra
/// diagonal shift (beyond the requested λ) the factorization needed, and how
/// many retry attempts it took (`0` = the plain shift succeeded).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ShiftOutcome {
    /// Extra shift added on top of λ (0.0 when none was needed).
    pub extra_shift: f64,
    /// Retry attempts consumed (0 = first try).
    pub attempts: u32,
}

/// `chol(H + (λ + extra)·I)` with **bounded growing-shift retries** — rung 3
/// of the breakdown-escalation ladder.
///
/// Tries the plain shift first ([`cholesky_shifted_into`], bitwise the hot
/// path when it succeeds); on breakdown retries with an extra diagonal shift
/// that starts at `ε·max(trace(H)/d, λ)` (the smallest perturbation that can
/// register against the matrix's own scale) and grows by `growth` each
/// attempt, at most `max_retries` times. Returns the extra shift actually
/// used so the caller can record the approximation in its degradation
/// report; the factor in `out` then solves the *shifted* problem, which is
/// the documented accuracy trade of this rung. The final error is returned
/// when every attempt fails (`out` holds an unusable partial factor).
pub fn cholesky_shifted_retry_into(
    h: &Matrix,
    lam: f64,
    out: &mut Matrix,
    max_retries: u32,
    growth: f64,
) -> Result<ShiftOutcome, CholeskyError> {
    match cholesky_shifted_into(h, lam, out) {
        Ok(()) => Ok(ShiftOutcome {
            extra_shift: 0.0,
            attempts: 0,
        }),
        Err(first) => {
            let d = h.rows().max(1);
            let trace: f64 = (0..h.rows()).map(|i| h[(i, i)].abs()).sum();
            let mut extra = (f64::EPSILON * (trace / d as f64).max(lam.abs()))
                .max(f64::MIN_POSITIVE);
            let growth = if growth > 1.0 { growth } else { 10.0 };
            let mut last = first;
            for attempt in 1..=max_retries {
                match cholesky_shifted_into(h, lam + extra, out) {
                    Ok(()) => {
                        return Ok(ShiftOutcome {
                            extra_shift: extra,
                            attempts: attempt,
                        })
                    }
                    Err(e) => {
                        last = e;
                        extra *= growth;
                    }
                }
            }
            Err(last)
        }
    }
}

/// Evenly split `lo..hi` into at most `parts` non-empty contiguous ranges.
fn chunk_ranges(lo: usize, hi: usize, parts: usize) -> Vec<(usize, usize)> {
    let n = hi.saturating_sub(lo);
    let parts = parts.max(1).min(n.max(1));
    let base = n / parts;
    let rem = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = lo;
    for p in 0..parts {
        let len = base + usize::from(p < rem);
        if len == 0 {
            continue;
        }
        out.push((start, start + len));
        start += len;
    }
    out
}

/// In-place blocked Cholesky with **intra-factorization parallelism**: the
/// TRSM and SYRK trailing updates of each panel step are tiled into
/// independent row-panel tasks executed on `pool` (§5's "maximally exploit
/// the compute power of modern architectures", applied to a single large
/// factor).
///
/// The result is **bitwise identical** to [`cholesky_in_place`] with the
/// same `block`, for any worker count: each TRSM tile runs the same
/// column-blocked [`trsm_right_lower_t_inplace`] the serial kernel runs
/// (row-partition independent by construction), and each SYRK tile is
/// produced by [`Gemm::a_bt_rows`], whose packed accumulation schedule is
/// independent of the row partition. Panel factorization (the `O(d·b²)`
/// serial fraction) stays on the calling thread.
///
/// **Deadlock rule:** must be driven from a thread that is *not* itself a
/// worker of `pool` (see the [`crate::coordinator::pool`] module docs).
/// Falls back to the serial kernel when the pool has one worker or the
/// matrix is too small to amortize tiling.
pub fn cholesky_in_place_pooled(
    a: &mut Matrix,
    block: usize,
    pool: &WorkerPool,
) -> Result<(), CholeskyError> {
    assert!(a.is_square(), "cholesky needs a square matrix");
    let n = a.rows();
    if pool.size() <= 1 || n <= 2 * block {
        return cholesky_in_place(a, block);
    }

    let mut j0 = 0;
    while j0 < n {
        let jb = block.min(n - j0);

        // 1. factor the diagonal panel on the calling thread
        potrf_unblocked(a, j0, jb)?;

        if j0 + jb < n {
            // 2. TRSM tiles: L21 = A21 · L11⁻ᵀ, row panels in parallel.
            // Each task owns copies of its operands (jobs must be 'static);
            // the panel is small (jb×jb) and the row chunk is disjoint.
            let l11 = Arc::new(a.slice(j0, j0 + jb, j0, j0 + jb));
            let row_chunks = chunk_ranges(j0 + jb, n, pool.size());
            let trsm_jobs: Vec<Box<dyn FnOnce() -> Matrix + Send + 'static>> = row_chunks
                .iter()
                .map(|&(r0, r1)| {
                    let l11 = Arc::clone(&l11);
                    let chunk = a.slice(r0, r1, j0, j0 + jb);
                    let f: Box<dyn FnOnce() -> Matrix + Send + 'static> = Box::new(move || {
                        let mut x = chunk;
                        let rows = x.rows();
                        trsm_right_lower_t_inplace(&mut x, 0, rows, 0, &l11);
                        x
                    });
                    f
                })
                .collect();
            for (&(r0, _), solved) in row_chunks.iter().zip(pool.map(trsm_jobs)) {
                a.set_block(r0, j0, &solved);
            }

            // 3. SYRK tiles: A22 -= L21 · L21ᵀ, row panels of the update in
            // parallel, subtraction applied in deterministic order here.
            let m = n - j0 - jb;
            let l21 = Arc::new(a.slice(j0 + jb, n, j0, j0 + jb));
            let upd_chunks = chunk_ranges(0, m, pool.size());
            let syrk_jobs: Vec<Box<dyn FnOnce() -> Matrix + Send + 'static>> = upd_chunks
                .iter()
                .map(|&(q0, q1)| {
                    let l21 = Arc::clone(&l21);
                    let f: Box<dyn FnOnce() -> Matrix + Send + 'static> =
                        Box::new(move || Gemm::default().a_bt_rows(&l21, &l21, q0, q1));
                    f
                })
                .collect();
            for (&(q0, q1), upd) in upd_chunks.iter().zip(pool.map(syrk_jobs)) {
                for i in q0..q1 {
                    let gi = j0 + jb + i;
                    let urow = upd.row(i - q0);
                    for j in 0..=i {
                        a[(gi, j0 + jb + j)] -= urow[j];
                    }
                }
            }
        }
        j0 += jb;
    }
    a.zero_upper();
    Ok(())
}

/// `chol(H + λI)` with the trailing updates tiled across `pool` — the
/// anchor-factorization kernel of the sweep engine when a few large factors
/// must be produced with many idle workers. Bitwise identical to
/// [`cholesky_shifted`]; same shift-and-retry recovery contract.
pub fn cholesky_shifted_pooled(
    h: &Matrix,
    lam: f64,
    pool: &WorkerPool,
) -> Result<Matrix, CholeskyError> {
    let mut a = h.add_diag(lam);
    cholesky_in_place_pooled(&mut a, 64, pool)?;
    Ok(a)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::gemm;
    use crate::testutil::{assert_matrix_close, random_spd};

    #[test]
    fn reconstructs_spd() {
        let a = random_spd(33, 1e4, 1);
        let l = cholesky_blocked(&a).unwrap();
        let rec = gemm(&l, &l.transpose());
        assert_matrix_close(&rec, &a, 1e-8);
    }

    #[test]
    fn matches_known_3x3() {
        // classic textbook example
        let a = Matrix::from_vec(
            3,
            3,
            vec![4.0, 12.0, -16.0, 12.0, 37.0, -43.0, -16.0, -43.0, 98.0],
        );
        let l = cholesky_blocked(&a).unwrap();
        let expect = Matrix::from_vec(3, 3, vec![2.0, 0.0, 0.0, 6.0, 1.0, 0.0, -8.0, 5.0, 3.0]);
        assert!(l.max_abs_diff(&expect) < 1e-12);
    }

    #[test]
    fn block_size_invariance() {
        let a = random_spd(100, 1e5, 2);
        let mut l8 = a.clone();
        cholesky_in_place(&mut l8, 8).unwrap();
        let mut l64 = a.clone();
        cholesky_in_place(&mut l64, 64).unwrap();
        let mut l256 = a.clone();
        cholesky_in_place(&mut l256, 256).unwrap();
        assert!(l8.max_abs_diff(&l64) < 1e-9);
        assert!(l64.max_abs_diff(&l256) < 1e-9);
    }

    #[test]
    fn rejects_indefinite() {
        let mut a = Matrix::eye(4);
        a[(2, 2)] = -1.0;
        let err = cholesky_blocked(&a).unwrap_err();
        assert_eq!(err.pivot, 2);
    }

    #[test]
    fn shift_regularizes() {
        // rank-deficient H: chol fails at λ=0, succeeds for λ>0
        let x = crate::testutil::random_matrix(10, 4, 3);
        let h = crate::linalg::gemm::syrk_lower(&x);
        let mut hfull = Matrix::zeros(10, 10);
        // embed the rank-4 gram of Xᵀ (10×10 of rank ≤ 4)
        let xt = x; // 10×4 → XXᵀ is 10×10 rank 4
        let g = crate::linalg::gemm::Gemm::default().a_bt(&xt, &xt);
        for i in 0..10 {
            for j in 0..10 {
                hfull[(i, j)] = g[(i, j)];
            }
        }
        let _ = h; // silence
        assert!(cholesky_blocked(&hfull).is_err());
        assert!(cholesky_shifted(&hfull, 1e-3).is_ok());
    }

    #[test]
    fn shifted_into_bitwise_matches_and_reuses_buffer() {
        let x = crate::testutil::random_matrix(90, 40, 31);
        let h = crate::linalg::gemm::syrk_lower(&x);
        let fresh = cholesky_shifted(&h, 0.2).unwrap();
        let mut out = Matrix::zeros(40, 40); // right-sized: must not realloc
        let ptr = out.as_slice().as_ptr();
        cholesky_shifted_into(&h, 0.2, &mut out).unwrap();
        assert_eq!(out.as_slice(), fresh.as_slice());
        assert_eq!(out.as_slice().as_ptr(), ptr, "factor buffer must be reused");
        // reuse with different λ — previous contents must not leak
        let fresh2 = cholesky_shifted(&h, 0.9).unwrap();
        cholesky_shifted_into(&h, 0.9, &mut out).unwrap();
        assert_eq!(out.as_slice(), fresh2.as_slice());
    }

    /// The regression pinned by the packed rewrite: at any panel width, the
    /// factorization is bitwise identical across worker counts 1/2/4 (and to
    /// the serial kernel).
    #[test]
    fn pooled_factorization_bitwise_matches_serial() {
        use crate::coordinator::pool::WorkerPool;
        let a = random_spd(150, 1e4, 11);
        for workers in [1, 2, 4] {
            let pool = WorkerPool::new(workers);
            for block in [16, 32, 64] {
                let mut serial = a.clone();
                cholesky_in_place(&mut serial, block).unwrap();
                let mut pooled = a.clone();
                cholesky_in_place_pooled(&mut pooled, block, &pool).unwrap();
                assert_eq!(
                    serial.max_abs_diff(&pooled),
                    0.0,
                    "pooled factor differs at workers={workers} block={block}"
                );
            }
        }
    }

    /// Odd panel widths (not multiples of the micro-kernel MR/NR or the TRSM
    /// column block) must keep the bitwise thread-count invariance.
    #[test]
    fn pooled_bitwise_invariance_at_odd_blocks() {
        use crate::coordinator::pool::WorkerPool;
        let a = random_spd(131, 1e4, 17);
        for block in [5, 23, 50] {
            let mut serial = a.clone();
            cholesky_in_place(&mut serial, block).unwrap();
            for workers in [2, 3, 4] {
                let pool = WorkerPool::new(workers);
                let mut pooled = a.clone();
                cholesky_in_place_pooled(&mut pooled, block, &pool).unwrap();
                assert_eq!(
                    serial.max_abs_diff(&pooled),
                    0.0,
                    "differs at workers={workers} block={block}"
                );
            }
        }
    }

    #[test]
    fn pooled_shifted_matches_serial_shifted() {
        use crate::coordinator::pool::WorkerPool;
        let x = crate::testutil::random_matrix(220, 130, 21);
        let h = crate::linalg::gemm::syrk_lower(&x);
        let pool = WorkerPool::new(3);
        let serial = cholesky_shifted(&h, 0.37).unwrap();
        let pooled = cholesky_shifted_pooled(&h, 0.37, &pool).unwrap();
        assert_eq!(serial.max_abs_diff(&pooled), 0.0);
    }

    #[test]
    fn pooled_rejects_indefinite_like_serial() {
        use crate::coordinator::pool::WorkerPool;
        let pool = WorkerPool::new(2);
        let mut a = Matrix::eye(200);
        a[(150, 150)] = -1.0;
        let mut p = a.clone();
        let err = cholesky_in_place_pooled(&mut p, 32, &pool).unwrap_err();
        assert_eq!(err.pivot, 150);
    }

    /// Rung-3 helper: plain shift success is bitwise the hot path with zero
    /// extra; an indefinite-at-λ problem recovers with a recorded extra
    /// shift; a hopeless problem (negative diagonal far beyond any bounded
    /// shift) returns the last error instead of looping forever.
    #[test]
    fn shifted_retry_ladder_semantics() {
        // success on first try: bitwise cholesky_shifted_into, no extra
        let x = crate::testutil::random_matrix(60, 24, 5);
        let h = crate::linalg::gemm::syrk_lower(&x);
        let mut out = Matrix::zeros(0, 0);
        let outcome = cholesky_shifted_retry_into(&h, 0.3, &mut out, 4, 10.0).unwrap();
        assert_eq!(
            outcome,
            ShiftOutcome {
                extra_shift: 0.0,
                attempts: 0
            }
        );
        let mut direct = Matrix::zeros(0, 0);
        cholesky_shifted_into(&h, 0.3, &mut direct).unwrap();
        assert_eq!(out.as_slice(), direct.as_slice());

        // rank-deficient at λ=0: the growing shift must rescue it and
        // report a positive extra
        let xt = crate::testutil::random_matrix(10, 4, 3);
        let g = crate::linalg::gemm::Gemm::default().a_bt(&xt, &xt); // 10×10 rank ≤ 4
        let outcome = cholesky_shifted_retry_into(&g, 0.0, &mut out, 8, 10.0).unwrap();
        assert!(outcome.extra_shift > 0.0);
        assert!(outcome.attempts >= 1);
        // the factor really factors G + extra·I
        let rec = gemm(&out, &out.transpose());
        let target = g.add_diag(outcome.extra_shift);
        assert_matrix_close(&rec, &target, 1e-6);

        // hopeless: a large negative diagonal entry survives every bounded
        // retry → the last error comes back
        let mut bad = Matrix::eye(6);
        bad[(3, 3)] = -1e9;
        let err = cholesky_shifted_retry_into(&bad, 1e-3, &mut out, 3, 10.0).unwrap_err();
        assert_eq!(err.pivot, 3);
    }

    #[test]
    fn factor_is_lower_triangular() {
        let a = random_spd(17, 100.0, 4);
        let l = cholesky_blocked(&a).unwrap();
        for i in 0..17 {
            for j in (i + 1)..17 {
                assert_eq!(l[(i, j)], 0.0);
            }
        }
    }
}
