//! Dense linear-algebra substrate.
//!
//! The paper assumes a multithreaded BLAS-3/LAPACK underneath ("maximally
//! exploiting modern hardware using high performance BLAS-3 software", §1).
//! Nothing of the sort exists in the offline crate set, so this module builds
//! the pieces from scratch, in the same cache-blocked style:
//!
//! - [`matrix`] — the row-major `Matrix` type and views
//! - [`kernel`] — the packed, register-blocked micro-kernel engine every
//!   BLAS-3 product runs on (pack buffers in a per-thread arena, fixed
//!   partition-independent accumulation schedule)
//! - [`gemm`] — blocked matmul / syrk / matvec (the BLAS-3 entry points,
//!   packed-kernel backed; the legacy loops live on in `gemm::reference`)
//! - [`cholesky`] — blocked right-looking Cholesky (LAPACK `potrf` shape)
//! - [`chud`] — blocked rank-1/rank-k Cholesky update (Givens) and downdate
//!   (hyperbolic rotations), chained in rank chunks: perturb an existing
//!   factor at `O(k·d²)` instead of refactorizing — the leave-one-out,
//!   factor-level k-fold ([`chud::downdate_rank_k`]) and streaming-data
//!   kernel
//! - [`trust`] — factor drift budgets: every reused factor carries a cheap
//!   running upper bound on `‖L·Lᵀ − (G + λI)‖_F` accumulated from the
//!   rotation identities; a configurable budget forces refactorization
//! - [`triangular`] — forward/backward substitution and block TRSM
//! - [`scratch`] — the per-worker solver scratch arena (factor, eval and
//!   solve buffers reused across sweep tasks)
//! - [`qr`] — Householder QR (thin Q), used by the randomized SVD
//! - [`svd`] — one-sided Jacobi SVD (the paper's `SVD` baseline)
//! - [`lanczos`] — Lanczos-bidiagonalization truncated SVD (`t-SVD` baseline)
//! - [`randomized`] — Halko–Martinsson–Tropp randomized SVD (`r-SVD` baseline)
//! - [`norms`] — Frobenius/spectral norms and condition estimates
//!
//! Everything is `f64`: the native path is the correctness reference the
//! fp32 HLO path is compared against.

pub mod cholesky;
pub mod chud;
pub mod gemm;
pub mod kernel;
pub mod lanczos;
pub mod lu;
pub mod matrix;
pub mod norms;
pub mod qr;
pub mod randomized;
pub mod scratch;
pub mod svd;
pub mod triangular;
pub mod trust;

pub use cholesky::{cholesky_blocked, cholesky_in_place, CholeskyError};
pub use chud::{
    chol_downdate, chol_downdate_rank1, chol_downdate_rank1_tracked, chol_downdate_tracked,
    chol_update, chol_update_rank1, chol_update_rank1_tracked, chol_update_tracked,
    downdate_rank_k, downdate_rank_k_pregathered, downdate_rank_k_pregathered_tracked,
    downdate_rank_k_tracked, gather_update_block,
};
pub use kernel::{active_backend, available_backends, force_backend, KernelBackend};
pub use gemm::{gemm, gemv, syrk_lower, Gemm};
pub use matrix::Matrix;
pub use norms::{fro_norm, spectral_norm_est};
pub use qr::householder_qr_thin;
pub use randomized::randomized_svd;
pub use scratch::Scratch;
pub use svd::jacobi_svd;
pub use triangular::{solve_cholesky, trsm_left_lower, trsv_lower, trsv_upper};
pub use trust::{FactorTrust, RotationStats, TrustBudget};
