//! The packed, register-blocked GEMM micro-kernel engine (BLIS-style).
//!
//! Every BLAS-3 entry point in [`super::gemm`] — and the GEMM-shaped updates
//! inside the blocked Cholesky/TRSM — funnels into the crate-internal
//! `gemm_into` driver, which:
//!
//! 1. **packs** panels of both operands into contiguous, cache-line-aligned
//!    scratch buffers (transposition is absorbed by the packing, so the
//!    micro-kernel never sees a strided operand);
//! 2. drives an `MR×NR` register-tile **micro-kernel** whose inner loop is a
//!    rank-1 update of a `[[f64; NR]; MR]` accumulator block — the shape
//!    LLVM auto-vectorizes into broadcast-multiply-accumulate over the full
//!    output tile (12 memory ops per 64 flops, vs ~3 per 2 for the legacy
//!    axpy loops kept in [`super::gemm::reference`]);
//! 3. blocks the three loops at `MC×KC×NC` so the packed A panel stays
//!    L2-resident and the B sliver streams through L1.
//!
//! ## Determinism schedule
//!
//! The sweep engine's bit-identical-at-any-thread-count guarantee requires
//! that tiling the *output row space* across workers never change a single
//! bit. The engine therefore fixes the accumulation schedule per output
//! element, independent of how rows/columns are partitioned across calls:
//!
//! - the `k` dimension is chunked into `KC` blocks as a pure function of
//!   the call's `k` extent (`0..KC, KC..2KC, …` within the call);
//! - within a chunk, each output element owns exactly one scalar register
//!   accumulator, added to in strictly ascending `k` order;
//! - chunk partials are folded into C in ascending chunk order.
//!
//! An output element's value is thus a pure function of its row of op(A),
//! its column of op(B), and the call's `k` extent — **rows and columns**
//! can be regrouped into arbitrary panels (e.g.
//! [`super::gemm::Gemm::a_bt_rows`] fanned across the pool) without
//! perturbing any result bit. The guarantee does *not* extend to splitting
//! the `k` dimension across separate accumulate calls: chunk boundaries
//! would shift relative to the full product and the fold order would
//! change. Every caller in this crate passes its full `k` extent per
//! product. Pinned by `a_bt_rows_bitwise_matches_full_product` and the
//! pooled-Cholesky bitwise tests.
//!
//! ## Vectorized micro-kernels and the lane-order contract
//!
//! The micro-kernel ships in three interchangeable backends behind the
//! [`KernelBackend`] dispatch seam: the portable scalar loop (always
//! available; the conformance oracle), an AVX2 path (two 256-bit f64 lanes
//! per accumulator row), and a NEON path (four 128-bit lanes per row). All
//! three are **bit-identical** by construction:
//!
//! - each output element owns exactly one lane slot of one accumulator
//!   vector for the whole `kc` loop — vectorization is across the NR
//!   *columns* of a tile, never across `k`, so no horizontal reduction
//!   ever happens and the ascending-`k` schedule is untouched;
//! - lane order is fixed: lane `l` of vector `v` of row `r` is always
//!   output column `v·LANES + l` (documented per backend), so packing,
//!   tiling, and stores address the same elements as the scalar loop;
//! - the arithmetic is **multiply then add** (`_mm256_mul_pd` +
//!   `_mm256_add_pd` / `vmulq_f64` + `vaddq_f64`), *not* FMA: the scalar
//!   kernel performs two roundings per update (Rust never contracts
//!   `a + b * c` into a fused multiply-add), so the vector paths repeat the
//!   exact same two roundings. AVX2 detection still requires the FMA
//!   feature bit (the ISA level this path targets), but the kernel body
//!   deliberately avoids fused contraction to preserve bitwise identity;
//! - there is no scalar tail loop to diverge from the vector body: packing
//!   zero-pads every sliver to full `MR×NR` tiles, so the vector kernel
//!   covers every tile wholly and the pad lanes accumulate exact zeros.
//!
//! The backend is resolved once (env override `PICHOL_KERNEL_BACKEND`,
//! else runtime feature detection) and cached in an atomic; tests may
//! repoint it via [`force_backend`]. Because every backend is bit-identical,
//! a racy repoint mid-run is observationally harmless. The cross-backend
//! guarantee is pinned by `tests/kernel_backends.rs` (scalar-vs-vector
//! bitwise conformance) and the lane-order property test in `gemm.rs`.
//!
//! ## Scratch ownership
//!
//! Pack buffers live in a **thread-local arena** (`PACKS` below): each
//! worker thread of the pool owns one pair of pack buffers (plus a `TMP`
//! output panel for in-place consumers like TRSM), grown on first use and
//! reused for the life of the thread — the steady-state fold×λ sweep packs
//! into warm buffers with zero heap allocation. The solver-side half of the
//! per-worker arena is [`super::scratch::Scratch`], threaded through
//! [`crate::coordinator::pool::WorkerPool`] explicitly.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU8, Ordering};

/// Micro-kernel register-tile rows (per A sliver).
pub const MR: usize = 4;
/// Micro-kernel register-tile columns (per B sliver).
pub const NR: usize = 8;
/// k-dimension cache block (absolute-index chunking — see module docs).
pub const KC: usize = 256;
/// Row cache block (packed A panel: `MC×KC` ≤ 256 KiB, L2-resident).
pub const MC: usize = 128;
/// Column cache block (packed B panel: `KC×NC` streamed sliver by sliver).
pub const NC: usize = 512;

/// Cache-line alignment (bytes) for the pack buffers.
const ALIGN: usize = 64;

/// A micro-kernel implementation (see "Vectorized micro-kernels" in the
/// module docs). All backends share the scalar kernel's signature and its
/// exact per-element rounding sequence, so they are freely interchangeable.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum KernelBackend {
    /// Portable scalar loop — always available; the conformance oracle.
    Scalar,
    /// AVX2 (x86-64): two 256-bit f64 lanes per accumulator row.
    Avx2,
    /// NEON (aarch64): four 128-bit f64 lanes per accumulator row.
    Neon,
}

impl KernelBackend {
    /// Stable lowercase name (also the `PICHOL_KERNEL_BACKEND` spelling).
    pub fn name(self) -> &'static str {
        match self {
            KernelBackend::Scalar => "scalar",
            KernelBackend::Avx2 => "avx2",
            KernelBackend::Neon => "neon",
        }
    }

    /// Parse a backend name (case-insensitive). `None` for unknown names.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "scalar" => Some(KernelBackend::Scalar),
            "avx2" => Some(KernelBackend::Avx2),
            "neon" => Some(KernelBackend::Neon),
            _ => None,
        }
    }

    /// Whether this backend can run on the current host (compile target
    /// *and* runtime CPU features). Scalar is always available.
    pub fn is_available(self) -> bool {
        match self {
            KernelBackend::Scalar => true,
            KernelBackend::Avx2 => {
                #[cfg(target_arch = "x86_64")]
                {
                    std::arch::is_x86_feature_detected!("avx2")
                        && std::arch::is_x86_feature_detected!("fma")
                }
                #[cfg(not(target_arch = "x86_64"))]
                {
                    false
                }
            }
            KernelBackend::Neon => {
                #[cfg(target_arch = "aarch64")]
                {
                    std::arch::is_aarch64_feature_detected!("neon")
                }
                #[cfg(not(target_arch = "aarch64"))]
                {
                    false
                }
            }
        }
    }

    /// The fastest backend available on this host.
    pub fn detect() -> Self {
        if KernelBackend::Avx2.is_available() {
            KernelBackend::Avx2
        } else if KernelBackend::Neon.is_available() {
            KernelBackend::Neon
        } else {
            KernelBackend::Scalar
        }
    }
}

/// Every backend available on this host, scalar first.
pub fn available_backends() -> Vec<KernelBackend> {
    [KernelBackend::Scalar, KernelBackend::Avx2, KernelBackend::Neon]
        .into_iter()
        .filter(|b| b.is_available())
        .collect()
}

/// The cached active backend: 0 = unresolved, else `encode(backend)`.
static ACTIVE: AtomicU8 = AtomicU8::new(0);

fn encode(b: KernelBackend) -> u8 {
    match b {
        KernelBackend::Scalar => 1,
        KernelBackend::Avx2 => 2,
        KernelBackend::Neon => 3,
    }
}

fn decode(v: u8) -> Option<KernelBackend> {
    match v {
        1 => Some(KernelBackend::Scalar),
        2 => Some(KernelBackend::Avx2),
        3 => Some(KernelBackend::Neon),
        _ => None,
    }
}

/// First-use resolution: honor `PICHOL_KERNEL_BACKEND` when it names an
/// available backend, else fall back to feature detection (an unknown or
/// unavailable name never panics — the scalar path always exists).
fn init_backend() -> KernelBackend {
    if let Ok(v) = std::env::var("PICHOL_KERNEL_BACKEND") {
        if let Some(b) = KernelBackend::parse(&v) {
            if b.is_available() {
                return b;
            }
        }
    }
    KernelBackend::detect()
}

/// The micro-kernel backend in effect, resolving it on first call.
pub fn active_backend() -> KernelBackend {
    match decode(ACTIVE.load(Ordering::Relaxed)) {
        Some(b) => b,
        None => {
            let b = init_backend();
            ACTIVE.store(encode(b), Ordering::Relaxed);
            b
        }
    }
}

/// Repoint the active backend (tests; `--kernel-backend` override). Errors
/// if the backend is not available on this host. Safe to call while other
/// threads compute: all backends are bit-identical, so an in-flight GEMM
/// finishing on the old backend produces the same bits.
pub fn force_backend(b: KernelBackend) -> Result<(), String> {
    if !b.is_available() {
        return Err(format!(
            "kernel backend '{}' is not available on this host",
            b.name()
        ));
    }
    ACTIVE.store(encode(b), Ordering::Relaxed);
    Ok(())
}

/// The micro-kernel dispatch seam: one fn pointer, resolved per
/// [`gemm_into`] call from the active backend and threaded through the
/// macro kernel. `unsafe` because the SIMD variants require their CPU
/// feature to be present — guaranteed by [`KernelBackend::is_available`]
/// gating in [`force_backend`]/[`init_backend`].
type MicroFn = unsafe fn(usize, &[f64], &[f64], &mut [[f64; NR]; MR]);

fn micro_fn(b: KernelBackend) -> MicroFn {
    match b {
        KernelBackend::Scalar => micro_kernel_scalar as MicroFn,
        #[cfg(target_arch = "x86_64")]
        KernelBackend::Avx2 => avx2::micro_kernel,
        #[cfg(target_arch = "aarch64")]
        KernelBackend::Neon => neon::micro_kernel,
        // Unreachable: is_available() gates selection per target arch.
        #[allow(unreachable_patterns)]
        _ => micro_kernel_scalar as MicroFn,
    }
}

/// One operand of the packed driver: a row-major buffer viewed either
/// normally or transposed, with a (row, col) offset. The *effective* matrix
/// element `E[r][c]` is:
///
/// - `N`: `data[(r0 + r) * stride + c0 + c]`
/// - `T`: `data[(r0 + c) * stride + c0 + r]`
#[derive(Clone, Copy)]
pub(crate) enum Src<'a> {
    N {
        data: &'a [f64],
        stride: usize,
        r0: usize,
        c0: usize,
    },
    T {
        data: &'a [f64],
        stride: usize,
        r0: usize,
        c0: usize,
    },
}

impl<'a> Src<'a> {
    /// Normal view of a whole row-major buffer.
    pub(crate) fn n(data: &'a [f64], stride: usize) -> Self {
        Src::N {
            data,
            stride,
            r0: 0,
            c0: 0,
        }
    }

    /// Transposed view of a whole row-major buffer.
    pub(crate) fn t(data: &'a [f64], stride: usize) -> Self {
        Src::T {
            data,
            stride,
            r0: 0,
            c0: 0,
        }
    }
}

/// How a computed tile is folded into C.
#[derive(Clone, Copy, PartialEq, Eq)]
pub(crate) enum Acc {
    /// Overwrite C (the first k-chunk stores, later chunks add).
    Set,
    /// `C += A·B`.
    Add,
    /// `C -= A·B`.
    Sub,
}

/// A `Vec<f64>` whose exposed slice starts on a cache-line boundary.
struct AlignedBuf {
    raw: Vec<f64>,
    off: usize,
    len: usize,
}

impl AlignedBuf {
    const fn new() -> Self {
        Self {
            raw: Vec::new(),
            off: 0,
            len: 0,
        }
    }

    /// Ensure capacity for `len` aligned f64s; contents are unspecified.
    fn ensure(&mut self, len: usize) -> &mut [f64] {
        let pad = ALIGN / std::mem::size_of::<f64>();
        if self.raw.len() < len + pad {
            self.raw.resize(len + pad, 0.0);
            let addr = self.raw.as_ptr() as usize;
            self.off = (ALIGN - addr % ALIGN) % ALIGN / std::mem::size_of::<f64>();
        }
        self.len = len;
        &mut self.raw[self.off..self.off + len]
    }

    fn slice(&self) -> &[f64] {
        &self.raw[self.off..self.off + self.len]
    }
}

thread_local! {
    /// Per-thread pack arena: (A panel, B panel). Grown on first use, then
    /// reused for the life of the thread (= the life of a pool worker).
    static PACKS: RefCell<(AlignedBuf, AlignedBuf)> =
        const { RefCell::new((AlignedBuf::new(), AlignedBuf::new())) };

    /// Per-thread output panel for consumers whose destination aliases an
    /// operand (blocked TRSM, the Cholesky trailing update).
    static TMP: RefCell<Vec<f64>> = const { RefCell::new(Vec::new()) };
}

/// Run `f` over a `len`-long slice of the per-thread temporary output panel
/// (contents unspecified on entry; no allocation once the panel is warm).
/// Reentrant calls are not allowed; [`gemm_into`] may be called inside `f`.
pub(crate) fn with_tmp<R>(len: usize, f: impl FnOnce(&mut [f64]) -> R) -> R {
    TMP.with(|cell| {
        let mut guard = cell.borrow_mut();
        let buf = &mut *guard;
        if buf.len() < len {
            buf.resize(len, 0.0);
        }
        f(&mut buf[..len])
    })
}

/// Pack the `mc×kc` panel of effective-A at (ic, pc) into MR-row slivers,
/// sliver-major, column-major within a sliver (`buf[s][p][r]`), zero-padding
/// the tail sliver to MR rows.
fn pack_a(a: &Src<'_>, ic: usize, mc: usize, pc: usize, kc: usize, buf: &mut [f64]) {
    let slivers = mc.div_ceil(MR);
    match *a {
        Src::N {
            data,
            stride,
            r0,
            c0,
        } => {
            for s in 0..slivers {
                let base = s * kc * MR;
                let rows = MR.min(mc - s * MR);
                for r in 0..rows {
                    let src = &data[(r0 + ic + s * MR + r) * stride + c0 + pc..][..kc];
                    for (p, &v) in src.iter().enumerate() {
                        buf[base + p * MR + r] = v;
                    }
                }
            }
        }
        Src::T {
            data,
            stride,
            r0,
            c0,
        } => {
            for s in 0..slivers {
                let base = s * kc * MR;
                let rows = MR.min(mc - s * MR);
                for p in 0..kc {
                    let src = &data[(r0 + pc + p) * stride + c0 + ic + s * MR..][..rows];
                    buf[base + p * MR..base + p * MR + rows].copy_from_slice(src);
                }
            }
        }
    }
    // zero the pad lanes of the tail sliver so padded rows accumulate zeros
    let tail_rows = mc - (slivers - 1) * MR;
    if tail_rows < MR {
        let base = (slivers - 1) * kc * MR;
        for p in 0..kc {
            for r in tail_rows..MR {
                buf[base + p * MR + r] = 0.0;
            }
        }
    }
}

/// Pack the `kc×nc` panel of effective-B at (pc, jc) into NR-column slivers,
/// sliver-major, row-major within a sliver (`buf[s][p][c]`), zero-padding
/// the tail sliver to NR columns.
fn pack_b(b: &Src<'_>, jc: usize, nc: usize, pc: usize, kc: usize, buf: &mut [f64]) {
    let slivers = nc.div_ceil(NR);
    match *b {
        Src::N {
            data,
            stride,
            r0,
            c0,
        } => {
            for s in 0..slivers {
                let base = s * kc * NR;
                let cols = NR.min(nc - s * NR);
                for p in 0..kc {
                    let src = &data[(r0 + pc + p) * stride + c0 + jc + s * NR..][..cols];
                    let dst = &mut buf[base + p * NR..base + (p + 1) * NR];
                    dst[..cols].copy_from_slice(src);
                    for v in &mut dst[cols..] {
                        *v = 0.0;
                    }
                }
            }
        }
        Src::T {
            data,
            stride,
            r0,
            c0,
        } => {
            for s in 0..slivers {
                let base = s * kc * NR;
                let cols = NR.min(nc - s * NR);
                for j in 0..cols {
                    let src = &data[(r0 + jc + s * NR + j) * stride + c0 + pc..][..kc];
                    for (p, &v) in src.iter().enumerate() {
                        buf[base + p * NR + j] = v;
                    }
                }
                if cols < NR {
                    for p in 0..kc {
                        for j in cols..NR {
                            buf[base + p * NR + j] = 0.0;
                        }
                    }
                }
            }
        }
    }
}

/// The scalar register-tile micro-kernel: `acc += Aᵖ·Bᵖ` over one packed
/// sliver pair. `a` is kc×MR column-major, `b` is kc×NR row-major; each of
/// the MR×NR accumulators is updated in strictly ascending `p` order (the
/// determinism schedule — see module docs). Every other backend must
/// reproduce this kernel's per-element rounding sequence bit-for-bit:
/// one multiply rounding + one add rounding per (element, p).
#[inline(always)]
fn micro_kernel_scalar(kc: usize, a: &[f64], b: &[f64], acc: &mut [[f64; NR]; MR]) {
    for p in 0..kc {
        let av: &[f64; MR] = a[p * MR..p * MR + MR].try_into().unwrap();
        let bv: &[f64; NR] = b[p * NR..p * NR + NR].try_into().unwrap();
        for r in 0..MR {
            let ar = av[r];
            let row = &mut acc[r];
            for c in 0..NR {
                row[c] += ar * bv[c];
            }
        }
    }
}

/// AVX2 micro-kernel. Lane-order contract: row `r`'s accumulator is two
/// `__m256d` vectors; vector `v`, lane `l` is always output column
/// `4·v + l` (columns 0–3 in the low vector, 4–7 in the high one). Each
/// element's update is `_mm256_mul_pd` then `_mm256_add_pd` — the same two
/// roundings as the scalar kernel's `row[c] += ar * bv[c]`, never a fused
/// multiply-add — and `p` advances in the same strictly ascending order, so
/// the output is bit-identical to [`micro_kernel_scalar`]. No horizontal
/// reduction occurs: lanes map 1:1 onto output elements for the whole loop.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::{MR, NR};
    use std::arch::x86_64::*;

    /// # Safety
    /// Requires AVX2 at runtime (gated by `KernelBackend::is_available`);
    /// `a` must hold `kc*MR` and `b` `kc*NR` elements (packed slivers).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn micro_kernel(
        kc: usize,
        a: &[f64],
        b: &[f64],
        acc: &mut [[f64; NR]; MR],
    ) {
        debug_assert!(a.len() >= kc * MR && b.len() >= kc * NR);
        unsafe {
            let mut vacc = [[_mm256_setzero_pd(); 2]; MR];
            for (lanes, row) in vacc.iter_mut().zip(acc.iter()) {
                lanes[0] = _mm256_loadu_pd(row.as_ptr());
                lanes[1] = _mm256_loadu_pd(row.as_ptr().add(4));
            }
            for p in 0..kc {
                let bp = b.as_ptr().add(p * NR);
                let b_lo = _mm256_loadu_pd(bp);
                let b_hi = _mm256_loadu_pd(bp.add(4));
                let ap = a.as_ptr().add(p * MR);
                for (r, lanes) in vacc.iter_mut().enumerate() {
                    let ar = _mm256_set1_pd(*ap.add(r));
                    // mul then add — NOT _mm256_fmadd_pd — to match the
                    // scalar kernel's two roundings per element exactly.
                    lanes[0] = _mm256_add_pd(lanes[0], _mm256_mul_pd(ar, b_lo));
                    lanes[1] = _mm256_add_pd(lanes[1], _mm256_mul_pd(ar, b_hi));
                }
            }
            for (lanes, row) in vacc.iter().zip(acc.iter_mut()) {
                _mm256_storeu_pd(row.as_mut_ptr(), lanes[0]);
                _mm256_storeu_pd(row.as_mut_ptr().add(4), lanes[1]);
            }
        }
    }
}

/// NEON micro-kernel. Lane-order contract: row `r`'s accumulator is four
/// `float64x2_t` vectors; vector `v`, lane `l` is always output column
/// `2·v + l`. Updates are `vmulq_f64` then `vaddq_f64` (two roundings, no
/// fused contraction) in strictly ascending `p`, bit-identical to
/// [`micro_kernel_scalar`]; no horizontal reduction occurs.
#[cfg(target_arch = "aarch64")]
mod neon {
    use super::{MR, NR};
    use std::arch::aarch64::*;

    /// # Safety
    /// Requires NEON at runtime (gated by `KernelBackend::is_available`);
    /// `a` must hold `kc*MR` and `b` `kc*NR` elements (packed slivers).
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn micro_kernel(
        kc: usize,
        a: &[f64],
        b: &[f64],
        acc: &mut [[f64; NR]; MR],
    ) {
        debug_assert!(a.len() >= kc * MR && b.len() >= kc * NR);
        unsafe {
            let mut vacc = [[vdupq_n_f64(0.0); 4]; MR];
            for (lanes, row) in vacc.iter_mut().zip(acc.iter()) {
                for (v, lane) in lanes.iter_mut().enumerate() {
                    *lane = vld1q_f64(row.as_ptr().add(2 * v));
                }
            }
            for p in 0..kc {
                let bp = b.as_ptr().add(p * NR);
                let bv = [
                    vld1q_f64(bp),
                    vld1q_f64(bp.add(2)),
                    vld1q_f64(bp.add(4)),
                    vld1q_f64(bp.add(6)),
                ];
                let ap = a.as_ptr().add(p * MR);
                for (r, lanes) in vacc.iter_mut().enumerate() {
                    let ar = vdupq_n_f64(*ap.add(r));
                    for (lane, &bl) in lanes.iter_mut().zip(bv.iter()) {
                        // mul then add — NOT vfmaq_f64 — to match the
                        // scalar kernel's two roundings per element.
                        *lane = vaddq_f64(*lane, vmulq_f64(ar, bl));
                    }
                }
            }
            for (lanes, row) in vacc.iter().zip(acc.iter_mut()) {
                for (v, &lane) in lanes.iter().enumerate() {
                    vst1q_f64(row.as_mut_ptr().add(2 * v), lane);
                }
            }
        }
    }
}

/// Sweep the packed panels with the micro-kernel `mk`, folding each tile
/// into C at (row0, col0) according to `acc`.
#[allow(clippy::too_many_arguments)]
fn macro_kernel(
    mc: usize,
    nc: usize,
    kc: usize,
    pa: &[f64],
    pb: &[f64],
    c: &mut [f64],
    c_stride: usize,
    row0: usize,
    col0: usize,
    acc: Acc,
    mk: MicroFn,
) {
    for js in 0..nc.div_ceil(NR) {
        let bs = &pb[js * kc * NR..][..kc * NR];
        let cols = NR.min(nc - js * NR);
        for is in 0..mc.div_ceil(MR) {
            let asl = &pa[is * kc * MR..][..kc * MR];
            let rows = MR.min(mc - is * MR);
            let mut tile = [[0.0f64; NR]; MR];
            // SAFETY: `mk` was resolved from a backend that passed
            // `is_available()`, and the packed slivers have full
            // `kc*MR`/`kc*NR` extents (zero-padded tails).
            unsafe { mk(kc, asl, bs, &mut tile) };
            for (r, trow) in tile.iter().enumerate().take(rows) {
                let dst = &mut c[(row0 + is * MR + r) * c_stride + col0 + js * NR..][..cols];
                let src = &trow[..cols];
                match acc {
                    Acc::Set => dst.copy_from_slice(src),
                    Acc::Add => {
                        for (d, &s) in dst.iter_mut().zip(src) {
                            *d += s;
                        }
                    }
                    Acc::Sub => {
                        for (d, &s) in dst.iter_mut().zip(src) {
                            *d -= s;
                        }
                    }
                }
            }
        }
    }
}

/// Packed GEMM driver: fold `op(A)·op(B)` (an `m×k` by `k×n` product) into
/// the `m×n` region of `c` at (c_r0, c_c0), row stride `c_stride`.
///
/// Handles all degenerate shapes (`m`, `n` or `k` zero; `k == 0` with
/// [`Acc::Set`] zero-fills the region). Pack buffers come from the
/// per-thread arena; the call performs no heap allocation once the arena is
/// warm. Must not be called reentrantly from inside another `gemm_into` (it
/// never is — this is leaf code).
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm_into(
    m: usize,
    n: usize,
    k: usize,
    a: Src<'_>,
    b: Src<'_>,
    c: &mut [f64],
    c_stride: usize,
    c_r0: usize,
    c_c0: usize,
    acc: Acc,
) {
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        if acc == Acc::Set {
            for i in 0..m {
                for v in &mut c[(c_r0 + i) * c_stride + c_c0..][..n] {
                    *v = 0.0;
                }
            }
        }
        return;
    }
    // Resolve the micro-kernel once per call: one relaxed atomic load,
    // then a plain fn pointer all the way down.
    let mk = micro_fn(active_backend());
    PACKS.with(|cell| {
        let mut packs = cell.borrow_mut();
        let (pa, pb) = &mut *packs;
        for jc in (0..n).step_by(NC) {
            let nc = NC.min(n - jc);
            let mut first = true;
            for pc in (0..k).step_by(KC) {
                let kc = KC.min(k - pc);
                let pbuf = pb.ensure(nc.div_ceil(NR) * kc * NR);
                pack_b(&b, jc, nc, pc, kc, pbuf);
                let eff = match acc {
                    Acc::Set if !first => Acc::Add,
                    other => other,
                };
                for ic in (0..m).step_by(MC) {
                    let mc = MC.min(m - ic);
                    let pbuf_a = pa.ensure(mc.div_ceil(MR) * kc * MR);
                    pack_a(&a, ic, mc, pc, kc, pbuf_a);
                    macro_kernel(
                        mc,
                        nc,
                        kc,
                        pa.slice(),
                        pb.slice(),
                        c,
                        c_stride,
                        c_r0 + ic,
                        c_c0 + jc,
                        eff,
                        mk,
                    );
                }
                first = false;
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aligned_buf_is_cache_line_aligned() {
        let mut b = AlignedBuf::new();
        let s = b.ensure(100);
        assert_eq!(s.as_ptr() as usize % ALIGN, 0);
        s[99] = 1.0;
        // growing keeps alignment
        let s2 = b.ensure(10_000);
        assert_eq!(s2.as_ptr() as usize % ALIGN, 0);
    }

    #[test]
    fn tiny_product_matches_by_hand() {
        // [1 2; 3 4] · [5 6; 7 8] = [19 22; 43 50]
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [5.0, 6.0, 7.0, 8.0];
        let mut c = [0.0; 4];
        gemm_into(2, 2, 2, Src::n(&a, 2), Src::n(&b, 2), &mut c, 2, 0, 0, Acc::Set);
        assert_eq!(c, [19.0, 22.0, 43.0, 50.0]);
        // Sub folds the product back out
        gemm_into(2, 2, 2, Src::n(&a, 2), Src::n(&b, 2), &mut c, 2, 0, 0, Acc::Sub);
        assert_eq!(c, [0.0; 4]);
    }

    #[test]
    fn transposed_views_match_normal() {
        // E = [1 2; 3 4]ᵀ via T view of the same buffer
        let a = [1.0, 2.0, 3.0, 4.0];
        let eye = [1.0, 0.0, 0.0, 1.0];
        let mut c = [0.0; 4];
        gemm_into(2, 2, 2, Src::t(&a, 2), Src::n(&eye, 2), &mut c, 2, 0, 0, Acc::Set);
        assert_eq!(c, [1.0, 3.0, 2.0, 4.0]);
    }

    #[test]
    fn k_zero_set_clears_region_and_add_is_noop() {
        let a: [f64; 0] = [];
        let b: [f64; 0] = [];
        let mut c = [7.0; 6];
        gemm_into(2, 3, 0, Src::n(&a, 1), Src::n(&b, 3), &mut c, 3, 0, 0, Acc::Add);
        assert_eq!(c, [7.0; 6]);
        gemm_into(2, 3, 0, Src::n(&a, 1), Src::n(&b, 3), &mut c, 3, 0, 0, Acc::Set);
        assert_eq!(c, [0.0; 6]);
    }

    #[test]
    fn backend_names_parse_roundtrip() {
        for b in [KernelBackend::Scalar, KernelBackend::Avx2, KernelBackend::Neon] {
            assert_eq!(KernelBackend::parse(b.name()), Some(b));
            assert_eq!(KernelBackend::parse(&b.name().to_uppercase()), Some(b));
        }
        assert_eq!(KernelBackend::parse("sse9000"), None);
    }

    #[test]
    fn scalar_always_listed_and_detect_is_available() {
        let avail = available_backends();
        assert_eq!(avail[0], KernelBackend::Scalar);
        assert!(KernelBackend::detect().is_available());
        assert!(avail.contains(&active_backend()));
    }

    #[test]
    fn force_backend_rejects_unavailable() {
        // At most one SIMD backend exists per target arch, so the other
        // one is always unavailable and must be rejected without panic.
        let missing = if cfg!(target_arch = "x86_64") {
            KernelBackend::Neon
        } else {
            KernelBackend::Avx2
        };
        assert!(force_backend(missing).is_err());
    }

    /// Every backend available on this host must reproduce the scalar
    /// kernel bit-for-bit at the `micro_kernel` level, including nonzero
    /// incoming accumulators and pad-lane zeros.
    #[test]
    fn available_micro_kernels_bitwise_match_scalar() {
        let kc = 7;
        let mut rng = crate::prng::Xoshiro256::seed_from(0xBEEF);
        let a: Vec<f64> = (0..kc * MR).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..kc * NR).map(|_| rng.normal()).collect();
        let mut seed_acc = [[0.0f64; NR]; MR];
        for row in &mut seed_acc {
            for v in row.iter_mut() {
                *v = rng.normal();
            }
        }
        let mut oracle = seed_acc;
        micro_kernel_scalar(kc, &a, &b, &mut oracle);
        for backend in available_backends() {
            let mut acc = seed_acc;
            // SAFETY: backend passed is_available(), slices are full-extent.
            unsafe { micro_fn(backend)(kc, &a, &b, &mut acc) };
            for r in 0..MR {
                for c in 0..NR {
                    assert_eq!(
                        acc[r][c].to_bits(),
                        oracle[r][c].to_bits(),
                        "backend {} differs from scalar at ({r},{c})",
                        backend.name()
                    );
                }
            }
        }
    }

    /// Forcing each available backend through the full packed driver gives
    /// bitwise-identical products (restores the detected backend after).
    #[test]
    fn gemm_into_bitwise_identical_across_backends() {
        let (m, n, k) = (13, 11, 9);
        let mut rng = crate::prng::Xoshiro256::seed_from(0xF00D);
        let a: Vec<f64> = (0..m * k).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..k * n).map(|_| rng.normal()).collect();
        let run = |backend| {
            force_backend(backend).unwrap();
            let mut c = vec![0.0; m * n];
            gemm_into(m, n, k, Src::n(&a, k), Src::n(&b, n), &mut c, n, 0, 0, Acc::Set);
            c
        };
        let oracle = run(KernelBackend::Scalar);
        for backend in available_backends() {
            let c = run(backend);
            assert!(
                c.iter().zip(&oracle).all(|(x, y)| x.to_bits() == y.to_bits()),
                "backend {} diverged from scalar",
                backend.name()
            );
        }
        force_backend(KernelBackend::detect()).unwrap();
    }
}
