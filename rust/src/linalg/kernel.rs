//! The packed, register-blocked GEMM micro-kernel engine (BLIS-style).
//!
//! Every BLAS-3 entry point in [`super::gemm`] — and the GEMM-shaped updates
//! inside the blocked Cholesky/TRSM — funnels into the crate-internal
//! `gemm_into` driver, which:
//!
//! 1. **packs** panels of both operands into contiguous, cache-line-aligned
//!    scratch buffers (transposition is absorbed by the packing, so the
//!    micro-kernel never sees a strided operand);
//! 2. drives an `MR×NR` register-tile **micro-kernel** whose inner loop is a
//!    rank-1 update of a `[[f64; NR]; MR]` accumulator block — the shape
//!    LLVM auto-vectorizes into broadcast-multiply-accumulate over the full
//!    output tile (12 memory ops per 64 flops, vs ~3 per 2 for the legacy
//!    axpy loops kept in [`super::gemm::reference`]);
//! 3. blocks the three loops at `MC×KC×NC` so the packed A panel stays
//!    L2-resident and the B sliver streams through L1.
//!
//! ## Determinism schedule
//!
//! The sweep engine's bit-identical-at-any-thread-count guarantee requires
//! that tiling the *output row space* across workers never change a single
//! bit. The engine therefore fixes the accumulation schedule per output
//! element, independent of how rows/columns are partitioned across calls:
//!
//! - the `k` dimension is chunked into `KC` blocks as a pure function of
//!   the call's `k` extent (`0..KC, KC..2KC, …` within the call);
//! - within a chunk, each output element owns exactly one scalar register
//!   accumulator, added to in strictly ascending `k` order;
//! - chunk partials are folded into C in ascending chunk order.
//!
//! An output element's value is thus a pure function of its row of op(A),
//! its column of op(B), and the call's `k` extent — **rows and columns**
//! can be regrouped into arbitrary panels (e.g.
//! [`super::gemm::Gemm::a_bt_rows`] fanned across the pool) without
//! perturbing any result bit. The guarantee does *not* extend to splitting
//! the `k` dimension across separate accumulate calls: chunk boundaries
//! would shift relative to the full product and the fold order would
//! change. Every caller in this crate passes its full `k` extent per
//! product. Pinned by `a_bt_rows_bitwise_matches_full_product` and the
//! pooled-Cholesky bitwise tests.
//!
//! ## Scratch ownership
//!
//! Pack buffers live in a **thread-local arena** (`PACKS` below): each
//! worker thread of the pool owns one pair of pack buffers (plus a `TMP`
//! output panel for in-place consumers like TRSM), grown on first use and
//! reused for the life of the thread — the steady-state fold×λ sweep packs
//! into warm buffers with zero heap allocation. The solver-side half of the
//! per-worker arena is [`super::scratch::Scratch`], threaded through
//! [`crate::coordinator::pool::WorkerPool`] explicitly.

use std::cell::RefCell;

/// Micro-kernel register-tile rows (per A sliver).
pub const MR: usize = 4;
/// Micro-kernel register-tile columns (per B sliver).
pub const NR: usize = 8;
/// k-dimension cache block (absolute-index chunking — see module docs).
pub const KC: usize = 256;
/// Row cache block (packed A panel: `MC×KC` ≤ 256 KiB, L2-resident).
pub const MC: usize = 128;
/// Column cache block (packed B panel: `KC×NC` streamed sliver by sliver).
pub const NC: usize = 512;

/// Cache-line alignment (bytes) for the pack buffers.
const ALIGN: usize = 64;

/// One operand of the packed driver: a row-major buffer viewed either
/// normally or transposed, with a (row, col) offset. The *effective* matrix
/// element `E[r][c]` is:
///
/// - `N`: `data[(r0 + r) * stride + c0 + c]`
/// - `T`: `data[(r0 + c) * stride + c0 + r]`
#[derive(Clone, Copy)]
pub(crate) enum Src<'a> {
    N {
        data: &'a [f64],
        stride: usize,
        r0: usize,
        c0: usize,
    },
    T {
        data: &'a [f64],
        stride: usize,
        r0: usize,
        c0: usize,
    },
}

impl<'a> Src<'a> {
    /// Normal view of a whole row-major buffer.
    pub(crate) fn n(data: &'a [f64], stride: usize) -> Self {
        Src::N {
            data,
            stride,
            r0: 0,
            c0: 0,
        }
    }

    /// Transposed view of a whole row-major buffer.
    pub(crate) fn t(data: &'a [f64], stride: usize) -> Self {
        Src::T {
            data,
            stride,
            r0: 0,
            c0: 0,
        }
    }
}

/// How a computed tile is folded into C.
#[derive(Clone, Copy, PartialEq, Eq)]
pub(crate) enum Acc {
    /// Overwrite C (the first k-chunk stores, later chunks add).
    Set,
    /// `C += A·B`.
    Add,
    /// `C -= A·B`.
    Sub,
}

/// A `Vec<f64>` whose exposed slice starts on a cache-line boundary.
struct AlignedBuf {
    raw: Vec<f64>,
    off: usize,
    len: usize,
}

impl AlignedBuf {
    const fn new() -> Self {
        Self {
            raw: Vec::new(),
            off: 0,
            len: 0,
        }
    }

    /// Ensure capacity for `len` aligned f64s; contents are unspecified.
    fn ensure(&mut self, len: usize) -> &mut [f64] {
        let pad = ALIGN / std::mem::size_of::<f64>();
        if self.raw.len() < len + pad {
            self.raw.resize(len + pad, 0.0);
            let addr = self.raw.as_ptr() as usize;
            self.off = (ALIGN - addr % ALIGN) % ALIGN / std::mem::size_of::<f64>();
        }
        self.len = len;
        &mut self.raw[self.off..self.off + len]
    }

    fn slice(&self) -> &[f64] {
        &self.raw[self.off..self.off + self.len]
    }
}

thread_local! {
    /// Per-thread pack arena: (A panel, B panel). Grown on first use, then
    /// reused for the life of the thread (= the life of a pool worker).
    static PACKS: RefCell<(AlignedBuf, AlignedBuf)> =
        const { RefCell::new((AlignedBuf::new(), AlignedBuf::new())) };

    /// Per-thread output panel for consumers whose destination aliases an
    /// operand (blocked TRSM, the Cholesky trailing update).
    static TMP: RefCell<Vec<f64>> = const { RefCell::new(Vec::new()) };
}

/// Run `f` over a `len`-long slice of the per-thread temporary output panel
/// (contents unspecified on entry; no allocation once the panel is warm).
/// Reentrant calls are not allowed; [`gemm_into`] may be called inside `f`.
pub(crate) fn with_tmp<R>(len: usize, f: impl FnOnce(&mut [f64]) -> R) -> R {
    TMP.with(|cell| {
        let mut guard = cell.borrow_mut();
        let buf = &mut *guard;
        if buf.len() < len {
            buf.resize(len, 0.0);
        }
        f(&mut buf[..len])
    })
}

/// Pack the `mc×kc` panel of effective-A at (ic, pc) into MR-row slivers,
/// sliver-major, column-major within a sliver (`buf[s][p][r]`), zero-padding
/// the tail sliver to MR rows.
fn pack_a(a: &Src<'_>, ic: usize, mc: usize, pc: usize, kc: usize, buf: &mut [f64]) {
    let slivers = mc.div_ceil(MR);
    match *a {
        Src::N {
            data,
            stride,
            r0,
            c0,
        } => {
            for s in 0..slivers {
                let base = s * kc * MR;
                let rows = MR.min(mc - s * MR);
                for r in 0..rows {
                    let src = &data[(r0 + ic + s * MR + r) * stride + c0 + pc..][..kc];
                    for (p, &v) in src.iter().enumerate() {
                        buf[base + p * MR + r] = v;
                    }
                }
            }
        }
        Src::T {
            data,
            stride,
            r0,
            c0,
        } => {
            for s in 0..slivers {
                let base = s * kc * MR;
                let rows = MR.min(mc - s * MR);
                for p in 0..kc {
                    let src = &data[(r0 + pc + p) * stride + c0 + ic + s * MR..][..rows];
                    buf[base + p * MR..base + p * MR + rows].copy_from_slice(src);
                }
            }
        }
    }
    // zero the pad lanes of the tail sliver so padded rows accumulate zeros
    let tail_rows = mc - (slivers - 1) * MR;
    if tail_rows < MR {
        let base = (slivers - 1) * kc * MR;
        for p in 0..kc {
            for r in tail_rows..MR {
                buf[base + p * MR + r] = 0.0;
            }
        }
    }
}

/// Pack the `kc×nc` panel of effective-B at (pc, jc) into NR-column slivers,
/// sliver-major, row-major within a sliver (`buf[s][p][c]`), zero-padding
/// the tail sliver to NR columns.
fn pack_b(b: &Src<'_>, jc: usize, nc: usize, pc: usize, kc: usize, buf: &mut [f64]) {
    let slivers = nc.div_ceil(NR);
    match *b {
        Src::N {
            data,
            stride,
            r0,
            c0,
        } => {
            for s in 0..slivers {
                let base = s * kc * NR;
                let cols = NR.min(nc - s * NR);
                for p in 0..kc {
                    let src = &data[(r0 + pc + p) * stride + c0 + jc + s * NR..][..cols];
                    let dst = &mut buf[base + p * NR..base + (p + 1) * NR];
                    dst[..cols].copy_from_slice(src);
                    for v in &mut dst[cols..] {
                        *v = 0.0;
                    }
                }
            }
        }
        Src::T {
            data,
            stride,
            r0,
            c0,
        } => {
            for s in 0..slivers {
                let base = s * kc * NR;
                let cols = NR.min(nc - s * NR);
                for j in 0..cols {
                    let src = &data[(r0 + jc + s * NR + j) * stride + c0 + pc..][..kc];
                    for (p, &v) in src.iter().enumerate() {
                        buf[base + p * NR + j] = v;
                    }
                }
                if cols < NR {
                    for p in 0..kc {
                        for j in cols..NR {
                            buf[base + p * NR + j] = 0.0;
                        }
                    }
                }
            }
        }
    }
}

/// The register-tile micro-kernel: `acc += Aᵖ·Bᵖ` over one packed sliver
/// pair. `a` is kc×MR column-major, `b` is kc×NR row-major; each of the
/// MR×NR accumulators is updated in strictly ascending `p` order (the
/// determinism schedule — see module docs).
#[inline(always)]
fn micro_kernel(kc: usize, a: &[f64], b: &[f64], acc: &mut [[f64; NR]; MR]) {
    for p in 0..kc {
        let av: &[f64; MR] = a[p * MR..p * MR + MR].try_into().unwrap();
        let bv: &[f64; NR] = b[p * NR..p * NR + NR].try_into().unwrap();
        for r in 0..MR {
            let ar = av[r];
            let row = &mut acc[r];
            for c in 0..NR {
                row[c] += ar * bv[c];
            }
        }
    }
}

/// Sweep the packed panels with the micro-kernel, folding each tile into C
/// at (row0, col0) according to `acc`.
fn macro_kernel(
    mc: usize,
    nc: usize,
    kc: usize,
    pa: &[f64],
    pb: &[f64],
    c: &mut [f64],
    c_stride: usize,
    row0: usize,
    col0: usize,
    acc: Acc,
) {
    for js in 0..nc.div_ceil(NR) {
        let bs = &pb[js * kc * NR..][..kc * NR];
        let cols = NR.min(nc - js * NR);
        for is in 0..mc.div_ceil(MR) {
            let asl = &pa[is * kc * MR..][..kc * MR];
            let rows = MR.min(mc - is * MR);
            let mut tile = [[0.0f64; NR]; MR];
            micro_kernel(kc, asl, bs, &mut tile);
            for (r, trow) in tile.iter().enumerate().take(rows) {
                let dst = &mut c[(row0 + is * MR + r) * c_stride + col0 + js * NR..][..cols];
                let src = &trow[..cols];
                match acc {
                    Acc::Set => dst.copy_from_slice(src),
                    Acc::Add => {
                        for (d, &s) in dst.iter_mut().zip(src) {
                            *d += s;
                        }
                    }
                    Acc::Sub => {
                        for (d, &s) in dst.iter_mut().zip(src) {
                            *d -= s;
                        }
                    }
                }
            }
        }
    }
}

/// Packed GEMM driver: fold `op(A)·op(B)` (an `m×k` by `k×n` product) into
/// the `m×n` region of `c` at (c_r0, c_c0), row stride `c_stride`.
///
/// Handles all degenerate shapes (`m`, `n` or `k` zero; `k == 0` with
/// [`Acc::Set`] zero-fills the region). Pack buffers come from the
/// per-thread arena; the call performs no heap allocation once the arena is
/// warm. Must not be called reentrantly from inside another `gemm_into` (it
/// never is — this is leaf code).
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm_into(
    m: usize,
    n: usize,
    k: usize,
    a: Src<'_>,
    b: Src<'_>,
    c: &mut [f64],
    c_stride: usize,
    c_r0: usize,
    c_c0: usize,
    acc: Acc,
) {
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        if acc == Acc::Set {
            for i in 0..m {
                for v in &mut c[(c_r0 + i) * c_stride + c_c0..][..n] {
                    *v = 0.0;
                }
            }
        }
        return;
    }
    PACKS.with(|cell| {
        let mut packs = cell.borrow_mut();
        let (pa, pb) = &mut *packs;
        for jc in (0..n).step_by(NC) {
            let nc = NC.min(n - jc);
            let mut first = true;
            for pc in (0..k).step_by(KC) {
                let kc = KC.min(k - pc);
                let pbuf = pb.ensure(nc.div_ceil(NR) * kc * NR);
                pack_b(&b, jc, nc, pc, kc, pbuf);
                let eff = match acc {
                    Acc::Set if !first => Acc::Add,
                    other => other,
                };
                for ic in (0..m).step_by(MC) {
                    let mc = MC.min(m - ic);
                    let pbuf_a = pa.ensure(mc.div_ceil(MR) * kc * MR);
                    pack_a(&a, ic, mc, pc, kc, pbuf_a);
                    macro_kernel(
                        mc,
                        nc,
                        kc,
                        pa.slice(),
                        pb.slice(),
                        c,
                        c_stride,
                        c_r0 + ic,
                        c_c0 + jc,
                        eff,
                    );
                }
                first = false;
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aligned_buf_is_cache_line_aligned() {
        let mut b = AlignedBuf::new();
        let s = b.ensure(100);
        assert_eq!(s.as_ptr() as usize % ALIGN, 0);
        s[99] = 1.0;
        // growing keeps alignment
        let s2 = b.ensure(10_000);
        assert_eq!(s2.as_ptr() as usize % ALIGN, 0);
    }

    #[test]
    fn tiny_product_matches_by_hand() {
        // [1 2; 3 4] · [5 6; 7 8] = [19 22; 43 50]
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [5.0, 6.0, 7.0, 8.0];
        let mut c = [0.0; 4];
        gemm_into(2, 2, 2, Src::n(&a, 2), Src::n(&b, 2), &mut c, 2, 0, 0, Acc::Set);
        assert_eq!(c, [19.0, 22.0, 43.0, 50.0]);
        // Sub folds the product back out
        gemm_into(2, 2, 2, Src::n(&a, 2), Src::n(&b, 2), &mut c, 2, 0, 0, Acc::Sub);
        assert_eq!(c, [0.0; 4]);
    }

    #[test]
    fn transposed_views_match_normal() {
        // E = [1 2; 3 4]ᵀ via T view of the same buffer
        let a = [1.0, 2.0, 3.0, 4.0];
        let eye = [1.0, 0.0, 0.0, 1.0];
        let mut c = [0.0; 4];
        gemm_into(2, 2, 2, Src::t(&a, 2), Src::n(&eye, 2), &mut c, 2, 0, 0, Acc::Set);
        assert_eq!(c, [1.0, 3.0, 2.0, 4.0]);
    }

    #[test]
    fn k_zero_set_clears_region_and_add_is_noop() {
        let a: [f64; 0] = [];
        let b: [f64; 0] = [];
        let mut c = [7.0; 6];
        gemm_into(2, 3, 0, Src::n(&a, 1), Src::n(&b, 3), &mut c, 3, 0, 0, Acc::Add);
        assert_eq!(c, [7.0; 6]);
        gemm_into(2, 3, 0, Src::n(&a, 1), Src::n(&b, 3), &mut c, 3, 0, 0, Acc::Set);
        assert_eq!(c, [0.0; 6]);
    }
}
