//! The per-worker scratch arena: every buffer a steady-state fold×λ sweep
//! task needs, owned by the worker and reused across tasks.
//!
//! The sweep engine's grid tasks each evaluate a batch of λ's; per λ they
//! reconstruct (or factorize) an `h×h` factor, run two `O(h²)` triangular
//! solves, and score the hold-out split. Before this arena existed, every
//! one of those steps allocated: a `D`-length eval vector, an `h×h`
//! `Matrix`, two solve vectors and a prediction vector — five heap
//! round-trips per λ, thousands per sweep. Now each
//! [`crate::coordinator::pool::WorkerPool`] worker owns one `Scratch` for
//! its whole life and hands `&mut` to every job it runs
//! ([`crate::coordinator::pool::WorkerPool::map_scratch`]); buffers grow to
//! their steady-state sizes on the first task and are reused verbatim after
//! that — zero allocations per task. The fold-level solvers (MChol's probe
//! loop, the SVD family's eq. 11 sweep, PINRMSE's sparse solves) draw from
//! the same arena through [`crate::cv::solvers::sweep`], so no solver
//! allocates per grid point.
//!
//! This is the *solver-side* half of the per-worker arena. The *kernel-side*
//! half — the packed GEMM pack panels and the TRSM/SYRK output panel — lives
//! in thread-local storage inside [`super::kernel`], which amounts to the
//! same per-worker ownership because pool workers are long-lived threads.
//!
//! Every buffer is fully overwritten before each read (`copy_from`,
//! `reset_zeroed`, `clear`+`extend` idioms), so reuse can never leak state
//! between tasks — the engine's bit-identical-at-any-thread-count guarantee
//! is preserved by construction.

use super::matrix::Matrix;

/// Reusable per-worker buffers for the sweep hot path. See the module docs
/// for the ownership story.
pub struct Scratch {
    /// `D`-length interpolant evaluation buffer (`vec(L)` at λ).
    pub vbuf: Vec<f64>,
    /// The `h×h` factor: interpolated (`eval_factor_into`) or exact
    /// (`cholesky_shifted_into`), fully overwritten per λ.
    pub factor: Matrix,
    /// Forward-substitution intermediate `w` of the `L Lᵀ θ = g` solve.
    pub work: Vec<f64>,
    /// The solution vector θ.
    pub theta: Vec<f64>,
    /// Hold-out prediction buffer (`Xv · θ`).
    pub pred: Vec<f64>,
    /// The `(jb+k)²` panel-transform accumulator of the rank-k Cholesky
    /// update/downdate kernels ([`crate::linalg::chud`]), reshaped and fully
    /// overwritten per panel. Passed explicitly (`&mut scratch.trans`) so
    /// callers can borrow `factor`/`vbuf` for the same kernel call.
    pub trans: Matrix,
    /// Downdated per-row gradient `g_i = g − y_i·x_i` of the leave-one-out
    /// sweep ([`crate::cv::loo`]), fully overwritten per held-out row.
    pub gvec: Vec<f64>,
    /// The `d×n_v` gathered update block (`X_vᵀ`, one update vector per
    /// column) of the factor-level fold downdate
    /// ([`crate::linalg::chud::downdate_rank_k`]), fully overwritten — and
    /// destroyed — per (fold, λ) task.
    pub update: Matrix,
    /// The λ-warm-start gather: a task covering several λ cells of one fold
    /// gathers `X_vᵀ` here once ([`crate::linalg::chud::gather_update_block`])
    /// and replays it per cell through
    /// [`crate::linalg::chud::downdate_rank_k_pregathered`] (which copies it
    /// into [`Scratch::update`] before destroying that copy). Fully
    /// overwritten per task.
    pub gather: Matrix,
    /// The `d×b` gathered right-hand-side block (`Xᵀ` of one row batch) of
    /// the batched hat-diagonal solve ([`crate::cv::aloocv`]), fully
    /// overwritten per (batch, anchor).
    pub rhs: Matrix,
    /// The multi-RHS TRSM output `W = L⁻¹Xᵀ` whose squared column norms are
    /// the hat diagonals ([`crate::linalg::triangular::trsm_left_lower_into`]),
    /// fully overwritten per (batch, anchor).
    pub wsol: Matrix,
}

impl Scratch {
    /// An empty arena; buffers grow to steady-state sizes on first use.
    pub fn new() -> Self {
        Self {
            vbuf: Vec::new(),
            factor: Matrix::zeros(0, 0),
            work: Vec::new(),
            theta: Vec::new(),
            pred: Vec::new(),
            trans: Matrix::zeros(0, 0),
            gvec: Vec::new(),
            update: Matrix::zeros(0, 0),
            gather: Matrix::zeros(0, 0),
            rhs: Matrix::zeros(0, 0),
            wsol: Matrix::zeros(0, 0),
        }
    }
}

impl Default for Scratch {
    fn default() -> Self {
        Self::new()
    }
}
