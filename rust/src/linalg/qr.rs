//! Householder QR with thin-Q extraction.
//!
//! Needed by the randomized-SVD baseline (orthonormalizing the sketch) and by
//! the Lanczos reorthogonalization. Standard LAPACK `geqrf`/`orgqr` shape,
//! unblocked — the matrices it sees (n × (k+p) sketches) are tall and skinny,
//! so BLAS-2 is fine.

use super::matrix::Matrix;

/// Compact QR state: Householder vectors stored below the diagonal of `qr`,
/// scalar factors in `tau`.
pub struct QrFactors {
    qr: Matrix,
    tau: Vec<f64>,
}

/// Factor `a` (m×n, m ≥ n) as Q·R.
pub fn householder_qr(a: &Matrix) -> QrFactors {
    let (m, n) = (a.rows(), a.cols());
    assert!(m >= n, "householder_qr expects a tall matrix");
    let mut qr = a.clone();
    let mut tau = vec![0.0; n];

    for k in 0..n {
        // norm of the k-th column below the diagonal
        let mut normx = 0.0;
        for i in k..m {
            normx += qr[(i, k)] * qr[(i, k)];
        }
        normx = normx.sqrt();
        if normx == 0.0 {
            tau[k] = 0.0;
            continue;
        }
        let alpha = qr[(k, k)];
        let beta = -alpha.signum() * normx;
        let v0 = alpha - beta;
        // v = [1, qr[k+1..,k]/v0]; apply H = I − τ v vᵀ
        tau[k] = -v0 / beta;
        for i in (k + 1)..m {
            qr[(i, k)] /= v0;
        }
        qr[(k, k)] = beta;
        // update trailing columns
        for j in (k + 1)..n {
            let mut dot = qr[(k, j)];
            for i in (k + 1)..m {
                dot += qr[(i, k)] * qr[(i, j)];
            }
            dot *= tau[k];
            qr[(k, j)] -= dot;
            for i in (k + 1)..m {
                let vik = qr[(i, k)];
                qr[(i, j)] -= dot * vik;
            }
        }
    }
    QrFactors { qr, tau }
}

impl QrFactors {
    /// The upper-triangular R (n×n).
    pub fn r(&self) -> Matrix {
        let n = self.qr.cols();
        Matrix::from_fn(n, n, |i, j| if j >= i { self.qr[(i, j)] } else { 0.0 })
    }

    /// Thin Q (m×n) via backward accumulation of the Householder reflectors.
    pub fn thin_q(&self) -> Matrix {
        let (m, n) = (self.qr.rows(), self.qr.cols());
        let mut q = Matrix::zeros(m, n);
        for i in 0..n {
            q[(i, i)] = 1.0;
        }
        for k in (0..n).rev() {
            if self.tau[k] == 0.0 {
                continue;
            }
            for j in 0..n {
                // dot = vᵀ q[:,j] with v = [1; qr[k+1..,k]]
                let mut dot = q[(k, j)];
                for i in (k + 1)..m {
                    dot += self.qr[(i, k)] * q[(i, j)];
                }
                dot *= self.tau[k];
                q[(k, j)] -= dot;
                for i in (k + 1)..m {
                    let vik = self.qr[(i, k)];
                    q[(i, j)] -= dot * vik;
                }
            }
        }
        q
    }
}

/// Convenience: thin (Q, R) of a tall matrix.
pub fn householder_qr_thin(a: &Matrix) -> (Matrix, Matrix) {
    let f = householder_qr(a);
    (f.thin_q(), f.r())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::gemm;
    use crate::testutil::{assert_matrix_close, random_matrix};

    #[test]
    fn qr_reconstructs() {
        let a = random_matrix(30, 8, 1);
        let (q, r) = householder_qr_thin(&a);
        assert_matrix_close(&gemm(&q, &r), &a, 1e-10);
    }

    #[test]
    fn q_is_orthonormal() {
        let a = random_matrix(40, 10, 2);
        let (q, _) = householder_qr_thin(&a);
        let qtq = gemm(&q.transpose(), &q);
        assert_matrix_close(&qtq, &Matrix::eye(10), 1e-10);
    }

    #[test]
    fn r_is_upper_triangular() {
        let a = random_matrix(20, 6, 3);
        let (_, r) = householder_qr_thin(&a);
        for i in 0..6 {
            for j in 0..i {
                assert_eq!(r[(i, j)], 0.0);
            }
        }
    }

    #[test]
    fn square_case() {
        let a = random_matrix(12, 12, 4);
        let (q, r) = householder_qr_thin(&a);
        assert_matrix_close(&gemm(&q, &r), &a, 1e-9);
    }
}
