//! Blocked BLAS-3 kernels: GEMM, SYRK, GEMV.
//!
//! The paper's whole efficiency story rides on keeping the heavy steps at
//! BLAS-3 granularity (§1a, §5). These kernels use the classic
//! cache-blocking scheme — pack nothing, block for L1/L2, keep the innermost
//! loop a contiguous `axpy` over the output row so the compiler can
//! auto-vectorize it.

use super::matrix::Matrix;

/// Cache block edge. 64×64 f64 blocks = 32 KiB per operand — L1-resident on
/// any modern core. The ablation bench (`bench_ablations`) sweeps this.
pub const BLOCK: usize = 64;

/// Blocked general matrix multiply with optional transposes.
pub struct Gemm {
    pub block: usize,
}

impl Default for Gemm {
    fn default() -> Self {
        Self { block: BLOCK }
    }
}

impl Gemm {
    /// `C = A · B`.
    pub fn mul(&self, a: &Matrix, b: &Matrix) -> Matrix {
        assert_eq!(a.cols(), b.rows(), "gemm shape mismatch");
        let (m, k, n) = (a.rows(), a.cols(), b.cols());
        let mut c = Matrix::zeros(m, n);
        let bs = self.block;
        for i0 in (0..m).step_by(bs) {
            let i1 = (i0 + bs).min(m);
            for k0 in (0..k).step_by(bs) {
                let k1 = (k0 + bs).min(k);
                for j0 in (0..n).step_by(bs) {
                    let j1 = (j0 + bs).min(n);
                    // micro-kernel: row of A broadcast against rows of B
                    for i in i0..i1 {
                        let arow = &a.row(i)[k0..k1];
                        let crow = &mut c.row_mut(i)[j0..j1];
                        for (kk, &aik) in arow.iter().enumerate() {
                            let brow = &b.row(k0 + kk)[j0..j1];
                            for (cj, &bkj) in crow.iter_mut().zip(brow) {
                                *cj += aik * bkj;
                            }
                        }
                    }
                }
            }
        }
        c
    }

    /// `C = Aᵀ · B` without materializing the transpose (the Gram-matrix
    /// access pattern: both operands walked row-wise).
    pub fn at_b(&self, a: &Matrix, b: &Matrix) -> Matrix {
        assert_eq!(a.rows(), b.rows(), "atb shape mismatch");
        let (k, m, n) = (a.rows(), a.cols(), b.cols());
        let mut c = Matrix::zeros(m, n);
        let bs = self.block;
        for k0 in (0..k).step_by(bs) {
            let k1 = (k0 + bs).min(k);
            for i0 in (0..m).step_by(bs) {
                let i1 = (i0 + bs).min(m);
                for j0 in (0..n).step_by(bs) {
                    let j1 = (j0 + bs).min(n);
                    for kk in k0..k1 {
                        let arow = &a.row(kk)[i0..i1];
                        let brow = &b.row(kk)[j0..j1];
                        for (di, &aki) in arow.iter().enumerate() {
                            let crow = &mut c.row_mut(i0 + di)[j0..j1];
                            for (cj, &bkj) in crow.iter_mut().zip(brow) {
                                *cj += aki * bkj;
                            }
                        }
                    }
                }
            }
        }
        c
    }

    /// Rows `r0..r1` of `A · Bᵀ`, as an `(r1-r0)×b.rows()` block.
    ///
    /// The per-row block schedule (j-blocks outer, k-blocks inner, dot
    /// accumulation order within a block) matches [`Gemm::a_bt`] exactly, so
    /// each output row is **bitwise identical** to the corresponding row of
    /// the full product — this is what lets the pooled Cholesky's trailing
    /// SYRK update fan row panels across workers without perturbing the
    /// factorization by a single ulp (the sweep engine's determinism
    /// guarantee rests on it).
    pub fn a_bt_rows(&self, a: &Matrix, b: &Matrix, r0: usize, r1: usize) -> Matrix {
        assert_eq!(a.cols(), b.cols(), "abt shape mismatch");
        assert!(r0 <= r1 && r1 <= a.rows(), "row range out of bounds");
        let (k, n) = (a.cols(), b.rows());
        let mut c = Matrix::zeros(r1 - r0, n);
        let bs = self.block;
        for i in r0..r1 {
            let ci = i - r0;
            for j0 in (0..n).step_by(bs) {
                let j1 = (j0 + bs).min(n);
                for k0 in (0..k).step_by(bs) {
                    let k1 = (k0 + bs).min(k);
                    let arow = &a.row(i)[k0..k1];
                    for j in j0..j1 {
                        let brow = &b.row(j)[k0..k1];
                        let mut dot = 0.0;
                        for (x, y) in arow.iter().zip(brow) {
                            dot += x * y;
                        }
                        c[(ci, j)] += dot;
                    }
                }
            }
        }
        c
    }

    /// `C = A · Bᵀ`.
    pub fn a_bt(&self, a: &Matrix, b: &Matrix) -> Matrix {
        assert_eq!(a.cols(), b.cols(), "abt shape mismatch");
        let (m, k, n) = (a.rows(), a.cols(), b.rows());
        let mut c = Matrix::zeros(m, n);
        let bs = self.block;
        for i0 in (0..m).step_by(bs) {
            let i1 = (i0 + bs).min(m);
            for j0 in (0..n).step_by(bs) {
                let j1 = (j0 + bs).min(n);
                for k0 in (0..k).step_by(bs) {
                    let k1 = (k0 + bs).min(k);
                    for i in i0..i1 {
                        let arow = &a.row(i)[k0..k1];
                        for j in j0..j1 {
                            let brow = &b.row(j)[k0..k1];
                            let mut dot = 0.0;
                            for (x, y) in arow.iter().zip(brow) {
                                dot += x * y;
                            }
                            c[(i, j)] += dot;
                        }
                    }
                }
            }
        }
        c
    }
}

/// `C = A · B` with the default block size.
pub fn gemm(a: &Matrix, b: &Matrix) -> Matrix {
    Gemm::default().mul(a, b)
}

/// `y = A · x`.
pub fn gemv(a: &Matrix, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.cols(), x.len());
    (0..a.rows())
        .map(|i| a.row(i).iter().zip(x).map(|(&aij, &xj)| aij * xj).sum())
        .collect()
}

/// `y = Aᵀ · x` without materializing the transpose.
pub fn gemv_t(a: &Matrix, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.rows(), x.len());
    let mut y = vec![0.0; a.cols()];
    for (i, &xi) in x.iter().enumerate() {
        for (yj, &aij) in y.iter_mut().zip(a.row(i)) {
            *yj += xi * aij;
        }
    }
    y
}

/// Symmetric rank-k update: lower triangle of `C = XᵀX` (the Hessian build,
/// Figure 1 step 2). Only the lower half is computed, then mirrored — this is
/// the ~2× saving over a plain gemm that LAPACK's `syrk` gives the paper.
pub fn syrk_lower(x: &Matrix) -> Matrix {
    let (n, h) = (x.rows(), x.cols());
    let mut c = Matrix::zeros(h, h);
    let bs = BLOCK;
    for k0 in (0..n).step_by(bs) {
        let k1 = (k0 + bs).min(n);
        for i0 in (0..h).step_by(bs) {
            let i1 = (i0 + bs).min(h);
            for j0 in (0..=i0).step_by(bs) {
                let j1 = (j0 + bs).min(h);
                for kk in k0..k1 {
                    let xrow = x.row(kk);
                    for i in i0..i1 {
                        let xki = xrow[i];
                        if xki == 0.0 {
                            continue;
                        }
                        let jhi = j1.min(i + 1);
                        let crow = &mut c.row_mut(i)[j0..jhi];
                        let xseg = &xrow[j0..jhi];
                        for (cij, &xkj) in crow.iter_mut().zip(xseg) {
                            *cij += xki * xkj;
                        }
                    }
                }
            }
        }
    }
    // mirror to the upper triangle
    for i in 0..h {
        for j in (i + 1)..h {
            c[(i, j)] = c[(j, i)];
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_mul(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0;
                for k in 0..a.cols() {
                    s += a[(i, k)] * b[(k, j)];
                }
                c[(i, j)] = s;
            }
        }
        c
    }

    fn randm(r: usize, c: usize, seed: u64) -> Matrix {
        let mut rng = crate::prng::Xoshiro256::seed_from(seed);
        Matrix::from_fn(r, c, |_, _| rng.normal())
    }

    #[test]
    fn gemm_matches_naive() {
        let a = randm(37, 53, 1);
        let b = randm(53, 29, 2);
        assert!(gemm(&a, &b).max_abs_diff(&naive_mul(&a, &b)) < 1e-10);
    }

    #[test]
    fn gemm_block_size_invariance() {
        let a = randm(70, 65, 3);
        let b = randm(65, 80, 4);
        let c1 = Gemm { block: 8 }.mul(&a, &b);
        let c2 = Gemm { block: 128 }.mul(&a, &b);
        assert!(c1.max_abs_diff(&c2) < 1e-10);
    }

    #[test]
    fn at_b_matches_explicit_transpose() {
        let a = randm(40, 31, 5);
        let b = randm(40, 23, 6);
        let c = Gemm::default().at_b(&a, &b);
        let expect = gemm(&a.transpose(), &b);
        assert!(c.max_abs_diff(&expect) < 1e-10);
    }

    #[test]
    fn a_bt_matches_explicit_transpose() {
        let a = randm(25, 31, 7);
        let b = randm(18, 31, 8);
        let c = Gemm::default().a_bt(&a, &b);
        let expect = gemm(&a, &b.transpose());
        assert!(c.max_abs_diff(&expect) < 1e-10);
    }

    #[test]
    fn a_bt_rows_bitwise_matches_full_product() {
        let a = randm(37, 29, 11);
        let b = randm(23, 29, 12);
        let gem = Gemm { block: 8 };
        let full = gem.a_bt(&a, &b);
        // arbitrary, unaligned row partitions must reproduce the exact bits
        for (r0, r1) in [(0, 5), (5, 17), (17, 37), (0, 37), (36, 37)] {
            let part = gem.a_bt_rows(&a, &b, r0, r1);
            for i in r0..r1 {
                for j in 0..23 {
                    assert_eq!(
                        part[(i - r0, j)],
                        full[(i, j)],
                        "row {i} col {j} differs for range {r0}..{r1}"
                    );
                }
            }
        }
    }

    #[test]
    fn syrk_matches_atb() {
        let x = randm(100, 33, 9);
        let c = syrk_lower(&x);
        let expect = Gemm::default().at_b(&x, &x);
        assert!(c.max_abs_diff(&expect) < 1e-9);
    }

    #[test]
    fn gemv_and_gemv_t() {
        let a = randm(13, 7, 10);
        let x: Vec<f64> = (0..7).map(|i| i as f64).collect();
        let y = gemv(&a, &x);
        let expect = gemm(&a, &Matrix::from_vec(7, 1, x.clone()));
        for i in 0..13 {
            assert!((y[i] - expect[(i, 0)]).abs() < 1e-12);
        }
        let z: Vec<f64> = (0..13).map(|i| (i as f64).sin()).collect();
        let w = gemv_t(&a, &z);
        let expect_t = gemm(&a.transpose(), &Matrix::from_vec(13, 1, z.clone()));
        for j in 0..7 {
            assert!((w[j] - expect_t[(j, 0)]).abs() < 1e-12);
        }
    }
}
