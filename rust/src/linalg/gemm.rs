//! BLAS-3 entry points: GEMM, SYRK, GEMV.
//!
//! The paper's whole efficiency story rides on keeping the heavy steps at
//! BLAS-3 granularity (§1a, §5). Every matrix-matrix product here routes
//! through the packed, register-blocked micro-kernel engine in
//! [`super::kernel`]: operand panels are packed into contiguous aligned
//! scratch (absorbing any transposition), and an `MR×NR` register tile is
//! driven over them with a fixed, partition-independent accumulation
//! schedule — see that module's docs for the layout and the determinism
//! contract.
//!
//! The previous generation of kernels — unpacked cache-blocked loops with an
//! auto-vectorized axpy/dot innermost — is preserved verbatim in
//! [`reference`]: it is the correctness oracle for the packed path's tests
//! and the baseline `bench_kernels` measures the packed speedup against.

use super::kernel::{self, Acc, Src};
use super::matrix::Matrix;

/// Legacy cache block edge (used by the [`reference`] kernels; the packed
/// engine blocks at [`kernel::MC`]/[`kernel::KC`]/[`kernel::NC`] instead).
pub const BLOCK: usize = 64;

/// General matrix multiply with optional transposes, packed micro-kernel
/// backed.
pub struct Gemm {
    /// Legacy cache-block knob, retained **only** so existing
    /// `Gemm { block }` construction sites keep compiling. The packed
    /// engine's tile sizes are fixed in [`super::kernel`] and this field is
    /// never read, so results are bitwise identical for every value. (The
    /// [`reference`] kernels take their block size as an explicit
    /// parameter.)
    pub block: usize,
}

impl Default for Gemm {
    fn default() -> Self {
        Self { block: BLOCK }
    }
}

impl Gemm {
    /// `C = A · B`.
    pub fn mul(&self, a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows(), b.cols());
        self.mul_into(a, b, &mut c);
        c
    }

    /// `C = A · B` into a caller-provided output (no allocation).
    pub fn mul_into(&self, a: &Matrix, b: &Matrix, c: &mut Matrix) {
        assert_eq!(a.cols(), b.rows(), "gemm shape mismatch");
        assert_eq!(
            (c.rows(), c.cols()),
            (a.rows(), b.cols()),
            "gemm output shape mismatch"
        );
        let (m, k, n) = (a.rows(), a.cols(), b.cols());
        kernel::gemm_into(
            m,
            n,
            k,
            Src::n(a.as_slice(), a.cols()),
            Src::n(b.as_slice(), b.cols()),
            c.as_mut_slice(),
            n,
            0,
            0,
            Acc::Set,
        );
    }

    /// `C = Aᵀ · B` without materializing the transpose (the Gram-matrix
    /// access pattern; the transposition is absorbed by the A-panel
    /// packing).
    pub fn at_b(&self, a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.cols(), b.cols());
        self.at_b_into(a, b, &mut c);
        c
    }

    /// `C = Aᵀ · B` into a caller-provided output (no allocation).
    pub fn at_b_into(&self, a: &Matrix, b: &Matrix, c: &mut Matrix) {
        assert_eq!(a.rows(), b.rows(), "atb shape mismatch");
        assert_eq!(
            (c.rows(), c.cols()),
            (a.cols(), b.cols()),
            "atb output shape mismatch"
        );
        let (k, m, n) = (a.rows(), a.cols(), b.cols());
        kernel::gemm_into(
            m,
            n,
            k,
            Src::t(a.as_slice(), a.cols()),
            Src::n(b.as_slice(), b.cols()),
            c.as_mut_slice(),
            n,
            0,
            0,
            Acc::Set,
        );
    }

    /// Rows `r0..r1` of `A · Bᵀ`, as an `(r1-r0)×b.rows()` block.
    ///
    /// **Bitwise identical** to the corresponding rows of the full
    /// [`Gemm::a_bt`] product, for *any* row partition: the packed engine's
    /// `k` chunking depends only on the (full, shared) `k` extent, and each
    /// output element gets one ascending-order scalar accumulator per chunk,
    /// so an element's bits are a pure function of its row/column data (see
    /// [`super::kernel`]'s determinism schedule). This is what lets the
    /// pooled Cholesky's trailing SYRK update fan row panels across workers
    /// without perturbing the factorization by a single ulp — the sweep
    /// engine's determinism guarantee rests on it.
    pub fn a_bt_rows(&self, a: &Matrix, b: &Matrix, r0: usize, r1: usize) -> Matrix {
        assert!(r0 <= r1 && r1 <= a.rows(), "row range out of bounds");
        let mut c = Matrix::zeros(r1 - r0, b.rows());
        self.a_bt_rows_into(a, b, r0, r1, &mut c);
        c
    }

    /// Row-block `A · Bᵀ` into a caller-provided output (no allocation).
    pub fn a_bt_rows_into(&self, a: &Matrix, b: &Matrix, r0: usize, r1: usize, c: &mut Matrix) {
        assert_eq!(a.cols(), b.cols(), "abt shape mismatch");
        assert!(r0 <= r1 && r1 <= a.rows(), "row range out of bounds");
        assert_eq!(
            (c.rows(), c.cols()),
            (r1 - r0, b.rows()),
            "abt output shape mismatch"
        );
        let (k, n) = (a.cols(), b.rows());
        kernel::gemm_into(
            r1 - r0,
            n,
            k,
            Src::N {
                data: a.as_slice(),
                stride: a.cols(),
                r0,
                c0: 0,
            },
            Src::t(b.as_slice(), b.cols()),
            c.as_mut_slice(),
            n,
            0,
            0,
            Acc::Set,
        );
    }

    /// `C = A · Bᵀ`.
    pub fn a_bt(&self, a: &Matrix, b: &Matrix) -> Matrix {
        self.a_bt_rows(a, b, 0, a.rows())
    }
}

/// `C = A · B` with the default configuration.
pub fn gemm(a: &Matrix, b: &Matrix) -> Matrix {
    Gemm::default().mul(a, b)
}

/// `y = A · x`.
pub fn gemv(a: &Matrix, x: &[f64]) -> Vec<f64> {
    let mut y = Vec::new();
    gemv_into(a, x, &mut y);
    y
}

/// `y = A · x` into a caller-provided buffer (no steady-state allocation).
pub fn gemv_into(a: &Matrix, x: &[f64], y: &mut Vec<f64>) {
    assert_eq!(a.cols(), x.len());
    y.clear();
    y.extend(
        (0..a.rows()).map(|i| a.row(i).iter().zip(x).map(|(&aij, &xj)| aij * xj).sum::<f64>()),
    );
}

/// `y = Aᵀ · x` without materializing the transpose.
pub fn gemv_t(a: &Matrix, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.rows(), x.len());
    let mut y = vec![0.0; a.cols()];
    for (i, &xi) in x.iter().enumerate() {
        for (yj, &aij) in y.iter_mut().zip(a.row(i)) {
            *yj += xi * aij;
        }
    }
    y
}

/// Column band width of the packed SYRK: each band is one `gemm_into` call
/// covering rows `j0..h` of the lower triangle.
const SYRK_BAND: usize = 48;

/// Fold the lower triangle of `Xᵀ[·, r0..r1] · X[r0..r1, ·]` into `out`
/// band-by-band through the packed engine, with accumulation mode `acc`.
/// Each band writes the full `out[j0..h, j0..j1]` rectangle — so
/// strictly-upper entries *inside a diagonal band block* are written (with
/// their symmetric values), while upper entries *above* the band blocks are
/// never touched; every caller mirrors the lower triangle afterwards. This
/// is the shared core of [`syrk_lower`] (`Set` over all rows), the
/// streaming Gram accumulator's per-segment partials
/// ([`crate::data::gram`]), and the hold-out downdate
/// ([`syrk_lower_downdate_into`], `Sub` over the validation block).
pub(crate) fn syrk_lower_bands_into(
    x: &Matrix,
    r0: usize,
    r1: usize,
    out: &mut Matrix,
    acc: Acc,
) {
    let h = x.cols();
    debug_assert!(r0 <= r1 && r1 <= x.rows());
    debug_assert_eq!((out.rows(), out.cols()), (h, h));
    for j0 in (0..h).step_by(SYRK_BAND) {
        let j1 = (j0 + SYRK_BAND).min(h);
        // out[j0..h, j0..j1] (acc)= Xᵀ[j0..h, r0..r1] · X[r0..r1, j0..j1]
        kernel::gemm_into(
            h - j0,
            j1 - j0,
            r1 - r0,
            Src::T {
                data: x.as_slice(),
                stride: h,
                r0,
                c0: j0,
            },
            Src::N {
                data: x.as_slice(),
                stride: h,
                r0,
                c0: j0,
            },
            out.as_mut_slice(),
            h,
            j0,
            j0,
            acc,
        );
    }
}

/// Symmetric rank-k update: `C = XᵀX` (the Hessian build, Figure 1 step 2).
/// Computed band-by-band over the lower triangle through the packed engine —
/// only rows at or below each column band are formed, then mirrored, keeping
/// LAPACK `syrk`'s ~2× saving over a plain gemm.
pub fn syrk_lower(x: &Matrix) -> Matrix {
    let h = x.cols();
    let mut c = Matrix::zeros(h, h);
    syrk_lower_bands_into(x, 0, x.rows(), &mut c, Acc::Set);
    c.mirror_lower();
    c
}

/// Symmetric rank-k **downdate**: `out = G − XᵀX`, the hold-out identity
/// `H_fold = XᵀX − X_vᵀX_v` that derives every fold's Hessian from one
/// shared Gram matrix (see [`crate::data::gram::GramCache`]). `G` must be
/// the full symmetric Gram; the subtraction runs band-by-band over the
/// lower triangle through the packed kernel (`Acc::Sub`) and is mirrored,
/// so `out` comes back full-symmetric. `out` is reshaped and fully
/// overwritten (arena-friendly: no allocation once warm).
pub fn syrk_lower_downdate_into(gram: &Matrix, x: &Matrix, out: &mut Matrix) {
    assert!(gram.is_square(), "gram must be square");
    assert_eq!(x.cols(), gram.rows(), "downdate shape mismatch");
    out.copy_from(gram);
    syrk_lower_bands_into(x, 0, x.rows(), out, Acc::Sub);
    out.mirror_lower();
}

/// Fused hold-out downdate of the shared Gram pair: `h_out = G − X_vᵀX_v`
/// and `g_out = g − X_vᵀy_v` — one call turns the global `(XᵀX, Xᵀy)` into a
/// fold's `(H_f, g_f)` using only the small validation block (`O(n_v·d²)`
/// instead of the `O(n_t·d²)` per-fold SYRK it replaces). Output buffers are
/// reshaped and fully overwritten.
///
/// Numerics: the subtraction carries absolute error `~eps·‖G‖`, so on data
/// where one fold's validation rows dominate the Gram (`‖H_f‖ ≪ ‖G‖`) the
/// downdated Hessian is less accurate than a direct `X_tᵀX_t` build and, at
/// extreme λ→0, can tip a barely-PD `H_f + λI` into a
/// [`super::cholesky::CholeskyError`] — which propagates under the usual
/// shift-and-retry contract. For the
/// balanced k-fold splits this crate generates, `‖H_f‖ ≈ (1−1/k)·‖G‖`, so
/// the loss is a few ulps ([`crate::data::gram`]'s tests pin 1e-10
/// agreement with the direct build).
pub fn gram_downdate(
    gram_h: &Matrix,
    gram_g: &[f64],
    xv: &Matrix,
    yv: &[f64],
    h_out: &mut Matrix,
    g_out: &mut Vec<f64>,
) {
    assert_eq!(xv.rows(), yv.len(), "validation block shape mismatch");
    assert_eq!(gram_g.len(), xv.cols(), "gradient length mismatch");
    syrk_lower_downdate_into(gram_h, xv, h_out);
    g_out.clear();
    g_out.extend_from_slice(gram_g);
    for (i, &yi) in yv.iter().enumerate() {
        for (o, &xij) in g_out.iter_mut().zip(xv.row(i)) {
            *o -= yi * xij;
        }
    }
}

/// The previous-generation blocked kernels, kept verbatim as the packed
/// engine's correctness oracle and perf baseline (`bench_kernels` measures
/// the packed speedup against these).
pub mod reference {
    use super::super::matrix::Matrix;

    /// Legacy blocked `C = A · B` (row-of-A broadcast against rows of B,
    /// contiguous axpy innermost).
    pub fn mul(block: usize, a: &Matrix, b: &Matrix) -> Matrix {
        assert_eq!(a.cols(), b.rows(), "gemm shape mismatch");
        let (m, k, n) = (a.rows(), a.cols(), b.cols());
        let mut c = Matrix::zeros(m, n);
        let bs = block;
        for i0 in (0..m).step_by(bs) {
            let i1 = (i0 + bs).min(m);
            for k0 in (0..k).step_by(bs) {
                let k1 = (k0 + bs).min(k);
                for j0 in (0..n).step_by(bs) {
                    let j1 = (j0 + bs).min(n);
                    for i in i0..i1 {
                        let arow = &a.row(i)[k0..k1];
                        let crow = &mut c.row_mut(i)[j0..j1];
                        for (kk, &aik) in arow.iter().enumerate() {
                            let brow = &b.row(k0 + kk)[j0..j1];
                            for (cj, &bkj) in crow.iter_mut().zip(brow) {
                                *cj += aik * bkj;
                            }
                        }
                    }
                }
            }
        }
        c
    }

    /// Legacy blocked `C = Aᵀ · B`.
    pub fn at_b(block: usize, a: &Matrix, b: &Matrix) -> Matrix {
        assert_eq!(a.rows(), b.rows(), "atb shape mismatch");
        let (k, m, n) = (a.rows(), a.cols(), b.cols());
        let mut c = Matrix::zeros(m, n);
        let bs = block;
        for k0 in (0..k).step_by(bs) {
            let k1 = (k0 + bs).min(k);
            for i0 in (0..m).step_by(bs) {
                let i1 = (i0 + bs).min(m);
                for j0 in (0..n).step_by(bs) {
                    let j1 = (j0 + bs).min(n);
                    for kk in k0..k1 {
                        let arow = &a.row(kk)[i0..i1];
                        let brow = &b.row(kk)[j0..j1];
                        for (di, &aki) in arow.iter().enumerate() {
                            let crow = &mut c.row_mut(i0 + di)[j0..j1];
                            for (cj, &bkj) in crow.iter_mut().zip(brow) {
                                *cj += aki * bkj;
                            }
                        }
                    }
                }
            }
        }
        c
    }

    /// Legacy blocked `C = A · Bᵀ` (dot-product innermost).
    pub fn a_bt(block: usize, a: &Matrix, b: &Matrix) -> Matrix {
        assert_eq!(a.cols(), b.cols(), "abt shape mismatch");
        let (m, k, n) = (a.rows(), a.cols(), b.rows());
        let mut c = Matrix::zeros(m, n);
        let bs = block;
        for i0 in (0..m).step_by(bs) {
            let i1 = (i0 + bs).min(m);
            for j0 in (0..n).step_by(bs) {
                let j1 = (j0 + bs).min(n);
                for k0 in (0..k).step_by(bs) {
                    let k1 = (k0 + bs).min(k);
                    for i in i0..i1 {
                        let arow = &a.row(i)[k0..k1];
                        for j in j0..j1 {
                            let brow = &b.row(j)[k0..k1];
                            let mut dot = 0.0;
                            for (x, y) in arow.iter().zip(brow) {
                                dot += x * y;
                            }
                            c[(i, j)] += dot;
                        }
                    }
                }
            }
        }
        c
    }

    /// Legacy blocked lower-triangle SYRK.
    pub fn syrk_lower(block: usize, x: &Matrix) -> Matrix {
        let (n, h) = (x.rows(), x.cols());
        let mut c = Matrix::zeros(h, h);
        let bs = block;
        for k0 in (0..n).step_by(bs) {
            let k1 = (k0 + bs).min(n);
            for i0 in (0..h).step_by(bs) {
                let i1 = (i0 + bs).min(h);
                for j0 in (0..=i0).step_by(bs) {
                    let j1 = (j0 + bs).min(h);
                    for kk in k0..k1 {
                        let xrow = x.row(kk);
                        for i in i0..i1 {
                            let xki = xrow[i];
                            if xki == 0.0 {
                                continue;
                            }
                            let jhi = j1.min(i + 1);
                            let crow = &mut c.row_mut(i)[j0..jhi];
                            let xseg = &xrow[j0..jhi];
                            for (cij, &xkj) in crow.iter_mut().zip(xseg) {
                                *cij += xki * xkj;
                            }
                        }
                    }
                }
            }
        }
        for i in 0..h {
            for j in (i + 1)..h {
                c[(i, j)] = c[(j, i)];
            }
        }
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_mul(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0;
                for k in 0..a.cols() {
                    s += a[(i, k)] * b[(k, j)];
                }
                c[(i, j)] = s;
            }
        }
        c
    }

    fn randm(r: usize, c: usize, seed: u64) -> Matrix {
        let mut rng = crate::prng::Xoshiro256::seed_from(seed);
        Matrix::from_fn(r, c, |_, _| rng.normal())
    }

    #[test]
    fn gemm_matches_naive() {
        let a = randm(37, 53, 1);
        let b = randm(53, 29, 2);
        assert!(gemm(&a, &b).max_abs_diff(&naive_mul(&a, &b)) < 1e-10);
    }

    #[test]
    fn gemm_block_size_invariance() {
        let a = randm(70, 65, 3);
        let b = randm(65, 80, 4);
        let c1 = Gemm { block: 8 }.mul(&a, &b);
        let c2 = Gemm { block: 128 }.mul(&a, &b);
        assert!(c1.max_abs_diff(&c2) < 1e-10);
    }

    #[test]
    fn at_b_matches_explicit_transpose() {
        let a = randm(40, 31, 5);
        let b = randm(40, 23, 6);
        let c = Gemm::default().at_b(&a, &b);
        let expect = gemm(&a.transpose(), &b);
        assert!(c.max_abs_diff(&expect) < 1e-10);
    }

    #[test]
    fn a_bt_matches_explicit_transpose() {
        let a = randm(25, 31, 7);
        let b = randm(18, 31, 8);
        let c = Gemm::default().a_bt(&a, &b);
        let expect = gemm(&a, &b.transpose());
        assert!(c.max_abs_diff(&expect) < 1e-10);
    }

    /// The determinism keystone: every row partition of the packed product —
    /// including one-row slivers that land mid register tile — must
    /// reproduce the exact bits of the full product.
    #[test]
    fn a_bt_rows_bitwise_matches_full_product() {
        let a = randm(37, 29, 11);
        let b = randm(23, 29, 12);
        let mut parts: Vec<(usize, usize)> =
            vec![(0, 5), (5, 17), (17, 37), (0, 37), (36, 37), (3, 4)];
        parts.extend((0..37).map(|r| (r, r + 1))); // every single-row sliver
        crate::testutil::assert_abt_partition_bitwise(&a, &b, &parts);
    }

    /// Same keystone at a size that crosses the MC/NC/KC cache-block edges.
    #[test]
    fn a_bt_rows_bitwise_across_cache_block_edges() {
        use crate::linalg::kernel::{KC, MC};
        let a = randm(MC + 9, KC + 7, 21);
        let b = randm(40, KC + 7, 22);
        crate::testutil::assert_abt_partition_bitwise(
            &a,
            &b,
            &[(0, MC), (MC, MC + 9), (MC - 1, MC + 1), (7, MC + 3)],
        );
    }

    /// Fuzzed extension of the keystone, run once per available micro-kernel
    /// backend: random shapes and random row partitions must reproduce the
    /// full product's bits exactly, whatever ISA computes the tiles. This is
    /// the lane-order-fixed reduction property — vectorizing across the NR
    /// columns leaves every output element's ascending-k, two-roundings-per-
    /// term accumulation untouched, so partition invariance cannot depend on
    /// the backend.
    #[test]
    fn partition_invariance_property_per_backend() {
        use crate::linalg::kernel::{self, KernelBackend};
        use crate::testutil::proptest_lite;
        for be in kernel::available_backends() {
            kernel::force_backend(be).unwrap();
            proptest_lite::check(&format!("abt-partition-{}", be.name()), 12, |c| {
                let m = c.dim(1, 40);
                let k = c.dim(1, 33);
                let n = c.dim(1, 24);
                let seed = 0xA000 + (c.index as u64) * 7;
                let a = randm(m, k, seed);
                let b = randm(n, k, seed + 1);
                let mut parts = vec![(0, m), (m - 1, m)];
                for _ in 0..4 {
                    let r0 = c.dim(0, m - 1);
                    let r1 = c.dim(r0 + 1, m);
                    parts.push((r0, r1));
                }
                crate::testutil::assert_abt_partition_bitwise(&a, &b, &parts);
            });
        }
        kernel::force_backend(KernelBackend::detect()).unwrap();
    }

    /// Packed kernels vs the naive oracle on degenerate and odd shapes:
    /// single rows/columns, empties, and sizes that are not multiples of
    /// MR/NR/KC.
    #[test]
    fn packed_matches_naive_on_degenerate_shapes() {
        let gem = Gemm::default();
        for &(m, k, n) in &[
            (1, 1, 1),
            (1, 7, 13),
            (13, 7, 1),
            (1, 1, 9),
            (9, 1, 1),
            (5, 1, 3),
            (3, 0, 4),
            (0, 5, 4),
            (4, 5, 0),
            (4, 9, 8),
            (8, 9, 4),
            (31, 17, 23),
        ] {
            let a = randm(m, k, (m * 100 + k * 10 + n) as u64 + 1);
            let b = randm(k, n, (m * 100 + k * 10 + n) as u64 + 2);
            let c = gem.mul(&a, &b);
            assert_eq!((c.rows(), c.cols()), (m, n));
            assert!(
                c.max_abs_diff(&naive_mul(&a, &b)) < 1e-12,
                "mul mismatch at ({m},{k},{n})"
            );

            let at = randm(k, m, (m * 100 + k * 10 + n) as u64 + 3);
            let catb = gem.at_b(&at, &b);
            assert!(
                catb.max_abs_diff(&naive_mul(&at.transpose(), &b)) < 1e-12,
                "at_b mismatch at ({m},{k},{n})"
            );

            let bt = randm(n, k, (m * 100 + k * 10 + n) as u64 + 4);
            let cabt = gem.a_bt(&a, &bt);
            assert!(
                cabt.max_abs_diff(&naive_mul(&a, &bt.transpose())) < 1e-12,
                "a_bt mismatch at ({m},{k},{n})"
            );
        }
    }

    #[test]
    fn packed_matches_reference_kernels() {
        let a = randm(67, 45, 31);
        let b = randm(45, 52, 32);
        assert!(gemm(&a, &b).max_abs_diff(&reference::mul(64, &a, &b)) < 1e-10);
        let x = randm(80, 37, 33);
        assert!(syrk_lower(&x).max_abs_diff(&reference::syrk_lower(64, &x)) < 1e-10);
        let c = randm(67, 29, 34);
        assert!(Gemm::default().at_b(&a, &c).max_abs_diff(&reference::at_b(64, &a, &c)) < 1e-10);
        let d = randm(28, 45, 35);
        assert!(Gemm::default().a_bt(&a, &d).max_abs_diff(&reference::a_bt(64, &a, &d)) < 1e-10);
    }

    #[test]
    fn mul_into_reuses_buffer_bitwise() {
        let a = randm(19, 11, 41);
        let b = randm(11, 17, 42);
        let fresh = gemm(&a, &b);
        let mut c = Matrix::zeros(19, 17);
        // fill with garbage first: Set must fully overwrite
        for v in c.as_mut_slice() {
            *v = f64::NAN;
        }
        Gemm::default().mul_into(&a, &b, &mut c);
        // raw-slice equality: NaN-propagating, unlike max_abs_diff (whose
        // f64::max fold would silently drop a leftover NaN)
        assert_eq!(c.as_slice(), fresh.as_slice());
    }

    #[test]
    fn syrk_matches_atb() {
        let x = randm(100, 33, 9);
        let c = syrk_lower(&x);
        let expect = Gemm::default().at_b(&x, &x);
        assert!(c.max_abs_diff(&expect) < 1e-9);
    }

    #[test]
    fn syrk_is_symmetric_and_handles_odd_shapes() {
        for &(n, h) in &[(1, 1), (3, 1), (1, 3), (7, 50), (100, 49)] {
            let x = randm(n, h, (n * 100 + h) as u64);
            let c = syrk_lower(&x);
            assert_eq!((c.rows(), c.cols()), (h, h));
            for i in 0..h {
                for j in 0..h {
                    assert_eq!(c[(i, j)], c[(j, i)], "asymmetry at ({i},{j}) n={n} h={h}");
                }
            }
            assert!(c.max_abs_diff(&naive_mul(&x.transpose(), &x)) < 1e-10);
        }
    }

    #[test]
    fn syrk_downdate_matches_direct_train_syrk() {
        // the hold-out identity: G − X_vᵀX_v == X_tᵀX_t (within rounding)
        for &(n, nv, h) in &[(60, 12, 17), (33, 1, 9), (9, 8, 5)] {
            let x = randm(n, h, (n * 1000 + nv * 10 + h) as u64);
            let xt = x.slice(0, n - nv, 0, h);
            let xv = x.slice(n - nv, n, 0, h);
            let gram = syrk_lower(&x);
            let mut down = Matrix::zeros(0, 0);
            syrk_lower_downdate_into(&gram, &xv, &mut down);
            let direct = syrk_lower(&xt);
            assert!(
                down.max_abs_diff(&direct) < 1e-10,
                "downdate mismatch at n={n} nv={nv} h={h}: {:.2e}",
                down.max_abs_diff(&direct)
            );
            // symmetric output
            for i in 0..h {
                for j in 0..h {
                    assert_eq!(down[(i, j)], down[(j, i)]);
                }
            }
        }
    }

    #[test]
    fn gram_downdate_fuses_hessian_and_gradient() {
        let (n, nv, h) = (50, 10, 13);
        let x = randm(n, h, 77);
        let y: Vec<f64> = (0..n).map(|i| (i as f64 * 0.31).sin()).collect();
        let xt = x.slice(0, n - nv, 0, h);
        let xv = x.slice(n - nv, n, 0, h);
        let gram_h = syrk_lower(&x);
        let gram_g = gemv_t(&x, &y);
        let mut h_out = Matrix::zeros(0, 0);
        let mut g_out = Vec::new();
        gram_downdate(&gram_h, &gram_g, &xv, &y[n - nv..], &mut h_out, &mut g_out);
        let h_direct = syrk_lower(&xt);
        let g_direct = gemv_t(&xt, &y[..n - nv]);
        assert!(h_out.max_abs_diff(&h_direct) < 1e-10);
        for (a, b) in g_out.iter().zip(&g_direct) {
            assert!((a - b).abs() < 1e-10, "{a} vs {b}");
        }
        // output buffers are reshaped + fully overwritten on reuse
        gram_downdate(&gram_h, &gram_g, &xv, &y[n - nv..], &mut h_out, &mut g_out);
        assert!(h_out.max_abs_diff(&h_direct) < 1e-10);
        assert_eq!(g_out.len(), h);
    }

    #[test]
    fn gemv_and_gemv_t() {
        let a = randm(13, 7, 10);
        let x: Vec<f64> = (0..7).map(|i| i as f64).collect();
        let y = gemv(&a, &x);
        let expect = gemm(&a, &Matrix::from_vec(7, 1, x.clone()));
        for i in 0..13 {
            assert!((y[i] - expect[(i, 0)]).abs() < 1e-12);
        }
        let z: Vec<f64> = (0..13).map(|i| (i as f64).sin()).collect();
        let w = gemv_t(&a, &z);
        let expect_t = gemm(&a.transpose(), &Matrix::from_vec(13, 1, z.clone()));
        for j in 0..7 {
            assert!((w[j] - expect_t[(j, 0)]).abs() < 1e-12);
        }
    }

    #[test]
    fn gemv_into_reuses_buffer() {
        let a = randm(9, 5, 51);
        let x: Vec<f64> = (0..5).map(|i| (i as f64).cos()).collect();
        let mut y = vec![99.0; 30];
        gemv_into(&a, &x, &mut y);
        assert_eq!(y.len(), 9);
        assert_eq!(y, gemv(&a, &x));
    }
}
