//! Row-major dense matrix.
//!
//! Deliberately minimal: a `Vec<f64>` plus dimensions, `(i, j)` indexing, and
//! the handful of structural helpers the algorithms need. All heavy lifting
//! (products, factorizations) lives in the sibling modules so the hot loops
//! stay visible and profilable.

use std::fmt;
use std::ops::{Index, IndexMut};

/// Dense row-major matrix of `f64`.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    /// Identity.
    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// From an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer/shape mismatch");
        Self { rows, cols, data }
    }

    /// From a closure over (i, j).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Self { rows, cols, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn is_square(&self) -> bool {
        self.rows == self.cols
    }

    /// Borrow the raw row-major buffer.
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Mutably borrow the raw row-major buffer.
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Consume into the raw buffer.
    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }

    /// Borrow row `i` as a contiguous slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutably borrow row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Two disjoint mutable rows (needed by the Jacobi rotations).
    pub fn two_rows_mut(&mut self, i: usize, j: usize) -> (&mut [f64], &mut [f64]) {
        assert!(i != j);
        let (lo, hi) = if i < j { (i, j) } else { (j, i) };
        let (a, b) = self.data.split_at_mut(hi * self.cols);
        let lo_row = &mut a[lo * self.cols..(lo + 1) * self.cols];
        let hi_row = &mut b[..self.cols];
        if i < j {
            (lo_row, hi_row)
        } else {
            (hi_row, lo_row)
        }
    }

    /// Copy of column `j`.
    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    /// Transpose (materialized).
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// Sub-matrix copy: rows `r0..r1`, cols `c0..c1`.
    pub fn slice(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Matrix {
        assert!(r1 <= self.rows && c1 <= self.cols && r0 <= r1 && c0 <= c1);
        let mut out = Matrix::zeros(r1 - r0, c1 - c0);
        for i in r0..r1 {
            out.row_mut(i - r0)
                .copy_from_slice(&self.row(i)[c0..c1]);
        }
        out
    }

    /// Write `block` into self at offset (r0, c0).
    pub fn set_block(&mut self, r0: usize, c0: usize, block: &Matrix) {
        assert!(r0 + block.rows <= self.rows && c0 + block.cols <= self.cols);
        for i in 0..block.rows {
            self.row_mut(r0 + i)[c0..c0 + block.cols].copy_from_slice(block.row(i));
        }
    }

    /// Copy `other`'s shape and contents into `self`, reusing the existing
    /// allocation when it has capacity — the arena-friendly alternative to
    /// `clone()` on hot paths (zero heap traffic in steady state).
    pub fn copy_from(&mut self, other: &Matrix) {
        self.rows = other.rows;
        self.cols = other.cols;
        self.data.clone_from(&other.data);
    }

    /// Reshape to `rows×cols` with all entries zero, reusing the allocation
    /// when it has capacity (the arena-friendly `Matrix::zeros`).
    pub fn reset_zeroed(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.clear();
        self.data.resize(rows * cols, 0.0);
    }

    /// Reshape to `rows×cols` taking all entries from `v` (one copy pass, no
    /// intermediate zero fill), reusing the allocation when it has capacity.
    pub fn reset_from_slice(&mut self, rows: usize, cols: usize, v: &[f64]) {
        assert_eq!(v.len(), rows * cols, "buffer/shape mismatch");
        self.rows = rows;
        self.cols = cols;
        self.data.clear();
        self.data.extend_from_slice(v);
    }

    /// `self + λI` (the paper's regularized Hessian `A = H + λI`).
    pub fn add_diag(&self, lam: f64) -> Matrix {
        assert!(self.is_square());
        let mut out = self.clone();
        for i in 0..self.rows {
            out[(i, i)] += lam;
        }
        out
    }

    /// In-place `self += λI`.
    pub fn add_diag_in_place(&mut self, lam: f64) {
        assert!(self.is_square());
        for i in 0..self.rows {
            self[(i, i)] += lam;
        }
    }

    /// Copy the strict lower triangle onto the strict upper one, making the
    /// matrix symmetric (the finishing step of lower-triangle SYRK
    /// assembly/downdates).
    pub fn mirror_lower(&mut self) {
        assert!(self.is_square());
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                self[(i, j)] = self[(j, i)];
            }
        }
    }

    /// Zero out the strict upper triangle (tidy a factor after in-place potrf).
    pub fn zero_upper(&mut self) {
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                self[(i, j)] = 0.0;
            }
        }
    }

    /// Max |a_ij − b_ij|.
    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Lossy f32 round-trip (what the HLO path sees).
    pub fn to_f32_vec(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }

    /// Build from an f32 buffer (HLO results back into native form).
    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Matrix {
        assert_eq!(data.len(), rows * cols);
        Matrix {
            rows,
            cols,
            data: data.iter().map(|&x| x as f64).collect(),
        }
    }
}

impl Index<(usize, usize)> for Matrix {
    type Output = f64;

    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        let show = self.rows.min(6);
        for i in 0..show {
            let cols = self.cols.min(8);
            let vals: Vec<String> = (0..cols).map(|j| format!("{:>10.4}", self[(i, j)])).collect();
            writeln!(f, "  [{}{}]", vals.join(", "), if self.cols > 8 { ", …" } else { "" })?;
        }
        if self.rows > show {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_round_trip() {
        let mut m = Matrix::zeros(3, 4);
        m[(1, 2)] = 5.0;
        assert_eq!(m[(1, 2)], 5.0);
        assert_eq!(m.row(1)[2], 5.0);
        assert_eq!(m.col(2)[1], 5.0);
    }

    #[test]
    fn transpose_involution() {
        let m = Matrix::from_fn(3, 5, |i, j| (i * 10 + j) as f64);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn slice_and_set_block() {
        let m = Matrix::from_fn(4, 4, |i, j| (i * 4 + j) as f64);
        let b = m.slice(1, 3, 2, 4);
        assert_eq!(b.rows(), 2);
        assert_eq!(b[(0, 0)], m[(1, 2)]);
        let mut z = Matrix::zeros(4, 4);
        z.set_block(1, 2, &b);
        assert_eq!(z[(2, 3)], m[(2, 3)]);
        assert_eq!(z[(0, 0)], 0.0);
    }

    #[test]
    fn add_diag() {
        let m = Matrix::eye(3).add_diag(0.5);
        assert_eq!(m[(0, 0)], 1.5);
        assert_eq!(m[(0, 1)], 0.0);
    }

    #[test]
    fn two_rows_mut_disjoint() {
        let mut m = Matrix::from_fn(3, 3, |i, _| i as f64);
        let (a, b) = m.two_rows_mut(0, 2);
        a[0] = 9.0;
        b[0] = 7.0;
        assert_eq!(m[(0, 0)], 9.0);
        assert_eq!(m[(2, 0)], 7.0);
    }

    #[test]
    fn copy_from_and_reset_reuse_allocation() {
        let src = Matrix::from_fn(3, 4, |i, j| (i * 4 + j) as f64);
        let mut dst = Matrix::zeros(3, 4);
        let cap_ptr = dst.as_slice().as_ptr();
        dst.copy_from(&src);
        assert_eq!(dst, src);
        assert_eq!(dst.as_slice().as_ptr(), cap_ptr, "copy_from must not reallocate");
        dst.reset_zeroed(2, 5);
        assert_eq!((dst.rows(), dst.cols()), (2, 5));
        assert!(dst.as_slice().iter().all(|&v| v == 0.0));
        assert_eq!(dst.as_slice().as_ptr(), cap_ptr, "reset_zeroed must not reallocate");
    }

    #[test]
    fn f32_round_trip() {
        let m = Matrix::from_fn(2, 2, |i, j| (i + j) as f64 + 0.25);
        let back = Matrix::from_f32(2, 2, &m.to_f32_vec());
        assert!(m.max_abs_diff(&back) < 1e-6);
    }
}
