//! Truncated SVD via Golub–Kahan–Lanczos bidiagonalization — the paper's
//! `t-SVD` baseline (§6.2 item 5: "we used an iterative solver to compute the
//! truncated SVD which is faster than the algorithm for computing the full
//! SVD").
//!
//! We run GKL with full reorthogonalization for `k + extra` steps, then take
//! the SVD of the small bidiagonal core via the dense Jacobi routine and keep
//! the top k triplets. Full reorthogonalization costs O(n·steps²) but keeps
//! the basis clean without the usual ghost-eigenvalue heuristics.

use super::gemm::{gemv, gemv_t};
use super::matrix::Matrix;
use super::svd::{jacobi_svd, Svd};
use crate::prng::Xoshiro256;

/// Truncated SVD: top-k singular triplets of an m×n matrix.
///
/// `oversample` extra Lanczos steps sharpen the trailing kept triplets
/// (default 8 is plenty for the spectra here).
pub fn lanczos_svd(a: &Matrix, k: usize, oversample: usize, seed: u64) -> Svd {
    let (m, n) = (a.rows(), a.cols());
    let kk = (k + oversample).min(n.min(m));

    let mut rng = Xoshiro256::seed_from(seed);
    // right Lanczos vectors (rows of vt), left vectors (rows of ut)
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(kk);
    let mut us: Vec<Vec<f64>> = Vec::with_capacity(kk);
    let mut alphas = Vec::with_capacity(kk);
    let mut betas = Vec::with_capacity(kk);

    // random unit start vector
    let mut v = vec![0.0; n];
    rng.fill_normal(&mut v);
    normalize(&mut v);

    let mut beta = 0.0;
    let mut u_prev = vec![0.0; m];

    for j in 0..kk {
        // u_j = A v_j − β_{j-1} u_{j-1}
        let mut u = gemv(a, &v);
        if j > 0 {
            for (ui, &pi) in u.iter_mut().zip(&u_prev) {
                *ui -= beta * pi;
            }
        }
        // full reorthogonalization of u against previous us
        for uo in &us {
            let d = dot(&u, uo);
            for (ui, &oi) in u.iter_mut().zip(uo) {
                *ui -= d * oi;
            }
        }
        let alpha = norm(&u);
        if alpha < 1e-14 {
            break;
        }
        scale(&mut u, 1.0 / alpha);

        vs.push(v.clone());
        us.push(u.clone());
        alphas.push(alpha);

        // v_{j+1} = Aᵀ u_j − α_j v_j
        let mut vnext = gemv_t(a, &u);
        for (vi, &ci) in vnext.iter_mut().zip(&v) {
            *vi -= alpha * ci;
        }
        for vo in &vs {
            let d = dot(&vnext, vo);
            for (vi, &oi) in vnext.iter_mut().zip(vo) {
                *vi -= d * oi;
            }
        }
        beta = norm(&vnext);
        if beta < 1e-14 {
            betas.push(0.0);
            break;
        }
        scale(&mut vnext, 1.0 / beta);
        betas.push(beta);
        u_prev = u;
        v = vnext;
    }

    let steps = alphas.len();
    // small bidiagonal core B (steps×steps): alphas on diag, betas on superdiag
    let mut b = Matrix::zeros(steps, steps);
    for i in 0..steps {
        b[(i, i)] = alphas[i];
        if i + 1 < steps && i < betas.len() {
            b[(i, i + 1)] = betas[i];
        }
    }
    let core = jacobi_svd(&b);

    // assemble truncated factors: U = Us · Uc, V = Vs · Vc
    let keep = k.min(steps);
    let mut u_out = Matrix::zeros(m, keep);
    let mut v_out = Matrix::zeros(n, keep);
    let mut s_out = Vec::with_capacity(keep);
    for t in 0..keep {
        s_out.push(core.s[t]);
        for i in 0..m {
            let mut acc = 0.0;
            for (j, uj) in us.iter().enumerate() {
                acc += uj[i] * core.u[(j, t)];
            }
            u_out[(i, t)] = acc;
        }
        for i in 0..n {
            let mut acc = 0.0;
            for (j, vj) in vs.iter().enumerate() {
                acc += vj[i] * core.v[(j, t)];
            }
            v_out[(i, t)] = acc;
        }
    }
    Svd {
        u: u_out,
        s: s_out,
        v: v_out,
    }
}

fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

fn norm(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

fn scale(a: &mut [f64], s: f64) {
    for x in a {
        *x *= s;
    }
}

fn normalize(a: &mut [f64]) {
    let n = norm(a);
    if n > 0.0 {
        scale(a, 1.0 / n);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::gemm;
    use crate::testutil::{assert_matrix_close, random_lowrank, random_matrix};

    #[test]
    fn recovers_top_singular_values() {
        let a = random_matrix(60, 30, 1);
        let full = jacobi_svd(&a);
        // generous oversampling: a flat random spectrum converges slowly, so
        // accept engineering accuracy on the trailing kept triplet
        let trunc = lanczos_svd(&a, 5, 20, 2);
        for i in 0..5 {
            let rel = (full.s[i] - trunc.s[i]).abs() / full.s[i];
            assert!(rel < 1e-3, "σ{i}: {} vs {}", full.s[i], trunc.s[i]);
        }
        // and full-length Lanczos (kk = n) is exact
        let exact = lanczos_svd(&a, 5, 25, 2);
        for i in 0..5 {
            let rel = (full.s[i] - exact.s[i]).abs() / full.s[i];
            assert!(rel < 1e-8, "full-length σ{i}: {} vs {}", full.s[i], exact.s[i]);
        }
    }

    #[test]
    fn exact_on_lowrank() {
        let a = random_lowrank(50, 24, 4, 3);
        let trunc = lanczos_svd(&a, 4, 6, 4);
        // rank-4 matrix: rank-4 truncation reconstructs it
        let us = Matrix::from_fn(50, 4, |i, j| trunc.u[(i, j)] * trunc.s[j]);
        let rec = gemm(&us, &trunc.v.transpose());
        assert_matrix_close(&rec, &a, 1e-7);
    }

    #[test]
    fn factors_orthonormal() {
        let a = random_matrix(40, 20, 5);
        let t = lanczos_svd(&a, 6, 8, 6);
        let utu = gemm(&t.u.transpose(), &t.u);
        let vtv = gemm(&t.v.transpose(), &t.v);
        assert_matrix_close(&utu, &Matrix::eye(6), 1e-8);
        assert_matrix_close(&vtv, &Matrix::eye(6), 1e-8);
    }
}
