//! Randomized SVD (Halko–Martinsson–Tropp) — the paper's `r-SVD` baseline
//! (§6.2 item 6, citing [13]).
//!
//! Standard prototype: sketch `Y = (A Aᵀ)^q A Ω` with a Gaussian test matrix
//! Ω (n × (k+p)), orthonormalize, project, and take the small dense SVD.
//! `q` power iterations sharpen the spectrum for the slowly-decaying Gram
//! spectra the paper's datasets produce.

use super::gemm::Gemm;
use super::matrix::Matrix;
use super::qr::householder_qr_thin;
use super::svd::{jacobi_svd, Svd};
use crate::prng::Xoshiro256;

/// Randomized truncated SVD: top-k triplets of an m×n matrix.
///
/// * `oversample` — extra sketch columns p (HMT recommend 5–10).
/// * `power_iters` — q in `(A Aᵀ)^q A Ω`; 1–2 suffices for our spectra.
pub fn randomized_svd(
    a: &Matrix,
    k: usize,
    oversample: usize,
    power_iters: usize,
    seed: u64,
) -> Svd {
    let (m, n) = (a.rows(), a.cols());
    let l = (k + oversample).min(n).min(m);
    let mut rng = Xoshiro256::seed_from(seed);
    let gem = Gemm::default();

    // Gaussian sketch Ω (n×l) → Y = AΩ (m×l)
    let omega = Matrix::from_fn(n, l, |_, _| rng.normal());
    let mut y = gem.mul(a, &omega);

    // power iterations with QR re-orthonormalization between applications
    for _ in 0..power_iters {
        let (q, _) = householder_qr_thin(&y);
        let z = gem.at_b(a, &q); // Aᵀ Q  (n×l)
        let (qz, _) = householder_qr_thin(&z);
        y = gem.mul(a, &qz);
    }

    let (q, _) = householder_qr_thin(&y); // m×l orthonormal range basis
    let b = gem.at_b(&q, a); // B = Qᵀ A (l×n)

    // dense SVD of the small B (pass transpose: jacobi wants tall)
    let bt = b.transpose(); // n×l
    let svd_bt = jacobi_svd(&bt); // Bᵀ = U_b S V_bᵀ  →  B = V_b S U_bᵀ
    let keep = k.min(l);

    // U = Q · V_b[:, :k],  V = U_b[:, :k]
    let vb = svd_bt.v; // l×l
    let mut u = Matrix::zeros(m, keep);
    for i in 0..m {
        for t in 0..keep {
            let mut acc = 0.0;
            for j in 0..l {
                acc += q[(i, j)] * vb[(j, t)];
            }
            u[(i, t)] = acc;
        }
    }
    let v = Matrix::from_fn(n, keep, |i, t| svd_bt.u[(i, t)]);
    Svd {
        u,
        s: svd_bt.s[..keep].to_vec(),
        v,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::gemm;
    use crate::testutil::{assert_matrix_close, random_lowrank, random_matrix};

    #[test]
    fn exact_on_lowrank() {
        let a = random_lowrank(60, 30, 5, 1);
        let r = randomized_svd(&a, 5, 8, 1, 2);
        let us = Matrix::from_fn(60, 5, |i, j| r.u[(i, j)] * r.s[j]);
        let rec = gemm(&us, &r.v.transpose());
        assert_matrix_close(&rec, &a, 1e-7);
    }

    #[test]
    fn approximates_top_spectrum() {
        let a = random_matrix(80, 40, 3);
        let full = jacobi_svd(&a);
        let r = randomized_svd(&a, 6, 10, 2, 4);
        for i in 0..6 {
            let rel = (full.s[i] - r.s[i]).abs() / full.s[i];
            assert!(rel < 0.05, "σ{i} rel err {rel}");
        }
    }

    #[test]
    fn orthonormal_factors() {
        let a = random_matrix(50, 25, 5);
        let r = randomized_svd(&a, 8, 6, 1, 6);
        let utu = gemm(&r.u.transpose(), &r.u);
        assert_matrix_close(&utu, &Matrix::eye(8), 1e-8);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = random_matrix(30, 15, 7);
        let r1 = randomized_svd(&a, 4, 4, 1, 42);
        let r2 = randomized_svd(&a, 4, 4, 1, 42);
        assert_eq!(r1.s, r2.s);
    }
}
