//! Factor drift budgets — the numerical-trust tags carried by every reused
//! Cholesky factor.
//!
//! The whole premise of the crate is factor *reuse*: interpolated anchors,
//! chained rank-k fold downdates, incremental `append_rows`/`retire_rows`
//! maintenance. Every reuse step is exact in exact arithmetic and accumulates
//! rounding in f64 — a factor that has been downdated a thousand times no
//! longer satisfies `L·Lᵀ = G + λI` to working precision, and nothing in the
//! reports would say so. ROADMAP item 1 calls a cheap running bound on
//! `‖L·Lᵀ − (G + λI)‖_F` "the SLA knob of the whole service"; this module is
//! that knob.
//!
//! ## The bound
//!
//! A [`FactorTrust`] tag travels with a factor from the moment it is produced
//! by a full factorization ([`FactorTrust::fresh`], drift 0) and is *charged*
//! once per rank-k update/downdate from the rotation identities the kernel
//! already computes ([`RotationStats`], accumulated for free inside
//! [`crate::linalg::chud`]'s scalar recurrence):
//!
//! - every Givens/hyperbolic rotation at pivot `j`, vector `q` moves entries
//!   of magnitude `√(l_jj² ± v_qj²)`; the sum `Σ (l_jj² + v_qj²)` over the
//!   pass (`pivot_sq_sum`) upper-bounds the Frobenius mass the pass rotated
//!   (for one pass it is `≥ tr(A) = ‖L‖_F²`, and `tr(A) ≥ ‖A‖_F` for SPD
//!   `A`);
//! - hyperbolic rotations amplify pre-existing error by `1/c = l_jj/r ≥ 1`;
//!   the pass keeps the worst single-rotation amplification (`amp_max`).
//!
//! The per-op charge is the standard backward-error shape `O(ε·√d·‖A‖_F)`
//! with an explicit safety constant and the measured amplification folded in:
//!
//! ```text
//!   drift ← amp·drift + TRUST_CHARGE_CONST · ε · √d · amp · pivot_sq_sum
//! ```
//!
//! This is a deliberately *generous* upper bound — cheap (O(1) arithmetic on
//! statistics the kernel computes anyway), certified by property tests
//! against the directly computed residual `‖L·Lᵀ − A‖_F` over randomized
//! update/downdate chains ([`tests`]): the bound must hold, and on
//! well-conditioned inputs stays within a documented slack factor
//! ([`TRUST_SLACK_FACTOR`]) of the true residual.
//!
//! ## The budget
//!
//! A [`TrustBudget`] (the `[trust]` config section / `--trust-budget` CLI
//! knob) declares the maximum *relative* drift (`drift / ‖L₀‖_F²`, i.e.
//! relative to `tr(G + λI)` at the last full factorization) and optionally a
//! maximum hop count a factor may accumulate before the engine forces a full
//! refactorization for that cell/anchor — the `drift-budget` cause in the
//! degradation report ([`crate::cv::recovery`]). The default budget (1e-8
//! relative) never bites on a single fold downdate (whose charge is ~1e-12
//! relative at d≈128) but catches unbounded incremental chains.

use super::matrix::Matrix;

/// Safety constant of the per-op drift charge (see the module docs): the
/// backward-error constant of one blocked rank-k pass, with margin.
pub const TRUST_CHARGE_CONST: f64 = 16.0;

/// Documented slack of the cheap bound on well-conditioned inputs: the
/// running bound stays within this factor of the directly computed residual
/// (floored at one ε of the matrix scale) — pinned by the property tests
/// below. The bound is *loose by design*; it must never under-estimate.
pub const TRUST_SLACK_FACTOR: f64 = 1e5;

/// Cheap per-pass rotation statistics, accumulated by the chud kernels
/// alongside (never inside) the arithmetic — collecting them does not change
/// a single bit of the factor.
#[derive(Clone, Copy, Debug)]
pub struct RotationStats {
    /// `Σ (l_jj² + v_qj²)` over every pivot rotation of the pass.
    pub pivot_sq_sum: f64,
    /// Worst single-rotation error amplification `max l_jj/r` over the
    /// hyperbolic rotations (1.0 for pure updates — Givens rotations are
    /// orthogonal and amplify nothing).
    pub amp_max: f64,
    /// Number of pivot rotations applied (`d·k` for a full rank-k pass).
    pub rotations: u64,
}

impl Default for RotationStats {
    fn default() -> Self {
        Self {
            pivot_sq_sum: 0.0,
            amp_max: 1.0,
            rotations: 0,
        }
    }
}

impl RotationStats {
    pub fn new() -> Self {
        Self::default()
    }
}

/// The running numerical-trust tag of one factor: a cheap upper bound on
/// `‖L·Lᵀ − A_target‖_F` plus the hop count since the last full
/// factorization. `Copy` on purpose — per-cell paths clone the anchor's tag
/// and charge the clone, so a breakdown or budget hit in one cell never
/// poisons the shared anchor's accounting.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FactorTrust {
    /// `‖L₀‖_F² = tr(A₀)` at the last full factorization — the scale the
    /// relative budget is measured against.
    base: f64,
    /// Running upper bound on `‖L·Lᵀ − A_target‖_F` (absolute units of A).
    drift: f64,
    /// Rank-k update/downdate passes absorbed since the last full
    /// factorization.
    hops: u64,
}

impl FactorTrust {
    /// Tag for a factor fresh out of a full factorization: zero drift, zero
    /// hops, scale anchored at `‖L‖_F²`.
    pub fn fresh(l: &Matrix) -> Self {
        let base: f64 = l.as_slice().iter().map(|v| v * v).sum();
        Self {
            base: base.max(f64::MIN_POSITIVE),
            drift: 0.0,
            hops: 0,
        }
    }

    /// Tag for a factor of known scale (when the factor itself is not at
    /// hand); `base` is clamped positive.
    pub fn with_base(base: f64) -> Self {
        Self {
            base: base.max(f64::MIN_POSITIVE),
            drift: 0.0,
            hops: 0,
        }
    }

    /// Charge one rank-k update/downdate pass of a `dim×dim` factor from its
    /// rotation statistics (see the module docs for the formula).
    pub fn charge(&mut self, dim: usize, stats: &RotationStats) {
        let amp = stats.amp_max.max(1.0);
        let inc =
            TRUST_CHARGE_CONST * f64::EPSILON * (dim as f64).sqrt() * amp * stats.pivot_sq_sum;
        self.drift = amp * self.drift + inc;
        self.hops += 1;
    }

    /// The absolute running bound on `‖L·Lᵀ − A_target‖_F`.
    pub fn drift(&self) -> f64 {
        self.drift
    }

    /// The bound relative to the factor's scale at the last full
    /// factorization (`tr(A₀)`), the unit [`TrustBudget`] is written in.
    pub fn relative_drift(&self) -> f64 {
        self.drift / self.base
    }

    /// Rank-k passes since the last full factorization.
    pub fn hops(&self) -> u64 {
        self.hops
    }

    /// The scale anchor `‖L₀‖_F²`.
    pub fn base(&self) -> f64 {
        self.base
    }

    /// Has this factor spent its budget? True forces a full refactorization
    /// on the trust-aware paths.
    pub fn exceeds(&self, budget: &TrustBudget) -> bool {
        let drift_hit = budget.max_relative_drift.is_finite()
            && budget.max_relative_drift > 0.0
            && self.relative_drift() > budget.max_relative_drift;
        let hops_hit = budget.max_hops > 0 && self.hops > budget.max_hops;
        drift_hit || hops_hit
    }
}

/// The configurable drift budget — the `[trust]` section of the experiment
/// config and the `--trust-budget` / `--trust-max-hops` CLI knobs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TrustBudget {
    /// Maximum allowed [`FactorTrust::relative_drift`]. Non-finite or ≤ 0
    /// disables the drift check.
    pub max_relative_drift: f64,
    /// Maximum rank-k hops since the last full factorization; 0 disables
    /// the hop check.
    pub max_hops: u64,
}

impl TrustBudget {
    /// A budget that never forces anything — the behavior of every path
    /// before this subsystem existed.
    pub const fn unlimited() -> Self {
        Self {
            max_relative_drift: f64::INFINITY,
            max_hops: 0,
        }
    }
}

impl Default for TrustBudget {
    /// 1e-8 relative drift, unlimited hops: roomy enough that single fold
    /// downdates (~1e-12 relative) never trip it, tight enough that an
    /// unbounded incremental chain eventually forces a refresh.
    fn default() -> Self {
        Self {
            max_relative_drift: 1e-8,
            max_hops: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::cholesky::cholesky_blocked;
    use crate::linalg::chud::{chol_downdate_tracked, chol_update_tracked};
    use crate::linalg::gemm::Gemm;
    use crate::testutil::{random_matrix, random_spd};

    fn fro(m: &Matrix) -> f64 {
        m.as_slice().iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    #[test]
    fn fresh_tag_is_clean() {
        let a = random_spd(9, 10.0, 1);
        let l = cholesky_blocked(&a).unwrap();
        let t = FactorTrust::fresh(&l);
        assert_eq!(t.drift(), 0.0);
        assert_eq!(t.hops(), 0);
        assert!(t.base() > 0.0);
        assert!(!t.exceeds(&TrustBudget::default()));
        assert!(!t.exceeds(&TrustBudget::unlimited()));
    }

    #[test]
    fn charge_accumulates_and_budget_trips() {
        let mut t = FactorTrust::with_base(1.0);
        let stats = RotationStats {
            pivot_sq_sum: 1.0,
            amp_max: 1.0,
            rotations: 4,
        };
        t.charge(4, &stats);
        assert!(t.drift() > 0.0);
        assert_eq!(t.hops(), 1);
        // a budget below the single charge trips; one above does not
        let tight = TrustBudget {
            max_relative_drift: t.relative_drift() / 2.0,
            max_hops: 0,
        };
        let roomy = TrustBudget {
            max_relative_drift: t.relative_drift() * 2.0,
            max_hops: 0,
        };
        assert!(t.exceeds(&tight));
        assert!(!t.exceeds(&roomy));
    }

    #[test]
    fn hop_budget_trips_independently_of_drift() {
        let mut t = FactorTrust::with_base(1.0);
        let stats = RotationStats::default(); // zero mass: drift stays 0
        for _ in 0..3 {
            t.charge(4, &stats);
        }
        assert_eq!(t.drift(), 0.0);
        assert_eq!(t.hops(), 3);
        let hop_budget = TrustBudget {
            max_relative_drift: f64::INFINITY,
            max_hops: 2,
        };
        assert!(t.exceeds(&hop_budget));
        let roomy = TrustBudget {
            max_relative_drift: f64::INFINITY,
            max_hops: 3,
        };
        assert!(!t.exceeds(&roomy));
    }

    #[test]
    fn unlimited_budget_never_trips() {
        let mut t = FactorTrust::with_base(1e-300);
        let stats = RotationStats {
            pivot_sq_sum: 1e300,
            amp_max: 10.0,
            rotations: 1,
        };
        for _ in 0..50 {
            t.charge(1000, &stats);
        }
        assert!(!t.exceeds(&TrustBudget::unlimited()));
    }

    /// The satellite property suite: over randomized update/downdate chains
    /// the cheap running bound must (a) dominate the directly computed
    /// residual `‖L·Lᵀ − A‖_F` and (b) stay within [`TRUST_SLACK_FACTOR`] of
    /// it (floored at ε of the matrix scale) on well-conditioned inputs —
    /// the bound is generous, not vacuous.
    #[test]
    fn prop_drift_bound_dominates_measured_residual() {
        use crate::testutil::proptest_lite;
        let dims = [3usize, 8, 17, 30];
        proptest_lite::check("trust bound ≥ residual", 20, |case| {
            let d = dims[case.index % dims.len()];
            let cond = 10f64.powf(case.float(0.5, 3.0));
            let seed = 0x7A57_0000 + case.index as u64;
            let a0 = random_spd(d, cond, seed);
            let mut l = cholesky_blocked(&a0).unwrap();
            let mut trust = FactorTrust::fresh(&l);
            let mut target = a0.clone();
            let mut trans = Matrix::zeros(0, 0);

            let n_ops = 1 + case.index % 5;
            for op in 0..n_ops {
                let k = 1 + (case.index + op) % 3;
                // update vectors scaled small so downdates keep λ_min ≈ 1
                // margin: ‖U·Uᵀ‖_F ≤ 0.25 per op
                let mut u = random_matrix(d, k, seed ^ (0xACE0 + op as u64));
                for q in 0..k {
                    let norm: f64 =
                        (0..d).map(|i| u[(i, q)] * u[(i, q)]).sum::<f64>().sqrt();
                    let scale = 0.5 / ((k as f64).sqrt() * norm.max(1e-12));
                    for i in 0..d {
                        u[(i, q)] *= scale;
                    }
                }
                let uut = Gemm::default().a_bt(&u, &u);
                let down = op % 2 == 1;
                let mut ub = u.clone();
                if down {
                    chol_downdate_tracked(&mut l, &mut ub, &mut trans, &mut trust).unwrap();
                } else {
                    chol_update_tracked(&mut l, &mut ub, &mut trans, &mut trust);
                }
                let sign = if down { -1.0 } else { 1.0 };
                target = Matrix::from_fn(d, d, |i, j| target[(i, j)] + sign * uut[(i, j)]);
            }
            assert_eq!(trust.hops(), n_ops as u64);

            // directly computed residual ‖L·Lᵀ − A_target‖_F (lower triangle
            // of the target mirrors its symmetry)
            let llt = Gemm::default().a_bt(&l, &l);
            let resid = Matrix::from_fn(d, d, |i, j| {
                let t = if j <= i { target[(i, j)] } else { target[(j, i)] };
                llt[(i, j)] - t
            });
            let resid_f = fro(&resid);
            assert!(
                resid_f <= trust.drift(),
                "bound violated: residual {resid_f:.3e} > drift {:.3e} \
                 (d={d} ops={n_ops} cond={cond:.1e})",
                trust.drift()
            );
            // and the bound is not vacuous: within the documented slack of
            // the residual, floored at ε of the matrix scale
            let floor = f64::EPSILON * trust.base();
            assert!(
                trust.drift() <= TRUST_SLACK_FACTOR * (resid_f + floor),
                "bound too loose: drift {:.3e} > {TRUST_SLACK_FACTOR:.0e}·({resid_f:.3e} + {floor:.3e}) \
                 (d={d} ops={n_ops} cond={cond:.1e})",
                trust.drift()
            );
        });
    }
}
