//! Rank-1 / rank-k Cholesky update and downdate — the factor-update
//! subsystem.
//!
//! The whole premise of the paper is that refactorizing the Hessian
//! dominates cross-validation cost. The update/downdate kernels attack the
//! workloads where a factor we *already hold* is perturbed by a low-rank
//! term, so a fresh `O(d³)` factorization is pure waste:
//!
//! - **leave-one-out CV** ([`crate::cv::loo`]): `H_i + λI = (G + λI) − x_i
//!   x_iᵀ` — every held-out factor is a rank-1 *downdate* of the per-λ
//!   anchor factor `chol(G + λI)`, `O(d²)` instead of `O(d³)`;
//! - **streaming data** ([`crate::data::gram::GramCache::append_rows`]):
//!   `m` new rows turn `G + λI` into `(G + λI) + X_newᵀX_new` — a rank-m
//!   *update* of each cached anchor factor.
//!
//! ## The kernels
//!
//! Given `L` with `L·Lᵀ = A` and an update block `U` (`n×k`, one update
//! vector per column):
//!
//! - [`chol_update`] rewrites `L` in place so `L·Lᵀ = A + U·Uᵀ`, via a
//!   sequence of **Givens rotations**: per (column `j`, vector `q`),
//!   `r = √(L[j][j]² + v[j]²)`, `c = r/L[j][j]`, `s = v[j]/L[j][j]`, then
//!   each affected pair transforms as `l ← (l + s·v)/c`, `v ← c·v − s·l`.
//!   Rotations are orthogonal, so the update can never break down.
//! - [`chol_downdate`] rewrites `L` so `L·Lᵀ = A − U·Uᵀ`, via **hyperbolic
//!   rotations**: the same recurrence with `r = √(L[j][j]² − v[j]²)` and
//!   `l ← (l − s·v)/c`. When `A − U·Uᵀ` is not (numerically)
//!   positive-definite some pivot satisfies `L[j][j]² − v[j]² ≤ 0`; the
//!   kernel stops and reports the failing **column index** as a
//!   [`CholeskyError`] (`pivot = j`, `value` = the non-positive `r²`) —
//!   it never panics, so a pool worker survives a breakdown and the caller
//!   can skip/record the bad perturbation (the LOO sweep does exactly
//!   that).
//!
//! ## Blocking — trailing panels run on the packed kernel engine
//!
//! The scalar recurrence is BLAS-1. The blocked form processes panels of
//! [`CHUD_BLOCK`] columns: the rotations for a panel depend only on the
//! panel's diagonal block and the matching rows of `U`, so they are
//! computed by the scalar recurrence on those rows **while being
//! accumulated into one `(jb+k)×(jb+k)` transform matrix `T`** (each
//! rotation is a linear map on the row space `[L[i, panel] | U[i, :]]`, and
//! `T` is their product, built with the very same scalar operations applied
//! to `T`'s columns). The trailing rows then apply `T` in one shot:
//!
//! ```text
//!   [L[i, panel] | U[i, :]] ← [L[i, panel] | U[i, :]] · T    for i > panel
//! ```
//!
//! — two GEMM-shaped products per row chunk (`L`-part and `U`-part of the
//! input, `Acc::Set` + `Acc::Add`) routed through the packed
//! register-blocked engine ([`super::kernel`]), exactly like the blocked
//! Cholesky's TRSM/SYRK trailing updates. The transform buffer `T` is drawn
//! from the per-worker [`Scratch`](super::scratch::Scratch) arena
//! (`scratch.trans`, passed explicitly so callers can borrow other scratch
//! fields at the same time); the GEMM output panel uses the kernel's
//! thread-local arena — steady-state downdates allocate nothing.
//!
//! ## Chaining — rank-k as a chain of rank-[`CHUD_RANK_CHUNK`] passes
//!
//! The composed transform is `(jb+k)²`, so a single monolithic pass costs
//! `O(k²·n²/b)` once `k ≫ b` — quadratic in the rank. The core therefore
//! **chains** the update block through the factor in column chunks of at
//! most [`CHUD_RANK_CHUNK`] vectors (`A ± U·Uᵀ = ((A ± U₁U₁ᵀ) ± U₂U₂ᵀ) ±
//! …`), keeping every transform `(jb + CHUD_RANK_CHUNK)`-wide and the total
//! work at `O(k·n²)`. This is what makes the **factor-level k-fold
//! workload** ([`downdate_rank_k`]: rank-`n_v` fold downdates of
//! `chol(G + λI)`) scale like `n_v` downdates instead of one `n_v²`-priced
//! transform. With `k ≤ CHUD_RANK_CHUNK` there is exactly one chunk and the
//! chained core is bitwise the original single-pass algorithm.
//!
//! ## Determinism
//!
//! Each kernel is a pure serial function of `(L, U)`: no pool, no shared
//! state, and the packed products use the engine's fixed accumulation
//! schedule. Fanning independent downdates across workers (the LOO sweep's
//! per-i tasks) therefore yields bitwise identical results at any worker
//! count — pinned by `round_trip_bitwise_across_worker_counts`.
//!
//! ## Breakdown contract
//!
//! On `Err`, `L` (and `U`) hold a partially-transformed state and are
//! unusable — same contract as [`super::cholesky::cholesky_in_place`]. The
//! LOO engine copies the anchor factor into scratch before every downdate,
//! so a breakdown only poisons the scratch copy, never the shared anchor.

use super::cholesky::CholeskyError;
use super::kernel::{self, Acc, Src};
use super::matrix::Matrix;
use super::trust::{FactorTrust, RotationStats};

/// Panel width of the blocked kernels. Small enough that the `(jb+k)²`
/// transform stays register/L1-friendly and the extra flops of the composed
/// transform (vs the scalar recurrence) stay bounded, large enough that the
/// trailing work is GEMM-shaped.
pub const CHUD_BLOCK: usize = 16;

/// Row chunk of the trailing transform application (bounds the kernel's
/// thread-local output panel, like the blocked Cholesky's `SYRK_CHUNK`).
const CHUD_ROW_CHUNK: usize = 128;

/// Column-chunk width of the chained rank-k processing: update vectors are
/// folded through the factor in runs of at most this many columns, so the
/// per-panel transform stays `(jb + CHUD_RANK_CHUNK)`-wide and the total
/// work scales as `O(k·n²)` instead of the `O(k²·n²/b)` one monolithic
/// transform would cost once `k ≫` [`CHUD_BLOCK`] (the fold-downdate
/// workload: rank `n_v = n/k` into a `d×d` factor). Equal to [`CHUD_BLOCK`]
/// — the `(b+c)²/(b·c)` flop overhead of the composed transform is
/// minimized at `c = b`.
pub const CHUD_RANK_CHUNK: usize = CHUD_BLOCK;

/// Update (`A + U·Uᵀ`, Givens) or downdate (`A − U·Uᵀ`, hyperbolic)?
#[derive(Clone, Copy, PartialEq)]
enum Dir {
    Update,
    Downdate,
}

/// The shared blocked core. `u` is the row-major `n×k` update block (one
/// vector per column), destroyed in the process; `block` is the panel
/// width; `trans` is the reusable transform buffer (reshaped and fully
/// overwritten per panel). Rank-k perturbations are **chained** through the
/// factor in column chunks of [`CHUD_RANK_CHUNK`] vectors (see the module
/// docs); with `k ≤ CHUD_RANK_CHUNK` the chain is a single pass, bitwise
/// identical to the unchained algorithm.
fn chud_in_place(
    l: &mut Matrix,
    u: &mut [f64],
    k: usize,
    block: usize,
    dir: Dir,
    trans: &mut Matrix,
    stats: &mut RotationStats,
) -> Result<(), CholeskyError> {
    assert!(l.is_square(), "chud needs a square factor");
    let n = l.rows();
    assert_eq!(u.len(), n * k, "update block shape mismatch");
    if n == 0 || k == 0 {
        return Ok(());
    }
    let mut q0 = 0;
    while q0 < k {
        let q1 = (q0 + CHUD_RANK_CHUNK).min(k);
        chud_chunk(l, u, k, q0, q1, block, dir, trans, stats)?;
        q0 = q1;
    }
    Ok(())
}

/// One chain link: fold update-block columns `[q0, q1)` into `l` (all
/// panels). On `Err` the factor holds the partially-transformed state —
/// same unusable-on-error contract as the public entry points.
fn chud_chunk(
    l: &mut Matrix,
    u: &mut [f64],
    k: usize,
    q0: usize,
    q1: usize,
    block: usize,
    dir: Dir,
    trans: &mut Matrix,
    stats: &mut RotationStats,
) -> Result<(), CholeskyError> {
    let n = l.rows();
    let kc = q1 - q0;
    let block = block.max(1);
    let stride = n;

    let mut j0 = 0;
    while j0 < n {
        let j1 = (j0 + block).min(n);
        let jb = j1 - j0;
        let w = jb + kc;

        // T ← I. Each rotation below is also applied to T's columns, so T
        // ends up as the composed linear map the trailing rows need.
        trans.reset_zeroed(w, w);
        for t in 0..w {
            trans[(t, t)] = 1.0;
        }

        // panel pass: the scalar recurrence on rows j0..j1, in the same
        // (vector-major, ascending-column) order the unblocked algorithm
        // uses — with block ≥ n this IS the unblocked algorithm.
        {
            let ld = l.as_mut_slice();
            for q in q0..q1 {
                for j in j0..j1 {
                    let ljj = ld[j * stride + j];
                    let vqj = u[j * k + q];
                    let r = match dir {
                        Dir::Update => (ljj * ljj + vqj * vqj).sqrt(),
                        Dir::Downdate => {
                            let r2 = ljj * ljj - vqj * vqj;
                            if r2 <= 0.0 || !r2.is_finite() {
                                // numerically indefinite at column j: stop
                                // and report the failing column
                                return Err(CholeskyError { pivot: j, value: r2 });
                            }
                            r2.sqrt()
                        }
                    };
                    // drift-budget bookkeeping (rotation identities, see
                    // super::trust) — pure observation, never touches the
                    // factor arithmetic
                    stats.rotations += 1;
                    stats.pivot_sq_sum += ljj * ljj + vqj * vqj;
                    if let Dir::Downdate = dir {
                        let amp = ljj / r;
                        if amp > stats.amp_max {
                            stats.amp_max = amp;
                        }
                    }
                    let c = r / ljj;
                    let s = vqj / ljj;
                    ld[j * stride + j] = r;
                    // panel rows below the pivot, scalar
                    for i in (j + 1)..j1 {
                        let lij = ld[i * stride + j];
                        let viq = u[i * k + q];
                        let lij_new = match dir {
                            Dir::Update => (lij + s * viq) / c,
                            Dir::Downdate => (lij - s * viq) / c,
                        };
                        u[i * k + q] = c * viq - s * lij_new;
                        ld[i * stride + j] = lij_new;
                    }
                    // fold the rotation into T (columns j−j0 and the chunk-
                    // local jb+(q−q0)), with the exact same scalar ops as
                    // the row transform
                    let (cj, cb) = (j - j0, jb + (q - q0));
                    for t in 0..w {
                        let a = trans[(t, cj)];
                        let b = trans[(t, cb)];
                        let a_new = match dir {
                            Dir::Update => (a + s * b) / c,
                            Dir::Downdate => (a - s * b) / c,
                        };
                        trans[(t, cb)] = c * b - s * a_new;
                        trans[(t, cj)] = a_new;
                    }
                }
            }
        }

        // trailing rows: [L[i, j0..j1] | U[i, q0..q1]] · T through the
        // packed kernel, chunked to bound the thread-local output panel
        if j1 < n {
            let m_total = n - j1;
            for r0 in (0..m_total).step_by(CHUD_ROW_CHUNK) {
                let r1 = (r0 + CHUD_ROW_CHUNK).min(m_total);
                let rows = r1 - r0;
                kernel::with_tmp(rows * w, |tmp| {
                    // tmp = L[j1+r0.., j0..j1] · T[0..jb, :]
                    kernel::gemm_into(
                        rows,
                        w,
                        jb,
                        Src::N {
                            data: l.as_slice(),
                            stride,
                            r0: j1 + r0,
                            c0: j0,
                        },
                        Src::N {
                            data: trans.as_slice(),
                            stride: w,
                            r0: 0,
                            c0: 0,
                        },
                        tmp,
                        w,
                        0,
                        0,
                        Acc::Set,
                    );
                    // tmp += U[j1+r0.., q0..q1] · T[jb.., :]
                    kernel::gemm_into(
                        rows,
                        w,
                        kc,
                        Src::N {
                            data: &*u,
                            stride: k,
                            r0: j1 + r0,
                            c0: q0,
                        },
                        Src::N {
                            data: trans.as_slice(),
                            stride: w,
                            r0: jb,
                            c0: 0,
                        },
                        tmp,
                        w,
                        0,
                        0,
                        Acc::Add,
                    );
                    // scatter back into the factor panel and U's chunk cols
                    let ld = l.as_mut_slice();
                    for i in 0..rows {
                        let gi = j1 + r0 + i;
                        ld[gi * stride + j0..gi * stride + j1]
                            .copy_from_slice(&tmp[i * w..i * w + jb]);
                        u[gi * k + q0..gi * k + q1]
                            .copy_from_slice(&tmp[i * w + jb..(i + 1) * w]);
                    }
                });
            }
        }
        j0 = j1;
    }
    Ok(())
}

/// Rank-k Cholesky **update**: rewrite `L` in place so `L·Lᵀ = A + U·Uᵀ`,
/// where `U` is `n×k` (one update vector per column; destroyed). `trans` is
/// the per-worker transform buffer (`Scratch::trans` on the pool paths).
/// Givens rotations are orthogonal, so the update cannot break down.
pub fn chol_update(l: &mut Matrix, u: &mut Matrix, trans: &mut Matrix) {
    let mut stats = RotationStats::new();
    assert_eq!(u.rows(), l.rows(), "update block must have n rows");
    let k = u.cols();
    chud_in_place(
        l,
        u.as_mut_slice(),
        k,
        CHUD_BLOCK,
        Dir::Update,
        trans,
        &mut stats,
    )
    .expect("rank-k Cholesky update cannot break down");
}

/// [`chol_update`] with drift accounting: the pass's rotation statistics are
/// charged to `trust` (see [`super::trust`]). Bitwise identical factor to
/// the untracked variant.
pub fn chol_update_tracked(
    l: &mut Matrix,
    u: &mut Matrix,
    trans: &mut Matrix,
    trust: &mut FactorTrust,
) {
    let mut stats = RotationStats::new();
    assert_eq!(u.rows(), l.rows(), "update block must have n rows");
    let k = u.cols();
    let dim = l.rows();
    chud_in_place(
        l,
        u.as_mut_slice(),
        k,
        CHUD_BLOCK,
        Dir::Update,
        trans,
        &mut stats,
    )
    .expect("rank-k Cholesky update cannot break down");
    trust.charge(dim, &stats);
}

/// Rank-k Cholesky **downdate**: rewrite `L` in place so `L·Lᵀ = A − U·Uᵀ`
/// (`U` destroyed). Returns [`CholeskyError`] with the failing column index
/// when `A − U·Uᵀ` is numerically indefinite; `L`/`U` are then unusable
/// (copy first if you need to recover — see the module docs).
pub fn chol_downdate(
    l: &mut Matrix,
    u: &mut Matrix,
    trans: &mut Matrix,
) -> Result<(), CholeskyError> {
    let mut stats = RotationStats::new();
    assert_eq!(u.rows(), l.rows(), "update block must have n rows");
    let k = u.cols();
    chud_in_place(
        l,
        u.as_mut_slice(),
        k,
        CHUD_BLOCK,
        Dir::Downdate,
        trans,
        &mut stats,
    )
}

/// [`chol_downdate`] with drift accounting: the pass's rotation statistics
/// are charged to `trust` whether it succeeds or breaks down (on `Err` the
/// factor is unusable regardless, and the caller escalates). Bitwise
/// identical factor to the untracked variant.
pub fn chol_downdate_tracked(
    l: &mut Matrix,
    u: &mut Matrix,
    trans: &mut Matrix,
    trust: &mut FactorTrust,
) -> Result<(), CholeskyError> {
    let mut stats = RotationStats::new();
    assert_eq!(u.rows(), l.rows(), "update block must have n rows");
    let k = u.cols();
    let dim = l.rows();
    let out = chud_in_place(
        l,
        u.as_mut_slice(),
        k,
        CHUD_BLOCK,
        Dir::Downdate,
        trans,
        &mut stats,
    );
    trust.charge(dim, &stats);
    out
}

/// Rank-1 update: `L·Lᵀ ← A + v·vᵀ` (`v` destroyed). The streaming-row
/// fast path of [`chol_update`].
pub fn chol_update_rank1(l: &mut Matrix, v: &mut [f64], trans: &mut Matrix) {
    let mut stats = RotationStats::new();
    chud_in_place(l, v, 1, CHUD_BLOCK, Dir::Update, trans, &mut stats)
        .expect("rank-1 Cholesky update cannot break down");
}

/// [`chol_update_rank1`] with drift accounting (see
/// [`chol_update_tracked`]).
pub fn chol_update_rank1_tracked(
    l: &mut Matrix,
    v: &mut [f64],
    trans: &mut Matrix,
    trust: &mut FactorTrust,
) {
    let mut stats = RotationStats::new();
    let dim = l.rows();
    chud_in_place(l, v, 1, CHUD_BLOCK, Dir::Update, trans, &mut stats)
        .expect("rank-1 Cholesky update cannot break down");
    trust.charge(dim, &stats);
}

/// Rank-1 downdate: `L·Lᵀ ← A − v·vᵀ` (`v` destroyed) — the leave-one-out
/// kernel (`chol(G + λI) → chol(G − x_ix_iᵀ + λI)` at `O(d²)`). Errors as
/// [`chol_downdate`].
pub fn chol_downdate_rank1(
    l: &mut Matrix,
    v: &mut [f64],
    trans: &mut Matrix,
) -> Result<(), CholeskyError> {
    let mut stats = RotationStats::new();
    chud_in_place(l, v, 1, CHUD_BLOCK, Dir::Downdate, trans, &mut stats)
}

/// [`chol_downdate_rank1`] with drift accounting (see
/// [`chol_downdate_tracked`]) — the trust-aware leave-one-out kernel.
pub fn chol_downdate_rank1_tracked(
    l: &mut Matrix,
    v: &mut [f64],
    trans: &mut Matrix,
    trust: &mut FactorTrust,
) -> Result<(), CholeskyError> {
    let mut stats = RotationStats::new();
    let dim = l.rows();
    let out = chud_in_place(l, v, 1, CHUD_BLOCK, Dir::Downdate, trans, &mut stats);
    trust.charge(dim, &stats);
    out
}

/// The **factor-level fold downdate** — the k-fold engine's task kernel.
///
/// Given the shared per-λ anchor factor `anchor = chol(G + λI)` and a
/// fold's validation rows `xv` (`n_v×d`), derives the fold factor
/// `chol(H_f + λI) = chol((G + λI) − X_vᵀX_v)` **without touching `H_f`**:
/// copies `anchor` into `out`, gathers the validation rows into the
/// reusable update block `ubuf` (`d×n_v`, one update vector per column) and
/// runs the chained blocked rank-`n_v` hyperbolic downdate —
/// `O(n_v·d²)` against the `O(d³)` refactorization it replaces. All three
/// output/work buffers come from the caller (the per-worker
/// [`Scratch`](super::scratch::Scratch): `factor`, `update`, `trans` on the
/// sweep-engine path), so one worker reuses a single packed `T`-transform
/// buffer across every fold it processes — steady-state fold downdates
/// allocate nothing.
///
/// On [`CholeskyError`] (`H_f + λI` numerically indefinite at the carried
/// column index) `out`/`ubuf` hold partially-transformed state; the anchor
/// itself is never written, so the caller can fall back to refactorizing
/// from the downdated Gram (what
/// [`FoldData::factor_from_anchor`](crate::cv::FoldData::factor_from_anchor)
/// does).
pub fn downdate_rank_k(
    anchor: &Matrix,
    xv: &Matrix,
    out: &mut Matrix,
    ubuf: &mut Matrix,
    trans: &mut Matrix,
) -> Result<(), CholeskyError> {
    let mut stats = RotationStats::new();
    assert_eq!(
        anchor.rows(),
        xv.cols(),
        "validation rows must match the factor dimension"
    );
    gather_update_block(xv, ubuf);
    downdate_gathered(anchor, out, ubuf, trans, &mut stats)
}

/// [`downdate_rank_k`] with drift accounting: `trust` (normally a clone of
/// the anchor's fresh tag) is charged with the pass's rotation statistics
/// whether it succeeds or breaks down. Bitwise identical factor to the
/// untracked variant.
pub fn downdate_rank_k_tracked(
    anchor: &Matrix,
    xv: &Matrix,
    out: &mut Matrix,
    ubuf: &mut Matrix,
    trans: &mut Matrix,
    trust: &mut FactorTrust,
) -> Result<(), CholeskyError> {
    let mut stats = RotationStats::new();
    assert_eq!(
        anchor.rows(),
        xv.cols(),
        "validation rows must match the factor dimension"
    );
    gather_update_block(xv, ubuf);
    let out_res = downdate_gathered(anchor, out, ubuf, trans, &mut stats);
    trust.charge(anchor.rows(), &stats);
    out_res
}

/// Gather a fold's validation rows `xv` (`n_v×d`) into the update block
/// layout `gbuf = X_vᵀ` (`d×n_v`, one update vector per column).
///
/// This is the **λ-independent half** of [`downdate_rank_k`]: the gathered
/// block depends only on the fold's rows, so a sweep task covering several
/// λ cells of one fold gathers once and replays the block per cell via
/// [`downdate_rank_k_pregathered`] — the warm-start move along the λ axis.
pub fn gather_update_block(xv: &Matrix, gbuf: &mut Matrix) {
    let (nv, d) = (xv.rows(), xv.cols());
    gbuf.reset_zeroed(d, nv);
    for i in 0..nv {
        for (j, &v) in xv.row(i).iter().enumerate() {
            gbuf[(j, i)] = v;
        }
    }
}

/// The λ-dependent half of [`downdate_rank_k`]: run the chained blocked
/// rank-`n_v` downdate of `anchor` against a pre-gathered update block `u0`
/// (`d×n_v`, from [`gather_update_block`]). `u0` is copied into the
/// destructible work buffer `ubuf` (a contiguous memcpy — cheaper than the
/// strided row gather) so one gathered block serves any number of λ cells.
/// Bitwise identical to `downdate_rank_k` on the same inputs: the gather
/// produces the exact values this copy replays.
pub fn downdate_rank_k_pregathered(
    anchor: &Matrix,
    u0: &Matrix,
    out: &mut Matrix,
    ubuf: &mut Matrix,
    trans: &mut Matrix,
) -> Result<(), CholeskyError> {
    let mut stats = RotationStats::new();
    assert_eq!(
        anchor.rows(),
        u0.rows(),
        "update block must match the factor dimension"
    );
    ubuf.copy_from(u0);
    downdate_gathered(anchor, out, ubuf, trans, &mut stats)
}

/// [`downdate_rank_k_pregathered`] with drift accounting (see
/// [`downdate_rank_k_tracked`]) — the trust-aware λ-warm-start kernel of the
/// anchored grid wave.
pub fn downdate_rank_k_pregathered_tracked(
    anchor: &Matrix,
    u0: &Matrix,
    out: &mut Matrix,
    ubuf: &mut Matrix,
    trans: &mut Matrix,
    trust: &mut FactorTrust,
) -> Result<(), CholeskyError> {
    let mut stats = RotationStats::new();
    assert_eq!(
        anchor.rows(),
        u0.rows(),
        "update block must match the factor dimension"
    );
    ubuf.copy_from(u0);
    let out_res = downdate_gathered(anchor, out, ubuf, trans, &mut stats);
    trust.charge(anchor.rows(), &stats);
    out_res
}

/// Shared tail of the two rank-`k` entry points: `ubuf` already holds the
/// gathered update block and is destroyed by the transform chain.
fn downdate_gathered(
    anchor: &Matrix,
    out: &mut Matrix,
    ubuf: &mut Matrix,
    trans: &mut Matrix,
    stats: &mut RotationStats,
) -> Result<(), CholeskyError> {
    out.copy_from(anchor);
    let nv = ubuf.cols();
    if nv == 0 {
        return Ok(());
    }
    chud_in_place(
        out,
        ubuf.as_mut_slice(),
        nv,
        CHUD_BLOCK,
        Dir::Downdate,
        trans,
        stats,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::cholesky::cholesky_blocked;
    use crate::linalg::gemm::{syrk_lower, Gemm};
    use crate::testutil::{random_matrix, random_spd};

    /// Textbook unblocked rank-1 recurrence — the oracle the blocked core's
    /// `block ≥ n` path must match bitwise.
    fn rank1_reference(l: &mut Matrix, v: &mut [f64], down: bool) -> Result<(), CholeskyError> {
        let n = l.rows();
        for j in 0..n {
            let ljj = l[(j, j)];
            let r = if down {
                let r2 = ljj * ljj - v[j] * v[j];
                if r2 <= 0.0 || !r2.is_finite() {
                    return Err(CholeskyError { pivot: j, value: r2 });
                }
                r2.sqrt()
            } else {
                (ljj * ljj + v[j] * v[j]).sqrt()
            };
            let c = r / ljj;
            let s = v[j] / ljj;
            l[(j, j)] = r;
            for i in (j + 1)..n {
                let lij = l[(i, j)];
                let lij_new = if down {
                    (lij - s * v[i]) / c
                } else {
                    (lij + s * v[i]) / c
                };
                v[i] = c * v[i] - s * lij_new;
                l[(i, j)] = lij_new;
            }
        }
        Ok(())
    }

    /// `A + v·vᵀ` (sign = ±1).
    fn rank1_perturbed(a: &Matrix, v: &[f64], sign: f64) -> Matrix {
        let n = a.rows();
        Matrix::from_fn(n, n, |i, j| a[(i, j)] + sign * v[i] * v[j])
    }

    #[test]
    fn update_rank1_matches_refactorization() {
        for &n in &[1usize, 2, 7, 23, 40] {
            let a = random_spd(n, 1e3, 100 + n as u64);
            let l0 = cholesky_blocked(&a).unwrap();
            let v: Vec<f64> = (0..n).map(|i| ((i + 1) as f64 * 0.37).sin()).collect();
            let mut l = l0.clone();
            let mut vv = v.clone();
            let mut trans = Matrix::zeros(0, 0);
            chol_update_rank1(&mut l, &mut vv, &mut trans);
            let exact = cholesky_blocked(&rank1_perturbed(&a, &v, 1.0)).unwrap();
            assert!(
                l.max_abs_diff(&exact) < 1e-9,
                "n={n}: {:.2e}",
                l.max_abs_diff(&exact)
            );
        }
    }

    #[test]
    fn downdate_rank1_matches_refactorization() {
        // A = XᵀX + I and v = a row of X: A − v·vᵀ ⪰ I is safely PD
        for &(n, d) in &[(8usize, 1usize), (30, 9), (80, 31)] {
            let x = random_matrix(n, d, 200 + d as u64);
            let mut a = syrk_lower(&x);
            a.add_diag_in_place(1.0);
            let l0 = cholesky_blocked(&a).unwrap();
            let v: Vec<f64> = x.row(n / 2).to_vec();
            let mut l = l0.clone();
            let mut vv = v.clone();
            let mut trans = Matrix::zeros(0, 0);
            chol_downdate_rank1(&mut l, &mut vv, &mut trans).unwrap();
            let exact = cholesky_blocked(&rank1_perturbed(&a, &v, -1.0)).unwrap();
            assert!(
                l.max_abs_diff(&exact) < 1e-9,
                "d={d}: {:.2e}",
                l.max_abs_diff(&exact)
            );
        }
    }

    #[test]
    fn rank_k_update_and_downdate_match_refactorization() {
        // k spans: below, at, and above the panel width (and k > d)
        for &(d, k) in &[(13usize, 3usize), (33, 5), (20, CHUD_BLOCK + 3), (4, 9)] {
            let x = random_matrix(3 * d + k, d, 300 + (d * k) as u64);
            let mut a = syrk_lower(&x);
            a.add_diag_in_place(1.0);
            let l0 = cholesky_blocked(&a).unwrap();
            let u = x.slice(0, k, 0, d).transpose(); // d×k, one vector per col
            let uut = Gemm::default().a_bt(&u, &u);

            // update: A + U·Uᵀ
            let mut l = l0.clone();
            let mut uu = u.clone();
            let mut trans = Matrix::zeros(0, 0);
            chol_update(&mut l, &mut uu, &mut trans);
            let plus = Matrix::from_fn(d, d, |i, j| a[(i, j)] + uut[(i, j)]);
            let exact = cholesky_blocked(&plus).unwrap();
            assert!(
                l.max_abs_diff(&exact) < 1e-8,
                "update d={d} k={k}: {:.2e}",
                l.max_abs_diff(&exact)
            );

            // downdate: A − U·Uᵀ (PD because A = XᵀX + I ⊇ U·Uᵀ + I)
            let mut l = l0.clone();
            let mut uu = u.clone();
            chol_downdate(&mut l, &mut uu, &mut trans).unwrap();
            let minus = Matrix::from_fn(d, d, |i, j| a[(i, j)] - uut[(i, j)]);
            let exact = cholesky_blocked(&minus).unwrap();
            assert!(
                l.max_abs_diff(&exact) < 1e-8,
                "downdate d={d} k={k}: {:.2e}",
                l.max_abs_diff(&exact)
            );
        }
    }

    /// The satellite round-trip: `downdate(update(L, v), v)` returns to `L`
    /// within refactorization tolerance — including d=1 and a vector that
    /// only touches the last column.
    #[test]
    fn update_then_downdate_round_trips() {
        for &n in &[1usize, 2, 13, 40] {
            let a = random_spd(n, 1e3, 400 + n as u64);
            let l0 = cholesky_blocked(&a).unwrap();
            let mut trans = Matrix::zeros(0, 0);
            let vecs: Vec<Vec<f64>> = vec![
                (0..n).map(|i| ((i + 2) as f64 * 0.61).cos()).collect(),
                // last-column edge case: only the final coordinate is hit,
                // so the whole perturbation lands on the last pivot
                (0..n)
                    .map(|i| if i + 1 == n { 0.75 } else { 0.0 })
                    .collect(),
            ];
            for v in vecs {
                let mut l = l0.clone();
                let mut vv = v.clone();
                chol_update_rank1(&mut l, &mut vv, &mut trans);
                let mut vv = v.clone();
                chol_downdate_rank1(&mut l, &mut vv, &mut trans).unwrap();
                assert!(
                    l.max_abs_diff(&l0) < 1e-9,
                    "n={n}: round-trip drift {:.2e}",
                    l.max_abs_diff(&l0)
                );
            }
        }
    }

    /// With `block ≥ n` the blocked core degenerates to the scalar
    /// recurrence — it must match an independently written unblocked
    /// reference bitwise; smaller blocks agree within rounding.
    #[test]
    fn blocked_core_matches_unblocked_reference() {
        let n = 37;
        let x = random_matrix(2 * n, n, 500);
        let mut a = syrk_lower(&x);
        a.add_diag_in_place(1.0);
        let l0 = cholesky_blocked(&a).unwrap();
        let v: Vec<f64> = x.row(3).to_vec();
        let mut trans = Matrix::zeros(0, 0);

        for down in [false, true] {
            let mut l_ref = l0.clone();
            let mut v_ref = v.clone();
            rank1_reference(&mut l_ref, &mut v_ref, down).unwrap();

            // block ≥ n: single panel, no trailing GEMM — bitwise equal
            let mut l_one = l0.clone();
            let mut v_one = v.clone();
            let dir = if down { Dir::Downdate } else { Dir::Update };
            let mut stats = RotationStats::new();
            chud_in_place(&mut l_one, &mut v_one, 1, n, dir, &mut trans, &mut stats).unwrap();
            assert_eq!(
                l_one.as_slice(),
                l_ref.as_slice(),
                "single-panel path must be bitwise the scalar recurrence (down={down})"
            );

            // smaller panels: same factor within rounding
            for block in [1usize, 5, CHUD_BLOCK] {
                let mut l_b = l0.clone();
                let mut v_b = v.clone();
                let mut stats = RotationStats::new();
                chud_in_place(&mut l_b, &mut v_b, 1, block, dir, &mut trans, &mut stats).unwrap();
                assert!(
                    l_b.max_abs_diff(&l_ref) < 1e-10,
                    "block={block} down={down}: {:.2e}",
                    l_b.max_abs_diff(&l_ref)
                );
            }
        }
    }

    #[test]
    fn downdate_breakdown_reports_failing_column() {
        // L = chol(I) = I; downdating by 2·e_j makes pivot j² − 4 < 0,
        // deterministically, at the first, a middle, and the LAST column
        let n = 9;
        for &col in &[0usize, 4, n - 1] {
            let mut l = Matrix::eye(n);
            let mut v = vec![0.0; n];
            v[col] = 2.0;
            let mut trans = Matrix::zeros(0, 0);
            let err = chol_downdate_rank1(&mut l, &mut v, &mut trans).unwrap_err();
            assert_eq!(err.pivot, col, "breakdown must report the failing column");
            assert!(err.value <= 0.0);
        }
        // d=1 breakdown
        let mut l = Matrix::eye(1);
        let mut v = vec![3.0];
        let mut trans = Matrix::zeros(0, 0);
        let err = chol_downdate_rank1(&mut l, &mut v, &mut trans).unwrap_err();
        assert_eq!(err.pivot, 0);
    }

    /// `downdate_rank_k` (the fold-level entry point) is bitwise the
    /// transpose-gather + [`chol_downdate`] composition, and matches a
    /// refactorization of the downdated matrix — including n_v spanning
    /// one chunk, the chunk boundary, and multiple chain links.
    #[test]
    fn downdate_rank_k_matches_chol_downdate_and_refactorization() {
        for &(d, nv) in &[
            (23usize, 1usize),
            (23, CHUD_RANK_CHUNK),
            (23, CHUD_RANK_CHUNK + 1),
            (33, 2 * CHUD_RANK_CHUNK + 5),
            (4, 9), // rank > dimension (the n_v > d fold shape)
        ] {
            let x = random_matrix(3 * d + nv, d, 700 + (d + nv) as u64);
            let mut a = syrk_lower(&x);
            a.add_diag_in_place(1.0);
            let anchor = cholesky_blocked(&a).unwrap();
            let xv = x.slice(0, nv, 0, d);

            let mut out = Matrix::zeros(0, 0);
            let mut ubuf = Matrix::zeros(0, 0);
            let mut trans = Matrix::zeros(0, 0);
            downdate_rank_k(&anchor, &xv, &mut out, &mut ubuf, &mut trans).unwrap();

            // bitwise the generic rank-k entry point on Xᵥᵀ
            let mut l = anchor.clone();
            let mut u = xv.transpose();
            chol_downdate(&mut l, &mut u, &mut trans).unwrap();
            assert_eq!(
                out.as_slice(),
                l.as_slice(),
                "d={d} nv={nv}: fold entry point must be bitwise chol_downdate"
            );

            // bitwise the split gather + pregathered replay (the warm-start
            // path): one gathered block, replayed through a fresh work buf
            let mut gbuf = Matrix::zeros(0, 0);
            gather_update_block(&xv, &mut gbuf);
            let mut out2 = Matrix::zeros(0, 0);
            let mut ubuf2 = Matrix::zeros(0, 0);
            downdate_rank_k_pregathered(&anchor, &gbuf, &mut out2, &mut ubuf2, &mut trans)
                .unwrap();
            assert_eq!(
                out.as_slice(),
                out2.as_slice(),
                "d={d} nv={nv}: pregathered replay must be bitwise downdate_rank_k"
            );

            // and within tolerance of refactorizing A − XᵥᵀXᵥ
            let uut = Gemm::default().a_bt(&xv.transpose(), &xv.transpose());
            let minus = Matrix::from_fn(d, d, |i, j| a[(i, j)] - uut[(i, j)]);
            let exact = cholesky_blocked(&minus).unwrap();
            assert!(
                out.max_abs_diff(&exact) < 1e-8,
                "d={d} nv={nv}: {:.2e}",
                out.max_abs_diff(&exact)
            );
        }
    }

    /// The chained core agrees with an *unchained* single-transform pass
    /// within rounding (they are algebraically the same downdate), so the
    /// `CHUD_RANK_CHUNK` chaining is a pure cost reshaping.
    #[test]
    fn chained_rank_k_matches_single_pass() {
        let d = 29;
        let nv = 2 * CHUD_RANK_CHUNK + 3;
        let x = random_matrix(3 * d + nv, d, 800);
        let mut a = syrk_lower(&x);
        a.add_diag_in_place(1.0);
        let l0 = cholesky_blocked(&a).unwrap();
        let u0 = x.slice(0, nv, 0, d).transpose();
        let mut trans = Matrix::zeros(0, 0);

        // chained (the production path)
        let mut l_chain = l0.clone();
        let mut u = u0.clone();
        chol_downdate(&mut l_chain, &mut u, &mut trans).unwrap();

        // unchained: one chud_chunk over the whole rank
        let mut l_one = l0.clone();
        let mut u = u0.clone();
        let mut stats = RotationStats::new();
        chud_chunk(
            &mut l_one,
            u.as_mut_slice(),
            nv,
            0,
            nv,
            CHUD_BLOCK,
            Dir::Downdate,
            &mut trans,
            &mut stats,
        )
        .unwrap();
        assert!(
            l_chain.max_abs_diff(&l_one) < 1e-10,
            "chained vs single-pass drift {:.2e}",
            l_chain.max_abs_diff(&l_one)
        );
    }

    /// Satellite property suite: randomized update-then-downdate round
    /// trips over dims {1, 3, CHUD_BLOCK, > CHUD_BLOCK} × ranks
    /// {1, 2, n_v}, at random conditioning, asserting agreement with
    /// refactorization within a condition-scaled tolerance.
    #[test]
    fn prop_update_downdate_round_trips_match_refactorization() {
        use crate::testutil::proptest_lite;
        let dims = [1usize, 3, CHUD_BLOCK, CHUD_BLOCK + 21];
        proptest_lite::check("chud round-trip × refactorization", 24, |case| {
            let d = dims[case.index % dims.len()];
            let ranks = [1usize, 2, (d / 2).max(3) + CHUD_RANK_CHUNK / 2];
            let nv = ranks[(case.index / dims.len()) % ranks.len()];
            let cond = 10f64.powf(case.float(1.0, 5.0));
            let seed = 0x5EED_C4D + case.index as u64;
            let a = random_spd(d, cond, seed);
            let l0 = cholesky_blocked(&a).unwrap();

            // U small enough that A − U·Uᵀ keeps the λ_min ≈ 1 margin:
            // each column scaled to ‖u‖ = 0.5/√n_v, so ‖U·Uᵀ‖ ≤ 0.25
            let mut u0 = random_matrix(d, nv, seed ^ 0xFACE);
            for q in 0..nv {
                let norm: f64 = (0..d).map(|i| u0[(i, q)] * u0[(i, q)]).sum::<f64>().sqrt();
                let scale = 0.5 / ((nv as f64).sqrt() * norm.max(1e-12));
                for i in 0..d {
                    u0[(i, q)] *= scale;
                }
            }
            let tol = 1e-12 * cond * (nv as f64 + 1.0).sqrt() + 1e-10;
            let mut trans = Matrix::zeros(0, 0);

            // update matches refactorization of A + U·Uᵀ …
            let uut = Gemm::default().a_bt(&u0, &u0);
            let mut l = l0.clone();
            let mut u = u0.clone();
            chol_update(&mut l, &mut u, &mut trans);
            let plus = Matrix::from_fn(d, d, |i, j| a[(i, j)] + uut[(i, j)]);
            let exact = cholesky_blocked(&plus).unwrap();
            assert!(
                l.max_abs_diff(&exact) < tol,
                "update d={d} nv={nv} cond={cond:.1e}: {:.2e} > {tol:.1e}",
                l.max_abs_diff(&exact)
            );

            // … the downdate returns to L₀ (round trip) …
            let mut u = u0.clone();
            chol_downdate(&mut l, &mut u, &mut trans).unwrap();
            assert!(
                l.max_abs_diff(&l0) < tol,
                "round trip d={d} nv={nv} cond={cond:.1e}: {:.2e} > {tol:.1e}",
                l.max_abs_diff(&l0)
            );

            // … and a straight downdate matches refactorizing A − U·Uᵀ
            let mut l = l0.clone();
            let mut u = u0.clone();
            chol_downdate(&mut l, &mut u, &mut trans).unwrap();
            let minus = Matrix::from_fn(d, d, |i, j| a[(i, j)] - uut[(i, j)]);
            let exact = cholesky_blocked(&minus).unwrap();
            assert!(
                l.max_abs_diff(&exact) < tol,
                "downdate d={d} nv={nv} cond={cond:.1e}: {:.2e} > {tol:.1e}",
                l.max_abs_diff(&exact)
            );
        });
    }

    /// Satellite property: rank-k round trips through the *fold* entry
    /// point, executed as pool tasks from worker scratch, are bitwise
    /// identical at workers {1, 2, 4} — the same invariance the rank-1 LOO
    /// path pins, at fold granularity.
    #[test]
    fn prop_rank_k_round_trip_bitwise_across_worker_counts() {
        use crate::coordinator::pool::WorkerPool;
        use crate::linalg::scratch::Scratch;
        let shapes: [(usize, usize); 6] =
            [(7, 1), (13, 2), (19, 5), (23, CHUD_RANK_CHUNK + 3), (5, 11), (31, 8)];
        let run = |workers: usize| -> Vec<Vec<f64>> {
            let pool = WorkerPool::new(workers);
            let jobs: Vec<Box<dyn FnOnce(&mut Scratch) -> Vec<f64> + Send>> = shapes
                .iter()
                .map(|&(d, nv)| {
                    let f: Box<dyn FnOnce(&mut Scratch) -> Vec<f64> + Send> =
                        Box::new(move |scratch| {
                            let x = random_matrix(2 * d + nv, d, 90 + (d * nv) as u64);
                            let mut a = syrk_lower(&x);
                            a.add_diag_in_place(1.0);
                            let anchor = cholesky_blocked(&a).unwrap();
                            let xv = x.slice(0, nv, 0, d);
                            // downdate through the fold entry point …
                            downdate_rank_k(
                                &anchor,
                                &xv,
                                &mut scratch.factor,
                                &mut scratch.update,
                                &mut scratch.trans,
                            )
                            .unwrap();
                            // … then update back up from the downdated factor
                            let mut u = xv.transpose();
                            chol_update(&mut scratch.factor, &mut u, &mut scratch.trans);
                            scratch.factor.as_slice().to_vec()
                        });
                    f
                })
                .collect();
            pool.map_scratch(jobs)
        };
        let serial = run(1);
        for workers in [2usize, 4] {
            assert_eq!(run(workers), serial, "bits drifted at workers={workers}");
        }
    }

    /// The tracked variants produce bitwise the same factor as the untracked
    /// ones (observation never perturbs arithmetic), charge exactly one hop,
    /// and hyperbolic passes report amplification ≥ 1.
    #[test]
    fn tracked_variants_are_bitwise_untracked_and_charge_trust() {
        use crate::linalg::trust::FactorTrust;
        let (d, nv) = (23usize, CHUD_RANK_CHUNK + 1);
        let x = random_matrix(3 * d + nv, d, 900);
        let mut a = syrk_lower(&x);
        a.add_diag_in_place(1.0);
        let anchor = cholesky_blocked(&a).unwrap();
        let xv = x.slice(0, nv, 0, d);
        let mut trans = Matrix::zeros(0, 0);

        // rank-k fold downdate
        let mut out_plain = Matrix::zeros(0, 0);
        let mut ubuf = Matrix::zeros(0, 0);
        downdate_rank_k(&anchor, &xv, &mut out_plain, &mut ubuf, &mut trans).unwrap();
        let mut out_tracked = Matrix::zeros(0, 0);
        let mut trust = FactorTrust::fresh(&anchor);
        downdate_rank_k_tracked(
            &anchor,
            &xv,
            &mut out_tracked,
            &mut ubuf,
            &mut trans,
            &mut trust,
        )
        .unwrap();
        assert_eq!(out_plain.as_slice(), out_tracked.as_slice());
        assert_eq!(trust.hops(), 1);
        assert!(trust.drift() > 0.0);

        // pregathered replay charges the same way
        let mut gbuf = Matrix::zeros(0, 0);
        gather_update_block(&xv, &mut gbuf);
        let mut out2 = Matrix::zeros(0, 0);
        let mut trust2 = FactorTrust::fresh(&anchor);
        downdate_rank_k_pregathered_tracked(
            &anchor,
            &gbuf,
            &mut out2,
            &mut ubuf,
            &mut trans,
            &mut trust2,
        )
        .unwrap();
        assert_eq!(out_plain.as_slice(), out2.as_slice());
        assert_eq!(trust2.drift(), trust.drift(), "same pass, same charge");

        // rank-1 pair
        let v: Vec<f64> = x.row(1).to_vec();
        let mut l_plain = anchor.clone();
        let mut vv = v.clone();
        chol_downdate_rank1(&mut l_plain, &mut vv, &mut trans).unwrap();
        let mut l_tracked = anchor.clone();
        let mut vv = v.clone();
        let mut trust1 = FactorTrust::fresh(&anchor);
        chol_downdate_rank1_tracked(&mut l_tracked, &mut vv, &mut trans, &mut trust1).unwrap();
        assert_eq!(l_plain.as_slice(), l_tracked.as_slice());
        assert_eq!(trust1.hops(), 1);

        // updates charge too, and an update-then-downdate chain is 2 hops
        let mut l = anchor.clone();
        let mut u = xv.transpose();
        let mut trust3 = FactorTrust::fresh(&anchor);
        chol_update_tracked(&mut l, &mut u, &mut trans, &mut trust3);
        let mut u = xv.transpose();
        chol_downdate_tracked(&mut l, &mut u, &mut trans, &mut trust3).unwrap();
        assert_eq!(trust3.hops(), 2);
        assert!(trust3.drift() > trust.drift(), "two passes charge more than one");
        let mut vv = v.clone();
        let mut trust4 = FactorTrust::fresh(&anchor);
        let mut l4 = anchor.clone();
        chol_update_rank1_tracked(&mut l4, &mut vv, &mut trans, &mut trust4);
        assert_eq!(trust4.hops(), 1);
    }

    /// A breakdown still charges the trust tag (the factor is poisoned
    /// either way, and the ladder reads the tag at failure).
    #[test]
    fn tracked_breakdown_still_charges() {
        use crate::linalg::trust::FactorTrust;
        let n = 9;
        let mut l = Matrix::eye(n);
        let mut v = vec![0.0; n];
        v[4] = 2.0;
        let mut trans = Matrix::zeros(0, 0);
        let mut trust = FactorTrust::fresh(&l);
        let err = chol_downdate_rank1_tracked(&mut l, &mut v, &mut trans, &mut trust).unwrap_err();
        assert_eq!(err.pivot, 4);
        assert_eq!(trust.hops(), 1);
    }

    /// Round-trips executed as pool tasks are bitwise identical at workers
    /// 1/2/4: the kernels are pure serial functions of their inputs, and
    /// worker scratch reuse never leaks a bit.
    #[test]
    fn round_trip_bitwise_across_worker_counts() {
        use crate::coordinator::pool::WorkerPool;
        use crate::linalg::scratch::Scratch;
        let n = 31;
        let a = random_spd(n, 1e3, 77);
        let l0 = std::sync::Arc::new(cholesky_blocked(&a).unwrap());
        let run = |workers: usize| -> Vec<Vec<f64>> {
            let pool = WorkerPool::new(workers);
            let jobs: Vec<Box<dyn FnOnce(&mut Scratch) -> Vec<f64> + Send>> = (0..8)
                .map(|t| {
                    let l0 = std::sync::Arc::clone(&l0);
                    let f: Box<dyn FnOnce(&mut Scratch) -> Vec<f64> + Send> =
                        Box::new(move |scratch| {
                            let mut l = (*l0).clone();
                            let v: Vec<f64> =
                                (0..n).map(|i| ((i + t) as f64 * 0.29).sin()).collect();
                            let mut vv = v.clone();
                            chol_update_rank1(&mut l, &mut vv, &mut scratch.trans);
                            let mut vv = v;
                            chol_downdate_rank1(&mut l, &mut vv, &mut scratch.trans).unwrap();
                            l.into_vec()
                        });
                    f
                })
                .collect();
            pool.map_scratch(jobs)
        };
        let serial = run(1);
        for workers in [2usize, 4] {
            assert_eq!(run(workers), serial, "bits drifted at workers={workers}");
        }
    }
}
