//! Norms and conditioning estimates used by the error-bound machinery (§4).

use super::gemm::gemv;
use super::matrix::Matrix;
use crate::prng::Xoshiro256;

/// Frobenius norm.
pub fn fro_norm(a: &Matrix) -> f64 {
    a.as_slice().iter().map(|x| x * x).sum::<f64>().sqrt()
}

/// Vector 2-norm.
pub fn vec_norm(v: &[f64]) -> f64 {
    v.iter().map(|x| x * x).sum::<f64>().sqrt()
}

/// RMS over all entries (the paper's `1/√D ‖·‖_F` normalization).
pub fn rms(a: &Matrix) -> f64 {
    fro_norm(a) / (a.as_slice().len() as f64).sqrt()
}

/// Spectral norm estimate via power iteration on `AᵀA`.
pub fn spectral_norm_est(a: &Matrix, iters: usize, seed: u64) -> f64 {
    let n = a.cols();
    let mut rng = Xoshiro256::seed_from(seed);
    let mut v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let mut sigma = 0.0;
    for _ in 0..iters {
        let av = gemv(a, &v);
        let atav = super::gemm::gemv_t(a, &av);
        let nrm = vec_norm(&atav);
        if nrm == 0.0 {
            return 0.0;
        }
        sigma = nrm.sqrt();
        for (vi, &x) in v.iter_mut().zip(&atav) {
            *vi = x / nrm;
        }
    }
    sigma
}

/// NRMSE between a prediction matrix and a target matrix, normalized by the
/// target's standard deviation — the paper's Figure 11 metric ("naively using
/// the mean of the target variable implies NRMSE of 1").
pub fn nrmse(pred: &Matrix, target: &Matrix) -> f64 {
    assert_eq!(
        (pred.rows(), pred.cols()),
        (target.rows(), target.cols()),
        "nrmse shape mismatch"
    );
    let n = target.as_slice().len() as f64;
    let mean = target.as_slice().iter().sum::<f64>() / n;
    let var = target.as_slice().iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n;
    let mse = pred
        .as_slice()
        .iter()
        .zip(target.as_slice())
        .map(|(p, t)| (p - t).powi(2))
        .sum::<f64>()
        / n;
    (mse / var.max(1e-300)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::random_spd;

    #[test]
    fn fro_of_identity() {
        assert!((fro_norm(&Matrix::eye(9)) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn spectral_matches_largest_eigenvalue_of_spd() {
        let a = random_spd(20, 100.0, 1);
        // largest eigenvalue via dense Jacobi SVD (SPD ⇒ σ₁ = λ₁)
        let svd = crate::linalg::svd::jacobi_svd(&a);
        let est = spectral_norm_est(&a, 200, 2);
        assert!((est - svd.s[0]).abs() / svd.s[0] < 1e-6);
    }

    #[test]
    fn nrmse_zero_when_equal_one_when_mean() {
        let t = Matrix::from_fn(5, 5, |i, j| (i * 5 + j) as f64);
        assert!(nrmse(&t, &t) < 1e-12);
        let mean = t.as_slice().iter().sum::<f64>() / 25.0;
        let m = Matrix::from_fn(5, 5, |_, _| mean);
        assert!((nrmse(&m, &t) - 1.0).abs() < 1e-12);
    }
}
