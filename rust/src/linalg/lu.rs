//! LU decomposition with partial pivoting.
//!
//! Needed by the §4 error-bound machinery: the Fréchet-derivative operator
//! `M = L⊗I + I⊗L` is *not* symmetric, so its inverse (Theorem 4.3/4.4)
//! requires a general solver. Standard `getrf`/`getrs` shape.

use super::matrix::Matrix;

/// Compact LU factors: `P·A = L·U` with unit-diagonal L stored below the
/// diagonal of `lu` and U on/above it.
pub struct LuFactors {
    lu: Matrix,
    /// Row permutation: row i of PA is row `perm[i]` of A.
    perm: Vec<usize>,
    /// Sign of the permutation (determinant bookkeeping).
    pub sign: f64,
}

/// Factor a square matrix; returns `None` if (numerically) singular.
pub fn lu_decompose(a: &Matrix) -> Option<LuFactors> {
    assert!(a.is_square());
    let n = a.rows();
    let mut lu = a.clone();
    let mut perm: Vec<usize> = (0..n).collect();
    let mut sign = 1.0;

    for k in 0..n {
        // pivot search in column k
        let mut p = k;
        let mut pmax = lu[(k, k)].abs();
        for i in (k + 1)..n {
            let v = lu[(i, k)].abs();
            if v > pmax {
                pmax = v;
                p = i;
            }
        }
        if pmax < 1e-300 {
            return None;
        }
        if p != k {
            perm.swap(p, k);
            sign = -sign;
            let (rk, rp) = lu.two_rows_mut(k, p);
            rk.swap_with_slice(rp);
        }
        let pivot = lu[(k, k)];
        for i in (k + 1)..n {
            let m = lu[(i, k)] / pivot;
            lu[(i, k)] = m;
            if m == 0.0 {
                continue;
            }
            // row update: contiguous tail axpy
            let (rk, ri) = lu.two_rows_mut(k, i);
            for j in (k + 1)..n {
                ri[j] -= m * rk[j];
            }
        }
    }
    Some(LuFactors { lu, perm, sign })
}

impl LuFactors {
    /// Solve `A x = b`.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.lu.rows();
        assert_eq!(b.len(), n);
        // apply permutation, then forward/back substitution
        let mut x: Vec<f64> = self.perm.iter().map(|&p| b[p]).collect();
        for i in 1..n {
            let row = self.lu.row(i);
            let mut s = x[i];
            for k in 0..i {
                s -= row[k] * x[k];
            }
            x[i] = s;
        }
        for i in (0..n).rev() {
            let row = self.lu.row(i);
            let mut s = x[i];
            for k in (i + 1)..n {
                s -= row[k] * x[k];
            }
            x[i] = s / row[i];
        }
        x
    }

    /// Solve for a multi-column RHS.
    pub fn solve_matrix(&self, b: &Matrix) -> Matrix {
        let n = self.lu.rows();
        assert_eq!(b.rows(), n);
        let mut out = Matrix::zeros(n, b.cols());
        for j in 0..b.cols() {
            let col = self.solve(&b.col(j));
            for i in 0..n {
                out[(i, j)] = col[i];
            }
        }
        out
    }

    /// Explicit inverse (used by the bound calculator at small h²).
    pub fn inverse(&self) -> Matrix {
        self.solve_matrix(&Matrix::eye(self.lu.rows()))
    }

    /// Determinant.
    pub fn det(&self) -> f64 {
        let mut d = self.sign;
        for i in 0..self.lu.rows() {
            d *= self.lu[(i, i)];
        }
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{gemm, gemv};
    use crate::testutil::{assert_matrix_close, assert_vec_close, random_matrix};

    #[test]
    fn solve_random_system() {
        let a = random_matrix(20, 20, 1);
        let x_true: Vec<f64> = (0..20).map(|i| (i as f64 * 0.3).cos()).collect();
        let b = gemv(&a, &x_true);
        let f = lu_decompose(&a).unwrap();
        assert_vec_close(&f.solve(&b), &x_true, 1e-9);
    }

    #[test]
    fn inverse_reconstructs_identity() {
        let a = random_matrix(15, 15, 2);
        let f = lu_decompose(&a).unwrap();
        let ainv = f.inverse();
        assert_matrix_close(&gemm(&a, &ainv), &Matrix::eye(15), 1e-9);
    }

    #[test]
    fn singular_detected() {
        let mut a = random_matrix(8, 8, 3);
        let dup = a.row(0).to_vec();
        a.row_mut(5).copy_from_slice(&dup);
        assert!(lu_decompose(&a).is_none());
    }

    #[test]
    fn det_of_known() {
        let a = Matrix::from_vec(2, 2, vec![3.0, 1.0, 4.0, 2.0]);
        let f = lu_decompose(&a).unwrap();
        assert!((f.det() - 2.0).abs() < 1e-12);
    }
}
