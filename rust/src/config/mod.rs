//! Experiment configuration: a TOML-subset parser plus the typed
//! [`ExperimentConfig`] the CLI and benches consume.
//!
//! The offline crate set has no `toml`/`serde`, so [`parse_toml`] supports
//! the slice actually used by experiment files: `[section]` headers,
//! `key = value` with string/int/float/bool/array values, `#` comments.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;

use crate::cv::window::ServiceConfig;
use crate::cv::{CvConfig, CvMode, FoldStrategy, Metric};
use crate::data::synthetic::DatasetKind;

/// A parsed scalar-or-array TOML value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Some(*i as usize),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// `section.key → value` map.
pub type TomlDoc = BTreeMap<String, TomlValue>;

fn parse_value(raw: &str) -> Result<TomlValue> {
    let raw = raw.trim();
    if raw.starts_with('"') {
        return parse_string(raw);
    }
    if raw == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if raw == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if raw.starts_with('[') && raw.ends_with(']') {
        let inner = &raw[1..raw.len() - 1];
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in split_top_level(inner)? {
                items.push(parse_value(part)?);
            }
        }
        return Ok(TomlValue::Array(items));
    }
    if let Ok(i) = raw.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = raw.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("cannot parse value '{raw}'")
}

/// Parse a `"…"` string value: `\"` and `\\` unescape, the closing quote
/// must exist and must end the value. Unterminated strings and trailing
/// junk are errors — silently keeping the outer quotes (or eating a
/// dangling fragment) would corrupt the config it came from.
fn parse_string(raw: &str) -> Result<TomlValue> {
    debug_assert!(raw.starts_with('"'));
    let mut out = String::with_capacity(raw.len());
    let mut escaped = false;
    let mut closed = false;
    for c in raw.chars().skip(1) {
        if closed {
            bail!("trailing characters after closing quote in {raw}");
        }
        if escaped {
            match c {
                '"' => out.push('"'),
                '\\' => out.push('\\'),
                other => bail!("unsupported escape '\\{other}' in {raw}"),
            }
            escaped = false;
        } else {
            match c {
                '\\' => escaped = true,
                '"' => closed = true,
                c => out.push(c),
            }
        }
    }
    if !closed {
        bail!("unterminated string {raw}");
    }
    Ok(TomlValue::Str(out))
}

/// Split an array body on **top-level** commas only: commas inside string
/// elements or nested arrays are element content, not separators. Tracks
/// quote state (with `\"`/`\\` escapes) and bracket depth; unterminated
/// strings and unbalanced brackets are errors.
fn split_top_level(inner: &str) -> Result<Vec<&str>> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut escaped = false;
    let mut start = 0usize;
    for (i, c) in inner.char_indices() {
        if in_str {
            if escaped {
                escaped = false;
            } else if c == '\\' {
                escaped = true;
            } else if c == '"' {
                in_str = false;
            }
            continue;
        }
        match c {
            '"' => in_str = true,
            '[' => depth += 1,
            ']' => {
                depth = depth
                    .checked_sub(1)
                    .ok_or_else(|| anyhow!("unbalanced ']' in array body '{inner}'"))?;
            }
            ',' if depth == 0 => {
                parts.push(&inner[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    if in_str {
        bail!("unterminated string in array body '{inner}'");
    }
    if depth != 0 {
        bail!("unbalanced '[' in array body '{inner}'");
    }
    parts.push(&inner[start..]);
    Ok(parts)
}

/// Parse a TOML-subset document into a flat `section.key` map.
pub fn parse_toml(text: &str) -> Result<TomlDoc> {
    let mut doc = TomlDoc::new();
    let mut section = String::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = match line.find('#') {
            // only strip comments outside strings (good enough for configs)
            Some(i) if !line[..i].contains('"') || line[..i].matches('"').count() % 2 == 0 => {
                &line[..i]
            }
            _ => line,
        };
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') && line.ends_with(']') {
            section = line[1..line.len() - 1].trim().to_string();
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| anyhow!("line {}: expected 'key = value'", lineno + 1))?;
        let key = line[..eq].trim();
        let value = parse_value(&line[eq + 1..])
            .with_context(|| format!("line {}", lineno + 1))?;
        let full_key = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        doc.insert(full_key, value);
    }
    Ok(doc)
}

/// Typed experiment configuration (CLI + config-file driven).
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Dataset to imitate.
    pub dataset: DatasetKind,
    /// Number of samples n.
    pub n: usize,
    /// Working dimension h = d+1.
    pub h: usize,
    /// Master seed.
    pub seed: u64,
    /// Cross-validation settings.
    pub cv: CvConfig,
    /// Worker threads (0 = auto).
    pub workers: usize,
    /// Artifacts directory for the HLO path.
    pub artifacts_dir: String,
    /// Chrome trace-event output path (`--trace-out` / `obs.trace_out`);
    /// setting it implies `cv.obs`.
    pub trace_out: Option<String>,
    /// Run-ledger JSONL output path (`--ledger-out` / `obs.ledger_out`);
    /// setting it implies `cv.obs`.
    pub ledger_out: Option<String>,
    /// Streaming-service shape (`[service]` section; see
    /// [`crate::coordinator::service`]). Only the `serve` subcommand reads it.
    pub service: ServiceConfig,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            dataset: DatasetKind::MnistLike,
            n: 1024,
            h: 128,
            seed: 42,
            cv: CvConfig::default(),
            workers: 0,
            artifacts_dir: "artifacts".to_string(),
            trace_out: None,
            ledger_out: None,
            service: ServiceConfig::default(),
        }
    }
}

impl ExperimentConfig {
    /// Load from a TOML-subset file; missing keys keep defaults.
    pub fn from_file(path: &str) -> Result<Self> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading config {path}"))?;
        let doc = parse_toml(&text)?;
        Self::from_doc(&doc)
    }

    /// Build from a parsed document.
    pub fn from_doc(doc: &TomlDoc) -> Result<Self> {
        let mut cfg = Self::default();
        if let Some(v) = doc.get("dataset").and_then(TomlValue::as_str) {
            cfg.dataset = parse_dataset(v)?;
        }
        if let Some(v) = doc.get("n").and_then(TomlValue::as_usize) {
            cfg.n = v;
        }
        if let Some(v) = doc.get("h").and_then(TomlValue::as_usize) {
            cfg.h = v;
        }
        if let Some(v) = doc.get("seed").and_then(TomlValue::as_usize) {
            cfg.seed = v as u64;
        }
        if let Some(v) = doc.get("workers").and_then(TomlValue::as_usize) {
            cfg.workers = v;
        }
        if let Some(v) = doc.get("artifacts_dir").and_then(TomlValue::as_str) {
            cfg.artifacts_dir = v.to_string();
        }
        if let Some(v) = doc.get("cv.k_folds").and_then(TomlValue::as_usize) {
            cfg.cv.k_folds = v;
        }
        if let Some(v) = doc.get("cv.q_grid").and_then(TomlValue::as_usize) {
            cfg.cv.q_grid = v;
        }
        if let Some(v) = doc.get("cv.g_samples").and_then(TomlValue::as_usize) {
            cfg.cv.g_samples = v;
        }
        if let Some(v) = doc.get("cv.degree").and_then(TomlValue::as_usize) {
            cfg.cv.degree = v;
        }
        if let Some(v) = doc.get("cv.mode").and_then(TomlValue::as_str) {
            cfg.cv.mode = CvMode::parse(v)
                .ok_or_else(|| anyhow!("unknown cv mode '{v}' (kfold | loo | aloocv)"))?;
        }
        if let Some(v) = doc.get("cv.fold_strategy").and_then(TomlValue::as_str) {
            cfg.cv.fold_strategy = FoldStrategy::parse(v).ok_or_else(|| {
                anyhow!("unknown fold strategy '{v}' (refactor | downdate | auto)")
            })?;
        }
        if let Some(v) = doc.get("cv.metric").and_then(TomlValue::as_str) {
            cfg.cv.metric = match v {
                "rmse" => Metric::Rmse,
                "misclass" => Metric::Misclass,
                other => bail!("unknown metric '{other}'"),
            };
        }
        let lo = doc.get("cv.lambda_min").and_then(TomlValue::as_f64);
        let hi = doc.get("cv.lambda_max").and_then(TomlValue::as_f64);
        if let (Some(lo), Some(hi)) = (lo, hi) {
            cfg.cv.lambda_range = Some((lo, hi));
        }
        // sweep-engine execution shape ([sweep] section; 0 = auto)
        if let Some(v) = doc.get("sweep.threads").and_then(TomlValue::as_usize) {
            cfg.cv.sweep_threads = v;
        }
        if let Some(v) = doc.get("sweep.batch").and_then(TomlValue::as_usize) {
            cfg.cv.sweep_batch = v;
        }
        // data-pipeline shape ([data] section; 0 = auto). The knob is
        // bit-neutral by construction (see `data::gram`), so it needs no
        // cross-validation against other settings.
        if let Some(v) = doc.get("data.chunk_rows").and_then(TomlValue::as_usize) {
            cfg.cv.chunk_rows = v;
        }
        // numerical-trust subsystem ([trust] section) — drift budget and
        // breakdown-escalation ladder knobs (see `cv::recovery`)
        if let Some(v) = doc.get("trust.budget").and_then(TomlValue::as_f64) {
            cfg.cv.recovery.budget.max_relative_drift = v;
        }
        if let Some(v) = doc.get("trust.max_hops").and_then(TomlValue::as_usize) {
            cfg.cv.recovery.budget.max_hops = v as u64;
        }
        if let Some(v) = doc.get("trust.shift_retries").and_then(TomlValue::as_usize) {
            cfg.cv.recovery.max_shift_retries = v as u32;
        }
        if let Some(v) = doc.get("trust.shift_growth").and_then(TomlValue::as_f64) {
            cfg.cv.recovery.shift_growth = v;
        }
        if let Some(v) = doc.get("trust.task_retries").and_then(TomlValue::as_usize) {
            cfg.cv.recovery.task_retries = v as u32;
        }
        // observability ([obs] section) — off by default; either output
        // path implies the event/histogram layer is armed
        if let Some(v) = doc.get("obs.enabled").and_then(TomlValue::as_bool) {
            cfg.cv.obs = v;
        }
        if let Some(v) = doc.get("obs.trace_out").and_then(TomlValue::as_str) {
            cfg.trace_out = Some(v.to_string());
        }
        if let Some(v) = doc.get("obs.ledger_out").and_then(TomlValue::as_str) {
            cfg.ledger_out = Some(v.to_string());
        }
        if cfg.trace_out.is_some() || cfg.ledger_out.is_some() {
            cfg.cv.obs = true;
        }
        // streaming-service shape ([service] section; 0 = auto where noted).
        // `tier` is intentionally separate from `cv.mode`: a batch experiment
        // and the service it feeds routinely want different accuracy tiers.
        if let Some(v) = doc.get("service.window").and_then(TomlValue::as_usize) {
            cfg.service.window = v;
        }
        if let Some(v) = doc.get("service.refresh_every").and_then(TomlValue::as_usize) {
            cfg.service.refresh_every = v;
        }
        if let Some(v) = doc.get("service.queue_depth").and_then(TomlValue::as_usize) {
            cfg.service.queue_depth = v;
        }
        if let Some(v) = doc.get("service.workers").and_then(TomlValue::as_usize) {
            cfg.service.workers = v;
        }
        if let Some(v) = doc.get("service.eval_batch").and_then(TomlValue::as_usize) {
            cfg.service.eval_batch = v;
        }
        if let Some(v) = doc.get("service.tier").and_then(TomlValue::as_str) {
            cfg.service.tier = CvMode::parse(v)
                .ok_or_else(|| anyhow!("unknown service tier '{v}' (loo | aloocv)"))?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Sanity-check invariants (Algorithm 1 preconditions etc.).
    pub fn validate(&self) -> Result<()> {
        if self.cv.g_samples <= self.cv.degree {
            bail!(
                "cv.g_samples ({}) must exceed cv.degree ({}) — Algorithm 1 needs g > r",
                self.cv.g_samples,
                self.cv.degree
            );
        }
        if self.cv.k_folds < 2 {
            bail!("cv.k_folds must be ≥ 2");
        }
        if self.h < 2 || self.n < self.cv.k_folds {
            bail!("need h ≥ 2 and n ≥ k_folds");
        }
        if let Some((lo, hi)) = self.cv.lambda_range {
            // explicit non-finite rejection: NaN fails `lo > 0.0` silently,
            // but the error should say *why* the range is bad
            if !(lo.is_finite() && hi.is_finite()) {
                bail!("lambda range must be finite, got [{lo}, {hi}]");
            }
            if !(lo > 0.0 && hi > lo) {
                bail!("lambda range must satisfy 0 < lo < hi");
            }
        }
        let b = &self.cv.recovery.budget;
        if b.max_relative_drift.is_nan() || b.max_relative_drift < 0.0 {
            bail!(
                "trust.budget must be a non-negative relative drift (inf = never refactor), got {}",
                b.max_relative_drift
            );
        }
        let r = &self.cv.recovery;
        if !r.shift_growth.is_finite() || r.shift_growth <= 1.0 {
            bail!(
                "trust.shift_growth must be a finite factor > 1, got {}",
                r.shift_growth
            );
        }
        let s = &self.service;
        if s.window == 0 {
            bail!("service.window must be ≥ 1 (rows retained in the sliding window)");
        }
        if s.refresh_every == 0 {
            bail!("service.refresh_every must be ≥ 1 (rows admitted between refreshes)");
        }
        if s.queue_depth == 0 {
            bail!("service.queue_depth must be ≥ 1 (bounded admission queue)");
        }
        if s.tier == CvMode::KFold {
            bail!("service.tier must be a streaming tier (loo | aloocv), not kfold");
        }
        Ok(())
    }
}

/// Parse a dataset name (paper names and shorthands).
pub fn parse_dataset(s: &str) -> Result<DatasetKind> {
    match s.to_ascii_lowercase().as_str() {
        "mnist" | "mnist-like" => Ok(DatasetKind::MnistLike),
        "coil" | "coil100" | "coil100-like" => Ok(DatasetKind::CoilLike),
        "caltech101" | "caltech101-like" => Ok(DatasetKind::Caltech101Like),
        "caltech256" | "caltech256-like" => Ok(DatasetKind::Caltech256Like),
        other => bail!("unknown dataset '{other}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = parse_toml(
            r#"
            # experiment
            dataset = "coil"
            n = 512
            [cv]
            k_folds = 3
            lambda_min = 0.001
            lambda_max = 1.0
            metric = "rmse"
            grid = [1, 2, 3]
            "#,
        )
        .unwrap();
        assert_eq!(doc.get("dataset").unwrap().as_str(), Some("coil"));
        assert_eq!(doc.get("n").unwrap().as_usize(), Some(512));
        assert_eq!(doc.get("cv.k_folds").unwrap().as_usize(), Some(3));
        assert_eq!(doc.get("cv.lambda_min").unwrap().as_f64(), Some(0.001));
        match doc.get("cv.grid").unwrap() {
            TomlValue::Array(a) => assert_eq!(a.len(), 3),
            _ => panic!(),
        }
    }

    /// The array-splitting bug: commas inside string elements or nested
    /// arrays are element content, not separators.
    #[test]
    fn array_split_respects_strings_and_nesting() {
        let doc =
            parse_toml("tags = [\"a,b\", \"c\"]\nnest = [[1, 2], [3]]\nempty = []\n").unwrap();
        match doc.get("tags").unwrap() {
            TomlValue::Array(a) => {
                assert_eq!(a.len(), 2, "comma inside the string must not split");
                assert_eq!(a[0].as_str(), Some("a,b"));
                assert_eq!(a[1].as_str(), Some("c"));
            }
            other => panic!("expected array, got {other:?}"),
        }
        match doc.get("nest").unwrap() {
            TomlValue::Array(a) => {
                assert_eq!(a.len(), 2);
                assert_eq!(a[0], TomlValue::Array(vec![TomlValue::Int(1), TomlValue::Int(2)]));
                assert_eq!(a[1], TomlValue::Array(vec![TomlValue::Int(3)]));
            }
            other => panic!("expected nested array, got {other:?}"),
        }
        assert_eq!(doc.get("empty").unwrap(), &TomlValue::Array(vec![]));
        // a string element containing a bracket must not confuse the depth
        let doc = parse_toml("v = [\"a]b\", 2]\n").unwrap();
        match doc.get("v").unwrap() {
            TomlValue::Array(a) => {
                assert_eq!(a[0].as_str(), Some("a]b"));
                assert_eq!(a[1], TomlValue::Int(2));
            }
            other => panic!("expected array, got {other:?}"),
        }
    }

    /// String values unescape `\"` and `\\`; malformed strings are loud
    /// errors instead of silently keeping the outer quotes.
    #[test]
    fn string_escapes_unescape_and_bad_strings_are_rejected() {
        let doc = parse_toml("v = \"say \\\"hi,there\\\" and \\\\slash\"\n").unwrap();
        assert_eq!(doc.get("v").unwrap().as_str(), Some("say \"hi,there\" and \\slash"));
        assert!(parse_toml("v = \"unterminated\n").is_err(), "unterminated string");
        assert!(parse_toml("v = \"closed\" junk\n").is_err(), "trailing junk");
        assert!(parse_toml("v = \"bad \\q escape\"\n").is_err(), "unknown escape");
        assert!(parse_toml("v = [\"open, 1]\n").is_err(), "unterminated in array");
        assert!(parse_toml("v = [[1, 2]\n").is_err(), "unbalanced brackets");
    }

    #[test]
    fn experiment_config_from_doc() {
        let doc = parse_toml(
            r#"
            dataset = "caltech101"
            h = 64
            [cv]
            g_samples = 5
            degree = 3
            "#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.dataset, DatasetKind::Caltech101Like);
        assert_eq!(cfg.h, 64);
        assert_eq!(cfg.cv.g_samples, 5);
        assert_eq!(cfg.cv.degree, 3);
    }

    #[test]
    fn sweep_knobs_parse() {
        let doc = parse_toml("[sweep]\nthreads = 4\nbatch = 8\n").unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.cv.sweep_threads, 4);
        assert_eq!(cfg.cv.sweep_batch, 8);
        // defaults stay auto
        let cfg = ExperimentConfig::from_doc(&parse_toml("n = 64\n").unwrap()).unwrap();
        assert_eq!(cfg.cv.sweep_threads, 0);
        assert_eq!(cfg.cv.sweep_batch, 0);
        assert_eq!(cfg.cv.chunk_rows, 0);
    }

    #[test]
    fn data_chunk_rows_parses() {
        let doc = parse_toml("[data]\nchunk_rows = 512\n").unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.cv.chunk_rows, 512);
    }

    #[test]
    fn fold_strategy_parses() {
        let doc = parse_toml("[cv]\nfold_strategy = \"refactor\"\n").unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.cv.fold_strategy, FoldStrategy::Refactor);
        // factor-level downdate chains are the default; junk rejected
        let cfg = ExperimentConfig::from_doc(&parse_toml("n = 64\n").unwrap()).unwrap();
        assert_eq!(cfg.cv.fold_strategy, FoldStrategy::Downdate);
        // the measured-crossover auto mode is a first-class config value
        let cfg = ExperimentConfig::from_doc(
            &parse_toml("[cv]\nfold_strategy = \"auto\"\n").unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.cv.fold_strategy, FoldStrategy::Auto);
        assert!(ExperimentConfig::from_doc(
            &parse_toml("[cv]\nfold_strategy = \"resolve\"\n").unwrap()
        )
        .is_err());
    }

    #[test]
    fn cv_mode_parses() {
        let doc = parse_toml("[cv]\nmode = \"loo\"\n").unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.cv.mode, CvMode::Loo);
        // the cheap hat-diagonal tier is a first-class config value
        let doc = parse_toml("[cv]\nmode = \"aloocv\"\n").unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.cv.mode, CvMode::Aloocv);
        // default stays k-fold; junk rejected
        let cfg = ExperimentConfig::from_doc(&parse_toml("n = 64\n").unwrap()).unwrap();
        assert_eq!(cfg.cv.mode, CvMode::KFold);
        assert!(ExperimentConfig::from_doc(&parse_toml("[cv]\nmode = \"hmm\"\n").unwrap())
            .is_err());
    }

    #[test]
    fn validation_rejects_g_le_r() {
        let doc = parse_toml("[cv]\ng_samples = 2\ndegree = 2\n").unwrap();
        assert!(ExperimentConfig::from_doc(&doc).is_err());
    }

    #[test]
    fn validation_rejects_bad_lambda_range() {
        let doc = parse_toml("[cv]\nlambda_min = 1.0\nlambda_max = 0.5\n").unwrap();
        assert!(ExperimentConfig::from_doc(&doc).is_err());
    }

    #[test]
    fn trust_knobs_parse_and_default() {
        let doc = parse_toml(
            "[trust]\nbudget = 1e-6\nmax_hops = 32\nshift_retries = 2\nshift_growth = 100.0\ntask_retries = 3\n",
        )
        .unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.cv.recovery.budget.max_relative_drift, 1e-6);
        assert_eq!(cfg.cv.recovery.budget.max_hops, 32);
        assert_eq!(cfg.cv.recovery.max_shift_retries, 2);
        assert_eq!(cfg.cv.recovery.shift_growth, 100.0);
        assert_eq!(cfg.cv.recovery.task_retries, 3);
        // untouched configs keep the documented defaults
        let cfg = ExperimentConfig::from_doc(&parse_toml("n = 64\n").unwrap()).unwrap();
        assert_eq!(
            cfg.cv.recovery,
            crate::cv::recovery::RecoveryPolicy::default()
        );
    }

    #[test]
    fn trust_validation_rejects_bad_knobs() {
        let doc = parse_toml("[trust]\nbudget = -1.0\n").unwrap();
        assert!(ExperimentConfig::from_doc(&doc).is_err(), "negative budget");
        let doc = parse_toml("[trust]\nshift_growth = 1.0\n").unwrap();
        assert!(ExperimentConfig::from_doc(&doc).is_err(), "growth must be > 1");
        let doc = parse_toml("[trust]\nshift_growth = inf\n").unwrap();
        assert!(
            ExperimentConfig::from_doc(&doc).is_err(),
            "growth must be finite"
        );
    }

    #[test]
    fn obs_knobs_parse_and_imply_enabled() {
        // off by default, no output paths
        let cfg = ExperimentConfig::from_doc(&parse_toml("n = 64\n").unwrap()).unwrap();
        assert!(!cfg.cv.obs);
        assert_eq!(cfg.trace_out, None);
        assert_eq!(cfg.ledger_out, None);
        // explicit enable without outputs
        let cfg =
            ExperimentConfig::from_doc(&parse_toml("[obs]\nenabled = true\n").unwrap()).unwrap();
        assert!(cfg.cv.obs);
        // either output path arms obs even with enabled unset
        let cfg = ExperimentConfig::from_doc(
            &parse_toml("[obs]\ntrace_out = \"trace.json\"\n").unwrap(),
        )
        .unwrap();
        assert!(cfg.cv.obs);
        assert_eq!(cfg.trace_out.as_deref(), Some("trace.json"));
        let cfg = ExperimentConfig::from_doc(
            &parse_toml("[obs]\nledger_out = \"run.jsonl\"\n").unwrap(),
        )
        .unwrap();
        assert!(cfg.cv.obs);
        assert_eq!(cfg.ledger_out.as_deref(), Some("run.jsonl"));
        // an output path overrides an explicit `enabled = false` — writing
        // the artifact the user asked for wins
        let cfg = ExperimentConfig::from_doc(
            &parse_toml("[obs]\nenabled = false\nledger_out = \"run.jsonl\"\n").unwrap(),
        )
        .unwrap();
        assert!(cfg.cv.obs);
    }

    #[test]
    fn service_knobs_parse_and_validate() {
        let doc = parse_toml(
            "[service]\nwindow = 1024\nrefresh_every = 32\nqueue_depth = 8\nworkers = 2\neval_batch = 64\ntier = \"loo\"\n",
        )
        .unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.service.window, 1024);
        assert_eq!(cfg.service.refresh_every, 32);
        assert_eq!(cfg.service.queue_depth, 8);
        assert_eq!(cfg.service.workers, 2);
        assert_eq!(cfg.service.eval_batch, 64);
        assert_eq!(cfg.service.tier, CvMode::Loo);
        // untouched configs keep the documented defaults
        let cfg = ExperimentConfig::from_doc(&parse_toml("n = 64\n").unwrap()).unwrap();
        assert_eq!(cfg.service, ServiceConfig::default());
        assert_eq!(cfg.service.tier, CvMode::Aloocv);
        // degenerate shapes are loud errors, not silent clamps
        for bad in [
            "[service]\nwindow = 0\n",
            "[service]\nrefresh_every = 0\n",
            "[service]\nqueue_depth = 0\n",
            "[service]\ntier = \"kfold\"\n",
            "[service]\ntier = \"hmm\"\n",
        ] {
            assert!(
                ExperimentConfig::from_doc(&parse_toml(bad).unwrap()).is_err(),
                "expected rejection of {bad:?}"
            );
        }
    }

    #[test]
    fn validation_rejects_non_finite_lambda_range() {
        let doc = parse_toml("[cv]\nlambda_min = nan\nlambda_max = 1.0\n").unwrap();
        assert!(ExperimentConfig::from_doc(&doc).is_err());
        let doc = parse_toml("[cv]\nlambda_min = 0.1\nlambda_max = inf\n").unwrap();
        assert!(ExperimentConfig::from_doc(&doc).is_err());
    }

    #[test]
    fn unknown_metric_rejected() {
        let doc = parse_toml("[cv]\nmetric = \"accuracy\"\n").unwrap();
        assert!(ExperimentConfig::from_doc(&doc).is_err());
    }

    #[test]
    fn dataset_parse_aliases() {
        assert!(parse_dataset("MNIST").is_ok());
        assert!(parse_dataset("coil100-like").is_ok());
        assert!(parse_dataset("imagenet").is_err());
    }
}
