//! Lightweight metrics registry: counters and duration gauges shared across
//! the coordinator's worker threads, snapshotted into experiment reports.
//!
//! Cells are `Arc<AtomicU64>`: the registry lock is held only long enough
//! to look up (or insert) a cell, and every add happens on the atomic
//! *outside* the lock. Hot loops can hoist the lookup entirely with
//! [`Metrics::counter_handle`] / [`Metrics::duration_handle`] and pay one
//! lock-free atomic per update. All accumulation saturates: nanosecond
//! conversion maps NaN/negative to 0 and huge/`inf` to `u64::MAX`
//! ([`secs_to_nanos`]), and adds clamp at `u64::MAX` instead of wrapping.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

pub use crate::obs::hist::secs_to_nanos;

type Registry = Mutex<BTreeMap<String, Arc<AtomicU64>>>;

/// `cell += v`, clamping at `u64::MAX` instead of wrapping.
fn saturating_fetch_add(cell: &AtomicU64, v: u64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let next = cur.saturating_add(v);
        match cell.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(seen) => cur = seen,
        }
    }
}

/// Process-wide metrics: monotonically increasing counters plus cumulative
/// phase durations (nanosecond-resolution, stored as u64 nanos).
#[derive(Default)]
pub struct Metrics {
    counters: Registry,
    durations: Registry,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Look up or insert a cell; the lock is released before the caller
    /// touches the atomic.
    fn cell(reg: &Registry, name: &str) -> Arc<AtomicU64> {
        let mut map = reg.lock().unwrap();
        if let Some(c) = map.get(name) {
            return Arc::clone(c);
        }
        let c = Arc::new(AtomicU64::new(0));
        map.insert(name.to_string(), Arc::clone(&c));
        c
    }

    /// A hoistable handle to a counter cell: hot loops fetch it once and
    /// update lock-free per iteration.
    pub fn counter_handle(&self, name: &str) -> Arc<AtomicU64> {
        Self::cell(&self.counters, name)
    }

    /// A hoistable handle to a duration cell (u64 nanoseconds).
    pub fn duration_handle(&self, name: &str) -> Arc<AtomicU64> {
        Self::cell(&self.durations, name)
    }

    /// Increment a counter.
    pub fn incr(&self, name: &str) {
        self.add(name, 1);
    }

    /// Add `n` to a counter (saturating).
    pub fn add(&self, name: &str, n: u64) {
        let cell = Self::cell(&self.counters, name);
        saturating_fetch_add(&cell, n);
    }

    /// Time a closure, accumulating under `name`.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        let cell = Self::cell(&self.durations, name);
        saturating_fetch_add(&cell, t0.elapsed().as_nanos().min(u64::MAX as u128) as u64);
        out
    }

    /// Accumulate an already-measured duration under `name` (how the sweep
    /// engine streams per-task wall times measured on worker threads).
    /// Saturating: NaN/negative inputs count as 0, `inf`/overflow clamp.
    pub fn add_secs(&self, name: &str, secs: f64) {
        let cell = Self::cell(&self.durations, name);
        saturating_fetch_add(&cell, secs_to_nanos(secs));
    }

    /// Counter value.
    pub fn counter(&self, name: &str) -> u64 {
        self.counters
            .lock()
            .unwrap()
            .get(name)
            .map(|a| a.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Cumulative seconds under a duration name.
    pub fn seconds(&self, name: &str) -> f64 {
        self.durations
            .lock()
            .unwrap()
            .get(name)
            .map(|a| a.load(Ordering::Relaxed) as f64 * 1e-9)
            .unwrap_or(0.0)
    }

    /// Render a sorted, fixed-format snapshot (CLI `--metrics` output).
    ///
    /// Names come out in BTreeMap (lexicographic) order; every name is
    /// padded to the longest name across both sections and values land in
    /// a fixed 14-character right-aligned column, so two snapshots diff
    /// line-by-line regardless of which names each run touched.
    pub fn snapshot(&self) -> String {
        let counters: Vec<(String, u64)> = self
            .counters
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
            .collect();
        let durations: Vec<(String, f64)> = self
            .durations
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed) as f64 * 1e-9))
            .collect();
        let width = counters
            .iter()
            .map(|(k, _)| k.len())
            .chain(durations.iter().map(|(k, _)| k.len()))
            .max()
            .unwrap_or(0);
        let mut s = String::new();
        for (k, v) in &counters {
            s.push_str(&format!("counter {k:<width$} = {v:>14}\n"));
        }
        for (k, v) in &durations {
            s.push_str(&format!("time    {k:<width$} = {v:>13.4}s\n"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.incr("solves");
        m.add("solves", 4);
        assert_eq!(m.counter("solves"), 5);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn durations_accumulate() {
        let m = Metrics::new();
        m.time("phase", || std::thread::sleep(std::time::Duration::from_millis(2)));
        m.time("phase", || std::thread::sleep(std::time::Duration::from_millis(2)));
        assert!(m.seconds("phase") >= 0.004);
    }

    #[test]
    fn add_secs_accumulates() {
        let m = Metrics::new();
        m.add_secs("task", 0.25);
        m.add_secs("task", 0.5);
        assert!((m.seconds("task") - 0.75).abs() < 1e-9);
    }

    #[test]
    fn add_secs_saturates_on_pathological_inputs() {
        let m = Metrics::new();
        m.add_secs("t", f64::NAN);
        assert_eq!(m.seconds("t"), 0.0, "NaN must count as zero");
        m.add_secs("t", -5.0);
        assert_eq!(m.seconds("t"), 0.0, "negative must count as zero");
        m.add_secs("t", f64::INFINITY);
        assert_eq!(
            m.seconds("t"),
            u64::MAX as f64 * 1e-9,
            "inf must clamp at the representable maximum"
        );
        // further adds must clamp instead of wrapping back toward zero
        m.add_secs("t", 1.0);
        assert_eq!(m.seconds("t"), u64::MAX as f64 * 1e-9);
    }

    #[test]
    fn counter_add_saturates_instead_of_wrapping() {
        let m = Metrics::new();
        m.add("c", u64::MAX - 1);
        m.add("c", 10);
        assert_eq!(m.counter("c"), u64::MAX);
    }

    #[test]
    fn handles_are_live_cells() {
        let m = Metrics::new();
        let h = m.counter_handle("hot");
        h.fetch_add(3, Ordering::Relaxed);
        m.incr("hot");
        assert_eq!(m.counter("hot"), 4);
        let d = m.duration_handle("wall");
        d.fetch_add(1_500_000_000, Ordering::Relaxed);
        assert!((m.seconds("wall") - 1.5).abs() < 1e-9);
    }

    #[test]
    fn snapshot_lists_everything() {
        let m = Metrics::new();
        m.incr("a");
        m.time("b", || {});
        let s = m.snapshot();
        assert!(s.contains("counter a"), "snapshot: {s}");
        assert!(s.contains("time    b"), "snapshot: {s}");
    }

    #[test]
    fn snapshot_golden_format() {
        let m = Metrics::new();
        m.add("sweep.runs", 2);
        m.add("sweep.grid_tasks", 120);
        m.add_secs("sweep.run_wall", 1.25);
        m.add_secs("gram", 0.0625);
        let expected = "\
counter sweep.grid_tasks =            120
counter sweep.runs       =              2
time    gram             =        0.0625s
time    sweep.run_wall   =        1.2500s
";
        assert_eq!(m.snapshot(), expected);
    }

    #[test]
    fn thread_safety() {
        let m = std::sync::Arc::new(Metrics::new());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        m.incr("x");
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.counter("x"), 4000);
    }
}
