//! Lightweight metrics registry: counters and duration gauges shared across
//! the coordinator's worker threads, snapshotted into experiment reports.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Process-wide metrics: monotonically increasing counters plus cumulative
/// phase durations (nanosecond-resolution, stored as u64 nanos).
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, AtomicU64>>,
    durations: Mutex<BTreeMap<String, AtomicU64>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Increment a counter.
    pub fn incr(&self, name: &str) {
        self.add(name, 1);
    }

    /// Add `n` to a counter.
    pub fn add(&self, name: &str, n: u64) {
        let mut map = self.counters.lock().unwrap();
        map.entry(name.to_string())
            .or_insert_with(|| AtomicU64::new(0))
            .fetch_add(n, Ordering::Relaxed);
    }

    /// Time a closure, accumulating under `name`.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        let nanos = t0.elapsed().as_nanos() as u64;
        let mut map = self.durations.lock().unwrap();
        map.entry(name.to_string())
            .or_insert_with(|| AtomicU64::new(0))
            .fetch_add(nanos, Ordering::Relaxed);
        out
    }

    /// Accumulate an already-measured duration under `name` (how the sweep
    /// engine streams per-task wall times measured on worker threads).
    pub fn add_secs(&self, name: &str, secs: f64) {
        let nanos = (secs.max(0.0) * 1e9) as u64;
        let mut map = self.durations.lock().unwrap();
        map.entry(name.to_string())
            .or_insert_with(|| AtomicU64::new(0))
            .fetch_add(nanos, Ordering::Relaxed);
    }

    /// Counter value.
    pub fn counter(&self, name: &str) -> u64 {
        self.counters
            .lock()
            .unwrap()
            .get(name)
            .map(|a| a.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Cumulative seconds under a duration name.
    pub fn seconds(&self, name: &str) -> f64 {
        self.durations
            .lock()
            .unwrap()
            .get(name)
            .map(|a| a.load(Ordering::Relaxed) as f64 * 1e-9)
            .unwrap_or(0.0)
    }

    /// Render a sorted snapshot (CLI `--metrics` output).
    pub fn snapshot(&self) -> String {
        let mut s = String::new();
        for (k, v) in self.counters.lock().unwrap().iter() {
            s.push_str(&format!("counter {k} = {}\n", v.load(Ordering::Relaxed)));
        }
        for (k, v) in self.durations.lock().unwrap().iter() {
            s.push_str(&format!(
                "time    {k} = {:.4}s\n",
                v.load(Ordering::Relaxed) as f64 * 1e-9
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.incr("solves");
        m.add("solves", 4);
        assert_eq!(m.counter("solves"), 5);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn durations_accumulate() {
        let m = Metrics::new();
        m.time("phase", || std::thread::sleep(std::time::Duration::from_millis(2)));
        m.time("phase", || std::thread::sleep(std::time::Duration::from_millis(2)));
        assert!(m.seconds("phase") >= 0.004);
    }

    #[test]
    fn add_secs_accumulates() {
        let m = Metrics::new();
        m.add_secs("task", 0.25);
        m.add_secs("task", 0.5);
        assert!((m.seconds("task") - 0.75).abs() < 1e-9);
    }

    #[test]
    fn snapshot_lists_everything() {
        let m = Metrics::new();
        m.incr("a");
        m.time("b", || {});
        let s = m.snapshot();
        assert!(s.contains("counter a = 1"));
        assert!(s.contains("time    b"));
    }

    #[test]
    fn thread_safety() {
        let m = std::sync::Arc::new(Metrics::new());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        m.incr("x");
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.counter("x"), 4000);
    }
}
