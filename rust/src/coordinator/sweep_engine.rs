//! The parallel λ×fold sweep engine: the batched executor behind
//! [`crate::cv::run_cv`].
//!
//! The paper's cost model (§1, Figures 1-2) says the λ sweep — `k` folds ×
//! `q` candidate λ's, one `chol(H + λI)` each — dominates ridge
//! cross-validation as soon as `n < k·q·d`. The serial loops this engine
//! replaces left every core but one idle; here the whole grid is enumerated
//! as a work queue and fanned over a [`WorkerPool`]:
//!
//! ```text
//!   SweepPlan ──► stage 0  shared Gram    ⌈n/chunk⌉ tasks: G = XᵀX, g = Xᵀy
//!              │           (streamed row blocks, ordered segment fold —
//!              │            assembled exactly ONCE per dataset)
//!              ├► stage 1  fold prep      k tasks: gather X_v + downdate
//!              │           H_f = G − X_vᵀX_v, g_f = g − X_vᵀy_v
//!              ├► stage 2  anchors        fold_strategy = "downdate"
//!              │           (default): one exact chol(G + λI) per *anchor*
//!              │           λ (every grid λ for Chol, the g samples for
//!              │           PiChol); "refactor": k·g per-fold
//!              │           chol(H_f + λ_s I) (PiChol only)
//!              ├► stage 3  grid sweep     k·⌈q/batch⌉ tasks: fold-downdate
//!              │           the anchor / interpolate / factorize, solve,
//!              │           score the hold-out split
//!              └► SweepReport             per-fold results + merged phase
//!                                         timer + degradation records +
//!                                         per-task metrics
//! ```
//!
//! Scheduling policy:
//!
//! - **The Gram is global.** Stage 0 assembles `(XᵀX, Xᵀy)` once per run
//!   ([`GramCache`], pool-parallel over row blocks) and shares it across all
//!   folds behind one `Arc`; fold prep costs `O(n_v·d²)` per fold — the
//!   `O(k·n·d²)` of per-fold SYRKs (and the k near-full dataset copies) are
//!   gone. The training split is gathered only for the SVD-family solvers,
//!   which need `X` itself.
//! - **Factor-level k-fold is the default task kind.** Under
//!   [`FoldStrategy::Downdate`] the hold-out downdate commutes with the λ
//!   shift (`H_f + λI = (G + λI) − X_vᵀX_v`), so the anchor wave factors
//!   `chol(G + λI)` exactly once per λ ("factor" phase, `Arc`-shared), and
//!   each grid task derives its fold factor by a chained rank-`n_v`
//!   hyperbolic downdate ([`crate::linalg::chud::downdate_rank_k`],
//!   "fold_downdate" phase) — per anchor, `k` refactorizations at `O(d³)`
//!   become `k` downdates at `O(n_v·d²)`. A numerically indefinite fold —
//!   or one whose drift budget is exhausted — climbs the unified recovery
//!   ladder *for that (fold, λ) cell only*, recorded in
//!   [`SweepReport::degradations`] ([`FoldData::factor_from_anchor`],
//!   [`crate::cv::recovery`]). Tasks that *panic* are resubmitted up to
//!   `RecoveryPolicy::task_retries` times and then quarantined: their cells
//!   stay NaN and the report gains a `cause: "panic"` entry naming the task.
//! - **Anchors run first.** Downdate/interpolated grid tasks only need the
//!   anchor factors / fitted interpolant, so the `O(d³)` exact
//!   factorizations are scheduled as their own wave and the cheap grid wave
//!   starts once the per-λ factors (or per-fold interpolants) are
//!   [`Arc`]-cached. Per-fold state ([`FoldData`], the interpolant) is
//!   shared across tasks by reference count, never cloned.
//! - **Few large anchors → intra-factorization parallelism.** When the
//!   anchor wave cannot fill the pool (`k·g <` workers) and the factor is
//!   large, anchors are factorized one at a time from the coordinating
//!   thread with [`cholesky_shifted_pooled`], which tiles each TRSM/SYRK
//!   trailing update into column-panel tasks on the *same* pool.
//! - **Everything else parallelizes at fold granularity.** MChol's binary
//!   search is inherently sequential and the SVD family factorizes once per
//!   fold, so those solvers run one task per fold via [`solvers::sweep`].
//! - **Leave-one-out is its own task kind.** [`SweepEngine::run_loo`]
//!   executes a [`LooPlan`]: shared Gram, one exact anchor factor per λ,
//!   then *per-i downdate* batches (copy anchor → rank-1 hyperbolic
//!   downdate by the held-out row → solve → score) fanned over the same
//!   pool — see [`crate::cv::loo`].
//!
//! ## Determinism
//!
//! Results are **bit-identical for every thread count** (the
//! `parallel_matches_serial_*` tests pin this). Tasks share no mutable
//! state, each task body is the same code the serial path runs
//! (`solvers::eval_exact_point` / `solvers::eval_interp_point`), the
//! pooled factorization is bitwise-equal to the serial kernel by
//! construction, and aggregation happens on the coordinating thread in
//! (fold, grid-index) order. Grid tasks draw their factor/eval/solve
//! buffers from the executing worker's [`Scratch`] arena
//! ([`WorkerPool::map_scratch`]) — every buffer is fully overwritten
//! before use, so the steady-state sweep allocates nothing per task
//! without perturbing a single bit.
//!
//! Thread count and batch shape are config knobs: `CvConfig::sweep_threads`
//! / `CvConfig::sweep_batch`, settable from experiment TOML as
//! `[sweep] threads = …` / `batch = …` (see [`crate::config`]).

use std::cell::RefCell;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::metrics::{secs_to_nanos, Metrics};
use crate::coordinator::pool::{default_workers, TaskFailure, WorkerPool};
use crate::cv::aloocv::{self, AloocvReport};
use crate::cv::loo::{self, LooReport, LooSkip};
use crate::cv::recovery::{DegradeInfo, Degradation, Rung};
use crate::cv::solvers::{self, SolverKind};
use crate::cv::{CvConfig, FoldData, FoldStrategy, SweepResult, TrainSplit};
use crate::data::folds::kfold;
use crate::data::gram::{self, GramCache};
use crate::data::synthetic::SyntheticDataset;
use crate::linalg::cholesky::{cholesky_shifted, cholesky_shifted_pooled, CholeskyError};
use crate::linalg::matrix::Matrix;
use crate::linalg::scratch::Scratch;
use crate::linalg::trust::FactorTrust;
use crate::obs::trace::{Event, Outcome};
use crate::obs::{ObsReport, RunObs};
use crate::pichol::pinrmse::fit_error_curve;
use crate::pichol::{self, FitOptions, Interpolant};
use crate::util::{logspace, subsample_indices, PhaseTimer};

/// Matrices at least this large get intra-factorization parallelism when
/// the anchor wave alone cannot fill the pool.
const INTRA_FACTOR_MIN_DIM: usize = 192;

/// Hessian accessors for the shared anchor wave (`fn` pointers so the wave
/// helper stays generic without boxing).
fn fold_hessian(fd: &FoldData) -> &Matrix {
    &fd.h_mat
}

fn gram_hessian(gram: &GramCache) -> &Matrix {
    gram.hessian()
}

/// A resolved description of one cross-validation sweep: solver, λ grid and
/// execution shape (thread count, λ's per grid task).
#[derive(Clone, Debug)]
pub struct SweepPlan {
    /// Algorithm to sweep.
    pub kind: SolverKind,
    /// Cross-validation settings the plan was derived from.
    pub cv: CvConfig,
    /// The candidate λ grid (`q` exponentially spaced points).
    pub grid: Vec<f64>,
    /// Resolved worker-thread count (≥ 1).
    pub threads: usize,
    /// λ grid points per sweep task (the batch shape; ≥ 1).
    pub batch: usize,
    /// Where `cv.fold_strategy` came from after resolution: `"config"`
    /// (explicit), `"bench-file"` / `"bench-file-mismatch"` (auto, measured
    /// crossover — the latter from rows recorded on a different kernel
    /// backend), `"probe"` (auto, no trajectory file — in-process
    /// micro-calibration) or `"default"` (auto, nothing usable) — see
    /// [`crate::cv::strategy`].
    pub strategy_source: &'static str,
}

impl SweepPlan {
    /// Resolve a plan from a dataset + config: builds the grid, resolves
    /// `sweep_threads == 0` to [`default_workers`] and `sweep_batch == 0` to
    /// an automatic shape (~4 batches per worker per fold for load balance).
    /// [`FoldStrategy::Auto`] is resolved here too — from the measured
    /// `chud_rk` crossover of the last `BENCH_kernels.json` at this run's
    /// `(n_v, d)` ([`crate::cv::strategy::resolve`]) — so the engine only
    /// ever sees a concrete strategy; the resolution's provenance lands in
    /// `strategy_source`.
    pub fn new(ds: &SyntheticDataset, kind: SolverKind, cfg: &CvConfig) -> Self {
        let (lo, hi) = cfg.lambda_range.unwrap_or_else(|| ds.kind.lambda_range());
        let grid = logspace(lo, hi, cfg.q_grid);
        let threads = if cfg.sweep_threads == 0 {
            default_workers()
        } else {
            cfg.sweep_threads
        };
        let batch = if cfg.sweep_batch == 0 {
            (grid.len() / (4 * threads)).max(1)
        } else {
            cfg.sweep_batch
        };
        let resolved =
            crate::cv::strategy::resolve(cfg.fold_strategy, ds.n(), ds.h(), cfg.k_folds);
        let mut cv = cfg.clone();
        cv.fold_strategy = resolved.strategy;
        Self {
            kind,
            cv,
            grid,
            threads,
            batch,
            strategy_source: resolved.source,
        }
    }

    /// Number of grid tasks this plan fans out (fold-level solvers use
    /// `k_folds` tasks instead).
    pub fn grid_tasks(&self) -> usize {
        self.cv.k_folds * self.grid.len().div_ceil(self.batch)
    }
}

/// A resolved leave-one-out sweep: the candidate grid, the `g` anchor λ's
/// that get exact factors (the same `subsample_indices` schedule piCholesky
/// uses for its sample points), and the execution shape.
#[derive(Clone, Debug)]
pub struct LooPlan {
    /// Cross-validation settings the plan was derived from.
    pub cv: CvConfig,
    /// The candidate λ grid (`q` exponentially spaced points).
    pub grid: Vec<f64>,
    /// The anchor λ's factored exactly (one `chol(G + λI)` each).
    pub anchors: Vec<f64>,
    /// Resolved worker-thread count (≥ 1).
    pub threads: usize,
    /// Held-out rows per per-i task (the batch shape; ≥ 1).
    pub batch: usize,
}

impl LooPlan {
    /// Resolve a plan from a dataset + config: grid from
    /// `q_grid`/`lambda_range`, anchors from `g_samples`,
    /// `sweep_threads == 0` → [`default_workers`], `sweep_batch == 0` → ~4
    /// row batches per worker.
    pub fn new(ds: &SyntheticDataset, cfg: &CvConfig) -> Self {
        let (lo, hi) = cfg.lambda_range.unwrap_or_else(|| ds.kind.lambda_range());
        let grid = logspace(lo, hi, cfg.q_grid);
        let anchors: Vec<f64> = subsample_indices(grid.len(), cfg.g_samples)
            .into_iter()
            .map(|i| grid[i])
            .collect();
        let threads = if cfg.sweep_threads == 0 {
            default_workers()
        } else {
            cfg.sweep_threads
        };
        let batch = if cfg.sweep_batch == 0 {
            (ds.n() / (4 * threads)).max(1)
        } else {
            cfg.sweep_batch
        };
        Self {
            cv: cfg.clone(),
            grid,
            anchors,
            threads,
            batch,
        }
    }
}

/// What one engine run produced: per-fold sweep results plus the merged
/// phase timer and scheduling counters.
pub struct SweepReport {
    /// Algorithm that was swept.
    pub kind: SolverKind,
    /// The candidate λ grid.
    pub grid: Vec<f64>,
    /// One [`SweepResult`] per fold, in fold order.
    pub fold_results: Vec<SweepResult>,
    /// Phase timings summed over all tasks (deterministic merge order).
    /// With threads > 1 this is CPU-time-like (sum over workers), not
    /// elapsed time — see `wall_secs` for the latter.
    pub timer: PhaseTimer,
    /// Elapsed wall-clock seconds of the whole run, as observed by the
    /// coordinating thread (this is what shrinks as threads grow).
    pub wall_secs: f64,
    /// Worker threads the run used.
    pub threads: usize,
    /// Total tasks executed (Gram chunks + fold prep + anchors + grid/fold
    /// sweeps).
    pub tasks: usize,
    /// Every cell that climbed above its baseline recovery rung —
    /// breakdowns, drift-budget refactorizations, quarantined panicking
    /// tasks ([`crate::cv::recovery`]) — merged on the coordinating thread
    /// in ascending (fold, grid-index) order — bitwise independent of
    /// scheduling like everything else.
    pub degradations: Vec<Degradation>,
    /// The micro-kernel backend every GEMM of this run dispatched to
    /// ([`crate::linalg::kernel::active_backend`]) — `"scalar"`, `"avx2"`
    /// or `"neon"`. All backends are bit-identical; this records which ran.
    pub kernel_backend: &'static str,
    /// The concrete fold strategy the run executed (never
    /// [`FoldStrategy::Auto`] — [`SweepPlan::new`] resolves it).
    pub fold_strategy: FoldStrategy,
    /// Provenance of `fold_strategy`: `"config"`, `"bench-file"`,
    /// `"bench-file-mismatch"`, `"probe"` or `"default"` (see
    /// [`SweepPlan::strategy_source`]).
    pub strategy_source: &'static str,
    /// Observability payload — the merged per-task event log plus latency
    /// histograms — present only when the run was armed (`CvConfig::obs`).
    /// Event *content* (the `(task_id, attempt, kind, outcome)` sequence)
    /// is bitwise worker-count-invariant; wall times and worker ids are
    /// payload, not contract ([`crate::obs`]).
    pub obs: Option<ObsReport>,
}

/// Output of one pool task, reassembled on the coordinating thread.
struct TaskOut {
    errors: Vec<f64>,
    /// Ladder climbs this task recorded: (grid index, final rung, cause).
    degradations: Vec<(usize, Rung, DegradeInfo)>,
    timer: PhaseTimer,
    wall: f64,
}

/// What stage 3's grid tasks do per λ — the engine's three grid task kinds.
enum GridKind {
    /// `chol(H_f + λI)` at every cell ([`FoldStrategy::Refactor`]),
    /// escalating through rungs 3–4 of the recovery ladder on breakdown.
    Exact,
    /// Factor-level downdate chains ([`FoldStrategy::Downdate`]):
    /// `anchors[i] = chol(G + grid[i]·I)` with its [`FactorTrust`] tag, each
    /// task derives its fold factor by rank-`n_v` tracked downdate
    /// (recovery-ladder escalation on breakdown or drift-budget
    /// exhaustion).
    Anchored(Arc<Vec<Matrix>>, Arc<Vec<FactorTrust>>),
    /// piCholesky: evaluate the per-fold interpolant.
    Interp(Vec<Arc<Interpolant>>),
}

/// Build the run/task timer: histogram-armed only when observability is —
/// the disarmed timer is byte-for-byte the pre-observability one.
fn new_timer(hists_on: bool) -> PhaseTimer {
    if hists_on {
        PhaseTimer::with_hists()
    } else {
        PhaseTimer::new()
    }
}

/// Record one completed span on the calling thread's ring. No-op (and no
/// allocation, no atomics) when the run is not armed.
fn record_span(
    obs: &Option<Arc<RunObs>>,
    task_id: u32,
    attempt: u32,
    kind: &'static str,
    surface: &'static str,
    fold: i64,
    lambda_index: i64,
    start_us: u64,
    outcome: Outcome,
    rung: Option<Rung>,
    degradations: u32,
) {
    if let Some(o) = obs {
        o.record(Event {
            task_id,
            attempt,
            kind,
            surface,
            fold,
            lambda_index,
            worker: 0, // stamped by record()
            start_us,
            stop_us: o.now_us(),
            outcome,
            rung,
            degradations,
        });
    }
}

/// Fold a LOO/ALOOCV batch's per-(row, anchor) cells into one span
/// outcome: degraded-cell count and the highest rung climbed (`Err` cells
/// count as `Skip`). Content-deterministic: the cells themselves are
/// bitwise worker-count-invariant, so this summary is too.
fn batch_outcome(
    per_rows: &[Vec<Result<(f64, Option<(Rung, DegradeInfo)>), CholeskyError>>],
) -> (Outcome, Option<Rung>, u32) {
    let mut degraded = 0u32;
    let mut max_rung: Option<Rung> = None;
    for per_anchor in per_rows {
        for cell in per_anchor {
            let rung = match cell {
                Ok((_, Some((rung, _)))) => Some(*rung),
                Ok((_, None)) => None,
                Err(_) => Some(Rung::Skip),
            };
            if let Some(r) = rung {
                degraded = degraded.saturating_add(1);
                max_rung = Some(max_rung.map_or(r, |m| m.max(r)));
            }
        }
    }
    let outcome = if degraded > 0 {
        Outcome::Degraded
    } else {
        Outcome::Ok
    };
    (outcome, max_rung, degraded)
}

/// The executor: a worker pool plus a metrics registry that per-task
/// timings stream into.
pub struct SweepEngine {
    pool: WorkerPool,
    metrics: Arc<Metrics>,
    /// Per-run observability state: armed at the top of `run`/`run_loo`/
    /// `run_aloocv` when the plan asks for it, disarmed (and drained into
    /// the report) at the end. `RefCell` because the engine is `!Sync`
    /// (runs are driven from one coordinating thread) and arming must not
    /// change every helper signature; workers only ever see cheap
    /// `Option<Arc<RunObs>>` clones captured at job-construction time.
    obs: RefCell<Option<Arc<RunObs>>>,
}

impl SweepEngine {
    /// Engine with `threads` workers and a private metrics registry.
    pub fn new(threads: usize) -> Self {
        Self::with_metrics(threads, Arc::new(Metrics::new()))
    }

    /// Engine streaming its task metrics into a shared registry (how the
    /// [`super::Coordinator`] wires the engine to its own metrics).
    pub fn with_metrics(threads: usize, metrics: Arc<Metrics>) -> Self {
        Self {
            pool: WorkerPool::new(threads.max(1)),
            metrics,
            obs: RefCell::new(None),
        }
    }

    /// This run's armed observability state, if any (an `Arc` clone).
    fn obs(&self) -> Option<Arc<RunObs>> {
        self.obs.borrow().clone()
    }

    /// Arm per-run event rings (one per worker plus the coordinator), each
    /// pre-sized to `capacity` events so the hot path never allocates. The
    /// capacity is a plan-derived overestimate of the whole run's event
    /// count — a single worker could legally receive every task.
    fn arm_obs(&self, enabled: bool, capacity: usize) {
        *self.obs.borrow_mut() = if enabled {
            Some(RunObs::new(self.pool.size(), capacity))
        } else {
            None
        };
    }

    /// Disarm and drain: merge every ring in `(task_id, attempt)` order and
    /// pair the event log with the run timer's per-phase histograms.
    /// Returns `None` when the run was never armed.
    fn finish_obs(&self, timer: &mut PhaseTimer) -> Option<ObsReport> {
        self.obs
            .borrow_mut()
            .take()
            .map(|o| ObsReport::from_run(&o, timer.take_hists()))
    }

    /// Worker count.
    pub fn threads(&self) -> usize {
        self.pool.size()
    }

    /// The metrics registry task timings stream into.
    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// Run a task batch: inline on the calling thread when the engine is
    /// single-threaded (no channel hops or worker handoff polluting timed
    /// serial runs — `run_matrix` relies on this for clean cross-algorithm
    /// comparisons), on the pool otherwise. Same input-order results and
    /// panic propagation either way. Jobs receive a [`Scratch`] arena: the
    /// executing worker's on the pool path, one arena shared sequentially
    /// across all jobs on the inline path — either way the buffers are warm
    /// after the first task and no further heap allocation happens.
    fn map_jobs<T: Send + 'static>(
        &self,
        jobs: Vec<Box<dyn FnOnce(&mut Scratch) -> T + Send + 'static>>,
    ) -> Vec<T> {
        if self.pool.size() == 1 {
            let mut scratch = Scratch::new();
            jobs.into_iter().map(|job| job(&mut scratch)).collect()
        } else {
            self.pool.map_scratch(jobs)
        }
    }

    /// [`Self::map_jobs`] with panic quarantine: a job that panics is rerun
    /// up to `retries` more times (jobs are `Fn`, not `FnOnce`, precisely so
    /// they can be resubmitted) and then surfaced as an
    /// [`Err`]`(`[`TaskFailure`]`)` in its input slot instead of taking the
    /// whole run down. Same input-order results as `map_jobs`, inline on
    /// the calling thread when single-threaded, on the pool otherwise
    /// ([`WorkerPool::map_scratch_recover`]).
    fn map_jobs_recover<T: Send + 'static>(
        &self,
        jobs: Vec<Arc<dyn Fn(&mut Scratch) -> T + Send + Sync + 'static>>,
        retries: u32,
    ) -> Vec<Result<T, TaskFailure>> {
        if self.pool.size() == 1 {
            let mut scratch = Scratch::new();
            jobs.into_iter()
                .enumerate()
                .map(|(i, job)| {
                    let mut attempts = 0u32;
                    loop {
                        attempts += 1;
                        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                            || job(&mut scratch),
                        ));
                        match caught {
                            Ok(v) => return Ok(v),
                            Err(payload) if attempts > retries => {
                                return Err(TaskFailure {
                                    task: i,
                                    attempts,
                                    message: crate::coordinator::pool::panic_message(&payload),
                                })
                            }
                            Err(_) => {}
                        }
                    }
                })
                .collect()
        } else {
            self.pool.map_scratch_recover(jobs, retries)
        }
    }

    /// Stage 0 of every run: assemble the shared Gram pair `(XᵀX, Xᵀy)`
    /// exactly once (streamed in row blocks over the pool when workers > 1;
    /// serial and pooled assembly are bitwise identical), timed under the
    /// `gram` phase. Returns the cache plus the chunk-task count.
    fn assemble_gram(
        &self,
        ds: &SyntheticDataset,
        chunk_rows: usize,
        timer: &mut PhaseTimer,
    ) -> (Arc<GramCache>, usize) {
        let pooled_gram = self.pool.size() >= 2;
        let gram_chunks = if pooled_gram {
            gram::chunk_ranges(ds.n(), chunk_rows).len()
        } else {
            // the serial path streams one segment at a time and ignores the
            // chunk knob — count what actually runs
            gram::chunk_ranges(ds.n(), gram::SEGMENT_ROWS).len()
        };
        let obs = self.obs();
        let tid = obs.as_ref().map_or(0, |o| o.alloc_id());
        let start = obs.as_ref().map_or(0, |o| o.now_us());
        let gram = timer.time("gram", || {
            if pooled_gram {
                GramCache::assemble_pooled(&ds.x, &ds.y, chunk_rows, &self.pool)
            } else {
                GramCache::assemble(&ds.x, &ds.y)
            }
        });
        record_span(&obs, tid, 0, "gram", "gram", -1, -1, start, Outcome::Ok, None, 0);
        self.metrics.incr("sweep.gram_builds");
        self.metrics.add("sweep.gram_chunks", gram_chunks as u64);
        (Arc::new(gram), gram_chunks)
    }

    /// The shared anchor-factorization wave: one exact `chol(hmat(m) + λI)`
    /// per `(m, λ)` item, returned in item order. Every anchor consumer —
    /// the factor-level per-λ waves (`grid_anchor_factors` and
    /// `fit_anchors`' downdate branch, phase `factor`), the legacy PiChol
    /// per-fold wave (`fit_anchors`' refactor branch, phase `chol`) and the
    /// LOO per-dataset wave (`run_loo`, phase `factor`) — runs through this
    /// one dispatcher, so the pool-vs-intra-factor heuristic and the
    /// `sweep.anchor_*` metrics cannot drift apart. When the wave cannot
    /// fill the pool and the factor is large, anchors are factorized one at
    /// a time from this thread with [`cholesky_shifted_pooled`] (bitwise
    /// equal to the serial kernel); otherwise one pool task per anchor.
    fn anchor_wave<M: Send + Sync + 'static>(
        &self,
        items: Vec<(Arc<M>, f64)>,
        hmat: fn(&M) -> &Matrix,
        phase: &'static str,
        timer: &mut PhaseTimer,
        tasks: &mut usize,
    ) -> crate::Result<Vec<Matrix>> {
        let few_large = self.pool.size() >= 2
            && items.len() < self.pool.size()
            && items
                .first()
                .is_some_and(|(m, _)| hmat(m).rows() >= INTRA_FACTOR_MIN_DIM);
        // task ids allocated here, in item order, on the coordinating
        // thread — both branches emit the same (task_id, attempt, kind)
        // content, so the few-large heuristic never shows in the event log
        let obs = self.obs();
        let ids: Vec<u32> = items
            .iter()
            .map(|_| obs.as_ref().map_or(0, |o| o.alloc_id()))
            .collect();
        let mut out = Vec::with_capacity(items.len());
        if few_large {
            // too few anchors to fill the pool and each one is big: tile
            // *inside* each factorization instead (driven from this thread —
            // never from a pool task, per the pool's deadlock rule)
            for (idx, (m, lam)) in items.iter().enumerate() {
                let start = obs.as_ref().map_or(0, |o| o.now_us());
                let t0 = Instant::now();
                let l = cholesky_shifted_pooled(hmat(m), *lam, &self.pool)?;
                let wall = t0.elapsed().as_secs_f64();
                record_span(
                    &obs,
                    ids[idx],
                    0,
                    phase,
                    "anchor",
                    -1,
                    idx as i64,
                    start,
                    Outcome::Ok,
                    None,
                    0,
                );
                timer.add(phase, wall);
                self.metrics.incr("sweep.anchor_tasks");
                self.metrics.add_secs("sweep.anchor_wall", wall);
                *tasks += 1;
                out.push(l);
            }
        } else {
            // enough anchors to fill the pool: one task per item
            type AnchorRes = Result<(Matrix, f64), CholeskyError>;
            let jobs: Vec<Box<dyn FnOnce(&mut Scratch) -> AnchorRes + Send>> = items
                .iter()
                .enumerate()
                .map(|(idx, (m, lam))| {
                    let m = Arc::clone(m);
                    let lam = *lam;
                    let obs = obs.clone();
                    let tid = ids[idx];
                    let f: Box<dyn FnOnce(&mut Scratch) -> AnchorRes + Send> =
                        Box::new(move |_scratch| {
                            let start = obs.as_ref().map_or(0, |o| o.now_us());
                            let t0 = Instant::now();
                            let l = cholesky_shifted(hmat(&m), lam)?;
                            record_span(
                                &obs,
                                tid,
                                0,
                                phase,
                                "anchor",
                                -1,
                                idx as i64,
                                start,
                                Outcome::Ok,
                                None,
                                0,
                            );
                            Ok((l, t0.elapsed().as_secs_f64()))
                        });
                    f
                })
                .collect();
            *tasks += jobs.len();
            for res in self.map_jobs(jobs) {
                let (l, wall) = res?;
                timer.add(phase, wall);
                self.metrics.incr("sweep.anchor_tasks");
                self.metrics.add_secs("sweep.anchor_wall", wall);
                out.push(l);
            }
        }
        Ok(out)
    }

    /// Execute a plan over a dataset.
    pub fn run(&self, ds: &SyntheticDataset, plan: &SweepPlan) -> crate::Result<SweepReport> {
        self.metrics.incr("sweep.runs");
        let run_t0 = Instant::now();
        // arm per-run observability (off by default: the disarmed path
        // takes one RefCell borrow per wave and no per-event work). Ring
        // capacity bounds the whole run's event count: gram + prep + the
        // largest possible anchor wave (whole grid for Chol, k·g + g for
        // PiChol) + 2× the grid tasks (retries and quarantine synthesis)
        // + k fold-level sweeps, doubled for headroom.
        let (k, q, g) = (plan.cv.k_folds, plan.grid.len(), plan.cv.g_samples);
        let cap = 2 * (8 + k + q + g * (k + 2) + 2 * plan.grid_tasks() + k);
        self.arm_obs(plan.cv.obs, cap);
        let hists_on = plan.cv.obs;
        let mut timer = new_timer(hists_on);
        let mut tasks = 0usize;

        // stage 0: the shared Gram — G = XᵀX and g = Xᵀy, assembled exactly
        // once per dataset. For the SVD-family solvers the Hessian itself
        // goes unused, but the one O(n·d²) assembly keeps FoldData uniform
        // and still undercuts the k per-fold SYRKs the old path spent on
        // those solvers.
        let (gram, gram_chunks) = self.assemble_gram(ds, plan.cv.chunk_rows, &mut timer);
        tasks += gram_chunks;

        // stage 1: fold prep — gather each fold's validation block serially
        // (borrows the dataset; the training split is gathered only for the
        // SVD family, which needs X itself), then downdate H_f/g_f from the
        // shared Gram in parallel (each task owns its gather + an Arc)
        let folds = kfold(ds.n(), plan.cv.k_folds, plan.cv.seed);
        let needs_x = matches!(
            plan.kind,
            SolverKind::Svd | SolverKind::TSvd | SolverKind::RSvd
        );
        let gathers: Vec<(Matrix, Vec<f64>, Option<TrainSplit>)> = folds
            .iter()
            .map(|f| {
                let (xv, yv) = f.materialize_val(&ds.x, &ds.y);
                let train = needs_x.then(|| {
                    let (xt, yt) = f.materialize_train(&ds.x, &ds.y);
                    TrainSplit { xt, yt }
                });
                (xv, yv, train)
            })
            .collect();
        type PrepRes = (FoldData, PhaseTimer, f64);
        let obs = self.obs();
        let build_jobs: Vec<Box<dyn FnOnce(&mut Scratch) -> PrepRes + Send>> = gathers
            .into_iter()
            .enumerate()
            .map(|(fi, (xv, yv, train))| {
                let gram = Arc::clone(&gram);
                let obs = obs.clone();
                let tid = obs.as_ref().map_or(0, |o| o.alloc_id());
                let f: Box<dyn FnOnce(&mut Scratch) -> PrepRes + Send> =
                    Box::new(move |_scratch| {
                        let start = obs.as_ref().map_or(0, |o| o.now_us());
                        let t0 = Instant::now();
                        let mut t = new_timer(obs.is_some());
                        let data = FoldData::from_gram(&gram, xv, yv, train, &mut t);
                        record_span(
                            &obs,
                            tid,
                            0,
                            "prep",
                            "fold",
                            fi as i64,
                            -1,
                            start,
                            Outcome::Ok,
                            None,
                            0,
                        );
                        (data, t, t0.elapsed().as_secs_f64())
                    });
                f
            })
            .collect();
        tasks += build_jobs.len();
        let mut fold_data: Vec<Arc<FoldData>> = Vec::with_capacity(folds.len());
        for (data, t, wall) in self.map_jobs(build_jobs) {
            timer.merge(&t);
            self.metrics.incr("sweep.prep_tasks");
            self.metrics.add_secs("sweep.prep_wall", wall);
            fold_data.push(Arc::new(data));
        }

        // stages 2-3: solver- and strategy-shaped scheduling
        let mut degradations: Vec<Degradation> = Vec::new();
        let fold_results = match plan.kind {
            SolverKind::Chol => {
                // Auto resolved to a concrete strategy in SweepPlan::new;
                // the defensive arm maps anything non-refactor to the
                // factor-level path (the crate default).
                let kind = if plan.cv.fold_strategy != FoldStrategy::Refactor {
                    // factor-level: every grid λ is an anchor — one exact
                    // chol(G + λI) each, fold factors by downdate chains
                    let (anchors, trusts) =
                        self.grid_anchor_factors(&gram, &plan.grid, &mut timer, &mut tasks)?;
                    GridKind::Anchored(anchors, trusts)
                } else {
                    GridKind::Exact
                };
                self.run_grid(plan, &fold_data, kind, &mut timer, &mut tasks, &mut degradations)?
            }
            SolverKind::PiChol => {
                let interps = self.fit_anchors(
                    plan,
                    &gram,
                    &fold_data,
                    &mut timer,
                    &mut tasks,
                    &mut degradations,
                )?;
                self.run_grid(
                    plan,
                    &fold_data,
                    GridKind::Interp(interps),
                    &mut timer,
                    &mut tasks,
                    &mut degradations,
                )?
            }
            _ => self.run_fold_level(plan, &fold_data, &mut timer, &mut tasks)?,
        };

        // actual λ evaluations: grid solvers score every grid point; fold-
        // level solvers may score fewer (MChol probes) — count what landed
        let evals: usize = fold_results
            .iter()
            .map(|r| r.errors.iter().filter(|e| e.is_finite()).count())
            .sum();
        self.metrics.add("sweep.lambda_evals", evals as u64);
        let wall_secs = run_t0.elapsed().as_secs_f64();
        self.metrics.add_secs("sweep.run_wall", wall_secs);
        let obs = self.finish_obs(&mut timer);
        Ok(SweepReport {
            kind: plan.kind,
            grid: plan.grid.clone(),
            fold_results,
            timer,
            wall_secs,
            threads: self.pool.size(),
            tasks,
            degradations,
            kernel_backend: crate::linalg::kernel::active_backend().name(),
            fold_strategy: plan.cv.fold_strategy,
            strategy_source: plan.strategy_source,
            obs,
        })
    }

    /// The factor-level anchor wave of the downdate strategy's exact sweep:
    /// one exact `chol(G + λI)` per **grid** λ ("factor" phase) — the only
    /// `O(d³)` work of the whole sweep — scheduled through the shared
    /// anchor dispatcher and `Arc`-shared by every grid task, each factor
    /// tagged with a fresh [`FactorTrust`] the downdate chains charge
    /// against. The wave itself stays fatal on [`CholeskyError`]: anchors
    /// factor `G + λI` with `λ > 0` on a real PSD Gram, which cannot go
    /// indefinite short of corrupted input — and corrupted input is
    /// rejected at ingest ([`gram::validate_rows`]).
    fn grid_anchor_factors(
        &self,
        gram: &Arc<GramCache>,
        grid: &[f64],
        timer: &mut PhaseTimer,
        tasks: &mut usize,
    ) -> crate::Result<(Arc<Vec<Matrix>>, Arc<Vec<FactorTrust>>)> {
        let items: Vec<(Arc<GramCache>, f64)> =
            grid.iter().map(|&lam| (Arc::clone(gram), lam)).collect();
        let factors = self.anchor_wave(items, gram_hessian, "factor", timer, tasks)?;
        let trusts: Vec<FactorTrust> = factors.iter().map(FactorTrust::fresh).collect();
        Ok((Arc::new(factors), Arc::new(trusts)))
    }

    /// Execute a leave-one-out plan: the factor-update subsystem's workload
    /// (see [`crate::cv::loo`] for the math and skip semantics).
    ///
    /// ```text
    ///   LooPlan ──► stage 0  shared Gram     ⌈n/chunk⌉ tasks: G = XᵀX, g = Xᵀy
    ///            ├► stage 1  anchor factors  g tasks: exact chol(G + λ_s I)
    ///            │           (pool-wide, or intra-factor tiling when a few
    ///            │            large anchors cannot fill the pool)
    ///            ├► stage 2  per-i downdates ⌈n/batch⌉ tasks: copy anchor,
    ///            │           rank-1 downdate by x_i, solve, score — the new
    ///            │           task kind; breakdowns recorded, not fatal
    ///            └► stage 3  curve fit       exact anchor RMSE → PINRMSE
    ///                                        polynomial over the full grid
    /// ```
    ///
    /// Bitwise independent of the worker count like every other path: tasks
    /// share no mutable state, anchor factors are bitwise equal serial or
    /// pooled, per-i results merge in ascending row order on the
    /// coordinating thread, and the per-(row, anchor) arithmetic is the
    /// serial `loo::eval_heldout_point` body verbatim.
    pub fn run_loo(&self, ds: &SyntheticDataset, plan: &LooPlan) -> crate::Result<LooReport> {
        // validation gate: a single NaN row would silently poison the shared
        // Gram and surface anchors deep as inexplicable breakdowns
        gram::validate_rows(&ds.x, &ds.y)?;
        self.metrics.incr("sweep.loo_runs");
        let run_t0 = Instant::now();
        // event bound: gram + g anchors + ⌈n/batch⌉ batches + the fit pair
        let cap = 2 * (8 + 2 * plan.anchors.len() + ds.n().div_ceil(plan.batch));
        self.arm_obs(plan.cv.obs, cap);
        let hists_on = plan.cv.obs;
        let mut timer = new_timer(hists_on);
        let mut tasks = 0usize;
        let n = ds.n();

        // stage 0: the shared Gram (assembled exactly once, like k-fold)
        let (gram, gram_chunks) = self.assemble_gram(ds, plan.cv.chunk_rows, &mut timer);
        tasks += gram_chunks;

        // stage 1: anchor factors L_s = chol(G + λ_s I) — the only O(d³)
        // work in the whole sweep, exactly one per anchor ("factor" phase),
        // scheduled by the shared anchor wave
        let g = plan.anchors.len();
        let items: Vec<(Arc<GramCache>, f64)> = plan
            .anchors
            .iter()
            .map(|&lam| (Arc::clone(&gram), lam))
            .collect();
        let factors = Arc::new(self.anchor_wave(
            items,
            gram_hessian,
            "factor",
            &mut timer,
            &mut tasks,
        )?);
        let trusts: Arc<Vec<FactorTrust>> =
            Arc::new(factors.iter().map(FactorTrust::fresh).collect());

        // stage 2: the per-i downdate wave — the new task kind. Each task
        // owns a gathered row batch and, per (row, anchor), copies the
        // anchor factor into worker scratch, downdates by x_i, solves and
        // scores (loo::eval_heldout_point). A breakdown — or a drift budget
        // exhausted by the rank-1 chain — climbs the recovery ladder inside
        // the cell; only full ladder exhaustion becomes an Err cell to
        // record, never a failed task.
        let policy = plan.cv.recovery;
        let anchor_lams = Arc::new(plan.anchors.clone());
        type CellRes = Result<(f64, Option<(Rung, DegradeInfo)>), CholeskyError>;
        type LooTaskRes = (Vec<Vec<CellRes>>, PhaseTimer, f64);
        let obs = self.obs();
        let mut jobs: Vec<Box<dyn FnOnce(&mut Scratch) -> LooTaskRes + Send>> = Vec::new();
        let mut spans: Vec<usize> = Vec::new(); // batch start rows
        let mut lo = 0;
        while lo < n {
            let hi = (lo + plan.batch).min(n);
            spans.push(lo);
            let xblock = ds.x.slice(lo, hi, 0, ds.h());
            let yblock = ds.y[lo..hi].to_vec();
            let gram = Arc::clone(&gram);
            let factors = Arc::clone(&factors);
            let trusts = Arc::clone(&trusts);
            let anchor_lams = Arc::clone(&anchor_lams);
            let obs = obs.clone();
            let tid = obs.as_ref().map_or(0, |o| o.alloc_id());
            let job: Box<dyn FnOnce(&mut Scratch) -> LooTaskRes + Send> =
                Box::new(move |scratch| {
                    let start = obs.as_ref().map_or(0, |o| o.now_us());
                    let t0 = Instant::now();
                    let mut t = new_timer(obs.is_some());
                    let mut per_rows = Vec::with_capacity(xblock.rows());
                    for r in 0..xblock.rows() {
                        let yi = yblock[r];
                        let mut per_anchor = Vec::with_capacity(factors.len());
                        for (s, anchor) in factors.iter().enumerate() {
                            per_anchor.push(loo::eval_heldout_point(
                                anchor,
                                trusts[s],
                                &gram,
                                xblock.row(r),
                                yi,
                                anchor_lams[s],
                                &policy,
                                scratch,
                                &mut t,
                            ));
                        }
                        per_rows.push(per_anchor);
                    }
                    if obs.is_some() {
                        let (outcome, rung, degraded) = batch_outcome(&per_rows);
                        record_span(
                            &obs,
                            tid,
                            0,
                            "loo_batch",
                            "loo",
                            lo as i64,
                            -1,
                            start,
                            outcome,
                            rung,
                            degraded,
                        );
                    }
                    (per_rows, t, t0.elapsed().as_secs_f64())
                });
            jobs.push(job);
            lo = hi;
        }
        tasks += jobs.len();

        // merge in ascending row order on this thread — scheduling never
        // touches the sums (degradations included)
        let mut sums = vec![0.0f64; g];
        let mut counts = vec![0usize; g];
        let mut skipped: Vec<LooSkip> = Vec::new();
        let mut degradations: Vec<Degradation> = Vec::new();
        // the registry lookup is hoisted out of the merge loop: one atomic
        // add per task, no lock inside the loop
        let m_tasks = self.metrics.counter_handle("sweep.loo_tasks");
        let m_wall = self.metrics.duration_handle("sweep.loo_wall");
        for (&lo, (per_rows, t, wall)) in spans.iter().zip(self.map_jobs(jobs)) {
            timer.merge(&t);
            m_tasks.fetch_add(1, Ordering::Relaxed);
            m_wall.fetch_add(secs_to_nanos(wall), Ordering::Relaxed);
            for (local, per_anchor) in per_rows.into_iter().enumerate() {
                for (s, cell) in per_anchor.into_iter().enumerate() {
                    match cell {
                        Ok((sqerr, degrade)) => {
                            sums[s] += sqerr;
                            counts[s] += 1;
                            if let Some((rung, info)) = degrade {
                                self.metrics.incr("sweep.degradations");
                                degradations.push(info.into_degradation(
                                    "loo",
                                    lo + local,
                                    plan.anchors[s],
                                    rung,
                                ));
                            }
                        }
                        Err(error) => {
                            self.metrics.incr("sweep.degradations");
                            degradations.push(Degradation {
                                surface: "loo",
                                fold: lo + local,
                                lambda: plan.anchors[s],
                                cause: "breakdown",
                                rung: Rung::Skip,
                                trust: 0.0,
                                detail: format!("ladder exhausted: {error}"),
                            });
                            skipped.push(LooSkip {
                                row: lo + local,
                                lambda: plan.anchors[s],
                                error,
                            });
                        }
                    }
                }
            }
        }
        self.metrics
            .add("sweep.loo_evals", counts.iter().sum::<usize>() as u64);
        self.metrics.add("sweep.loo_skips", skipped.len() as u64);

        // stage 3: exact anchor RMSE, then the PINRMSE polynomial over the
        // full grid (fitted on the anchors that survived)
        let anchor_rmse: Vec<f64> = sums
            .iter()
            .zip(&counts)
            .map(|(&s, &c)| if c > 0 { (s / c as f64).sqrt() } else { f64::NAN })
            .collect();
        let usable: (Vec<f64>, Vec<f64>) = plan
            .anchors
            .iter()
            .zip(&anchor_rmse)
            .filter(|(_, e)| e.is_finite())
            .map(|(&l, &e)| (l, e))
            .unzip();
        let (best_lambda, best_error, curve) = if usable.0.len() > plan.cv.degree {
            let tid = obs.as_ref().map_or(0, |o| o.alloc_id());
            let start = obs.as_ref().map_or(0, |o| o.now_us());
            let poly = timer.time("fit", || {
                fit_error_curve(&usable.0, &usable.1, plan.cv.degree)
            });
            let swept = timer.time("interp", || poly.sweep(&plan.grid));
            record_span(&obs, tid, 0, "fit", "curve", -1, -1, start, Outcome::Ok, None, 0);
            swept
        } else if let Some((bl, be)) = usable
            .0
            .iter()
            .zip(&usable.1)
            .min_by(|(_, a), (_, b)| a.partial_cmp(b).unwrap())
            .map(|(&l, &e)| (l, e))
        {
            // too few surviving anchors to fit the degree-r curve, but some
            // hold finite exact LOO-RMSE: degrade to the argmin over them
            // (the interpolated curve stays NaN — it cannot be fitted)
            (bl, be, vec![f64::NAN; plan.grid.len()])
        } else {
            // every anchor lost all its rows: nothing at all to select from
            (f64::NAN, f64::NAN, vec![f64::NAN; plan.grid.len()])
        };

        let wall_secs = run_t0.elapsed().as_secs_f64();
        self.metrics.add_secs("sweep.run_wall", wall_secs);
        let obs = self.finish_obs(&mut timer);
        Ok(LooReport {
            grid: plan.grid.clone(),
            curve,
            anchor_lambdas: plan.anchors.clone(),
            anchor_rmse,
            best_lambda,
            best_error,
            skipped,
            degradations,
            timer,
            wall_secs,
            threads: self.pool.size(),
            tasks,
            n,
            obs,
        })
    }

    /// Execute an ALOOCV plan: the cheap tier of the accuracy/cost ladder
    /// (see [`crate::cv::aloocv`] for the math and escalation semantics).
    ///
    /// ```text
    ///   LooPlan ──► stage 0  shared Gram     ⌈n/chunk⌉ tasks: G = XᵀX, g = Xᵀy
    ///            ├► stage 1  anchor factors  g tasks: exact chol(G + λ_s I),
    ///            │           then θ_s = (G + λ_s I)⁻¹g on the coordinating
    ///            │           thread ("solve" phase, exactly one per anchor)
    ///            ├► stage 2  batched hat     ⌈n/batch⌉ tasks: per anchor,
    ///            │           solves          gather Xᵀ, blocked multi-RHS
    ///            │                           TRSM, h_i per column, score
    ///            │                           e_i/(1−h_i); leverage rows
    ///            │                           escalate to exact LOO
    ///            └► stage 3  curve fit       anchor ALOO-RMSE → PINRMSE
    ///                                        polynomial over the full grid
    /// ```
    ///
    /// Bitwise independent of the worker count like every other path: the
    /// blocked TRSM is bitwise column-partition independent (so batch
    /// boundaries can never change a hat diagonal), θ_s is computed once on
    /// the coordinating thread, and per-batch results merge in ascending
    /// (row, anchor) order.
    pub fn run_aloocv(
        &self,
        ds: &SyntheticDataset,
        plan: &LooPlan,
    ) -> crate::Result<AloocvReport> {
        gram::validate_rows(&ds.x, &ds.y)?;
        self.metrics.incr("sweep.aloocv_runs");
        let run_t0 = Instant::now();
        // event bound: gram + g anchors + g solves + ⌈n/batch⌉ batches +
        // the fit pair
        let cap = 2 * (8 + 3 * plan.anchors.len() + ds.n().div_ceil(plan.batch));
        self.arm_obs(plan.cv.obs, cap);
        let hists_on = plan.cv.obs;
        let mut timer = new_timer(hists_on);
        let mut tasks = 0usize;
        let n = ds.n();

        // stage 0: the shared Gram (assembled exactly once, like LOO)
        let (gram, gram_chunks) = self.assemble_gram(ds, plan.cv.chunk_rows, &mut timer);
        tasks += gram_chunks;

        // stage 1: anchor factors L_s = chol(G + λ_s I) — the only O(d³)
        // work — then the full-data solve θ_s, once per anchor on the
        // coordinating thread (the per-row solves of the exact tier are
        // exactly what this tier amortizes away)
        let g = plan.anchors.len();
        let items: Vec<(Arc<GramCache>, f64)> = plan
            .anchors
            .iter()
            .map(|&lam| (Arc::clone(&gram), lam))
            .collect();
        let factors = Arc::new(self.anchor_wave(
            items,
            gram_hessian,
            "factor",
            &mut timer,
            &mut tasks,
        )?);
        let trusts: Arc<Vec<FactorTrust>> =
            Arc::new(factors.iter().map(FactorTrust::fresh).collect());
        let obs = self.obs();
        let thetas: Arc<Vec<Vec<f64>>> = {
            let mut work = Vec::new();
            let mut ths = Vec::with_capacity(g);
            for (s, l) in factors.iter().enumerate() {
                let tid = obs.as_ref().map_or(0, |o| o.alloc_id());
                let start = obs.as_ref().map_or(0, |o| o.now_us());
                let mut theta = Vec::new();
                timer.time("solve", || {
                    crate::linalg::triangular::solve_cholesky_into(
                        l,
                        gram.gradient(),
                        &mut work,
                        &mut theta,
                    )
                });
                record_span(
                    &obs,
                    tid,
                    0,
                    "solve",
                    "anchor",
                    -1,
                    s as i64,
                    start,
                    Outcome::Ok,
                    None,
                    0,
                );
                ths.push(theta);
            }
            Arc::new(ths)
        };

        // stage 2: the batched hat-diagonal wave. Each task owns a gathered
        // row batch and, per anchor, runs one blocked multi-RHS TRSM and
        // scores every row (aloocv::eval_hat_block). A leverage blow-up
        // escalates the row to the exact-LOO body inside the cell; only
        // full ladder exhaustion becomes an Err cell to record.
        let policy = plan.cv.recovery;
        let anchor_lams = Arc::new(plan.anchors.clone());
        type CellRes = Result<(f64, Option<(Rung, DegradeInfo)>), CholeskyError>;
        type AlooTaskRes = (Vec<Vec<CellRes>>, PhaseTimer, f64);
        let mut jobs: Vec<Box<dyn FnOnce(&mut Scratch) -> AlooTaskRes + Send>> = Vec::new();
        let mut spans: Vec<usize> = Vec::new(); // batch start rows
        let mut lo = 0;
        while lo < n {
            let hi = (lo + plan.batch).min(n);
            spans.push(lo);
            let xblock = ds.x.slice(lo, hi, 0, ds.h());
            let yblock = ds.y[lo..hi].to_vec();
            let gram = Arc::clone(&gram);
            let factors = Arc::clone(&factors);
            let trusts = Arc::clone(&trusts);
            let thetas = Arc::clone(&thetas);
            let anchor_lams = Arc::clone(&anchor_lams);
            let obs = obs.clone();
            let tid = obs.as_ref().map_or(0, |o| o.alloc_id());
            let job: Box<dyn FnOnce(&mut Scratch) -> AlooTaskRes + Send> =
                Box::new(move |scratch| {
                    let start = obs.as_ref().map_or(0, |o| o.now_us());
                    let t0 = Instant::now();
                    let mut t = new_timer(obs.is_some());
                    let rows = xblock.rows();
                    let mut per_rows: Vec<Vec<CellRes>> = (0..rows)
                        .map(|_| Vec::with_capacity(factors.len()))
                        .collect();
                    for (s, anchor) in factors.iter().enumerate() {
                        let cells = aloocv::eval_hat_block(
                            anchor,
                            trusts[s],
                            &gram,
                            &thetas[s],
                            &xblock,
                            &yblock,
                            anchor_lams[s],
                            &policy,
                            scratch,
                            &mut t,
                        );
                        for (local, cell) in cells.into_iter().enumerate() {
                            per_rows[local].push(cell);
                        }
                    }
                    if obs.is_some() {
                        let (outcome, rung, degraded) = batch_outcome(&per_rows);
                        record_span(
                            &obs,
                            tid,
                            0,
                            "aloo_batch",
                            "aloocv",
                            lo as i64,
                            -1,
                            start,
                            outcome,
                            rung,
                            degraded,
                        );
                    }
                    (per_rows, t, t0.elapsed().as_secs_f64())
                });
            jobs.push(job);
            lo = hi;
        }
        tasks += jobs.len();

        // merge in ascending (row, anchor) order on this thread —
        // scheduling never touches the sums (degradations included)
        let mut sums = vec![0.0f64; g];
        let mut counts = vec![0usize; g];
        let mut skipped: Vec<LooSkip> = Vec::new();
        let mut degradations: Vec<Degradation> = Vec::new();
        // hoisted registry lookups: one lock-free atomic per task merge
        let m_tasks = self.metrics.counter_handle("sweep.aloocv_tasks");
        let m_wall = self.metrics.duration_handle("sweep.aloocv_wall");
        for (&lo, (per_rows, t, wall)) in spans.iter().zip(self.map_jobs(jobs)) {
            timer.merge(&t);
            m_tasks.fetch_add(1, Ordering::Relaxed);
            m_wall.fetch_add(secs_to_nanos(wall), Ordering::Relaxed);
            for (local, per_anchor) in per_rows.into_iter().enumerate() {
                for (s, cell) in per_anchor.into_iter().enumerate() {
                    match cell {
                        Ok((sqerr, degrade)) => {
                            sums[s] += sqerr;
                            counts[s] += 1;
                            if let Some((rung, info)) = degrade {
                                self.metrics.incr("sweep.degradations");
                                degradations.push(info.into_degradation(
                                    "aloocv",
                                    lo + local,
                                    plan.anchors[s],
                                    rung,
                                ));
                            }
                        }
                        Err(error) => {
                            self.metrics.incr("sweep.degradations");
                            degradations.push(Degradation {
                                surface: "aloocv",
                                fold: lo + local,
                                lambda: plan.anchors[s],
                                cause: "leverage",
                                rung: Rung::Skip,
                                trust: 0.0,
                                detail: format!("ladder exhausted: {error}"),
                            });
                            skipped.push(LooSkip {
                                row: lo + local,
                                lambda: plan.anchors[s],
                                error,
                            });
                        }
                    }
                }
            }
        }
        self.metrics
            .add("sweep.aloocv_evals", counts.iter().sum::<usize>() as u64);
        self.metrics.add("sweep.aloocv_skips", skipped.len() as u64);

        // stage 3: anchor ALOO-RMSE, then the PINRMSE polynomial over the
        // full grid (fitted on the anchors that survived)
        let anchor_rmse: Vec<f64> = sums
            .iter()
            .zip(&counts)
            .map(|(&s, &c)| if c > 0 { (s / c as f64).sqrt() } else { f64::NAN })
            .collect();
        let usable: (Vec<f64>, Vec<f64>) = plan
            .anchors
            .iter()
            .zip(&anchor_rmse)
            .filter(|(_, e)| e.is_finite())
            .map(|(&l, &e)| (l, e))
            .unzip();
        let (best_lambda, best_error, curve) = if usable.0.len() > plan.cv.degree {
            let tid = obs.as_ref().map_or(0, |o| o.alloc_id());
            let start = obs.as_ref().map_or(0, |o| o.now_us());
            let poly = timer.time("fit", || {
                fit_error_curve(&usable.0, &usable.1, plan.cv.degree)
            });
            let swept = timer.time("interp", || poly.sweep(&plan.grid));
            record_span(&obs, tid, 0, "fit", "curve", -1, -1, start, Outcome::Ok, None, 0);
            swept
        } else if let Some((bl, be)) = usable
            .0
            .iter()
            .zip(&usable.1)
            .min_by(|(_, a), (_, b)| a.partial_cmp(b).unwrap())
            .map(|(&l, &e)| (l, e))
        {
            // too few surviving anchors to fit the degree-r curve, but some
            // hold finite ALOO-RMSE: degrade to the argmin over them
            (bl, be, vec![f64::NAN; plan.grid.len()])
        } else {
            // every anchor lost all its rows: nothing at all to select from
            (f64::NAN, f64::NAN, vec![f64::NAN; plan.grid.len()])
        };

        let wall_secs = run_t0.elapsed().as_secs_f64();
        self.metrics.add_secs("sweep.run_wall", wall_secs);
        let obs = self.finish_obs(&mut timer);
        Ok(AloocvReport {
            grid: plan.grid.clone(),
            curve,
            anchor_lambdas: plan.anchors.clone(),
            anchor_rmse,
            best_lambda,
            best_error,
            skipped,
            degradations,
            timer,
            wall_secs,
            threads: self.pool.size(),
            tasks,
            n,
            certification: None,
            obs,
        })
    }

    /// Stage 2 (PiChol): per-fold anchor factors `chol(H_f + λ_s I)`, then
    /// one Algorithm-1 fit per fold. Returns `Arc`-cached interpolants the
    /// grid wave shares.
    ///
    /// Under [`FoldStrategy::Downdate`] (default) the per-fold factors are
    /// *derived*, not refactorized: one exact `chol(G + λ_s I)` per sample
    /// λ ("factor" phase), then a **fold-downdate wave** — one task per
    /// (fold, λ_s), each running [`FoldData::factor_from_anchor`]
    /// ("fold_downdate" phase, recovery-ladder escalations recorded into
    /// `degradations`) — results merged in ascending (fold, λ_s) order.
    /// A *fully* exhausted ladder still propagates as an error here: the
    /// Algorithm-1 interpolant needs every one of its g sample factors.
    /// [`FoldStrategy::Refactor`] keeps the legacy flat k·g
    /// refactorization wave ("chol" phase).
    fn fit_anchors(
        &self,
        plan: &SweepPlan,
        gram: &Arc<GramCache>,
        fold_data: &[Arc<FoldData>],
        timer: &mut PhaseTimer,
        tasks: &mut usize,
        degradations: &mut Vec<Degradation>,
    ) -> crate::Result<Vec<Arc<Interpolant>>> {
        let sample_lams: Vec<f64> = subsample_indices(plan.grid.len(), plan.cv.g_samples)
            .into_iter()
            .map(|i| plan.grid[i])
            .collect();
        let g = sample_lams.len();
        let k = fold_data.len();

        let factors: Vec<Vec<Matrix>> = if plan.cv.fold_strategy != FoldStrategy::Refactor {
            // stage 2a: g global anchors chol(G + λ_s I), exactly one O(d³)
            // factorization per sample λ
            let items: Vec<(Arc<GramCache>, f64)> = sample_lams
                .iter()
                .map(|&lam| (Arc::clone(gram), lam))
                .collect();
            let global = Arc::new(self.anchor_wave(items, gram_hessian, "factor", timer, tasks)?);
            let trusts: Vec<FactorTrust> = global.iter().map(FactorTrust::fresh).collect();

            // stage 2b: the fold-downdate wave — k·g tasks, merged in
            // ascending (fold, λ_s) order so the regrouping (and the
            // degradation record) never depends on scheduling
            type FdRes = (
                Result<(Matrix, crate::cv::FoldFactor), CholeskyError>,
                PhaseTimer,
                f64,
            );
            let policy = plan.cv.recovery;
            let obs = self.obs();
            let mut jobs: Vec<Box<dyn FnOnce(&mut Scratch) -> FdRes + Send>> = Vec::new();
            let mut meta: Vec<(usize, f64)> = Vec::new(); // (fold, λ_s)
            for (fi, fd) in fold_data.iter().enumerate() {
                for (s, &lam) in sample_lams.iter().enumerate() {
                    meta.push((fi, lam));
                    let fd = Arc::clone(fd);
                    let global = Arc::clone(&global);
                    let trust = trusts[s];
                    let obs = obs.clone();
                    let tid = obs.as_ref().map_or(0, |o| o.alloc_id());
                    let job: Box<dyn FnOnce(&mut Scratch) -> FdRes + Send> =
                        Box::new(move |scratch| {
                            let start = obs.as_ref().map_or(0, |o| o.now_us());
                            let t0 = Instant::now();
                            let mut t = new_timer(obs.is_some());
                            let res = fd
                                .factor_from_anchor(&global[s], trust, lam, &policy, scratch, &mut t)
                                .map(|ff| (scratch.factor.clone(), ff));
                            let (outcome, rung, deg) = match &res {
                                Ok((_, ff)) if ff.degraded.is_some() => {
                                    (Outcome::Degraded, Some(ff.rung), 1)
                                }
                                Ok(_) => (Outcome::Ok, None, 0),
                                // fatal for the run (the interpolant needs
                                // every sample factor) — still log the span
                                Err(_) => (Outcome::Degraded, Some(Rung::Skip), 1),
                            };
                            record_span(
                                &obs,
                                tid,
                                0,
                                "fold_downdate",
                                "anchor",
                                fi as i64,
                                s as i64,
                                start,
                                outcome,
                                rung,
                                deg,
                            );
                            (res, t, t0.elapsed().as_secs_f64())
                        });
                    jobs.push(job);
                }
            }
            *tasks += jobs.len();
            let m_tasks = self.metrics.counter_handle("sweep.fold_downdate_tasks");
            let m_wall = self.metrics.duration_handle("sweep.fold_downdate_wall");
            let mut flat = Vec::with_capacity(meta.len());
            for ((fi, lam), (res, t, wall)) in meta.into_iter().zip(self.map_jobs(jobs)) {
                timer.merge(&t);
                m_tasks.fetch_add(1, Ordering::Relaxed);
                m_wall.fetch_add(secs_to_nanos(wall), Ordering::Relaxed);
                let (l, ff) = res?;
                if let Some(info) = ff.degraded {
                    self.metrics.incr("sweep.degradations");
                    degradations.push(info.into_degradation("kfold", fi, lam, ff.rung));
                }
                flat.push(l);
            }
            let mut flat = flat.into_iter();
            (0..k).map(|_| flat.by_ref().take(g).collect()).collect()
        } else {
            // legacy: factors[fold][s] = chol(H_fold + λ_s I), one flat
            // (fold, λ_s) refactorization wave through the shared anchor
            // scheduler, regrouped per fold (item-order results)
            let items: Vec<(Arc<FoldData>, f64)> = fold_data
                .iter()
                .flat_map(|fd| sample_lams.iter().map(move |&lam| (Arc::clone(fd), lam)))
                .collect();
            let flat = self.anchor_wave(items, fold_hessian, "chol", timer, tasks)?;
            let mut flat = flat.into_iter();
            (0..k).map(|_| flat.by_ref().take(g).collect()).collect()
        };

        // Algorithm-1 fits: cheap (O(g·r·D)) relative to the anchors, done
        // here in fold order so timer merge order is deterministic
        let mut interps = Vec::with_capacity(k);
        for per in &factors {
            let strategy = solvers::pichol_strategy();
            let interp = pichol::fit_from_factors(
                &sample_lams,
                per,
                &FitOptions {
                    degree: plan.cv.degree,
                    strategy: &strategy,
                },
                timer,
            );
            interps.push(Arc::new(interp));
        }
        Ok(interps)
    }

    /// Stage 3: the λ-grid wave. [`GridKind::Anchored`] tasks derive each
    /// fold factor by downdating the shared per-λ anchor (the
    /// fold-downdate task kind, recovery-ladder escalation on breakdown or
    /// drift-budget exhaustion); [`GridKind::Interp`] tasks interpolate
    /// (piCholesky); [`GridKind::Exact`] tasks factorize at every cell
    /// (refactor strategy, rungs 3–4 on breakdown). Task bodies never fail:
    /// a hopeless cell degrades to NaN, and a *panicking* task is
    /// resubmitted up to `RecoveryPolicy::task_retries` times before being
    /// quarantined (its cells stay NaN, the report records the panic).
    /// Results — and degradation records — merge on this thread in
    /// ascending (fold, grid-index) order.
    fn run_grid(
        &self,
        plan: &SweepPlan,
        fold_data: &[Arc<FoldData>],
        kind: GridKind,
        timer: &mut PhaseTimer,
        tasks: &mut usize,
        degradations: &mut Vec<Degradation>,
    ) -> crate::Result<Vec<SweepResult>> {
        let grid = Arc::new(plan.grid.clone());
        let metric = plan.cv.metric;
        let policy = plan.cv.recovery;

        let obs = self.obs();
        let surface: &'static str = match &kind {
            GridKind::Exact => "exact",
            GridKind::Anchored(..) => "anchored",
            GridKind::Interp(_) => "interp",
        };
        let mut jobs: Vec<Arc<dyn Fn(&mut Scratch) -> TaskOut + Send + Sync>> = Vec::new();
        let mut spans: Vec<(usize, usize, usize)> = Vec::new(); // (fold, lo, hi)
        // per-task event identity: ids allocated in (fold, lo) construction
        // order; the attempt counter is bumped at the top of the body —
        // *before* fault injection — so a retried task's surviving event
        // carries the true attempt ordinal and a panicked attempt records
        // nothing (its ring slot is never reached)
        let mut task_ids: Vec<u32> = Vec::new();
        for (fi, fd) in fold_data.iter().enumerate() {
            let mut lo = 0;
            while lo < grid.len() {
                let hi = (lo + plan.batch).min(grid.len());
                spans.push((fi, lo, hi));
                let fd = Arc::clone(fd);
                let grid = Arc::clone(&grid);
                // per-task view of the shared state for this task kind
                let kind_view = match &kind {
                    GridKind::Exact => GridKind::Exact,
                    GridKind::Anchored(anchors, trusts) => {
                        GridKind::Anchored(Arc::clone(anchors), Arc::clone(trusts))
                    }
                    GridKind::Interp(v) => GridKind::Interp(vec![Arc::clone(&v[fi])]),
                };
                // the task body borrows the executing worker's Scratch: the
                // factor/eval/solve buffers are warm after the worker's
                // first task, so the steady-state sweep allocates nothing
                // per λ evaluation. Jobs are `Fn` (not `FnOnce`) so a
                // panicking task can be resubmitted by map_jobs_recover.
                let ti = jobs.len();
                let obs_t = obs.clone();
                let tid = obs_t.as_ref().map_or(0, |o| o.alloc_id());
                task_ids.push(tid);
                let attempt_ctr = Arc::new(AtomicU32::new(0));
                let job: Arc<dyn Fn(&mut Scratch) -> TaskOut + Send + Sync> =
                    Arc::new(move |scratch| {
                        let attempt = attempt_ctr.fetch_add(1, Ordering::Relaxed);
                        let start = obs_t.as_ref().map_or(0, |o| o.now_us());
                        crate::testutil::faults::maybe_panic_task(ti);
                        let t0 = Instant::now();
                        let mut t = new_timer(obs_t.is_some());
                        let mut errors = Vec::with_capacity(hi - lo);
                        let mut cell_degrades: Vec<(usize, Rung, DegradeInfo)> = Vec::new();
                        match &kind_view {
                            GridKind::Interp(interp) => {
                                let strategy = solvers::pichol_strategy();
                                for &lam in &grid[lo..hi] {
                                    errors.push(solvers::eval_interp_point(
                                        &fd,
                                        &interp[0],
                                        &strategy,
                                        lam,
                                        metric,
                                        scratch,
                                        &mut t,
                                    ));
                                }
                            }
                            GridKind::Anchored(anchors, trusts) => {
                                // λ-warm-start: the update block X_vᵀ is
                                // λ-independent, so gather it once for this
                                // task's whole λ batch ("gather" phase) and
                                // replay it per cell — bitwise identical to
                                // re-gathering, one strided pass cheaper per
                                // cell. The buffer is taken out of the arena
                                // so the per-cell calls can borrow the rest
                                // of the scratch mutably.
                                let mut gathered = std::mem::replace(
                                    &mut scratch.gather,
                                    Matrix::zeros(0, 0),
                                );
                                t.time("gather", || {
                                    crate::linalg::chud::gather_update_block(
                                        &fd.xv,
                                        &mut gathered,
                                    )
                                });
                                for (off, &lam) in grid[lo..hi].iter().enumerate() {
                                    let (e, degrade) = solvers::eval_anchored_point_pregathered(
                                        &fd,
                                        &anchors[lo + off],
                                        trusts[lo + off],
                                        &gathered,
                                        lam,
                                        metric,
                                        &policy,
                                        scratch,
                                        &mut t,
                                    );
                                    errors.push(e);
                                    if let Some((rung, info)) = degrade {
                                        cell_degrades.push((lo + off, rung, info));
                                    }
                                }
                                scratch.gather = gathered;
                            }
                            GridKind::Exact => {
                                for (off, &lam) in grid[lo..hi].iter().enumerate() {
                                    let (e, degrade) = solvers::eval_exact_point_recovering(
                                        &fd, lam, metric, &policy, scratch, &mut t,
                                    );
                                    errors.push(e);
                                    if let Some((rung, info)) = degrade {
                                        cell_degrades.push((lo + off, rung, info));
                                    }
                                }
                            }
                        }
                        if obs_t.is_some() {
                            let (outcome, rung) = if cell_degrades.is_empty() {
                                (Outcome::Ok, None)
                            } else {
                                (
                                    Outcome::Degraded,
                                    cell_degrades.iter().map(|(_, r, _)| *r).max(),
                                )
                            };
                            record_span(
                                &obs_t,
                                tid,
                                attempt,
                                "grid",
                                surface,
                                fi as i64,
                                lo as i64,
                                start,
                                outcome,
                                rung,
                                cell_degrades.len() as u32,
                            );
                        }
                        TaskOut {
                            errors,
                            degradations: cell_degrades,
                            timer: t,
                            wall: t0.elapsed().as_secs_f64(),
                        }
                    });
                jobs.push(job);
                lo = hi;
            }
        }
        *tasks += jobs.len();

        let outs = self.map_jobs_recover(jobs, policy.task_retries);
        let mut per_fold: Vec<Vec<f64>> = fold_data
            .iter()
            .map(|_| vec![f64::NAN; grid.len()])
            .collect();
        // hoisted registry lookups: one lock-free atomic per task merge
        let m_tasks = self.metrics.counter_handle("sweep.grid_tasks");
        let m_wall = self.metrics.duration_handle("sweep.grid_wall");
        for (&(fi, lo, hi), out) in spans.iter().zip(outs) {
            match out {
                Ok(out) => {
                    per_fold[fi][lo..hi].copy_from_slice(&out.errors);
                    for (gidx, rung, info) in out.degradations {
                        self.metrics.incr("sweep.degradations");
                        degradations.push(info.into_degradation(
                            "kfold",
                            fi,
                            plan.grid[gidx],
                            rung,
                        ));
                    }
                    timer.merge(&out.timer);
                    m_tasks.fetch_add(1, Ordering::Relaxed);
                    m_wall.fetch_add(secs_to_nanos(out.wall), Ordering::Relaxed);
                }
                Err(fail) => {
                    // quarantined: this task's cells stay NaN and the sweep
                    // carries on — one berserk task degrades one span.
                    // Every attempt panicked before it could reach its ring,
                    // so the coordinator synthesizes the task's one event —
                    // a zero-length Quarantined span at the final attempt
                    // ordinal (the content tuple stays worker-invariant;
                    // fault injection is by task index, not by worker).
                    if let Some(o) = &obs {
                        let now = o.now_us();
                        o.record(Event {
                            task_id: task_ids[fail.task],
                            attempt: fail.attempts,
                            kind: "grid",
                            surface,
                            fold: fi as i64,
                            lambda_index: lo as i64,
                            worker: 0, // stamped by record()
                            start_us: now,
                            stop_us: now,
                            outcome: Outcome::Quarantined,
                            rung: Some(Rung::Skip),
                            degradations: 1,
                        });
                    }
                    self.metrics.incr("sweep.task_quarantines");
                    degradations.push(Degradation {
                        surface: "task",
                        fold: fi,
                        lambda: f64::NAN,
                        cause: "panic",
                        rung: Rung::Skip,
                        trust: 0.0,
                        detail: format!(
                            "grid task {} (cells {}..{}) quarantined after {} attempts: {}",
                            fail.task, lo, hi, fail.attempts, fail.message
                        ),
                    });
                }
            }
        }

        Ok(per_fold
            .into_iter()
            .map(|errors| {
                let (bl, be) = solvers::best_of(&plan.grid, &errors);
                SweepResult {
                    errors,
                    best_lambda: bl,
                    best_error: be,
                    probes: Vec::new(),
                }
            })
            .collect())
    }

    /// Fold-granular scheduling for the solvers whose per-fold work is
    /// sequential (MChol's binary search) or front-loaded (the SVD family,
    /// PINRMSE): one task per fold through the serial [`solvers::sweep`],
    /// fed by the executing worker's [`Scratch`] arena so even the cold-path
    /// solvers allocate nothing per grid point.
    fn run_fold_level(
        &self,
        plan: &SweepPlan,
        fold_data: &[Arc<FoldData>],
        timer: &mut PhaseTimer,
        tasks: &mut usize,
    ) -> crate::Result<Vec<SweepResult>> {
        let grid = Arc::new(plan.grid.clone());
        let obs = self.obs();
        type FoldRes = (crate::Result<SweepResult>, PhaseTimer, f64);
        let jobs: Vec<Box<dyn FnOnce(&mut Scratch) -> FoldRes + Send>> = fold_data
            .iter()
            .enumerate()
            .map(|(fi, fd)| {
                let fd = Arc::clone(fd);
                let grid = Arc::clone(&grid);
                let cfg = plan.cv.clone();
                let kind = plan.kind;
                let obs = obs.clone();
                let tid = obs.as_ref().map_or(0, |o| o.alloc_id());
                let f: Box<dyn FnOnce(&mut Scratch) -> FoldRes + Send> =
                    Box::new(move |scratch| {
                        let start = obs.as_ref().map_or(0, |o| o.now_us());
                        let t0 = Instant::now();
                        let mut t = new_timer(obs.is_some());
                        let res = solvers::sweep(kind, &fd, &grid, &cfg, scratch, &mut t);
                        record_span(
                            &obs,
                            tid,
                            0,
                            "fold_sweep",
                            "fold",
                            fi as i64,
                            -1,
                            start,
                            Outcome::Ok,
                            None,
                            0,
                        );
                        (res, t, t0.elapsed().as_secs_f64())
                    });
                f
            })
            .collect();
        *tasks += jobs.len();

        let mut out = Vec::with_capacity(fold_data.len());
        for (res, t, wall) in self.map_jobs(jobs) {
            timer.merge(&t);
            self.metrics.incr("sweep.fold_tasks");
            self.metrics.add_secs("sweep.fold_wall", wall);
            out.push(res?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::DatasetKind;

    fn cfg_with_threads(threads: usize) -> CvConfig {
        CvConfig {
            k_folds: 5,
            q_grid: 50,
            sweep_threads: threads,
            ..CvConfig::default()
        }
    }

    fn ds() -> SyntheticDataset {
        SyntheticDataset::generate(DatasetKind::MnistLike, 160, 17, 9)
    }

    fn run(kind: SolverKind, threads: usize) -> SweepReport {
        let ds = ds();
        let cfg = cfg_with_threads(threads);
        let plan = SweepPlan::new(&ds, kind, &cfg);
        assert_eq!(plan.threads, threads);
        let engine = SweepEngine::new(plan.threads);
        engine.run(&ds, &plan).unwrap()
    }

    /// The acceptance bar: a parallel sweep over a k=5, q=50 grid is
    /// bit-identical (≪ 1e-12) to the serial path, for both the exact and
    /// the interpolated solver, across thread counts 1/2/4.
    #[test]
    fn parallel_matches_serial_across_thread_counts() {
        for kind in [SolverKind::Chol, SolverKind::PiChol] {
            let serial = run(kind, 1);
            for threads in [2, 4] {
                let par = run(kind, threads);
                assert_eq!(par.threads, threads);
                for (fs, fp) in serial.fold_results.iter().zip(&par.fold_results) {
                    assert_eq!(
                        fs.best_lambda, fp.best_lambda,
                        "{:?} best_lambda differs at {threads} threads",
                        kind
                    );
                    assert_eq!(
                        fs.best_error, fp.best_error,
                        "{:?} best_error differs at {threads} threads",
                        kind
                    );
                    for (a, b) in fs.errors.iter().zip(&fp.errors) {
                        assert!(
                            (a == b) || (a.is_nan() && b.is_nan()),
                            "{:?} grid errors differ at {threads} threads: {a} vs {b}",
                            kind
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn fold_level_solvers_match_across_thread_counts() {
        for kind in [SolverKind::Svd, SolverKind::Pinrmse] {
            let serial = run(kind, 1);
            let par = run(kind, 3);
            for (fs, fp) in serial.fold_results.iter().zip(&par.fold_results) {
                assert_eq!(fs.best_lambda, fp.best_lambda);
                assert_eq!(fs.best_error, fp.best_error);
            }
        }
    }

    #[test]
    fn report_carries_timings_and_task_counts() {
        let rep = run(SolverKind::Chol, 2);
        assert_eq!(rep.fold_results.len(), 5);
        assert_eq!(rep.grid.len(), 50);
        assert!(rep.timer.get("gram") > 0.0);
        // factor-level default: anchors under "factor", per-cell work under
        // "fold_downdate"
        assert!(rep.timer.get("factor") > 0.0);
        assert!(rep.timer.get("fold_downdate") > 0.0);
        assert!(rep.wall_secs > 0.0);
        // 1+ gram tasks + 5 prep tasks + 50 anchors + 5 folds × ⌈50/batch⌉
        // grid tasks
        assert!(rep.tasks > 56, "tasks = {}", rep.tasks);
    }

    /// The factor-level acceptance assertion (extending the
    /// `gram_assembled_once_and_folds_downdate` pattern one level down):
    /// per anchor λ, exactly one O(d³) `factor` and k `fold_downdate`s, and
    /// the per-cell `chol` phase vanishes on the happy path — for both the
    /// exact sweep (anchors = the whole grid) and PiChol (anchors = the g
    /// samples). The refactor strategy keeps the legacy accounting.
    #[test]
    fn factor_level_phase_counts_per_anchor() {
        for threads in [1usize, 3] {
            // Chol: every grid λ is an anchor
            let rep = run(SolverKind::Chol, threads);
            assert_eq!(rep.timer.count("factor"), 50, "factor == 1 per anchor");
            assert_eq!(
                rep.timer.count("fold_downdate"),
                50 * 5,
                "fold_downdate == k per anchor"
            );
            assert_eq!(rep.timer.count("chol"), 0, "no per-cell refactorization");
            assert!(rep.degradations.is_empty());

            // PiChol: the g sample λ's are the anchors
            let rep = run(SolverKind::PiChol, threads);
            assert_eq!(rep.timer.count("factor"), 4);
            assert_eq!(rep.timer.count("fold_downdate"), 4 * 5);
            assert_eq!(rep.timer.count("chol"), 0);
            assert!(rep.degradations.is_empty());
        }

        // refactor strategy: per-cell chol, no factor-level phases
        let ds = ds();
        let cfg = CvConfig {
            fold_strategy: FoldStrategy::Refactor,
            ..cfg_with_threads(2)
        };
        let plan = SweepPlan::new(&ds, SolverKind::Chol, &cfg);
        let rep = SweepEngine::new(plan.threads).run(&ds, &plan).unwrap();
        assert_eq!(rep.timer.count("chol"), 50 * 5);
        assert_eq!(rep.timer.count("factor"), 0);
        assert_eq!(rep.timer.count("fold_downdate"), 0);
        assert!(rep.degradations.is_empty());
    }

    /// The two fold strategies are numerically interchangeable: same λ*
    /// grid cell per fold and curves within rounding — the in-crate slice
    /// of the cross-mode conformance suite (tests/conformance.rs runs the
    /// full one).
    #[test]
    fn downdate_strategy_matches_refactor_strategy() {
        let ds = ds();
        let mut reports = Vec::new();
        for strategy in [FoldStrategy::Refactor, FoldStrategy::Downdate] {
            let cfg = CvConfig {
                fold_strategy: strategy,
                ..cfg_with_threads(2)
            };
            let plan = SweepPlan::new(&ds, SolverKind::Chol, &cfg);
            reports.push(SweepEngine::new(plan.threads).run(&ds, &plan).unwrap());
        }
        let (refactor, downdate) = (&reports[0], &reports[1]);
        let cell = |grid: &[f64], lam: f64| grid.iter().position(|&l| l == lam).unwrap();
        for (fr, fd) in refactor.fold_results.iter().zip(&downdate.fold_results) {
            // λ* may only move to an adjacent cell, and only across a tie
            // at rounding level (best_of breaks exact ties leftward)
            let (ci, cj) = (
                cell(&refactor.grid, fr.best_lambda) as i64,
                cell(&downdate.grid, fd.best_lambda) as i64,
            );
            assert!((ci - cj).abs() <= 1, "λ* cells {ci} vs {cj}");
            assert!((fr.best_error - fd.best_error).abs() < 1e-9);
            for (a, b) in fr.errors.iter().zip(&fd.errors) {
                assert!((a - b).abs() < 1e-9, "curves drifted: {a} vs {b}");
            }
        }
    }

    /// The drift budget demonstrably bites: a budget tighter than one
    /// downdate's charge forces **every** cell of the downdate strategy
    /// through a full refactorization — visible in the phase counts (a
    /// per-cell `chol` appears next to the still-running `fold_downdate`s)
    /// and in the report (one `drift-budget` degradation per cell at rung
    /// 2) — and the resulting curve is **bitwise** the refactor strategy's,
    /// because rung 2 runs the identical `chol(H_f + λI)`.
    #[test]
    fn tight_drift_budget_bites_engine_wide() {
        use crate::cv::recovery::{RecoveryPolicy, Rung};
        use crate::linalg::trust::TrustBudget;
        let ds = ds();
        let ref_cfg = CvConfig {
            fold_strategy: FoldStrategy::Refactor,
            ..cfg_with_threads(2)
        };
        let ref_plan = SweepPlan::new(&ds, SolverKind::Chol, &ref_cfg);
        let oracle = SweepEngine::new(ref_plan.threads).run(&ds, &ref_plan).unwrap();

        let cfg = CvConfig {
            recovery: RecoveryPolicy {
                budget: TrustBudget {
                    max_relative_drift: 1e-300,
                    max_hops: 0,
                },
                ..RecoveryPolicy::default()
            },
            ..cfg_with_threads(2)
        };
        let plan = SweepPlan::new(&ds, SolverKind::Chol, &cfg);
        let rep = SweepEngine::new(plan.threads).run(&ds, &plan).unwrap();

        assert_eq!(rep.degradations.len(), 5 * 50, "every cell must escalate");
        assert!(rep.degradations.iter().all(|d| {
            d.surface == "kfold"
                && d.cause == "drift-budget"
                && d.rung == Rung::Refactor
                && d.trust > 0.0
        }));
        assert_eq!(rep.timer.count("chol"), 5 * 50, "one forced refactor per cell");
        assert_eq!(rep.timer.count("fold_downdate"), 5 * 50);
        assert_eq!(rep.timer.count("factor"), 50);
        for (fo, fd) in oracle.fold_results.iter().zip(&rep.fold_results) {
            assert_eq!(fo.errors, fd.errors, "forced-refactor curve must be bitwise");
            assert_eq!(fo.best_lambda, fd.best_lambda);
            assert_eq!(fo.best_error, fd.best_error);
        }
    }

    /// The tentpole acceptance assertion: fold prep never SYRKs X_train —
    /// the Gram is assembled exactly once per dataset (one `gram` phase
    /// invocation) and every fold's Hessian comes from the downdate path
    /// (one `downdate` invocation per fold, zero `hessian` invocations).
    #[test]
    fn gram_assembled_once_and_folds_downdate() {
        for kind in [SolverKind::Chol, SolverKind::PiChol, SolverKind::Svd] {
            for threads in [1, 3] {
                let rep = run(kind, threads);
                assert_eq!(
                    rep.timer.count("gram"),
                    1,
                    "{kind:?}@{threads}: Gram must be assembled exactly once"
                );
                assert_eq!(
                    rep.timer.count("downdate"),
                    5,
                    "{kind:?}@{threads}: one downdate per fold"
                );
                assert_eq!(
                    rep.timer.count("hessian"),
                    0,
                    "{kind:?}@{threads}: no per-fold SYRK on X_train may remain"
                );
            }
        }
    }

    #[test]
    fn chunk_rows_knob_does_not_change_results() {
        // n = 600 spans three accumulation segments, so the chunk plans
        // genuinely differ between these knob values
        let ds = SyntheticDataset::generate(DatasetKind::MnistLike, 600, 17, 9);
        let mut reference: Option<SweepReport> = None;
        for chunk_rows in [0usize, 7, 64, 600] {
            let cfg = CvConfig {
                chunk_rows,
                ..cfg_with_threads(2)
            };
            let plan = SweepPlan::new(&ds, SolverKind::Chol, &cfg);
            let engine = SweepEngine::new(plan.threads);
            let rep = engine.run(&ds, &plan).unwrap();
            if let Some(r) = &reference {
                for (a, b) in r.fold_results.iter().zip(&rep.fold_results) {
                    assert_eq!(a.best_lambda, b.best_lambda);
                    assert_eq!(a.best_error, b.best_error);
                    assert_eq!(a.errors, b.errors, "chunk_rows={chunk_rows} drifted");
                }
            } else {
                reference = Some(rep);
            }
        }
    }

    #[test]
    fn engine_streams_metrics() {
        let ds = ds();
        let cfg = cfg_with_threads(2);
        let plan = SweepPlan::new(&ds, SolverKind::PiChol, &cfg);
        let engine = SweepEngine::new(plan.threads);
        engine.run(&ds, &plan).unwrap();
        let m = engine.metrics();
        assert_eq!(m.counter("sweep.runs"), 1);
        assert_eq!(m.counter("sweep.gram_builds"), 1);
        assert!(m.counter("sweep.gram_chunks") >= 1);
        assert_eq!(m.counter("sweep.prep_tasks"), 5);
        // downdate default: the anchor wave factors only the g global
        // anchors; per-fold factors are fold-downdate tasks
        assert_eq!(m.counter("sweep.anchor_tasks"), 4); // g
        assert_eq!(m.counter("sweep.fold_downdate_tasks"), 5 * 4); // k × g
        assert_eq!(m.counter("sweep.degradations"), 0);
        assert_eq!(m.counter("sweep.task_quarantines"), 0);
        assert!(m.counter("sweep.grid_tasks") > 0);
        assert!(m.seconds("sweep.grid_wall") > 0.0);
        assert_eq!(m.counter("sweep.lambda_evals"), 5 * 50);
    }

    #[test]
    fn loo_plan_resolves_anchors_and_knobs() {
        let ds = ds();
        let cfg = CvConfig {
            q_grid: 31,
            g_samples: 5,
            sweep_threads: 3,
            sweep_batch: 0,
            ..CvConfig::default()
        };
        let plan = LooPlan::new(&ds, &cfg);
        assert_eq!(plan.grid.len(), 31);
        assert_eq!(plan.anchors.len(), 5);
        assert_eq!(plan.threads, 3);
        assert!(plan.batch >= 1);
        // anchors are grid points, ascending, endpoints included
        assert_eq!(plan.anchors[0], plan.grid[0]);
        assert_eq!(*plan.anchors.last().unwrap(), *plan.grid.last().unwrap());
        for w in plan.anchors.windows(2) {
            assert!(w[1] > w[0]);
        }
        let explicit = CvConfig {
            sweep_batch: 9,
            ..cfg
        };
        assert_eq!(LooPlan::new(&ds, &explicit).batch, 9);
    }

    /// The report records the dispatch decisions of the run: which kernel
    /// backend every GEMM went through and which fold strategy (with
    /// provenance) the sweep executed.
    #[test]
    fn report_carries_kernel_backend_and_strategy() {
        let rep = run(SolverKind::Chol, 2);
        assert!(
            ["scalar", "avx2", "neon"].contains(&rep.kernel_backend),
            "unexpected backend '{}'",
            rep.kernel_backend
        );
        assert_eq!(rep.fold_strategy, FoldStrategy::Downdate);
        assert_eq!(rep.strategy_source, "config");
    }

    /// `fold_strategy = "auto"` resolves in `SweepPlan::new`: the engine
    /// sees a concrete strategy, the report carries the resolution, and the
    /// run completes normally with no bench file present.
    #[test]
    fn plan_resolves_auto_strategy_before_engine_runs() {
        let ds = ds();
        let cfg = CvConfig {
            fold_strategy: FoldStrategy::Auto,
            ..cfg_with_threads(2)
        };
        let plan = SweepPlan::new(&ds, SolverKind::Chol, &cfg);
        assert_ne!(plan.cv.fold_strategy, FoldStrategy::Auto);
        assert!(
            plan.strategy_source == "bench-file" || plan.strategy_source == "default",
            "auto provenance, got '{}'",
            plan.strategy_source
        );
        let rep = SweepEngine::new(plan.threads).run(&ds, &plan).unwrap();
        assert_eq!(rep.fold_strategy, plan.cv.fold_strategy);
        assert_eq!(rep.strategy_source, plan.strategy_source);
        assert!(rep.fold_results.iter().all(|r| r.best_error.is_finite()));
    }

    /// The λ-warm-start: each Anchored grid task gathers its fold's update
    /// block exactly once (the `gather` phase), not once per λ cell — while
    /// the pinned per-cell `fold_downdate` accounting is untouched (see
    /// `factor_level_phase_counts_per_anchor`).
    #[test]
    fn anchored_grid_tasks_gather_once_per_task() {
        let rep = run(SolverKind::Chol, 2);
        let grid_tasks = 5 * 50usize.div_ceil({
            let ds = ds();
            let plan = SweepPlan::new(&ds, SolverKind::Chol, &cfg_with_threads(2));
            plan.batch
        });
        assert_eq!(
            rep.timer.count("gather"),
            grid_tasks as u64,
            "one gather per Anchored grid task"
        );
        assert_eq!(rep.timer.count("fold_downdate"), 5 * 50);
    }

    /// Arming observability perturbs nothing: the numeric report is
    /// bitwise the disarmed run's, the event log is complete (one span per
    /// task, exact count for this shape), merged in ascending
    /// `(task_id, attempt)` order with unique ids, nothing dropped, and
    /// the latency histograms cover the phases the run actually timed.
    #[test]
    fn obs_armed_run_is_bitwise_identical_and_carries_events() {
        let ds = ds();
        let base = CvConfig {
            sweep_batch: 4,
            ..cfg_with_threads(2)
        };
        let on = CvConfig {
            obs: true,
            ..base.clone()
        };
        let plan_off = SweepPlan::new(&ds, SolverKind::Chol, &base);
        let plan_on = SweepPlan::new(&ds, SolverKind::Chol, &on);
        let off = SweepEngine::new(plan_off.threads)
            .run(&ds, &plan_off)
            .unwrap();
        let rep = SweepEngine::new(plan_on.threads).run(&ds, &plan_on).unwrap();
        assert!(off.obs.is_none(), "disarmed run must not carry an ObsReport");
        let o = rep.obs.as_ref().expect("armed run must carry an ObsReport");
        assert_eq!(o.dropped, 0);
        // 1 gram + 5 prep + 50 anchor factors + 5·⌈50/4⌉ grid tasks
        assert_eq!(o.events.len(), 1 + 5 + 50 + 5 * 13, "one span per task");
        for w in o.events.windows(2) {
            assert!(
                (w[0].task_id, w[0].attempt) < (w[1].task_id, w[1].attempt),
                "merged log must be strictly ordered by (task_id, attempt)"
            );
        }
        for (a, b) in off.fold_results.iter().zip(&rep.fold_results) {
            assert_eq!(a.errors, b.errors, "arming obs perturbed the sweep");
            assert_eq!(a.best_lambda, b.best_lambda);
            assert_eq!(a.best_error, b.best_error);
        }
        assert!(o.phase_hists.get("factor").is_some());
        assert!(o.phase_hists.get("fold_downdate").is_some());
        assert_eq!(
            o.kind_hists.get("grid").map(|h| h.count()),
            Some(5 * 13),
            "per-kind histogram counts every grid span"
        );
    }

    /// The merged event-log *content* — the (task_id, attempt, kind,
    /// surface, fold, λ-index, outcome) tuple sequence — is identical at
    /// any worker count; wall times and worker ids are payload.
    #[test]
    fn obs_event_content_is_worker_count_invariant() {
        let ds = ds();
        let mut logs = Vec::new();
        for threads in [1usize, 2, 4] {
            let cfg = CvConfig {
                obs: true,
                sweep_batch: 4,
                ..cfg_with_threads(threads)
            };
            let plan = SweepPlan::new(&ds, SolverKind::PiChol, &cfg);
            let rep = SweepEngine::new(plan.threads).run(&ds, &plan).unwrap();
            let o = rep.obs.expect("armed");
            assert_eq!(o.dropped, 0);
            logs.push(
                o.events
                    .iter()
                    .map(|e| {
                        (
                            e.task_id,
                            e.attempt,
                            e.kind,
                            e.surface,
                            e.fold,
                            e.lambda_index,
                            e.outcome,
                            e.degradations,
                        )
                    })
                    .collect::<Vec<_>>(),
            );
        }
        assert_eq!(logs[0], logs[1], "2 workers changed event content");
        assert_eq!(logs[0], logs[2], "4 workers changed event content");
    }

    #[test]
    fn plan_resolves_auto_knobs() {
        let ds = ds();
        let cfg = CvConfig {
            k_folds: 2,
            q_grid: 31,
            sweep_threads: 3,
            sweep_batch: 0,
            ..CvConfig::default()
        };
        let plan = SweepPlan::new(&ds, SolverKind::Chol, &cfg);
        assert_eq!(plan.threads, 3);
        assert!(plan.batch >= 1);
        assert_eq!(plan.grid.len(), 31);
        assert!(plan.grid_tasks() >= plan.cv.k_folds);

        let explicit = CvConfig {
            sweep_batch: 7,
            ..cfg
        };
        assert_eq!(SweepPlan::new(&ds, SolverKind::Chol, &explicit).batch, 7);
    }
}
