//! The AOT request path: one cross-validation fold entirely through the
//! compiled HLO artifacts (python is long gone by now).
//!
//! Pipeline per fold (artifact names in backticks):
//!
//! ```text
//!   `gram`     (X_t, y_t)            → (H, g)          O(n d²), Pallas tiles
//!   `cholvec`  (H, λ_sample[g])      → T[g, D]         the g exact factors
//!   `polyfit`  (λ_sample, T)         → Θ[(r+1), D_pad] Algorithm 1
//!   `sweep`    (Θ, λ_grid[m], g, Xv, yv) → errs[m, 2]  interp+solve+holdout,
//!                                                      all m λ's in one call
//! ```
//!
//! plus `exact_sweep` (H, λ_grid, g, Xv, yv) → errs for the Chol baseline.
//! The fused `sweep` artifact is the L2-level batching win: one executable
//! launch serves the entire grid, so the per-λ dispatch cost the paper
//! attributes to BLAS-3 batching shows up here as a single PJRT execution.

use anyhow::Result;

use super::metrics::Metrics;
use crate::linalg::matrix::Matrix;
use crate::runtime::{ConfigEntry, Engine, Tensor};
use crate::util::{logspace, subsample_indices};

/// Per-λ hold-out results from one fold sweep.
#[derive(Clone, Debug)]
pub struct HloSweepResult {
    pub grid: Vec<f64>,
    /// RMSE per grid λ.
    pub rmse: Vec<f64>,
    /// Misclassification rate per grid λ.
    pub miscls: Vec<f64>,
    /// Index of the best (RMSE-minimizing) λ.
    pub best_idx: usize,
}

impl HloSweepResult {
    fn from_errs(grid: Vec<f64>, errs: &Tensor) -> Result<Self> {
        anyhow::ensure!(
            errs.dims == vec![grid.len(), 2],
            "sweep output shape {:?}",
            errs.dims
        );
        let rmse: Vec<f64> = (0..grid.len()).map(|i| errs.data[2 * i] as f64).collect();
        let miscls: Vec<f64> = (0..grid.len())
            .map(|i| errs.data[2 * i + 1] as f64)
            .collect();
        let best_idx = rmse
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| a.partial_cmp(b).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);
        Ok(Self {
            grid,
            rmse,
            miscls,
            best_idx,
        })
    }

    pub fn best_lambda(&self) -> f64 {
        self.grid[self.best_idx]
    }

    pub fn best_rmse(&self) -> f64 {
        self.rmse[self.best_idx]
    }
}

/// One fold's data, shaped exactly as the AOT config expects.
pub struct HloFold {
    pub xt: Matrix,
    pub yt: Vec<f64>,
    pub xv: Matrix,
    pub yv: Vec<f64>,
}

impl HloFold {
    fn validate(&self, cfg: &ConfigEntry) -> Result<()> {
        anyhow::ensure!(
            self.xt.rows() == cfg.n && self.xt.cols() == cfg.h,
            "train split {}×{} != lowered {}×{}",
            self.xt.rows(),
            self.xt.cols(),
            cfg.n,
            cfg.h
        );
        anyhow::ensure!(
            self.xv.rows() == cfg.n_val && self.xv.cols() == cfg.h,
            "val split {}×{} != lowered {}×{}",
            self.xv.rows(),
            self.xv.cols(),
            cfg.n_val,
            cfg.h
        );
        Ok(())
    }
}

/// The fold pipeline bound to one engine + shape config.
pub struct HloPipeline<'e> {
    engine: &'e Engine,
    cfg: &'e ConfigEntry,
    metrics: &'e Metrics,
}

impl<'e> HloPipeline<'e> {
    pub fn new(engine: &'e Engine, cfg: &'e ConfigEntry, metrics: &'e Metrics) -> Self {
        Self {
            engine,
            cfg,
            metrics,
        }
    }

    /// Compile every artifact up front so fold execution never compiles.
    pub fn warmup(&self) -> Result<()> {
        self.metrics.time("hlo.compile", || {
            self.engine.warmup(
                self.cfg,
                &["gram", "cholvec", "polyfit", "sweep", "exact_sweep"],
            )
        })
    }

    /// The λ grid this config was lowered for (m points).
    pub fn grid(&self, lo: f64, hi: f64) -> Vec<f64> {
        logspace(lo, hi, self.cfg.m)
    }

    /// Sparse sample λ's (g of the m grid points).
    pub fn sample_lambdas(&self, grid: &[f64]) -> Vec<f64> {
        subsample_indices(grid.len(), self.cfg.g)
            .into_iter()
            .map(|i| grid[i])
            .collect()
    }

    /// `gram`: Hessian + gradient on-device.
    pub fn gram(&self, fold: &HloFold) -> Result<(Tensor, Tensor)> {
        fold.validate(self.cfg)?;
        let out = self.metrics.time("hlo.gram", || {
            self.engine.run(
                self.cfg,
                "gram",
                &[Tensor::from_matrix(&fold.xt), Tensor::from_vec(&fold.yt)],
            )
        })?;
        self.metrics.incr("hlo.gram.calls");
        anyhow::ensure!(out.len() == 2, "gram returned {} outputs", out.len());
        let mut it = out.into_iter();
        Ok((it.next().unwrap(), it.next().unwrap()))
    }

    /// piCholesky fit through `cholvec` + `polyfit`; returns Θ (padded).
    pub fn fit(&self, h_t: &Tensor, sample_lams: &[f64]) -> Result<Tensor> {
        let lams = Tensor::from_vec(sample_lams);
        let t = self.metrics.time("hlo.cholvec", || {
            self.engine.run(self.cfg, "cholvec", &[h_t.clone(), lams.clone()])
        })?;
        self.metrics.incr("hlo.cholvec.calls");
        let theta = self.metrics.time("hlo.polyfit", || {
            self.engine.run(self.cfg, "polyfit", &[lams, t[0].clone()])
        })?;
        self.metrics.incr("hlo.polyfit.calls");
        Ok(theta.into_iter().next().unwrap())
    }

    /// Fused piCholesky sweep: interp + solve + holdout for the whole grid.
    pub fn sweep(
        &self,
        theta: &Tensor,
        grid: &[f64],
        g_vec: &Tensor,
        fold: &HloFold,
    ) -> Result<HloSweepResult> {
        let out = self.metrics.time("hlo.sweep", || {
            self.engine.run(
                self.cfg,
                "sweep",
                &[
                    theta.clone(),
                    Tensor::from_vec(grid),
                    g_vec.clone(),
                    Tensor::from_matrix(&fold.xv),
                    Tensor::from_vec(&fold.yv),
                ],
            )
        })?;
        self.metrics.incr("hlo.sweep.calls");
        HloSweepResult::from_errs(grid.to_vec(), &out[0])
    }

    /// Exact-Cholesky sweep baseline (`exact_sweep` artifact).
    pub fn exact_sweep(
        &self,
        h_t: &Tensor,
        grid: &[f64],
        g_vec: &Tensor,
        fold: &HloFold,
    ) -> Result<HloSweepResult> {
        let out = self.metrics.time("hlo.exact_sweep", || {
            self.engine.run(
                self.cfg,
                "exact_sweep",
                &[
                    h_t.clone(),
                    Tensor::from_vec(grid),
                    g_vec.clone(),
                    Tensor::from_matrix(&fold.xv),
                    Tensor::from_vec(&fold.yv),
                ],
            )
        })?;
        self.metrics.incr("hlo.exact_sweep.calls");
        HloSweepResult::from_errs(grid.to_vec(), &out[0])
    }

    /// Full piCholesky fold: gram → fit → sweep.
    pub fn run_fold(&self, fold: &HloFold, lo: f64, hi: f64) -> Result<HloSweepResult> {
        let grid = self.grid(lo, hi);
        let (h_t, g_t) = self.gram(fold)?;
        let theta = self.fit(&h_t, &self.sample_lambdas(&grid))?;
        self.sweep(&theta, &grid, &g_t, fold)
    }

    /// Full exact-Cholesky fold: gram → exact sweep (the baseline).
    pub fn run_fold_exact(&self, fold: &HloFold, lo: f64, hi: f64) -> Result<HloSweepResult> {
        let grid = self.grid(lo, hi);
        let (h_t, g_t) = self.gram(fold)?;
        self.exact_sweep(&h_t, &grid, &g_t, fold)
    }
}
