//! A small fixed-size worker pool over `std::thread` — the substrate every
//! parallel stage of the coordinator runs on.
//!
//! tokio is not in the offline crate set, and the coordinator's concurrency
//! needs are simple: fan a batch of CPU-bound jobs over N workers and collect
//! results in a deterministic order. Three layers consume this pool:
//!
//! - [`super::sweep_engine`] — fold-prep, anchor-factorization and λ-grid
//!   tasks of the cross-validation sweep (the paper's dominant cost);
//! - [`crate::linalg::cholesky::cholesky_in_place_pooled`] — column-panel
//!   TRSM/SYRK tiles inside one blocked factorization (intra-factorization
//!   parallelism for large `d`);
//! - [`super::Coordinator::run_matrix`] — whole-algorithm jobs for the
//!   Figure 6 / Table 3 experiment matrices.
//!
//! Jobs are `FnOnce`-boxed closures; results come back tagged with their job
//! index so callers reassemble input order regardless of completion order.
//!
//! ## Per-worker scratch arena
//!
//! Every worker owns one [`Scratch`] for its whole life and passes `&mut` to
//! each job it runs. Jobs submitted through [`WorkerPool::map_scratch`] (the
//! sweep engine's grid wave) reuse the worker's warm factor/eval/solve
//! buffers task after task — the steady-state fold×λ sweep allocates
//! nothing per task. The kernel-side half of the arena (packed-GEMM pack
//! panels) is thread-local inside [`crate::linalg::kernel`], which lands on
//! the same per-worker ownership because workers are long-lived threads.
//! Scratch reuse cannot leak state between tasks: every buffer is fully
//! overwritten before use, so determinism is unaffected.
//!
//! ## Panic semantics
//!
//! A panicking job never kills its worker: the worker catches the unwind and
//! moves on to the next job, so the pool stays usable. [`WorkerPool::map`]
//! additionally captures each job's panic payload and re-raises the first
//! one (in input order) on the *calling* thread via
//! `std::panic::resume_unwind`, with the failing **task index prepended to
//! the message** (`"worker task 7 panicked: …"`) — a panic in a sweep task
//! surfaces like a panic in the serial path, but never loses *which* task
//! blew up. (A panic may leave the worker's scratch buffers at odd sizes;
//! that is harmless, the next job resizes them.)
//!
//! [`WorkerPool::map_scratch_recover`] trades re-raising for **bounded
//! retry and quarantine**: jobs are `Fn` closures that can be resubmitted,
//! a job that panics is retried up to a caller-chosen number of times, and
//! one that keeps panicking comes back as an [`Err`]`(`[`TaskFailure`]`)`
//! in its input slot — task index, attempt count and final panic message
//! attached — while every other job's result is unaffected. This is the
//! dispatch surface of the sweep engine's panic-quarantine rung (see
//! [`crate::cv::recovery`]).
//!
//! ## Deadlock rule
//!
//! [`WorkerPool::map`] blocks until every job finishes. Never call it from
//! *inside* a job running on the same pool (all workers could end up blocked
//! waiting on jobs that no worker is free to run). The sweep engine follows
//! this rule by driving intra-factorization parallelism only from the
//! coordinating thread, never from within a pool task.

use crate::linalg::scratch::Scratch;
use std::cell::Cell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce(&mut Scratch) + Send + 'static>;

thread_local! {
    /// Stable pool index of the current worker thread, set once at spawn.
    /// The observability layer keys its per-worker event rings off this
    /// (`usize::MAX` = not a pool worker, i.e. the coordinating thread).
    static WORKER_INDEX: Cell<usize> = Cell::new(usize::MAX);
}

/// The calling thread's pool worker index, or `None` when called from a
/// thread that is not a pool worker (e.g. the coordinating thread).
pub fn worker_index() -> Option<usize> {
    let i = WORKER_INDEX.with(Cell::get);
    (i != usize::MAX).then_some(i)
}

/// Render a caught panic payload as a human-readable message.
///
/// Rust panic payloads are `Box<dyn Any + Send>`; in practice they are a
/// `&'static str` (from `panic!("literal")`) or a `String` (from
/// `panic!("{…}")`). Anything else collapses to a fixed placeholder rather
/// than losing the event entirely.
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// A batch job that kept panicking after its retry budget was spent.
///
/// Returned (per input slot) by [`WorkerPool::map_scratch_recover`] so the
/// caller can quarantine exactly the failing task: `task` is the job's index
/// in the submitted batch, `attempts` counts the initial run plus every
/// retry, and `message` carries the final attempt's panic payload rendered
/// through [`panic_message`].
#[derive(Debug, Clone)]
pub struct TaskFailure {
    /// Index of the job in the submitted batch.
    pub task: usize,
    /// Total executions attempted (1 initial + retries).
    pub attempts: u32,
    /// Panic message of the last failed attempt.
    pub message: String,
}

/// Fixed-size worker pool. Dropping the pool joins all workers.
pub struct WorkerPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `n` workers (at least 1), each owning a [`Scratch`] arena.
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = rx.clone();
                thread::Builder::new()
                    .name(format!("pichol-worker-{i}"))
                    .spawn(move || {
                        WORKER_INDEX.with(|w| w.set(i));
                        let mut scratch = Scratch::new();
                        loop {
                            let job = { rx.lock().unwrap().recv() };
                            match job {
                                // isolate panics so one bad job can't take
                                // the worker (and every queued job) down
                                Ok(job) => {
                                    let _ =
                                        catch_unwind(AssertUnwindSafe(|| job(&mut scratch)));
                                }
                                Err(_) => break, // sender dropped: shut down
                            }
                        }
                    })
                    .expect("spawning worker thread")
            })
            .collect();
        Self {
            tx: Some(tx),
            workers,
        }
    }

    fn send(&self, job: Job) {
        self.tx
            .as_ref()
            .expect("pool already shut down")
            .send(job)
            .expect("worker pool channel closed");
    }

    /// Submit one fire-and-forget job. If it panics, the panic is swallowed
    /// by the worker (use [`WorkerPool::map`] when panics must propagate).
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        self.send(Box::new(move |_scratch| job()));
    }

    /// Submit one fire-and-forget job with access to the worker's
    /// [`Scratch`].
    pub fn submit_with(&self, job: impl FnOnce(&mut Scratch) + Send + 'static) {
        self.send(Box::new(job));
    }

    /// Run a batch of jobs and return their results **in input order**.
    ///
    /// If any job panicked, the first panic (by input index) is re-raised on
    /// the calling thread with its original payload after all jobs have
    /// settled; the pool itself remains usable.
    pub fn map<T: Send + 'static>(
        &self,
        jobs: Vec<Box<dyn FnOnce() -> T + Send + 'static>>,
    ) -> Vec<T> {
        self.map_scratch(
            jobs.into_iter()
                .map(|job| {
                    let f: Box<dyn FnOnce(&mut Scratch) -> T + Send + 'static> =
                        Box::new(move |_scratch| job());
                    f
                })
                .collect(),
        )
    }

    /// [`WorkerPool::map`] for jobs that use the executing worker's
    /// [`Scratch`] arena — the sweep engine's grid tasks run through this so
    /// their factor/eval/solve buffers persist across tasks. Same
    /// input-order results and panic propagation as `map`.
    pub fn map_scratch<T: Send + 'static>(
        &self,
        jobs: Vec<Box<dyn FnOnce(&mut Scratch) -> T + Send + 'static>>,
    ) -> Vec<T> {
        let n = jobs.len();
        let (rtx, rrx) = mpsc::channel::<(usize, thread::Result<T>)>();
        for (i, job) in jobs.into_iter().enumerate() {
            let rtx = rtx.clone();
            self.submit_with(move |scratch| {
                let out = catch_unwind(AssertUnwindSafe(|| job(scratch)));
                // receiver may be gone if the caller panicked; ignore
                let _ = rtx.send((i, out));
            });
        }
        drop(rtx);
        let mut slots: Vec<Option<thread::Result<T>>> = (0..n).map(|_| None).collect();
        for (i, out) in rrx {
            slots[i] = Some(out);
        }
        slots
            .into_iter()
            .enumerate()
            .map(
                |(i, s)| match s.expect("worker died before returning a result") {
                    Ok(v) => v,
                    // re-raise with the task index attached: a panic deep in a
                    // sweep must never lose *which* cell-range task blew up
                    Err(payload) => resume_unwind(Box::new(format!(
                        "worker task {i} panicked: {}",
                        panic_message(payload.as_ref())
                    ))),
                },
            )
            .collect()
    }

    /// [`WorkerPool::map_scratch`] with **bounded retry and quarantine**
    /// instead of panic propagation.
    ///
    /// Jobs are shared `Fn` closures so a panicking task can be resubmitted
    /// verbatim. Each job runs up to `1 + retries` times; a job that panics
    /// on every attempt settles as `Err(`[`TaskFailure`]`)` in its input
    /// slot while all other results are returned normally. Resubmission
    /// rounds process failed indices in ascending order, so scheduling is
    /// deterministic given deterministic jobs.
    pub fn map_scratch_recover<T: Send + 'static>(
        &self,
        jobs: Vec<Arc<dyn Fn(&mut Scratch) -> T + Send + Sync + 'static>>,
        retries: u32,
    ) -> Vec<Result<T, TaskFailure>> {
        let n = jobs.len();
        let mut results: Vec<Option<Result<T, TaskFailure>>> = (0..n).map(|_| None).collect();
        let mut attempts = vec![0u32; n];
        let mut pending: Vec<usize> = (0..n).collect();
        while !pending.is_empty() {
            let (rtx, rrx) = mpsc::channel::<(usize, thread::Result<T>)>();
            for &i in &pending {
                attempts[i] += 1;
                let rtx = rtx.clone();
                let job = Arc::clone(&jobs[i]);
                self.submit_with(move |scratch| {
                    let out = catch_unwind(AssertUnwindSafe(|| job(scratch)));
                    // receiver may be gone if the caller panicked; ignore
                    let _ = rtx.send((i, out));
                });
            }
            drop(rtx);
            let mut failed: Vec<usize> = Vec::new();
            for (i, out) in rrx {
                match out {
                    Ok(v) => results[i] = Some(Ok(v)),
                    Err(payload) if attempts[i] > retries => {
                        results[i] = Some(Err(TaskFailure {
                            task: i,
                            attempts: attempts[i],
                            message: panic_message(payload.as_ref()),
                        }));
                    }
                    Err(_) => failed.push(i),
                }
            }
            failed.sort_unstable();
            pending = failed;
        }
        results
            .into_iter()
            .map(|s| s.expect("worker died before returning a result"))
            .collect()
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the channel, workers drain and exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Pick a worker count: respects `PICHOL_WORKERS`, defaults to available
/// parallelism.
pub fn default_workers() -> usize {
    if let Ok(v) = std::env::var("PICHOL_WORKERS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn map_preserves_order() {
        let pool = WorkerPool::new(4);
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..20)
            .map(|i| {
                let f: Box<dyn FnOnce() -> usize + Send> = Box::new(move || i * i);
                f
            })
            .collect();
        let out = pool.map(jobs);
        assert_eq!(out, (0..20).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn all_jobs_run_once() {
        let pool = WorkerPool::new(3);
        let counter = Arc::new(AtomicUsize::new(0));
        let jobs: Vec<Box<dyn FnOnce() -> () + Send>> = (0..50)
            .map(|_| {
                let c = counter.clone();
                let f: Box<dyn FnOnce() + Send> = Box::new(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
                f
            })
            .collect();
        pool.map(jobs);
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn scratch_persists_across_tasks_on_a_worker() {
        // single worker: a buffer grown by task 1 must arrive warm (same
        // capacity, no reallocation) in task 2
        let pool = WorkerPool::new(1);
        let jobs: Vec<Box<dyn FnOnce(&mut Scratch) -> (usize, usize) + Send>> = (0..4)
            .map(|_| {
                let f: Box<dyn FnOnce(&mut Scratch) -> (usize, usize) + Send> =
                    Box::new(|scratch: &mut Scratch| {
                        let before = scratch.vbuf.capacity();
                        scratch.vbuf.clear();
                        scratch.vbuf.resize(1000, 1.0);
                        (before, scratch.vbuf.capacity())
                    });
                f
            })
            .collect();
        let outs = pool.map_scratch(jobs);
        assert_eq!(outs[0].0, 0, "first task sees a cold arena");
        for (before, after) in &outs[1..] {
            assert!(*before >= 1000, "later tasks must see the warm arena");
            assert_eq!(before, after, "warm arena must not reallocate");
        }
    }

    #[test]
    fn drop_joins_workers() {
        let pool = WorkerPool::new(2);
        pool.submit(|| {});
        drop(pool); // must not hang
    }

    #[test]
    fn size_clamped_to_one() {
        assert_eq!(WorkerPool::new(0).size(), 1);
    }

    #[test]
    fn panic_propagates_with_payload_and_pool_survives() {
        let pool = WorkerPool::new(2);
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = vec![
            Box::new(|| 1),
            Box::new(|| panic!("task exploded")),
            Box::new(|| 3),
        ];
        let caught = catch_unwind(AssertUnwindSafe(|| pool.map(jobs)));
        let payload = caught.expect_err("map must re-raise the worker panic");
        let msg = payload
            .downcast_ref::<&str>()
            .copied()
            .map(str::to_string)
            .or_else(|| payload.downcast_ref::<String>().cloned())
            .expect("panic payload should be a string");
        assert!(msg.contains("task exploded"), "payload: {msg}");
        assert!(
            msg.contains("worker task 1"),
            "re-raise must name the failing task index: {msg}"
        );

        // the pool must still be fully functional afterwards
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> =
            (0..8).map(|i| {
                let f: Box<dyn FnOnce() -> usize + Send> = Box::new(move || i + 100);
                f
            })
            .collect();
        assert_eq!(pool.map(jobs), (100..108).collect::<Vec<_>>());
    }

    #[test]
    fn recover_retries_flaky_and_quarantines_persistent() {
        let pool = WorkerPool::new(2);
        let flaky_calls = Arc::new(AtomicUsize::new(0));
        let fc = flaky_calls.clone();
        let jobs: Vec<Arc<dyn Fn(&mut Scratch) -> usize + Send + Sync>> = vec![
            Arc::new(|_s| 10),
            Arc::new(move |_s| {
                // panics on its first attempt, succeeds on the retry
                if fc.fetch_add(1, Ordering::SeqCst) == 0 {
                    panic!("flaky once");
                }
                11
            }),
            Arc::new(|_s| panic!("always broken")),
            Arc::new(|_s| 13),
        ];
        let out = pool.map_scratch_recover(jobs, 1);
        assert_eq!(out.len(), 4, "every input slot must settle");
        assert_eq!(*out[0].as_ref().unwrap(), 10);
        assert_eq!(*out[1].as_ref().unwrap(), 11, "flaky task must be retried");
        assert_eq!(flaky_calls.load(Ordering::SeqCst), 2);
        let fail = out[2].as_ref().unwrap_err();
        assert_eq!(fail.task, 2, "failure must carry its input index");
        assert_eq!(fail.attempts, 2, "1 initial run + 1 retry");
        assert!(fail.message.contains("always broken"), "{}", fail.message);
        assert_eq!(*out[3].as_ref().unwrap(), 13);

        // the pool must still be fully functional afterwards
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..8)
            .map(|i| {
                let f: Box<dyn FnOnce() -> usize + Send> = Box::new(move || i + 100);
                f
            })
            .collect();
        assert_eq!(pool.map(jobs), (100..108).collect::<Vec<_>>());
    }

    #[test]
    fn recover_with_zero_retries_quarantines_on_first_panic() {
        let pool = WorkerPool::new(1);
        let calls = Arc::new(AtomicUsize::new(0));
        let c = calls.clone();
        let jobs: Vec<Arc<dyn Fn(&mut Scratch) -> u32 + Send + Sync>> = vec![Arc::new(
            move |_s| {
                c.fetch_add(1, Ordering::SeqCst);
                panic!("no second chances");
            },
        )];
        let out = pool.map_scratch_recover(jobs, 0);
        let fail = out[0].as_ref().unwrap_err();
        assert_eq!((fail.task, fail.attempts), (0, 1));
        assert_eq!(calls.load(Ordering::SeqCst), 1, "retries=0 means one run");
    }

    #[test]
    fn recover_preserves_input_order() {
        let pool = WorkerPool::new(4);
        let jobs: Vec<Arc<dyn Fn(&mut Scratch) -> usize + Send + Sync>> = (0..20)
            .map(|i| {
                let f: Arc<dyn Fn(&mut Scratch) -> usize + Send + Sync> =
                    Arc::new(move |_s| i * i);
                f
            })
            .collect();
        let out = pool.map_scratch_recover(jobs, 1);
        let vals: Vec<usize> = out.into_iter().map(|r| r.unwrap()).collect();
        assert_eq!(vals, (0..20).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn worker_index_set_on_workers_and_none_on_caller() {
        assert_eq!(worker_index(), None, "coordinating thread has no index");
        let pool = WorkerPool::new(3);
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..12)
            .map(|_| {
                let f: Box<dyn FnOnce() -> usize + Send> =
                    Box::new(|| worker_index().expect("pool thread must have an index"));
                f
            })
            .collect();
        let out = pool.map(jobs);
        assert!(out.iter().all(|&i| i < 3), "indices within pool size: {out:?}");
    }

    #[test]
    fn submitted_panic_does_not_kill_worker() {
        let pool = WorkerPool::new(1); // single worker: it MUST survive
        pool.submit(|| panic!("fire-and-forget failure"));
        let jobs: Vec<Box<dyn FnOnce() -> u32 + Send>> = vec![Box::new(|| 7)];
        assert_eq!(pool.map(jobs), vec![7]);
    }
}
