//! A small fixed-size worker pool over `std::thread` — the substrate every
//! parallel stage of the coordinator runs on.
//!
//! tokio is not in the offline crate set, and the coordinator's concurrency
//! needs are simple: fan a batch of CPU-bound jobs over N workers and collect
//! results in a deterministic order. Three layers consume this pool:
//!
//! - [`super::sweep_engine`] — fold-prep, anchor-factorization and λ-grid
//!   tasks of the cross-validation sweep (the paper's dominant cost);
//! - [`crate::linalg::cholesky::cholesky_in_place_pooled`] — column-panel
//!   TRSM/SYRK tiles inside one blocked factorization (intra-factorization
//!   parallelism for large `d`);
//! - [`super::Coordinator::run_matrix`] — whole-algorithm jobs for the
//!   Figure 6 / Table 3 experiment matrices.
//!
//! Jobs are `FnOnce`-boxed closures; results come back tagged with their job
//! index so callers reassemble input order regardless of completion order.
//!
//! ## Per-worker scratch arena
//!
//! Every worker owns one [`Scratch`] for its whole life and passes `&mut` to
//! each job it runs. Jobs submitted through [`WorkerPool::map_scratch`] (the
//! sweep engine's grid wave) reuse the worker's warm factor/eval/solve
//! buffers task after task — the steady-state fold×λ sweep allocates
//! nothing per task. The kernel-side half of the arena (packed-GEMM pack
//! panels) is thread-local inside [`crate::linalg::kernel`], which lands on
//! the same per-worker ownership because workers are long-lived threads.
//! Scratch reuse cannot leak state between tasks: every buffer is fully
//! overwritten before use, so determinism is unaffected.
//!
//! ## Panic semantics
//!
//! A panicking job never kills its worker: the worker catches the unwind and
//! moves on to the next job, so the pool stays usable. [`WorkerPool::map`]
//! additionally captures each job's panic payload and re-raises the first
//! one (in input order) on the *calling* thread via
//! `std::panic::resume_unwind`, preserving the original message — a panic in
//! a sweep task therefore surfaces exactly like a panic in the serial path.
//! (A panic may leave the worker's scratch buffers at odd sizes; that is
//! harmless, the next job resizes them.)
//!
//! ## Deadlock rule
//!
//! [`WorkerPool::map`] blocks until every job finishes. Never call it from
//! *inside* a job running on the same pool (all workers could end up blocked
//! waiting on jobs that no worker is free to run). The sweep engine follows
//! this rule by driving intra-factorization parallelism only from the
//! coordinating thread, never from within a pool task.

use crate::linalg::scratch::Scratch;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce(&mut Scratch) + Send + 'static>;

/// Fixed-size worker pool. Dropping the pool joins all workers.
pub struct WorkerPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `n` workers (at least 1), each owning a [`Scratch`] arena.
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = rx.clone();
                thread::Builder::new()
                    .name(format!("pichol-worker-{i}"))
                    .spawn(move || {
                        let mut scratch = Scratch::new();
                        loop {
                            let job = { rx.lock().unwrap().recv() };
                            match job {
                                // isolate panics so one bad job can't take
                                // the worker (and every queued job) down
                                Ok(job) => {
                                    let _ =
                                        catch_unwind(AssertUnwindSafe(|| job(&mut scratch)));
                                }
                                Err(_) => break, // sender dropped: shut down
                            }
                        }
                    })
                    .expect("spawning worker thread")
            })
            .collect();
        Self {
            tx: Some(tx),
            workers,
        }
    }

    fn send(&self, job: Job) {
        self.tx
            .as_ref()
            .expect("pool already shut down")
            .send(job)
            .expect("worker pool channel closed");
    }

    /// Submit one fire-and-forget job. If it panics, the panic is swallowed
    /// by the worker (use [`WorkerPool::map`] when panics must propagate).
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        self.send(Box::new(move |_scratch| job()));
    }

    /// Submit one fire-and-forget job with access to the worker's
    /// [`Scratch`].
    pub fn submit_with(&self, job: impl FnOnce(&mut Scratch) + Send + 'static) {
        self.send(Box::new(job));
    }

    /// Run a batch of jobs and return their results **in input order**.
    ///
    /// If any job panicked, the first panic (by input index) is re-raised on
    /// the calling thread with its original payload after all jobs have
    /// settled; the pool itself remains usable.
    pub fn map<T: Send + 'static>(
        &self,
        jobs: Vec<Box<dyn FnOnce() -> T + Send + 'static>>,
    ) -> Vec<T> {
        self.map_scratch(
            jobs.into_iter()
                .map(|job| {
                    let f: Box<dyn FnOnce(&mut Scratch) -> T + Send + 'static> =
                        Box::new(move |_scratch| job());
                    f
                })
                .collect(),
        )
    }

    /// [`WorkerPool::map`] for jobs that use the executing worker's
    /// [`Scratch`] arena — the sweep engine's grid tasks run through this so
    /// their factor/eval/solve buffers persist across tasks. Same
    /// input-order results and panic propagation as `map`.
    pub fn map_scratch<T: Send + 'static>(
        &self,
        jobs: Vec<Box<dyn FnOnce(&mut Scratch) -> T + Send + 'static>>,
    ) -> Vec<T> {
        let n = jobs.len();
        let (rtx, rrx) = mpsc::channel::<(usize, thread::Result<T>)>();
        for (i, job) in jobs.into_iter().enumerate() {
            let rtx = rtx.clone();
            self.submit_with(move |scratch| {
                let out = catch_unwind(AssertUnwindSafe(|| job(scratch)));
                // receiver may be gone if the caller panicked; ignore
                let _ = rtx.send((i, out));
            });
        }
        drop(rtx);
        let mut slots: Vec<Option<thread::Result<T>>> = (0..n).map(|_| None).collect();
        for (i, out) in rrx {
            slots[i] = Some(out);
        }
        slots
            .into_iter()
            .map(|s| match s.expect("worker died before returning a result") {
                Ok(v) => v,
                Err(payload) => resume_unwind(payload),
            })
            .collect()
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the channel, workers drain and exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Pick a worker count: respects `PICHOL_WORKERS`, defaults to available
/// parallelism.
pub fn default_workers() -> usize {
    if let Ok(v) = std::env::var("PICHOL_WORKERS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn map_preserves_order() {
        let pool = WorkerPool::new(4);
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..20)
            .map(|i| {
                let f: Box<dyn FnOnce() -> usize + Send> = Box::new(move || i * i);
                f
            })
            .collect();
        let out = pool.map(jobs);
        assert_eq!(out, (0..20).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn all_jobs_run_once() {
        let pool = WorkerPool::new(3);
        let counter = Arc::new(AtomicUsize::new(0));
        let jobs: Vec<Box<dyn FnOnce() -> () + Send>> = (0..50)
            .map(|_| {
                let c = counter.clone();
                let f: Box<dyn FnOnce() + Send> = Box::new(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
                f
            })
            .collect();
        pool.map(jobs);
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn scratch_persists_across_tasks_on_a_worker() {
        // single worker: a buffer grown by task 1 must arrive warm (same
        // capacity, no reallocation) in task 2
        let pool = WorkerPool::new(1);
        let jobs: Vec<Box<dyn FnOnce(&mut Scratch) -> (usize, usize) + Send>> = (0..4)
            .map(|_| {
                let f: Box<dyn FnOnce(&mut Scratch) -> (usize, usize) + Send> =
                    Box::new(|scratch: &mut Scratch| {
                        let before = scratch.vbuf.capacity();
                        scratch.vbuf.clear();
                        scratch.vbuf.resize(1000, 1.0);
                        (before, scratch.vbuf.capacity())
                    });
                f
            })
            .collect();
        let outs = pool.map_scratch(jobs);
        assert_eq!(outs[0].0, 0, "first task sees a cold arena");
        for (before, after) in &outs[1..] {
            assert!(*before >= 1000, "later tasks must see the warm arena");
            assert_eq!(before, after, "warm arena must not reallocate");
        }
    }

    #[test]
    fn drop_joins_workers() {
        let pool = WorkerPool::new(2);
        pool.submit(|| {});
        drop(pool); // must not hang
    }

    #[test]
    fn size_clamped_to_one() {
        assert_eq!(WorkerPool::new(0).size(), 1);
    }

    #[test]
    fn panic_propagates_with_payload_and_pool_survives() {
        let pool = WorkerPool::new(2);
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = vec![
            Box::new(|| 1),
            Box::new(|| panic!("task exploded")),
            Box::new(|| 3),
        ];
        let caught = catch_unwind(AssertUnwindSafe(|| pool.map(jobs)));
        let payload = caught.expect_err("map must re-raise the worker panic");
        let msg = payload
            .downcast_ref::<&str>()
            .copied()
            .map(str::to_string)
            .or_else(|| payload.downcast_ref::<String>().cloned())
            .expect("panic payload should be a string");
        assert!(msg.contains("task exploded"), "payload: {msg}");

        // the pool must still be fully functional afterwards
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> =
            (0..8).map(|i| {
                let f: Box<dyn FnOnce() -> usize + Send> = Box::new(move || i + 100);
                f
            })
            .collect();
        assert_eq!(pool.map(jobs), (100..108).collect::<Vec<_>>());
    }

    #[test]
    fn submitted_panic_does_not_kill_worker() {
        let pool = WorkerPool::new(1); // single worker: it MUST survive
        pool.submit(|| panic!("fire-and-forget failure"));
        let jobs: Vec<Box<dyn FnOnce() -> u32 + Send>> = vec![Box::new(|| 7)];
        assert_eq!(pool.map(jobs), vec![7]);
    }
}
