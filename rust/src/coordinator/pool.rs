//! A small fixed-size worker pool over std::thread.
//!
//! tokio is not in the offline crate set, and the coordinator's concurrency
//! needs are simple: fan a batch of CPU-bound jobs (fold × algorithm sweeps)
//! over N workers and collect results in completion order. Jobs are
//! `FnOnce`-boxed closures; results come back tagged with their job index so
//! callers can reassemble deterministic orderings.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool. Dropping the pool joins all workers.
pub struct WorkerPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `n` workers (at least 1).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = rx.clone();
                thread::Builder::new()
                    .name(format!("pichol-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawning worker thread")
            })
            .collect();
        Self {
            tx: Some(tx),
            workers,
        }
    }

    /// Submit one job.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        self.tx
            .as_ref()
            .expect("pool already shut down")
            .send(Box::new(job))
            .expect("worker pool channel closed");
    }

    /// Run a batch of jobs and return their results **in input order**.
    pub fn map<T: Send + 'static>(
        &self,
        jobs: Vec<Box<dyn FnOnce() -> T + Send + 'static>>,
    ) -> Vec<T> {
        let n = jobs.len();
        let (rtx, rrx) = mpsc::channel::<(usize, T)>();
        for (i, job) in jobs.into_iter().enumerate() {
            let rtx = rtx.clone();
            self.submit(move || {
                let out = job();
                // receiver may be gone if the caller panicked; ignore
                let _ = rtx.send((i, out));
            });
        }
        drop(rtx);
        let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for (i, out) in rrx {
            slots[i] = Some(out);
        }
        slots
            .into_iter()
            .map(|s| s.expect("worker died before returning a result"))
            .collect()
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the channel, workers drain and exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Pick a worker count: respects `PICHOL_WORKERS`, defaults to available
/// parallelism (this box: 1).
pub fn default_workers() -> usize {
    if let Ok(v) = std::env::var("PICHOL_WORKERS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn map_preserves_order() {
        let pool = WorkerPool::new(4);
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..20)
            .map(|i| {
                let f: Box<dyn FnOnce() -> usize + Send> = Box::new(move || i * i);
                f
            })
            .collect();
        let out = pool.map(jobs);
        assert_eq!(out, (0..20).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn all_jobs_run_once() {
        let pool = WorkerPool::new(3);
        let counter = Arc::new(AtomicUsize::new(0));
        let jobs: Vec<Box<dyn FnOnce() -> () + Send>> = (0..50)
            .map(|_| {
                let c = counter.clone();
                let f: Box<dyn FnOnce() + Send> = Box::new(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
                f
            })
            .collect();
        pool.map(jobs);
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = WorkerPool::new(2);
        pool.submit(|| {});
        drop(pool); // must not hang
    }

    #[test]
    fn size_clamped_to_one() {
        assert_eq!(WorkerPool::new(0).size(), 1);
    }
}
