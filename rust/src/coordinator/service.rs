//! The long-lived streaming CV coordinator: async admission, epoch-swapped
//! snapshot serving, and the deterministic traffic replay.
//!
//! ## Shape
//!
//! ```text
//!   clients ──admit(batch)──► bounded MPSC queue ──► service worker thread
//!      │                                                   │
//!      │                                     WindowCv (per-row numerics,
//!      │                                      refresh → new Snapshot)
//!      │                                                   │
//!      └──query() ◄── Mutex<Arc<Snapshot>> ◄── epoch swap ─┘
//! ```
//!
//! - **Admission** rides a bounded [`std::sync::mpsc::sync_channel`]:
//!   `queue_depth` batches of backpressure, any number of producer
//!   clients ([`ServiceHandle`] is `Clone`). Rows are validated
//!   client-side ([`gram::validate_rows`]) so a poisoned batch is rejected
//!   synchronously, before it can occupy queue space.
//! - **Serving** is an epoch swap in the `arc-swap` style, built from std
//!   primitives: the worker builds each new [`Snapshot`] entirely off to
//!   the side, then swaps the `Arc` under a mutex held for a pointer
//!   store; readers hold the lock for a pointer clone. Queries therefore
//!   **never block on a window update** — a refresh computes outside the
//!   lock — and a reader holding an old snapshot keeps a fully consistent
//!   view at its stamped epoch.
//! - **Determinism**: the worker splits every admitted batch into single
//!   rows before touching numerics ([`WindowCv::push_row`]), and refresh
//!   points are a pure function of the admitted row sequence — so the
//!   snapshot stream is bitwise identical at any worker count and any
//!   admission batch size (pinned by `tests/service.rs`).
//!
//! ## Observability
//!
//! When armed ([`CvConfig::obs`]), the worker records one `"admit"` span
//! per batch and one `"refresh"` span per rebuild into the PR-9 event
//! rings, and the refresh phases land in per-phase latency histograms.
//! Query spans are captured client-side as `(start, stop)` pairs and
//! appended to the event log at [`CvService::finish`] (the rings are
//! single-producer, so live client threads record into a mutex-guarded
//! side buffer instead). Admission and query latencies additionally feed
//! dedicated histograms — the `service_replay` bench's p50/p99 source —
//! armed or not.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::coordinator::pool::{default_workers, WorkerPool};
use crate::cv::recovery::Degradation;
use crate::cv::window::{ServiceConfig, Snapshot, WindowCv};
use crate::cv::CvConfig;
use crate::data::gram::{self, IngestError};
use crate::data::synthetic::{DatasetKind, SyntheticDataset};
use crate::linalg::matrix::Matrix;
use crate::obs::{Event, Hist, ObsReport, Outcome, RunObs};
use crate::util::PhaseTimer;

/// Why an admission was refused.
#[derive(Debug)]
pub enum AdmitError {
    /// The batch failed ingest validation (client-side, synchronous).
    Ingest(IngestError),
    /// The service worker has shut down.
    Closed,
}

impl std::fmt::Display for AdmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmitError::Ingest(e) => write!(f, "batch rejected at admission: {e}"),
            AdmitError::Closed => write!(f, "service is shut down"),
        }
    }
}

impl std::error::Error for AdmitError {}

/// State shared between the worker and every handle.
struct Shared {
    snapshot: Mutex<Arc<Snapshot>>,
    epoch: AtomicU64,
    admit_hist: Mutex<Hist>,
    query_hist: Mutex<Hist>,
    /// Client-side query spans (µs since the obs epoch), drained into the
    /// event log at finish; `None` when observability is disarmed.
    query_spans: Mutex<Vec<(u64, u64)>>,
    obs: Option<Arc<RunObs>>,
}

/// A cloneable producer/reader handle onto a running [`CvService`].
#[derive(Clone)]
pub struct ServiceHandle {
    tx: SyncSender<(Matrix, Vec<f64>)>,
    shared: Arc<Shared>,
}

impl ServiceHandle {
    /// Admit one row batch. Validates client-side (a bad batch is
    /// rejected *here*, synchronously and without queue space), then
    /// blocks while the bounded queue is full — admission backpressure.
    /// The measured latency (validation + queue wait) feeds the
    /// admission histogram.
    pub fn admit(&self, x: Matrix, y: Vec<f64>) -> Result<(), AdmitError> {
        let t0 = Instant::now();
        gram::validate_rows(&x, &y).map_err(AdmitError::Ingest)?;
        self.tx.send((x, y)).map_err(|_| AdmitError::Closed)?;
        let secs = t0.elapsed().as_secs_f64();
        self.shared
            .admit_hist
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .record_secs(secs);
        Ok(())
    }

    /// Non-blocking admission: `Ok(false)` when the queue is full.
    pub fn try_admit(&self, x: Matrix, y: Vec<f64>) -> Result<bool, AdmitError> {
        gram::validate_rows(&x, &y).map_err(AdmitError::Ingest)?;
        match self.tx.try_send((x, y)) {
            Ok(()) => Ok(true),
            Err(TrySendError::Full(_)) => Ok(false),
            Err(TrySendError::Disconnected(_)) => Err(AdmitError::Closed),
        }
    }

    /// Serve the current snapshot: clone the `Arc` under a
    /// held-for-a-pointer-copy lock. Never waits on a window update —
    /// refreshes are computed off to the side and swapped in. The
    /// measured latency feeds the query histogram (and, when armed, a
    /// query span into the event log at finish).
    pub fn query(&self) -> Arc<Snapshot> {
        let start_us = self.shared.obs.as_ref().map(|o| o.now_us());
        let t0 = Instant::now();
        let snap = Arc::clone(
            &self
                .shared
                .snapshot
                .lock()
                .unwrap_or_else(|e| e.into_inner()),
        );
        let secs = t0.elapsed().as_secs_f64();
        self.shared
            .query_hist
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .record_secs(secs);
        if let (Some(start), Some(obs)) = (start_us, self.shared.obs.as_ref()) {
            self.shared
                .query_spans
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .push((start, obs.now_us()));
        }
        snap
    }

    /// The epoch of the currently served snapshot, lock-free.
    pub fn epoch(&self) -> u64 {
        self.shared.epoch.load(Ordering::Acquire)
    }
}

/// What a finished service run produced — the streaming analogue of
/// `LooReport`/`AloocvReport`, consumed by `pichol serve` and the
/// determinism suite.
pub struct ServiceReport {
    /// The snapshot served at shutdown (after the final drain refresh).
    pub final_snapshot: Arc<Snapshot>,
    /// Every degradation the window recorded, in admission order.
    pub degradations: Vec<Degradation>,
    /// Rows admitted over the service lifetime.
    pub rows_admitted: u64,
    /// Batches admitted over the service lifetime.
    pub batches: u64,
    /// Rows rejected by in-worker validation (client-validated batches
    /// make this 0; direct queue producers can still trip it).
    pub rejected: u64,
    /// Snapshot refreshes performed.
    pub refreshes: u64,
    /// Per-phase timings of every refresh, merged.
    pub timer: PhaseTimer,
    /// Worker-thread wall clock, admission of the first batch to drain.
    pub wall_secs: f64,
    /// Eval pool worker threads.
    pub threads: usize,
    /// Admission latency (validate + queue wait), recorded client-side.
    pub admit_hist: Hist,
    /// Query latency (snapshot clone), recorded client-side.
    pub query_hist: Hist,
    /// Observability payload when the run was armed.
    pub obs: Option<ObsReport>,
}

/// The running service: owns the worker thread. Admission and queries go
/// through [`ServiceHandle`] clones; dropping every handle closes the
/// queue, after which [`CvService::finish`] joins the worker and returns
/// the report.
pub struct CvService {
    worker: std::thread::JoinHandle<WorkerOut>,
    shared: Arc<Shared>,
    threads: usize,
}

struct WorkerOut {
    window: WindowCv,
    timer: PhaseTimer,
    batches: u64,
    rejected: u64,
    refreshes: u64,
    wall_secs: f64,
}

impl CvService {
    /// Start the service worker and hand back the first producer handle.
    /// `cv` supplies the λ grid/anchor plan, recovery policy, and the obs
    /// switch; `svc` the window/queue/tier knobs.
    pub fn start(svc: ServiceConfig, cv: CvConfig) -> (CvService, ServiceHandle) {
        let threads = if svc.workers == 0 {
            default_workers()
        } else {
            svc.workers
        };
        let obs = cv.obs.then(|| {
            // admit + refresh spans from the worker, query spans appended
            // at finish: one ring's worth of capacity each
            RunObs::new(1, 4096)
        });
        let window = WindowCv::new(svc, cv);
        let shared = Arc::new(Shared {
            snapshot: Mutex::new(Arc::new(window.empty_snapshot())),
            epoch: AtomicU64::new(0),
            admit_hist: Mutex::new(Hist::new()),
            query_hist: Mutex::new(Hist::new()),
            query_spans: Mutex::new(Vec::new()),
            obs: obs.clone(),
        });
        let (tx, rx) = sync_channel::<(Matrix, Vec<f64>)>(svc.queue_depth.max(1));
        let worker_shared = Arc::clone(&shared);
        let worker = std::thread::Builder::new()
            .name("pichol-service".into())
            .spawn(move || worker_loop(window, rx, worker_shared, threads, obs))
            .expect("spawn service worker");
        let handle = ServiceHandle {
            tx,
            shared: Arc::clone(&shared),
        };
        (
            CvService {
                worker,
                shared,
                threads,
            },
            handle,
        )
    }

    /// Join the worker (the caller must have dropped every
    /// [`ServiceHandle`] sender first — the queue closing is the shutdown
    /// signal) and assemble the report. Appends the client-side query
    /// spans to the event log: the worker has quiesced, so the
    /// single-producer ring contract holds for this thread.
    pub fn finish(self) -> ServiceReport {
        let out = self.worker.join().expect("service worker panicked");
        let mut timer = out.timer;
        let obs = self.shared.obs.as_ref().map(|o| {
            let spans = std::mem::take(
                &mut *self
                    .shared
                    .query_spans
                    .lock()
                    .unwrap_or_else(|e| e.into_inner()),
            );
            for (start_us, stop_us) in spans {
                o.record(Event {
                    task_id: o.alloc_id(),
                    kind: "query",
                    surface: "service",
                    start_us,
                    stop_us,
                    outcome: Outcome::Ok,
                    ..Event::default()
                });
            }
            ObsReport::from_run(o, timer.take_hists())
        });
        let final_snapshot = Arc::clone(
            &self
                .shared
                .snapshot
                .lock()
                .unwrap_or_else(|e| e.into_inner()),
        );
        let take_hist = |m: &Mutex<Hist>| std::mem::take(&mut *m.lock().unwrap_or_else(|e| e.into_inner()));
        ServiceReport {
            final_snapshot,
            degradations: out.window.degradations,
            rows_admitted: out.window.rows_admitted(),
            batches: out.batches,
            rejected: out.rejected,
            refreshes: out.refreshes,
            timer,
            wall_secs: out.wall_secs,
            threads: self.threads,
            admit_hist: take_hist(&self.shared.admit_hist),
            query_hist: take_hist(&self.shared.query_hist),
            obs,
        }
    }
}

/// The worker loop: drain batches, fold per-row, refresh when the window
/// says so, swap the snapshot. Exits when every sender is dropped, after
/// one final drain refresh so shutdown never discards admitted rows.
fn worker_loop(
    mut window: WindowCv,
    rx: Receiver<(Matrix, Vec<f64>)>,
    shared: Arc<Shared>,
    threads: usize,
    obs: Option<Arc<RunObs>>,
) -> WorkerOut {
    let pool = WorkerPool::new(threads);
    let hists_on = obs.is_some();
    let mut timer = if hists_on {
        PhaseTimer::with_hists()
    } else {
        PhaseTimer::new()
    };
    let mut batches = 0u64;
    let mut rejected = 0u64;
    let mut refreshes = 0u64;
    let t0 = Instant::now();

    let publish = |window: &mut WindowCv,
                   pool: &WorkerPool,
                   timer: &mut PhaseTimer,
                   refreshes: &mut u64| {
        let start = obs.as_ref().map_or(0, |o| o.now_us());
        let snap = Arc::new(window.refresh(pool, timer));
        let degs = window.degradations.len() as u32;
        let epoch = snap.epoch;
        // built off to the side; the lock is held for one pointer store
        *shared.snapshot.lock().unwrap_or_else(|e| e.into_inner()) = snap;
        shared.epoch.store(epoch, Ordering::Release);
        *refreshes += 1;
        if let Some(o) = &obs {
            o.record(Event {
                task_id: o.alloc_id(),
                kind: "refresh",
                surface: "service",
                fold: epoch as i64,
                start_us: start,
                stop_us: o.now_us(),
                outcome: if degs > 0 {
                    Outcome::Degraded
                } else {
                    Outcome::Ok
                },
                degradations: degs,
                ..Event::default()
            });
        }
    };

    while let Ok((x, y)) = rx.recv() {
        let start = obs.as_ref().map_or(0, |o| o.now_us());
        let rows = x.rows();
        let mut bad = 0u64;
        // per-row numerics AND a per-row refresh check: neither the update
        // sequence nor the refresh points may depend on how rows were
        // batched at admission (the bitwise batch-size-invariance contract)
        for r in 0..rows {
            if window.push_row(x.row(r), y[r]).is_err() {
                bad += 1;
            } else if window.needs_refresh() {
                publish(&mut window, &pool, &mut timer, &mut refreshes);
            }
        }
        rejected += bad;
        batches += 1;
        if let Some(o) = &obs {
            o.record(Event {
                task_id: o.alloc_id(),
                kind: "admit",
                surface: "service",
                fold: rows as i64,
                start_us: start,
                stop_us: o.now_us(),
                outcome: if bad > 0 { Outcome::Degraded } else { Outcome::Ok },
                ..Event::default()
            });
        }
    }
    // drain refresh: serve everything admitted before shutdown
    if window.rows_admitted() > 0 {
        publish(&mut window, &pool, &mut timer, &mut refreshes);
    }
    WorkerOut {
        window,
        timer,
        batches,
        rejected,
        refreshes,
        wall_secs: t0.elapsed().as_secs_f64(),
    }
}

/// Knobs of the deterministic traffic replay (the `service_replay` bench
/// stage and `pichol serve`'s driver): a seeded dataset streamed as
/// sustained fixed-size appends with interleaved point queries.
#[derive(Clone, Copy, Debug)]
pub struct ReplayConfig {
    /// Total rows to stream.
    pub rows: usize,
    /// Feature dimension.
    pub dim: usize,
    /// Rows per admitted batch.
    pub batch: usize,
    /// Point queries issued after each admitted batch.
    pub queries_per_batch: usize,
    /// Dataset family and seed — the replay is a pure function of these.
    pub kind: DatasetKind,
    pub seed: u64,
}

impl Default for ReplayConfig {
    fn default() -> Self {
        Self {
            rows: 512,
            dim: 16,
            batch: 8,
            queries_per_batch: 4,
            kind: DatasetKind::MnistLike,
            seed: 42,
        }
    }
}

/// Run the seeded replay: generate the dataset, stream it through a fresh
/// service in `batch`-row admissions from this thread (one producer — the
/// admitted row sequence is the dataset order, independent of timing),
/// issue `queries_per_batch` point queries after each batch, drain, and
/// return the report. The snapshot stream this produces is bitwise
/// identical at any `svc.workers` and any `batch` (pinned by
/// `tests/service.rs`).
pub fn run_replay(replay: ReplayConfig, svc: ServiceConfig, cv: CvConfig) -> ServiceReport {
    let ds = SyntheticDataset::generate(replay.kind, replay.rows, replay.dim, replay.seed);
    let (service, handle) = CvService::start(svc, cv);
    let batch = replay.batch.max(1);
    let mut lo = 0usize;
    while lo < replay.rows {
        let hi = (lo + batch).min(replay.rows);
        let x = ds.x.slice(lo, hi, 0, replay.dim);
        let y = ds.y[lo..hi].to_vec();
        handle
            .admit(x, y)
            .expect("replay batches are pre-validated synthetic data");
        for q in 0..replay.queries_per_batch {
            let snap = handle.query();
            // a deterministic point query against the served model; the
            // value is intentionally unused — the replay measures serving
            let probe = (lo + q) % replay.rows;
            let _ = snap.predict(ds.x.row(probe));
        }
        lo = hi;
    }
    drop(handle);
    service.finish()
}
