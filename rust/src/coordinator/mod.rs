//! Layer-3 coordinator: the process that owns the cross-validation run.
//!
//! The paper's systems contribution is *amortization across the λ sweep*
//! (g exact factorizations serve q ≫ g candidate values); the coordinator is
//! where that shows up operationally:
//!
//! - [`pool`] — a std::thread worker pool, the substrate for every parallel
//!   stage (sweep tasks, matrix jobs, intra-factorization tiles);
//! - [`sweep_engine`] — the batched fold×λ executor every native CV run
//!   routes through ([`SweepPlan`] → [`SweepReport`], anchors-first
//!   scheduling, bit-identical at any thread count);
//! - [`metrics`] — shared counters/timers the engine streams per-task
//!   timings into, snapshotted into reports;
//! - [`hlo_pipeline`] — the AOT request path (gram → cholvec → polyfit →
//!   fused sweep, one PJRT execution per stage, python nowhere in sight);
//! - [`service`] — the streaming variant: a long-lived [`service::CvService`]
//!   admitting row batches over a bounded queue, maintaining a sliding-window
//!   Gram, and serving λ*/θ from epoch-swapped immutable snapshots;
//! - [`Coordinator`] — ties them together: plans folds, schedules work,
//!   aggregates [`crate::cv::CvReport`]s for whole experiment matrices.

pub mod hlo_pipeline;
pub mod metrics;
pub mod pool;
pub mod service;
pub mod sweep_engine;

use std::sync::Arc;

use crate::cv::solvers::SolverKind;
use crate::cv::{aggregate_sweep, run_cv, CvConfig, CvReport};
use crate::data::synthetic::{DatasetKind, SyntheticDataset};
pub use hlo_pipeline::{HloFold, HloPipeline, HloSweepResult};
pub use metrics::Metrics;
pub use pool::WorkerPool;
pub use sweep_engine::{LooPlan, SweepEngine, SweepPlan, SweepReport};

/// The coordinator: worker pool + metrics + (lazily created) PJRT engine.
pub struct Coordinator {
    pool: WorkerPool,
    pub metrics: Arc<Metrics>,
}

impl Default for Coordinator {
    fn default() -> Self {
        Self::new(pool::default_workers())
    }
}

impl Coordinator {
    pub fn new(workers: usize) -> Self {
        Self {
            pool: WorkerPool::new(workers),
            metrics: Arc::new(Metrics::new()),
        }
    }

    pub fn workers(&self) -> usize {
        self.pool.size()
    }

    /// Run one algorithm over one dataset (k-fold, native path), timed.
    /// Routes through the sweep engine, sharing this coordinator's metrics
    /// registry so per-task timings land in `self.metrics`.
    ///
    /// Thread-count precedence: an explicit `cfg.sweep_threads` wins;
    /// `0` (auto) resolves to this coordinator's worker count, so a
    /// `workers = 1` experiment config still bounds total CPU use the way
    /// it did before the engine existed.
    pub fn run_one(
        &self,
        ds: &SyntheticDataset,
        kind: SolverKind,
        cfg: &CvConfig,
    ) -> crate::Result<CvReport> {
        match cfg.mode {
            crate::cv::CvMode::Loo => anyhow::bail!(
                "cfg.mode is 'loo' but run_one executes k-fold sweeps; \
                 call Coordinator::run_loo instead"
            ),
            crate::cv::CvMode::Aloocv => anyhow::bail!(
                "cfg.mode is 'aloocv' but run_one executes k-fold sweeps; \
                 call Coordinator::run_aloocv instead"
            ),
            crate::cv::CvMode::KFold => {}
        }
        self.metrics.incr("cv.runs");
        let mut cfg = cfg.clone();
        if cfg.sweep_threads == 0 {
            cfg.sweep_threads = self.workers();
        }
        let plan = SweepPlan::new(ds, kind, &cfg);
        let rep = self.run_plan(ds, &plan)?;
        self.metrics
            .add("cv.lambda_evals", (rep.grid.len() * cfg.k_folds) as u64);
        Ok(rep)
    }

    /// Run exact leave-one-out CV over one dataset (the factor-update
    /// subsystem's workload — see [`crate::cv::loo`]), wired to this
    /// coordinator's metrics. Thread-count precedence as in
    /// [`Coordinator::run_one`].
    pub fn run_loo(
        &self,
        ds: &SyntheticDataset,
        cfg: &CvConfig,
    ) -> crate::Result<crate::cv::loo::LooReport> {
        self.metrics.incr("cv.loo_runs");
        let mut cfg = cfg.clone();
        if cfg.sweep_threads == 0 {
            cfg.sweep_threads = self.workers();
        }
        let plan = LooPlan::new(ds, &cfg);
        let engine = SweepEngine::with_metrics(plan.threads, self.metrics.clone());
        engine.run_loo(ds, &plan)
    }

    /// Run approximate leave-one-out CV — the cheap tier of the
    /// accuracy/cost ladder (see [`crate::cv::aloocv`]) — wired to this
    /// coordinator's metrics. Thread-count precedence as in
    /// [`Coordinator::run_one`].
    pub fn run_aloocv(
        &self,
        ds: &SyntheticDataset,
        cfg: &CvConfig,
    ) -> crate::Result<crate::cv::aloocv::AloocvReport> {
        self.metrics.incr("cv.aloocv_runs");
        let mut cfg = cfg.clone();
        if cfg.sweep_threads == 0 {
            cfg.sweep_threads = self.workers();
        }
        let plan = LooPlan::new(ds, &cfg);
        let engine = SweepEngine::with_metrics(plan.threads, self.metrics.clone());
        engine.run_aloocv(ds, &plan)
    }

    /// Execute an explicit [`SweepPlan`] on a fresh [`SweepEngine`] wired to
    /// this coordinator's metrics, and aggregate into a [`CvReport`].
    ///
    /// (A fresh engine pool is spawned per plan rather than reusing
    /// `self.pool`: matrix jobs already occupy that pool, and the engine's
    /// blocking waves must never run on the pool they schedule onto.)
    pub fn run_plan(&self, ds: &SyntheticDataset, plan: &SweepPlan) -> crate::Result<CvReport> {
        let engine = SweepEngine::with_metrics(plan.threads, self.metrics.clone());
        Ok(aggregate_sweep(engine.run(ds, plan)?))
    }

    /// Run a full algorithm matrix over one dataset, fanning algorithms
    /// across the worker pool (the Figure 6 / Table 3 workload).
    ///
    /// Matrix jobs already saturate the machine at algorithm granularity, so
    /// each job's inner sweep runs single-threaded unless the caller
    /// explicitly set `sweep_threads` — otherwise every job would spawn a
    /// core-count engine pool, and the contention would distort exactly the
    /// cross-algorithm wall-clock comparisons this method exists to measure.
    pub fn run_matrix(
        &self,
        ds: Arc<SyntheticDataset>,
        kinds: &[SolverKind],
        cfg: &CvConfig,
    ) -> Vec<crate::Result<CvReport>> {
        let mut job_cfg = cfg.clone();
        if job_cfg.sweep_threads == 0 {
            job_cfg.sweep_threads = 1;
        }
        let jobs: Vec<Box<dyn FnOnce() -> crate::Result<CvReport> + Send>> = kinds
            .iter()
            .map(|&kind| {
                let ds = ds.clone();
                let cfg = job_cfg.clone();
                let f: Box<dyn FnOnce() -> crate::Result<CvReport> + Send> =
                    Box::new(move || run_cv(&ds, kind, &cfg));
                f
            })
            .collect();
        self.metrics.add("cv.matrix_jobs", kinds.len() as u64);
        self.pool.map(jobs)
    }

    /// Generate the four paper-style datasets at a working dimension h,
    /// in parallel.
    pub fn generate_datasets(
        &self,
        n: usize,
        h: usize,
        seed: u64,
    ) -> Vec<Arc<SyntheticDataset>> {
        let jobs: Vec<Box<dyn FnOnce() -> Arc<SyntheticDataset> + Send>> = DatasetKind::all()
            .into_iter()
            .map(|kind| {
                let f: Box<dyn FnOnce() -> Arc<SyntheticDataset> + Send> = Box::new(move || {
                    Arc::new(SyntheticDataset::generate(kind, n, h, seed))
                });
                f
            })
            .collect();
        self.pool.map(jobs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_runs_all_algorithms() {
        let coord = Coordinator::new(2);
        let ds = Arc::new(SyntheticDataset::generate(
            DatasetKind::MnistLike,
            120,
            17,
            1,
        ));
        let cfg = CvConfig {
            k_folds: 2,
            q_grid: 7,
            ..CvConfig::default()
        };
        let kinds = [SolverKind::Chol, SolverKind::PiChol, SolverKind::RSvd];
        let reports = coord.run_matrix(ds, &kinds, &cfg);
        assert_eq!(reports.len(), 3);
        for (kind, rep) in kinds.iter().zip(reports) {
            let rep = rep.unwrap();
            assert_eq!(rep.kind, *kind);
            assert!(rep.best_error.is_finite());
        }
        assert_eq!(coord.metrics.counter("cv.matrix_jobs"), 3);
    }

    #[test]
    fn run_plan_streams_task_metrics_into_coordinator() {
        let coord = Coordinator::new(2);
        let ds = SyntheticDataset::generate(DatasetKind::MnistLike, 100, 15, 2);
        let cfg = CvConfig {
            k_folds: 2,
            q_grid: 9,
            sweep_threads: 2,
            ..CvConfig::default()
        };
        let plan = SweepPlan::new(&ds, SolverKind::PiChol, &cfg);
        let rep = coord.run_plan(&ds, &plan).unwrap();
        assert!(rep.best_error.is_finite());
        assert_eq!(coord.metrics.counter("sweep.runs"), 1);
        assert_eq!(coord.metrics.counter("sweep.prep_tasks"), 2);
        assert!(coord.metrics.counter("sweep.grid_tasks") > 0);
    }

    #[test]
    fn generate_datasets_covers_all_kinds() {
        let coord = Coordinator::new(2);
        let ds = coord.generate_datasets(40, 9, 3);
        assert_eq!(ds.len(), 4);
        let names: Vec<_> = ds.iter().map(|d| d.kind.name()).collect();
        assert!(names.contains(&"mnist-like") && names.contains(&"caltech256-like"));
    }
}
