//! Layer-3 coordinator: the process that owns the cross-validation run.
//!
//! The paper's systems contribution is *amortization across the λ sweep*
//! (g exact factorizations serve q ≫ g candidate values); the coordinator is
//! where that shows up operationally:
//!
//! - [`pool`] — a std::thread worker pool fanning fold×algorithm sweeps;
//! - [`metrics`] — shared counters/timers, snapshotted into reports;
//! - [`hlo_pipeline`] — the AOT request path (gram → cholvec → polyfit →
//!   fused sweep, one PJRT execution per stage, python nowhere in sight);
//! - [`Coordinator`] — ties them together: plans folds, schedules work,
//!   aggregates [`crate::cv::CvReport`]s for whole experiment matrices.

pub mod hlo_pipeline;
pub mod metrics;
pub mod pool;

use std::sync::Arc;

use crate::cv::solvers::SolverKind;
use crate::cv::{run_cv, CvConfig, CvReport};
use crate::data::synthetic::{DatasetKind, SyntheticDataset};
pub use hlo_pipeline::{HloFold, HloPipeline, HloSweepResult};
pub use metrics::Metrics;
pub use pool::WorkerPool;

/// The coordinator: worker pool + metrics + (lazily created) PJRT engine.
pub struct Coordinator {
    pool: WorkerPool,
    pub metrics: Arc<Metrics>,
}

impl Default for Coordinator {
    fn default() -> Self {
        Self::new(pool::default_workers())
    }
}

impl Coordinator {
    pub fn new(workers: usize) -> Self {
        Self {
            pool: WorkerPool::new(workers),
            metrics: Arc::new(Metrics::new()),
        }
    }

    pub fn workers(&self) -> usize {
        self.pool.size()
    }

    /// Run one algorithm over one dataset (k-fold, native path), timed.
    pub fn run_one(
        &self,
        ds: &SyntheticDataset,
        kind: SolverKind,
        cfg: &CvConfig,
    ) -> crate::Result<CvReport> {
        self.metrics.incr("cv.runs");
        let rep = run_cv(ds, kind, cfg)?;
        self.metrics
            .add("cv.lambda_evals", (rep.grid.len() * cfg.k_folds) as u64);
        Ok(rep)
    }

    /// Run a full algorithm matrix over one dataset, fanning algorithms
    /// across the worker pool (the Figure 6 / Table 3 workload).
    pub fn run_matrix(
        &self,
        ds: Arc<SyntheticDataset>,
        kinds: &[SolverKind],
        cfg: &CvConfig,
    ) -> Vec<crate::Result<CvReport>> {
        let jobs: Vec<Box<dyn FnOnce() -> crate::Result<CvReport> + Send>> = kinds
            .iter()
            .map(|&kind| {
                let ds = ds.clone();
                let cfg = cfg.clone();
                let f: Box<dyn FnOnce() -> crate::Result<CvReport> + Send> =
                    Box::new(move || run_cv(&ds, kind, &cfg));
                f
            })
            .collect();
        self.metrics.add("cv.matrix_jobs", kinds.len() as u64);
        self.pool.map(jobs)
    }

    /// Generate the four paper-style datasets at a working dimension h,
    /// in parallel.
    pub fn generate_datasets(
        &self,
        n: usize,
        h: usize,
        seed: u64,
    ) -> Vec<Arc<SyntheticDataset>> {
        let jobs: Vec<Box<dyn FnOnce() -> Arc<SyntheticDataset> + Send>> = DatasetKind::all()
            .into_iter()
            .map(|kind| {
                let f: Box<dyn FnOnce() -> Arc<SyntheticDataset> + Send> = Box::new(move || {
                    Arc::new(SyntheticDataset::generate(kind, n, h, seed))
                });
                f
            })
            .collect();
        self.pool.map(jobs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_runs_all_algorithms() {
        let coord = Coordinator::new(2);
        let ds = Arc::new(SyntheticDataset::generate(
            DatasetKind::MnistLike,
            120,
            17,
            1,
        ));
        let cfg = CvConfig {
            k_folds: 2,
            q_grid: 7,
            ..CvConfig::default()
        };
        let kinds = [SolverKind::Chol, SolverKind::PiChol, SolverKind::RSvd];
        let reports = coord.run_matrix(ds, &kinds, &cfg);
        assert_eq!(reports.len(), 3);
        for (kind, rep) in kinds.iter().zip(reports) {
            let rep = rep.unwrap();
            assert_eq!(rep.kind, *kind);
            assert!(rep.best_error.is_finite());
        }
        assert_eq!(coord.metrics.counter("cv.matrix_jobs"), 3);
    }

    #[test]
    fn generate_datasets_covers_all_kinds() {
        let coord = Coordinator::new(2);
        let ds = coord.generate_datasets(40, 9, 3);
        assert_eq!(ds.len(), 4);
        let names: Vec<_> = ds.iter().map(|d| d.kind.name()).collect();
        assert!(names.contains(&"mnist-like") && names.contains(&"caltech256-like"));
    }
}
