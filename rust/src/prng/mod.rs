//! Deterministic pseudo-random number generation.
//!
//! The offline crate set has no `rand`, so the crate carries its own
//! generators: [`SplitMix64`] for seeding and [`Xoshiro256`]
//! (xoshiro256++, Blackman & Vigna) as the workhorse. Normal deviates come
//! from the polar Box–Muller transform. Everything is seedable and
//! reproducible across runs — dataset synthesis, randomized SVD and the
//! property-test harness all flow through here.

/// SplitMix64 — tiny, fast, full-period 2⁶⁴ generator used to expand a single
/// `u64` seed into the 256-bit xoshiro state (the construction its authors
/// recommend).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — the crate's default generator: 256-bit state, period
/// 2²⁵⁶−1, passes BigCrush; `++` output scrambler.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 expansion so nearby seeds give unrelated streams.
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53 bits of mantissa.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire rejection).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi;
            }
        }
    }

    /// Standard normal via polar Box–Muller (discards the second deviate for
    /// statelessness; the extra uniform draws are immaterial here).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.uniform() - 1.0;
            let v = 2.0 * self.uniform() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Normal with given mean / standard deviation.
    #[inline]
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Rademacher ±1 (used by the Kar–Karnick feature maps).
    #[inline]
    pub fn rademacher(&mut self) -> f64 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Fill a slice with standard normals.
    pub fn fill_normal(&mut self, xs: &mut [f64]) {
        for x in xs {
            *x = self.normal();
        }
    }

    /// Geometric-ish draw from a categorical distribution given cumulative
    /// weights (used by the mixture generators).
    pub fn categorical(&mut self, cumw: &[f64]) -> usize {
        let u = self.uniform() * cumw.last().copied().unwrap_or(1.0);
        cumw.iter().position(|&c| u < c).unwrap_or(cumw.len() - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Reference stream for seed 1234567 (from the public-domain C impl).
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // determinism
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(a, sm2.next_u64());
        assert_eq!(b, sm2.next_u64());
    }

    #[test]
    fn xoshiro_deterministic_and_distinct_streams() {
        let mut a = Xoshiro256::seed_from(1);
        let mut b = Xoshiro256::seed_from(1);
        let mut c = Xoshiro256::seed_from(2);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let zs: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(xs, ys);
        assert_ne!(xs, zs);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Xoshiro256::seed_from(42);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Xoshiro256::seed_from(7);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_is_unbiased_ish() {
        let mut r = Xoshiro256::seed_from(3);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn permutation_is_a_permutation() {
        let mut r = Xoshiro256::seed_from(9);
        let p = r.permutation(100);
        let mut seen = vec![false; 100];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn rademacher_is_pm_one() {
        let mut r = Xoshiro256::seed_from(11);
        let mut plus = 0;
        for _ in 0..10_000 {
            let v = r.rademacher();
            assert!(v == 1.0 || v == -1.0);
            if v > 0.0 {
                plus += 1;
            }
        }
        assert!((plus as f64 - 5_000.0).abs() < 300.0);
    }
}
