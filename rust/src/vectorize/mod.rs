//! Triangular-factor vectorization strategies (paper §5, Table 1).
//!
//! Algorithm 1 needs each Cholesky factor `Lˢ` flattened into row s of the
//! g×D target matrix T so the fit/interp steps run at BLAS-3 granularity.
//! How the flattening is done controls two costs:
//!
//! 1. the *vec* cost — memory-copy pattern (contiguity, copy count, alignment)
//! 2. the *fit/interp* cost — the vector length D the polynomial machinery
//!    must chew through (the triangle has h(h+1)/2 entries; a full-matrix
//!    dump has h², i.e. ~2× redundant work downstream)
//!
//! The three strategies of the paper:
//!
//! - [`rowwise::RowWise`] — concatenate the triangle row by row: minimal D
//!   but h separate copies of wildly varying length (1…h), the worst-case
//!   pattern for copy engines;
//! - [`fullmatrix::FullMatrix`] — one h² memcpy: a single aligned copy but D
//!   doubles, so lines 5–6 of Algorithm 1 and every interpolation pay 2×;
//! - [`recursive::Recursive`] — the paper's contribution: divide-and-conquer
//!   partition (eq. 10) into one *square* block (copied with full-matrix
//!   alignment, no redundancy) and two half-size triangles recursed until a
//!   base size h₀, which is flattened row-wise. Aligned copies *and*
//!   minimal D.
//!
//! All strategies are exact bijections between factors and vectors; the
//! property tests verify `unvec(vec(L)) = L` for every strategy and shape.

pub mod fullmatrix;
pub mod recursive;
pub mod rowwise;

use crate::linalg::matrix::Matrix;

pub use fullmatrix::FullMatrix;
pub use recursive::Recursive;
pub use rowwise::RowWise;

/// Number of entries in an h×h lower triangle (the paper's D).
pub fn tri_d(h: usize) -> usize {
    h * (h + 1) / 2
}

/// A bijection between lower-triangular h×h factors and flat vectors.
pub trait VecStrategy: Send + Sync {
    /// Human-readable strategy name (Table 1 column group).
    fn name(&self) -> &'static str;

    /// Length of the vectorized form for dimension h.
    fn dim(&self, h: usize) -> usize;

    /// Flatten the lower triangle of `l` into `out` (`out.len() == dim(h)`).
    fn vec_into(&self, l: &Matrix, out: &mut [f64]);

    /// Inverse: rebuild the lower-triangular factor from its vector form.
    fn unvec(&self, v: &[f64], h: usize) -> Matrix {
        let mut out = Matrix::zeros(0, 0);
        self.unvec_into(v, h, &mut out);
        out
    }

    /// Inverse into a caller-provided matrix: `out` is reshaped to `h×h` and
    /// **fully overwritten** (zeros included), reusing its allocation — the
    /// sweep engine's grid tasks rebuild factors into their worker's
    /// [`crate::linalg::scratch::Scratch`] with zero heap traffic. Bitwise
    /// identical to [`VecStrategy::unvec`].
    fn unvec_into(&self, v: &[f64], h: usize, out: &mut Matrix);

    /// Convenience allocating wrapper around [`VecStrategy::vec_into`].
    fn vec(&self, l: &Matrix) -> Vec<f64> {
        let mut out = vec![0.0; self.dim(l.rows())];
        self.vec_into(l, &mut out);
        out
    }
}

/// Flatten g factors into a g×D target matrix T (Algorithm 1 line 2).
pub fn build_target_matrix(strategy: &dyn VecStrategy, factors: &[Matrix]) -> Matrix {
    assert!(!factors.is_empty());
    let h = factors[0].rows();
    let d = strategy.dim(h);
    let mut t = Matrix::zeros(factors.len(), d);
    for (s, l) in factors.iter().enumerate() {
        assert_eq!(l.rows(), h, "factor dimension mismatch");
        strategy.vec_into(l, t.row_mut(s));
    }
    t
}

/// All three strategies, for Table 1 sweeps.
pub fn all_strategies() -> Vec<Box<dyn VecStrategy>> {
    vec![
        Box::new(RowWise),
        Box::new(FullMatrix),
        Box::new(Recursive::default()),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{proptest_lite, random_lower_factor};

    #[test]
    fn dims() {
        assert_eq!(tri_d(4), 10);
        assert_eq!(RowWise.dim(4), 10);
        assert_eq!(FullMatrix.dim(4), 16);
        assert_eq!(Recursive::default().dim(4), 10);
    }

    #[test]
    fn unvec_into_dirty_buffer_matches_unvec_bitwise() {
        // reuse must fully overwrite: seed the target with a larger, dirty
        // factor first and require bit-equality with a fresh unvec
        for h in [1, 2, 5, 17, 64, 65] {
            let l = random_lower_factor(h, 0xD1B + h as u64);
            for s in all_strategies() {
                let v = s.vec(&l);
                let fresh = s.unvec(&v, h);
                let mut out = random_lower_factor(h + 13, 0xBAD);
                s.unvec_into(&v, h, &mut out);
                assert_eq!((out.rows(), out.cols()), (h, h));
                // slice equality is NaN-propagating (max_abs_diff is not)
                assert_eq!(out.as_slice(), fresh.as_slice(), "{} h={h}", s.name());
            }
        }
    }

    #[test]
    fn roundtrip_all_strategies_property() {
        proptest_lite::check("vec-unvec roundtrip", 40, |c| {
            let h = c.dim(1, 97);
            let l = random_lower_factor(h, 0xAB00 + c.index as u64);
            for s in all_strategies() {
                let v = s.vec(&l);
                assert_eq!(v.len(), s.dim(h), "{} dim", s.name());
                let back = s.unvec(&v, h);
                assert!(
                    back.max_abs_diff(&l) == 0.0,
                    "{} roundtrip not exact at h={h}",
                    s.name()
                );
            }
        });
    }

    #[test]
    fn strategies_are_permutations_of_each_other() {
        // same multiset of entries regardless of ordering strategy
        let l = random_lower_factor(13, 5);
        let mut a = RowWise.vec(&l);
        let mut b = Recursive::default().vec(&l);
        assert_eq!(a.len(), b.len());
        a.sort_by(|x, y| x.partial_cmp(y).unwrap());
        b.sort_by(|x, y| x.partial_cmp(y).unwrap());
        assert_eq!(a, b);
    }

    #[test]
    fn build_target_matrix_rows() {
        let ls: Vec<Matrix> = (0..3).map(|s| random_lower_factor(8, s)).collect();
        let t = build_target_matrix(&RowWise, &ls);
        assert_eq!(t.rows(), 3);
        assert_eq!(t.cols(), tri_d(8));
        for (s, l) in ls.iter().enumerate() {
            assert_eq!(t.row(s), RowWise.vec(l).as_slice());
        }
    }
}
