//! Row-wise vectorization: the naive baseline of Table 1.
//!
//! Concatenates the lower-triangular rows `L[0][..1], L[1][..2], …,
//! L[h-1][..h]`. Minimal output length D = h(h+1)/2, but the copy loop
//! issues h separate copies whose lengths ramp from 1 to h — short copies
//! amortize nothing, and in the paper's column-major setting they are also
//! non-contiguous. This is also the canonical ordering of the HLO
//! interchange (matches `jnp.tril_indices` row-major order in
//! `python/compile/kernels/ref.py::vec_tri_ref`).

use super::{tri_d, VecStrategy};
use crate::linalg::matrix::Matrix;

/// Row-by-row triangle flattening.
pub struct RowWise;

impl VecStrategy for RowWise {
    fn name(&self) -> &'static str {
        "row-wise"
    }

    fn dim(&self, h: usize) -> usize {
        tri_d(h)
    }

    fn vec_into(&self, l: &Matrix, out: &mut [f64]) {
        let h = l.rows();
        debug_assert_eq!(out.len(), tri_d(h));
        let mut off = 0;
        for i in 0..h {
            let take = i + 1;
            out[off..off + take].copy_from_slice(&l.row(i)[..take]);
            off += take;
        }
    }

    fn unvec_into(&self, v: &[f64], h: usize, out: &mut Matrix) {
        assert_eq!(v.len(), tri_d(h));
        out.reset_zeroed(h, h);
        let mut off = 0;
        for i in 0..h {
            let take = i + 1;
            out.row_mut(i)[..take].copy_from_slice(&v[off..off + take]);
            off += take;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordering_matches_tril_indices() {
        // The canonical interchange ordering: (0,0),(1,0),(1,1),(2,0),…
        let l = Matrix::from_fn(3, 3, |i, j| if j <= i { (i * 3 + j) as f64 } else { 0.0 });
        let v = RowWise.vec(&l);
        assert_eq!(v, vec![0.0, 3.0, 4.0, 6.0, 7.0, 8.0]);
    }

    #[test]
    fn h1_edge_case() {
        let l = Matrix::from_vec(1, 1, vec![2.5]);
        let v = RowWise.vec(&l);
        assert_eq!(v, vec![2.5]);
        assert_eq!(RowWise.unvec(&v, 1)[(0, 0)], 2.5);
    }
}
