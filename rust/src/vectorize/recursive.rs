//! Recursive divide-and-conquer vectorization — the paper's §5 contribution
//! (Figure 5, eq. 10).
//!
//! Partition the h×h lower triangle at h₂ = ⌊h/2⌋ into
//!
//! ```text
//!   L11 = L[0..h2,  0..h2]   (lower triangle, recurse)
//!   L12 = L[h2..h,  0..h2]   (dense square block — full-matrix copy)
//!   L22 = L[h2..h,  h2..h]   (lower triangle, recurse)
//! ```
//!
//! and emit `[vec(L12), vec_rec(L11), vec_rec(L22)]` (the square block first,
//! matching the paper's concatenation order). The square blocks are copied
//! row-by-row as long aligned runs — the memcpy profile of full-matrix — while
//! the output length stays at the minimal D = h(h+1)/2. Recursion stops at
//! the threshold h₀, below which a row-wise flattening of the small triangle
//! is cheap ("for a sufficiently small h₀ is not expensive").
//!
//! The layout is a pure function of (h, h₀), so `unvec` replays the same
//! recursion to invert it. The strategy works for any h (not just powers of
//! two): odd splits simply produce uneven halves.

use super::{tri_d, VecStrategy};
use crate::linalg::matrix::Matrix;

/// Recursive block vectorization with base-case threshold `h0`.
pub struct Recursive {
    /// Triangle size at which to fall back to row-wise copying.
    pub h0: usize,
}

impl Default for Recursive {
    fn default() -> Self {
        // Table 1's sweet spot: big enough to amortize recursion overhead,
        // small enough that base-case row-wise copies stay cache-resident.
        Self { h0: 64 }
    }
}

impl Recursive {
    pub fn with_base(h0: usize) -> Self {
        assert!(h0 >= 1);
        Self { h0 }
    }

    /// Recursive vec of the triangle at (r0, c0) with size n; returns the new
    /// write offset.
    fn vec_rec(&self, l: &Matrix, r0: usize, n: usize, out: &mut [f64], mut off: usize) -> usize {
        if n == 0 {
            return off;
        }
        if n <= self.h0 {
            // base case: row-wise over the small triangle
            for i in 0..n {
                let take = i + 1;
                out[off..off + take].copy_from_slice(&l.row(r0 + i)[r0..r0 + take]);
                off += take;
            }
            return off;
        }
        let h2 = n / 2;
        // square block L12 = rows r0+h2 .. r0+n, cols r0 .. r0+h2 — each row
        // is one long contiguous copy (the alignment win)
        for i in h2..n {
            out[off..off + h2].copy_from_slice(&l.row(r0 + i)[r0..r0 + h2]);
            off += h2;
        }
        off = self.vec_rec(l, r0, h2, out, off);
        self.vec_rec(l, r0 + h2, n - h2, out, off)
    }

    /// Inverse recursion.
    fn unvec_rec(&self, v: &[f64], l: &mut Matrix, r0: usize, n: usize, mut off: usize) -> usize {
        if n == 0 {
            return off;
        }
        if n <= self.h0 {
            for i in 0..n {
                let take = i + 1;
                l.row_mut(r0 + i)[r0..r0 + take].copy_from_slice(&v[off..off + take]);
                off += take;
            }
            return off;
        }
        let h2 = n / 2;
        for i in h2..n {
            l.row_mut(r0 + i)[r0..r0 + h2].copy_from_slice(&v[off..off + h2]);
            off += h2;
        }
        off = self.unvec_rec(v, l, r0, h2, off);
        self.unvec_rec(v, l, r0 + h2, n - h2, off)
    }
}

impl VecStrategy for Recursive {
    fn name(&self) -> &'static str {
        "recursive"
    }

    fn dim(&self, h: usize) -> usize {
        tri_d(h)
    }

    fn vec_into(&self, l: &Matrix, out: &mut [f64]) {
        let h = l.rows();
        debug_assert_eq!(out.len(), tri_d(h));
        let end = self.vec_rec(l, 0, h, out, 0);
        debug_assert_eq!(end, tri_d(h));
    }

    fn unvec_into(&self, v: &[f64], h: usize, out: &mut Matrix) {
        assert_eq!(v.len(), tri_d(h));
        out.reset_zeroed(h, h);
        let end = self.unvec_rec(v, out, 0, h, 0);
        debug_assert_eq!(end, tri_d(h));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{proptest_lite, random_lower_factor};

    #[test]
    fn matches_paper_partition_order_h4() {
        // h=4, h0=1: split at 2 → square block rows 2,3 cols 0,1 first,
        // then L11 (rows 0,1) recursed, then L22 (rows 2,3) recursed.
        let l = Matrix::from_fn(4, 4, |i, j| if j <= i { (i * 4 + j) as f64 } else { 0.0 });
        let v = Recursive::with_base(1).vec(&l);
        assert_eq!(
            v,
            vec![
                8.0, 9.0, 12.0, 13.0, // L12 square (rows 2-3 × cols 0-1)
                4.0, 0.0, 5.0, // L11 triangle: square [4] first, then [0], [5]
                14.0, 10.0, 15.0 // L22 triangle at (2,2): square [14], then [10], [15]
            ]
        );
    }

    #[test]
    fn base_case_equals_rowwise() {
        let l = random_lower_factor(16, 1);
        let big_base = Recursive::with_base(16).vec(&l);
        let rw = super::super::RowWise.vec(&l);
        assert_eq!(big_base, rw);
    }

    #[test]
    fn roundtrip_across_bases_and_sizes_property() {
        proptest_lite::check("recursive roundtrip (h0 sweep)", 30, |c| {
            let h = c.dim(1, 130);
            let h0 = c.dim(1, 32);
            let l = random_lower_factor(h, 0xEC0 + c.index as u64);
            let s = Recursive::with_base(h0);
            let back = s.unvec(&s.vec(&l), h);
            assert!(back.max_abs_diff(&l) == 0.0, "h={h} h0={h0}");
        });
    }

    #[test]
    fn odd_and_power_of_two_sizes() {
        for h in [1usize, 2, 3, 7, 8, 15, 16, 17, 31, 33, 64, 100] {
            let l = random_lower_factor(h, h as u64);
            let s = Recursive::default();
            assert_eq!(s.vec(&l).len(), tri_d(h));
            assert!(s.unvec(&s.vec(&l), h).max_abs_diff(&l) == 0.0, "h={h}");
        }
    }
}
