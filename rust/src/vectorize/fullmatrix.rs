//! Full-matrix vectorization: the other Table 1 baseline.
//!
//! Dumps the entire h×h buffer in one perfectly aligned memcpy — the fastest
//! possible *vec* step — but D becomes h² instead of h(h+1)/2, so every
//! downstream polynomial fit and interpolation does ~2× the work ("would
//! increase the number of interpolations by a factor of 2", §5). The zeros
//! above the diagonal are fitted as (exactly zero) polynomials.

use super::VecStrategy;
use crate::linalg::matrix::Matrix;

/// Whole-buffer flattening, upper-triangle zeros included.
pub struct FullMatrix;

impl VecStrategy for FullMatrix {
    fn name(&self) -> &'static str {
        "full-matrix"
    }

    fn dim(&self, h: usize) -> usize {
        h * h
    }

    fn vec_into(&self, l: &Matrix, out: &mut [f64]) {
        debug_assert_eq!(out.len(), l.rows() * l.cols());
        out.copy_from_slice(l.as_slice());
    }

    fn unvec_into(&self, v: &[f64], h: usize, out: &mut Matrix) {
        assert_eq!(v.len(), h * h);
        out.reset_from_slice(h, h, v);
        // the interpolated upper triangle is numerically ~0 but may carry
        // roundoff from the fit; clamp it to keep the factor triangular
        out.zero_upper();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::random_lower_factor;

    #[test]
    fn single_copy_layout() {
        let l = random_lower_factor(5, 1);
        let v = FullMatrix.vec(&l);
        assert_eq!(v, l.as_slice());
    }

    #[test]
    fn unvec_clamps_upper_noise() {
        let mut v = vec![0.0; 9];
        v[0] = 1.0;
        v[4] = 1.0;
        v[8] = 1.0;
        v[1] = 1e-9; // roundoff noise above the diagonal
        let m = FullMatrix.unvec(&v, 3);
        assert_eq!(m[(0, 1)], 0.0);
        assert_eq!(m[(0, 0)], 1.0);
    }
}
