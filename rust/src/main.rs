//! `pichol` — the leader binary: CLI over the coordinator, the native and
//! HLO cross-validation pipelines, and the experiment suite.

use anyhow::{bail, Result};
use std::sync::Arc;

use picholesky::cli::{Args, USAGE};
use picholesky::config::{parse_dataset, ExperimentConfig};
use picholesky::coordinator::{Coordinator, HloFold, HloPipeline};
use picholesky::cv::solvers::SolverKind;
use picholesky::cv::{CvConfig, CvMode, FoldStrategy};
use picholesky::data::synthetic::{DatasetKind, SyntheticDataset};
use picholesky::experiments;
use picholesky::runtime::Engine;
use picholesky::util::fmt_secs;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.command.as_str() {
        "cv" => cmd_cv(&args),
        "serve" => cmd_serve(&args),
        "compare" => cmd_compare(&args),
        "hlo" => cmd_hlo(&args),
        "experiments" => cmd_experiments(&args),
        "bound" => cmd_bound(&args),
        "info" => cmd_info(&args),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
}

/// Assemble an ExperimentConfig from `--config` file + flag overrides.
fn experiment_config(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = match args.flag("config") {
        Some(path) => ExperimentConfig::from_file(path)?,
        None => ExperimentConfig::default(),
    };
    if let Some(ds) = args.flag("dataset") {
        cfg.dataset = parse_dataset(ds)?;
    }
    cfg.h = args.usize_flag("h", cfg.h)?;
    cfg.n = args.usize_flag("n", cfg.n)?;
    cfg.seed = args.usize_flag("seed", cfg.seed as usize)? as u64;
    cfg.cv.k_folds = args.usize_flag("folds", cfg.cv.k_folds)?;
    cfg.cv.q_grid = args.usize_flag("grid", cfg.cv.q_grid)?;
    cfg.cv.g_samples = args.usize_flag("g", cfg.cv.g_samples)?;
    cfg.cv.degree = args.usize_flag("degree", cfg.cv.degree)?;
    cfg.cv.sweep_threads = args.usize_flag("threads", cfg.cv.sweep_threads)?;
    cfg.cv.sweep_batch = args.usize_flag("batch", cfg.cv.sweep_batch)?;
    cfg.cv.chunk_rows = args.usize_flag("chunk-rows", cfg.cv.chunk_rows)?;
    // numerical-trust knobs (drift budget + escalation ladder, see
    // cv::recovery); validated with everything else below
    cfg.cv.recovery.budget.max_relative_drift =
        args.f64_flag("trust-budget", cfg.cv.recovery.budget.max_relative_drift)?;
    cfg.cv.recovery.budget.max_hops =
        args.usize_flag("trust-max-hops", cfg.cv.recovery.budget.max_hops as usize)? as u64;
    cfg.cv.recovery.max_shift_retries = args
        .usize_flag("trust-shift-retries", cfg.cv.recovery.max_shift_retries as usize)?
        as u32;
    cfg.cv.recovery.shift_growth =
        args.f64_flag("trust-shift-growth", cfg.cv.recovery.shift_growth)?;
    cfg.cv.recovery.task_retries =
        args.usize_flag("trust-task-retries", cfg.cv.recovery.task_retries as usize)? as u32;
    if let Some(mode) = args.flag("mode") {
        cfg.cv.mode = CvMode::parse(mode)
            .ok_or_else(|| anyhow::anyhow!("unknown --mode '{mode}' (kfold | loo | aloocv)"))?;
    }
    if let Some(fs) = args.flag("fold-strategy") {
        cfg.cv.fold_strategy = FoldStrategy::parse(fs).ok_or_else(|| {
            anyhow::anyhow!("unknown --fold-strategy '{fs}' (refactor | downdate | auto)")
        })?;
    }
    cfg.cv.seed = cfg.seed;
    if let Some(dir) = args.flag("artifacts") {
        cfg.artifacts_dir = dir.to_string();
    }
    // observability: --obs arms the event/histogram layer; either artifact
    // flag implies it (writing the artifact is the point of asking for it)
    if args.switch("obs") {
        cfg.cv.obs = true;
    }
    if let Some(p) = args.flag("trace-out") {
        cfg.trace_out = Some(p.to_string());
    }
    if let Some(p) = args.flag("ledger-out") {
        cfg.ledger_out = Some(p.to_string());
    }
    if cfg.trace_out.is_some() || cfg.ledger_out.is_some() {
        cfg.cv.obs = true;
    }
    cfg.validate()?;
    Ok(cfg)
}

/// Print per-phase latency quantiles and write the `--trace-out` /
/// `--ledger-out` artifacts for a finished observable run.
fn emit_obs(cfg: &ExperimentConfig, run: &picholesky::obs::ledger::LedgerRun) -> Result<()> {
    let fmt_q = |q: Option<f64>| match q {
        Some(us) => format!("{us:.0}"),
        None => "-".to_string(),
    };
    if !run.obs.phase_hists.is_empty() {
        println!("  latency quantiles (µs):");
        for (name, h) in run.obs.phase_hists.entries() {
            println!(
                "    {name:<12} p50={} p90={} p99={}  n={}",
                fmt_q(h.quantile_us(0.50)),
                fmt_q(h.quantile_us(0.90)),
                fmt_q(h.quantile_us(0.99)),
                h.count()
            );
        }
    }
    if let Some(path) = &cfg.ledger_out {
        picholesky::obs::ledger::write_ledger(path, run)?;
        println!(
            "  ledger → {path} ({} events, {} dropped)",
            run.obs.events.len(),
            run.obs.dropped
        );
    }
    if let Some(path) = &cfg.trace_out {
        picholesky::obs::trace::write_chrome_trace(path, &run.obs.events)?;
        println!("  trace  → {path}  (open in chrome://tracing or Perfetto)");
    }
    Ok(())
}

fn cmd_cv(args: &Args) -> Result<()> {
    let cfg = experiment_config(args)?;
    let solver = SolverKind::parse(args.flag("solver").unwrap_or("pichol"))
        .ok_or_else(|| anyhow::anyhow!("unknown --solver"))?;
    let coord = Coordinator::new(cfg.workers.max(1));
    if cfg.cv.mode == CvMode::Aloocv {
        // approximate LOO: hat diagonals through the packed multi-RHS TRSM;
        // the solver flag does not apply — every solve is Hessian-exact
        println!(
            "dataset={} n={} h={} mode=aloocv anchors={} grid={}",
            cfg.dataset.name(),
            cfg.n,
            cfg.h,
            cfg.cv.g_samples,
            cfg.cv.q_grid
        );
        let ds = SyntheticDataset::generate(cfg.dataset, cfg.n, cfg.h, cfg.seed);
        let rep = if args.switch("certify") {
            // re-run the exact-LOO tier and stamp the agreement verdict
            picholesky::cv::aloocv::run_certified(&ds, &cfg.cv)?
        } else {
            coord.run_aloocv(&ds, &cfg.cv)?
        };
        println!(
            "λ* = {:.4e}   ALOO-RMSE = {:.4}   wall = {}   skipped = {}/{}",
            rep.best_lambda,
            rep.best_error,
            fmt_secs(rep.wall_secs),
            rep.skipped.len(),
            rep.n * rep.anchor_lambdas.len()
        );
        if let Some(cert) = &rep.certification {
            println!(
                "  certification: ALOO λ* = {:.4e} vs exact-LOO λ* = {:.4e} ({:.3} decades apart) → {}",
                cert.aloo_lambda,
                cert.loo_lambda,
                cert.decades,
                if cert.certified { "certified" } else { "NOT CERTIFIED" }
            );
        }
        if !rep.degradations.is_empty() {
            println!(
                "  {} cell(s) served past the hat-diagonal fast path:",
                rep.degradations.len()
            );
            for d in &rep.degradations {
                println!("    {d}");
            }
        }
        for (lam, rmse) in rep.anchor_lambdas.iter().zip(&rep.anchor_rmse) {
            println!("  anchor λ = {lam:.4e}   ALOO-RMSE = {rmse:.4}");
        }
        for (phase, secs) in rep.timer.entries() {
            println!("  {phase:<10} {}", fmt_secs(*secs));
        }
        if let Some(obs) = &rep.obs {
            emit_obs(
                &cfg,
                &picholesky::obs::ledger::LedgerRun {
                    mode: "aloocv",
                    solver: "chol",
                    kernel_backend: picholesky::linalg::kernel::active_backend().name(),
                    fold_strategy: "hat-diagonal",
                    strategy_source: "mode",
                    threads: rep.threads,
                    tasks: rep.tasks,
                    k_folds: rep.n,
                    q_grid: cfg.cv.q_grid,
                    g_samples: cfg.cv.g_samples,
                    seed: cfg.seed,
                    policy: &cfg.cv.recovery,
                    best_lambda: rep.best_lambda,
                    best_error: rep.best_error,
                    wall_secs: rep.wall_secs,
                    degradations: &rep.degradations,
                    certification: rep.certification.as_ref(),
                    timer: &rep.timer,
                    obs,
                },
            )?;
        }
        if args.switch("metrics") {
            print!("{}", coord.metrics.snapshot());
        }
        return Ok(());
    }
    if cfg.cv.mode == CvMode::Loo {
        // leave-one-out: the factor-update subsystem (anchors + downdates);
        // the solver flag does not apply — every solve is Hessian-exact
        println!(
            "dataset={} n={} h={} mode=loo anchors={} grid={}",
            cfg.dataset.name(),
            cfg.n,
            cfg.h,
            cfg.cv.g_samples,
            cfg.cv.q_grid
        );
        let ds = SyntheticDataset::generate(cfg.dataset, cfg.n, cfg.h, cfg.seed);
        let rep = coord.run_loo(&ds, &cfg.cv)?;
        println!(
            "λ* = {:.4e}   LOO-RMSE = {:.4}   wall = {}   skipped = {}/{}",
            rep.best_lambda,
            rep.best_error,
            fmt_secs(rep.wall_secs),
            rep.skipped.len(),
            rep.n * rep.anchor_lambdas.len()
        );
        if !rep.degradations.is_empty() {
            println!(
                "  {} cell(s) served past the downdate rung:",
                rep.degradations.len()
            );
            for d in &rep.degradations {
                println!("    {d}");
            }
        }
        for (lam, rmse) in rep.anchor_lambdas.iter().zip(&rep.anchor_rmse) {
            println!("  anchor λ = {lam:.4e}   exact LOO-RMSE = {rmse:.4}");
        }
        for (phase, secs) in rep.timer.entries() {
            println!("  {phase:<10} {}", fmt_secs(*secs));
        }
        if let Some(obs) = &rep.obs {
            emit_obs(
                &cfg,
                &picholesky::obs::ledger::LedgerRun {
                    mode: "loo",
                    solver: "chol",
                    kernel_backend: picholesky::linalg::kernel::active_backend().name(),
                    fold_strategy: "downdate",
                    strategy_source: "mode",
                    threads: rep.threads,
                    tasks: rep.tasks,
                    k_folds: rep.n,
                    q_grid: cfg.cv.q_grid,
                    g_samples: cfg.cv.g_samples,
                    seed: cfg.seed,
                    policy: &cfg.cv.recovery,
                    best_lambda: rep.best_lambda,
                    best_error: rep.best_error,
                    wall_secs: rep.wall_secs,
                    degradations: &rep.degradations,
                    certification: None,
                    timer: &rep.timer,
                    obs,
                },
            )?;
        }
        if args.switch("metrics") {
            print!("{}", coord.metrics.snapshot());
        }
        return Ok(());
    }
    println!(
        "dataset={} n={} h={} solver={} folds={} grid={} fold_strategy={}",
        cfg.dataset.name(),
        cfg.n,
        cfg.h,
        solver.name(),
        cfg.cv.k_folds,
        cfg.cv.q_grid,
        cfg.cv.fold_strategy.name()
    );
    let ds = SyntheticDataset::generate(cfg.dataset, cfg.n, cfg.h, cfg.seed);
    let rep = coord.run_one(&ds, solver, &cfg.cv)?;
    println!(
        "  kernel_backend={}   resolved_strategy={} (source: {})",
        rep.kernel_backend,
        rep.fold_strategy.name(),
        rep.strategy_source
    );
    if !rep.degradations.is_empty() {
        println!(
            "  {} (fold, λ) cell(s) served past the downdate rung of the recovery ladder:",
            rep.degradations.len()
        );
        for d in &rep.degradations {
            println!("    {d}");
        }
    }
    println!(
        "λ* = {:.4e}   holdout = {:.4}   wall = {}   cpu = {}",
        rep.best_lambda,
        rep.best_error,
        fmt_secs(rep.wall_secs),
        fmt_secs(rep.total_secs())
    );
    for (phase, secs) in rep.timer.entries() {
        println!("  {phase:<10} {}", fmt_secs(*secs));
    }
    if let Some(obs) = &rep.obs {
        emit_obs(
            &cfg,
            &picholesky::obs::ledger::LedgerRun {
                mode: "kfold",
                solver: solver.name(),
                kernel_backend: rep.kernel_backend,
                fold_strategy: rep.fold_strategy.name(),
                strategy_source: rep.strategy_source,
                threads: rep.threads,
                tasks: rep.tasks,
                k_folds: cfg.cv.k_folds,
                q_grid: cfg.cv.q_grid,
                g_samples: cfg.cv.g_samples,
                seed: cfg.seed,
                policy: &cfg.cv.recovery,
                best_lambda: rep.best_lambda,
                best_error: rep.best_error,
                wall_secs: rep.wall_secs,
                degradations: &rep.degradations,
                certification: None,
                timer: &rep.timer,
                obs,
            },
        )?;
    }
    if args.switch("metrics") {
        print!("{}", coord.metrics.snapshot());
    }
    Ok(())
}

/// `pichol serve` — run the streaming CV service over the deterministic
/// traffic replay: seeded rows streamed through the bounded admission
/// queue, snapshot queries interleaved, λ*/θ served from epoch-swapped
/// snapshots. The replay is the service's reference driver (and the
/// `service_replay` bench source) — a pure function of its knobs, bitwise
/// identical at any worker count or admission batch size.
fn cmd_serve(args: &Args) -> Result<()> {
    use picholesky::coordinator::service::{run_replay, ReplayConfig};

    let mut cfg = experiment_config(args)?;
    // service knobs: flags override the [service] section
    cfg.service.window = args.usize_flag("window", cfg.service.window)?;
    cfg.service.refresh_every =
        args.usize_flag("refresh-every", cfg.service.refresh_every)?;
    cfg.service.queue_depth = args.usize_flag("queue-depth", cfg.service.queue_depth)?;
    cfg.service.eval_batch = args.usize_flag("eval-batch", cfg.service.eval_batch)?;
    cfg.service.workers = args.usize_flag("threads", cfg.service.workers)?;
    if let Some(tier) = args.flag("tier") {
        cfg.service.tier = CvMode::parse(tier)
            .ok_or_else(|| anyhow::anyhow!("unknown --tier '{tier}' (loo | aloocv)"))?;
    }
    cfg.validate()?;
    let replay = ReplayConfig {
        rows: cfg.n,
        dim: cfg.h,
        batch: args.usize_flag("batch", ReplayConfig::default().batch)?.max(1),
        queries_per_batch: args
            .usize_flag("queries", ReplayConfig::default().queries_per_batch)?,
        kind: cfg.dataset,
        seed: cfg.seed,
    };
    println!(
        "serve: dataset={} rows={} d={} batch={} window={} refresh_every={} queue_depth={} tier={:?}",
        cfg.dataset.name(),
        replay.rows,
        replay.dim,
        replay.batch,
        cfg.service.window,
        cfg.service.refresh_every,
        cfg.service.queue_depth,
        cfg.service.tier,
    );

    let rep = run_replay(replay, cfg.service, cfg.cv.clone());
    let snap = &rep.final_snapshot;
    println!(
        "λ* = {:.4e}   error = {:.4}   epoch = {}   window rows = {}   wall = {}",
        snap.best_lambda,
        snap.best_error,
        snap.epoch,
        snap.rows,
        fmt_secs(rep.wall_secs)
    );
    println!(
        "  admitted {} rows in {} batches   refreshes = {}   trust: drift ≤ {:.2e}, hops ≤ {}",
        rep.rows_admitted, rep.batches, rep.refreshes, snap.max_relative_drift, snap.max_hops
    );
    let fmt_q = |q: Option<f64>| match q {
        Some(us) => format!("{us:.0}"),
        None => "-".to_string(),
    };
    for (name, h) in [("admit", &rep.admit_hist), ("query", &rep.query_hist)] {
        println!(
            "  {name:<6} latency (µs): p50={} p90={} p99={}  n={}",
            fmt_q(h.quantile_us(0.50)),
            fmt_q(h.quantile_us(0.90)),
            fmt_q(h.quantile_us(0.99)),
            h.count()
        );
    }
    if !rep.degradations.is_empty() {
        println!("  {} degradation(s) recorded:", rep.degradations.len());
        for d in &rep.degradations {
            println!("    {d}");
        }
    }
    for (phase, secs) in rep.timer.entries() {
        println!("  {phase:<14} {}", fmt_secs(*secs));
    }
    if let Some(obs) = &rep.obs {
        emit_obs(
            &cfg,
            &picholesky::obs::ledger::LedgerRun {
                mode: "service",
                solver: "chol",
                kernel_backend: picholesky::linalg::kernel::active_backend().name(),
                fold_strategy: "sliding-window",
                strategy_source: "service",
                threads: rep.threads,
                tasks: rep.batches as usize,
                k_folds: snap.rows,
                q_grid: cfg.cv.q_grid,
                g_samples: cfg.cv.g_samples,
                seed: cfg.seed,
                policy: &cfg.cv.recovery,
                best_lambda: snap.best_lambda,
                best_error: snap.best_error,
                wall_secs: rep.wall_secs,
                degradations: &rep.degradations,
                certification: None,
                timer: &rep.timer,
                obs,
            },
        )?;
    }
    Ok(())
}

fn cmd_compare(args: &Args) -> Result<()> {
    let cfg = experiment_config(args)?;
    let coord = Coordinator::new(cfg.workers.max(1));
    let ds = Arc::new(SyntheticDataset::generate(
        cfg.dataset, cfg.n, cfg.h, cfg.seed,
    ));
    println!(
        "comparing 6 algorithms on {} (n={}, h={})",
        cfg.dataset.name(),
        cfg.n,
        cfg.h
    );
    let reports = coord.run_matrix(ds, &SolverKind::paper_six(), &cfg.cv);
    println!("{:<8} {:>12} {:>12} {:>10}", "algo", "λ*", "holdout", "total");
    for rep in reports {
        let rep = rep?;
        println!(
            "{:<8} {:>12.4e} {:>12.4} {:>10}",
            rep.kind.name(),
            rep.best_lambda,
            rep.best_error,
            fmt_secs(rep.total_secs())
        );
    }
    Ok(())
}

fn cmd_hlo(args: &Args) -> Result<()> {
    let cfg = experiment_config(args)?;
    let engine = Engine::new(&cfg.artifacts_dir)?;
    let entry = engine.config(cfg.h, None, None)?;
    println!(
        "platform: {}   config: {} (n={}, n_val={}, g={}, r={}, m={})",
        engine.platform(),
        entry.tag,
        entry.n,
        entry.n_val,
        entry.g,
        entry.r,
        entry.m
    );

    // dataset sized exactly to the lowered shapes
    let total = entry.n + entry.n_val;
    let ds = SyntheticDataset::generate(cfg.dataset, total, entry.h, cfg.seed);
    let fold = HloFold {
        xt: ds.x.slice(0, entry.n, 0, entry.h),
        yt: ds.y[..entry.n].to_vec(),
        xv: ds.x.slice(entry.n, total, 0, entry.h),
        yv: ds.y[entry.n..].to_vec(),
    };
    let metrics = picholesky::coordinator::Metrics::new();
    let pipe = HloPipeline::new(&engine, entry, &metrics);
    let (lo, hi) = cfg
        .cv
        .lambda_range
        .unwrap_or_else(|| cfg.dataset.lambda_range());

    let t0 = std::time::Instant::now();
    pipe.warmup()?;
    println!("compiled in {}", fmt_secs(t0.elapsed().as_secs_f64()));

    let t0 = std::time::Instant::now();
    let result = pipe.run_fold(&fold, lo, hi)?;
    let pichol_secs = t0.elapsed().as_secs_f64();
    println!(
        "piCholesky (HLO): λ* = {:.4e}  rmse = {:.4}  miscls = {:.4}  in {}",
        result.best_lambda(),
        result.best_rmse(),
        result.miscls[result.best_idx],
        fmt_secs(pichol_secs)
    );

    if args.switch("exact") {
        let t0 = std::time::Instant::now();
        let exact = pipe.run_fold_exact(&fold, lo, hi)?;
        let exact_secs = t0.elapsed().as_secs_f64();
        println!(
            "exact Chol (HLO): λ* = {:.4e}  rmse = {:.4}  in {}  (pichol speedup {:.2}×)",
            exact.best_lambda(),
            exact.best_rmse(),
            fmt_secs(exact_secs),
            exact_secs / pichol_secs
        );
    }
    print!("{}", metrics.snapshot());
    Ok(())
}

fn cmd_experiments(args: &Args) -> Result<()> {
    let out = args.flag("out").unwrap_or("results").to_string();
    let fast = args.switch("fast");
    let seed = args.usize_flag("seed", 42)? as u64;
    let coord = Coordinator::default();

    // sizes: --fast for smoke runs, default for the EXPERIMENTS.md record
    #[allow(clippy::type_complexity)]
    let (t1_dims, f2_ns, f2_hs, f6_hs, big_h, big_n): (
        Vec<usize>,
        Vec<usize>,
        Vec<usize>,
        Vec<usize>,
        usize,
        usize,
    ) = if fast {
        (
            vec![128, 256],
            vec![256, 512],
            vec![32, 64],
            vec![32, 64],
            64,
            256,
        )
    } else {
        (
            vec![256, 512, 1024, 2048],
            vec![512, 1024, 2048, 4096],
            vec![64, 128, 256],
            vec![64, 128, 256, 384],
            256,
            1024,
        )
    };
    let cfg = CvConfig::default();

    let reports = vec![
        experiments::table1::run(&t1_dims, 4, 31, seed),
        experiments::fig2::run(&f2_ns, &f2_hs, cfg.q_grid, seed),
        experiments::fig4::run(if fast { 48 } else { 128 }, 6, 2, 50, seed),
        experiments::fig6_table3::run_fig6(&coord, &f6_hs, 8, &cfg),
        experiments::fig6_table3::run_table3(&coord, big_n, big_h, &cfg),
        experiments::fig7_table4::run_fig7_8(&coord, &DatasetKind::all(), big_n, big_h, &cfg),
        experiments::fig7_table4::run_table4(&coord, big_n, big_h, &cfg),
        experiments::fig9::run(DatasetKind::CoilLike, big_n, big_h, &cfg, seed),
        experiments::fig10::run(
            &coord,
            &DatasetKind::all(),
            big_n,
            if fast { 48 } else { 96 },
            &cfg,
        ),
        experiments::fig11::run(if fast { 48 } else { 128 }, 4, 2, 31, seed),
        experiments::ablations::run_gr(if fast { 24 } else { 64 }, seed),
        experiments::ablations::run_chol_block(
            if fast { 128 } else { 512 },
            &[8, 16, 32, 64, 128, 256],
            3,
            seed,
        ),
        experiments::ablations::run_recursive_h0(
            if fast { 256 } else { 1024 },
            &[4, 8, 16, 32, 64, 128, 256],
            10,
            seed,
        ),
    ];
    for rep in &reports {
        rep.print();
        rep.write_to(&out)?;
    }
    println!("\nwrote {} reports to {out}/", reports.len());
    Ok(())
}

fn cmd_bound(args: &Args) -> Result<()> {
    let h = args.usize_flag("h", 16)?;
    let lambda_c = args.f64_flag("lambda-c", 0.5)?;
    let w = args.f64_flag("w", 0.15)?;
    let gamma = args.f64_flag("gamma", 0.25)?;
    let seed = args.usize_flag("seed", 1)? as u64;

    let a = picholesky::testutil::random_spd(h, 1e3, seed);
    let calc = picholesky::pichol::bound::BoundCalculator::new(a.clone());
    let lams: Vec<f64> = (0..4)
        .map(|i| lambda_c - w + 2.0 * w * i as f64 / 3.0)
        .collect();
    let mut timer = picholesky::util::PhaseTimer::new();
    let interp = picholesky::pichol::fit(
        &a,
        &lams,
        &picholesky::pichol::FitOptions {
            degree: 2,
            strategy: &picholesky::vectorize::RowWise,
        },
        &mut timer,
    )?;
    let bound = calc.thm47_rhs(gamma, w, lambda_c, &lams, 2, 7);
    println!("Theorem 4.7 bound (h={h}, λc={lambda_c}, w={w}, γ={gamma}): {bound:.4e}");
    println!("{:<10} {:>14} {:>14} {:>8}", "λ", "measured", "bound", "ok");
    for i in 0..7 {
        let lam = lambda_c - gamma + 2.0 * gamma * i as f64 / 6.0;
        let approx = interp.eval_factor(lam, &picholesky::vectorize::RowWise);
        let measured = calc.measured_rms_error(lam, &approx);
        println!(
            "{lam:<10.4} {measured:>14.4e} {bound:>14.4e} {:>8}",
            if measured <= bound { "ok" } else { "VIOLATED" }
        );
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = args.flag("artifacts").unwrap_or("artifacts");
    match Engine::new(dir) {
        Ok(engine) => {
            println!("platform: {}", engine.platform());
            println!("artifacts ({dir}):");
            for cfg in &engine.manifest().configs {
                println!(
                    "  {:<22} h={:<5} n={:<6} D={:<9} files={}",
                    cfg.tag,
                    cfg.h,
                    cfg.n,
                    cfg.d_tri,
                    cfg.files.len()
                );
            }
        }
        Err(e) => {
            println!("no artifacts loaded: {e:#}");
            println!("(native path still available: `pichol cv`, `pichol compare`)");
        }
    }
    println!("native linalg: ok (f64, blocked kernels)");
    Ok(())
}
