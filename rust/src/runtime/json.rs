//! Minimal JSON parser for `artifacts/manifest.json`.
//!
//! The offline crate set has no `serde_json`, and the manifest is the only
//! JSON this crate reads, so a ~150-line recursive-descent parser is the
//! whole dependency. Supports the full JSON value grammar (objects, arrays,
//! strings with escapes, numbers, booleans, null).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: &str) -> Result<T, JsonError> {
        Err(JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        })
    }

    fn skip_ws(&mut self) {
        while let Some(&c) = self.b.get(self.pos) {
            if c == b' ' || c == b'\t' || c == b'\n' || c == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            self.err(&format!("expected '{}'", c as char))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("unexpected character"),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            self.err(&format!("expected '{word}'"))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| JsonError {
                                    pos: self.pos,
                                    msg: "bad \\u escape".into(),
                                })?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| JsonError {
                                    pos: self.pos,
                                    msg: "bad \\u escape".into(),
                                })?,
                                16,
                            )
                            .map_err(|_| JsonError {
                                pos: self.pos,
                                msg: "bad \\u escape".into(),
                            })?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return self.err("bad escape"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let text = std::str::from_utf8(&self.b[start..]).map_err(|_| JsonError {
                        pos: start,
                        msg: "invalid utf-8".into(),
                    })?;
                    let ch = text.chars().next().unwrap();
                    s.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError {
                pos: start,
                msg: format!("bad number '{text}'"),
            })
    }
}

/// Parse a complete JSON document.
pub fn parse(text: &str) -> Result<Json, JsonError> {
    let mut p = Parser {
        b: text.as_bytes(),
        pos: 0,
    };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return p.err("trailing data");
    }
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let doc = r#"{
          "artifacts": ["gram", "sweep"],
          "configs": [{"h": 64, "files": {"gram": {"file": "gram_h64.hlo.txt", "bytes": 123}}}]
        }"#;
        let j = parse(doc).unwrap();
        assert_eq!(j.get("artifacts").unwrap().as_arr().unwrap().len(), 2);
        let cfg = &j.get("configs").unwrap().as_arr().unwrap()[0];
        assert_eq!(cfg.get("h").unwrap().as_usize(), Some(64));
        assert_eq!(
            cfg.get("files")
                .unwrap()
                .get("gram")
                .unwrap()
                .get("file")
                .unwrap()
                .as_str(),
            Some("gram_h64.hlo.txt")
        );
    }

    #[test]
    fn scalars_and_escapes() {
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            parse(r#""a\nbA""#).unwrap(),
            Json::Str("a\nbA".into())
        );
    }

    #[test]
    fn nested_arrays() {
        let j = parse("[[1,2],[3]]").unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a[0].as_arr().unwrap()[1].as_f64(), Some(2.0));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
    }
}
