//! PJRT runtime: load the AOT HLO artifacts and execute them from rust.
//!
//! The interchange is HLO **text** (`HloModuleProto::from_text_file`): jax ≥
//! 0.5 emits serialized protos with 64-bit instruction ids that the crate's
//! xla_extension 0.5.1 rejects, while the text parser reassigns ids (see
//! /opt/xla-example/README.md). Every entry point was lowered with
//! `return_tuple=True`, so results always unwrap from a tuple.
//!
//! [`Engine`] owns the PJRT CPU client and a compile-once/execute-many cache
//! keyed by `(artifact, config tag)` — compilation happens at most once per
//! process, execution is the only per-request cost (python is never
//! involved).
//!
//! ## The `pjrt` feature
//!
//! The real engine needs the `xla` bindings, which the offline build
//! environment does not ship. Without `--features pjrt` this module compiles
//! a **stub [`Engine`]** with the same API: it still loads and validates
//! `manifest.json` (so `pichol info` works), but [`Engine::run`] /
//! [`Engine::warmup`] return a descriptive error instead of executing. See
//! the README ("PJRT runtime") and the commented `xla` dependency in
//! `Cargo.toml` for enabling the real path.

pub mod json;
pub mod manifest;

use anyhow::{bail, Result};
#[cfg(feature = "pjrt")]
use anyhow::Context;
#[cfg(feature = "pjrt")]
use std::collections::HashMap;
#[cfg(feature = "pjrt")]
use std::sync::Mutex;

use crate::linalg::matrix::Matrix;
pub use manifest::{ArtifactInfo, ConfigEntry, Manifest};

/// A tensor crossing the PJRT boundary (f32, row-major, shape-carrying).
#[derive(Clone, Debug)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            dims.iter().product::<usize>(),
            data.len(),
            "shape/buffer mismatch"
        );
        Self { dims, data }
    }

    pub fn scalar(v: f64) -> Self {
        Self {
            dims: vec![],
            data: vec![v as f32],
        }
    }

    pub fn from_vec(v: &[f64]) -> Self {
        Self {
            dims: vec![v.len()],
            data: v.iter().map(|&x| x as f32).collect(),
        }
    }

    pub fn from_matrix(m: &Matrix) -> Self {
        Self {
            dims: vec![m.rows(), m.cols()],
            data: m.to_f32_vec(),
        }
    }

    pub fn to_matrix(&self) -> Result<Matrix> {
        match self.dims.as_slice() {
            [r, c] => Ok(Matrix::from_f32(*r, *c, &self.data)),
            d => bail!("tensor is not a matrix: dims {d:?}"),
        }
    }

    pub fn to_vec_f64(&self) -> Vec<f64> {
        self.data.iter().map(|&x| x as f64).collect()
    }

    #[cfg(feature = "pjrt")]
    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(&self.data);
        let dims: Vec<i64> = self.dims.iter().map(|&d| d as i64).collect();
        Ok(lit.reshape(&dims)?)
    }

    #[cfg(feature = "pjrt")]
    fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.shape()?;
        let dims: Vec<usize> = match &shape {
            xla::Shape::Array(a) => a.dims().iter().map(|&d| d as usize).collect(),
            _ => bail!("unexpected non-array result shape"),
        };
        let data = lit.to_vec::<f32>()?;
        Ok(Tensor { dims, data })
    }
}

/// Compile-once, execute-many PJRT engine over a manifest of artifacts.
#[cfg(feature = "pjrt")]
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

#[cfg(feature = "pjrt")]
impl Engine {
    /// Create a CPU engine over `<dir>/manifest.json`.
    pub fn new(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Platform description (for the CLI `info` command).
    pub fn platform(&self) -> String {
        format!(
            "{} ({} devices)",
            self.client.platform_name(),
            self.client.device_count()
        )
    }

    /// Resolve the config for a factor dimension h (optionally g, r).
    pub fn config(&self, h: usize, g: Option<usize>, r: Option<usize>) -> Result<&ConfigEntry> {
        self.manifest.require_config(h, g, r)
    }

    fn executable(
        &self,
        cfg: &ConfigEntry,
        name: &str,
    ) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        let key = format!("{}:{}", cfg.tag, name);
        if let Some(exe) = self.cache.lock().unwrap().get(&key) {
            return Ok(exe.clone());
        }
        let info = cfg.artifact(name)?;
        let path = self.manifest.path_of(info);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling artifact '{name}' ({})", cfg.tag))?,
        );
        self.cache.lock().unwrap().insert(key, exe.clone());
        Ok(exe)
    }

    /// Validate inputs against the manifest shapes, execute, unwrap the tuple.
    pub fn run(&self, cfg: &ConfigEntry, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let info = cfg.artifact(name)?;
        if inputs.len() != info.params.len() {
            bail!(
                "artifact '{name}': expected {} inputs, got {}",
                info.params.len(),
                inputs.len()
            );
        }
        for (i, (t, expect)) in inputs.iter().zip(&info.params).enumerate() {
            if &t.dims != expect {
                bail!(
                    "artifact '{name}' input {i}: shape {:?} != lowered shape {:?}",
                    t.dims,
                    expect
                );
            }
        }
        let exe = self.executable(cfg, name)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(Tensor::to_literal)
            .collect::<Result<_>>()?;
        let result = exe.execute::<xla::Literal>(&literals)?;
        let lit = result[0][0].to_literal_sync()?;
        // return_tuple=True: unwrap all tuple elements
        let parts = lit.to_tuple()?;
        parts.iter().map(Tensor::from_literal).collect()
    }

    /// Warm the compile cache for a config (used by the coordinator at
    /// startup so the request path never compiles).
    pub fn warmup(&self, cfg: &ConfigEntry, names: &[&str]) -> Result<()> {
        for name in names {
            self.executable(cfg, name)?;
        }
        Ok(())
    }
}

/// Stub engine compiled without the `pjrt` feature: same API surface as the
/// real [`Engine`], loads and validates the artifact manifest, but cannot
/// compile or execute HLO — [`Engine::run`] / [`Engine::warmup`] error with
/// instructions for enabling the real runtime.
#[cfg(not(feature = "pjrt"))]
pub struct Engine {
    manifest: Manifest,
}

#[cfg(not(feature = "pjrt"))]
impl Engine {
    /// Create a stub engine over `<dir>/manifest.json` (manifest parsing and
    /// shape validation still run; execution does not).
    pub fn new(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        Ok(Engine { manifest })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Platform description (for the CLI `info` command).
    pub fn platform(&self) -> String {
        "pjrt disabled (rebuild with `--features pjrt` and the xla dependency)".to_string()
    }

    /// Resolve the config for a factor dimension h (optionally g, r).
    pub fn config(&self, h: usize, g: Option<usize>, r: Option<usize>) -> Result<&ConfigEntry> {
        self.manifest.require_config(h, g, r)
    }

    /// Always errors: executing artifacts needs the `pjrt` feature.
    pub fn run(&self, _cfg: &ConfigEntry, name: &str, _inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        bail!(
            "cannot execute artifact '{name}': this build has no PJRT runtime \
             (enable the `pjrt` feature and the xla dependency in rust/Cargo.toml)"
        )
    }

    /// Always errors: compiling artifacts needs the `pjrt` feature.
    pub fn warmup(&self, _cfg: &ConfigEntry, names: &[&str]) -> Result<()> {
        bail!(
            "cannot compile artifacts {names:?}: this build has no PJRT runtime \
             (enable the `pjrt` feature and the xla dependency in rust/Cargo.toml)"
        )
    }
}

#[cfg(test)]
mod tests {
    //! Integration tests against the real artifacts live in
    //! `rust/tests/runtime_integration.rs`; these only cover the Tensor
    //! marshalling helpers (no PJRT needed).
    use super::*;

    #[test]
    fn tensor_shape_checks() {
        let t = Tensor::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.dims, vec![2, 3]);
        let m = t.to_matrix().unwrap();
        assert_eq!((m.rows(), m.cols()), (2, 3));
        assert!(Tensor::new(vec![6], vec![0.0; 6]).to_matrix().is_err());
    }

    #[test]
    #[should_panic(expected = "shape/buffer mismatch")]
    fn tensor_rejects_bad_buffer() {
        let _ = Tensor::new(vec![2, 2], vec![0.0; 5]);
    }

    #[test]
    fn tensor_matrix_roundtrip() {
        let m = crate::testutil::random_matrix(3, 4, 1);
        let t = Tensor::from_matrix(&m);
        let back = t.to_matrix().unwrap();
        assert!(m.max_abs_diff(&back) < 1e-6);
    }
}
