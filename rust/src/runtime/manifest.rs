//! Typed view of `artifacts/manifest.json` — the contract between the
//! build-time python lowering (`python/compile/aot.py`) and the rust runtime.

use super::json::{parse, Json};
use anyhow::{anyhow, bail, Context};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One lowered HLO artifact.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    /// File name under the artifacts directory.
    pub file: String,
    /// Parameter shapes the function was lowered at (row-major dims).
    pub params: Vec<Vec<usize>>,
    /// Size in bytes (sanity-checked on load).
    pub bytes: usize,
}

/// One shape configuration (mirrors `python/compile/shapes.PiCholConfig`).
#[derive(Clone, Debug)]
pub struct ConfigEntry {
    pub h: usize,
    pub n: usize,
    pub n_val: usize,
    pub g: usize,
    pub r: usize,
    pub m: usize,
    pub d_tri: usize,
    /// Vector length of the HLO path's flattening (h² — full-matrix layout,
    /// see EXPERIMENTS.md §Perf for why not the triangle).
    pub d_vec: usize,
    pub d_pad: usize,
    pub tag: String,
    pub files: BTreeMap<String, ArtifactInfo>,
}

/// The parsed manifest plus its directory (for resolving file paths).
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub configs: Vec<ConfigEntry>,
}

fn usize_field(j: &Json, key: &str) -> anyhow::Result<usize> {
    j.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow!("manifest: missing numeric field '{key}'"))
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} — run `make artifacts` first", path.display()))?;
        let j = parse(&text).map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;

        let mut configs = Vec::new();
        for cj in j
            .get("configs")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest: missing 'configs' array"))?
        {
            let mut files = BTreeMap::new();
            for (name, fj) in cj
                .get("files")
                .and_then(Json::as_obj)
                .ok_or_else(|| anyhow!("manifest: config missing 'files'"))?
            {
                let params = fj
                    .get("params")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("manifest: artifact '{name}' missing params"))?
                    .iter()
                    .map(|p| {
                        p.as_arr()
                            .map(|dims| dims.iter().filter_map(Json::as_usize).collect())
                            .ok_or_else(|| anyhow!("manifest: bad param shape in '{name}'"))
                    })
                    .collect::<anyhow::Result<Vec<Vec<usize>>>>()?;
                files.insert(
                    name.clone(),
                    ArtifactInfo {
                        file: fj
                            .get("file")
                            .and_then(Json::as_str)
                            .ok_or_else(|| anyhow!("manifest: artifact '{name}' missing file"))?
                            .to_string(),
                        params,
                        bytes: usize_field(fj, "bytes")?,
                    },
                );
            }
            configs.push(ConfigEntry {
                h: usize_field(cj, "h")?,
                n: usize_field(cj, "n")?,
                n_val: usize_field(cj, "n_val")?,
                g: usize_field(cj, "g")?,
                r: usize_field(cj, "r")?,
                m: usize_field(cj, "m")?,
                d_tri: usize_field(cj, "d_tri")?,
                d_vec: usize_field(cj, "d_vec")?,
                d_pad: usize_field(cj, "d_pad")?,
                tag: cj
                    .get("tag")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("manifest: config missing tag"))?
                    .to_string(),
                files,
            });
        }
        if configs.is_empty() {
            bail!("manifest has no configs — re-run `make artifacts`");
        }
        Ok(Manifest { dir, configs })
    }

    /// Find the config for a given h (and optionally g/r).
    pub fn config_for(&self, h: usize, g: Option<usize>, r: Option<usize>) -> Option<&ConfigEntry> {
        self.configs.iter().find(|c| {
            c.h == h && g.map(|v| c.g == v).unwrap_or(true) && r.map(|v| c.r == v).unwrap_or(true)
        })
    }

    /// [`Manifest::config_for`] with the standard error message — shared by
    /// the real and stub `runtime::Engine` so the two `cfg` branches cannot
    /// drift.
    pub fn require_config(
        &self,
        h: usize,
        g: Option<usize>,
        r: Option<usize>,
    ) -> anyhow::Result<&ConfigEntry> {
        self.config_for(h, g, r).ok_or_else(|| {
            anyhow!(
                "no AOT config for h={h} (g={g:?}, r={r:?}); re-run `make artifacts` \
                 with a matching shapes.CONFIGS entry"
            )
        })
    }

    /// Absolute path of one artifact file.
    pub fn path_of(&self, info: &ArtifactInfo) -> PathBuf {
        self.dir.join(&info.file)
    }
}

impl ConfigEntry {
    /// Look up one artifact by name, with a helpful error.
    pub fn artifact(&self, name: &str) -> anyhow::Result<&ArtifactInfo> {
        self.files
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest for {}", self.tag))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn repo_artifacts() -> Option<Manifest> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        Manifest::load(dir).ok()
    }

    #[test]
    fn loads_real_manifest_when_present() {
        let Some(m) = repo_artifacts() else {
            eprintln!("skipping: run `make artifacts`");
            return;
        };
        assert!(!m.configs.is_empty());
        let c = m.config_for(64, None, None).expect("h=64 config");
        assert_eq!(c.d_tri, 64 * 65 / 2);
        let gram = c.artifact("gram").unwrap();
        assert_eq!(gram.params[0], vec![c.n, c.h]);
        // file exists and size matches
        let meta = std::fs::metadata(m.path_of(gram)).unwrap();
        assert_eq!(meta.len() as usize, gram.bytes);
    }

    #[test]
    fn config_for_filters() {
        let Some(m) = repo_artifacts() else {
            return;
        };
        assert!(m.config_for(256, Some(6), Some(3)).is_some());
        assert!(m.config_for(256, Some(4), Some(2)).is_some());
        assert!(m.config_for(999, None, None).is_none());
    }
}
