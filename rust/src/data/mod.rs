//! Datasets and feature maps.
//!
//! The paper evaluates on MNIST, COIL-100, Caltech-101 and Caltech-256,
//! projected with the Kar–Karnick randomized polynomial kernel (MNIST/COIL)
//! or a spatial-pyramid pipeline (Caltech) to h−1 dimensions, then converted
//! to balanced 2-class problems (§6.1, Table 2).
//!
//! Those corpora are unavailable offline, so [`synthetic`] generates
//! deterministic Gaussian-mixture stand-ins with the same raw dimensionality
//! and class structure (see DESIGN.md §3 for why this preserves behaviour:
//! every algorithm under test touches the data only through `H = XᵀX` and
//! `g = Xᵀy`). [`features`] implements the Kar–Karnick map itself — the same
//! construction the paper runs, not a stand-in. [`folds`] does the k-fold
//! splitting, and [`gram`] is the shared-Gram pipeline: `XᵀX`/`Xᵀy`
//! assembled once per dataset (streamed in row blocks, bitwise-deterministic
//! reduction), from which every fold's Hessian is derived by downdate.

pub mod features;
pub mod folds;
pub mod gram;
pub mod synthetic;

pub use folds::{kfold, Fold};
pub use gram::GramCache;
pub use synthetic::{DatasetKind, SyntheticDataset};
