//! The shared-Gram data pipeline: assemble `G = XᵀX` and `g = Xᵀy` exactly
//! once per dataset, streaming rows in blocks.
//!
//! ## Why
//!
//! The Figure-1 pipeline used to rebuild `H = X_tᵀX_t` from scratch for
//! every fold — `O(k·n·d²)` of Gram work plus `k` near-full copies of the
//! dataset. Standard hold-out algebra collapses that: with one global Gram
//! `G = XᵀX`, each fold's Hessian is the cheap **downdate**
//! `H_f = G − X_vᵀX_v` (and `g_f = g − X_vᵀy_v`), touching only the small
//! validation block — `O(n·d²/k)` per fold, `O(n·d²)` total. The assembly
//! itself streams `X` in row blocks, so only one block (not the dataset)
//! needs to be resident per task: the seam an out-of-core / sharded backend
//! plugs into. A *grown or shrunk* dataset never reassembles either:
//! [`GramCache::append_rows`] / [`GramCache::retire_rows`] fold a row block
//! in or out at `O(m·d²)`, and
//! [`crate::cv::loo::AnchorFactors`] keeps cached `chol(G + λI)` anchor
//! factors in step by rank-m update/downdate ([`crate::linalg::chud`]).
//!
//! ## Determinism contract — why the streamed Gram is bitwise exact
//!
//! The packed kernel ([`crate::linalg::kernel`]) chunks its `k` extent at
//! absolute `KC`-multiples (`0..KC, KC..2KC, …`), accumulates each chunk
//! into a fresh register tile in ascending `k` order, and folds chunk
//! partials into the output in ascending chunk order (`Set` first, `+=`
//! after). The streaming accumulator reproduces *exactly that schedule* from
//! outside the kernel: row **segments are aligned to the same global
//! `KC`-multiples** ([`SEGMENT_ROWS`] `==` [`kernel::KC`]), each segment's
//! partial is one packed SYRK whose `k` extent fits in a single internal
//! chunk (so its bits equal the corresponding chunk tile of a full-extent
//! call), and the reduction folds segment partials **in ascending segment
//! order** — first segment copied, later ones `+=`, the same scalar ops in
//! the same order as the kernel's own fold. Consequences, pinned by tests:
//!
//! - `GramCache` assembly is **bitwise identical to a single
//!   [`syrk_lower`]** over the whole dataset;
//! - it is bitwise independent of the `chunk_rows` knob (chunks snap to
//!   whole segments, and the reduction is per *segment*, not per chunk) and
//!   of the worker count (any worker may compute any segment — a segment's
//!   bits are a pure function of its rows — and
//!   [`WorkerPool::map_scratch`] returns results in input order, so the
//!   fold order never depends on scheduling).
//!
//! The gradient `g = Xᵀy` uses the same fixed per-segment fold (its own
//! schedule, a pure function of `n` alone): bitwise stable across chunk
//! sizes and worker counts, and within ordinary rounding of a monolithic
//! [`gemv_t`].
//!
//! The per-fold consumers are the downdate kernels
//! ([`crate::linalg::gemm::gram_downdate`] /
//! [`crate::linalg::gemm::syrk_lower_downdate_into`]) wired up by
//! [`crate::cv::FoldData::from_gram`] and scheduled by the sweep engine's
//! fold-prep wave.

use crate::coordinator::pool::WorkerPool;
use crate::linalg::gemm::{gemv_t, syrk_lower, syrk_lower_bands_into};
use crate::linalg::kernel::{self, Acc};
use crate::linalg::matrix::Matrix;
use crate::linalg::scratch::Scratch;
use std::fmt;

/// Structured rejection of a bad ingest block — the validation gate of the
/// numerical-trust subsystem (see [`crate::cv::recovery`]).
///
/// A single NaN row silently poisons the *entire* Gram (every `G[i][j]`
/// touching that row goes NaN, then every fold Hessian, then every factor),
/// so non-finite data must be stopped at the door rather than diagnosed
/// three layers deep as a mysterious [`crate::linalg::cholesky::CholeskyError`].
/// Both the dataset entry points ([`validate_rows`], called by
/// `cv::run_cv` / the sweep engine's LOO path) and the streaming mutator
/// [`GramCache::append_rows`] reject with this error instead of asserting.
#[derive(Debug, Clone, PartialEq)]
pub enum IngestError {
    /// A feature value is NaN or ±Inf.
    NonFinite { row: usize, col: usize, value: f64 },
    /// A label is NaN or ±Inf.
    NonFiniteLabel { row: usize, value: f64 },
    /// An appended block's feature dimension disagrees with the cache.
    DimMismatch { expected: usize, got: usize },
    /// Feature rows and labels disagree in count.
    LabelMismatch { rows: usize, labels: usize },
}

impl fmt::Display for IngestError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            IngestError::NonFinite { row, col, value } => {
                write!(f, "non-finite feature value {value} at row {row}, col {col}")
            }
            IngestError::NonFiniteLabel { row, value } => {
                write!(f, "non-finite label {value} at row {row}")
            }
            IngestError::DimMismatch { expected, got } => {
                write!(f, "feature dimension mismatch: cache holds {expected}, block has {got}")
            }
            IngestError::LabelMismatch { rows, labels } => {
                write!(f, "row/label count mismatch: {rows} rows vs {labels} labels")
            }
        }
    }
}

impl std::error::Error for IngestError {}

/// Validate one (features, labels) block for ingest: matching row/label
/// counts and every value finite. Returns the **first** offender (row-major
/// over features, then labels) so the error names a reproducible location.
pub fn validate_rows(x: &Matrix, y: &[f64]) -> Result<(), IngestError> {
    if x.rows() != y.len() {
        return Err(IngestError::LabelMismatch {
            rows: x.rows(),
            labels: y.len(),
        });
    }
    for r in 0..x.rows() {
        for (c, &v) in x.row(r).iter().enumerate() {
            if !v.is_finite() {
                return Err(IngestError::NonFinite { row: r, col: c, value: v });
            }
        }
    }
    for (r, &v) in y.iter().enumerate() {
        if !v.is_finite() {
            return Err(IngestError::NonFiniteLabel { row: r, value: v });
        }
    }
    Ok(())
}

/// Row-segment length of the streaming accumulator — equal to the packed
/// kernel's `KC` so every segment is exactly one internal k-chunk of a
/// full-extent SYRK (the keystone of the bitwise-exactness argument above).
pub const SEGMENT_ROWS: usize = kernel::KC;

/// The dataset-global Gram pair: `G = XᵀX` (full symmetric) and `g = Xᵀy`,
/// assembled once and shared (behind an `Arc`) by every fold's downdate.
pub struct GramCache {
    h: Matrix,
    g: Vec<f64>,
    n: usize,
}

/// Resolve the `chunk_rows` knob: `0` = auto (one segment per task); any
/// other value is rounded **up** to a whole number of [`SEGMENT_ROWS`]
/// segments, so chunk boundaries always land on the fixed accumulation
/// grid and the knob can never perturb a result bit.
pub fn effective_chunk_rows(chunk_rows: usize) -> usize {
    if chunk_rows == 0 {
        SEGMENT_ROWS
    } else {
        chunk_rows.div_ceil(SEGMENT_ROWS) * SEGMENT_ROWS
    }
}

/// The task plan: contiguous `[lo, hi)` row ranges, one per pool task, each
/// covering whole segments of the fixed accumulation grid.
pub fn chunk_ranges(n: usize, chunk_rows: usize) -> Vec<(usize, usize)> {
    let eff = effective_chunk_rows(chunk_rows);
    (0..n)
        .step_by(eff)
        .map(|lo| (lo, (lo + eff).min(n)))
        .collect()
}

/// One segment's Gram contribution over global rows `[lo, hi)` of `x`:
/// lower-triangle SYRK bands (k extent ≤ [`SEGMENT_ROWS`] — a single kernel
/// chunk) plus the matching `Xᵀy` slice. `ph`/`pg` are fully overwritten.
fn segment_partial_into(
    x: &Matrix,
    y: &[f64],
    lo: usize,
    hi: usize,
    ph: &mut Matrix,
    pg: &mut [f64],
) {
    debug_assert!(hi - lo <= SEGMENT_ROWS);
    syrk_lower_bands_into(x, lo, hi, ph, Acc::Set);
    pg.fill(0.0);
    for i in lo..hi {
        let yi = y[i];
        for (o, &xij) in pg.iter_mut().zip(x.row(i)) {
            *o += yi * xij;
        }
    }
}

/// Owned variant of [`segment_partial_into`] for callers that cache
/// partials long-term (the streaming window seals one per
/// [`SEGMENT_ROWS`]-aligned segment). Same code path as [`GramCache::assemble`],
/// so a later [`fold_partials`] over these is bitwise a fresh assembly.
pub(crate) fn segment_partial(x: &Matrix, y: &[f64], lo: usize, hi: usize) -> (Matrix, Vec<f64>) {
    let hdim = x.cols();
    let mut ph = Matrix::zeros(hdim, hdim);
    let mut pg = vec![0.0; hdim];
    segment_partial_into(x, y, lo, hi, &mut ph, &mut pg);
    (ph, pg)
}

/// Rebuild a [`GramCache`] from cached per-segment partials, folded in the
/// order given. When every partial covers exactly [`SEGMENT_ROWS`] rows
/// except possibly the last, this is **bitwise identical** to
/// [`GramCache::assemble`] over the concatenated rows — the identical
/// copy-first-then-add reduction over the identical per-segment bits. The
/// streaming window leans on this to repair incremental drift at refresh
/// without the `O(n·d²)` reassembly ever diverging from the from-scratch
/// oracle.
pub(crate) fn fold_partials<'a>(
    partials: impl IntoIterator<Item = (&'a Matrix, &'a [f64])>,
    hdim: usize,
    n: usize,
) -> GramCache {
    let mut red = GramReducer::new(hdim);
    for (ph, pg) in partials {
        red.fold(ph, pg);
    }
    red.finish(n)
}

/// The ordered reduction: fold per-segment partials into the running
/// accumulators in ascending segment order (copy the first, `+=` the rest —
/// the same op sequence as the packed kernel's internal chunk fold).
struct GramReducer {
    h: Matrix,
    g: Vec<f64>,
    seen: usize,
}

impl GramReducer {
    fn new(hdim: usize) -> Self {
        Self {
            h: Matrix::zeros(hdim, hdim),
            g: vec![0.0; hdim],
            seen: 0,
        }
    }

    fn fold(&mut self, ph: &Matrix, pg: &[f64]) {
        if self.seen == 0 {
            self.h.copy_from(ph);
            self.g.copy_from_slice(pg);
        } else {
            for (d, &s) in self.h.as_mut_slice().iter_mut().zip(ph.as_slice()) {
                *d += s;
            }
            for (d, &s) in self.g.iter_mut().zip(pg) {
                *d += s;
            }
        }
        self.seen += 1;
    }

    fn finish(mut self, n: usize) -> GramCache {
        self.h.mirror_lower();
        GramCache {
            h: self.h,
            g: self.g,
            n,
        }
    }
}

impl GramCache {
    /// Serial streaming assembly: one pass over `X` in [`SEGMENT_ROWS`]
    /// blocks, ordered fold. Bitwise identical to [`Self::assemble_pooled`]
    /// at any chunk size / worker count, and to a monolithic
    /// [`syrk_lower`] of the whole dataset.
    pub fn assemble(x: &Matrix, y: &[f64]) -> GramCache {
        assert_eq!(x.rows(), y.len(), "dataset shape mismatch");
        let hdim = x.cols();
        let mut red = GramReducer::new(hdim);
        let mut ph = Matrix::zeros(hdim, hdim);
        let mut pg = vec![0.0; hdim];
        for (lo, hi) in chunk_ranges(x.rows(), SEGMENT_ROWS) {
            segment_partial_into(x, y, lo, hi, &mut ph, &mut pg);
            red.fold(&ph, &pg);
        }
        red.finish(x.rows())
    }

    /// Pool-parallel streaming assembly: each task owns a gathered row
    /// block of `ceil(chunk_rows / SEGMENT_ROWS)` segments and returns its
    /// per-segment partials; the coordinating thread folds them in
    /// ascending segment order. Tasks are dispatched in **waves of one
    /// chunk per worker** and each wave is folded before the next is
    /// gathered, so peak residency is bounded by `workers` row blocks plus
    /// their partials — never the whole dataset (the streaming claim an
    /// out-of-core backend inherits). See the module docs for why the
    /// result is bitwise independent of both knobs.
    pub fn assemble_pooled(
        x: &Matrix,
        y: &[f64],
        chunk_rows: usize,
        pool: &WorkerPool,
    ) -> GramCache {
        assert_eq!(x.rows(), y.len(), "dataset shape mismatch");
        let hdim = x.cols();
        type ChunkOut = Vec<(Matrix, Vec<f64>)>;
        let ranges = chunk_ranges(x.rows(), chunk_rows);
        let mut red = GramReducer::new(hdim);
        for wave in ranges.chunks(pool.size().max(1)) {
            let jobs: Vec<Box<dyn FnOnce(&mut Scratch) -> ChunkOut + Send>> = wave
                .iter()
                .map(|&(lo, hi)| {
                    // stream: gather this task's row block; the job owns it
                    let block = x.slice(lo, hi, 0, hdim);
                    let yb = y[lo..hi].to_vec();
                    let f: Box<dyn FnOnce(&mut Scratch) -> ChunkOut + Send> =
                        Box::new(move |_scratch| {
                            let rows = block.rows();
                            (0..rows)
                                .step_by(SEGMENT_ROWS)
                                .map(|slo| {
                                    let shi = (slo + SEGMENT_ROWS).min(rows);
                                    let mut ph = Matrix::zeros(hdim, hdim);
                                    let mut pg = vec![0.0; hdim];
                                    segment_partial_into(
                                        &block, &yb, slo, shi, &mut ph, &mut pg,
                                    );
                                    (ph, pg)
                                })
                                .collect()
                        });
                    f
                })
                .collect();
            // map_scratch returns task results in input order, waves run in
            // range order, and segments within a task are ascending → the
            // fold is globally ascending
            for chunk in pool.map_scratch(jobs) {
                for (ph, pg) in &chunk {
                    red.fold(ph, pg);
                }
            }
        }
        red.finish(x.rows())
    }

    /// Fold `m` newly arrived rows into the cache **incrementally**:
    /// `G += X_newᵀX_new` (one rank-m SYRK over just the new block, through
    /// the packed kernel) and `g += X_newᵀy_new` — `O(m·d²)` instead of the
    /// `O(n·d²)` reassembly. The companion
    /// [`crate::cv::loo::AnchorFactors::append_rows`] keeps cached anchor
    /// factors fresh the same way (rank-m Cholesky update).
    ///
    /// Incremental accumulation inserts the new block *after* the original
    /// fold sequence, so the result is rounding-level (not bitwise) equal to
    /// a fresh assembly of the grown dataset — same contract as the
    /// per-fold downdates.
    ///
    /// The block is validated before any mutation ([`validate_rows`] plus a
    /// feature-dimension check): on [`Err`]`(`[`IngestError`]`)` the cache
    /// is untouched — a half-folded poisoned block would be unrecoverable.
    pub fn append_rows(&mut self, x_new: &Matrix, y_new: &[f64]) -> Result<(), IngestError> {
        if x_new.cols() != self.h.rows() {
            return Err(IngestError::DimMismatch {
                expected: self.h.rows(),
                got: x_new.cols(),
            });
        }
        validate_rows(x_new, y_new)?;
        syrk_lower_bands_into(x_new, 0, x_new.rows(), &mut self.h, Acc::Add);
        self.h.mirror_lower();
        for (i, &yi) in y_new.iter().enumerate() {
            for (gj, &xij) in self.g.iter_mut().zip(x_new.row(i)) {
                *gj += yi * xij;
            }
        }
        self.n += x_new.rows();
        Ok(())
    }

    /// Remove `m` retired rows incrementally: `G −= X_oldᵀX_old`,
    /// `g −= X_oldᵀy_old` (the streaming-window counterpart of
    /// [`GramCache::append_rows`]; the subtraction is the same banded SYRK
    /// downdate the per-fold Hessians use). The caller is responsible for
    /// passing rows that are actually in the cache — the Gram itself cannot
    /// check.
    pub fn retire_rows(&mut self, x_old: &Matrix, y_old: &[f64]) {
        assert_eq!(x_old.rows(), y_old.len(), "retired block shape mismatch");
        assert_eq!(x_old.cols(), self.h.rows(), "retired block dim mismatch");
        assert!(x_old.rows() <= self.n, "cannot retire more rows than held");
        syrk_lower_bands_into(x_old, 0, x_old.rows(), &mut self.h, Acc::Sub);
        self.h.mirror_lower();
        for (i, &yi) in y_old.iter().enumerate() {
            for (gj, &xij) in self.g.iter_mut().zip(x_old.row(i)) {
                *gj -= yi * xij;
            }
        }
        self.n -= x_old.rows();
    }

    /// The global Gram `G = XᵀX` (full symmetric).
    pub fn hessian(&self) -> &Matrix {
        &self.h
    }

    /// The global gradient `g = Xᵀy`.
    pub fn gradient(&self) -> &[f64] {
        &self.g
    }

    /// Rows of the dataset the cache was assembled from.
    pub fn n_rows(&self) -> usize {
        self.n
    }

    /// Consume into `(G, g)` (the Figure-2 pipeline measures these
    /// directly).
    pub fn into_parts(self) -> (Matrix, Vec<f64>) {
        (self.h, self.g)
    }
}

/// Convenience: the full-dataset reference pair `(XᵀX, Xᵀy)` via the
/// monolithic kernels — the oracle the streamed assembly is tested against.
pub fn reference_gram(x: &Matrix, y: &[f64]) -> (Matrix, Vec<f64>) {
    (syrk_lower(x), gemv_t(x, y))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::random_matrix;

    fn dataset(n: usize, h: usize, seed: u64) -> (Matrix, Vec<f64>) {
        let x = random_matrix(n, h, seed);
        let y: Vec<f64> = (0..n).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        (x, y)
    }

    #[test]
    fn chunk_ranges_cover_and_align() {
        for &(n, chunk) in &[(1000, 0), (1000, 7), (1000, 64), (1000, 1000), (3, 0), (513, 512)] {
            let ranges = chunk_ranges(n, chunk);
            assert_eq!(ranges.first().unwrap().0, 0);
            assert_eq!(ranges.last().unwrap().1, n);
            for w in ranges.windows(2) {
                assert_eq!(w[0].1, w[1].0, "ranges must tile contiguously");
            }
            for &(lo, _) in &ranges {
                assert_eq!(lo % SEGMENT_ROWS, 0, "chunk starts must be segment-aligned");
            }
        }
        assert!(chunk_ranges(0, 0).is_empty());
    }

    #[test]
    fn streamed_gram_is_bitwise_the_monolithic_syrk() {
        // the keystone: segment-aligned streaming reproduces the packed
        // kernel's own internal chunk fold, bit for bit — across sizes that
        // are below, at, and past the KC boundary
        for &(n, h) in &[(37, 9), (SEGMENT_ROWS, 17), (SEGMENT_ROWS + 3, 17), (700, 33)] {
            let (x, y) = dataset(n, h, 0x6AA + n as u64);
            let cache = GramCache::assemble(&x, &y);
            let (href, gref) = reference_gram(&x, &y);
            assert_eq!(
                cache.hessian().as_slice(),
                href.as_slice(),
                "streamed Gram must be bitwise identical to syrk_lower at n={n} h={h}"
            );
            // the gradient has its own fixed fold — rounding-level equal
            for (a, b) in cache.gradient().iter().zip(&gref) {
                assert!((a - b).abs() < 1e-11, "n={n} h={h}: {a} vs {b}");
            }
            assert_eq!(cache.n_rows(), n);
        }
    }

    #[test]
    fn assembly_bitwise_identical_across_chunk_sizes_and_worker_counts() {
        // the satellite acceptance grid: chunks {7, 64, n} × workers {1, 2, 4}
        let n = 700;
        let (x, y) = dataset(n, 21, 0xC0FFEE);
        let serial = GramCache::assemble(&x, &y);
        for workers in [1usize, 2, 4] {
            let pool = WorkerPool::new(workers);
            for chunk in [7usize, 64, n] {
                let pooled = GramCache::assemble_pooled(&x, &y, chunk, &pool);
                assert_eq!(
                    pooled.hessian().as_slice(),
                    serial.hessian().as_slice(),
                    "Gram bits drifted at chunk={chunk} workers={workers}"
                );
                assert_eq!(
                    pooled.gradient(),
                    serial.gradient(),
                    "gradient bits drifted at chunk={chunk} workers={workers}"
                );
            }
        }
    }

    #[test]
    fn append_and_retire_rows_track_fresh_assembly() {
        let (n, h, m) = (300usize, 13usize, 45usize);
        let (x, y) = dataset(n + m, h, 0xA99);
        let x0 = x.slice(0, n, 0, h);
        let y0 = y[..n].to_vec();
        let x_new = x.slice(n, n + m, 0, h);
        let y_new = y[n..].to_vec();

        let mut cache = GramCache::assemble(&x0, &y0);
        cache.append_rows(&x_new, &y_new).unwrap();
        assert_eq!(cache.n_rows(), n + m);
        let full = GramCache::assemble(&x, &y);
        assert!(
            cache.hessian().max_abs_diff(full.hessian()) < 1e-9,
            "grown Gram drift {:.2e}",
            cache.hessian().max_abs_diff(full.hessian())
        );
        for (a, b) in cache.gradient().iter().zip(full.gradient()) {
            assert!((a - b).abs() < 1e-10);
        }
        // symmetry survives the incremental band update + mirror
        for i in 0..h {
            for j in 0..h {
                assert_eq!(cache.hessian()[(i, j)], cache.hessian()[(j, i)]);
            }
        }

        // retire the same block: back to the original window
        cache.retire_rows(&x_new, &y_new);
        assert_eq!(cache.n_rows(), n);
        let base = GramCache::assemble(&x0, &y0);
        assert!(cache.hessian().max_abs_diff(base.hessian()) < 1e-9);
        for (a, b) in cache.gradient().iter().zip(base.gradient()) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    /// The streaming-window keystone: refolding cached segment partials is
    /// bitwise a fresh assembly whenever the partials sit on the
    /// [`SEGMENT_ROWS`] grid — including a short tail, and including a
    /// window whose oldest segments were dropped (survivors re-partialed
    /// from row 0 of the surviving block).
    #[test]
    fn refolding_segment_partials_is_bitwise_a_fresh_assembly() {
        let n = 3 * SEGMENT_ROWS + 5;
        let (x, y) = dataset(n, 11, 0x5EA1);
        let partials: Vec<(Matrix, Vec<f64>)> = chunk_ranges(n, SEGMENT_ROWS)
            .into_iter()
            .map(|(lo, hi)| segment_partial(&x, &y, lo, hi))
            .collect();
        let refolded = fold_partials(
            partials.iter().map(|(ph, pg)| (ph, pg.as_slice())),
            11,
            n,
        );
        let fresh = GramCache::assemble(&x, &y);
        assert_eq!(refolded.hessian().as_slice(), fresh.hessian().as_slice());
        assert_eq!(refolded.gradient(), fresh.gradient());
        assert_eq!(refolded.n_rows(), n);

        // drop the oldest segment (a window retirement): survivors start at
        // a segment boundary, so their partials are unchanged — the refold
        // must match assembling the surviving rows from scratch
        let survivors = x.slice(SEGMENT_ROWS, n, 0, 11);
        let ys = y[SEGMENT_ROWS..].to_vec();
        let retired = fold_partials(
            partials[1..].iter().map(|(ph, pg)| (ph, pg.as_slice())),
            11,
            n - SEGMENT_ROWS,
        );
        let fresh2 = GramCache::assemble(&survivors, &ys);
        assert_eq!(retired.hessian().as_slice(), fresh2.hessian().as_slice());
        assert_eq!(retired.gradient(), fresh2.gradient());
    }

    /// Ingest validation pins the exact offender: NaN/Inf features, NaN
    /// labels, and row/label miscounts each map to their structured variant,
    /// and a clean block passes.
    #[test]
    fn validate_rows_rejects_non_finite_and_mismatched_blocks() {
        let (x, y) = dataset(30, 7, 0xBAD);
        assert_eq!(validate_rows(&x, &y), Ok(()));

        let mut xb = x.clone();
        xb[(12, 3)] = f64::NAN;
        match validate_rows(&xb, &y) {
            Err(IngestError::NonFinite { row: 12, col: 3, value }) => assert!(value.is_nan()),
            other => panic!("expected NonFinite at (12, 3), got {other:?}"),
        }

        let mut xb = x.clone();
        xb[(0, 0)] = f64::INFINITY;
        assert!(matches!(
            validate_rows(&xb, &y),
            Err(IngestError::NonFinite { row: 0, col: 0, .. })
        ));

        let mut yb = y.clone();
        yb[5] = f64::NEG_INFINITY;
        assert!(matches!(
            validate_rows(&x, &yb),
            Err(IngestError::NonFiniteLabel { row: 5, .. })
        ));

        assert_eq!(
            validate_rows(&x, &y[..29]),
            Err(IngestError::LabelMismatch { rows: 30, labels: 29 })
        );
    }

    /// A rejected append must leave the cache bitwise untouched — validation
    /// happens before any accumulation.
    #[test]
    fn append_rows_rejects_bad_blocks_without_mutating() {
        let (x, y) = dataset(60, 7, 0xFACE);
        let mut cache = GramCache::assemble(&x, &y);
        let before_h = cache.hessian().as_slice().to_vec();
        let before_g = cache.gradient().to_vec();

        let mut x_bad = random_matrix(4, 7, 9);
        x_bad[(2, 5)] = f64::NAN;
        let y_bad = vec![1.0; 4];
        assert!(matches!(
            cache.append_rows(&x_bad, &y_bad),
            Err(IngestError::NonFinite { row: 2, col: 5, .. })
        ));

        let x_narrow = random_matrix(4, 5, 9);
        assert_eq!(
            cache.append_rows(&x_narrow, &y_bad),
            Err(IngestError::DimMismatch { expected: 7, got: 5 })
        );

        assert_eq!(cache.hessian().as_slice(), &before_h[..]);
        assert_eq!(cache.gradient(), &before_g[..]);
        assert_eq!(cache.n_rows(), 60);

        // error text names the location (what a log line will show)
        let err = IngestError::NonFinite { row: 2, col: 5, value: f64::NAN };
        assert!(err.to_string().contains("row 2, col 5"), "{err}");
    }

    #[test]
    fn gram_is_symmetric() {
        let (x, y) = dataset(130, 11, 5);
        let cache = GramCache::assemble(&x, &y);
        let h = cache.hessian();
        for i in 0..11 {
            for j in 0..11 {
                assert_eq!(h[(i, j)], h[(j, i)]);
            }
        }
    }

    #[test]
    fn downdate_agrees_with_direct_fold_hessians_on_odd_folds() {
        use crate::data::folds::kfold;
        use crate::linalg::gemm::gram_downdate;
        // n not divisible by k, including k == n (single-row validation)
        for &(n, k) in &[(103usize, 5usize), (10, 10), (67, 4)] {
            let (x, y) = dataset(n, 13, 0xF01D + n as u64);
            let cache = GramCache::assemble(&x, &y);
            let mut h_out = Matrix::zeros(0, 0);
            let mut g_out = Vec::new();
            for fold in kfold(n, k, 3) {
                let (xt, yt) = fold.materialize_train(&x, &y);
                let (xv, yv) = fold.materialize_val(&x, &y);
                gram_downdate(
                    cache.hessian(),
                    cache.gradient(),
                    &xv,
                    &yv,
                    &mut h_out,
                    &mut g_out,
                );
                let (hd, gd) = reference_gram(&xt, &yt);
                assert!(
                    h_out.max_abs_diff(&hd) < 1e-10,
                    "H_f mismatch at n={n} k={k}: {:.2e}",
                    h_out.max_abs_diff(&hd)
                );
                for (a, b) in g_out.iter().zip(&gd) {
                    assert!((a - b).abs() < 1e-10, "g_f mismatch at n={n} k={k}");
                }
            }
        }
    }
}
