//! Kar–Karnick randomized polynomial-kernel feature maps.
//!
//! The paper projects its image data with "the randomized polynomial kernel
//! [17]" (Kar & Karnick, *Random Feature Maps for Dot Product Kernels*,
//! AISTATS 2012). For the degree-p dot-product kernel `k(x,z) = (xᵀz)^p`,
//! each random feature is
//!
//! ```text
//!   φ_j(x) = a_j · Π_{t=1..p} (ω_{j,t}ᵀ x),     ω entries Rademacher ±1
//! ```
//!
//! so that `E[φ(x)ᵀφ(z)] = k(x, z)`. This module implements the exact
//! construction (it needs only a seeded PRNG, so unlike the image corpora it
//! is *not* a stand-in — see DESIGN.md §3).

use crate::linalg::matrix::Matrix;
use crate::prng::Xoshiro256;

/// A sampled degree-`p` random polynomial feature map raw_dim → out_dim.
pub struct KarKarnickMap {
    /// ω vectors: `p` banks of out_dim × raw_dim Rademacher matrices.
    banks: Vec<Matrix>,
    raw_dim: usize,
    out_dim: usize,
    degree: usize,
}

impl KarKarnickMap {
    /// Sample a map. Each of the `degree` banks holds one ω per output
    /// feature; the normalization 1/√out_dim makes the feature inner product
    /// an unbiased kernel estimate.
    pub fn new(raw_dim: usize, out_dim: usize, degree: usize, seed: u64) -> Self {
        assert!(degree >= 1);
        let mut rng = Xoshiro256::seed_from(seed);
        let banks = (0..degree)
            .map(|_| Matrix::from_fn(out_dim, raw_dim, |_, _| rng.rademacher()))
            .collect();
        Self {
            banks,
            raw_dim,
            out_dim,
            degree,
        }
    }

    pub fn out_dim(&self) -> usize {
        self.out_dim
    }

    /// Map one raw sample.
    pub fn apply_one(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.raw_dim);
        let norm = 1.0 / (self.out_dim as f64).sqrt();
        let mut out = vec![norm; self.out_dim];
        for bank in &self.banks {
            for (j, o) in out.iter_mut().enumerate() {
                let dot: f64 = bank.row(j).iter().zip(x).map(|(w, v)| w * v).sum();
                *o *= dot;
            }
        }
        out
    }

    /// Map a whole n×raw_dim matrix to n×out_dim (row-blocked GEMM per bank,
    /// then a Hadamard product across banks — BLAS-3 all the way).
    pub fn apply(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.cols(), self.raw_dim);
        let n = x.rows();
        let gem = crate::linalg::gemm::Gemm::default();
        let norm = 1.0 / (self.out_dim as f64).sqrt();
        let mut out = Matrix::from_fn(n, self.out_dim, |_, _| norm);
        for bank in &self.banks {
            // proj = X · bankᵀ  (n × out_dim)
            let proj = gem.a_bt(x, bank);
            for (o, p) in out.as_mut_slice().iter_mut().zip(proj.as_slice()) {
                *o *= p;
            }
        }
        out
    }

    pub fn degree(&self) -> usize {
        self.degree
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::random_matrix;

    #[test]
    fn batch_matches_single() {
        let map = KarKarnickMap::new(20, 15, 2, 1);
        let x = random_matrix(6, 20, 2);
        let batch = map.apply(&x);
        for i in 0..6 {
            let one = map.apply_one(x.row(i));
            for j in 0..15 {
                assert!((batch[(i, j)] - one[j]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn kernel_estimate_is_unbiased() {
        // φ(x)ᵀφ(z) ≈ (xᵀz)^p for large out_dim
        let raw = 10;
        let mut rng = crate::prng::Xoshiro256::seed_from(3);
        let x: Vec<f64> = (0..raw).map(|_| rng.normal() / (raw as f64).sqrt()).collect();
        let z: Vec<f64> = (0..raw).map(|_| rng.normal() / (raw as f64).sqrt()).collect();
        let exact: f64 = x.iter().zip(&z).map(|(a, b)| a * b).sum::<f64>().powi(2);

        let out_dim = 20_000;
        let map = KarKarnickMap::new(raw, out_dim, 2, 7);
        let fx = map.apply_one(&x);
        let fz = map.apply_one(&z);
        let est: f64 = fx.iter().zip(&fz).map(|(a, b)| a * b).sum();
        assert!(
            (est - exact).abs() < 0.05 * exact.abs().max(0.05),
            "kernel estimate {est} vs exact {exact}"
        );
    }

    #[test]
    fn degree_one_is_linear_projection() {
        let map = KarKarnickMap::new(8, 4, 1, 5);
        let x: Vec<f64> = (0..8).map(|i| i as f64).collect();
        let y: Vec<f64> = (0..8).map(|i| (7 - i) as f64).collect();
        let sum: Vec<f64> = x.iter().zip(&y).map(|(a, b)| a + b).collect();
        let fx = map.apply_one(&x);
        let fy = map.apply_one(&y);
        let fsum = map.apply_one(&sum);
        for j in 0..4 {
            assert!((fsum[j] - fx[j] - fy[j]).abs() < 1e-10, "not linear at {j}");
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let a = KarKarnickMap::new(6, 5, 2, 11);
        let b = KarKarnickMap::new(6, 5, 2, 11);
        let x: Vec<f64> = (0..6).map(|i| (i as f64).cos()).collect();
        assert_eq!(a.apply_one(&x), b.apply_one(&x));
    }
}
