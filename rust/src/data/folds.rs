//! k-fold cross-validation splits.
//!
//! The paper's pipeline (Figure 1) runs the whole Hessian + Cholesky-sweep
//! machinery once per fold; these splits are shuffled once with a seeded
//! permutation so every algorithm sees identical folds.

use crate::linalg::matrix::Matrix;
use crate::prng::Xoshiro256;

/// One train/validation split (index sets into the parent dataset).
#[derive(Clone, Debug)]
pub struct Fold {
    pub train: Vec<usize>,
    pub val: Vec<usize>,
}

/// Standard shuffled k-fold split of `n` samples.
pub fn kfold(n: usize, k: usize, seed: u64) -> Vec<Fold> {
    assert!(k >= 2 && k <= n, "need 2 <= k <= n");
    let mut rng = Xoshiro256::seed_from(seed ^ 0xF01D);
    let perm = rng.permutation(n);
    let mut folds = Vec::with_capacity(k);
    for f in 0..k {
        let lo = f * n / k;
        let hi = (f + 1) * n / k;
        let val: Vec<usize> = perm[lo..hi].to_vec();
        let train: Vec<usize> = perm[..lo].iter().chain(&perm[hi..]).copied().collect();
        folds.push(Fold { train, val });
    }
    folds
}

/// Gather the rows of `x`/`y` named by `idx` into a fresh owned split.
pub fn gather_rows(idx: &[usize], x: &Matrix, y: &[f64]) -> (Matrix, Vec<f64>) {
    let h = x.cols();
    let mut xm = Matrix::zeros(idx.len(), h);
    let mut ym = Vec::with_capacity(idx.len());
    for (r, &i) in idx.iter().enumerate() {
        xm.row_mut(r).copy_from_slice(x.row(i));
        ym.push(y[i]);
    }
    (xm, ym)
}

impl Fold {
    /// Materialize (X_train, y_train, X_val, y_val) for this fold.
    ///
    /// On the shared-Gram pipeline this is the *slow* path: the sweep engine
    /// gathers only the validation block ([`Fold::materialize_val`]) and
    /// derives the fold Hessian by downdating the global Gram
    /// ([`crate::data::gram::GramCache`]); the training split is gathered
    /// only for solvers that need `X` itself (the SVD family).
    pub fn materialize(&self, x: &Matrix, y: &[f64]) -> (Matrix, Vec<f64>, Matrix, Vec<f64>) {
        let (xt, yt) = self.materialize_train(x, y);
        let (xv, yv) = self.materialize_val(x, y);
        (xt, yt, xv, yv)
    }

    /// Gather only the training split (X_train, y_train).
    pub fn materialize_train(&self, x: &Matrix, y: &[f64]) -> (Matrix, Vec<f64>) {
        gather_rows(&self.train, x, y)
    }

    /// Gather only the validation split (X_val, y_val) — all a fold needs on
    /// the Gram-downdate fast path.
    pub fn materialize_val(&self, x: &Matrix, y: &[f64]) -> (Matrix, Vec<f64>) {
        gather_rows(&self.val, x, y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::random_matrix;

    #[test]
    fn partition_properties() {
        let folds = kfold(103, 5, 1);
        assert_eq!(folds.len(), 5);
        let mut seen = vec![0usize; 103];
        for f in &folds {
            assert_eq!(f.train.len() + f.val.len(), 103);
            for &i in &f.val {
                seen[i] += 1;
            }
            // train ∩ val = ∅
            let tset: std::collections::HashSet<_> = f.train.iter().collect();
            assert!(f.val.iter().all(|i| !tset.contains(i)));
        }
        // every sample is validated exactly once
        assert!(seen.iter().all(|&c| c == 1));
    }

    #[test]
    fn deterministic() {
        let a = kfold(50, 5, 7);
        let b = kfold(50, 5, 7);
        for (fa, fb) in a.iter().zip(&b) {
            assert_eq!(fa.val, fb.val);
        }
    }

    #[test]
    fn materialize_gathers_rows() {
        let x = random_matrix(10, 3, 1);
        let y: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let folds = kfold(10, 5, 2);
        let (xt, yt, xv, yv) = folds[0].materialize(&x, &y);
        assert_eq!(xt.rows(), 8);
        assert_eq!(xv.rows(), 2);
        for (r, &i) in folds[0].val.iter().enumerate() {
            assert_eq!(yv[r], i as f64);
            assert_eq!(xv.row(r), x.row(i));
        }
        for (r, &i) in folds[0].train.iter().enumerate() {
            assert_eq!(yt[r], y[i]);
        }
    }
}
