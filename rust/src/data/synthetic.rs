//! Deterministic synthetic stand-ins for the paper's image datasets.
//!
//! Each dataset is a balanced 2-class Gaussian mixture in the original raw
//! dimensionality of its namesake (Table 2), with multiple sub-clusters per
//! class (images of a digit/object vary by style/pose) and anisotropic
//! covariance (pixel correlations). The parameters are tuned so that after
//! the random-feature projection the hold-out-error curve over λ is convex
//! with an interior optimum — the regime the paper's experiments live in.

use crate::linalg::matrix::Matrix;
use crate::prng::Xoshiro256;

/// Which paper dataset to imitate (raw dims follow paper Table 2).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DatasetKind {
    /// MNIST-like: 28×28 = 784 raw dims, 10 sub-clusters/class, mild noise.
    MnistLike,
    /// COIL-100-like: 784 raw dims, many small clusters (100 objects × poses).
    CoilLike,
    /// Caltech-101-like: high raw dim (spatial-pyramid-ish), few samples/class.
    Caltech101Like,
    /// Caltech-256-like: as above, more classes → harder, error near 1 in the
    /// paper's NRMSE-style units.
    Caltech256Like,
}

impl DatasetKind {
    /// Raw dimensionality before the random-feature projection.
    pub fn raw_dim(&self) -> usize {
        match self {
            DatasetKind::MnistLike | DatasetKind::CoilLike => 784,
            // the paper uses 320×200 images through a spatial pyramid; we use
            // a 2048-dim descriptor stand-in (the projection target is what
            // matters for the algorithms)
            DatasetKind::Caltech101Like | DatasetKind::Caltech256Like => 2048,
        }
    }

    /// Sub-clusters per class (style/pose variation).
    fn clusters_per_class(&self) -> usize {
        match self {
            DatasetKind::MnistLike => 5,
            DatasetKind::CoilLike => 12,
            DatasetKind::Caltech101Like => 8,
            DatasetKind::Caltech256Like => 16,
        }
    }

    /// Label noise rate (fraction of flipped labels) — drives the achievable
    /// hold-out error floor, mimicking the paper's per-dataset error levels
    /// (MNIST ≈ 0.36, COIL ≈ 0.45, Caltech-256 ≈ 0.94 in RMSE units).
    fn label_noise(&self) -> f64 {
        match self {
            DatasetKind::MnistLike => 0.04,
            DatasetKind::CoilLike => 0.08,
            DatasetKind::Caltech101Like => 0.15,
            DatasetKind::Caltech256Like => 0.30,
        }
    }

    /// Cluster separation (in units of within-cluster std).
    fn separation(&self) -> f64 {
        match self {
            DatasetKind::MnistLike => 2.2,
            DatasetKind::CoilLike => 1.8,
            DatasetKind::Caltech101Like => 1.2,
            DatasetKind::Caltech256Like => 0.7,
        }
    }

    /// Paper λ search range for this dataset (§6.3).
    pub fn lambda_range(&self) -> (f64, f64) {
        match self {
            DatasetKind::Caltech101Like => (1e-8, 1e-5),
            _ => (1e-3, 1.0),
        }
    }

    /// Post-projection feature scale. Ridge's optimal λ scales with the Gram
    /// scale (λ* ∝ ‖X‖²), so this constant places each dataset's optimum
    /// inside its paper search range: raw samples are unit-normalized before
    /// the degree-2 kernel map (k(x,x) = 1), and Caltech-101's tiny paper
    /// range [10⁻⁸, 10⁻⁵] is reached by shrinking its features ~10⁻³.
    fn feature_scale(&self) -> f64 {
        match self {
            DatasetKind::Caltech101Like => 1e-3,
            _ => 0.12,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DatasetKind::MnistLike => "mnist-like",
            DatasetKind::CoilLike => "coil100-like",
            DatasetKind::Caltech101Like => "caltech101-like",
            DatasetKind::Caltech256Like => "caltech256-like",
        }
    }

    /// All four, in the paper's column order.
    pub fn all() -> [DatasetKind; 4] {
        [
            DatasetKind::MnistLike,
            DatasetKind::CoilLike,
            DatasetKind::Caltech101Like,
            DatasetKind::Caltech256Like,
        ]
    }
}

/// A generated dataset, already projected to the working dimension d = h−1
/// (an intercept column of ones is appended, giving h columns total, matching
/// the paper's `X` being n×(d+1)).
pub struct SyntheticDataset {
    pub kind: DatasetKind,
    /// n×h design matrix (last column = intercept ones).
    pub x: Matrix,
    /// ±1 labels.
    pub y: Vec<f64>,
    /// Seed used (for reproducibility records in EXPERIMENTS.md).
    pub seed: u64,
}

impl SyntheticDataset {
    /// Generate `n` samples projected to `h−1` feature dims (+1 intercept).
    ///
    /// Pipeline mirrors §6.1: raw mixture sample → Kar–Karnick random
    /// polynomial feature map (degree 2) → append intercept → ±1 labels with
    /// dataset-specific noise.
    pub fn generate(kind: DatasetKind, n: usize, h: usize, seed: u64) -> Self {
        assert!(h >= 2, "need at least one feature plus intercept");
        let raw_dim = kind.raw_dim();
        let mut rng = Xoshiro256::seed_from(seed ^ 0xDA7A_5E1D);

        // --- mixture parameters ---
        let k = kind.clusters_per_class();
        let sep = kind.separation();
        // cluster centres: scaled Gaussian directions in raw space
        let mut centres: Vec<(f64, Vec<f64>)> = Vec::with_capacity(2 * k);
        for class in 0..2 {
            let sign = if class == 0 { 1.0 } else { -1.0 };
            for _ in 0..k {
                let c: Vec<f64> = (0..raw_dim)
                    .map(|_| rng.normal() * sep / (raw_dim as f64).sqrt())
                    .collect();
                centres.push((sign, c));
            }
        }
        // anisotropy: per-coordinate scales (pixel-like correlated variances)
        let scales: Vec<f64> = (0..raw_dim)
            .map(|j| 0.3 + 0.7 * ((j as f64 * 0.37).sin().abs()))
            .collect();

        // --- raw samples ---
        let mut raw = Matrix::zeros(n, raw_dim);
        let mut y = Vec::with_capacity(n);
        let noise = kind.label_noise();
        for i in 0..n {
            let cidx = rng.below(centres.len() as u64) as usize;
            let (sign, centre) = &centres[cidx];
            let row = raw.row_mut(i);
            let mut sq = 0.0;
            for j in 0..raw_dim {
                row[j] = centre[j] + rng.normal() * scales[j] / (raw_dim as f64).sqrt();
                sq += row[j] * row[j];
            }
            // unit-normalize the raw sample (standard for polynomial-kernel
            // pipelines: k(x,x) = (xᵀx)² = 1 after this)
            let inv = 1.0 / sq.sqrt().max(1e-12);
            for v in row.iter_mut() {
                *v *= inv;
            }
            let mut label = *sign;
            if rng.uniform() < noise {
                label = -label;
            }
            y.push(label);
        }

        // --- Kar–Karnick projection to h−1 dims, then scale + intercept ---
        let feat = super::features::KarKarnickMap::new(raw_dim, h - 1, 2, seed ^ 0xFEA7);
        let projected = feat.apply(&raw);
        let fscale = kind.feature_scale();
        let mut x = Matrix::zeros(n, h);
        for i in 0..n {
            for j in 0..h - 1 {
                x[(i, j)] = projected[(i, j)] * fscale;
            }
            x[(i, h - 1)] = 1.0; // intercept
        }

        Self { kind, x, y, seed }
    }

    pub fn n(&self) -> usize {
        self.x.rows()
    }

    pub fn h(&self) -> usize {
        self.x.cols()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_labels() {
        let ds = SyntheticDataset::generate(DatasetKind::MnistLike, 200, 33, 1);
        assert_eq!(ds.x.rows(), 200);
        assert_eq!(ds.x.cols(), 33);
        assert_eq!(ds.y.len(), 200);
        assert!(ds.y.iter().all(|&v| v == 1.0 || v == -1.0));
        // intercept column
        for i in 0..200 {
            assert_eq!(ds.x[(i, 32)], 1.0);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = SyntheticDataset::generate(DatasetKind::CoilLike, 64, 17, 9);
        let b = SyntheticDataset::generate(DatasetKind::CoilLike, 64, 17, 9);
        let c = SyntheticDataset::generate(DatasetKind::CoilLike, 64, 17, 10);
        assert!(a.x.max_abs_diff(&b.x) == 0.0);
        assert!(a.x.max_abs_diff(&c.x) > 0.0);
    }

    #[test]
    fn roughly_balanced_classes() {
        let ds = SyntheticDataset::generate(DatasetKind::MnistLike, 1000, 17, 2);
        let pos = ds.y.iter().filter(|&&v| v > 0.0).count();
        assert!(
            (pos as f64 - 500.0).abs() < 120.0,
            "class balance off: {pos}/1000"
        );
    }

    #[test]
    fn linearly_learnable_signal_exists() {
        // ridge on the generated features must beat chance on held-out data
        let ds = SyntheticDataset::generate(DatasetKind::MnistLike, 400, 33, 3);
        let (tr, va) = (300, 100);
        let xt = ds.x.slice(0, tr, 0, 33);
        let xv = ds.x.slice(tr, tr + va, 0, 33);
        let h = crate::linalg::gemm::syrk_lower(&xt);
        let g = crate::linalg::gemm::gemv_t(&xt, &ds.y[..tr]);
        let l = crate::linalg::cholesky::cholesky_shifted(&h, 1.0).unwrap();
        let th = crate::linalg::triangular::solve_cholesky(&l, &g);
        let pred = crate::linalg::gemm::gemv(&xv, &th);
        let errs = pred
            .iter()
            .zip(&ds.y[tr..])
            .filter(|(p, y)| p.signum() != y.signum())
            .count();
        assert!(
            (errs as f64) / (va as f64) < 0.35,
            "misclassification too high: {errs}/{va}"
        );
    }
}
