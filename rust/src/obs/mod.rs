//! Deterministic observability: structured task tracing, log-bucketed
//! latency histograms, and a machine-readable run ledger.
//!
//! Everything here is **off by default and free when off**: the engine
//! arms a [`trace::RunObs`] (pre-sized per-worker event rings) only when
//! the run's config asks for observation, and every hot-path call site
//! carries an `Option` that short-circuits to nothing when disarmed —
//! zero allocation, zero atomics, zero branches beyond the `None` check.
//! When armed, observation is *deterministic in content*: task ids are
//! allocated in construction order on the coordinating thread, the
//! merged event log is sorted by `(task_id, attempt)`, and histograms
//! merge commutatively — so the observable record (minus wall-clock
//! payload) is bit-identical at any worker count, exactly like the
//! numeric results it describes. `tests/obs.rs` pins both halves of the
//! contract: obs-on runs are bitwise identical to obs-off runs, and the
//! event-log content is worker-count invariant.
//!
//! - [`trace`]: per-worker lock-free event rings, the `(task_id,
//!   attempt)` merge, and the Chrome trace-event exporter (`--trace-out`,
//!   viewable in `chrome://tracing` / Perfetto).
//! - [`hist`]: HDR-style powers-of-√2 latency histograms, exact-count
//!   and mergeable in any order; p50/p90/p99 per phase and per task kind.
//! - [`ledger`]: one JSONL file per run (`--ledger-out`) capturing config
//!   provenance, every degradation, certification verdicts, and the
//!   histogram summaries.

pub mod hist;
pub mod ledger;
pub mod trace;

pub use hist::{Hist, PhaseHists};
pub use trace::{Event, Outcome, RunObs};

use std::sync::Arc;

/// Write `contents` to `path` via temp file + atomic rename, so a reader
/// racing the writer never observes a truncated file and a crashed run
/// never leaves one behind (same discipline as the bench harness).
pub(crate) fn write_atomic(path: &str, contents: &str) -> crate::Result<()> {
    let tmp = format!("{path}.tmp");
    std::fs::write(&tmp, contents)?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// The per-run observation record carried on every report when the run
/// was armed: the merged event log plus latency histograms per phase
/// (from the instrumented `PhaseTimer`s) and per task kind (derived from
/// event spans at collect time).
#[derive(Clone, Debug, Default)]
pub struct ObsReport {
    /// Merged event log in ascending `(task_id, attempt)` order.
    pub events: Vec<Event>,
    /// Events lost to ring overflow (0 in any correctly-sized run).
    pub dropped: u64,
    /// Latency histograms keyed by `PhaseTimer` phase name.
    pub phase_hists: PhaseHists,
    /// Latency histograms keyed by event kind.
    pub kind_hists: PhaseHists,
}

impl ObsReport {
    /// Drain `obs` (after all waves quiesced) and pair the merged event
    /// log with the phase histograms harvested from the run's timers.
    pub fn from_run(obs: &Arc<RunObs>, phase_hists: PhaseHists) -> ObsReport {
        let (events, dropped) = obs.collect();
        let mut kind_hists = PhaseHists::new();
        for e in &events {
            kind_hists.record(e.kind, e.stop_us.saturating_sub(e.start_us) * 1000);
        }
        ObsReport {
            events,
            dropped,
            phase_hists,
            kind_hists,
        }
    }

    /// The deterministic content of the event log: everything except the
    /// wall-clock/worker payload. Identical at any worker count — the
    /// acceptance tuple of `tests/obs.rs` and the `ci.sh --obs` gate.
    pub fn content_tuples(&self) -> Vec<(u32, u32, &'static str, &'static str)> {
        self.events
            .iter()
            .map(|e| (e.task_id, e.attempt, e.kind, e.outcome.name()))
            .collect()
    }
}
