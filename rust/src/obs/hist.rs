//! Log-bucketed latency histograms: HDR-style powers-of-√2 buckets,
//! exact counts, mergeable in any order.
//!
//! Values are `u64` nanoseconds. Bucket `b` covers the half-open value
//! range `(√2^b, √2^(b+1)]` (zero is counted separately), so two buckets
//! per power of two give every bucket a ≤ ~41% relative width — enough
//! resolution for p50/p90/p99 while keeping the whole histogram a fixed
//! 128-slot array that merges by element-wise addition. Because counts
//! are exact and addition is commutative/associative, merging per-worker
//! histograms in any order yields identical bucket counts and therefore
//! identical quantiles — the same partition-independence contract the
//! numeric kernels follow.
//!
//! The bucket index of `v > 0` is `2k + u` where `k = ⌊log2 v⌋`
//! (computed as `63 − leading_zeros`, no `ilog2` needed) and `u = 1` iff
//! `v ≥ 2^(k+½)`, decided exactly in integers by `v² ≥ 2^(2k+1)`
//! (the square is taken in `u128` so `v` up to `2⁶⁴−1` cannot overflow).

use std::f64::consts::SQRT_2;

/// Number of value buckets: two per power of two, `k ∈ 0..64`.
pub const BUCKETS: usize = 128;

/// Saturating `f64` seconds → `u64` nanoseconds. `NaN` and negatives
/// map to 0; values at or beyond `u64::MAX` ns (~584 years) saturate.
pub fn secs_to_nanos(secs: f64) -> u64 {
    if !(secs > 0.0) {
        return 0; // NaN, zero, negative
    }
    let ns = secs * 1e9;
    if ns >= u64::MAX as f64 {
        u64::MAX
    } else {
        ns as u64
    }
}

/// Bucket index for `v > 0`: `2k + u`, see module docs.
fn bucket_index(v: u64) -> usize {
    debug_assert!(v > 0);
    let k = (63 - v.leading_zeros()) as usize;
    let upper = (v as u128) * (v as u128) >= 2u128 << (2 * k);
    (2 * k + usize::from(upper)).min(BUCKETS - 1)
}

/// Representative (upper-bound) value of bucket `b`, in nanoseconds.
fn bucket_upper(b: usize) -> f64 {
    let k = (b / 2) as i32;
    if b % 2 == 0 {
        2f64.powi(k) * SQRT_2
    } else {
        2f64.powi(k + 1)
    }
}

/// One mergeable log-bucketed histogram (values in nanoseconds).
#[derive(Clone, Debug, PartialEq)]
pub struct Hist {
    zero: u64,
    total: u64,
    buckets: [u64; BUCKETS],
}

impl Default for Hist {
    fn default() -> Self {
        Hist {
            zero: 0,
            total: 0,
            buckets: [0u64; BUCKETS],
        }
    }
}

impl Hist {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one value (nanoseconds).
    pub fn record(&mut self, v: u64) {
        if v == 0 {
            self.zero += 1;
        } else {
            self.buckets[bucket_index(v)] += 1;
        }
        self.total += 1;
    }

    /// Record one duration in seconds (saturating conversion).
    pub fn record_secs(&mut self, secs: f64) {
        self.record(secs_to_nanos(secs));
    }

    /// Total number of recorded samples.
    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Element-wise merge; order of merges never changes the result.
    pub fn merge(&mut self, other: &Hist) {
        self.zero += other.zero;
        self.total += other.total;
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
    }

    /// Quantile `q ∈ [0,1]` in nanoseconds (bucket upper bound), or
    /// `None` when the histogram is empty. `q = 0` returns the bucket
    /// of the smallest sample, `q = 1` of the largest.
    pub fn quantile(&self, q: f64) -> Option<f64> {
        if self.total == 0 {
            return None;
        }
        let q = q.clamp(0.0, 1.0);
        let target = ((self.total as f64 * q).ceil() as u64).clamp(1, self.total);
        let mut cum = self.zero;
        if target <= cum {
            return Some(0.0);
        }
        for (b, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if target <= cum {
                return Some(bucket_upper(b));
            }
        }
        Some(bucket_upper(BUCKETS - 1))
    }

    /// Quantile in microseconds (the reporting unit).
    pub fn quantile_us(&self, q: f64) -> Option<f64> {
        self.quantile(q).map(|ns| ns / 1e3)
    }
}

/// Named histograms (per phase or per task kind), kept sorted by name so
/// every rendering of the collection is deterministic regardless of the
/// order phases were first observed in.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PhaseHists {
    entries: Vec<(String, Hist)>,
}

impl PhaseHists {
    pub fn new() -> Self {
        Self::default()
    }

    fn hist_mut(&mut self, name: &str) -> &mut Hist {
        match self.entries.binary_search_by(|(n, _)| n.as_str().cmp(name)) {
            Ok(i) => &mut self.entries[i].1,
            Err(i) => {
                self.entries.insert(i, (name.to_string(), Hist::new()));
                &mut self.entries[i].1
            }
        }
    }

    /// Record one sample (nanoseconds) under `name`.
    pub fn record(&mut self, name: &str, nanos: u64) {
        self.hist_mut(name).record(nanos);
    }

    /// Record one duration in seconds under `name`.
    pub fn record_secs(&mut self, name: &str, secs: f64) {
        self.hist_mut(name).record_secs(secs);
    }

    /// Merge another collection in; any merge order yields the same state.
    pub fn merge(&mut self, other: &PhaseHists) {
        for (name, h) in &other.entries {
            self.hist_mut(name).merge(h);
        }
    }

    pub fn get(&self, name: &str) -> Option<&Hist> {
        self.entries
            .binary_search_by(|(n, _)| n.as_str().cmp(name))
            .ok()
            .map(|i| &self.entries[i].1)
    }

    /// Sorted `(name, hist)` pairs.
    pub fn entries(&self) -> &[(String, Hist)] {
        &self.entries
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_is_monotone_and_sqrt2_spaced() {
        // v = 1 lands in bucket 0; v = 2 in bucket 2 (one power up).
        assert_eq!(bucket_index(1), 0);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 3); // 3 ≥ 2^1.5 ≈ 2.83
        let mut prev = 0;
        for shift in 0..63 {
            let v = 1u64 << shift;
            let b = bucket_index(v);
            assert!(b >= prev, "bucket index must be monotone");
            prev = b;
        }
    }

    #[test]
    fn bucket_upper_bounds_contain_their_values() {
        for v in [1u64, 2, 3, 7, 1000, 123_456, u64::MAX / 2] {
            let b = bucket_index(v);
            assert!(
                bucket_upper(b) >= v as f64 * 0.999_999,
                "v={v} above its bucket upper bound {}",
                bucket_upper(b)
            );
            if b > 0 {
                assert!(
                    bucket_upper(b - 1) < v as f64 * SQRT_2,
                    "v={v} far below its bucket"
                );
            }
        }
    }

    #[test]
    fn quantiles_track_the_data() {
        let mut h = Hist::new();
        for v in 1..=1000u64 {
            h.record(v * 1000); // 1µs .. 1ms
        }
        let p50 = h.quantile(0.5).unwrap();
        let p99 = h.quantile(0.99).unwrap();
        // bucket resolution is √2: the estimate is within ~41% above truth
        assert!(p50 >= 500_000.0 && p50 <= 500_000.0 * SQRT_2 * 1.01);
        assert!(p99 >= 990_000.0 && p99 <= 990_000.0 * SQRT_2 * 1.01);
        assert!(h.quantile(0.0).unwrap() <= h.quantile(1.0).unwrap());
    }

    #[test]
    fn empty_histogram_has_no_quantiles() {
        let h = Hist::new();
        assert_eq!(h.quantile(0.5), None);
        assert_eq!(h.quantile_us(0.99), None);
        assert_eq!(h.count(), 0);
    }

    #[test]
    fn zero_values_are_exact() {
        let mut h = Hist::new();
        h.record(0);
        h.record(0);
        h.record(10);
        assert_eq!(h.quantile(0.1), Some(0.0));
        assert_eq!(h.count(), 3);
    }

    #[test]
    fn merge_is_order_independent() {
        let vals: Vec<u64> = (0..500).map(|i| (i * 7919 + 13) % 100_000).collect();
        let mut whole = Hist::new();
        for &v in &vals {
            whole.record(v);
        }
        let mut a = Hist::new();
        let mut b = Hist::new();
        let mut c = Hist::new();
        for (i, &v) in vals.iter().enumerate() {
            [&mut a, &mut b, &mut c][i % 3].record(v);
        }
        let mut ab_c = a.clone();
        ab_c.merge(&b);
        ab_c.merge(&c);
        let mut c_b_a = c.clone();
        c_b_a.merge(&b);
        c_b_a.merge(&a);
        assert_eq!(ab_c, c_b_a);
        assert_eq!(ab_c, whole);
    }

    #[test]
    fn secs_to_nanos_saturates() {
        assert_eq!(secs_to_nanos(f64::NAN), 0);
        assert_eq!(secs_to_nanos(-1.0), 0);
        assert_eq!(secs_to_nanos(f64::INFINITY), u64::MAX);
        assert_eq!(secs_to_nanos(1e30), u64::MAX);
        assert_eq!(secs_to_nanos(1.5), 1_500_000_000);
    }

    #[test]
    fn phase_hists_sorted_and_mergeable() {
        let mut p = PhaseHists::new();
        p.record("zeta", 10);
        p.record("alpha", 20);
        p.record("zeta", 30);
        let names: Vec<&str> = p.entries().iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, ["alpha", "zeta"]);
        assert_eq!(p.get("zeta").unwrap().count(), 2);
        let mut q = PhaseHists::new();
        q.record("alpha", 40);
        q.record("mid", 50);
        p.merge(&q);
        let names: Vec<&str> = p.entries().iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, ["alpha", "mid", "zeta"]);
        assert_eq!(p.get("alpha").unwrap().count(), 2);
    }
}
